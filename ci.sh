#!/usr/bin/env bash
# CI entry point: build, test, and smoke-run the dynamic-replay path.
#
#   ./ci.sh          fast checks (tier-1 + replay smoke)
#   ./ci.sh --bench  also runs the fig11 elastic bench (reduced budgets)
#
# The test suite runs twice. HETRL_TEST_THREADS=n replaces the
# determinism tests' thread matrix with {1, n} (testing::fixtures):
# the =1 pass pins that everything passes with a purely sequential
# engine (no cross-thread comparisons at all), the =8 pass adds the
# 1-vs-8 determinism comparisons (prop_anytime,
# prop_scheduler_parallel) and the interleaving-fuzz thread matrix
# (prop_interleave: shuffled DES replays bit-equal unshuffled ones at
# every worker-thread count). The second pass costs a full re-run; drop
# the =1 pass if CI minutes ever matter more than the sequential pin.
#
# Bench/RunRecord output lands in rust/bench_out/ (HETRL_RESULTS overrides).
set -euo pipefail
cd "$(dirname "$0")/rust"

echo "== DES shuffle-invariance differential model (python, no-toolchain gate) =="
# Bit-exact stdlib-Python port of the DES engine's RNG, executors,
# conflict-component rank construction and the random_sim_graph
# fixture: runs the prop_interleave DES fuzz (plus wider zero-duration
# adversarial sweeps and the historical mid-instant-release
# counterexample) even where no Rust toolchain exists.
if command -v python3 >/dev/null 2>&1; then
    python3 ../python/tests/test_des_shuffle.py
else
    echo "ci.sh: WARNING - no python3 on PATH; skipping DES model." >&2
fi

if ! command -v cargo >/dev/null 2>&1; then
    echo "ci.sh: WARNING - no rust toolchain on PATH; skipping build/test." >&2
    echo "ci.sh: the crate is dependency-free; any stock cargo can build it." >&2
    exit 0
fi

echo "== cargo build --release =="
cargo build --release

echo "== hetrl lint (detlint determinism/concurrency gate) =="
# Zero-dep static analysis: wall-clock, hash-order, NaN-unsafe
# comparators, ambient nondeterminism, unaudited atomics/locks, stale
# allow directives. Nonzero exit on any finding.
./target/release/hetrl lint

if cargo clippy --version >/dev/null 2>&1; then
    echo "== cargo clippy --all-targets (warnings are errors) =="
    cargo clippy --all-targets -- -D warnings
else
    echo "ci.sh: clippy not installed; skipping." >&2
fi

echo "== cargo doc (rustdoc gate: warnings are errors) =="
# Broken intra-doc links, bad HTML in doc comments etc. fail the build;
# README/ARCHITECTURE point at the rendered API docs, so keep them clean.
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "== cargo test -q (HETRL_TEST_THREADS=1) =="
HETRL_TEST_THREADS=1 cargo test -q

echo "== cargo test -q (HETRL_TEST_THREADS=8) =="
HETRL_TEST_THREADS=8 cargo test -q

echo "== replay smoke (tiny trace, deterministic) =="
./target/release/hetrl replay --scenario country --seed 0 \
    --iters 6 --events 3 --budget 120 --warm-budget 60 --policy warm --tiny

echo "== replay smoke (anytime background search) =="
./target/release/hetrl replay --scenario country --seed 0 \
    --iters 6 --events 3 --budget 120 --warm-budget 60 \
    --anytime-rate 4 --policy anytime --tiny

echo "== replay smoke (predictive preemption, forced notice) =="
./target/release/hetrl replay --scenario country --seed 0 \
    --iters 6 --events 3 --budget 120 --warm-budget 60 \
    --anytime-rate 4 --notice-secs 100000 --policy preempt --tiny

echo "== replay smoke (async workflow, all five policies) =="
# Bounded-staleness pipeline over the same tiny trace: generation and
# training pools degrade independently; the staleness/queue invariants
# are also asserted by tests/prop_async.rs.
./target/release/hetrl replay --workflow async --scenario country --seed 0 \
    --iters 6 --events 3 --budget 120 --warm-budget 60 --policy all --tiny

echo "== shuffle-invariance smoke (--shuffle-seed 7 vs FIFO, sync + async) =="
# Replay-order invariance end to end: permuting same-timestamp DES
# ready ties with --shuffle-seed must not change one byte of replay
# output. tests/prop_interleave.rs fuzzes the same property over 8
# seeds x 3 traces x all policies; this pins the CLI flag plumbing.
# --threads 1 keeps the cache-hit column deterministic so a whole-
# output diff is valid.
for wf_flags in "" "--workflow async"; do
    plain=$(./target/release/hetrl replay $wf_flags --scenario country --seed 0 \
        --iters 6 --events 3 --budget 120 --warm-budget 60 --threads 1 \
        --policy all --tiny)
    shuffled=$(./target/release/hetrl replay $wf_flags --scenario country --seed 0 \
        --iters 6 --events 3 --budget 120 --warm-budget 60 --threads 1 \
        --policy all --tiny --shuffle-seed 7)
    if [[ "$plain" != "$shuffled" ]]; then
        echo "ci.sh: FAIL - --shuffle-seed 7 changed replay output (${wf_flags:-sync}):" >&2
        diff <(echo "$plain") <(echo "$shuffled") >&2 || true
        exit 1
    fi
done
echo "shuffle-invariance smoke: sync and async outputs byte-identical"

echo "== chaos replay smoke (transient faults + recovery pricing, sync) =="
# Seeded NIC bursts / checkpoint-store outages / task failures with
# bounded-retry stalls, rollback rework and a searched checkpoint
# cadence, across all five policies; tests/prop_recover.rs asserts the
# degeneracy pins and bit-determinism of the same path.
./target/release/hetrl replay --scenario country --seed 0 \
    --iters 6 --events 3 --budget 120 --warm-budget 60 \
    --faults --ckpt-interval auto --policy all --tiny

echo "== chaos replay smoke (transient faults + recovery pricing, async) =="
./target/release/hetrl replay --workflow async --scenario country --seed 0 \
    --iters 6 --events 3 --budget 120 --warm-budget 60 \
    --faults --max-retries 2 --policy all --tiny

echo "== search-throughput smoke (parallel engine, 1 vs N threads, full vs delta) =="
# fig5_search_throughput sweeps thread counts x {full, delta} at a small
# budget and exits non-zero if any N-thread run diverges from (in
# particular, finds a worse plan than) the 1-thread run at the same
# seed, or if delta-eval diverges from full re-pricing / fails to price
# strictly fewer tasks.
cargo bench --bench fig5_search_throughput

echo "== delta-vs-full consistency smoke (hetrl schedule) =="
# Delta evaluation must change work, never results: the same schedule
# run with incremental pricing (the default) and with --full-eval must
# print the identical plan fingerprint and predicted iteration time.
delta_out=$(./target/release/hetrl schedule --scenario country --seed 0 --budget 300 \
    | grep -E '^(plan fingerprint|predicted):')
full_out=$(./target/release/hetrl schedule --scenario country --seed 0 --budget 300 --full-eval \
    | grep -E '^(plan fingerprint|predicted):')
if [[ "$delta_out" != "$full_out" ]]; then
    echo "ci.sh: FAIL - delta-eval schedule diverged from --full-eval:" >&2
    diff <(echo "$delta_out") <(echo "$full_out") >&2 || true
    exit 1
fi
echo "$delta_out"

if [[ "${1:-}" == "--bench" ]]; then
    echo "== fig11 elastic bench =="
    cargo bench --bench fig11_elastic
    ls -l bench_out/ || true
fi

echo "ci.sh: OK"
