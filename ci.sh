#!/usr/bin/env bash
# CI entry point: build, test, and smoke-run the dynamic-replay path.
#
#   ./ci.sh          fast checks (tier-1 + replay smoke)
#   ./ci.sh --bench  also runs the fig11 elastic bench (reduced budgets)
#
# Bench/RunRecord output lands in rust/bench_out/ (HETRL_RESULTS overrides).
set -euo pipefail
cd "$(dirname "$0")/rust"

if ! command -v cargo >/dev/null 2>&1; then
    echo "ci.sh: WARNING - no rust toolchain on PATH; skipping build/test." >&2
    echo "ci.sh: the crate is dependency-free; any stock cargo can build it." >&2
    exit 0
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== replay smoke (tiny trace, deterministic) =="
./target/release/hetrl replay --scenario country --seed 0 \
    --iters 6 --events 3 --budget 120 --warm-budget 60 --policy warm --tiny

echo "== search-throughput smoke (parallel engine, 1 vs N threads) =="
# fig5_search_throughput sweeps thread counts at a small budget and
# exits non-zero if any N-thread run diverges from (in particular, finds
# a worse plan than) the 1-thread run at the same seed.
cargo bench --bench fig5_search_throughput

if [[ "${1:-}" == "--bench" ]]; then
    echo "== fig11 elastic bench =="
    cargo bench --bench fig11_elastic
    ls -l bench_out/ || true
fi

echo "ci.sh: OK"
