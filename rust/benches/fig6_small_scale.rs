//! Figure 6 — small-scale settings: (a) search efficiency on 24 GPUs;
//! (b) HetRL(ILP) time-to-solution across fleet sizes.
//!
//! Expected shape: ILP reaches (near-)optimal within minutes at ≤ 24
//! GPUs; SHA-EA lands within ~1% of it; ILP time grows steeply with N.

mod common;

use hetrl::metrics::RunRecord;
use hetrl::scheduler::{Budget, IlpScheduler, Scheduler, ShaEaScheduler, VerlScheduler};
use hetrl::topology::{build_testbed, subset_by_model, GpuModel, Scenario, TestbedSpec};
use hetrl::util::json::Json;
use hetrl::util::table::Table;
use hetrl::workflow::{Algo, JobConfig, Mode, ModelSpec, RlWorkflow};

fn small_topo(per_model: usize) -> hetrl::topology::DeviceTopology {
    let full = build_testbed(Scenario::SingleRegion, &TestbedSpec::default());
    subset_by_model(
        &full,
        &[
            (GpuModel::A100, per_model),
            (GpuModel::L40S, per_model),
            (GpuModel::L4, per_model),
        ],
    )
}

fn main() {
    hetrl::util::logging::init();
    let job = JobConfig::default();
    let wf = RlWorkflow::new(Algo::Grpo, Mode::Sync, ModelSpec::qwen_4b());

    // (a) 24-GPU search efficiency
    let topo24 = small_topo(8);
    let mut ta = Table::new(
        "Figure 6(a): 24-GPU search efficiency (GRPO-Sync Qwen-4B)",
        &["scheduler", "wall (s)", "best iter (s)", "gap vs ILP"],
    );
    let mut record = RunRecord::new(
        "fig6_small_scale",
        &["part", "label", "wall_s", "iter_time_s"],
    );
    let mut ilp = IlpScheduler::with_time_limit(if common::full() { 180.0 } else { 45.0 });
    let iout = ilp.schedule(&topo24, &wf, &job, Budget::timed(1_000_000, 200.0));
    let mut rows = vec![("HetRL(ILP)".to_string(), iout.wall, iout.cost)];
    let sout = ShaEaScheduler::new(3).schedule(&topo24, &wf, &job, Budget::timed(1200, 60.0));
    rows.push(("HetRL(SHA-EA)".into(), sout.wall, sout.cost));
    let vout = VerlScheduler::new(3).schedule(&topo24, &wf, &job, Budget::timed(200, 30.0));
    rows.push(("verl".into(), vout.wall, vout.cost));
    for (name, wall, cost) in &rows {
        ta.row(vec![
            name.clone(),
            format!("{wall:.2}"),
            format!("{cost:.1}"),
            format!("{:+.2}%", (cost / iout.cost - 1.0) * 100.0),
        ]);
        record.push(vec![
            Json::str("a"),
            Json::str(name),
            Json::num(*wall),
            Json::num(*cost),
        ]);
    }
    ta.print();

    // (b) ILP time-to-solution vs fleet size
    let sizes: Vec<usize> = if common::full() { vec![2, 4, 6, 8] } else { vec![2, 4, 8] };
    let mut tb = Table::new(
        "Figure 6(b): HetRL(ILP) time to solution vs fleet size",
        &["GPUs", "wall (s)", "iter (s)", "optimal?"],
    );
    for per_model in sizes {
        let topo = small_topo(per_model);
        let mut ilp = IlpScheduler::with_time_limit(if common::full() { 180.0 } else { 60.0 });
        let out = ilp.schedule(&topo, &wf, &job, Budget::timed(1_000_000, 200.0));
        tb.row(vec![
            topo.n().to_string(),
            format!("{:.2}", out.wall),
            format!("{:.1}", out.cost),
            if out.cost.is_finite() { "yes".into() } else { "timeout".to_string() },
        ]);
        record.push(vec![
            Json::str("b"),
            Json::str(&topo.n().to_string()),
            Json::num(out.wall),
            Json::num(out.cost),
        ]);
    }
    tb.print();
    if let Ok(p) = record.save(&hetrl::metrics::results_dir()) {
        println!("rows saved to {}", p.display());
    }
}
