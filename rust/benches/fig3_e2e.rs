//! Figure 3 — end-to-end comparison of HetRL vs verl vs StreamRL across
//! the four network scenarios: (a,b) the scenario delay/bandwidth
//! envelopes, (c-e) simulated training throughput per model size for
//! PPO and GRPO, sync and async.
//!
//! Expected shape (paper §5.2): HetRL ≥ baselines everywhere; gaps grow
//! with network heterogeneity; verl-Async sometimes < verl-Sync;
//! StreamRL between verl and HetRL outside Single-Region.

mod common;

use common::{model_sizes, run_system, workflow, System};
use hetrl::metrics::RunRecord;
use hetrl::topology::{build_testbed, Scenario, TestbedSpec};
use hetrl::util::json::Json;
use hetrl::util::table::Table;
use hetrl::workflow::{Algo, JobConfig, Mode};

fn main() {
    hetrl::util::logging::init();
    let job = JobConfig::default();

    // (a, b) scenario link envelopes
    let mut env = Table::new(
        "Figure 3(a,b): scenario link envelopes",
        &["scenario", "max delay (ms)", "min WAN bw (Gbps)"],
    );
    for s in Scenario::ALL {
        let t = build_testbed(s, &TestbedSpec::default());
        let mut dmax: f64 = 0.0;
        let mut bmin = f64::INFINITY;
        for i in 0..t.n() {
            for j in 0..t.n() {
                if i != j {
                    dmax = dmax.max(t.lat(i, j));
                    if t.devices[i].region != t.devices[j].region
                        || t.bw(i, j) < 5e9
                    {
                        bmin = bmin.min(t.bw(i, j));
                    }
                }
            }
        }
        env.row(vec![
            s.name().to_string(),
            format!("{:.1}", dmax * 1e3),
            if bmin.is_finite() {
                format!("{:.2}", bmin * 8.0 / 1e9)
            } else {
                "-".to_string()
            },
        ]);
    }
    env.print();

    // (c-e) throughput per scenario × algo × size × mode × system
    let mut record = RunRecord::new(
        "fig3_e2e",
        &["scenario", "algo", "mode", "model", "system", "throughput"],
    );
    for algo in [Algo::Ppo, Algo::Grpo] {
        for mode in [Mode::Sync, Mode::Async] {
            let mut table = Table::new(
                &format!("Figure 3: {}-{} simulated throughput (samples/s)", algo.name(), mode.name()),
                &["scenario", "model", "HetRL", "verl", "StreamRL", "HetRL/verl"],
            );
            for scenario in Scenario::ALL {
                let topo = build_testbed(scenario, &TestbedSpec::default());
                for model in model_sizes() {
                    let wf = workflow(algo, mode, &model);
                    let mut row = vec![scenario.name().to_string(), model.name.clone()];
                    let mut tps = Vec::new();
                    for system in [System::HetRl, System::Verl, System::StreamRl] {
                        // StreamRL is an async system; skip in sync mode.
                        let tp = if system == System::StreamRl && mode == Mode::Sync {
                            f64::NAN
                        } else {
                            run_system(system, &topo, &wf, &job, 1)
                                .map(|r| r.throughput)
                                .unwrap_or(0.0)
                        };
                        record.push(vec![
                            Json::str(scenario.name()),
                            Json::str(algo.name()),
                            Json::str(mode.name()),
                            Json::str(&model.name),
                            Json::str(system.name()),
                            Json::num(if tp.is_nan() { -1.0 } else { tp }),
                        ]);
                        row.push(if tp.is_nan() {
                            "-".into()
                        } else {
                            format!("{tp:.1}")
                        });
                        tps.push(tp);
                    }
                    row.push(format!("{:.2}x", tps[0] / tps[1].max(1e-9)));
                    table.row(row);
                }
            }
            table.print();
        }
    }
    if let Ok(p) = record.save(&hetrl::metrics::results_dir()) {
        println!("rows saved to {}", p.display());
    }
}
