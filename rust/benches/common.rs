//! Shared helpers for the figure/table benches. Every bench prints the
//! same rows/series the paper reports and persists a `RunRecord` under
//! `bench_out/` (`HETRL_RESULTS` overrides). Budgets scale down by
//! default; set `HETRL_BENCH_FULL=1` for the full sweeps.

#![allow(dead_code)]

use hetrl::balance::{self, BalanceConfig};
use hetrl::scheduler::{
    Budget, PureEaScheduler, Scheduler, ShaEaScheduler, StreamRlScheduler, VerlScheduler,
};
use hetrl::simulator::{simulate_plan, NoiseModel, SimConfig, SimResult};
use hetrl::topology::DeviceTopology;
use hetrl::workflow::{Algo, JobConfig, Mode, ModelSpec, RlWorkflow};

pub fn full() -> bool {
    // detlint:allow(D4): bench sweep-size toggle — affects how much is measured, not any measured result
    std::env::var("HETRL_BENCH_FULL").map(|v| v == "1").unwrap_or(false)
}

/// Model sizes for the sweeps (paper: 4B, 8B, 14B).
pub fn model_sizes() -> Vec<ModelSpec> {
    if full() {
        vec![ModelSpec::qwen_4b(), ModelSpec::qwen_8b(), ModelSpec::qwen_14b()]
    } else {
        vec![ModelSpec::qwen_4b(), ModelSpec::qwen_8b()]
    }
}

pub fn sha_budget() -> usize {
    if full() {
        1500
    } else {
        400
    }
}

pub fn sim_cfg() -> SimConfig {
    SimConfig {
        iters: if full() { 3 } else { 2 },
        seed: 0xBE,
        noise: NoiseModel::default(),
        shuffle: None,
    }
}

/// System under test for the end-to-end comparisons.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum System {
    HetRl,
    Verl,
    StreamRl,
}

impl System {
    pub fn name(self) -> &'static str {
        match self {
            System::HetRl => "HetRL",
            System::Verl => "verl",
            System::StreamRl => "StreamRL",
        }
    }
}

/// Schedule with the given system, apply HetRL's load balancing for
/// HetRL only, and run the simulator. Returns simulated throughput in
/// samples/s (0 when no feasible plan is found).
pub fn run_system(
    system: System,
    topo: &DeviceTopology,
    wf: &RlWorkflow,
    job: &JobConfig,
    seed: u64,
) -> Option<SimResult> {
    let mut sched: Box<dyn Scheduler> = match system {
        System::HetRl => Box::new(ShaEaScheduler::new(seed)),
        System::Verl => Box::new(VerlScheduler::new(seed)),
        System::StreamRl => Box::new(StreamRlScheduler::new(seed)),
    };
    let budget = match system {
        System::HetRl => sha_budget(),
        _ => 200,
    };
    let out = sched.schedule(topo, wf, job, Budget::timed(budget, 120.0));
    let mut plan = out.plan?;
    if system == System::HetRl {
        plan = balance::apply(&plan, wf, topo, BalanceConfig::default());
    }
    Some(simulate_plan(topo, wf, job, &plan, &sim_cfg()))
}

/// The pure-EA (DEAP-like) baseline, for the search-efficiency plots.
pub fn deap(seed: u64) -> PureEaScheduler {
    PureEaScheduler::new(seed)
}

/// Workflow shorthand.
pub fn workflow(algo: Algo, mode: Mode, model: &ModelSpec) -> RlWorkflow {
    RlWorkflow::new(algo, mode, model.clone())
}
