//! Figure 7 — cost-model validation: predicted vs simulated iteration
//! time across Qwen model sizes and the four scenarios (mean ± std over
//! simulator seeds).
//!
//! Expected shape: single-digit-to-~30% errors, growing with network
//! heterogeneity (paper §5.5).

mod common;

use common::{model_sizes, sha_budget, workflow};
use hetrl::costmodel::CostModel;
use hetrl::metrics::RunRecord;
use hetrl::scheduler::{Budget, Scheduler, ShaEaScheduler};
use hetrl::simulator::{simulate_plan, NoiseModel, SimConfig};
use hetrl::topology::{build_testbed, Scenario, TestbedSpec};
use hetrl::util::json::Json;
use hetrl::util::table::Table;
use hetrl::workflow::{Algo, JobConfig, Mode};

fn main() {
    hetrl::util::logging::init();
    let job = JobConfig::default();
    let mut table = Table::new(
        "Figure 7: cost-model prediction accuracy (GRPO-Sync)",
        &["scenario", "model", "predicted (s)", "simulated (s)", "error"],
    );
    let mut record = RunRecord::new(
        "fig7_costmodel",
        &["scenario", "model", "predicted_s", "simulated_s", "sim_std", "error_pct"],
    );
    let seeds = if common::full() { 5 } else { 3 };
    for scenario in Scenario::ALL {
        let topo = build_testbed(scenario, &TestbedSpec::default());
        for model in model_sizes() {
            let wf = workflow(Algo::Grpo, Mode::Sync, &model);
            let out = ShaEaScheduler::new(5)
                .schedule(&topo, &wf, &job, Budget::timed(sha_budget(), 60.0));
            let Some(plan) = out.plan else { continue };
            let pred = CostModel::new(&topo, &wf, &job).plan_cost(&plan).iter_time;
            let mut meas = Vec::new();
            for s in 0..seeds {
                let cfg = SimConfig { iters: 2, seed: 100 + s, noise: NoiseModel::default(), shuffle: None };
                meas.push(simulate_plan(&topo, &wf, &job, &plan, &cfg).iter_time);
            }
            let stats = hetrl::util::stats::summarize(&meas);
            let err = (pred - stats.mean).abs() / stats.mean * 100.0;
            table.row(vec![
                scenario.name().to_string(),
                model.name.clone(),
                format!("{pred:.1}"),
                format!("{:.1}±{:.1}", stats.mean, stats.std),
                format!("{err:.1}%"),
            ]);
            record.push(vec![
                Json::str(scenario.name()),
                Json::str(&model.name),
                Json::num(pred),
                Json::num(stats.mean),
                Json::num(stats.std),
                Json::num(err),
            ]);
        }
    }
    table.print();
    if let Ok(p) = record.save(&hetrl::metrics::results_dir()) {
        println!("rows saved to {}", p.display());
    }
}
