//! §Perf micro-benchmarks for the L3 hot paths (EXPERIMENTS.md §Perf):
//! cost-model evaluation (the scheduler's inner loop), ring bottleneck
//! search, simulator event throughput, EA mutation+local-search, the
//! simplex pivot loop, JSON parsing, and (when artifacts are present)
//! the PJRT forward execution.

mod common;

use hetrl::costmodel::{ring_minmax, CostCache, CostModel};
use hetrl::plan::{ExecutionPlan, ParallelStrategy, TaskPlan};
use hetrl::scheduler::ea::perturbations_with_footprints;
use hetrl::scheduler::{Budget, Scheduler, ShaEaScheduler};
use hetrl::simulator::{simulate_plan, NoiseModel, SimConfig};
use hetrl::solver::{solve_milp, BnbConfig, Cmp, Lp};
use hetrl::topology::{build_testbed, Scenario, TestbedSpec};
use hetrl::util::benchkit::Runner;
use hetrl::util::json::Json;
use hetrl::workflow::{Algo, JobConfig, Mode, ModelSpec, RlWorkflow};

fn make_plan(wf: &RlWorkflow, per_task: usize) -> ExecutionPlan {
    let mut task_plans = Vec::new();
    for (t, task) in wf.tasks.iter().enumerate() {
        let s = ParallelStrategy::new((per_task / 8).max(1), 2, 4);
        let devs: Vec<usize> = (t * per_task..(t + 1) * per_task).collect();
        task_plans.push(TaskPlan::uniform(s, task.model.nl, devs));
    }
    ExecutionPlan {
        task_groups: vec![(0..wf.n_tasks()).collect()],
        gpu_groups: vec![(0..64).collect()],
        task_plans,
    }
}

fn main() {
    let mut r = Runner::from_args("perf_hotpaths");
    let topo = build_testbed(Scenario::MultiCountry, &TestbedSpec::default());
    let wf = RlWorkflow::new(Algo::Grpo, Mode::Sync, ModelSpec::qwen_8b());
    let job = JobConfig::default();
    let plan = make_plan(&wf, 16);
    let cm = CostModel::new(&topo, &wf, &job);

    r.bench("costmodel/plan_cost", 5, 50, || {
        std::hint::black_box(cm.plan_cost(&plan));
    });

    // The scheduler's actual inner loop after the PR 9 speed pass:
    // re-price only a mutation's dirty footprint against a cached
    // baseline (compare against costmodel/plan_cost above).
    let cache = CostCache::new();
    let base = cm.plan_cost(&plan).per_task;
    let (mutant, dirty) = perturbations_with_footprints(&plan, 1, 7)
        .pop()
        .expect("one perturbation");
    r.bench("costmodel/plan_cost_delta", 5, 50, || {
        std::hint::black_box(cm.plan_cost_delta(&mutant, &base, &dirty, &cache));
    });

    let ring_devs: Vec<usize> = (0..8).map(|i| i * 8).collect();
    r.bench("costmodel/ring_minmax_8dev", 10, 200, || {
        std::hint::black_box(ring_minmax(&topo, &ring_devs, 1e8));
    });

    let sim_cfg = SimConfig { iters: 1, seed: 1, noise: NoiseModel::default(), shuffle: None };
    let tiny_job = JobConfig::tiny();
    r.bench("simulator/grpo_iteration", 2, 10, || {
        std::hint::black_box(simulate_plan(&topo, &wf, &tiny_job, &plan, &sim_cfg));
    });

    r.bench("scheduler/sha_ea_100evals", 1, 5, || {
        let mut s = ShaEaScheduler::new(1);
        std::hint::black_box(s.schedule(&topo, &wf, &job, Budget::evals(100)));
    });

    r.bench("solver/milp_knapsack12", 2, 10, || {
        let mut lp = Lp::new(12, (0..12).map(|i| (i % 5) as f64 + 0.4).collect(), true);
        let terms: Vec<(usize, f64)> =
            (0..12).map(|i| (i, ((i * 7) % 3) as f64 + 1.1)).collect();
        lp.constrain(terms, Cmp::Le, 9.0);
        let cfg = BnbConfig { time_limit: 5.0, max_nodes: 5_000, gap: 1e-6 };
        std::hint::black_box(solve_milp(&lp, &(0..12).collect::<Vec<_>>(), &cfg));
    });

    let json_src = Json::obj(vec![
        ("xs", Json::arr((0..500).map(|i| Json::num(i as f64)))),
        ("name", Json::str("hetrl")),
    ])
    .dump();
    r.bench("util/json_parse_500elems", 10, 200, || {
        std::hint::black_box(Json::parse(&json_src).unwrap());
    });

    if std::path::Path::new("artifacts/manifest.json").exists() {
        use hetrl::engine::Policy;
        use hetrl::runtime::{HostTensor, Runtime};
        let rt = Runtime::load("artifacts").expect("runtime");
        let policy = Policy::init(&rt, 1).unwrap();
        let b = rt.manifest.batch;
        let l = rt.model().max_len;
        let tokens = HostTensor::i32(vec![b, l], vec![3; b * l]);
        let mut inputs = policy.params.clone();
        inputs.push(tokens);
        r.bench("runtime/forward_b8_l96", 2, 10, || {
            std::hint::black_box(rt.execute("forward", &inputs).unwrap());
        });
        // §Perf L3-3: parameters converted to literals once (the decode
        // loop's configuration).
        let prepared = rt.upload(&policy.params).unwrap();
        let tokens = HostTensor::i32(vec![b, l], vec![3; b * l]);
        r.bench("runtime/forward_prepared_params", 2, 10, || {
            std::hint::black_box(
                rt.execute_prepared("forward", &prepared, &[tokens.clone()]).unwrap(),
            );
        });
    } else {
        println!("runtime/forward: skipped (run `make artifacts`)");
    }

    r.finish();
}
