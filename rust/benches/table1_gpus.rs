//! Table 1 — GPU specifications. Regenerates the paper's hardware table
//! from the catalog plus derived quantities the scheduler actually uses.

use hetrl::topology::GpuModel;
use hetrl::util::table::Table;
use hetrl::util::units::{GBPS_BYTES, GIB, TFLOPS};

fn main() {
    let mut t = Table::new(
        "Table 1: GPU specifications",
        &[
            "Model",
            "Arch",
            "Size (GB)",
            "FP16 (TFLOPS)",
            "HBM (GB/s)",
            "Link (GB/s)",
            "eff TFLOPS",
        ],
    );
    for model in GpuModel::table1() {
        let s = model.spec();
        t.row(vec![
            s.name.to_string(),
            s.arch.to_string(),
            format!("{:.0}", s.mem_bytes / GIB),
            format!("{:.0}", s.fp16_flops / TFLOPS),
            format!("{:.0}", s.hbm_bps / GBPS_BYTES),
            format!("{:.0}", s.link_bps / GBPS_BYTES),
            format!("{:.0}", s.fp16_flops * s.mfu / TFLOPS),
        ]);
    }
    t.print();
    println!("testbed: 24×A100 + 24×L40S + 16×L4 = 64 GPUs (8-GPU machines)\n");
}
