//! Figure 10 — throughput of HetRL vs verl for Qwen-8B under varying
//! combinations of heterogeneous GPUs (Single-Region), across
//! PPO/GRPO × Sync/Async.
//!
//! Expected shape: HetRL > verl on every combo; ALL-GPUs beats the
//! 24×A100 homogeneous subset by using the extra heterogeneous capacity.

mod common;

use common::{run_system, workflow, System};
use hetrl::metrics::RunRecord;
use hetrl::topology::{build_testbed, subset_by_model, GpuModel, Scenario, TestbedSpec};
use hetrl::util::json::Json;
use hetrl::util::table::Table;
use hetrl::workflow::{Algo, JobConfig, Mode, ModelSpec};

fn main() {
    hetrl::util::logging::init();
    let job = JobConfig::default();
    let model = ModelSpec::qwen_8b();
    let full_topo = build_testbed(Scenario::SingleRegion, &TestbedSpec::default());

    let combos: Vec<(&str, Vec<(GpuModel, usize)>)> = vec![
        ("24xA100", vec![(GpuModel::A100, 24)]),
        ("24xL40S", vec![(GpuModel::L40S, 24)]),
        ("24xA100+24xL40S", vec![(GpuModel::A100, 24), (GpuModel::L40S, 24)]),
        (
            "24xA100+16xL4",
            vec![(GpuModel::A100, 24), (GpuModel::L4, 16)],
        ),
        (
            "ALL (64 GPUs)",
            vec![(GpuModel::A100, 24), (GpuModel::L40S, 24), (GpuModel::L4, 16)],
        ),
    ];

    let mut record = RunRecord::new(
        "fig10_gpu_combos",
        &["combo", "algo", "mode", "hetrl", "verl", "speedup"],
    );
    for algo in [Algo::Ppo, Algo::Grpo] {
        for mode in [Mode::Sync, Mode::Async] {
            let mut table = Table::new(
                &format!(
                    "Figure 10: {}-{} Qwen-8B throughput by GPU combo (samples/s)",
                    algo.name(),
                    mode.name()
                ),
                &["combo", "HetRL", "verl", "HetRL/verl"],
            );
            for (name, keep) in &combos {
                let topo = subset_by_model(&full_topo, keep);
                let wf = workflow(algo, mode, &model);
                let hetrl = run_system(System::HetRl, &topo, &wf, &job, 6)
                    .map(|r| r.throughput)
                    .unwrap_or(0.0);
                let verl = run_system(System::Verl, &topo, &wf, &job, 6)
                    .map(|r| r.throughput)
                    .unwrap_or(0.0);
                table.row(vec![
                    name.to_string(),
                    format!("{hetrl:.1}"),
                    format!("{verl:.1}"),
                    format!("{:.2}x", hetrl / verl.max(1e-9)),
                ]);
                record.push(vec![
                    Json::str(name),
                    Json::str(algo.name()),
                    Json::str(mode.name()),
                    Json::num(hetrl),
                    Json::num(verl),
                    Json::num(hetrl / verl.max(1e-9)),
                ]);
            }
            table.print();
        }
    }
    if let Ok(p) = record.save(&hetrl::metrics::results_dir()) {
        println!("rows saved to {}", p.display());
    }
}
