//! Figure 5b (new, beyond the paper) — scaling of the parallel
//! plan-evaluation engine: SHA-EA search throughput (cost-model
//! evals/sec) and time-to-incumbent-quality vs worker-thread count on
//! the Multi-Country 64-GPU fleet, same seed and eval budget per run,
//! with each thread count run twice — full re-pricing and incremental
//! (delta) evaluation — to put a number on the hot-path speed pass.
//!
//! This bench doubles as the CI determinism smoke, with two gates:
//!
//! * the engine's contract that the same seed yields the
//!   **bit-identical best plan at any thread count** — any divergence
//!   in best cost / plan / evals across the thread sweep exits non-zero
//!   and fails `ci.sh`;
//! * the delta-eval contract that incremental pricing changes *work*,
//!   never *results* — at every thread count the delta run must match
//!   the full run bit-for-bit while resolving **strictly fewer**
//!   per-task costs.
//!
//! Rows are persisted as a `RunRecord` under `bench_out/`; the
//! `task_pricings` / `pricings_per_eval` columns are the paired
//! full-vs-delta cost of one candidate evaluation.

mod common;

use hetrl::metrics::RunRecord;
use hetrl::scheduler::{Budget, ScheduleOutcome, Scheduler, ShaEaScheduler};
use hetrl::topology::{build_testbed, Scenario, TestbedSpec};
use hetrl::util::json::Json;
use hetrl::util::table::Table;
use hetrl::workflow::{Algo, JobConfig, Mode, ModelSpec, RlWorkflow};

/// Wall-clock at which the trace first comes within 5% of the final
/// best — "time to incumbent quality".
fn time_to_quality(out: &ScheduleOutcome) -> f64 {
    let target = out.cost * 1.05;
    out.trace
        .iter()
        .find(|p| p.best_cost <= target)
        .map(|p| p.wall)
        .unwrap_or(out.wall)
}

fn main() {
    hetrl::util::logging::init();
    let topo = build_testbed(Scenario::MultiCountry, &TestbedSpec::default());
    let wf = RlWorkflow::new(Algo::Ppo, Mode::Sync, ModelSpec::qwen_8b());
    let job = JobConfig::default();
    let budget = if common::full() { 6000 } else { 1500 };
    let seed = 2u64;
    let cores = hetrl::scheduler::resolve_threads(0);
    let mut thread_counts: Vec<usize> = vec![1, 2, 4];
    if cores > 4 {
        thread_counts.push(cores);
    }

    let mut record = RunRecord::new(
        "fig5_search_throughput",
        &[
            "threads",
            "eval_mode",
            "budget_evals",
            "evals",
            "wall_s",
            "evals_per_s",
            "best_iter_time_s",
            "t_to_95pct_s",
            "cache_hit_rate",
            "task_pricings",
            "pricings_per_eval",
        ],
    );
    let mut table = Table::new(
        &format!(
            "Figure 5b: parallel search throughput (Qwen-8B sync PPO, Multi-Country, \
             budget {budget}, seed {seed})"
        ),
        &[
            "threads",
            "eval",
            "wall (s)",
            "evals/s",
            "best iter (s)",
            "t→95% (s)",
            "cache hit%",
            "pricings/eval",
        ],
    );

    // (threads, mode, outcome); mode false = full re-price, true = delta.
    let mut runs: Vec<(usize, bool, ScheduleOutcome)> = Vec::new();
    for &t in &thread_counts {
        for delta in [false, true] {
            let mut sched = ShaEaScheduler::with_threads(seed, t);
            sched.cfg.ea.delta_eval = delta;
            let out = sched.schedule(&topo, &wf, &job, Budget::evals(budget));
            let eps = if out.wall > 0.0 { out.evals as f64 / out.wall } else { 0.0 };
            let lookups = out.cache_hits + out.cache_misses;
            let hit_rate = if lookups > 0 {
                out.cache_hits as f64 / lookups as f64
            } else {
                0.0
            };
            let mode = if delta { "delta" } else { "full" };
            let per_eval = if out.evals > 0 {
                out.task_pricings as f64 / out.evals as f64
            } else {
                0.0
            };
            table.row(vec![
                t.to_string(),
                mode.to_string(),
                format!("{:.3}", out.wall),
                format!("{eps:.0}"),
                if out.cost.is_finite() { format!("{:.1}", out.cost) } else { "∞".into() },
                format!("{:.3}", time_to_quality(&out)),
                format!("{:.0}%", hit_rate * 100.0),
                format!("{per_eval:.2}"),
            ]);
            record.push(vec![
                Json::num(t as f64),
                Json::str(mode),
                Json::num(budget as f64),
                Json::num(out.evals as f64),
                Json::num(out.wall),
                Json::num(eps),
                Json::num(if out.cost.is_finite() { out.cost } else { -1.0 }),
                Json::num(time_to_quality(&out)),
                Json::num(hit_rate),
                Json::num(out.task_pricings as f64),
                Json::num(per_eval),
            ]);
            runs.push((t, delta, out));
        }
    }
    table.print();

    let mut ok = true;

    // Gate 1 (determinism): every thread count must reproduce the
    // 1-thread incumbent bit-for-bit, within each eval mode.
    for mode in [false, true] {
        let base = &runs.iter().find(|(t, d, _)| *t == 1 && *d == mode).unwrap().2;
        for (t, _, out) in runs.iter().filter(|(t, d, _)| *t != 1 && *d == mode) {
            if out.cost.to_bits() != base.cost.to_bits() {
                eprintln!(
                    "FAIL: {t}-thread best cost {} != 1-thread {} (seed {seed})",
                    out.cost, base.cost
                );
                ok = false;
            }
            if out.plan != base.plan {
                eprintln!("FAIL: {t}-thread best plan differs from 1-thread (seed {seed})");
                ok = false;
            }
            if out.evals != base.evals {
                eprintln!(
                    "FAIL: {t}-thread spent {} evals != 1-thread {} (seed {seed})",
                    out.evals, base.evals
                );
                ok = false;
            }
        }
    }

    // Gate 2 (delta-eval): at each thread count, delta must match full
    // bit-for-bit and resolve strictly fewer per-task costs.
    for &t in &thread_counts {
        let full = &runs.iter().find(|(tt, d, _)| *tt == t && !*d).unwrap().2;
        let delta = &runs.iter().find(|(tt, d, _)| *tt == t && *d).unwrap().2;
        if delta.cost.to_bits() != full.cost.to_bits() || delta.plan != full.plan {
            eprintln!("FAIL: delta-eval diverged from full re-pricing at {t} threads (seed {seed})");
            ok = false;
        }
        if delta.task_pricings >= full.task_pricings {
            eprintln!(
                "FAIL: delta-eval priced {} tasks >= full's {} at {t} threads (seed {seed})",
                delta.task_pricings, full.task_pricings
            );
            ok = false;
        }
    }

    let base = &runs.iter().find(|(t, d, _)| *t == 1 && *d).unwrap().2;
    if let Some((_, _, four)) = runs.iter().find(|(t, d, _)| *t == 4 && *d) {
        let speedup = (four.evals as f64 / four.wall) / (base.evals as f64 / base.wall);
        println!("speedup @4 threads: {speedup:.2}x ({cores} cores available)");
    }
    if let Ok(p) = record.save(&hetrl::metrics::results_dir()) {
        println!("rows saved to {}", p.display());
    }
    if !ok {
        std::process::exit(1);
    }
}
