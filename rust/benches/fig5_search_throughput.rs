//! Figure 5b (new, beyond the paper) — scaling of the parallel
//! plan-evaluation engine: SHA-EA search throughput (cost-model
//! evals/sec) and time-to-incumbent-quality vs worker-thread count on
//! the Multi-Country 64-GPU fleet, same seed and eval budget per run.
//!
//! This bench doubles as the CI determinism smoke: the engine's
//! contract is that the same seed yields the **bit-identical best plan
//! at any thread count**, so any divergence in best cost or plan across
//! the thread sweep (in particular an N-thread run finding a *worse*
//! plan than the 1-thread run) exits non-zero and fails `ci.sh`.
//!
//! Rows are persisted as a `RunRecord` under `bench_out/`.

mod common;

use hetrl::metrics::RunRecord;
use hetrl::scheduler::{Budget, ScheduleOutcome, Scheduler, ShaEaScheduler};
use hetrl::topology::{build_testbed, Scenario, TestbedSpec};
use hetrl::util::json::Json;
use hetrl::util::table::Table;
use hetrl::workflow::{Algo, JobConfig, Mode, ModelSpec, RlWorkflow};

/// Wall-clock at which the trace first comes within 5% of the final
/// best — "time to incumbent quality".
fn time_to_quality(out: &ScheduleOutcome) -> f64 {
    let target = out.cost * 1.05;
    out.trace
        .iter()
        .find(|p| p.best_cost <= target)
        .map(|p| p.wall)
        .unwrap_or(out.wall)
}

fn main() {
    hetrl::util::logging::init();
    let topo = build_testbed(Scenario::MultiCountry, &TestbedSpec::default());
    let wf = RlWorkflow::new(Algo::Ppo, Mode::Sync, ModelSpec::qwen_8b());
    let job = JobConfig::default();
    let budget = if common::full() { 6000 } else { 1500 };
    let seed = 2u64;
    let cores = hetrl::scheduler::resolve_threads(0);
    let mut thread_counts: Vec<usize> = vec![1, 2, 4];
    if cores > 4 {
        thread_counts.push(cores);
    }

    let mut record = RunRecord::new(
        "fig5_search_throughput",
        &[
            "threads",
            "budget_evals",
            "evals",
            "wall_s",
            "evals_per_s",
            "best_iter_time_s",
            "t_to_95pct_s",
            "cache_hit_rate",
        ],
    );
    let mut table = Table::new(
        &format!(
            "Figure 5b: parallel search throughput (Qwen-8B sync PPO, Multi-Country, \
             budget {budget}, seed {seed})"
        ),
        &["threads", "wall (s)", "evals/s", "best iter (s)", "t→95% (s)", "cache hit%"],
    );

    let mut runs: Vec<(usize, ScheduleOutcome)> = Vec::new();
    for &t in &thread_counts {
        let mut sched = ShaEaScheduler::with_threads(seed, t);
        let out = sched.schedule(&topo, &wf, &job, Budget::evals(budget));
        let eps = if out.wall > 0.0 { out.evals as f64 / out.wall } else { 0.0 };
        let lookups = out.cache_hits + out.cache_misses;
        let hit_rate = if lookups > 0 {
            out.cache_hits as f64 / lookups as f64
        } else {
            0.0
        };
        table.row(vec![
            t.to_string(),
            format!("{:.3}", out.wall),
            format!("{eps:.0}"),
            if out.cost.is_finite() { format!("{:.1}", out.cost) } else { "∞".into() },
            format!("{:.3}", time_to_quality(&out)),
            format!("{:.0}%", hit_rate * 100.0),
        ]);
        record.push(vec![
            Json::num(t as f64),
            Json::num(budget as f64),
            Json::num(out.evals as f64),
            Json::num(out.wall),
            Json::num(eps),
            Json::num(if out.cost.is_finite() { out.cost } else { -1.0 }),
            Json::num(time_to_quality(&out)),
            Json::num(hit_rate),
        ]);
        runs.push((t, out));
    }
    table.print();

    // Determinism + quality gate (the CI smoke): every thread count
    // must reproduce the 1-thread incumbent bit-for-bit.
    let (_, base) = &runs[0];
    let mut ok = true;
    for (t, out) in &runs[1..] {
        if out.cost.to_bits() != base.cost.to_bits() {
            eprintln!(
                "FAIL: {t}-thread best cost {} != 1-thread {} (seed {seed})",
                out.cost, base.cost
            );
            ok = false;
        }
        if out.plan != base.plan {
            eprintln!("FAIL: {t}-thread best plan differs from 1-thread (seed {seed})");
            ok = false;
        }
        if out.evals != base.evals {
            eprintln!(
                "FAIL: {t}-thread spent {} evals != 1-thread {} (seed {seed})",
                out.evals, base.evals
            );
            ok = false;
        }
    }
    if let Some((_, four)) = runs.iter().find(|(t, _)| *t == 4) {
        let speedup = (four.evals as f64 / four.wall) / (base.evals as f64 / base.wall);
        println!("speedup @4 threads: {speedup:.2}x ({cores} cores available)");
    }
    if let Ok(p) = record.save(&hetrl::metrics::results_dir()) {
        println!("rows saved to {}", p.display());
    }
    if !ok {
        std::process::exit(1);
    }
}
