//! Figure "async" (new, beyond the paper) — synchronous vs asynchronous
//! workflow goodput under elastic cluster dynamics: the fig11 replay
//! matrix re-run with the RL task graph split into a generation stream
//! and a training stream joined by a bounded rollout queue
//! (staleness bound `k = 2`), against the `k = 0` degenerate case that
//! is bit-identical to the synchronous path.
//!
//! For every scenario × policy cell the same seeded event trace is
//! replayed twice — once per workflow — so the `vs sync` column
//! isolates what bounded staleness buys once the fleet starts churning:
//! the generation and training pools degrade independently, and a
//! machine loss confined to one pool stalls only that stream while the
//! rollout queue buffers the other (up to `k` policy versions).
//!
//! Like fig11, the matrix carries a `trace` column: `base` is the plain
//! loss/join trace with recovery pricing off (recovery columns
//! identically zero), `chaos` overlays seeded transient faults with
//! recovery pricing and the analytically picked checkpoint cadence, and
//! `total-loss` preempts every machine at once to pin graceful
//! degradation (the replay parks, retains the incumbent, and resumes
//! on rejoin — asserted, never a panic).
//!
//! Rows carry the full per-iteration telemetry of fig11 plus the
//! async-side columns (`workflow`, `staleness_bound`, rollout-queue
//! mean/max depth, producer stall, observed staleness) and are
//! persisted as a `RunRecord` under `bench_out/`.

mod common;

use hetrl::asyncrl::{replay_async, replay_async_with_trace, AsyncReplayConfig, AsyncReplayResult};
use hetrl::costmodel::RecoveryModel;
use hetrl::elastic::{
    first_event_iter, generate_trace, CkptSearchConfig, ClusterEvent, Policy, ReplanConfig,
    ReplayConfig, TraceConfig, TraceEvent,
};
use hetrl::metrics::RunRecord;
use hetrl::topology::{build_testbed, DeviceTopology, Scenario, TestbedSpec};
use hetrl::util::json::Json;
use hetrl::util::table::Table;
use hetrl::workflow::{Algo, JobConfig, Mode, ModelSpec, RlWorkflow};

/// Preempt every machine of `base` at once (no advance notice), rejoin
/// them all four iterations later: the graceful-degradation worst case.
fn total_loss_trace(base: &DeviceTopology) -> Vec<TraceEvent> {
    let n = base.devices.iter().map(|d| d.machine + 1).max().unwrap_or(0);
    let mut trace: Vec<TraceEvent> = (0..n)
        .map(|m| TraceEvent {
            at_iter: 2,
            event: ClusterEvent::MachinePreempt { machine: m },
            notice_secs: None,
        })
        .collect();
    trace.extend((0..n).map(|m| TraceEvent {
        at_iter: 6,
        event: ClusterEvent::MachineJoin { machine: m },
        notice_secs: None,
    }));
    trace
}

fn push_rows(
    record: &mut RunRecord,
    scenario: Scenario,
    trace_name: &str,
    policy: Policy,
    k: usize,
    r: &AsyncReplayResult,
) {
    for (rec, q) in r.base.records.iter().zip(&r.queue) {
        record.push(vec![
            Json::str(scenario.name()),
            Json::str(trace_name),
            Json::str(r.workflow_name()),
            Json::num(k as f64),
            Json::str(policy.name()),
            Json::num(rec.iter as f64),
            Json::num(rec.iter_secs),
            Json::num(rec.migration_secs),
            Json::num(rec.active_gpus as f64),
            Json::num(rec.evals as f64),
            Json::num(rec.anytime_evals as f64),
            Json::num(rec.hypothesis_evals as f64),
            // JSON has no ∞; -1 marks "no incumbent / not anytime".
            Json::num(if rec.anytime_cost.is_finite() { rec.anytime_cost } else { -1.0 }),
            Json::num(rec.cache_hits as f64),
            Json::num(rec.cache_misses as f64),
            Json::num(q.queue_depth_mean),
            Json::num(q.queue_depth_max as f64),
            Json::num(q.producer_stall_secs),
            Json::num(rec.retry_stall_secs),
            Json::num(rec.rework_secs),
            Json::num(rec.ckpt_secs),
            Json::num(if rec.degraded { 1.0 } else { 0.0 }),
            Json::num(q.max_staleness as f64),
            Json::str(&rec.events.join("+")),
        ]);
    }
}

fn main() {
    hetrl::util::logging::init();
    let seed = 17u64;
    let iters = if common::full() { 32 } else { 16 };
    let wf = RlWorkflow::new(Algo::Grpo, Mode::Sync, ModelSpec::qwen_4b());
    let job = JobConfig::default();
    let spec = TestbedSpec::default();
    let base_cfg = ReplayConfig {
        iters,
        trace: TraceConfig { horizon: iters, n_events: 5, ..TraceConfig::default() },
        replan: ReplanConfig {
            warm_budget: if common::full() { 200 } else { 120 },
            cold_budget: common::sha_budget(),
            ..ReplanConfig::default()
        },
        ..ReplayConfig::default()
    };
    // Chaos variant: same trace plus seeded transient faults, recovery
    // pricing on; the async path picks its checkpoint cadence
    // analytically from the candidate set for the fixed pool-split plan.
    let chaos_base = ReplayConfig {
        trace: TraceConfig { fault_events: 4, ..base_cfg.trace.clone() },
        recovery: RecoveryModel::with_interval(600.0),
        ckpt_search: Some(CkptSearchConfig { rounds: 1, ..CkptSearchConfig::default() }),
        ..base_cfg.clone()
    };

    let mut record = RunRecord::new(
        "fig_async",
        &[
            "scenario",
            "trace",
            "workflow",
            "staleness_bound",
            "policy",
            "iter",
            "iter_secs",
            "migration_secs",
            "active_gpus",
            "evals",
            "anytime_evals",
            "hypothesis_evals",
            "anytime_cost",
            "cache_hits",
            "cache_misses",
            "queue_depth_mean",
            "queue_depth_max",
            "producer_stall_secs",
            "retry_stall_secs",
            "rework_secs",
            "ckpt_secs",
            "degraded",
            "max_staleness",
            "events",
        ],
    );
    let mut summary = Table::new(
        &format!("Async vs sync elastic replay (Qwen-4B GRPO, {iters} iters, seed {seed})"),
        &[
            "scenario",
            "trace",
            "policy",
            "workflow",
            "k",
            "thpt (samp/s)",
            "post-event thpt",
            "vs sync",
            "queue mean/max",
            "gen stall (s)",
            "stall (s)",
            "rework (s)",
            "ckpt (s)",
            "degr",
            "evals",
        ],
    );
    let row = |summary: &mut Table,
               scenario: Scenario,
               tr: &str,
               policy: Policy,
               k: usize,
               r: &AsyncReplayResult,
               post: usize,
               sync_thpt: f64| {
        let thpt = r.base.throughput();
        summary.row(vec![
            scenario.name().to_string(),
            tr.to_string(),
            policy.name().to_string(),
            r.workflow_name().to_string(),
            k.to_string(),
            format!("{thpt:.2}"),
            format!("{:.2}", r.base.throughput_after(post)),
            if k > 0 && sync_thpt.is_finite() && sync_thpt > 0.0 {
                format!("{:+.1}%", (thpt / sync_thpt - 1.0) * 100.0)
            } else {
                "-".to_string()
            },
            format!("{:.2}/{}", r.mean_queue_depth(), r.max_queue_depth()),
            format!("{:.1}", r.producer_stall_secs()),
            format!("{:.1}", r.base.retry_stall_secs),
            format!("{:.1}", r.base.rework_secs),
            format!("{:.1}/{}", r.base.ckpt_secs, r.base.ckpts),
            r.base.degraded_iters.to_string(),
            r.base.total_evals.to_string(),
        ]);
    };
    for scenario in Scenario::ALL {
        let base = build_testbed(scenario, &spec);
        let trace = generate_trace(&base, &base_cfg.trace, seed);
        let post = first_event_iter(&trace).unwrap_or(0);
        eprintln!(
            "{}: {} events, first at iter {post}",
            scenario.name(),
            trace.len()
        );
        for policy in Policy::ALL {
            let mut sync_thpt = f64::NAN;
            for k in [0usize, 2] {
                let cfg = AsyncReplayConfig {
                    base: base_cfg.clone(),
                    staleness_bound: k,
                    ..AsyncReplayConfig::default()
                };
                let r = replay_async(scenario, &spec, &wf, &job, policy, &cfg, seed);
                if k == 0 {
                    sync_thpt = r.base.throughput();
                }
                push_rows(&mut record, scenario, "base", policy, k, &r);
                row(&mut summary, scenario, "base", policy, k, &r, post, sync_thpt);
                // Degeneracy pin: recovery off charges exactly nothing.
                assert_eq!(
                    r.base.retry_stall_secs + r.base.rework_secs + r.base.ckpt_secs,
                    0.0
                );
            }
            // Chaos pass (k = 2): the split-pool replay must survive the
            // fault stream and report the recovery charges it paid.
            let cfg = AsyncReplayConfig {
                base: chaos_base.clone(),
                staleness_bound: 2,
                ..AsyncReplayConfig::default()
            };
            let r = replay_async(scenario, &spec, &wf, &job, policy, &cfg, seed);
            assert!(r.base.total_secs.is_finite());
            push_rows(&mut record, scenario, "chaos", policy, 2, &r);
            row(&mut summary, scenario, "chaos", policy, 2, &r, post, f64::NAN);
        }
        // Total-loss pass: the whole fleet disappears at once; the
        // async replay must park in the degraded state and resume.
        let cfg = AsyncReplayConfig {
            base: chaos_base.clone(),
            staleness_bound: 2,
            ..AsyncReplayConfig::default()
        };
        let r = replay_async_with_trace(
            base.clone(),
            total_loss_trace(&base),
            &wf,
            &job,
            Policy::Warm,
            &cfg,
            seed,
        );
        assert!(r.base.degraded_iters >= 1, "{}: total loss never degraded", scenario.name());
        assert!(!r.base.records.last().map(|x| x.degraded).unwrap_or(true));
        push_rows(&mut record, scenario, "total-loss", Policy::Warm, 2, &r);
        row(&mut summary, scenario, "total-loss", Policy::Warm, 2, &r, post, f64::NAN);
    }
    summary.print();
    if let Ok(p) = record.save(&hetrl::metrics::results_dir()) {
        println!("rows saved to {}", p.display());
    }
}
