//! Figure "async" (new, beyond the paper) — synchronous vs asynchronous
//! workflow goodput under elastic cluster dynamics: the fig11 replay
//! matrix re-run with the RL task graph split into a generation stream
//! and a training stream joined by a bounded rollout queue
//! (staleness bound `k = 2`), against the `k = 0` degenerate case that
//! is bit-identical to the synchronous path.
//!
//! For every scenario × policy cell the same seeded event trace is
//! replayed twice — once per workflow — so the `vs sync` column
//! isolates what bounded staleness buys once the fleet starts churning:
//! the generation and training pools degrade independently, and a
//! machine loss confined to one pool stalls only that stream while the
//! rollout queue buffers the other (up to `k` policy versions).
//!
//! Rows carry the full per-iteration telemetry of fig11 plus the
//! async-side columns (`workflow`, `staleness_bound`, rollout-queue
//! mean/max depth, producer stall, observed staleness) and are
//! persisted as a `RunRecord` under `bench_out/`.

mod common;

use hetrl::asyncrl::{replay_async, AsyncReplayConfig};
use hetrl::elastic::{first_event_iter, generate_trace, Policy, ReplanConfig, ReplayConfig, TraceConfig};
use hetrl::metrics::RunRecord;
use hetrl::topology::{build_testbed, Scenario, TestbedSpec};
use hetrl::util::json::Json;
use hetrl::util::table::Table;
use hetrl::workflow::{Algo, JobConfig, Mode, ModelSpec, RlWorkflow};

fn main() {
    hetrl::util::logging::init();
    let seed = 17u64;
    let iters = if common::full() { 32 } else { 16 };
    let wf = RlWorkflow::new(Algo::Grpo, Mode::Sync, ModelSpec::qwen_4b());
    let job = JobConfig::default();
    let spec = TestbedSpec::default();
    let base_cfg = ReplayConfig {
        iters,
        trace: TraceConfig { horizon: iters, n_events: 5, ..TraceConfig::default() },
        replan: ReplanConfig {
            warm_budget: if common::full() { 200 } else { 120 },
            cold_budget: common::sha_budget(),
            ..ReplanConfig::default()
        },
        ..ReplayConfig::default()
    };

    let mut record = RunRecord::new(
        "fig_async",
        &[
            "scenario",
            "workflow",
            "staleness_bound",
            "policy",
            "iter",
            "iter_secs",
            "migration_secs",
            "active_gpus",
            "evals",
            "anytime_evals",
            "hypothesis_evals",
            "anytime_cost",
            "cache_hits",
            "cache_misses",
            "queue_depth_mean",
            "queue_depth_max",
            "producer_stall_secs",
            "max_staleness",
            "events",
        ],
    );
    let mut summary = Table::new(
        &format!("Async vs sync elastic replay (Qwen-4B GRPO, {iters} iters, seed {seed})"),
        &[
            "scenario",
            "policy",
            "workflow",
            "k",
            "thpt (samp/s)",
            "post-event thpt",
            "vs sync",
            "queue mean/max",
            "gen stall (s)",
            "evals",
        ],
    );
    for scenario in Scenario::ALL {
        let base = build_testbed(scenario, &spec);
        let trace = generate_trace(&base, &base_cfg.trace, seed);
        let post = first_event_iter(&trace).unwrap_or(0);
        eprintln!(
            "{}: {} events, first at iter {post}",
            scenario.name(),
            trace.len()
        );
        for policy in Policy::ALL {
            let mut sync_thpt = f64::NAN;
            for k in [0usize, 2] {
                let cfg = AsyncReplayConfig {
                    base: base_cfg.clone(),
                    staleness_bound: k,
                    ..AsyncReplayConfig::default()
                };
                let r = replay_async(scenario, &spec, &wf, &job, policy, &cfg, seed);
                for (rec, q) in r.base.records.iter().zip(&r.queue) {
                    record.push(vec![
                        Json::str(scenario.name()),
                        Json::str(r.workflow_name()),
                        Json::num(k as f64),
                        Json::str(policy.name()),
                        Json::num(rec.iter as f64),
                        Json::num(rec.iter_secs),
                        Json::num(rec.migration_secs),
                        Json::num(rec.active_gpus as f64),
                        Json::num(rec.evals as f64),
                        Json::num(rec.anytime_evals as f64),
                        Json::num(rec.hypothesis_evals as f64),
                        // JSON has no ∞; -1 marks "no incumbent / not anytime".
                        Json::num(if rec.anytime_cost.is_finite() { rec.anytime_cost } else { -1.0 }),
                        Json::num(rec.cache_hits as f64),
                        Json::num(rec.cache_misses as f64),
                        Json::num(q.queue_depth_mean),
                        Json::num(q.queue_depth_max as f64),
                        Json::num(q.producer_stall_secs),
                        Json::num(q.max_staleness as f64),
                        Json::str(&rec.events.join("+")),
                    ]);
                }
                let thpt = r.base.throughput();
                if k == 0 {
                    sync_thpt = thpt;
                }
                summary.row(vec![
                    scenario.name().to_string(),
                    policy.name().to_string(),
                    r.workflow_name().to_string(),
                    k.to_string(),
                    format!("{thpt:.2}"),
                    format!("{:.2}", r.base.throughput_after(post)),
                    if k > 0 && sync_thpt.is_finite() && sync_thpt > 0.0 {
                        format!("{:+.1}%", (thpt / sync_thpt - 1.0) * 100.0)
                    } else {
                        "-".to_string()
                    },
                    format!("{:.2}/{}", r.mean_queue_depth(), r.max_queue_depth()),
                    format!("{:.1}", r.producer_stall_secs()),
                    r.base.total_evals.to_string(),
                ]);
            }
        }
    }
    summary.print();
    if let Ok(p) = record.save(&hetrl::metrics::results_dir()) {
        println!("rows saved to {}", p.display());
    }
}
