//! Figures 8/9 — training dynamics with GRPO on GSM8K-like (Fig 8) and
//! MATH-like (Fig 9) synthetic tasks: heterogeneous vs homogeneous
//! fleets, compared by training step and by (virtual) wall-clock.
//!
//! This is a REAL run: the rust engine drives the AOT-compiled
//! JAX/Pallas model through PJRT. Expected shape: per-step reward
//! curves indistinguishable between fleets (heterogeneity does not hurt
//! quality); the heterogeneous fleet's larger aggregate throughput wins
//! on wall-clock.
//!
//! Requires `make artifacts`. Steps scale with HETRL_BENCH_FULL.

mod common;

use hetrl::engine::{GrpoConfig, GrpoTrainer, TaskDifficulty, WorkerFleet};
use hetrl::metrics::RunRecord;
use hetrl::runtime::Runtime;
use hetrl::util::json::Json;
use hetrl::util::table::Table;

fn main() {
    hetrl::util::logging::init();
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("fig8_9_dynamics: artifacts/ missing — run `make artifacts` first; skipping");
        return;
    }
    let rt = Runtime::load("artifacts").expect("runtime");
    let steps = if common::full() { 120 } else { 30 };

    let mut record = RunRecord::new(
        "fig8_9_dynamics",
        &["figure", "fleet", "step", "reward", "kl", "virtual_wall_s"],
    );
    for (figure, difficulty) in [
        ("Fig8(GSM8K-like)", TaskDifficulty::Easy),
        ("Fig9(MATH-like)", TaskDifficulty::Hard),
    ] {
        let mut table = Table::new(
            &format!("{figure}: GRPO training dynamics ({steps} steps)"),
            &["fleet", "mean reward (last 25%)", "final kl", "virtual wall (s)"],
        );
        for (fleet_name, fleet) in [
            ("homogeneous(3 ref)", WorkerFleet::homogeneous(3)),
            ("heterogeneous(8 mixed)", WorkerFleet::heterogeneous_default()),
        ] {
            let cfg = GrpoConfig {
                difficulty,
                seed: 11, // same seed: identical rollouts modulo fleet
                ..GrpoConfig::default()
            };
            let mut trainer = GrpoTrainer::new(&rt, cfg, fleet).expect("trainer");
            let mut rewards = Vec::new();
            let mut final_kl = 0.0;
            let mut vwall = 0.0;
            for s in 0..steps {
                let st = trainer.step().expect("step");
                record.push(vec![
                    Json::str(figure),
                    Json::str(fleet_name),
                    Json::num(st.step as f64),
                    Json::num(st.mean_reward),
                    Json::num(st.kl),
                    Json::num(st.virtual_wall),
                ]);
                rewards.push(st.mean_reward);
                final_kl = st.kl;
                vwall = st.virtual_wall;
                if s % 10 == 0 {
                    eprintln!("  {figure} {fleet_name} step {s}: reward {:.3}", st.mean_reward);
                }
            }
            let tail = &rewards[rewards.len() * 3 / 4..];
            let tail_mean = tail.iter().sum::<f64>() / tail.len() as f64;
            table.row(vec![
                fleet_name.to_string(),
                format!("{tail_mean:.3}"),
                format!("{final_kl:.4}"),
                format!("{vwall:.1}"),
            ]);
        }
        table.print();
    }
    if let Ok(p) = record.save(&hetrl::metrics::results_dir()) {
        println!("curves saved to {}", p.display());
    }
}
