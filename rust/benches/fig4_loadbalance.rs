//! Figure 4 — load-balancing ablation on synchronous RL training:
//! throughput with and without HetRL's data-level + layer-level
//! balancing, across model sizes, Single- and Multi-Region scenarios.
//!
//! Expected shape: up to ~12% gain in Single-Region, up to ~18% in
//! Multi-Region (paper §5.3).

mod common;

use common::{model_sizes, sha_budget, sim_cfg, workflow};
use hetrl::balance::{self, BalanceConfig};
use hetrl::metrics::RunRecord;
use hetrl::scheduler::{Budget, Scheduler, ShaEaScheduler};
use hetrl::simulator::simulate_plan;
use hetrl::topology::{build_testbed, Scenario, TestbedSpec};
use hetrl::util::json::Json;
use hetrl::util::table::Table;
use hetrl::workflow::{Algo, JobConfig, Mode};

fn main() {
    hetrl::util::logging::init();
    let job = JobConfig::default();
    let mut record = RunRecord::new(
        "fig4_loadbalance",
        &["scenario", "algo", "model", "lb_off", "lb_on", "gain_pct"],
    );
    let mut table = Table::new(
        "Figure 4: load balancing ablation (sync, simulated samples/s)",
        &["scenario", "algo", "model", "LB off", "LB on", "gain"],
    );
    for scenario in [Scenario::SingleRegion, Scenario::MultiRegionHybrid] {
        let topo = build_testbed(scenario, &TestbedSpec::default());
        for algo in [Algo::Ppo, Algo::Grpo] {
            for model in model_sizes() {
                let wf = workflow(algo, Mode::Sync, &model);
                let mut sched = ShaEaScheduler::new(4);
                let out = sched.schedule(&topo, &wf, &job, Budget::timed(sha_budget(), 90.0));
                let Some(plan) = out.plan else { continue };
                let off = simulate_plan(&topo, &wf, &job, &plan, &sim_cfg()).throughput;
                let balanced = balance::apply(&plan, &wf, &topo, BalanceConfig::default());
                let on = simulate_plan(&topo, &wf, &job, &balanced, &sim_cfg()).throughput;
                let gain = (on / off - 1.0) * 100.0;
                table.row(vec![
                    scenario.name().to_string(),
                    algo.name().to_string(),
                    model.name.clone(),
                    format!("{off:.1}"),
                    format!("{on:.1}"),
                    format!("{gain:+.1}%"),
                ]);
                record.push(vec![
                    Json::str(scenario.name()),
                    Json::str(algo.name()),
                    Json::str(&model.name),
                    Json::num(off),
                    Json::num(on),
                    Json::num(gain),
                ]);
            }
        }
    }
    table.print();
    if let Ok(p) = record.save(&hetrl::metrics::results_dir()) {
        println!("rows saved to {}", p.display());
    }
}
