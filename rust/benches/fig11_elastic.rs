//! Figure 11 (new, beyond the paper) — elastic cluster dynamics: replay
//! a seeded event trace (with a guaranteed spot preemption) through the
//! full stack under five policies and compare simulated throughput.
//! Policies run and are recorded in the fixed `Policy::ALL` order, and
//! every JSON row names its policy explicitly (the `policy` column):
//!
//! * static        — incumbent plan repaired only, never re-searched;
//! * warm-replan   — event-driven warm-started search, migration-aware
//!                   objective, reduced budget;
//! * anytime       — warm-replan plus the background anytime search
//!                   between events (sim-time eval allowance), merged
//!                   migration-aware at each barrier;
//! * preempt       — anytime plus predictive preemption: noticed
//!                   machine losses pre-warm a hypothesis incumbent on
//!                   the post-event fleet (allowance split between the
//!                   two incumbents; `hypothesis_evals` column);
//! * oracle        — full-budget re-search with free instant migration
//!                   (upper bound).
//!
//! The matrix runs three traces per scenario (the `trace` column):
//!
//! * `base`       — the loss/join trace exactly as before this column
//!                  existed; recovery pricing off, so the recovery
//!                  columns are identically zero (asserted — the
//!                  degeneracy pin);
//! * `chaos`      — the same trace plus seeded transient faults (NIC
//!                  bursts, checkpoint-store outages, task failures)
//!                  with recovery pricing on and the checkpoint cadence
//!                  searched — retry stall, rollback rework and
//!                  checkpoint overhead all land in the rows;
//! * `total-loss` — a synthetic trace that preempts *every* machine at
//!                  once (unnoticed) and rejoins them later: the replay
//!                  must park in the degraded state and resume, never
//!                  panic (asserted).
//!
//! Expected shape: after the first preemption, warm-replan recovers
//! most of the oracle's throughput while static — stuck with a plan
//! shaped for the departed fleet — trails; anytime closes more of the
//! remaining gap using only spare cycles, and preempt closes it
//! earlier still by planning through the forecast loss; warm-replan
//! spends a small fraction of the oracle's search evaluations. Rows
//! are persisted as a `RunRecord` under `bench_out/`.

mod common;

use hetrl::costmodel::RecoveryModel;
use hetrl::elastic::{
    self, first_event_iter, generate_trace, CkptSearchConfig, ClusterEvent, Policy, ReplanConfig,
    ReplayConfig, ReplayResult, TraceConfig, TraceEvent,
};
use hetrl::metrics::RunRecord;
use hetrl::topology::{build_testbed, DeviceTopology, Scenario, TestbedSpec};
use hetrl::util::json::Json;
use hetrl::util::table::Table;
use hetrl::workflow::{Algo, JobConfig, Mode, ModelSpec, RlWorkflow};

/// Preempt every machine of `base` at once (no advance notice), rejoin
/// them all four iterations later: the graceful-degradation worst case.
fn total_loss_trace(base: &DeviceTopology) -> Vec<TraceEvent> {
    let n = base.devices.iter().map(|d| d.machine + 1).max().unwrap_or(0);
    let mut trace: Vec<TraceEvent> = (0..n)
        .map(|m| TraceEvent {
            at_iter: 2,
            event: ClusterEvent::MachinePreempt { machine: m },
            notice_secs: None,
        })
        .collect();
    trace.extend((0..n).map(|m| TraceEvent {
        at_iter: 6,
        event: ClusterEvent::MachineJoin { machine: m },
        notice_secs: None,
    }));
    trace
}

fn push_rows(
    record: &mut RunRecord,
    scenario: Scenario,
    trace_name: &str,
    policy: Policy,
    r: &ReplayResult,
) {
    for rec in &r.records {
        record.push(vec![
            Json::str(scenario.name()),
            Json::str(trace_name),
            // Constant here; `benches/fig_async.rs` fills the
            // async side of the same schema.
            Json::str("sync"),
            Json::num(0.0),
            Json::str(policy.name()),
            Json::num(rec.iter as f64),
            Json::num(rec.iter_secs),
            Json::num(rec.migration_secs),
            Json::num(rec.active_gpus as f64),
            Json::num(rec.evals as f64),
            Json::num(rec.anytime_evals as f64),
            Json::num(rec.hypothesis_evals as f64),
            // JSON has no ∞; -1 marks "no incumbent / not anytime".
            Json::num(if rec.anytime_cost.is_finite() { rec.anytime_cost } else { -1.0 }),
            Json::num(rec.cache_hits as f64),
            Json::num(rec.cache_misses as f64),
            // The sync iteration has no rollout queue.
            Json::num(0.0),
            Json::num(0.0),
            Json::num(0.0),
            Json::num(rec.retry_stall_secs),
            Json::num(rec.rework_secs),
            Json::num(rec.ckpt_secs),
            Json::num(if rec.degraded { 1.0 } else { 0.0 }),
            Json::str(&rec.events.join("+")),
        ]);
    }
}

fn main() {
    hetrl::util::logging::init();
    let seed = 17u64;
    let iters = if common::full() { 32 } else { 16 };
    let wf = RlWorkflow::new(Algo::Grpo, Mode::Sync, ModelSpec::qwen_4b());
    let job = JobConfig::default();
    let spec = TestbedSpec::default();
    let cfg = ReplayConfig {
        iters,
        trace: TraceConfig { horizon: iters, n_events: 5, ..TraceConfig::default() },
        replan: ReplanConfig {
            warm_budget: if common::full() { 200 } else { 120 },
            cold_budget: common::sha_budget(),
            ..ReplanConfig::default()
        },
        ..ReplayConfig::default()
    };
    // Chaos variant: seeded transient faults on top of the same base
    // trace, recovery pricing on, checkpoint cadence searched (one
    // halving round over the default candidate set).
    let chaos_cfg = ReplayConfig {
        trace: TraceConfig { fault_events: 4, ..cfg.trace.clone() },
        recovery: RecoveryModel::with_interval(600.0),
        ckpt_search: Some(CkptSearchConfig { rounds: 1, ..CkptSearchConfig::default() }),
        ..cfg.clone()
    };

    let mut record = RunRecord::new(
        "fig11_elastic",
        &[
            "scenario",
            "trace",
            "workflow",
            "staleness_bound",
            "policy",
            "iter",
            "iter_secs",
            "migration_secs",
            "active_gpus",
            "evals",
            "anytime_evals",
            "hypothesis_evals",
            "anytime_cost",
            "cache_hits",
            "cache_misses",
            "queue_depth_mean",
            "queue_depth_max",
            "producer_stall_secs",
            "retry_stall_secs",
            "rework_secs",
            "ckpt_secs",
            "degraded",
            "events",
        ],
    );
    let mut summary = Table::new(
        &format!("Figure 11: elastic replay (Qwen-4B sync GRPO, {iters} iters, seed {seed})"),
        &[
            "scenario",
            "trace",
            "policy",
            "thpt (samp/s)",
            "post-event thpt",
            "vs static",
            "evals",
            "cache hit%",
            "migration (s)",
            "stall (s)",
            "rework (s)",
            "ckpt (s)",
            "degr",
        ],
    );
    let row = |summary: &mut Table,
               scenario: Scenario,
               tr: &str,
               policy: Policy,
               r: &ReplayResult,
               post: usize,
               static_post: f64| {
        let post_thpt = r.throughput_after(post);
        let mig: f64 = r.records.iter().map(|x| x.migration_secs).sum();
        summary.row(vec![
            scenario.name().to_string(),
            tr.to_string(),
            policy.name().to_string(),
            format!("{:.2}", r.throughput()),
            format!("{post_thpt:.2}"),
            if static_post.is_finite() && static_post > 0.0 {
                format!("{:+.1}%", (post_thpt / static_post - 1.0) * 100.0)
            } else {
                "-".to_string()
            },
            r.total_evals.to_string(),
            format!("{:.0}%", r.cache_hit_rate() * 100.0),
            format!("{mig:.1}"),
            format!("{:.1}", r.retry_stall_secs),
            format!("{:.1}", r.rework_secs),
            format!("{:.1}/{}", r.ckpt_secs, r.ckpts),
            r.degraded_iters.to_string(),
        ]);
    };
    for scenario in Scenario::ALL {
        let base = build_testbed(scenario, &spec);
        let trace = generate_trace(&base, &cfg.trace, seed);
        let post = first_event_iter(&trace).unwrap_or(0);
        eprintln!(
            "{}: {} events, first at iter {post}",
            scenario.name(),
            trace.len()
        );
        let mut static_post = f64::NAN;
        for policy in Policy::ALL {
            let r = elastic::replay(scenario, &spec, &wf, &job, policy, &cfg, seed);
            if policy == Policy::Static {
                static_post = r.throughput_after(post);
            }
            push_rows(&mut record, scenario, "base", policy, &r);
            row(&mut summary, scenario, "base", policy, &r, post, static_post);
            // Degeneracy pin: recovery off charges exactly nothing.
            assert_eq!(r.retry_stall_secs + r.rework_secs + r.ckpt_secs, 0.0);
        }
        // Chaos pass: every policy must survive the fault stream and
        // report the recovery charges it paid.
        let mut chaos_static_post = f64::NAN;
        for policy in Policy::ALL {
            let r = elastic::replay(scenario, &spec, &wf, &job, policy, &chaos_cfg, seed);
            if policy == Policy::Static {
                chaos_static_post = r.throughput_after(post);
            }
            push_rows(&mut record, scenario, "chaos", policy, &r);
            row(&mut summary, scenario, "chaos", policy, &r, post, chaos_static_post);
            assert!(r.total_secs.is_finite());
        }
        // Total-loss pass: the whole fleet disappears at once; the
        // replay must park in the degraded state and resume on rejoin.
        let r = elastic::replay_with_trace(
            base.clone(),
            total_loss_trace(&base),
            &wf,
            &job,
            Policy::Warm,
            &chaos_cfg,
            seed,
        );
        assert!(r.degraded_iters >= 1, "{}: total loss never degraded", scenario.name());
        assert!(!r.records.last().map(|x| x.degraded).unwrap_or(true));
        push_rows(&mut record, scenario, "total-loss", Policy::Warm, &r);
        row(&mut summary, scenario, "total-loss", Policy::Warm, &r, post, f64::NAN);
    }
    summary.print();
    if let Ok(p) = record.save(&hetrl::metrics::results_dir()) {
        println!("rows saved to {}", p.display());
    }
}
