//! Figure 11 (new, beyond the paper) — elastic cluster dynamics: replay
//! a seeded event trace (with a guaranteed spot preemption) through the
//! full stack under five policies and compare simulated throughput.
//! Policies run and are recorded in the fixed `Policy::ALL` order, and
//! every JSON row names its policy explicitly (the `policy` column):
//!
//! * static        — incumbent plan repaired only, never re-searched;
//! * warm-replan   — event-driven warm-started search, migration-aware
//!                   objective, reduced budget;
//! * anytime       — warm-replan plus the background anytime search
//!                   between events (sim-time eval allowance), merged
//!                   migration-aware at each barrier;
//! * preempt       — anytime plus predictive preemption: noticed
//!                   machine losses pre-warm a hypothesis incumbent on
//!                   the post-event fleet (allowance split between the
//!                   two incumbents; `hypothesis_evals` column);
//! * oracle        — full-budget re-search with free instant migration
//!                   (upper bound).
//!
//! Expected shape: after the first preemption, warm-replan recovers
//! most of the oracle's throughput while static — stuck with a plan
//! shaped for the departed fleet — trails; anytime closes more of the
//! remaining gap using only spare cycles, and preempt closes it
//! earlier still by planning through the forecast loss; warm-replan
//! spends a small fraction of the oracle's search evaluations. Rows
//! are persisted as a `RunRecord` under `bench_out/`.

mod common;

use hetrl::elastic::{self, first_event_iter, generate_trace, Policy, ReplanConfig, ReplayConfig, TraceConfig};
use hetrl::metrics::RunRecord;
use hetrl::topology::{build_testbed, Scenario, TestbedSpec};
use hetrl::util::json::Json;
use hetrl::util::table::Table;
use hetrl::workflow::{Algo, JobConfig, Mode, ModelSpec, RlWorkflow};

fn main() {
    hetrl::util::logging::init();
    let seed = 17u64;
    let iters = if common::full() { 32 } else { 16 };
    let wf = RlWorkflow::new(Algo::Grpo, Mode::Sync, ModelSpec::qwen_4b());
    let job = JobConfig::default();
    let spec = TestbedSpec::default();
    let cfg = ReplayConfig {
        iters,
        trace: TraceConfig { horizon: iters, n_events: 5, ..TraceConfig::default() },
        replan: ReplanConfig {
            warm_budget: if common::full() { 200 } else { 120 },
            cold_budget: common::sha_budget(),
            ..ReplanConfig::default()
        },
        ..ReplayConfig::default()
    };

    let mut record = RunRecord::new(
        "fig11_elastic",
        &[
            "scenario",
            "workflow",
            "staleness_bound",
            "policy",
            "iter",
            "iter_secs",
            "migration_secs",
            "active_gpus",
            "evals",
            "anytime_evals",
            "hypothesis_evals",
            "anytime_cost",
            "cache_hits",
            "cache_misses",
            "queue_depth_mean",
            "queue_depth_max",
            "producer_stall_secs",
            "events",
        ],
    );
    let mut summary = Table::new(
        &format!("Figure 11: elastic replay (Qwen-4B sync GRPO, {iters} iters, seed {seed})"),
        &[
            "scenario",
            "policy",
            "thpt (samp/s)",
            "post-event thpt",
            "vs static",
            "evals",
            "bg evals",
            "hyp evals",
            "cache hit%",
            "migration (s)",
        ],
    );
    for scenario in Scenario::ALL {
        let base = build_testbed(scenario, &spec);
        let trace = generate_trace(&base, &cfg.trace, seed);
        let post = first_event_iter(&trace).unwrap_or(0);
        eprintln!(
            "{}: {} events, first at iter {post}",
            scenario.name(),
            trace.len()
        );
        let mut static_post = f64::NAN;
        for policy in Policy::ALL {
            let r = elastic::replay(scenario, &spec, &wf, &job, policy, &cfg, seed);
            for rec in &r.records {
                record.push(vec![
                    Json::str(scenario.name()),
                    // Constant here; `benches/fig_async.rs` fills the
                    // async side of the same schema.
                    Json::str("sync"),
                    Json::num(0.0),
                    Json::str(policy.name()),
                    Json::num(rec.iter as f64),
                    Json::num(rec.iter_secs),
                    Json::num(rec.migration_secs),
                    Json::num(rec.active_gpus as f64),
                    Json::num(rec.evals as f64),
                    Json::num(rec.anytime_evals as f64),
                    Json::num(rec.hypothesis_evals as f64),
                    // JSON has no ∞; -1 marks "no incumbent / not anytime".
                    Json::num(if rec.anytime_cost.is_finite() { rec.anytime_cost } else { -1.0 }),
                    Json::num(rec.cache_hits as f64),
                    Json::num(rec.cache_misses as f64),
                    // The sync iteration has no rollout queue.
                    Json::num(0.0),
                    Json::num(0.0),
                    Json::num(0.0),
                    Json::str(&rec.events.join("+")),
                ]);
            }
            let post_thpt = r.throughput_after(post);
            if policy == Policy::Static {
                static_post = post_thpt;
            }
            let mig: f64 = r.records.iter().map(|x| x.migration_secs).sum();
            summary.row(vec![
                scenario.name().to_string(),
                policy.name().to_string(),
                format!("{:.2}", r.throughput()),
                format!("{post_thpt:.2}"),
                if static_post.is_finite() && static_post > 0.0 {
                    format!("{:+.1}%", (post_thpt / static_post - 1.0) * 100.0)
                } else {
                    "-".to_string()
                },
                r.total_evals.to_string(),
                r.anytime_evals.to_string(),
                r.hypothesis_evals.to_string(),
                format!("{:.0}%", r.cache_hit_rate() * 100.0),
                format!("{mig:.1}"),
            ]);
        }
    }
    summary.print();
    if let Ok(p) = record.save(&hetrl::metrics::results_dir()) {
        println!("rows saved to {}", p.display());
    }
}
