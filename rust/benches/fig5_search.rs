//! Figure 5 — search-efficiency comparison for Qwen-8B synchronous PPO
//! on the 64-GPU fleet: best plan cost found vs wall-clock search time
//! for HetRL(SHA-EA), HetRL(ILP), verl's scheduler and a pure EA (DEAP).
//!
//! Expected shape: SHA-EA dominates at every budget; ILP is poor at
//! small budgets but (on small instances; see fig6) optimal eventually;
//! verl plateaus immediately (its search space ignores heterogeneity);
//! DEAP trails SHA-EA.

mod common;

use hetrl::metrics::RunRecord;
use hetrl::scheduler::{
    Budget, IlpScheduler, PureEaScheduler, Scheduler, ShaEaScheduler, VerlScheduler,
};
use hetrl::topology::{build_testbed, Scenario, TestbedSpec};
use hetrl::util::json::Json;
use hetrl::util::table::Table;
use hetrl::workflow::{Algo, JobConfig, Mode, ModelSpec, RlWorkflow};

fn main() {
    hetrl::util::logging::init();
    let topo = build_testbed(Scenario::MultiCountry, &TestbedSpec::default());
    let wf = RlWorkflow::new(Algo::Ppo, Mode::Sync, ModelSpec::qwen_8b());
    let job = JobConfig::default();
    let budgets: Vec<usize> = if common::full() {
        vec![50, 150, 400, 1000, 2500, 6000]
    } else {
        vec![50, 150, 400, 1000]
    };
    let wall_cap = if common::full() { 120.0 } else { 30.0 };

    let mut record = RunRecord::new(
        "fig5_search",
        &["scheduler", "budget_evals", "wall_s", "best_iter_time_s"],
    );
    let mut table = Table::new(
        "Figure 5: search efficiency (Qwen-8B sync PPO, 64 GPUs, Multi-Country)",
        &["scheduler", "budget", "wall (s)", "best iter (s)"],
    );
    for budget in &budgets {
        let runs: Vec<(String, Box<dyn Scheduler>)> = vec![
            ("HetRL(SHA-EA)".into(), Box::new(ShaEaScheduler::new(2))),
            ("HetRL(ILP)".into(), Box::new(IlpScheduler::with_time_limit(wall_cap * 0.8))),
            ("verl".into(), Box::new(VerlScheduler::new(2))),
            ("DEAP".into(), Box::new(PureEaScheduler::new(2))),
        ];
        for (name, mut sched) in runs {
            let out = sched.schedule(&topo, &wf, &job, Budget::timed(*budget, wall_cap));
            table.row(vec![
                name.clone(),
                budget.to_string(),
                format!("{:.2}", out.wall),
                if out.cost.is_finite() {
                    format!("{:.1}", out.cost)
                } else {
                    "∞".into()
                },
            ]);
            record.push(vec![
                Json::str(&name),
                Json::num(*budget as f64),
                Json::num(out.wall),
                Json::num(if out.cost.is_finite() { out.cost } else { -1.0 }),
            ]);
        }
    }
    table.print();
    if let Ok(p) = record.save(&hetrl::metrics::results_dir()) {
        println!("rows saved to {}", p.display());
    }
}
