//! Component engine: the simulator's event loop as an explicit set of
//! schedulable components (borrowed from embedded_emul's execution
//! engine architecture).
//!
//! A [`Component`] exposes `id()`, `next_tick()` and `tick()`; the
//! [`Engine`] drives all components off one priority queue keyed
//! `(next_tick, ComponentId)` — earliest tick first, ties broken by
//! ascending component id, so the global event order is a deterministic
//! function of component state alone. The op-DAG executor
//! ([`OpExecutor`]), device banks and NIC/link-token pools and
//! checkpoint stores ([`ResourceOwner`], one per [`ResourceKind`]) are
//! all components; future background migrations slot in as additional
//! components with finite `next_tick`s rather than special cases inside
//! the executor loop.
//!
//! # Bit-identity with the legacy executor
//!
//! [`crate::simulator::SimGraph::simulate`] runs on this engine and is
//! bit-identical to the pre-component executor
//! ([`crate::simulator::SimGraph::simulate_reference`]): the executor
//! commits exactly one op per tick — the least `(ready_time, tie_rank,
//! op id)` entry of its ready heap — and its `next_tick` is that
//! entry's ready time, so the engine pops ops in exactly the legacy
//! `(ready_time, op id)` order (successor ready times equal dependency
//! finish times, which are never below the current queue minimum, so
//! engine time is monotone). Start/finish arithmetic, resource
//! free-time updates and busy accounting run in the same order with
//! the same expressions, hence identical f64 results.
//!
//! # Seeded interleaving fuzz ([`ShuffleConfig`])
//!
//! With a shuffle seed set, same-timestamp ready ties are permuted by
//! a deterministic seeded `tie_rank`; ops with distinct ready times
//! are never reordered. The rank is assigned per *conflict component*,
//! not per op: ops that contend for a resource keep their FIFO (op id
//! = program issue) order, which is load-bearing — e.g. microbatch
//! issue order through a pipeline stage is a permutation-flow-shop
//! sequence whose reordering would legitimately change the makespan.
//!
//! A conflict component is the union-find closure of two couplings:
//! ops transitively sharing a resource, **and every zero-duration op
//! joined into its successors' components**. The second rule is what
//! makes the invariance sound. An op with positive duration that
//! commits at instant `t` releases its successors strictly after `t`,
//! so every op that becomes ready at an instant is already in the
//! ready heap when the engine starts draining that instant — except
//! when the releasing dependency is a zero-duration op committing at
//! the same instant (a barrier, or a dur-0 resource op whose resources
//! are idle). Such a *mid-instant release* makes the releaser's pop
//! position observable: A=barrier(dur 0), C=op(res 0, dep A),
//! B=op(res 0), all ready at t=0 — FIFO pops A, C, B (start `[0,0,1]`)
//! but any rank placing A after res 0's component pops B first (start
//! `[0,1,0]`). Coupling A into C's component pins A's pop to FIFO
//! order relative to B and C.
//!
//! With that rule, every mid-instant release is an intra-component
//! event, so each component's commit sequence is a self-contained
//! "least op id currently ready" process — identical under FIFO and
//! under every rank assignment, whatever the cross-component
//! interleaving. Components touch disjoint resource state and ready
//! times are dependency finishes, so by induction over instants the
//! entire [`SimOutcome`] (start, finish, busy, makespan, bit for bit)
//! is invariant under every shuffle seed. The shuffle therefore
//! perturbs the engine's *internal* event interleaving (the thing a
//! latent order-sensitivity bug would depend on) while pinning the
//! *observable* schedule; with it off (`None`, the default) the rank
//! is the op id itself and the order is byte-identical to FIFO.
//! `tests/prop_interleave.rs` fuzzes this invariance across random
//! DAGs (tie-rich, ~1 in 8 barriers, ~1 in 5 zero durations) and both
//! replay workflows; `python/tests/test_des_shuffle.py` runs the same
//! fuzz against an executable Python port of this engine.

use std::any::Any;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::des::{OpId, ResourceKind, SimGraph, SimOutcome};
use crate::util::ford;
use crate::util::rng::Rng;

/// Identity of a component in the [`Engine`]; doubles as the
/// same-tick tie-break (ascending) in the event queue.
pub type ComponentId = usize;

/// Seeded tie-break shuffler for same-timestamp ready events.
///
/// Off (`Option::None` wherever it is plumbed) means strict FIFO
/// `(ready_time, op id)` order, byte-identical to the legacy executor.
/// On, ops that become ready at the *same* instant are reordered by a
/// deterministic seeded rank of their conflict component (ops
/// transitively sharing a resource, plus every zero-duration op
/// coupled into its successors' components — see the module docs for
/// why within-component FIFO order must be preserved, why mid-instant
/// releases force the zero-duration coupling, and why the resulting
/// schedule is bit-invariant). Distinct ready times are never
/// reordered, and any two runs with the same seed still produce the
/// identical event order — this fuzzes the tie-break, not determinism.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShuffleConfig {
    /// Seed of the tie-rank stream (the crate's [`Rng`]).
    pub seed: u64,
}

impl ShuffleConfig {
    /// Deterministic tie rank for conflict-component key `key`: one
    /// draw from a per-key [`Rng`] stream split off `(seed, key)`.
    /// Equal-ready-time ties order by `(rank, op id)`, so even rank
    /// collisions stay deterministic.
    pub fn tie_rank(&self, key: u64) -> u64 {
        Rng::new(self.seed ^ key.wrapping_mul(0x9E37_79B9_7F4A_7C15)).next_u64()
    }
}

/// A schedulable simulation component.
///
/// The engine pops the component with the least `(next_tick, id)` and
/// calls [`Component::tick`]; the returned value is its new
/// `next_tick` (`f64::INFINITY` to go idle). A component's `next_tick`
/// may only change as a result of its *own* tick; cross-component
/// interaction during a tick goes through [`EngineCtx`] accessors and
/// must not reschedule the peer (a stale-entry check in the engine
/// guards this contract).
pub trait Component: Any {
    /// Queue identity; assigned at [`Engine::add`] time.
    fn id(&self) -> ComponentId;
    /// Simulation time of this component's next event
    /// (`f64::INFINITY` when idle).
    fn next_tick(&self) -> f64;
    /// Advance to `now`, perform one event, return the new `next_tick`.
    fn tick(&mut self, now: f64, ctx: &mut EngineCtx) -> f64;
    /// Downcast support for typed cross-component access.
    fn as_any_mut(&mut self) -> &mut dyn Any;
}

/// Queue key: `(next_tick, component id)`, min-first.
struct EventKey(f64, ComponentId);

impl PartialEq for EventKey {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}
impl Eq for EventKey {}
impl PartialOrd for EventKey {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for EventKey {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        ford::cmp_f64(self.0, other.0).then(self.1.cmp(&other.1))
    }
}

/// What a ticking component sees of the rest of the system: the graph
/// being simulated plus typed access to its peer components (the
/// ticking component itself is checked out of its slot for the
/// duration of the tick).
pub struct EngineCtx<'a, 'g> {
    /// The graph under simulation (op table, resource kinds).
    pub graph: &'g SimGraph,
    slots: &'a mut [Option<Box<dyn Component>>],
}

impl EngineCtx<'_, '_> {
    /// Typed mutable access to a peer component. Panics if `cid` is the
    /// ticking component (checked out) or the type does not match.
    pub fn peer_mut<C: Component>(&mut self, cid: ComponentId) -> &mut C {
        self.slots[cid]
            .as_mut()
            .expect("peer_mut: component is ticking or absent")
            .as_any_mut()
            .downcast_mut::<C>()
            .expect("peer_mut: component type mismatch")
    }
}

/// The component scheduler: a slot per component plus the
/// `(next_tick, ComponentId)` event queue.
#[derive(Default)]
pub struct Engine {
    slots: Vec<Option<Box<dyn Component>>>,
    queue: BinaryHeap<Reverse<EventKey>>,
}

impl Engine {
    /// An engine with no components.
    pub fn new() -> Self {
        Engine::default()
    }

    /// Next component id to be assigned by [`Engine::add`].
    pub fn next_id(&self) -> ComponentId {
        self.slots.len()
    }

    /// Register a component. Its `id()` must equal [`Engine::next_id`]
    /// at the time of the call (components are constructed knowing
    /// their slot).
    pub fn add(&mut self, c: Box<dyn Component>) -> ComponentId {
        let cid = self.slots.len();
        assert_eq!(c.id(), cid, "component id must match its slot");
        self.slots.push(Some(c));
        cid
    }

    /// Typed mutable access to a component between runs (setup /
    /// outcome extraction). Panics on type mismatch.
    pub fn component_mut<C: Component>(&mut self, cid: ComponentId) -> &mut C {
        self.slots[cid]
            .as_mut()
            .expect("component_mut: absent component")
            .as_any_mut()
            .downcast_mut::<C>()
            .expect("component_mut: component type mismatch")
    }

    /// Run to quiescence: repeatedly pop the least `(next_tick, id)`
    /// entry and tick that component until no component has a finite
    /// `next_tick`. Stale queue entries (a component whose `next_tick`
    /// moved since it was enqueued) are re-enqueued at their fresh
    /// time, never ticked.
    pub fn run(&mut self, graph: &SimGraph) {
        for (cid, slot) in self.slots.iter().enumerate() {
            let t = slot.as_ref().expect("run: absent component").next_tick();
            if t.is_finite() {
                self.queue.push(Reverse(EventKey(t, cid)));
            }
        }
        while let Some(Reverse(EventKey(t, cid))) = self.queue.pop() {
            let fresh = self.slots[cid].as_ref().expect("run: absent component").next_tick();
            if ford::cmp_f64(fresh, t) != std::cmp::Ordering::Equal {
                if fresh.is_finite() {
                    self.queue.push(Reverse(EventKey(fresh, cid)));
                }
                continue;
            }
            let mut c = self.slots[cid].take().expect("run: component re-entry");
            let nt = c.tick(t, &mut EngineCtx { graph, slots: &mut self.slots });
            self.slots[cid] = Some(c);
            if nt.is_finite() {
                self.queue.push(Reverse(EventKey(nt, cid)));
            }
        }
    }
}

/// Passive resource-owner component: holds free-time and busy
/// accounting for all resources of one [`ResourceKind`] (devices,
/// NIC/link tokens, checkpoint stores). Passive today — its
/// `next_tick` is infinite until background transfers (migration
/// overlap, ROADMAP) give it events of its own; the executor reads and
/// writes it through [`EngineCtx::peer_mut`] during op commits.
pub struct ResourceOwner {
    cid: ComponentId,
    kind: ResourceKind,
    /// Time each resource becomes available, indexed by the
    /// *kind-local* resource index (`run_sim`'s `local_of` map turns a
    /// global resource id into its owner's local index), so each owner
    /// allocates exactly as many slots as it owns resources.
    free: Vec<f64>,
    /// Cumulative busy time per resource (same indexing).
    busy: Vec<f64>,
}

impl ResourceOwner {
    /// Owner of `n_kind` resources of `kind`, addressed by kind-local
    /// index `0..n_kind`.
    pub fn new(cid: ComponentId, kind: ResourceKind, n_kind: usize) -> Self {
        ResourceOwner { cid, kind, free: vec![0.0; n_kind], busy: vec![0.0; n_kind] }
    }

    /// The kind of resource this component owns.
    pub fn kind(&self) -> ResourceKind {
        self.kind
    }

    /// Time the resource with kind-local index `l` becomes available.
    pub fn free_at(&self, l: usize) -> f64 {
        self.free[l]
    }

    /// Occupy kind-local resource `l` until `until`, accruing `dur`
    /// busy time.
    pub fn occupy(&mut self, l: usize, until: f64, dur: f64) {
        self.free[l] = until;
        self.busy[l] += dur;
    }
}

impl Component for ResourceOwner {
    fn id(&self) -> ComponentId {
        self.cid
    }
    fn next_tick(&self) -> f64 {
        f64::INFINITY
    }
    fn tick(&mut self, _now: f64, _ctx: &mut EngineCtx) -> f64 {
        f64::INFINITY
    }
    fn as_any_mut(&mut self) -> &mut dyn Any {
        self
    }
}

/// Ready-heap key: `(ready_time, tie_rank, op id)`, min-first. With
/// the shuffle off `tie_rank == op id`, so the order collapses to the
/// legacy `(ready_time, op id)` FIFO.
struct ReadyKey(f64, u64, OpId);

impl PartialEq for ReadyKey {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}
impl Eq for ReadyKey {}
impl PartialOrd for ReadyKey {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for ReadyKey {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        ford::cmp_f64(self.0, other.0)
            .then(self.1.cmp(&other.1))
            .then(self.2.cmp(&other.2))
    }
}

/// The op-DAG executor component: FIFO list scheduling over the graph,
/// one op commit per tick. Resource state lives in the
/// [`ResourceOwner`] peers; this component owns only the dependency
/// bookkeeping and the per-op schedule it is building.
pub struct OpExecutor {
    cid: ComponentId,
    /// Owning component per global resource id.
    owner_of: Vec<ComponentId>,
    /// Kind-local index per global resource id (the owner's slot).
    local_of: Vec<usize>,
    /// Ready-heap tie rank per op: the op id itself with the shuffle
    /// off, else the seeded rank of the op's conflict component.
    rank: Vec<u64>,
    indeg: Vec<usize>,
    rdeps: Vec<Vec<OpId>>,
    ready: BinaryHeap<Reverse<ReadyKey>>,
    start: Vec<f64>,
    finish: Vec<f64>,
    makespan: f64,
    committed: usize,
}

impl OpExecutor {
    /// Build the executor for `graph`, seeding the ready heap with all
    /// zero-indegree ops at time 0.
    pub fn new(
        cid: ComponentId,
        graph: &SimGraph,
        owner_of: Vec<ComponentId>,
        local_of: Vec<usize>,
        shuffle: Option<ShuffleConfig>,
    ) -> Self {
        let n = graph.ops.len();
        let mut indeg = vec![0usize; n];
        let mut rdeps: Vec<Vec<OpId>> = vec![Vec::new(); n];
        for (id, op) in graph.ops.iter().enumerate() {
            indeg[id] = op.deps.len();
            for &d in &op.deps {
                rdeps[d].push(id);
            }
        }
        let rank = match shuffle {
            None => (0..n as u64).collect(),
            Some(s) => {
                // Conflict components: union-find over one node per
                // resource plus one virtual node per op (node `nr + id`
                // for op `id`, so resource-less barriers have an
                // identity too). An op joins every resource it uses,
                // which also merges co-used resources, so ops that
                // transitively share a resource land in one component
                // and keep their FIFO order under a shared rank.
                //
                // Zero-duration ops are additionally coupled into each
                // *successor*'s component: a zero-duration commit can
                // release its successors at the very instant being
                // drained, so its position among same-instant pops
                // gates when those successors enter the ready heap
                // relative to their component peers. Shuffling it
                // independently would reorder arrivals at a contended
                // resource (see the module docs' counterexample); with
                // the coupling, every mid-instant release is an
                // intra-component event and FIFO order within the
                // component is preserved.
                let nr = graph.n_resources();
                let mut parent: Vec<usize> = (0..nr + n).collect();
                fn find(parent: &mut [usize], mut x: usize) -> usize {
                    while parent[x] != x {
                        parent[x] = parent[parent[x]];
                        x = parent[x];
                    }
                    x
                }
                fn unite(parent: &mut [usize], a: usize, b: usize) {
                    let (ra, rb) = (find(parent, a), find(parent, b));
                    parent[ra.max(rb)] = ra.min(rb);
                }
                for (id, op) in graph.ops.iter().enumerate() {
                    for &r in &op.resources {
                        unite(&mut parent, nr + id, r);
                    }
                    if op.duration == 0.0 {
                        for &succ in &rdeps[id] {
                            unite(&mut parent, nr + id, nr + succ);
                        }
                    }
                }
                (0..n).map(|id| s.tie_rank(find(&mut parent, nr + id) as u64)).collect()
            }
        };
        let mut ex = OpExecutor {
            cid,
            owner_of,
            local_of,
            rank,
            indeg,
            rdeps,
            ready: BinaryHeap::new(),
            start: vec![f64::NAN; n],
            finish: vec![f64::NAN; n],
            makespan: 0.0,
            committed: 0,
        };
        for id in 0..n {
            if ex.indeg[id] == 0 {
                ex.push_ready(0.0, id);
            }
        }
        ex
    }

    fn push_ready(&mut self, ready: f64, id: OpId) {
        self.ready.push(Reverse(ReadyKey(ready, self.rank[id], id)));
    }

    /// Number of ops committed so far (equals the op count after a
    /// completed run iff the graph was acyclic).
    pub fn committed(&self) -> usize {
        self.committed
    }

    /// Extract the schedule built so far as a [`SimOutcome`] (busy
    /// accounting is merged in by the caller from the resource owners).
    pub fn outcome(&self, busy: Vec<f64>) -> SimOutcome {
        SimOutcome {
            makespan: self.makespan,
            finish: self.finish.clone(),
            start: self.start.clone(),
            busy,
        }
    }
}

impl Component for OpExecutor {
    fn id(&self) -> ComponentId {
        self.cid
    }

    fn next_tick(&self) -> f64 {
        match self.ready.peek() {
            Some(Reverse(k)) => k.0,
            None => f64::INFINITY,
        }
    }

    fn tick(&mut self, _now: f64, ctx: &mut EngineCtx) -> f64 {
        let Reverse(ReadyKey(rt, _rank, id)) = self.ready.pop().expect("tick on empty ready heap");
        let op = &ctx.graph.ops[id];
        let mut t0 = rt;
        for &r in &op.resources {
            t0 = t0
                .max(ctx.peer_mut::<ResourceOwner>(self.owner_of[r]).free_at(self.local_of[r]));
        }
        let t1 = t0 + op.duration;
        for &r in &op.resources {
            ctx.peer_mut::<ResourceOwner>(self.owner_of[r]).occupy(
                self.local_of[r],
                t1,
                op.duration,
            );
        }
        self.start[id] = t0;
        self.finish[id] = t1;
        self.makespan = self.makespan.max(t1);
        self.committed += 1;
        // Each op commits exactly once, so its reverse-dependency list
        // can be consumed (and this sidesteps holding a borrow of
        // `rdeps` across the `indeg`/heap mutations below).
        for succ in std::mem::take(&mut self.rdeps[id]) {
            self.indeg[succ] -= 1;
            if self.indeg[succ] == 0 {
                let r = ctx.graph.ready_of(succ, &self.finish);
                self.push_ready(r, succ);
            }
        }
        self.next_tick()
    }

    fn as_any_mut(&mut self) -> &mut dyn Any {
        self
    }
}

/// Simulate `graph` on the component engine: one [`ResourceOwner`] per
/// resource kind present plus the [`OpExecutor`]. This is the engine
/// behind [`SimGraph::simulate`] / [`SimGraph::simulate_with`].
pub(super) fn run_sim(graph: &SimGraph, shuffle: Option<ShuffleConfig>) -> SimOutcome {
    let nr = graph.n_resources();
    let mut engine = Engine::new();
    // Owner components in fixed kind order; each global resource maps
    // to its kind's owner and a kind-local slot within it (owners
    // allocate only as many slots as they own resources).
    let kind_ix: Vec<usize> = (0..nr)
        .map(|r| {
            ResourceKind::ALL
                .iter()
                .position(|&k| k == graph.resource_kind(r))
                .expect("resource kind not in ResourceKind::ALL")
        })
        .collect();
    let mut kind_counts = [0usize; ResourceKind::ALL.len()];
    let mut local_of = vec![0usize; nr];
    for r in 0..nr {
        local_of[r] = kind_counts[kind_ix[r]];
        kind_counts[kind_ix[r]] += 1;
    }
    let mut owner_cid: [Option<ComponentId>; ResourceKind::ALL.len()] =
        [None; ResourceKind::ALL.len()];
    for (ki, &kind) in ResourceKind::ALL.iter().enumerate() {
        if kind_counts[ki] > 0 {
            let cid = engine.next_id();
            owner_cid[ki] =
                Some(engine.add(Box::new(ResourceOwner::new(cid, kind, kind_counts[ki]))));
        }
    }
    let owner_of: Vec<ComponentId> = (0..nr)
        .map(|r| owner_cid[kind_ix[r]].expect("resource kind without owner component"))
        .collect();
    let exec_cid = engine.next_id();
    engine.add(Box::new(OpExecutor::new(
        exec_cid,
        graph,
        owner_of.clone(),
        local_of.clone(),
        shuffle,
    )));
    engine.run(graph);

    let mut busy = vec![0.0f64; nr];
    for r in 0..nr {
        busy[r] = engine.component_mut::<ResourceOwner>(owner_of[r]).busy[local_of[r]];
    }
    let ex = engine.component_mut::<OpExecutor>(exec_cid);
    assert_eq!(ex.committed(), graph.ops.len(), "cycle in sim graph");
    ex.outcome(busy)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Toy component that fires at fixed times, recording `(time, id)`
    /// into a shared log via the recorder peer.
    struct Pinger {
        cid: ComponentId,
        times: Vec<f64>, // reversed; pop() yields ascending
        recorder: ComponentId,
    }
    struct Recorder {
        cid: ComponentId,
        log: Vec<(f64, ComponentId)>,
    }
    impl Component for Recorder {
        fn id(&self) -> ComponentId {
            self.cid
        }
        fn next_tick(&self) -> f64 {
            f64::INFINITY
        }
        fn tick(&mut self, _now: f64, _ctx: &mut EngineCtx) -> f64 {
            f64::INFINITY
        }
        fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
            self
        }
    }
    impl Component for Pinger {
        fn id(&self) -> ComponentId {
            self.cid
        }
        fn next_tick(&self) -> f64 {
            self.times.last().copied().unwrap_or(f64::INFINITY)
        }
        fn tick(&mut self, now: f64, ctx: &mut EngineCtx) -> f64 {
            let t = self.times.pop().expect("tick past schedule");
            assert_eq!(t, now);
            let me = self.cid;
            ctx.peer_mut::<Recorder>(self.recorder).log.push((now, me));
            self.next_tick()
        }
        fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
            self
        }
    }

    #[test]
    fn engine_orders_by_tick_then_component_id() {
        let g = SimGraph::new(0);
        let mut e = Engine::new();
        let rec = e.add(Box::new(Recorder { cid: 0, log: Vec::new() }));
        // Pinger 1 fires at 2.0 and 1.0; pinger 2 at 1.0 and 3.0. At
        // t=1.0 both are due: component id breaks the tie (1 before 2).
        e.add(Box::new(Pinger { cid: 1, times: vec![2.0, 1.0], recorder: rec }));
        e.add(Box::new(Pinger { cid: 2, times: vec![3.0, 1.0], recorder: rec }));
        e.run(&g);
        let log = &e.component_mut::<Recorder>(rec).log;
        assert_eq!(log, &[(1.0, 1), (1.0, 2), (2.0, 1), (3.0, 2)]);
    }

    #[test]
    fn tie_rank_deterministic_and_seed_sensitive() {
        let s7 = ShuffleConfig { seed: 7 };
        assert_eq!(s7.tie_rank(3), s7.tie_rank(3));
        let ranks7: Vec<u64> = (0..64).map(|i| s7.tie_rank(i)).collect();
        let ranks8: Vec<u64> = (0..64).map(|i| ShuffleConfig { seed: 8 }.tie_rank(i)).collect();
        assert_ne!(ranks7, ranks8);
        // Ranks must actually permute relative order somewhere,
        // otherwise the fuzz is vacuous.
        assert!((1..64).any(|i| ranks7[i] < ranks7[i - 1]));
    }

    #[test]
    fn shuffle_reorders_ties_but_not_distinct_ready_times() {
        // Three independent unit ops on disjoint resources, all ready
        // at t=0: any commit order yields the same schedule, but the
        // shuffle must still be exercised (covered by the equivalence
        // suites); an op chained after them has a distinct ready time
        // and must start last under every seed.
        for seed in [0u64, 7, 41] {
            let mut g = SimGraph::new(3);
            let a = g.add(vec![0], 1.0, vec![], 0);
            g.add(vec![1], 1.0, vec![], 0);
            g.add(vec![2], 1.0, vec![], 0);
            let tail = g.add(vec![0], 1.0, vec![a], 0);
            let o = g.simulate_with(Some(ShuffleConfig { seed }));
            let base = g.simulate();
            assert_eq!(o.start[tail], 1.0);
            assert_eq!(o.makespan, base.makespan);
            assert_eq!(o.start, base.start);
            assert_eq!(o.finish, base.finish);
            assert_eq!(o.busy, base.busy);
        }
    }

    #[test]
    fn zero_duration_release_not_shuffled_across_a_contended_resource() {
        // The mid-instant-release counterexample from the module docs:
        // A=barrier(dur 0), C=op(res 0, dep A), B=op(res 0), all ready
        // at t=0. FIFO commits A, C, B (start [0,0,1]); any rank
        // placing the barrier after res 0's component would commit B
        // first (start [0,1,0]). The zero-duration coupling in the
        // rank union-find must pin FIFO order for every seed.
        let mut g = SimGraph::new(1);
        let a = g.barrier(vec![]);
        let c = g.add(vec![0], 1.0, vec![a], 0);
        let b = g.add(vec![0], 1.0, vec![], 0);
        let base = g.simulate();
        assert_eq!((base.start[c], base.start[b]), (0.0, 1.0));
        for seed in 0..64u64 {
            let o = g.simulate_with(Some(ShuffleConfig { seed }));
            assert_eq!(o.start, base.start, "seed {seed}: start");
            assert_eq!(o.finish, base.finish, "seed {seed}: finish");
            assert_eq!(o.busy, base.busy, "seed {seed}: busy");
        }
    }

    #[test]
    fn zero_duration_chains_stay_coupled_transitively() {
        // A dur-0 resource op (async-pipeline queue enq/deq shape)
        // releasing through a dur-0 chain into a *different* resource's
        // component: q=op(res 1, dur 0) → z=barrier → c=op(res 0),
        // racing b=op(res 0) at t=0. FIFO pops q, z, c, b (start
        // [0,0,0,1]); only the transitive coupling q ∪ z ∪ c keeps the
        // chain's pop positions FIFO relative to b under every seed.
        let mut g = SimGraph::new(2);
        let q = g.add(vec![1], 0.0, vec![], 0);
        let z = g.barrier(vec![q]);
        let c = g.add(vec![0], 1.0, vec![z], 0);
        let b = g.add(vec![0], 1.0, vec![], 0);
        let base = g.simulate();
        assert_eq!((base.start[c], base.start[b]), (0.0, 1.0));
        for seed in 0..64u64 {
            let o = g.simulate_with(Some(ShuffleConfig { seed }));
            assert_eq!(o.start, base.start, "seed {seed}: start");
            assert_eq!(o.finish, base.finish, "seed {seed}: finish");
            assert_eq!(o.busy, base.busy, "seed {seed}: busy");
        }
    }

    #[test]
    fn resource_owners_split_by_kind() {
        // One device op and one link-token op: busy accounting merged
        // across two owner components must match the reference run.
        let mut g = SimGraph::new(1);
        let l = g.add_resource(); // ResourceKind::LinkToken
        g.add(vec![0], 2.0, vec![], 0);
        g.add(vec![l], 3.0, vec![], 0);
        assert_eq!(g.resource_kind(0), ResourceKind::Device);
        assert_eq!(g.resource_kind(l), ResourceKind::LinkToken);
        let o = g.simulate();
        let r = g.simulate_reference();
        assert_eq!(o.busy, vec![2.0, 3.0]);
        assert_eq!(o.busy, r.busy);
        assert_eq!(o.start, r.start);
        assert_eq!(o.finish, r.finish);
    }

    #[test]
    fn ckpt_store_kind_supported() {
        let mut g = SimGraph::new(1);
        let c = g.add_resource_of(ResourceKind::CkptStore);
        let w = g.add(vec![0, c], 1.0, vec![], 0);
        let o = g.simulate();
        assert_eq!(o.finish[w], 1.0);
        assert_eq!(o.busy[c], 1.0);
    }
}
