//! Discrete-event cluster simulator — the testbed substitute.
//!
//! The paper evaluates on 64 physical GPUs with traffic-shaped WAN links;
//! we replay execution plans on a discrete-event simulation of the same
//! device/network graphs. The simulator is deliberately a *different,
//! more detailed* code path than the analytical cost model (§3.3 /
//! Appendix B): it schedules individual micro-batches through pipeline
//! stages with device and link contention, samples response lengths and
//! multiplicative compute/communication jitter, and derives pipeline
//! bubbles and task overlap from the event order rather than closed
//! forms. Cost-model validation (paper Figure 7) compares the two.

pub mod component;
pub mod des;
pub mod noise;
pub mod execsim;

pub use component::{Component, ComponentId, Engine, EngineCtx, OpExecutor, ResourceOwner, ShuffleConfig};
pub use des::{OpId, ResourceKind, SimGraph};
pub use execsim::{simulate_plan, SimConfig, SimResult};
pub use noise::NoiseModel;
