//! Plan execution on the discrete-event core: builds a [`SimGraph`] from
//! an [`ExecutionPlan`] at micro-batch granularity and measures iteration
//! time.
//!
//! Differences from the analytical cost model (intentional — this is the
//! "measured" side of Figure 7):
//! * micro-batches are scheduled individually; pipeline bubbles, 1F1B
//!   interleaving and stage imbalance emerge from the event order;
//! * response lengths are *sampled* per micro-batch (the cost model uses
//!   the expected length);
//! * collectives are simulated step-by-step: a ring all-reduce is
//!   `2(g-1)` chunk steps, each paying the worst link's latency — the
//!   cost model folds this into one α + cv/β term;
//! * WAN links are shared resources: transfers between the same region
//!   pair serialize across tasks;
//! * multiplicative lognormal jitter on compute and communication.

use super::component::ShuffleConfig;
use super::des::{OpId, SimGraph};
use super::noise::NoiseModel;
use crate::costmodel::comm::{cv_all_gather, cv_dp, cv_p2p, cv_pp, cv_tp, layer_params};
use crate::plan::memory::decode_batch_size;
use crate::plan::{ExecutionPlan, TaskPlan};
use crate::topology::DeviceTopology;
use crate::util::rng::Rng;
use crate::util::units::B_BF16;
use crate::workflow::{JobConfig, Mode, RlTaskId, RlWorkflow, TaskKind};

/// Simulation configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Iterations to simulate (results are averaged).
    pub iters: usize,
    pub seed: u64,
    pub noise: NoiseModel,
    /// Optional seeded same-timestamp tie shuffle (`None` = FIFO,
    /// byte-identical to the pre-shuffle simulator). See
    /// [`ShuffleConfig`].
    pub shuffle: Option<ShuffleConfig>,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig { iters: 3, seed: 0xBEEF, noise: NoiseModel::default(), shuffle: None }
    }
}

/// Simulation result.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Mean iteration time (s).
    pub iter_time: f64,
    pub iter_std: f64,
    /// Mean per-task busy span (s), indexed like the workflow tasks.
    pub per_task: Vec<f64>,
    /// Mean device utilization in [0,1].
    pub utilization: f64,
    /// Throughput, samples/s.
    pub throughput: f64,
}

/// Effective sustained FLOP/s of a device (see
/// [`crate::topology::Device::effective_flops`]).
#[inline]
pub fn effective_flops(topo: &DeviceTopology, d: usize) -> f64 {
    topo.devices[d].effective_flops()
}

struct Builder<'a> {
    topo: &'a DeviceTopology,
    job: &'a JobConfig,
    g: SimGraph,
    /// Synthetic shared resources per region pair (WAN backbone). Real
    /// inter-region paths are ECMP multi-flow, so each pair gets
    /// `WAN_CHANNELS` parallel channels; concurrent transfers beyond
    /// that serialize.
    wan_links: Vec<Vec<Vec<usize>>>,
    wan_next: std::cell::Cell<usize>,
    rng: Rng,
    noise: NoiseModel,
}

impl<'a> Builder<'a> {
    fn new(topo: &'a DeviceTopology, job: &'a JobConfig, seed: u64, noise: NoiseModel) -> Self {
        let nr = topo.region_names.len().max(
            topo.devices.iter().map(|d| d.region + 1).max().unwrap_or(1),
        );
        Builder {
            topo,
            job,
            g: SimGraph::new(topo.n()),
            wan_links: vec![vec![Vec::new(); nr]; nr],
            wan_next: std::cell::Cell::new(0),
            rng: Rng::new(seed),
            noise,
        }
    }

    /// WAN backbone channels per region pair.
    const WAN_CHANNELS: usize = 4;

    /// WAN backbone resource for a cross-region transfer (lazily
    /// created; transfers rotate over the pair's channels).
    fn wan_link(&mut self, ra: usize, rb: usize) -> Option<usize> {
        if ra == rb {
            return None;
        }
        let (x, y) = (ra.min(rb), ra.max(rb));
        if self.wan_links[x][y].is_empty() {
            self.wan_links[x][y] =
                (0..Self::WAN_CHANNELS).map(|_| self.g.add_resource()).collect();
        }
        let k = self.wan_next.get();
        self.wan_next.set(k.wrapping_add(1));
        Some(self.wan_links[x][y][k % Self::WAN_CHANNELS])
    }

    /// Simulated duration of a ring all-reduce over `devs` moving `vol`
    /// payload bytes (already scaled by the collective's volume factor):
    /// `2(g-1)` steps of `α_worst + vol/(g·β_worst)`.
    fn allreduce_time(&mut self, devs: &[usize], vol: f64) -> f64 {
        let g = devs.len();
        if g <= 1 || vol <= 0.0 {
            return 0.0;
        }
        let order = self.topo.locality_order(devs);
        let mut alpha_max: f64 = 0.0;
        let mut beta_min = f64::INFINITY;
        for i in 0..g {
            let (a, b) = (order[i], order[(i + 1) % g]);
            alpha_max = alpha_max.max(self.topo.lat(a, b));
            beta_min = beta_min.min(self.topo.bw(a, b));
        }
        let steps = 2.0 * (g as f64 - 1.0);
        let t = steps * (alpha_max + vol / (g as f64 * beta_min));
        t * self.noise.comm_jitter(&mut self.rng)
    }

    /// Best (min) point-to-point pair between two stages and its transfer
    /// duration for `bytes`.
    fn p2p(&mut self, from: &[usize], to: &[usize], bytes: f64) -> (usize, usize, f64) {
        let mut best = (from[0], to[0], f64::INFINITY);
        for &a in from {
            for &b in to {
                if a == b {
                    return (a, b, 0.0);
                }
                let t = self.topo.xfer_time(a, b, bytes);
                if t < best.2 {
                    best = (a, b, t);
                }
            }
        }
        let jt = best.2 * self.noise.comm_jitter(&mut self.rng);
        (best.0, best.1, jt)
    }

    /// Transfer op between stages; uses the WAN backbone resource when
    /// crossing regions so concurrent cross-region transfers contend.
    fn transfer_op(&mut self, from: &[usize], to: &[usize], bytes: f64, deps: Vec<OpId>, tag: usize) -> OpId {
        let (a, b, dur) = self.p2p(from, to, bytes);
        let (ra, rb) = (self.topo.devices[a].region, self.topo.devices[b].region);
        let mut resources = Vec::new();
        if let Some(l) = self.wan_link(ra, rb) {
            resources.push(l);
        }
        self.g.add(resources, dur, deps, tag)
    }

    /// Build ops for one task. Returns the "task finished" barrier op.
    fn build_task(
        &mut self,
        t_idx: usize,
        kind: TaskKind,
        model: &crate::workflow::ModelSpec,
        plan: &TaskPlan,
        after: &[OpId],
    ) -> OpId {
        let s = plan.strategy;
        let job = self.job;
        let total_m = crate::costmodel::task_cost::total_microbatches(job);
        let mut replica_ends: Vec<OpId> = Vec::new();
        // Per (stage, shard) last-backward deps for the DP all-reduce.
        let mut stage_bwd_deps: Vec<Vec<Vec<OpId>>> =
            vec![vec![Vec::new(); s.tp.max(1)]; s.pp];

        for i in 0..s.dp {
            let nm_i = plan.replica_microbatches(total_m, i);
            match kind {
                TaskKind::Generation => {
                    let end = self.build_generation_replica(t_idx, model, plan, i, after);
                    replica_ends.push(end);
                }
                TaskKind::Inference | TaskKind::Training => {
                    let end = self.build_pipeline_replica(
                        t_idx,
                        model,
                        plan,
                        i,
                        nm_i,
                        kind == TaskKind::Training,
                        after,
                        &mut stage_bwd_deps,
                    );
                    replica_ends.push(end);
                }
            }
        }

        // DP gradient all-reduce (training only, dp > 1).
        if kind == TaskKind::Training && s.dp > 1 {
            let mut ar_ops = Vec::new();
            for j in 0..s.pp {
                let vol = cv_dp(plan.layer_split[j], model.h1, model.h2, s.dp, s.tp);
                for k in 0..s.tp {
                    let devs = plan.dp_group(j, k);
                    let dur = self.allreduce_time(&devs, vol);
                    let deps = stage_bwd_deps[j][k].clone();
                    ar_ops.push(self.g.add(devs, dur, deps, t_idx));
                }
            }
            replica_ends.extend(ar_ops);
        }
        self.g.barrier(replica_ends)
    }

    /// Forward(/backward) pipeline for one replica of an inference or
    /// training task.
    #[allow(clippy::too_many_arguments)]
    fn build_pipeline_replica(
        &mut self,
        t_idx: usize,
        model: &crate::workflow::ModelSpec,
        plan: &TaskPlan,
        i: usize,
        nm_i: usize,
        training: bool,
        after: &[OpId],
        stage_bwd_deps: &mut [Vec<Vec<OpId>>],
    ) -> OpId {
        let s = plan.strategy;
        let job = self.job;
        let vol_pp = cv_pp(job.mbs, job.seq_total(), model.h1);
        let mut fwd: Vec<Vec<OpId>> = vec![Vec::new(); s.pp]; // [j][m]
        let mut last_ops: Vec<OpId> = Vec::new();

        // Sampled sequence length per micro-batch (responses vary).
        let seqs: Vec<usize> = (0..nm_i)
            .map(|_| job.seq_in + self.noise.response_len(&mut self.rng, job.seq_out))
            .collect();

        // forward sweep
        for m in 0..nm_i {
            let mut carry: Option<OpId> = None;
            for j in 0..s.pp {
                let devs = plan.tp_group(i, j);
                let dur = self.stage_time(model, plan, j, seqs[m], &devs, false);
                let mut deps: Vec<OpId> = after.to_vec();
                if let Some(c) = carry {
                    deps.push(c);
                }
                let f = self.g.add(devs.clone(), dur, deps, t_idx);
                fwd[j].push(f);
                if j + 1 < s.pp {
                    let next = plan.tp_group(i, j + 1);
                    carry = Some(self.transfer_op(&devs, &next, vol_pp, vec![f], t_idx));
                } else {
                    carry = Some(f);
                }
            }
            last_ops.push(carry.unwrap());
        }

        if !training {
            return self.g.barrier(last_ops);
        }

        // backward sweep (2× forward cost), reverse stage order
        let mut bwd_prev: Vec<Option<OpId>> = vec![None; nm_i];
        let mut ends = Vec::new();
        for m in 0..nm_i {
            // backward for microbatch m starts after its own forward
            let mut carry: Option<OpId> = Some(last_ops[m]);
            for j in (0..s.pp).rev() {
                let devs = plan.tp_group(i, j);
                let dur = self.stage_time(model, plan, j, seqs[m], &devs, true);
                let mut deps: Vec<OpId> = Vec::new();
                if let Some(c) = carry {
                    deps.push(c);
                }
                if let Some(p) = bwd_prev[m] {
                    deps.push(p);
                }
                let b = self.g.add(devs.clone(), dur, deps, t_idx);
                if j > 0 {
                    let prev = plan.tp_group(i, j - 1);
                    carry = Some(self.transfer_op(&devs, &prev, vol_pp, vec![b], t_idx));
                } else {
                    carry = None;
                    ends.push(b);
                }
                bwd_prev[m] = Some(b);
                if m == nm_i - 1 {
                    for k in 0..s.tp {
                        stage_bwd_deps[j][k].push(b);
                    }
                }
            }
        }
        self.g.barrier(ends)
    }

    /// Duration of one pipeline-stage execution of one micro-batch:
    /// compute (slowest TP shard) + per-layer TP all-reduces.
    fn stage_time(
        &mut self,
        model: &crate::workflow::ModelSpec,
        plan: &TaskPlan,
        j: usize,
        seq: usize,
        devs: &[usize],
        backward: bool,
    ) -> f64 {
        let s = plan.strategy;
        let job = self.job;
        let nl_j = plan.layer_split[j];
        let flops = job.mbs as f64
            * nl_j as f64
            * crate::costmodel::compute::layer_flops(seq, model.h1, model.h2);
        let mut comp: f64 = 0.0;
        for &d in devs {
            comp = comp.max(flops / (effective_flops(self.topo, d) * s.tp as f64));
        }
        if backward {
            comp *= 2.0;
        }
        let comp = comp * self.noise.comp_jitter(&mut self.rng);
        // TP all-reduces: one per layer (fwd), two per layer (bwd w/
        // recompute folded into the factor).
        let per_layer = if backward { 2.0 } else { 1.0 };
        let vol_tp = cv_tp(job.mbs, seq, model.h1, s.tp);
        let tp_time = if s.tp > 1 {
            per_layer * nl_j as f64 * self.allreduce_time(devs, vol_tp / 2.0)
        } else {
            0.0
        };
        comp + tp_time
    }

    /// Generation replica: prefill + token-by-token decode, in decode
    /// batches sized by what fits in memory.
    fn build_generation_replica(
        &mut self,
        t_idx: usize,
        model: &crate::workflow::ModelSpec,
        plan: &TaskPlan,
        i: usize,
        after: &[OpId],
    ) -> OpId {
        let s = plan.strategy;
        let job = self.job;
        let local_batch = ((job.total_samples() as f64) * plan.dp_shares[i]).ceil() as usize;
        // Decode batch bounded by the most memory-constrained device.
        let task = crate::workflow::RlTask {
            id: RlTaskId::ActorGen,
            model: model.clone(),
        };
        let mut dbs = usize::MAX;
        for j in 0..s.pp {
            for &d in &plan.tp_group(i, j) {
                let cap = self.topo.devices[d].spec().mem_bytes;
                dbs = dbs.min(decode_batch_size(
                    &task,
                    job,
                    plan.layer_split[j],
                    s.tp,
                    local_batch,
                    cap,
                ));
            }
        }
        let dbs = dbs.max(1).min(local_batch.max(1));
        let n_batches = local_batch.div_ceil(dbs).max(1);

        let mut batch_ends = Vec::new();
        let mut prev_batch: Option<OpId> = None;
        for _b in 0..n_batches {
            // Response length for this batch: max of dbs samples (the
            // batch runs until its longest sequence finishes).
            let resp = (0..dbs.min(64))
                .map(|_| self.noise.response_len(&mut self.rng, job.seq_out))
                .max()
                .unwrap_or(job.seq_out);
            let mut carry: Option<OpId> = prev_batch;
            for j in 0..s.pp {
                let devs = plan.tp_group(i, j);
                let nl_j = plan.layer_split[j];
                // prefill: forward over seq_in for dbs sequences
                let prefill_flops = dbs as f64
                    * nl_j as f64
                    * crate::costmodel::compute::layer_flops(job.seq_in, model.h1, model.h2);
                let mut prefill: f64 = 0.0;
                for &d in &devs {
                    prefill = prefill
                        .max(prefill_flops / (effective_flops(self.topo, d) * s.tp as f64));
                }
                // decode: every token re-reads stage weights; batch of
                // dbs amortizes one read.
                let weight_bytes = B_BF16 * nl_j as f64 * layer_params(model.h1, model.h2);
                let mut per_token: f64 = 0.0;
                for &d in &devs {
                    let hbm = self.topo.devices[d].spec().hbm_bps;
                    per_token = per_token.max(weight_bytes / (hbm * s.tp as f64));
                }
                // TP all-reduce per layer per token (latency-bound).
                let tp_tok = if s.tp > 1 {
                    let order = self.topo.locality_order(&devs);
                    let mut alpha_max: f64 = 0.0;
                    for x in 0..order.len() {
                        let (a, b) = (order[x], order[(x + 1) % order.len()]);
                        alpha_max = alpha_max.max(self.topo.lat(a, b));
                    }
                    2.0 * (s.tp as f64 - 1.0) * alpha_max * nl_j as f64
                } else {
                    0.0
                };
                let decode = resp as f64 * (per_token + tp_tok);
                let dur = (prefill + decode)
                    * self.noise.comp_jitter(&mut self.rng);
                let mut deps: Vec<OpId> = after.to_vec();
                if let Some(c) = carry {
                    deps.push(c);
                }
                let op = self.g.add(devs, dur, deps, t_idx);
                carry = Some(op);
            }
            prev_batch = carry;
            batch_ends.push(carry.unwrap());
        }
        self.g.barrier(batch_ends)
    }
}

/// Simulate an execution plan; averages `cfg.iters` sampled iterations.
pub fn simulate_plan(
    topo: &DeviceTopology,
    wf: &RlWorkflow,
    job: &JobConfig,
    plan: &ExecutionPlan,
    cfg: &SimConfig,
) -> SimResult {
    let mut iter_times = Vec::with_capacity(cfg.iters);
    let mut per_task_acc = vec![0.0f64; wf.n_tasks()];
    let mut util_acc = 0.0;
    for it in 0..cfg.iters {
        let seed = cfg.seed ^ (it as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut b = Builder::new(topo, job, seed, cfg.noise);

        // Build per-task subgraphs with dependency barriers. In async
        // mode, edges out of actor-gen are dropped (the trainer consumes
        // the previous iteration's rollouts) and the weight-sync cost is
        // appended.
        let gen_idx = wf.task_index(RlTaskId::ActorGen);
        let mut barriers: Vec<Option<OpId>> = vec![None; wf.n_tasks()];
        let order = wf.waves().concat();
        for &t in &order {
            let mut after: Vec<OpId> = Vec::new();
            for &(from, to) in &wf.deps {
                if to == t {
                    let dropped = wf.mode == Mode::Async && Some(from) == gen_idx;
                    if !dropped {
                        if let Some(bar) = barriers[from] {
                            after.push(bar);
                        }
                    }
                }
            }
            let task = &wf.tasks[t];
            let bar = b.build_task(t, task.kind(), &task.model, &plan.task_plans[t], &after);
            barriers[t] = Some(bar);
        }

        // Weight propagation: reshard (sync) or train→gen sync (async),
        // simulated as all-gather + p2p + broadcast ops.
        if let (Some(tt), Some(tg)) = (wf.task_index(RlTaskId::ActorTrain), gen_idx) {
            let pt = &plan.task_plans[tt];
            let pg = &plan.task_plans[tg];
            let m = &wf.tasks[tt].model;
            let deps: Vec<OpId> = barriers.iter().flatten().cloned().collect();
            let ag_vol = cv_all_gather(m.nl, m.h1, m.h2, pt.strategy.pp * pt.strategy.tp);
            let devs0 = pt.replica_devices(0);
            let dur_ag = b.allreduce_time(&devs0, ag_vol) / 2.0; // all-gather ≈ half an all-reduce
            let ag = b.g.add(devs0, dur_ag, deps, usize::MAX - 1);
            if wf.mode == Mode::Async || pt.devices() != pg.devices() {
                let p2p_vol = cv_p2p(m.nl, m.h1, m.h2);
                let x = b.transfer_op(&pt.devices(), &pg.devices(), p2p_vol, vec![ag], usize::MAX - 1);
                let bc_vol = cv_all_gather(m.nl, m.h1, m.h2, pg.strategy.pp * pg.strategy.tp);
                let gdevs = pg.replica_devices(0);
                let dur_bc = b.allreduce_time(&gdevs, bc_vol) / 2.0;
                b.g.add(gdevs, dur_bc, vec![x], usize::MAX - 1);
            }
        }

        let outcome = b.g.simulate_with(cfg.shuffle);
        iter_times.push(outcome.makespan);
        for t in 0..wf.n_tasks() {
            let f = b.g.tag_finish(&outcome, t);
            if f.is_finite() {
                per_task_acc[t] += f;
            }
        }
        let busy: f64 = outcome.busy[..topo.n()].iter().sum();
        util_acc += busy / (outcome.makespan * topo.n() as f64);
    }
    let s = crate::util::stats::summarize(&iter_times);
    SimResult {
        iter_time: s.mean,
        iter_std: s.std,
        per_task: per_task_acc.iter().map(|x| x / cfg.iters as f64).collect(),
        utilization: util_acc / cfg.iters as f64,
        throughput: job.total_samples() as f64 / s.mean,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::{ParallelStrategy, TaskPlan};
    use crate::topology::{build_testbed, Scenario, TestbedSpec};
    use crate::workflow::{Algo, ModelSpec};

    fn make_plan(wf: &RlWorkflow, n: usize, per_task: usize) -> ExecutionPlan {
        let mut task_plans = Vec::new();
        for (t, task) in wf.tasks.iter().enumerate() {
            let s = ParallelStrategy::new((per_task / 8).max(1), 2, 4);
            let start = (t * per_task) % n;
            let devs: Vec<usize> = (start..start + per_task).collect();
            task_plans.push(TaskPlan::uniform(s, task.model.nl, devs));
        }
        ExecutionPlan {
            task_groups: vec![(0..wf.n_tasks()).collect()],
            gpu_groups: vec![(0..n).collect()],
            task_plans,
        }
    }

    fn fast_cfg() -> SimConfig {
        SimConfig { iters: 2, seed: 7, noise: NoiseModel::default(), shuffle: None }
    }

    #[test]
    fn simulates_grpo_plan() {
        let topo = build_testbed(Scenario::SingleRegion, &TestbedSpec::default());
        let job = JobConfig::tiny();
        let wf = RlWorkflow::new(Algo::Grpo, Mode::Sync, ModelSpec::qwen_4b());
        let plan = make_plan(&wf, 64, 16);
        let r = simulate_plan(&topo, &wf, &job, &plan, &fast_cfg());
        assert!(r.iter_time > 0.0 && r.iter_time.is_finite());
        assert!(r.utilization > 0.0 && r.utilization <= 1.0);
        assert_eq!(r.per_task.len(), 4);
    }

    #[test]
    fn wan_slower_than_local() {
        let job = JobConfig::tiny();
        let wf = RlWorkflow::new(Algo::Grpo, Mode::Sync, ModelSpec::qwen_4b());
        let plan = make_plan(&wf, 64, 16);
        let local = build_testbed(Scenario::SingleRegion, &TestbedSpec::default());
        let wan = build_testbed(Scenario::MultiContinent, &TestbedSpec::default());
        let r_local = simulate_plan(&local, &wf, &job, &plan, &fast_cfg());
        let r_wan = simulate_plan(&wan, &wf, &job, &plan, &fast_cfg());
        assert!(
            r_wan.iter_time > 1.5 * r_local.iter_time,
            "wan {} local {}",
            r_wan.iter_time,
            r_local.iter_time
        );
    }

    #[test]
    fn async_not_slower_than_sync_same_plan() {
        let topo = build_testbed(Scenario::SingleRegion, &TestbedSpec::default());
        let job = JobConfig::tiny();
        let model = ModelSpec::qwen_4b();
        let sync = RlWorkflow::new(Algo::Grpo, Mode::Sync, model.clone());
        let asyn = RlWorkflow::new(Algo::Grpo, Mode::Async, model);
        // Disaggregated plan: generation on its own devices.
        let plan = make_plan(&sync, 64, 16);
        let cfg = SimConfig { iters: 2, seed: 3, noise: NoiseModel::off(), shuffle: None };
        let r_sync = simulate_plan(&topo, &sync, &job, &plan, &cfg);
        let r_async = simulate_plan(&topo, &asyn, &job, &plan, &cfg);
        assert!(r_async.iter_time <= r_sync.iter_time * 1.05);
    }

    #[test]
    fn deterministic_given_seed() {
        let topo = build_testbed(Scenario::MultiCountry, &TestbedSpec::default());
        let job = JobConfig::tiny();
        let wf = RlWorkflow::new(Algo::Grpo, Mode::Sync, ModelSpec::qwen_4b());
        let plan = make_plan(&wf, 64, 16);
        let a = simulate_plan(&topo, &wf, &job, &plan, &fast_cfg());
        let b = simulate_plan(&topo, &wf, &job, &plan, &fast_cfg());
        assert_eq!(a.iter_time, b.iter_time);
    }

    #[test]
    fn bigger_model_slower() {
        let topo = build_testbed(Scenario::SingleRegion, &TestbedSpec::default());
        let job = JobConfig::tiny();
        let wf4 = RlWorkflow::new(Algo::Grpo, Mode::Sync, ModelSpec::qwen_4b());
        let wf14 = RlWorkflow::new(Algo::Grpo, Mode::Sync, ModelSpec::qwen_14b());
        let p4 = make_plan(&wf4, 64, 16);
        let p14 = make_plan(&wf14, 64, 16);
        let r4 = simulate_plan(&topo, &wf4, &job, &p4, &fast_cfg());
        let r14 = simulate_plan(&topo, &wf14, &job, &p14, &fast_cfg());
        assert!(r14.iter_time > r4.iter_time);
    }

    #[test]
    fn sim_in_same_ballpark_as_cost_model() {
        // The two paths are different but should land within ~2.5× of
        // each other for a sane local plan (Figure 7's premise).
        let topo = build_testbed(Scenario::SingleRegion, &TestbedSpec::default());
        let job = JobConfig::default();
        let wf = RlWorkflow::new(Algo::Grpo, Mode::Sync, ModelSpec::qwen_4b());
        let plan = make_plan(&wf, 64, 16);
        let cm = crate::costmodel::CostModel::new(&topo, &wf, &job);
        let pred = cm.plan_cost(&plan).iter_time;
        let cfg = SimConfig { iters: 2, seed: 11, noise: NoiseModel::default(), shuffle: None };
        let meas = simulate_plan(&topo, &wf, &job, &plan, &cfg).iter_time;
        let ratio = pred / meas;
        assert!(
            (0.4..2.5).contains(&ratio),
            "pred {pred:.1}s vs meas {meas:.1}s (ratio {ratio:.2})"
        );
    }
}
