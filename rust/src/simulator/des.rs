//! Generic discrete-event scheduling core: a DAG of operations, each
//! occupying a set of exclusive resources for a duration. Simulation
//! performs event-driven list scheduling: an op starts when all its
//! dependencies have finished and all its resources are free; ties are
//! broken FIFO by ready time, then by op id (deterministic), unless a
//! [`ShuffleConfig`] seed permutes same-timestamp ties (see
//! [`SimGraph::simulate_with`]).
//!
//! Since the component refactor the event loop itself lives in
//! [`super::component`]: the op-DAG executor, device banks, link-token
//! pools and checkpoint stores are [`super::component::Component`]s
//! driven off one `(next_tick, ComponentId)` queue. This module keeps
//! the graph representation, the public `simulate*` API, and the
//! pre-component executor as a pinned reference implementation
//! ([`SimGraph::simulate_reference`]) for the equivalence suites.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::component::{self, ShuffleConfig};

/// Index of an op in a [`SimGraph`].
pub type OpId = usize;

/// What a simulation resource models; each kind is owned by its own
/// [`super::component::ResourceOwner`] component in the engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ResourceKind {
    /// A physical device (GPU); `SimGraph::new(n)` creates `n` of these.
    Device,
    /// A synthetic NIC/link token (e.g. a WAN backbone channel);
    /// [`SimGraph::add_resource`] creates these.
    LinkToken,
    /// A checkpoint store endpoint (serialized snapshot writes).
    CkptStore,
}

impl ResourceKind {
    /// Every kind, in the fixed order owner components are
    /// instantiated (stable across runs — part of the determinism
    /// contract).
    pub const ALL: [ResourceKind; 3] =
        [ResourceKind::Device, ResourceKind::LinkToken, ResourceKind::CkptStore];
}

/// One operation: compute on a device group, or a transfer on a link.
#[derive(Debug, Clone)]
pub struct Op {
    /// Exclusive resources (e.g. device ids, or synthetic link ids).
    pub resources: Vec<usize>,
    pub duration: f64,
    pub deps: Vec<OpId>,
    /// Tag for reporting (task index, or usize::MAX for plumbing).
    pub tag: usize,
}

/// A DAG of [`Op`]s over a fixed resource universe.
#[derive(Debug, Default)]
pub struct SimGraph {
    pub ops: Vec<Op>,
    kinds: Vec<ResourceKind>,
}

/// Result of simulating a graph.
#[derive(Debug, Clone)]
pub struct SimOutcome {
    pub makespan: f64,
    pub finish: Vec<f64>,
    pub start: Vec<f64>,
    /// Busy time per resource (for utilization reporting).
    pub busy: Vec<f64>,
}

impl SimGraph {
    /// A graph over `n_resources` devices ([`ResourceKind::Device`]).
    pub fn new(n_resources: usize) -> Self {
        SimGraph { ops: Vec::new(), kinds: vec![ResourceKind::Device; n_resources] }
    }

    /// Allocate an extra synthetic link token
    /// ([`ResourceKind::LinkToken`], e.g. a WAN backbone channel).
    pub fn add_resource(&mut self) -> usize {
        self.add_resource_of(ResourceKind::LinkToken)
    }

    /// Allocate an extra resource of an explicit kind.
    pub fn add_resource_of(&mut self, kind: ResourceKind) -> usize {
        self.kinds.push(kind);
        self.kinds.len() - 1
    }

    pub fn n_resources(&self) -> usize {
        self.kinds.len()
    }

    /// The kind of resource `r`. Panics if out of range.
    pub fn resource_kind(&self, r: usize) -> ResourceKind {
        self.kinds[r]
    }

    /// Add an op; panics on out-of-range resources or forward deps.
    pub fn add(&mut self, resources: Vec<usize>, duration: f64, deps: Vec<OpId>, tag: usize) -> OpId {
        let id = self.ops.len();
        for &r in &resources {
            assert!(r < self.kinds.len(), "resource {r} out of range");
        }
        for &d in &deps {
            assert!(d < id, "dependency {d} must precede op {id}");
        }
        assert!(duration >= 0.0 && duration.is_finite(), "bad duration {duration}");
        self.ops.push(Op { resources, duration, deps, tag });
        id
    }

    /// A zero-duration barrier op over no resources.
    pub fn barrier(&mut self, deps: Vec<OpId>) -> OpId {
        self.add(Vec::new(), 0.0, deps, usize::MAX)
    }

    /// Ready time of `op` given per-op finish times: the latest
    /// dependency finish (0 for sources). The single source of truth
    /// for ready-time computation, shared by the component executor
    /// and the pinned reference executor.
    pub(crate) fn ready_of(&self, op: OpId, finish: &[f64]) -> f64 {
        self.ops[op].deps.iter().map(|&d| finish[d]).fold(0.0f64, f64::max)
    }

    /// Event-driven simulation on the component engine, FIFO tie-break.
    /// `O((V+E) log V + V·R)` with small R. Bit-identical to
    /// [`SimGraph::simulate_reference`].
    pub fn simulate(&self) -> SimOutcome {
        self.simulate_with(None)
    }

    /// Simulation with an optional seeded tie-break shuffle for
    /// same-timestamp ready events. `None` is byte-identical to
    /// [`SimGraph::simulate`]; any seed still yields a fully
    /// deterministic event order (see
    /// [`super::component::ShuffleConfig`]).
    pub fn simulate_with(&self, shuffle: Option<ShuffleConfig>) -> SimOutcome {
        component::run_sim(self, shuffle)
    }

    /// The pre-component executor, kept verbatim (modulo the dead
    /// `ready_time` buffer it used to carry) as the pinned oracle for
    /// the component-engine equivalence suites
    /// (`tests/integration_simulator.rs`).
    pub fn simulate_reference(&self) -> SimOutcome {
        let n = self.ops.len();
        let mut indeg: Vec<usize> = vec![0; n];
        let mut rdeps: Vec<Vec<OpId>> = vec![Vec::new(); n];
        for (id, op) in self.ops.iter().enumerate() {
            indeg[id] = op.deps.len();
            for &d in &op.deps {
                rdeps[d].push(id);
            }
        }
        // resource_free[r] = time the resource becomes available
        let mut resource_free = vec![0.0f64; self.kinds.len()];
        let mut busy = vec![0.0f64; self.kinds.len()];
        let mut start = vec![f64::NAN; n];
        let mut finish = vec![f64::NAN; n];

        // Ready queue ordered by (ready_time, id). We pop the earliest
        // ready op and start it at max(ready_time, resources free).
        // NOTE: this is FIFO list scheduling (non-preemptive, no
        // backfilling) — matching how NCCL streams and engine queues
        // serialize work in practice.
        #[derive(PartialEq)]
        struct QEntry(f64, OpId);
        impl Eq for QEntry {}
        impl PartialOrd for QEntry {
            fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
                Some(self.cmp(other))
            }
        }
        impl Ord for QEntry {
            fn cmp(&self, other: &Self) -> std::cmp::Ordering {
                crate::util::ford::cmp_f64(self.0, other.0).then(self.1.cmp(&other.1))
            }
        }

        let mut queue: BinaryHeap<Reverse<QEntry>> = BinaryHeap::new();
        for id in 0..n {
            if indeg[id] == 0 {
                queue.push(Reverse(QEntry(0.0, id)));
            }
        }
        let mut makespan = 0.0f64;
        let mut done = 0usize;
        while let Some(Reverse(QEntry(rt, id))) = queue.pop() {
            let op = &self.ops[id];
            let mut t0 = rt;
            for &r in &op.resources {
                t0 = t0.max(resource_free[r]);
            }
            let t1 = t0 + op.duration;
            for &r in &op.resources {
                resource_free[r] = t1;
                busy[r] += op.duration;
            }
            start[id] = t0;
            finish[id] = t1;
            makespan = makespan.max(t1);
            done += 1;
            for &succ in &rdeps[id] {
                indeg[succ] -= 1;
                if indeg[succ] == 0 {
                    // Ready when the latest dependency finishes.
                    queue.push(Reverse(QEntry(self.ready_of(succ, &finish), succ)));
                }
            }
        }
        assert_eq!(done, n, "cycle in sim graph");
        SimOutcome { makespan, finish, start, busy }
    }

    /// Finish time of the last op with the given tag (NaN if none).
    pub fn tag_finish(&self, outcome: &SimOutcome, tag: usize) -> f64 {
        let mut t = f64::NAN;
        for (id, op) in self.ops.iter().enumerate() {
            if op.tag == tag {
                t = if t.is_nan() { outcome.finish[id] } else { t.max(outcome.finish[id]) };
            }
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_chain() {
        let mut g = SimGraph::new(1);
        let a = g.add(vec![0], 1.0, vec![], 0);
        let b = g.add(vec![0], 2.0, vec![a], 0);
        let c = g.add(vec![0], 3.0, vec![b], 0);
        let o = g.simulate();
        assert_eq!(o.makespan, 6.0);
        assert_eq!(o.finish[c], 6.0);
        assert_eq!(o.busy[0], 6.0);
    }

    #[test]
    fn parallel_on_disjoint_resources() {
        let mut g = SimGraph::new(2);
        g.add(vec![0], 5.0, vec![], 0);
        g.add(vec![1], 3.0, vec![], 1);
        let o = g.simulate();
        assert_eq!(o.makespan, 5.0);
    }

    #[test]
    fn contention_serializes() {
        let mut g = SimGraph::new(1);
        g.add(vec![0], 5.0, vec![], 0);
        g.add(vec![0], 3.0, vec![], 1);
        let o = g.simulate();
        assert_eq!(o.makespan, 8.0);
    }

    #[test]
    fn multi_resource_op_waits_for_all() {
        let mut g = SimGraph::new(2);
        g.add(vec![0], 4.0, vec![], 0); // busy res0 until 4
        g.add(vec![1], 1.0, vec![], 0); // busy res1 until 1
        let both = g.add(vec![0, 1], 1.0, vec![], 1);
        let o = g.simulate();
        assert_eq!(o.start[both], 4.0);
        assert_eq!(o.makespan, 5.0);
    }

    #[test]
    fn dependencies_respected_across_resources() {
        let mut g = SimGraph::new(2);
        let a = g.add(vec![0], 2.0, vec![], 0);
        let b = g.add(vec![1], 1.0, vec![a], 0);
        let o = g.simulate();
        assert_eq!(o.start[b], 2.0);
        assert_eq!(o.makespan, 3.0);
    }

    #[test]
    fn pipeline_bubble_emerges() {
        // 2-stage pipeline, 3 microbatches, unit stage time and zero
        // transfer: makespan = stages + microbatches - 1 = 4.
        let mut g = SimGraph::new(2);
        let mut prev_stage: Vec<Option<OpId>> = vec![None, None];
        for _m in 0..3 {
            let f0 = g.add(vec![0], 1.0, prev_stage[0].into_iter().collect(), 0);
            let f1 = g.add(vec![1], 1.0, vec![f0], 0);
            prev_stage = vec![Some(f0), Some(f1)];
        }
        let o = g.simulate();
        assert_eq!(o.makespan, 4.0);
    }

    #[test]
    fn barrier_and_tags() {
        let mut g = SimGraph::new(1);
        let a = g.add(vec![0], 1.5, vec![], 7);
        let _bar = g.barrier(vec![a]);
        let o = g.simulate();
        assert_eq!(g.tag_finish(&o, 7), 1.5);
        assert!(g.tag_finish(&o, 9).is_nan());
    }

    #[test]
    fn deterministic() {
        let build = || {
            let mut g = SimGraph::new(4);
            let mut last = Vec::new();
            for i in 0..50 {
                let deps = if i % 7 == 0 { last.clone() } else { Vec::new() };
                let id = g.add(vec![i % 4], (i % 5) as f64 * 0.3 + 0.1, deps, 0);
                if i % 3 == 0 {
                    last = vec![id];
                }
            }
            g.simulate().makespan
        };
        assert_eq!(build(), build());
    }

    #[test]
    fn ready_time_is_max_dep_finish() {
        // Pin for the ready-time unification (the old executor kept a
        // `ready_time` buffer that was written but never read after
        // push; `ready_of` is now the single source): a diamond's join
        // becomes ready exactly when its *later* dependency finishes,
        // on both executors.
        let mut g = SimGraph::new(2);
        let a = g.add(vec![0], 1.0, vec![], 0);
        let b = g.add(vec![0], 2.0, vec![a], 0); // finishes at 3
        let c = g.add(vec![1], 1.0, vec![a], 0); // finishes at 2
        let d = g.add(vec![1], 1.0, vec![b, c], 0);
        let o = g.simulate();
        let r = g.simulate_reference();
        assert_eq!(g.ready_of(d, &o.finish), 3.0);
        assert_eq!(o.start[d], 3.0);
        assert_eq!(o.finish[d], 4.0);
        assert_eq!(o.start, r.start);
        assert_eq!(o.finish, r.finish);
        assert_eq!(o.busy, r.busy);
        assert_eq!(o.makespan, r.makespan);
    }
}
