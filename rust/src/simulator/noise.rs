//! Stochastic components of the simulator: multiplicative lognormal
//! jitter on compute/communication and the response-length distribution
//! for generation (real RL rollouts rarely use the full budget; the
//! paper's GSM8K workload produces a long-tailed length mix).

use crate::util::rng::Rng;

/// Noise configuration.
#[derive(Debug, Clone, Copy)]
pub struct NoiseModel {
    /// Sigma of lognormal jitter on compute durations.
    pub comp_sigma: f64,
    /// Sigma of lognormal jitter on communication durations.
    pub comm_sigma: f64,
    /// Mean response length as a fraction of `seq_out`.
    pub mean_resp_frac: f64,
    /// Coefficient of variation of response lengths.
    pub resp_cv: f64,
}

impl Default for NoiseModel {
    fn default() -> Self {
        NoiseModel {
            comp_sigma: 0.03,
            comm_sigma: 0.08,
            mean_resp_frac: 0.70,
            resp_cv: 0.35,
        }
    }
}

impl NoiseModel {
    /// Deterministic model (used by tests and the ILP-vs-sim checks).
    pub fn off() -> Self {
        NoiseModel { comp_sigma: 0.0, comm_sigma: 0.0, mean_resp_frac: 0.70, resp_cv: 0.0 }
    }

    /// Jitter factor with E[x] = 1 for compute.
    pub fn comp_jitter(&self, rng: &mut Rng) -> f64 {
        jitter(rng, self.comp_sigma)
    }

    /// Jitter factor with E[x] = 1 for communication.
    pub fn comm_jitter(&self, rng: &mut Rng) -> f64 {
        jitter(rng, self.comm_sigma)
    }

    /// Sample a response length in `[1, seq_out]`.
    pub fn response_len(&self, rng: &mut Rng, seq_out: usize) -> usize {
        let mean = self.mean_resp_frac * seq_out as f64;
        if self.resp_cv == 0.0 {
            return (mean.round() as usize).clamp(1, seq_out);
        }
        // Lognormal with the requested mean and CV.
        let sigma2 = (1.0 + self.resp_cv * self.resp_cv).ln();
        let mu = mean.ln() - sigma2 / 2.0;
        let x = rng.lognormal(mu, sigma2.sqrt());
        (x.round() as usize).clamp(1, seq_out)
    }

    /// Expected response length (what an oracle cost model would use).
    pub fn expected_response_len(&self, seq_out: usize) -> f64 {
        self.mean_resp_frac * seq_out as f64
    }
}

fn jitter(rng: &mut Rng, sigma: f64) -> f64 {
    if sigma == 0.0 {
        return 1.0;
    }
    // lognormal(μ=-σ²/2, σ) has mean exactly 1.
    rng.lognormal(-sigma * sigma / 2.0, sigma)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jitter_mean_one() {
        let nm = NoiseModel::default();
        let mut rng = Rng::new(1);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| nm.comm_jitter(&mut rng)).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn off_is_deterministic() {
        let nm = NoiseModel::off();
        let mut rng = Rng::new(2);
        assert_eq!(nm.comp_jitter(&mut rng), 1.0);
        assert_eq!(nm.response_len(&mut rng, 1000), 700);
    }

    #[test]
    fn response_len_statistics() {
        let nm = NoiseModel::default();
        let mut rng = Rng::new(3);
        let n = 50_000;
        let lens: Vec<f64> = (0..n).map(|_| nm.response_len(&mut rng, 1024) as f64).collect();
        let mean = lens.iter().sum::<f64>() / n as f64;
        // Mean close to 0.7*1024 (clamping pulls it down slightly).
        assert!((mean - 716.8).abs() < 40.0, "mean {mean}");
        assert!(lens.iter().all(|&l| (1.0..=1024.0).contains(&l)));
        // Actually long-tailed: p95 well above mean.
        let mut sorted = lens.clone();
        crate::util::ford::sort_f64(&mut sorted);
        let p95 = crate::util::stats::percentile_sorted(&sorted, 95.0);
        assert!(p95 > mean * 1.3);
    }
}
