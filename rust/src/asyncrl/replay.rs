//! Dynamic-trace replay for **asynchronous** workflows: the
//! [`crate::elastic::replay`] loop with the pool-split search
//! ([`super::search::plan_async`]) for cold episodes and the
//! bounded-staleness pipeline DES ([`super::pipeline::simulate_async`])
//! as the measurement, so the generation and training pools degrade —
//! and are re-planned — independently as the fleet churns.
//!
//! All five [`Policy`] variants run unchanged: events fire against the
//! same [`FleetState`], warm replans and the anytime/preempt background
//! machinery evolve the incumbent through the same [`Replanner`], and
//! only cold searches (initial plan, repair fallback, oracle) go
//! through the pool-split sweep. Event labels are annotated with the
//! pool the event hits (`[pool:gen]` / `[pool:train]` / `[pool:both]`),
//! which is what makes "generation pool lost a machine" distinguishable
//! from "training pool lost a machine" in the replay table and
//! `fig_async` rows.
//!
//! `staleness_bound = 0` does not merely *approximate* the synchronous
//! path — it **delegates** to [`crate::elastic::replay`] with the
//! workflow forced to `Mode::Sync`, so a `k = 0` async replay is
//! bit-identical to a plain sync replay of the same inputs (pinned by
//! `tests/prop_async.rs`).
//!
//! The failure-and-recovery pricing of the sync replay
//! ([`ReplayConfig::recovery`]) applies unchanged here: checkpoint
//! writes at the configured cadence, rollback on unnoticed losses and
//! retry-exhausted task failures, bounded retry stalls for transient
//! faults, and graceful degradation (incumbent retained, iterations
//! stall) when the whole fleet vanishes. With
//! [`ReplayConfig::ckpt_search`] set, the async path picks the cadence
//! *analytically* for the cold pool-split plan
//! ([`crate::elastic::pick_interval_analytic`]) rather than re-running
//! the plan search per interval arm.

use super::pipeline::{simulate_async, AsyncPipelineConfig};
use super::search::{plan_async, AsyncSearchConfig};
use crate::balance::{self, BalanceConfig};
use crate::costmodel::{CostModel, RecoveryState};
use crate::elastic::replan::{plan_to_base, prev_placement, repair_plan, Replanner};
use crate::elastic::{
    generate_trace, pick_interval_analytic, unnoticed_loss_rate, AnytimeSearch, ClusterEvent,
    FleetState, IterRecord, Policy, ReplayConfig, ReplayResult, TraceEvent,
};
use crate::plan::ExecutionPlan;
use crate::scheduler::Budget;
use crate::topology::{build_testbed, DeviceTopology, Scenario, TestbedSpec};
use crate::workflow::{JobConfig, Mode, RlTaskId, RlWorkflow};

/// Configuration of an asynchronous replay.
#[derive(Debug, Clone)]
pub struct AsyncReplayConfig {
    /// The underlying replay knobs (iterations, trace, replan budgets,
    /// noise, balancing) — shared with the synchronous path.
    pub base: ReplayConfig,
    /// Hard off-policy staleness bound `k`. `0` delegates to the
    /// synchronous replay bit-identically.
    pub staleness_bound: usize,
    /// Rollout-queue capacity (clamped to ≥ 1).
    pub queue_capacity: usize,
    /// Pipeline steps simulated per measured iteration (the DES window).
    pub window: usize,
    /// Candidate generation-pool fractions for cold pool-split searches.
    pub gen_fracs: Vec<f64>,
}

impl Default for AsyncReplayConfig {
    fn default() -> Self {
        AsyncReplayConfig {
            base: ReplayConfig::default(),
            staleness_bound: 2,
            queue_capacity: 2,
            window: 8,
            gen_fracs: AsyncSearchConfig::default().gen_fracs,
        }
    }
}

/// Per-iteration pipeline telemetry of an async replay.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AsyncIterStats {
    /// Time-weighted mean rollout-queue depth during the iteration.
    pub queue_depth_mean: f64,
    /// Max simultaneous queue depth during the iteration.
    pub queue_depth_max: usize,
    /// Producer (generation) stall per training step, seconds.
    pub producer_stall_secs: f64,
    /// Largest observed off-policy staleness in the iteration's window.
    pub max_staleness: usize,
}

impl AsyncIterStats {
    /// All-zero stats (stalled iterations, and every `k = 0` row).
    pub fn zero() -> AsyncIterStats {
        AsyncIterStats {
            queue_depth_mean: 0.0,
            queue_depth_max: 0,
            producer_stall_secs: 0.0,
            max_staleness: 0,
        }
    }
}

/// Outcome of one async replay: the ordinary [`ReplayResult`] plus the
/// queue/staleness telemetry the async pipeline adds.
#[derive(Debug, Clone, PartialEq)]
pub struct AsyncReplayResult {
    /// The policy/iteration telemetry shared with sync replays. For
    /// `staleness_bound ≥ 1`, `iter_secs` is the pipeline *period*
    /// (seconds per training step), directly comparable to the sync
    /// iteration time.
    pub base: ReplayResult,
    /// The staleness bound the replay ran under.
    pub staleness_bound: usize,
    /// The rollout-queue capacity the replay ran under.
    pub queue_capacity: usize,
    /// Per-iteration pipeline stats, aligned with `base.records`.
    pub queue: Vec<AsyncIterStats>,
    /// Largest observed staleness across the whole replay. Hard
    /// invariant: `≤ staleness_bound`.
    pub max_staleness: usize,
}

impl AsyncReplayResult {
    /// `"sync"` for `k = 0` (the delegated path), `"async"` otherwise —
    /// the replay table's `workflow` column.
    pub fn workflow_name(&self) -> &'static str {
        if self.staleness_bound == 0 { "sync" } else { "async" }
    }

    /// Mean of the per-iteration mean queue depths (0 when empty).
    pub fn mean_queue_depth(&self) -> f64 {
        if self.queue.is_empty() {
            0.0
        } else {
            self.queue.iter().map(|q| q.queue_depth_mean).sum::<f64>() / self.queue.len() as f64
        }
    }

    /// Largest queue depth seen in any iteration.
    pub fn max_queue_depth(&self) -> usize {
        self.queue.iter().map(|q| q.queue_depth_max).max().unwrap_or(0)
    }

    /// Total producer stall over the replay (per-step stall × window
    /// steps per iteration, summed).
    pub fn producer_stall_secs(&self) -> f64 {
        self.queue.iter().map(|q| q.producer_stall_secs).sum::<f64>()
    }
}

/// Base device ids a cluster event touches (`None` for link events,
/// which hit the WAN between the pools rather than either pool).
fn affected_base_devices(event: &ClusterEvent, base: &DeviceTopology) -> Option<Vec<usize>> {
    match event {
        ClusterEvent::MachinePreempt { machine }
        | ClusterEvent::MachineLeave { machine }
        | ClusterEvent::MachineJoin { machine } => Some(
            base.devices
                .iter()
                .filter(|d| d.machine == *machine)
                .map(|d| d.id)
                .collect(),
        ),
        ClusterEvent::StragglerOnset { device, .. } | ClusterEvent::StragglerClear { device } => {
            Some(vec![*device])
        }
        ClusterEvent::NicDegrade { machine, .. } | ClusterEvent::NicRestore { machine } => Some(
            base.devices
                .iter()
                .filter(|d| d.machine == *machine)
                .map(|d| d.id)
                .collect(),
        ),
        ClusterEvent::TaskFailure { device, .. } => Some(vec![*device]),
        // WAN shifts and checkpoint-store outages sit between/off the
        // pools — both pools feel them.
        ClusterEvent::LinkDegrade { .. }
        | ClusterEvent::LinkRestore { .. }
        | ClusterEvent::CkptOutage { .. }
        | ClusterEvent::CkptRestore => None,
    }
}

/// Classify which pool of the incumbent an event hits, as a label
/// suffix. `gen`/`train` are the incumbent's device sets in base ids.
fn pool_suffix(
    event: &ClusterEvent,
    base: &DeviceTopology,
    gen: &[usize],
    train: &[usize],
) -> &'static str {
    let Some(devs) = affected_base_devices(event, base) else {
        // WAN events sit between the pools.
        return " [pool:both]";
    };
    let hits_gen = devs.iter().any(|d| gen.contains(d));
    let hits_train = devs.iter().any(|d| train.contains(d));
    match (hits_gen, hits_train) {
        (true, true) => " [pool:both]",
        (true, false) => " [pool:gen]",
        (false, true) => " [pool:train]",
        (false, false) => " [pool:none]",
    }
}

/// The incumbent's (generation, training) device sets in base ids.
fn pool_devices(wf: &RlWorkflow, incumbent_base: Option<&ExecutionPlan>) -> (Vec<usize>, Vec<usize>) {
    let (Some(inc), Some(gen_t)) = (incumbent_base, wf.task_index(RlTaskId::ActorGen)) else {
        return (Vec::new(), Vec::new());
    };
    let gen = inc.task_plans[gen_t].devices();
    let mut train: Vec<usize> = inc
        .task_plans
        .iter()
        .enumerate()
        .filter(|&(t, _)| t != gen_t)
        .flat_map(|(_, tp)| tp.devices())
        .collect();
    train.sort_unstable();
    train.dedup();
    (gen, train)
}

/// Replay a dynamic trace under one policy with the asynchronous
/// workflow model. A pure function of its arguments (same contract as
/// [`crate::elastic::replay`]); `cfg.staleness_bound = 0` delegates to
/// the synchronous replay bit-identically.
pub fn replay_async(
    scenario: Scenario,
    spec: &TestbedSpec,
    wf: &RlWorkflow,
    job: &JobConfig,
    policy: Policy,
    cfg: &AsyncReplayConfig,
    seed: u64,
) -> AsyncReplayResult {
    let base_topo = build_testbed(scenario, spec);
    let trace = generate_trace(&base_topo, &cfg.base.trace, seed);
    replay_async_with_trace(base_topo, trace, wf, job, policy, cfg, seed)
}

/// [`replay_async`] with an injected base topology and event trace —
/// the async counterpart of [`crate::elastic::replay_with_trace`], for
/// adversarial traces the seeded generator would rarely draw (e.g.
/// every machine lost at once). `cfg.base.trace` is ignored.
pub fn replay_async_with_trace(
    base_topo: DeviceTopology,
    trace: Vec<TraceEvent>,
    wf: &RlWorkflow,
    job: &JobConfig,
    policy: Policy,
    cfg: &AsyncReplayConfig,
    seed: u64,
) -> AsyncReplayResult {
    if cfg.staleness_bound == 0 {
        // k = 0 IS the synchronous iteration; run the actual sync path
        // (job untouched — the staleness fields are inert under
        // Mode::Sync) so the equivalence is structural, not numeric.
        let base = crate::elastic::replay_with_trace(
            base_topo,
            trace,
            &wf.with_mode(Mode::Sync),
            job,
            policy,
            &cfg.base,
            seed,
        );
        let queue = vec![AsyncIterStats::zero(); base.records.len()];
        return AsyncReplayResult {
            base,
            staleness_bound: 0,
            queue_capacity: cfg.queue_capacity,
            queue,
            max_staleness: 0,
        };
    }

    let awf = wf.with_mode(Mode::Async);
    let wf = &awf;
    let mut job_async = job.clone();
    job_async.staleness_bound = cfg.staleness_bound;
    job_async.rollout_queue_cap = cfg.queue_capacity.max(1);
    let job = &job_async;

    // Cold episodes run the pool-split sweep under the cold budget; the
    // episode counter keeps oracle re-searches independently seeded the
    // same way the replanner's episodes are.
    let search_cfg = AsyncSearchConfig {
        budget: Budget::evals(cfg.base.replan.cold_budget),
        gen_fracs: cfg.gen_fracs.clone(),
        threads: cfg.base.replan.threads,
        ea: cfg.base.replan.ea.clone(),
        ..AsyncSearchConfig::default()
    };
    let mut cold_episodes: u64 = 0;
    let mut cold = |topo: &DeviceTopology| {
        let ep_seed = seed
            .wrapping_mul(6364136223846793005)
            .wrapping_add(cold_episodes.wrapping_mul(1442695040888963407));
        cold_episodes += 1;
        plan_async(topo, wf, job, &search_cfg, ep_seed)
    };

    let mut fleet = FleetState::new(base_topo);
    let mut replanner = Replanner::new(seed, cfg.base.replan.clone());
    // Recovery pricing: local copy so the analytically picked
    // checkpoint interval can replace the configured cadence.
    let mut recovery = cfg.base.recovery;
    let mut recov_state = RecoveryState::default();
    let mut anytime = if policy.runs_background() {
        Some(AnytimeSearch::new(seed ^ 0xA11C_E5EA, cfg.base.replan.clone()))
    } else {
        None
    };
    let mut hypo: Option<(DeviceTopology, Vec<usize>, usize)> = None;

    let (mut topo, mut map) = fleet.snapshot();
    let first = cold(&topo);
    let mut plan: Option<ExecutionPlan> = first.outcome.plan.map(|p| {
        if cfg.base.balance {
            balance::apply(&p, wf, &topo, BalanceConfig::default())
        } else {
            p
        }
    });
    let mut incumbent_base = plan.as_ref().map(|p| plan_to_base(p, &map));
    reseed_anytime(&mut anytime, &topo, wf, job, plan.as_ref());

    // Checkpoint interval as a plan dimension, async flavour: the pool
    // split is fixed by the cold sweep, so instead of re-searching the
    // plan per interval arm the cadence is picked analytically for the
    // chosen plan — same objective the sync search's arms minimize.
    if let (Some(cs), Some(p)) = (&cfg.base.ckpt_search, plan.as_ref()) {
        if recovery.enabled {
            let iter_time = CostModel::new(&topo, wf, job).plan_cost(p).iter_time;
            let write = recovery.ckpt_write_secs(&cfg.base.replan.migration, wf, job, p);
            let lambda = unnoticed_loss_rate(&trace, &recovery, cfg.base.iters);
            recovery.ckpt_interval_secs = pick_interval_analytic(
                iter_time,
                write,
                lambda,
                &cs.candidates,
                recovery.ckpt_interval_secs,
            );
        }
    }

    let mut records = Vec::with_capacity(cfg.base.iters);
    let mut stats = Vec::with_capacity(cfg.base.iters);
    let mut total_secs = 0.0;
    let mut replans = 0;
    let mut total_evals = first.outcome.evals;
    let mut total_anytime_evals = 0usize;
    let mut total_hypothesis_evals = 0usize;
    let mut cache_hits = first.outcome.cache_hits;
    let mut cache_misses = first.outcome.cache_misses;
    let mut max_staleness = 0usize;
    let mut cursor = 0usize;
    let mut total_stall = 0.0f64;
    let mut total_rework = 0.0f64;
    let mut total_ckpt = 0.0f64;
    let mut degraded_iters = 0usize;

    for iter in 0..cfg.base.iters {
        // Classify fired events against the *pre-event* incumbent: the
        // interesting question is which pool the fleet change hit.
        let (gen_pool, train_pool) = pool_devices(wf, incumbent_base.as_ref());
        let fired_from = cursor;
        let mut labels = Vec::new();
        while cursor < trace.len() && trace[cursor].at_iter <= iter {
            let suffix = pool_suffix(&trace[cursor].event, fleet.base(), &gen_pool, &train_pool);
            fleet.apply(&trace[cursor].event);
            labels.push(format!("{}{}", trace[cursor].label(), suffix));
            cursor += 1;
        }
        // Recovery pricing for the fired events — same rules as the
        // sync replay: bounded retry stalls for transient faults,
        // rollback to the last checkpoint on unnoticed machine losses
        // and retry-exhausted task failures.
        let mut retry_stall_secs = 0.0f64;
        let mut rework_secs = 0.0f64;
        if recovery.enabled {
            for ev in &trace[fired_from..cursor] {
                if let Some(attempts) = ev.event.attempts() {
                    let (stall, recovered) = recovery.retry_stall(attempts);
                    retry_stall_secs += stall;
                    if !recovered && matches!(ev.event, ClusterEvent::TaskFailure { .. }) {
                        rework_secs += recov_state.rollback();
                    }
                }
                if ev.is_machine_loss() && ev.notice_secs.is_none() {
                    rework_secs += recov_state.rollback();
                }
            }
        }
        let mut migration_secs = 0.0;
        let mut evals = 0;
        let mut iter_hits = 0;
        let mut iter_misses = 0;
        let mut replanned = false;
        if !labels.is_empty() {
            let anytime_base = anytime
                .as_ref()
                .and_then(|a| a.incumbent().map(|(p, _)| plan_to_base(p, &map)));
            let hypothesis_base = match (&anytime, &hypo) {
                (Some(a), Some((_, hyp_map, idx))) if (fired_from..cursor).contains(idx) => {
                    a.hypothesis().map(|(p, _)| plan_to_base(p, hyp_map))
                }
                _ => None,
            };
            let (t, m) = fleet.snapshot();
            topo = t;
            map = m;
            let b2n = FleetState::base_to_snapshot(&map);
            let mm = cfg.base.replan.migration;
            let new_plan = match (policy, incumbent_base.as_ref()) {
                (Policy::Static, Some(inc)) => {
                    let prev = prev_placement(inc, &b2n);
                    let repaired = repair_plan(inc, wf, job, &topo, &b2n, seed ^ iter as u64);
                    match repaired {
                        Some(p) => {
                            migration_secs = mm.migration_time(&topo, wf, job, &prev, &p);
                            Some(p)
                        }
                        None => {
                            let out = cold(&topo);
                            evals += out.outcome.evals;
                            iter_hits += out.outcome.cache_hits;
                            iter_misses += out.outcome.cache_misses;
                            if let Some(p) = &out.outcome.plan {
                                migration_secs = mm.migration_time(&topo, wf, job, &prev, p);
                            }
                            out.outcome.plan
                        }
                    }
                }
                (Policy::Warm, Some(inc)) => {
                    replanned = true;
                    let out = replanner.replan(&topo, wf, job, inc, &b2n);
                    evals += out.evals;
                    iter_hits += out.cache_hits;
                    iter_misses += out.cache_misses;
                    migration_secs = out.migration_secs;
                    out.plan
                }
                (Policy::Anytime | Policy::Preempt, Some(inc)) => {
                    replanned = true;
                    let out = replanner.replan_with_anytime(
                        &topo,
                        wf,
                        job,
                        inc,
                        anytime_base.as_ref(),
                        hypothesis_base.as_ref(),
                        &b2n,
                    );
                    evals += out.evals;
                    iter_hits += out.cache_hits;
                    iter_misses += out.cache_misses;
                    migration_secs = out.migration_secs;
                    out.plan
                }
                (Policy::Oracle, _) | (_, None) => {
                    replanned = true;
                    let out = cold(&topo);
                    evals += out.outcome.evals;
                    iter_hits += out.outcome.cache_hits;
                    iter_misses += out.outcome.cache_misses;
                    out.outcome.plan
                }
            };
            plan = new_plan.map(|p| {
                if cfg.base.balance {
                    balance::apply(&p, wf, &topo, BalanceConfig::default())
                } else {
                    p
                }
            });
            // Graceful degradation (same as the sync replay): a barrier
            // with no feasible plan retains the incumbent in base-id
            // space; planning resumes from it at the next join barrier.
            if let Some(p) = plan.as_ref() {
                incumbent_base = Some(plan_to_base(p, &map));
            }
            if replanned {
                replans += 1;
            }
            reseed_anytime(&mut anytime, &topo, wf, job, plan.as_ref());
            hypo = None;
        }

        // Measure this iteration as one DES window of the pipeline; the
        // period (seconds per training step) is the async counterpart of
        // the sync iteration time.
        let (iter_secs, iter_samples, iter_stats) = match &plan {
            Some(p) => {
                let pipe = AsyncPipelineConfig {
                    staleness_bound: cfg.staleness_bound,
                    queue_capacity: cfg.queue_capacity,
                    window: cfg.window.max(1),
                    seed: seed ^ (iter as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                    noise: cfg.base.noise,
                    shuffle: cfg.base.shuffle,
                };
                let r = simulate_async(&topo, wf, job, p, &pipe);
                let st = AsyncIterStats {
                    queue_depth_mean: r.queue.mean_depth,
                    queue_depth_max: r.queue.max_depth,
                    producer_stall_secs: r.queue.producer_stall_secs / pipe.window as f64,
                    max_staleness: r.max_staleness,
                };
                (r.period, job.total_samples(), st)
            }
            None => (
                records.last().map(|r: &IterRecord| r.iter_secs).unwrap_or(600.0),
                0,
                AsyncIterStats::zero(),
            ),
        };
        max_staleness = max_staleness.max(iter_stats.max_staleness);
        // Checkpoint cadence over productive pipeline time: writes are
        // priced while the store is reachable; outages freeze the
        // stable point (widening the rollback exposure) instead.
        let mut ckpt_secs = 0.0f64;
        if recovery.enabled {
            if let Some(p) = &plan {
                let write = recovery.ckpt_write_secs(&cfg.base.replan.migration, wf, job, p);
                ckpt_secs =
                    recov_state.advance(iter_secs, write, fleet.store_up(), recovery.ckpt_interval_secs);
            }
        }
        let degraded = plan.is_none();
        if degraded {
            degraded_iters += 1;
        }
        total_secs += iter_secs + migration_secs + retry_stall_secs + rework_secs + ckpt_secs;
        total_stall += retry_stall_secs;
        total_rework += rework_secs;
        total_ckpt += ckpt_secs;

        if policy == Policy::Preempt {
            if hypo.is_none() {
                if let Some(idx) = next_noticed_loss(&trace, cursor, iter, iter_secs) {
                    let hyp_fleet = fleet.apply_hypothetical(&trace[idx].event);
                    let (ht, hm) = hyp_fleet.snapshot();
                    // An empty hypothetical fleet (every machine gone)
                    // has nothing to search — skip priming.
                    if ht.n() > 0 {
                        hypo = Some((ht, hm, idx));
                    }
                }
            }
            if let (Some(a), Some((ht, hm, idx))) = (anytime.as_mut(), hypo.as_ref()) {
                if a.hypothesis_key() != Some(*idx as u64) {
                    let hb2n = FleetState::base_to_snapshot(hm);
                    let mm = cfg.base.replan.migration;
                    let horizon = cfg.base.replan.horizon_iters.max(1.0);
                    let prev = incumbent_base
                        .as_ref()
                        .map(|inc| prev_placement(inc, &hb2n))
                        .unwrap_or_default();
                    let seed_plan = incumbent_base.as_ref().and_then(|inc| {
                        repair_plan(
                            inc,
                            wf,
                            job,
                            ht,
                            &hb2n,
                            seed ^ (*idx as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                        )
                    });
                    let objective = seed_plan
                        .as_ref()
                        .map(|p| {
                            CostModel::new(ht, wf, job).plan_cost(p).iter_time
                                + mm.migration_time(ht, wf, job, &prev, p) / horizon
                        })
                        .unwrap_or(f64::INFINITY);
                    a.prime_hypothesis(*idx as u64, seed_plan.as_ref(), objective, prev);
                }
            }
        }

        let mut anytime_evals = 0;
        let mut hypothesis_evals = 0;
        let mut anytime_cost = f64::INFINITY;
        if let Some(a) = anytime.as_mut() {
            a.accrue(iter_secs);
            let st = a.step(&topo, wf, job, hypo.as_ref().map(|(t, _, _)| t));
            anytime_evals = st.evals;
            hypothesis_evals = st.hypothesis_evals;
            anytime_cost = st.incumbent_cost;
            iter_hits += st.cache_hits;
            iter_misses += st.cache_misses;
        }
        total_evals += evals;
        total_anytime_evals += anytime_evals;
        total_hypothesis_evals += hypothesis_evals;
        cache_hits += iter_hits;
        cache_misses += iter_misses;

        records.push(IterRecord {
            iter,
            events: labels,
            replanned,
            evals,
            cache_hits: iter_hits,
            cache_misses: iter_misses,
            migration_secs,
            iter_secs,
            samples: iter_samples,
            active_gpus: topo.n(),
            anytime_evals,
            hypothesis_evals,
            anytime_cost,
            retry_stall_secs,
            rework_secs,
            ckpt_secs,
            degraded,
        });
        stats.push(iter_stats);
    }

    AsyncReplayResult {
        base: ReplayResult {
            policy,
            seed,
            samples: records.iter().map(|r| r.samples).sum(),
            records,
            total_secs,
            replans,
            total_evals,
            anytime_evals: total_anytime_evals,
            hypothesis_evals: total_hypothesis_evals,
            cache_hits,
            cache_misses,
            retry_stall_secs: total_stall,
            rework_secs: total_rework,
            ckpt_secs: total_ckpt,
            ckpts: recov_state.ckpts,
            degraded_iters,
            ckpt_interval_secs: if recovery.enabled { recovery.ckpt_interval_secs } else { 0.0 },
        },
        staleness_bound: cfg.staleness_bound,
        queue_capacity: cfg.queue_capacity.max(1),
        queue: stats,
        max_staleness,
    }
}

/// Reseed the background service on a fresh epoch (same convention as
/// the sync replay: the plan is costed at its pure iteration time).
fn reseed_anytime(
    anytime: &mut Option<AnytimeSearch>,
    topo: &DeviceTopology,
    wf: &RlWorkflow,
    job: &JobConfig,
    plan: Option<&ExecutionPlan>,
) {
    if let Some(a) = anytime.as_mut() {
        let cost = plan
            .map(|p| CostModel::new(topo, wf, job).plan_cost(p).iter_time)
            .unwrap_or(f64::INFINITY);
        a.reseed(plan, cost);
    }
}

/// Index of the next unfired noticed machine loss whose notice window
/// covers the estimated time until it fires (the sync replay's
/// predictive-preemption scan, verbatim).
fn next_noticed_loss(
    trace: &[crate::elastic::TraceEvent],
    cursor: usize,
    iter: usize,
    iter_secs: f64,
) -> Option<usize> {
    let (idx, ev) = trace
        .iter()
        .enumerate()
        .skip(cursor)
        .find(|(_, e)| e.is_machine_loss())?;
    let notice = ev.notice_secs?;
    let remaining = ev.at_iter.saturating_sub(iter + 1) as f64 * iter_secs.max(0.0);
    (remaining <= notice).then_some(idx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::fixtures;

    fn cfg(k: usize) -> AsyncReplayConfig {
        fixtures::async_replay_cfg(k, 1)
    }

    #[test]
    fn async_replay_runs_all_policies() {
        let wf = fixtures::tiny_wf();
        let job = fixtures::async_job();
        for policy in Policy::ALL {
            let r = replay_async(
                Scenario::MultiCountry,
                &fixtures::small_spec(),
                &wf,
                &job,
                policy,
                &cfg(2),
                3,
            );
            assert_eq!(r.base.records.len(), r.queue.len());
            assert!(r.base.total_secs > 0.0 && r.base.total_secs.is_finite(), "{policy:?}");
            assert!(r.max_staleness <= 2, "{policy:?}");
            assert_eq!(r.workflow_name(), "async");
        }
    }

    #[test]
    fn k0_delegates_to_sync_replay() {
        let wf = fixtures::tiny_wf();
        let job = fixtures::async_job();
        let c = cfg(0);
        let a = replay_async(
            Scenario::MultiCountry,
            &fixtures::small_spec(),
            &wf,
            &job,
            Policy::Warm,
            &c,
            7,
        );
        let s = crate::elastic::replay(
            Scenario::MultiCountry,
            &fixtures::small_spec(),
            &wf.with_mode(Mode::Sync),
            &job,
            Policy::Warm,
            &c.base,
            7,
        );
        assert_eq!(a.base, s);
        assert_eq!(a.workflow_name(), "sync");
        assert_eq!(a.max_staleness, 0);
        assert!(a.queue.iter().all(|q| *q == AsyncIterStats::zero()));
    }

    #[test]
    fn async_replay_is_deterministic() {
        let wf = fixtures::tiny_wf();
        let job = fixtures::async_job();
        let run = || {
            replay_async(
                Scenario::MultiRegionHybrid,
                &fixtures::small_spec(),
                &wf,
                &job,
                Policy::Anytime,
                &cfg(2),
                9,
            )
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn async_faults_charge_exactly_their_recovery_time() {
        let wf = fixtures::tiny_wf();
        let job = fixtures::async_job();
        let mut chaos = cfg(2);
        chaos.base.trace.fault_events = 3;
        let run = |c: &AsyncReplayConfig| {
            replay_async(
                Scenario::MultiCountry,
                &fixtures::small_spec(),
                &wf,
                &job,
                Policy::Warm,
                c,
                2,
            )
        };
        let free = run(&chaos);
        assert_eq!(free.base.retry_stall_secs, 0.0);
        assert_eq!(free.base.ckpt_secs, 0.0);

        let mut priced = chaos.clone();
        priced.base.recovery = crate::costmodel::RecoveryModel::with_interval(120.0);
        let paid = run(&priced);
        let extra = paid.base.retry_stall_secs + paid.base.rework_secs + paid.base.ckpt_secs;
        assert!(paid.base.retry_stall_secs > 0.0, "fault trace produced no retry stalls");
        // Recovery pricing is purely additive: it never perturbs the
        // plan-search trajectory, so the totals differ by exactly the
        // stall + rework + checkpoint charge.
        let diff = paid.base.total_secs - free.base.total_secs;
        assert!(
            (diff - extra).abs() <= 1e-9 * paid.base.total_secs.max(1.0),
            "diff {diff} != recovery charge {extra}"
        );
        assert_eq!(paid.base.ckpt_interval_secs, 120.0);
    }

    #[test]
    fn event_labels_carry_pool_annotations() {
        let wf = fixtures::tiny_wf();
        let job = fixtures::async_job();
        let r = replay_async(
            Scenario::MultiCountry,
            &fixtures::small_spec(),
            &wf,
            &job,
            Policy::Warm,
            &cfg(2),
            3,
        );
        let labels: Vec<&String> =
            r.base.records.iter().flat_map(|rec| rec.events.iter()).collect();
        assert!(!labels.is_empty(), "trace fired no events");
        assert!(
            labels.iter().all(|l| l.contains("[pool:")),
            "unannotated labels: {labels:?}"
        );
    }
}
