//! Asynchronous RL workflows with bounded off-policy staleness.
//!
//! The synchronous HetRL iteration is a barrier: generate → infer →
//! train → sync, every step on the whole fleet. Asynchronous RL systems
//! (AReaL, LlamaRL, StreamRL) instead split the task graph into a
//! **generation stream** and a **training stream** joined by a bounded
//! **rollout queue**, with a hard off-policy staleness bound `k`: a
//! rollout batch may be consumed at most `k` policy versions after the
//! one that generated it. `k = 0` degenerates exactly to today's
//! synchronous iteration.
//!
//! The subsystem has four layers, each reusing an existing mechanism:
//!
//! * **Workload model** — [`JobConfig::staleness_bound`] /
//!   [`JobConfig::rollout_queue_cap`]
//!   (crate::workflow::JobConfig) carry `k` and the queue capacity;
//!   the analytic period
//!   [`bounded_staleness_period`](crate::costmodel::bounded_staleness_period)
//!   prices async plans k-aware through the ordinary cost model.
//! * **Simulation** — [`pipeline::simulate_async`] runs per-stream
//!   continuous batching on the generic DES core
//!   ([`crate::simulator::des::SimGraph`]), with the queue capacity and
//!   staleness bound encoded as dependency edges over synthetic
//!   resources; [`queue::QueueTelemetry`] reports occupancy and
//!   producer stall.
//! * **Search** — [`search::plan_async`] adds the **pool split** plan
//!   dimension: the fleet partitioned into generation and training
//!   pools, swept as SHA arms on the existing engine under the
//!   determinism contract (same seed ⇒ bit-identical plan at any
//!   thread count).
//! * **Elastic replay** — [`replay::replay_async`] reuses the
//!   [`crate::elastic`] event/replan/anytime machinery so the two pools
//!   degrade independently under cluster churn (`hetrl replay
//!   --workflow async`, `benches/fig_async.rs`).
//!
//! [`JobConfig::staleness_bound`]: crate::workflow::JobConfig::staleness_bound
//! [`JobConfig::rollout_queue_cap`]: crate::workflow::JobConfig::rollout_queue_cap

pub mod pipeline;
pub mod queue;
pub mod replay;
pub mod search;

pub use pipeline::{simulate_async, AsyncPipelineConfig, AsyncSimResult};
pub use queue::QueueTelemetry;
pub use replay::{
    replay_async, replay_async_with_trace, AsyncIterStats, AsyncReplayConfig, AsyncReplayResult,
};
pub use search::{plan_async, AsyncOutcome, AsyncSearchConfig};
