//! Rollout-queue occupancy telemetry: time-weighted depth statistics
//! and producer stall time, computed from the enqueue/dequeue instants
//! the [`super::pipeline`] DES produces.
//!
//! The queue itself is *modeled* inside the simulated op graph (its
//! capacity and staleness bounds are dependency edges over synthetic
//! resources); this module only turns the resulting event times into
//! the mean/max-depth and stall numbers the replay table, `fig_async`
//! JSON and property tests report.

use crate::util::ford;

/// Occupancy telemetry of one simulated rollout queue.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QueueTelemetry {
    /// Time-weighted mean queue depth over the simulated horizon.
    pub mean_depth: f64,
    /// Maximum simultaneous queue depth observed.
    pub max_depth: usize,
    /// Total time the producer (generation stream) spent stalled on the
    /// queue/staleness bounds, in simulated seconds.
    pub producer_stall_secs: f64,
}

impl QueueTelemetry {
    /// All-zero telemetry (empty horizon, or the `k = 0` sync path that
    /// has no queue at all).
    pub fn empty() -> QueueTelemetry {
        QueueTelemetry { mean_depth: 0.0, max_depth: 0, producer_stall_secs: 0.0 }
    }

    /// Time-weighted occupancy from enqueue/dequeue instants over
    /// `[0, horizon]`. At equal timestamps dequeues are processed before
    /// enqueues, so a batch that is consumed the instant it arrives
    /// (zero dwell) never counts toward depth. `producer_stall_secs` is
    /// passed through (the pipeline computes it from gen-op gaps, which
    /// this module cannot reconstruct from queue events alone).
    pub fn from_events(
        enqueues: &[f64],
        dequeues: &[f64],
        horizon: f64,
        producer_stall_secs: f64,
    ) -> QueueTelemetry {
        if horizon <= 0.0 || enqueues.is_empty() {
            return QueueTelemetry { producer_stall_secs, ..QueueTelemetry::empty() };
        }
        // (time, delta): dequeues (-1) sort before enqueues (+1) at the
        // same instant.
        let mut events: Vec<(f64, i64)> = Vec::with_capacity(enqueues.len() + dequeues.len());
        events.extend(enqueues.iter().map(|&t| (t, 1i64)));
        events.extend(dequeues.iter().map(|&t| (t, -1i64)));
        events.sort_by(|a, b| ford::cmp_f64(a.0, b.0).then(a.1.cmp(&b.1)));

        let mut depth = 0i64;
        let mut max_depth = 0i64;
        let mut area = 0.0f64;
        let mut last_t = 0.0f64;
        for &(t, delta) in &events {
            let t = t.clamp(0.0, horizon);
            area += depth.max(0) as f64 * (t - last_t).max(0.0);
            last_t = t;
            depth += delta;
            max_depth = max_depth.max(depth);
        }
        area += depth.max(0) as f64 * (horizon - last_t).max(0.0);
        QueueTelemetry {
            mean_depth: area / horizon,
            max_depth: max_depth.max(0) as usize,
            producer_stall_secs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_zero() {
        let t = QueueTelemetry::empty();
        assert_eq!(t.mean_depth, 0.0);
        assert_eq!(t.max_depth, 0);
        assert_eq!(t.producer_stall_secs, 0.0);
        let u = QueueTelemetry::from_events(&[], &[], 10.0, 1.5);
        assert_eq!(u.mean_depth, 0.0);
        assert_eq!(u.producer_stall_secs, 1.5);
    }

    #[test]
    fn single_batch_dwell() {
        // Enqueued at 2, dequeued at 6, horizon 10: depth 1 for 4s.
        let t = QueueTelemetry::from_events(&[2.0], &[6.0], 10.0, 0.0);
        assert!((t.mean_depth - 0.4).abs() < 1e-12);
        assert_eq!(t.max_depth, 1);
    }

    #[test]
    fn overlapping_batches_stack() {
        // Two batches in flight during [2, 3].
        let t = QueueTelemetry::from_events(&[1.0, 2.0], &[3.0, 4.0], 4.0, 0.0);
        assert_eq!(t.max_depth, 2);
        // depth: 1 over [1,2], 2 over [2,3], 1 over [3,4] → area 4.
        assert!((t.mean_depth - 1.0).abs() < 1e-12);
    }

    #[test]
    fn zero_dwell_does_not_register() {
        // Consumed the instant it arrives: dequeue sorts first at ties.
        let t = QueueTelemetry::from_events(&[1.0, 2.0], &[1.0, 2.0], 4.0, 0.0);
        assert_eq!(t.max_depth, 0);
        assert_eq!(t.mean_depth, 0.0);
    }

    #[test]
    fn horizon_clamps_tail() {
        // Never dequeued within the horizon: depth 1 from t=1 to end.
        let t = QueueTelemetry::from_events(&[1.0], &[9.0], 5.0, 0.0);
        assert!((t.mean_depth - 4.0 / 5.0).abs() < 1e-12);
        assert_eq!(t.max_depth, 1);
    }
}
