//! Scheduler extension for asynchronous workflows: the **pool split**
//! plan dimension.
//!
//! An async plan partitions the heterogeneous fleet into a *generation
//! pool* and a *training pool* (every non-generation task). Structurally
//! this is just a Level-1/Level-2 decision — the task grouping
//! `[[actor-gen], [everything else]]` with GPU-group sizes
//! `[gen, n - gen]` — so the existing SHA/EA machinery searches it
//! unchanged: one [`EaArm`] per candidate generation-pool fraction,
//! successive halving over the shared eval ledger, and the EA's
//! cross-group swap and TFLOPS-upgrade mutations refining pool
//! *membership* within each arm. Plans are priced by the k-aware async
//! cost model
//! ([`bounded_staleness_period`](crate::costmodel::bounded_staleness_period)
//! via the workflow's `Async` mode), so the split that wins is the one
//! whose generation and training periods balance under the job's
//! staleness bound.
//!
//! The search inherits the engine's determinism contract: the same seed
//! yields the bit-identical plan, cost and eval count at any thread
//! count (quotas from the ledger, merges in arm order, seeded RNG
//! streams, no wall-clock).

use crate::scheduler::ea::{EaArm, EaConfig};
use crate::scheduler::engine::{resolve_threads, run_rung, split_quota, ArmTask};
use crate::scheduler::{Budget, EvalCtx, ScheduleOutcome};
use crate::topology::DeviceTopology;
use crate::util::ford;
use crate::workflow::{JobConfig, RlTaskId, RlWorkflow};

/// Configuration of one pool-split search.
#[derive(Debug, Clone)]
pub struct AsyncSearchConfig {
    /// Evaluation budget for the whole search.
    pub budget: Budget,
    /// Candidate generation-pool sizes as fractions of the fleet; each
    /// distinct clamped size becomes one SHA arm.
    pub gen_fracs: Vec<f64>,
    /// Successive-halving rounds over the arms.
    pub rounds: usize,
    /// Worker threads (0 = all cores); never affects the result.
    pub threads: usize,
    /// EA hyperparameters for the per-arm low-level search.
    pub ea: EaConfig,
}

impl Default for AsyncSearchConfig {
    fn default() -> Self {
        AsyncSearchConfig {
            budget: Budget::evals(600),
            gen_fracs: vec![0.25, 0.375, 0.5, 0.625, 0.75],
            rounds: 2,
            threads: 1,
            ea: EaConfig::default(),
        }
    }
}

/// Result of a pool-split search: the schedule outcome plus the winning
/// generation-pool share.
#[derive(Debug, Clone)]
pub struct AsyncOutcome {
    /// Plan, cost, evals, trace and cache telemetry (the cost is the
    /// k-aware async iteration-time estimate).
    pub outcome: ScheduleOutcome,
    /// Fraction of the fleet the best plan dedicates to generation
    /// (0.0 when no plan was found).
    pub gen_frac: f64,
}

/// Search execution plans for an asynchronous workflow by sweeping the
/// generation/training pool split. `wf.mode` should be
/// [`Async`](crate::workflow::Mode::Async) so candidates are priced by
/// the bounded-staleness period; the function itself is mode-agnostic.
///
/// Same `seed` ⇒ bit-identical `outcome.plan` / `cost` / `evals` —
/// and, since the sharded cache's accounting is exact, bit-identical
/// `cache_hits` / `cache_misses` / `task_pricings` — at any
/// `cfg.threads`.
pub fn plan_async(
    topo: &DeviceTopology,
    wf: &RlWorkflow,
    job: &JobConfig,
    cfg: &AsyncSearchConfig,
    seed: u64,
) -> AsyncOutcome {
    let Some(gen_t) = wf.task_index(RlTaskId::ActorGen) else {
        return AsyncOutcome { outcome: ScheduleOutcome::empty(), gen_frac: 0.0 };
    };
    let n = topo.n();
    if n < 2 {
        return AsyncOutcome { outcome: ScheduleOutcome::empty(), gen_frac: 0.0 };
    }
    let rest: Vec<usize> = (0..wf.n_tasks()).filter(|&t| t != gen_t).collect();
    let grouping = vec![vec![gen_t], rest];

    // Candidate generation-pool sizes: distinct clamped fractions, in
    // config order (order is part of the seed derivation).
    let mut gen_sizes: Vec<usize> = Vec::new();
    for &f in &cfg.gen_fracs {
        let size = ((f * n as f64).round() as usize).clamp(1, n - 1);
        if !gen_sizes.contains(&size) {
            gen_sizes.push(size);
        }
    }
    if gen_sizes.is_empty() {
        gen_sizes.push((n / 2).max(1));
    }

    let threads = resolve_threads(cfg.threads);
    let mut ctx = EvalCtx::new(topo, wf, job, cfg.budget);
    // (original arm index, arm): the index survives halving so seeds and
    // merge order never depend on which arms got dropped.
    let mut arms: Vec<(usize, EaArm)> = gen_sizes
        .iter()
        .enumerate()
        .map(|(i, &gs)| {
            let arm_seed = seed ^ (i as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
            (i, EaArm::new(grouping.clone(), vec![gs, n - gs], cfg.ea.clone(), arm_seed))
        })
        .collect();

    let rounds = cfg.rounds.max(1);
    for round in 0..rounds {
        if arms.is_empty() || ctx.exhausted() {
            break;
        }
        let quotas = split_quota(ctx.ledger.remaining(), arms.len(), rounds - round);
        let tasks: Vec<ArmTask> = arms
            .drain(..)
            .zip(quotas)
            .map(|((i, arm), quota)| ArmTask { key: (0, i), arm, quota })
            .collect();
        let runs = run_rung(&mut ctx, tasks, threads);
        arms = runs
            .into_iter()
            .filter(|r| !r.arm.is_infeasible())
            .map(|r| (r.key.1, r.arm))
            .collect();
        // Successive halving: keep the better half by arm best, ties to
        // the lower original index, keepers back in arm order.
        if round + 1 < rounds && arms.len() > 1 {
            let keep = arms.len().div_ceil(2);
            let mut order: Vec<usize> = (0..arms.len()).collect();
            order.sort_by(|&a, &b| {
                ford::cmp_f64(arms[a].1.best, arms[b].1.best).then(arms[a].0.cmp(&arms[b].0))
            });
            let mut kept: Vec<bool> = vec![false; arms.len()];
            for &o in order.iter().take(keep) {
                kept[o] = true;
            }
            let mut next = Vec::with_capacity(keep);
            for (slot, pair) in arms.into_iter().enumerate() {
                if kept[slot] {
                    next.push(pair);
                }
            }
            arms = next;
        }
    }

    let gen_frac = ctx
        .best_plan
        .as_ref()
        .map(|p| p.task_plans[gen_t].devices().len() as f64 / n as f64)
        .unwrap_or(0.0);
    AsyncOutcome { outcome: ctx.outcome(), gen_frac }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::CostModel;
    use crate::testing::fixtures;
    use crate::topology::Scenario;
    use crate::workflow::Mode;

    fn setup() -> (DeviceTopology, RlWorkflow, JobConfig, AsyncSearchConfig) {
        let topo = fixtures::small_topo(Scenario::SingleRegion);
        let wf = fixtures::tiny_wf().with_mode(Mode::Async);
        let job = JobConfig::tiny();
        let cfg = AsyncSearchConfig {
            budget: Budget::evals(160),
            gen_fracs: vec![1.0 / 3.0, 0.5, 2.0 / 3.0],
            ea: EaConfig { swap_samples: 40, ..EaConfig::default() },
            ..AsyncSearchConfig::default()
        };
        (topo, wf, job, cfg)
    }

    #[test]
    fn finds_a_plan_with_disjoint_pools() {
        let (topo, wf, job, cfg) = setup();
        let out = plan_async(&topo, &wf, &job, &cfg, 11);
        let plan = out.outcome.plan.expect("pool-split search found no plan");
        assert!(out.outcome.cost.is_finite());
        assert!(out.gen_frac > 0.0 && out.gen_frac < 1.0);
        // The 2-group Level-1 structure makes the pools disjoint, so the
        // plan's gen-overlap fraction — and with it the async overlap
        // penalty — must be zero.
        let sc = CostModel::new(&topo, &wf, &job).stream_costs(&plan);
        assert_eq!(sc.overlap_frac, 0.0);
    }

    #[test]
    fn deterministic_across_threads() {
        let (topo, wf, job, cfg) = setup();
        let base = plan_async(&topo, &wf, &job, &cfg, 23);
        for threads in fixtures::test_threads() {
            let c = AsyncSearchConfig { threads, ..cfg.clone() };
            let out = plan_async(&topo, &wf, &job, &c, 23);
            assert_eq!(out.outcome.cost, base.outcome.cost, "threads={threads}");
            assert_eq!(out.outcome.evals, base.outcome.evals, "threads={threads}");
            assert_eq!(out.outcome.plan, base.outcome.plan, "threads={threads}");
            assert_eq!(out.gen_frac, base.gen_frac, "threads={threads}");
        }
    }

    #[test]
    fn different_seeds_may_differ_but_all_valid() {
        let (topo, wf, job, cfg) = setup();
        for seed in [1u64, 2, 3] {
            let out = plan_async(&topo, &wf, &job, &cfg, seed);
            if let Some(p) = &out.outcome.plan {
                p.validate(&wf, &topo, &job).unwrap();
            }
        }
    }

    #[test]
    fn respects_budget() {
        let (topo, wf, job, mut cfg) = setup();
        cfg.budget = Budget::evals(40);
        let out = plan_async(&topo, &wf, &job, &cfg, 5);
        assert!(out.outcome.evals <= 40);
    }
}
