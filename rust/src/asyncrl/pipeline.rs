//! Discrete-event simulation of the bounded-staleness asynchronous RL
//! pipeline: a generation stream and a training stream joined by a
//! bounded rollout queue, executed as per-stream continuous batching on
//! the generic [`SimGraph`](crate::simulator::des::SimGraph) core.
//!
//! # Op structure
//!
//! For each training step `i` in a window of `w` steps the graph holds
//! five ops over four resources (`r_gen`, `r_train`, `r_queue`,
//! `r_sync`):
//!
//! | op    | resources            | duration     | dependencies |
//! |-------|----------------------|--------------|--------------|
//! | `G_i` | `r_gen`              | gen batch    | `G_{i-1}`; `S_{i-k-1}` if `i ≥ k+1`; `D_{i-cap}` if `i ≥ cap` |
//! | `E_i` | `r_queue`            | 0 (enqueue)  | `G_i`, `E_{i-1}` |
//! | `D_i` | `r_queue`            | 0 (dequeue)  | `E_i`, `T_{i-1}` |
//! | `T_i` | `r_train`            | train side   | `D_i` |
//! | `S_i` | `r_train`, `r_sync`  | weight sync  | `T_i` |
//!
//! The queue's capacity and the staleness bound are **dependency
//! edges**, not resource counts: the event-driven core breaks ready-time
//! ties FIFO, so encoding `cap` as "`cap` interchangeable slot
//! resources" could let generation of step `i + cap` steal a slot ahead
//! of the dequeue that step `i`'s consumer is still waiting on. Edges
//! make the bounds structural — `G_i` cannot *start* until the weight
//! sync of step `i - k - 1` has landed and batch `i - cap` has left the
//! queue, so `max_staleness ≤ k` holds for every schedule the core can
//! produce, noise or not.
//!
//! Weight sync occupies the training pool plus a sync token but **not**
//! the generation pool: generation picks up new weights in flight
//! (AReaL-style), which is why the analytic period
//! [`bounded_staleness_period`](crate::costmodel::bounded_staleness_period)
//! charges `sync` to the training side only. With `k = 0` the staleness
//! edge `G_{i+1} ← S_i` serializes the whole pipeline into exactly the
//! synchronous iteration `gen + train_side + sync`.

use super::queue::QueueTelemetry;
use crate::costmodel::{CostModel, StreamCosts};
use crate::plan::ExecutionPlan;
use crate::simulator::{NoiseModel, SimGraph};
use crate::topology::DeviceTopology;
use crate::util::rng::Rng;
use crate::workflow::{JobConfig, RlWorkflow};

/// Tolerance when deciding whether a weight sync landed before a
/// generation started (guards against float round-off on exact ties).
const SYNC_EPS: f64 = 1e-9;

/// Configuration of one async-pipeline simulation.
#[derive(Debug, Clone, Copy)]
pub struct AsyncPipelineConfig {
    /// Hard off-policy bound `k`: training step `i` may use rollouts
    /// generated at most `k` policy versions earlier. `0` = synchronous.
    pub staleness_bound: usize,
    /// Rollout-queue capacity (clamped to ≥ 1).
    pub queue_capacity: usize,
    /// Number of training steps to simulate.
    pub window: usize,
    /// Seed for the jitter draws.
    pub seed: u64,
    /// Noise model for compute/communication jitter.
    pub noise: NoiseModel,
    /// Optional seeded same-timestamp tie shuffle for the DES run
    /// (`None` = FIFO order, byte-identical to the pre-shuffle
    /// pipeline). See [`crate::simulator::ShuffleConfig`].
    pub shuffle: Option<crate::simulator::ShuffleConfig>,
}

impl Default for AsyncPipelineConfig {
    fn default() -> Self {
        AsyncPipelineConfig {
            staleness_bound: 1,
            queue_capacity: 2,
            window: 8,
            seed: 0,
            noise: NoiseModel::default(),
            shuffle: None,
        }
    }
}

/// Outcome of simulating the async pipeline for one plan.
#[derive(Debug, Clone, PartialEq)]
pub struct AsyncSimResult {
    /// Average seconds per training step over the window
    /// (`makespan / window`).
    pub period: f64,
    /// Finish time of the last op.
    pub makespan: f64,
    /// Largest observed off-policy staleness: for each training step,
    /// how many policy versions behind the generating policy was. Hard
    /// invariant: `max_staleness ≤ staleness_bound`.
    pub max_staleness: usize,
    /// Rollout-queue occupancy telemetry.
    pub queue: QueueTelemetry,
}

/// Simulate `cfg.window` training steps of the bounded-staleness
/// pipeline for `plan`, with per-step durations taken from
/// [`CostModel::stream_costs`] and jittered by `cfg.noise`.
///
/// The generation duration absorbs the plan's gen-device overlap
/// penalty (`overlap_frac · min(gen, train_side)`, the same term the
/// analytic async cost adds): a plan that time-shares generation
/// devices with the training side cannot actually stream, and the
/// pipeline pays for it on the generation critical path.
pub fn simulate_async(
    topo: &DeviceTopology,
    wf: &RlWorkflow,
    job: &JobConfig,
    plan: &ExecutionPlan,
    cfg: &AsyncPipelineConfig,
) -> AsyncSimResult {
    let sc: StreamCosts = CostModel::new(topo, wf, job).stream_costs(plan);
    let w = cfg.window.max(1);
    let k = cfg.staleness_bound;
    let cap = cfg.queue_capacity.max(1);
    let overlap_pause = sc.overlap_frac * sc.gen.min(sc.train_side);
    let mut rng = Rng::new(cfg.seed);

    const R_GEN: usize = 0;
    const R_TRAIN: usize = 1;
    const R_QUEUE: usize = 2;
    const R_SYNC: usize = 3;
    // Tags: gen / train / sync ops are reportable, queue ops plumbing.
    const TAG_GEN: usize = 0;
    const TAG_TRAIN: usize = 1;
    const TAG_SYNC: usize = 2;

    let mut g = SimGraph::new(4);
    let mut gen_ops = Vec::with_capacity(w);
    let mut enq_ops = Vec::with_capacity(w);
    let mut deq_ops = Vec::with_capacity(w);
    let mut train_ops = Vec::with_capacity(w);
    let mut sync_ops = Vec::with_capacity(w);

    for i in 0..w {
        // Fixed per-step draw order keeps the schedule a pure function
        // of (plan, cfg) regardless of how the core orders ready ops.
        let gen_dur = sc.gen * cfg.noise.comp_jitter(&mut rng) + overlap_pause;
        let train_dur = sc.train_side * cfg.noise.comp_jitter(&mut rng);
        let sync_dur = sc.sync * cfg.noise.comm_jitter(&mut rng);

        let mut gen_deps = Vec::new();
        if i >= 1 {
            gen_deps.push(gen_ops[i - 1]);
        }
        if i >= k + 1 {
            gen_deps.push(sync_ops[i - k - 1]);
        }
        if i >= cap {
            gen_deps.push(deq_ops[i - cap]);
        }
        let gi = g.add(vec![R_GEN], gen_dur, gen_deps, TAG_GEN);

        let mut enq_deps = vec![gi];
        if i >= 1 {
            enq_deps.push(enq_ops[i - 1]);
        }
        let ei = g.add(vec![R_QUEUE], 0.0, enq_deps, usize::MAX);

        let mut deq_deps = vec![ei];
        if i >= 1 {
            deq_deps.push(train_ops[i - 1]);
        }
        let di = g.add(vec![R_QUEUE], 0.0, deq_deps, usize::MAX);

        let ti = g.add(vec![R_TRAIN], train_dur, vec![di], TAG_TRAIN);
        let si = g.add(vec![R_TRAIN, R_SYNC], sync_dur, vec![ti], TAG_SYNC);

        gen_ops.push(gi);
        enq_ops.push(ei);
        deq_ops.push(di);
        train_ops.push(ti);
        sync_ops.push(si);
    }

    let out = g.simulate_with(cfg.shuffle);

    // Observed staleness of step i: versions the generating policy was
    // behind when G_i started = i minus the number of weight syncs that
    // had landed by then.
    let mut max_staleness = 0usize;
    for i in 0..w {
        let g_start = out.start[gen_ops[i]] + SYNC_EPS;
        let landed = sync_ops
            .iter()
            .take(i)
            .filter(|&&s| out.finish[s] <= g_start)
            .count();
        max_staleness = max_staleness.max(i - landed);
    }

    // Producer stall: idle time on the generation stream between
    // consecutive batches — time spent blocked on the staleness or
    // capacity edge rather than generating.
    let mut stall = 0.0f64;
    for i in 1..w {
        stall += (out.start[gen_ops[i]] - out.finish[gen_ops[i - 1]]).max(0.0);
    }

    let enqueues: Vec<f64> = enq_ops.iter().map(|&e| out.finish[e]).collect();
    let dequeues: Vec<f64> = deq_ops.iter().map(|&d| out.finish[d]).collect();
    let queue = QueueTelemetry::from_events(&enqueues, &dequeues, out.makespan, stall);

    AsyncSimResult {
        period: out.makespan / w as f64,
        makespan: out.makespan,
        max_staleness,
        queue,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::bounded_staleness_period;
    use crate::testing::fixtures;
    use crate::topology::Scenario;
    use crate::workflow::Mode;

    fn setup() -> (DeviceTopology, RlWorkflow, JobConfig, ExecutionPlan) {
        let topo = fixtures::small_topo(Scenario::SingleRegion);
        let wf = fixtures::tiny_wf().with_mode(Mode::Async);
        let job = JobConfig::tiny();
        let plan = fixtures::random_plan(&wf, &topo, &job, 3).expect("plan");
        (topo, wf, job, plan)
    }

    fn cfg(k: usize, cap: usize) -> AsyncPipelineConfig {
        AsyncPipelineConfig {
            staleness_bound: k,
            queue_capacity: cap,
            window: 12,
            seed: 0,
            noise: NoiseModel::off(),
            shuffle: None,
        }
    }

    #[test]
    fn k0_is_the_synchronous_iteration() {
        let (topo, wf, job, plan) = setup();
        let sc = CostModel::new(&topo, &wf, &job).stream_costs(&plan);
        let r = simulate_async(&topo, &wf, &job, &plan, &cfg(0, 4));
        let pause = sc.overlap_frac * sc.gen.min(sc.train_side);
        let step = sc.gen + pause + sc.train_side + sc.sync;
        assert!(
            (r.period - step).abs() < 1e-9 * step.max(1.0),
            "k=0 period {} != serial step {}",
            r.period,
            step
        );
        assert_eq!(r.max_staleness, 0);
    }

    #[test]
    fn staleness_bound_is_hard() {
        let (topo, wf, job, plan) = setup();
        for k in 0..4usize {
            for seed in [0u64, 1, 2] {
                let mut c = cfg(k, 2);
                c.seed = seed;
                c.noise = NoiseModel::default(); // jitter must not break it
                let r = simulate_async(&topo, &wf, &job, &plan, &c);
                assert!(
                    r.max_staleness <= k,
                    "staleness {} > bound {k} (seed {seed})",
                    r.max_staleness
                );
            }
        }
    }

    #[test]
    fn queue_depth_respects_capacity() {
        let (topo, wf, job, plan) = setup();
        for cap in 1..4usize {
            let r = simulate_async(&topo, &wf, &job, &plan, &cfg(3, cap));
            assert!(
                r.queue.max_depth <= cap,
                "depth {} > cap {cap}",
                r.queue.max_depth
            );
        }
    }

    #[test]
    fn period_monotone_in_staleness_and_floored() {
        let (topo, wf, job, plan) = setup();
        let sc = CostModel::new(&topo, &wf, &job).stream_costs(&plan);
        let pause = sc.overlap_frac * sc.gen.min(sc.train_side);
        let floor = (sc.gen + pause).max(sc.train_side + sc.sync);
        let mut prev = f64::INFINITY;
        for k in 0..5usize {
            let r = simulate_async(&topo, &wf, &job, &plan, &cfg(k, 4));
            assert!(r.period <= prev + 1e-9, "period rose at k={k}");
            assert!(r.period >= floor - 1e-9, "period below floor at k={k}");
            prev = r.period;
        }
    }

    #[test]
    fn window_period_converges_to_analytic() {
        // The analytic bound is steady-state; a finite window's period
        // must be ≥ it (warm-up) and approach it as the window grows.
        let (topo, wf, job, plan) = setup();
        let sc = CostModel::new(&topo, &wf, &job).stream_costs(&plan);
        let pause = sc.overlap_frac * sc.gen.min(sc.train_side);
        for k in [0usize, 1, 2] {
            let analytic =
                bounded_staleness_period(sc.gen + pause, sc.train_side, sc.sync, k, 2);
            let mut c = cfg(k, 2);
            c.window = 64;
            let r = simulate_async(&topo, &wf, &job, &plan, &c);
            assert!(r.period >= analytic - 1e-9, "k={k}");
            assert!(
                r.period <= analytic * 1.25 + 1e-9,
                "k={k}: window period {} far above analytic {analytic}",
                r.period
            );
        }
    }

    #[test]
    fn deterministic_for_seed() {
        let (topo, wf, job, plan) = setup();
        let mut c = cfg(2, 2);
        c.noise = NoiseModel::default();
        c.seed = 7;
        let a = simulate_async(&topo, &wf, &job, &plan, &c);
        let b = simulate_async(&topo, &wf, &job, &plan, &c);
        assert_eq!(a, b);
    }

    #[test]
    fn producer_stall_shrinks_with_slack() {
        // k=0 forces a stall of train+sync per step; large k with a deep
        // queue lets generation stream (stall only if train is slower).
        let (topo, wf, job, plan) = setup();
        let tight = simulate_async(&topo, &wf, &job, &plan, &cfg(0, 4));
        let loose = simulate_async(&topo, &wf, &job, &plan, &cfg(4, 4));
        assert!(loose.queue.producer_stall_secs <= tight.queue.producer_stall_secs + 1e-9);
    }
}
