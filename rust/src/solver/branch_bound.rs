//! Best-first branch & bound over the binary variables of a mixed 0-1
//! LP. LP relaxations come from [`super::simplex`]; fractional binaries
//! are branched most-fractional-first; incumbent solutions come from an
//! LP-rounding heuristic plus exact subtree leaves.

use super::simplex::{solve, Cmp, Lp, LpOutcome};
// detlint:allow(D1): B&B is an anytime *exact* baseline — its wall-clock cutoff is a sanctioned exception to bit-determinism (see scheduler::Budget docs)
use std::time::Instant;

/// Solver configuration.
#[derive(Debug, Clone)]
pub struct BnbConfig {
    /// Wall-clock budget in seconds.
    pub time_limit: f64,
    /// Node limit (safety).
    pub max_nodes: usize,
    /// Absolute optimality gap at which a node is pruned.
    pub gap: f64,
}

impl Default for BnbConfig {
    fn default() -> Self {
        BnbConfig { time_limit: 60.0, max_nodes: 200_000, gap: 1e-6 }
    }
}

/// Result of a branch & bound run.
#[derive(Debug, Clone)]
pub struct BnbResult {
    /// Best feasible (integral) solution found, if any.
    pub x: Option<Vec<f64>>,
    pub obj: f64,
    /// Best bound proven (equal to `obj` when `optimal`).
    pub bound: f64,
    pub optimal: bool,
    pub nodes: usize,
    pub elapsed: f64,
}

#[derive(Clone, Debug)]
struct Node {
    fixed: Vec<(usize, f64)>,
    bound: f64,
}

impl Node {
    fn depth(&self) -> usize {
        self.fixed.len()
    }
}

/// Solve `lp` with the variables in `binaries` restricted to {0,1}.
pub fn solve_milp(lp: &Lp, binaries: &[usize], cfg: &BnbConfig) -> BnbResult {
    let t0 = Instant::now(); // detlint:allow(D1): anytime cutoff for the exact ILP baseline, exempt from bit-determinism
    let minimize = !lp.maximize;
    let better = |a: f64, b: f64| if minimize { a < b } else { a > b };

    let mut best_obj = if minimize { f64::INFINITY } else { f64::NEG_INFINITY };
    let mut best_x: Option<Vec<f64>> = None;
    let mut nodes_explored = 0usize;

    // Add 0/1 upper bounds for the binaries once.
    let base_lp = {
        let mut l = lp.clone();
        for &b in binaries {
            l.constrain(vec![(b, 1.0)], Cmp::Le, 1.0);
        }
        l
    };

    let relax = |fixed: &[(usize, f64)]| -> LpOutcome {
        let mut l = base_lp.clone();
        for &(v, val) in fixed {
            l.constrain(vec![(v, 1.0)], Cmp::Eq, val);
        }
        solve(&l)
    };

    let root = relax(&[]);
    let root_bound = match &root {
        LpOutcome::Optimal { obj, .. } => *obj,
        LpOutcome::Infeasible => {
            return BnbResult {
                x: None,
                obj: best_obj,
                bound: best_obj,
                optimal: true,
                nodes: 1,
                elapsed: t0.elapsed().as_secs_f64(),
            }
        }
        LpOutcome::Unbounded => {
            return BnbResult {
                x: None,
                obj: best_obj,
                bound: if minimize { f64::NEG_INFINITY } else { f64::INFINITY },
                optimal: false,
                nodes: 1,
                elapsed: t0.elapsed().as_secs_f64(),
            }
        }
    };

    let mut queue: Vec<Node> = vec![Node { fixed: Vec::new(), bound: root_bound }];
    let mut timed_out = false;

    while !queue.is_empty() {
        // Best-first with depth dives: pick the best bound, breaking
        // (near-)ties toward the deepest node so degenerate plateaus
        // still produce incumbents quickly.
        let mut best_i = 0;
        for (i, n) in queue.iter().enumerate() {
            let cur = &queue[best_i];
            let tie = (n.bound - cur.bound).abs() <= 1e-9 * (1.0 + cur.bound.abs());
            if (tie && n.depth() > cur.depth()) || (!tie && better(n.bound, cur.bound)) {
                best_i = i;
            }
        }
        let node = queue.swap_remove(best_i);

        nodes_explored += 1;
        if nodes_explored > cfg.max_nodes || t0.elapsed().as_secs_f64() > cfg.time_limit {
            timed_out = true;
            queue.push(node);
            break;
        }
        // Prune by incumbent.
        if best_x.is_some() && !strictly_improving(node.bound, best_obj, minimize, cfg.gap) {
            continue;
        }
        let (x, obj) = match relax(&node.fixed) {
            LpOutcome::Optimal { x, obj } => (x, obj),
            _ => continue, // infeasible subtree
        };
        if best_x.is_some() && !strictly_improving(obj, best_obj, minimize, cfg.gap) {
            continue;
        }
        // Most fractional binary.
        let mut branch_var = usize::MAX;
        let mut best_frac = -1.0;
        for &b in binaries {
            let v = x[b];
            let dist = (v - v.round()).abs();
            if dist > 1e-6 {
                let frac_score = 0.5 - (v - v.floor() - 0.5).abs();
                if frac_score > best_frac {
                    best_frac = frac_score;
                    branch_var = b;
                }
            }
        }
        if branch_var == usize::MAX {
            if best_x.is_none() || better(obj, best_obj) {
                best_obj = obj;
                best_x = Some(x);
            }
            continue;
        }
        // Rounding heuristic for an early incumbent.
        if best_x.is_none() {
            if let Some((rx, robj)) = try_round(&base_lp, binaries, &x) {
                best_obj = robj;
                best_x = Some(rx);
            }
        }
        let toward = x[branch_var].round().clamp(0.0, 1.0);
        for val in [toward, 1.0 - toward] {
            let mut fixed = node.fixed.clone();
            fixed.push((branch_var, val));
            queue.push(Node { fixed, bound: obj });
        }
    }

    let mut proven_bound = best_obj;
    if timed_out || !queue.is_empty() {
        proven_bound = best_obj;
        for n in &queue {
            if better(n.bound, proven_bound) {
                proven_bound = n.bound;
            }
        }
        if best_x.is_none() {
            proven_bound = root_bound;
        }
    }

    BnbResult {
        optimal: !timed_out && queue.is_empty() && best_x.is_some(),
        x: best_x,
        obj: best_obj,
        bound: proven_bound,
        nodes: nodes_explored,
        elapsed: t0.elapsed().as_secs_f64(),
    }
}

/// Fix all binaries to rounded values and re-solve; returns the rounded
/// solution if feasible.
fn try_round(base: &Lp, binaries: &[usize], x: &[f64]) -> Option<(Vec<f64>, f64)> {
    let mut l = base.clone();
    for &b in binaries {
        l.constrain(vec![(b, 1.0)], Cmp::Eq, x[b].round().clamp(0.0, 1.0));
    }
    match solve(&l) {
        LpOutcome::Optimal { x, obj } => Some((x, obj)),
        _ => None,
    }
}

fn strictly_improving(bound: f64, incumbent: f64, minimize: bool, gap: f64) -> bool {
    if minimize {
        bound < incumbent - gap
    } else {
        bound > incumbent + gap
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> BnbConfig {
        BnbConfig { time_limit: 10.0, max_nodes: 50_000, gap: 1e-6 }
    }

    #[test]
    fn knapsack() {
        // max 10a + 13b + 7c s.t. 3a + 4b + 2c ≤ 6, binary → a+c (17) vs
        // b+c (20, weight 6 OK) → optimal 20.
        let mut lp = Lp::new(3, vec![10.0, 13.0, 7.0], true);
        lp.constrain(vec![(0, 3.0), (1, 4.0), (2, 2.0)], Cmp::Le, 6.0);
        let r = solve_milp(&lp, &[0, 1, 2], &cfg());
        assert!(r.optimal);
        assert!((r.obj - 20.0).abs() < 1e-6, "obj {}", r.obj);
        let x = r.x.unwrap();
        assert!(x[1] > 0.5 && x[2] > 0.5 && x[0] < 0.5);
    }

    #[test]
    fn matches_exhaustive_on_random_instances() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(42);
        for case in 0..20 {
            let n = 6;
            let c: Vec<f64> = (0..n).map(|_| rng.range_f64(-5.0, 10.0)).collect();
            let w: Vec<f64> = (0..n).map(|_| rng.range_f64(1.0, 5.0)).collect();
            let cap = rng.range_f64(4.0, 12.0);
            let mut lp = Lp::new(n, c.clone(), true);
            lp.constrain(w.iter().cloned().enumerate().collect(), Cmp::Le, cap);
            let r = solve_milp(&lp, &(0..n).collect::<Vec<_>>(), &cfg());
            // Exhaustive check.
            let mut best = 0.0f64;
            for mask in 0..(1usize << n) {
                let weight: f64 = (0..n).filter(|i| mask >> i & 1 == 1).map(|i| w[i]).sum();
                if weight <= cap + 1e-9 {
                    let val: f64 = (0..n).filter(|i| mask >> i & 1 == 1).map(|i| c[i]).sum();
                    best = best.max(val);
                }
            }
            assert!(r.optimal, "case {case} not optimal");
            assert!((r.obj - best).abs() < 1e-5, "case {case}: {} vs {best}", r.obj);
        }
    }

    #[test]
    fn mixed_integer_with_continuous() {
        // min 2x + y, x binary, y ≥ 0 continuous, x + y ≥ 1.5.
        // x=1 → y=0.5, obj 2.5 ; x=0 → y=1.5, obj 1.5 → optimal 1.5.
        let mut lp = Lp::new(2, vec![2.0, 1.0], false);
        lp.constrain(vec![(0, 1.0), (1, 1.0)], Cmp::Ge, 1.5);
        let r = solve_milp(&lp, &[0], &cfg());
        assert!(r.optimal);
        assert!((r.obj - 1.5).abs() < 1e-6);
    }

    #[test]
    fn infeasible_milp() {
        let mut lp = Lp::new(2, vec![1.0, 1.0], false);
        lp.constrain(vec![(0, 1.0), (1, 1.0)], Cmp::Ge, 3.0); // binaries sum ≤ 2
        let r = solve_milp(&lp, &[0, 1], &cfg());
        assert!(r.x.is_none());
    }

    #[test]
    fn respects_node_budget() {
        let mut lp = Lp::new(12, (0..12).map(|i| (i % 5) as f64 + 0.37).collect(), true);
        let terms: Vec<(usize, f64)> = (0..12).map(|i| (i, ((i * 7) % 3) as f64 + 1.1)).collect();
        lp.constrain(terms, Cmp::Le, 9.0);
        let tight = BnbConfig { time_limit: 10.0, max_nodes: 3, gap: 1e-6 };
        let r = solve_milp(&lp, &(0..12).collect::<Vec<_>>(), &tight);
        assert!(r.nodes <= 4);
    }
}
