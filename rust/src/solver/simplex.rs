//! Dense two-phase primal simplex.
//!
//! Solves `min c·x  s.t.  A x {≤,=,≥} b,  x ≥ 0` (maximization is
//! negated at the boundary). Phase 1 drives artificial variables out of
//! the basis; Bland's rule guards against cycling. Dense tableau — fine
//! for the few-thousand-variable relaxations the ILP scheduler builds.

/// Constraint comparator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Cmp {
    Le,
    Ge,
    Eq,
}

/// A linear program over `n` variables (all implicitly ≥ 0).
#[derive(Debug, Clone)]
pub struct Lp {
    pub n: usize,
    /// Objective coefficients (length n).
    pub c: Vec<f64>,
    pub maximize: bool,
    /// Sparse constraint rows: (terms, cmp, rhs).
    pub rows: Vec<(Vec<(usize, f64)>, Cmp, f64)>,
}

impl Lp {
    pub fn new(n: usize, c: Vec<f64>, maximize: bool) -> Lp {
        assert_eq!(c.len(), n);
        Lp { n, c, maximize, rows: Vec::new() }
    }

    pub fn constrain(&mut self, terms: Vec<(usize, f64)>, cmp: Cmp, rhs: f64) {
        for &(j, _) in &terms {
            assert!(j < self.n, "variable {j} out of range");
        }
        self.rows.push((terms, cmp, rhs));
    }
}

/// Result of an LP solve.
#[derive(Debug, Clone, PartialEq)]
pub enum LpOutcome {
    Optimal { x: Vec<f64>, obj: f64 },
    Infeasible,
    Unbounded,
}

const EPS: f64 = 1e-9;

/// Solve the LP. Deterministic.
pub fn solve(lp: &Lp) -> LpOutcome {
    // Standard form: min c'·x, rows ax = b with b ≥ 0, slack/surplus +
    // artificial variables appended.
    let m = lp.rows.len();
    let n = lp.n;
    // Count extra columns.
    let mut n_slack = 0;
    for (_, cmp, _) in &lp.rows {
        if matches!(cmp, Cmp::Le | Cmp::Ge) {
            n_slack += 1;
        }
    }
    // One artificial per row that needs it (Ge, Eq, or Le with b<0 after
    // normalization — we normalize so b ≥ 0 first).
    let mut rows_norm: Vec<(Vec<(usize, f64)>, Cmp, f64)> = Vec::with_capacity(m);
    for (terms, cmp, rhs) in &lp.rows {
        if *rhs < 0.0 {
            let neg: Vec<(usize, f64)> = terms.iter().map(|&(j, a)| (j, -a)).collect();
            let c = match cmp {
                Cmp::Le => Cmp::Ge,
                Cmp::Ge => Cmp::Le,
                Cmp::Eq => Cmp::Eq,
            };
            rows_norm.push((neg, c, -rhs));
        } else {
            rows_norm.push((terms.clone(), *cmp, *rhs));
        }
    }
    let mut n_art = 0;
    for (_, cmp, _) in &rows_norm {
        if matches!(cmp, Cmp::Ge | Cmp::Eq) {
            n_art += 1;
        }
    }
    let total = n + n_slack + n_art;
    // tableau: m rows × (total + 1) columns (last = rhs)
    let width = total + 1;
    let mut t = vec![0.0f64; m * width];
    let mut basis = vec![usize::MAX; m];
    let mut s_idx = n;
    let mut a_idx = n + n_slack;
    let mut artificial_cols = Vec::new();
    for (i, (terms, cmp, rhs)) in rows_norm.iter().enumerate() {
        let row = &mut t[i * width..(i + 1) * width];
        for &(j, a) in terms {
            row[j] += a;
        }
        row[total] = *rhs;
        match cmp {
            Cmp::Le => {
                row[s_idx] = 1.0;
                basis[i] = s_idx;
                s_idx += 1;
            }
            Cmp::Ge => {
                row[s_idx] = -1.0;
                s_idx += 1;
                row[a_idx] = 1.0;
                basis[i] = a_idx;
                artificial_cols.push(a_idx);
                a_idx += 1;
            }
            Cmp::Eq => {
                row[a_idx] = 1.0;
                basis[i] = a_idx;
                artificial_cols.push(a_idx);
                a_idx += 1;
            }
        }
    }

    // objective rows (reduced costs), phase 1 then phase 2
    let sign = if lp.maximize { -1.0 } else { 1.0 };
    let mut c2 = vec![0.0f64; total];
    for j in 0..n {
        c2[j] = sign * lp.c[j];
    }

    if n_art > 0 {
        // Phase 1: minimize sum of artificials.
        let mut c1 = vec![0.0f64; total];
        for &j in &artificial_cols {
            c1[j] = 1.0;
        }
        let obj = run_simplex(&mut t, &mut basis, &c1, m, total, width, total);
        match obj {
            None => return LpOutcome::Unbounded, // cannot happen in phase 1
            Some(v) if v > 1e-6 => return LpOutcome::Infeasible,
            _ => {}
        }
        // Drive remaining artificial basics out (degenerate rows).
        for i in 0..m {
            if artificial_cols.contains(&basis[i]) {
                // pivot on any non-artificial column with nonzero coeff
                let mut pivoted = false;
                for j in 0..n + n_slack {
                    if t[i * width + j].abs() > EPS {
                        pivot(&mut t, &mut basis, i, j, m, width);
                        pivoted = true;
                        break;
                    }
                }
                if !pivoted {
                    // redundant row; leave artificial at zero
                }
            }
        }
    }

    // Phase 2: artificial columns are barred from entering the basis
    // (any still basic are at value 0 after phase 1 and contribute
    // nothing to the objective).
    let enter_limit = n + n_slack;
    let obj = run_simplex(&mut t, &mut basis, &c2, m, total, width, enter_limit);
    let Some(raw) = obj else {
        return LpOutcome::Unbounded;
    };
    // Extract solution.
    let mut x = vec![0.0f64; n];
    for i in 0..m {
        if basis[i] < n {
            x[basis[i]] = t[i * width + total];
        }
    }
    let obj_val = if lp.maximize { -raw } else { raw };
    LpOutcome::Optimal { x, obj: obj_val }
}

/// Run simplex iterations on the tableau with cost vector `c`. Columns
/// `>= enter_limit` may not enter the basis (phase-2 artificials).
/// Returns the objective value, or None if unbounded.
fn run_simplex(
    t: &mut [f64],
    basis: &mut [usize],
    c: &[f64],
    m: usize,
    total: usize,
    width: usize,
    enter_limit: usize,
) -> Option<f64> {
    // reduced cost row: z_j = c_j - c_B · B^{-1} A_j, maintained directly
    let mut zrow = vec![0.0f64; total + 1];
    for j in 0..total {
        zrow[j] = c[j];
    }
    for i in 0..m {
        let cb = c[basis[i]];
        if cb != 0.0 {
            for j in 0..=total {
                zrow[j] -= cb * t[i * width + j];
            }
        }
    }
    let mut iters = 0usize;
    let max_iters = 20_000 + 50 * (m + total);
    loop {
        iters += 1;
        if iters > max_iters {
            // Numerical trouble / cycling beyond Bland safeguard: treat
            // current vertex as optimal-enough.
            break;
        }
        // entering column: most negative reduced cost (Dantzig), falling
        // back to Bland (lowest index) every 64 iterations to kill cycles.
        let mut enter = usize::MAX;
        let limit = enter_limit.min(total);
        if iters % 64 == 0 {
            for j in 0..limit {
                if zrow[j] < -EPS {
                    enter = j;
                    break;
                }
            }
        } else {
            let mut best = -EPS;
            for j in 0..limit {
                if zrow[j] < best {
                    best = zrow[j];
                    enter = j;
                }
            }
        }
        if enter == usize::MAX {
            break; // optimal
        }
        // leaving row: min ratio test (Bland ties by basis index)
        let mut leave = usize::MAX;
        let mut best_ratio = f64::INFINITY;
        for i in 0..m {
            let a = t[i * width + enter];
            if a > EPS {
                let ratio = t[i * width + total] / a;
                if ratio < best_ratio - EPS
                    || (ratio < best_ratio + EPS
                        && leave != usize::MAX
                        && basis[i] < basis[leave])
                {
                    best_ratio = ratio;
                    leave = i;
                }
            }
        }
        if leave == usize::MAX {
            return None; // unbounded
        }
        pivot_with_z(t, basis, &mut zrow, leave, enter, m, width);
    }
    // objective = -zrow[total] (z row holds c·x_B offset)
    Some(-zrow[total])
}

fn pivot(t: &mut [f64], basis: &mut [usize], row: usize, col: usize, m: usize, width: usize) {
    let p = t[row * width + col];
    debug_assert!(p.abs() > EPS);
    for j in 0..width {
        t[row * width + j] /= p;
    }
    for i in 0..m {
        if i != row {
            let f = t[i * width + col];
            if f.abs() > EPS {
                for j in 0..width {
                    t[i * width + j] -= f * t[row * width + j];
                }
            }
        }
    }
    basis[row] = col;
}

fn pivot_with_z(
    t: &mut [f64],
    basis: &mut [usize],
    zrow: &mut [f64],
    row: usize,
    col: usize,
    m: usize,
    width: usize,
) {
    pivot(t, basis, row, col, m, width);
    let f = zrow[col];
    if f.abs() > EPS {
        for j in 0..width {
            zrow[j] -= f * t[row * width + j];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_close(a: f64, b: f64) {
        assert!((a - b).abs() < 1e-6, "{a} vs {b}");
    }

    #[test]
    fn max_2d() {
        // max 3x + 2y s.t. x + y ≤ 4, x + 3y ≤ 6 → x=4, y=0, obj 12
        let mut lp = Lp::new(2, vec![3.0, 2.0], true);
        lp.constrain(vec![(0, 1.0), (1, 1.0)], Cmp::Le, 4.0);
        lp.constrain(vec![(0, 1.0), (1, 3.0)], Cmp::Le, 6.0);
        match solve(&lp) {
            LpOutcome::Optimal { x, obj } => {
                assert_close(obj, 12.0);
                assert_close(x[0], 4.0);
                assert_close(x[1], 0.0);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn min_with_ge_and_eq() {
        // min x + y s.t. x + 2y ≥ 4, x = 1 → y = 1.5, obj 2.5
        let mut lp = Lp::new(2, vec![1.0, 1.0], false);
        lp.constrain(vec![(0, 1.0), (1, 2.0)], Cmp::Ge, 4.0);
        lp.constrain(vec![(0, 1.0)], Cmp::Eq, 1.0);
        match solve(&lp) {
            LpOutcome::Optimal { x, obj } => {
                assert_close(obj, 2.5);
                assert_close(x[0], 1.0);
                assert_close(x[1], 1.5);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn infeasible_detected() {
        // x ≤ 1 and x ≥ 2
        let mut lp = Lp::new(1, vec![1.0], false);
        lp.constrain(vec![(0, 1.0)], Cmp::Le, 1.0);
        lp.constrain(vec![(0, 1.0)], Cmp::Ge, 2.0);
        assert_eq!(solve(&lp), LpOutcome::Infeasible);
    }

    #[test]
    fn unbounded_detected() {
        let mut lp = Lp::new(1, vec![1.0], true);
        lp.constrain(vec![(0, -1.0)], Cmp::Le, 0.0); // -x ≤ 0 i.e. x ≥ 0
        assert_eq!(solve(&lp), LpOutcome::Unbounded);
    }

    #[test]
    fn negative_rhs_normalized() {
        // min x s.t. -x ≤ -3  (x ≥ 3)
        let mut lp = Lp::new(1, vec![1.0], false);
        lp.constrain(vec![(0, -1.0)], Cmp::Le, -3.0);
        match solve(&lp) {
            LpOutcome::Optimal { x, obj } => {
                assert_close(obj, 3.0);
                assert_close(x[0], 3.0);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn assignment_lp_is_integral() {
        // 2×2 assignment: min 1*x00 + 3*x01 + 2*x10 + 1*x11
        // each row/col sums to 1 → x00 = x11 = 1, obj 2
        let mut lp = Lp::new(4, vec![1.0, 3.0, 2.0, 1.0], false);
        lp.constrain(vec![(0, 1.0), (1, 1.0)], Cmp::Eq, 1.0);
        lp.constrain(vec![(2, 1.0), (3, 1.0)], Cmp::Eq, 1.0);
        lp.constrain(vec![(0, 1.0), (2, 1.0)], Cmp::Eq, 1.0);
        lp.constrain(vec![(1, 1.0), (3, 1.0)], Cmp::Eq, 1.0);
        match solve(&lp) {
            LpOutcome::Optimal { x, obj } => {
                assert_close(obj, 2.0);
                assert_close(x[0], 1.0);
                assert_close(x[3], 1.0);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn degenerate_does_not_cycle() {
        // Classic degeneracy-prone instance.
        let mut lp = Lp::new(4, vec![-0.75, 150.0, -0.02, 6.0], false);
        lp.constrain(vec![(0, 0.25), (1, -60.0), (2, -0.04), (3, 9.0)], Cmp::Le, 0.0);
        lp.constrain(vec![(0, 0.5), (1, -90.0), (2, -0.02), (3, 3.0)], Cmp::Le, 0.0);
        lp.constrain(vec![(2, 1.0)], Cmp::Le, 1.0);
        match solve(&lp) {
            LpOutcome::Optimal { obj, .. } => assert_close(obj, -0.05),
            other => panic!("{other:?}"),
        }
    }

    /// Brute-force LP check on random small boxes: compare against
    /// evaluating the objective on a fine grid of the feasible region.
    #[test]
    fn prop_matches_grid_search_2d() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(99);
        for _case in 0..30 {
            let c0 = rng.range_f64(-3.0, 3.0);
            let c1 = rng.range_f64(-3.0, 3.0);
            let b0 = rng.range_f64(1.0, 5.0);
            let b1 = rng.range_f64(1.0, 5.0);
            // max c·x s.t. x0 ≤ b0, x1 ≤ b1, x0 + x1 ≤ b0+b1 (redundant)
            let mut lp = Lp::new(2, vec![c0, c1], true);
            lp.constrain(vec![(0, 1.0)], Cmp::Le, b0);
            lp.constrain(vec![(1, 1.0)], Cmp::Le, b1);
            lp.constrain(vec![(0, 1.0), (1, 1.0)], Cmp::Le, b0 + b1);
            let expect = c0.max(0.0) * b0 + c1.max(0.0) * b1;
            match solve(&lp) {
                LpOutcome::Optimal { obj, .. } => {
                    assert!((obj - expect).abs() < 1e-6, "case: {obj} vs {expect}")
                }
                other => panic!("{other:?}"),
            }
        }
    }
}
