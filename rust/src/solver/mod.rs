//! Standalone mixed 0-1 linear-programming solver (no Gurobi/CBC in this
//! offline environment): a dense two-phase primal simplex for the LP
//! relaxation plus best-first branch & bound over the binary variables.
//!
//! This is the substrate under HetRL's ILP-based scheduling algorithm
//! (paper §3.5). Scale target: the paper's small-scale setting (≤ 24
//! GPUs, Figure 6), where exact solutions are reported in minutes.

pub mod simplex;
pub mod branch_bound;

pub use branch_bound::{solve_milp, BnbConfig, BnbResult};
pub use simplex::{Cmp, Lp, LpOutcome};
