//! Tiny CLI argument parser (no clap offline). Supports subcommands,
//! `--flag`, `--key value` and `--key=value` forms, with typed accessors
//! and automatically generated usage text.

use std::collections::BTreeMap;

/// Declarative option spec used to render `--help`.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
}

/// Parsed arguments: a subcommand, positional args and key/value options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse raw argv (without the program name). The first non-option
    /// token becomes the subcommand; later non-option tokens are positional.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if body.is_empty() {
                    // "--": everything after is positional
                    out.positional.extend(it);
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.options.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name} expects a number, got '{v}'")),
        }
    }
}

/// Render a usage/help block.
pub fn usage(program: &str, subcommands: &[(&str, &str)], opts: &[OptSpec]) -> String {
    let mut s = format!("usage: {program} <subcommand> [options]\n\nsubcommands:\n");
    for (name, help) in subcommands {
        s.push_str(&format!("  {name:<22} {help}\n"));
    }
    if !opts.is_empty() {
        s.push_str("\noptions:\n");
        for o in opts {
            let d = o.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
            s.push_str(&format!("  --{:<20} {}{}\n", o.name, o.help, d));
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|t| t.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("schedule pos1 --gpus 64 --scenario=multi-country --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("schedule"));
        assert_eq!(a.get("gpus"), Some("64"));
        assert_eq!(a.get("scenario"), Some("multi-country"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
        // NOTE: `--verbose pos1` would bind pos1 as the option's value —
        // value-taking and boolean options are disambiguated by position.
    }

    #[test]
    fn typed_accessors() {
        let a = parse("x --n 12 --f 2.5");
        assert_eq!(a.get_usize("n", 0).unwrap(), 12);
        assert_eq!(a.get_f64("f", 0.0).unwrap(), 2.5);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
        assert!(parse("x --n abc").get_usize("n", 0).is_err());
    }

    #[test]
    fn double_dash_positional() {
        let a = parse("run -- --not-an-option");
        assert_eq!(a.positional, vec!["--not-an-option"]);
    }

    #[test]
    fn trailing_flag() {
        let a = parse("run --fast");
        assert!(a.flag("fast"));
        assert_eq!(a.get("fast"), None);
    }
}
