//! Deterministic pseudo-random number generation.
//!
//! The offline registry has no `rand`; the scheduler (EA initialization,
//! mutation, SHA shuffles), the simulator (jitter) and the engine
//! (sampling) all need a seedable, reproducible PRNG. We implement
//! SplitMix64 (seeding) feeding xoshiro256**, the standard combination.

/// xoshiro256** seeded through SplitMix64. Deterministic across platforms.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        // 53 high bits → uniform double in [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)`. Panics if `n == 0`.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        // Lemire-style rejection-free for our purposes: modulo bias is
        // negligible for n << 2^64 but we debias anyway.
        let n64 = n as u64;
        let zone = u64::MAX - (u64::MAX % n64);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n64) as usize;
            }
        }
    }

    /// Uniform integer in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a uniformly random element of a slice.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Pick a uniformly random index of a slice.
    pub fn choice_index<T>(&mut self, xs: &[T]) -> usize {
        self.below(xs.len())
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal with the given parameters of the underlying normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted() needs positive total weight");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Split off an independently-seeded child generator (for threadpool
    /// fan-out with per-worker determinism).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_uniformish() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "count {c} out of band");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(5);
        let w = [1.0, 0.0, 9.0];
        let mut c = [0usize; 3];
        for _ in 0..10_000 {
            c[r.weighted(&w)] += 1;
        }
        assert_eq!(c[1], 0);
        assert!(c[2] > 5 * c[0]);
    }
}
