//! Micro-benchmark harness used by `cargo bench` (criterion is not
//! available offline). Provides warmup, repeated timed runs, median/MAD
//! reporting and a tiny runner with `--filter` support so `cargo bench`
//! behaves like a normal bench target.

use std::time::Instant;

use super::stats;

/// Wall-clock stopwatch for telemetry. This module is one of the three
/// detlint **D1** allowlisted homes of `Instant` (`util/logging`,
/// `util/benchkit`, `engine/grpo`): code elsewhere may *report* elapsed
/// time through a `Stopwatch`, but must never branch on it — wall-clock
/// time influencing search results breaks the bit-determinism contract
/// (see `hetrl lint`).
#[derive(Debug, Clone, Copy)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    /// Start timing now.
    pub fn start() -> Stopwatch {
        Stopwatch { start: Instant::now() }
    }

    /// Seconds elapsed since [`Stopwatch::start`]. Telemetry only.
    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "bench {:<44} {:>12}/iter (median over {} iters, min {}, max {})",
            self.name,
            super::units::fmt_secs(self.median_ns * 1e-9),
            self.iters,
            super::units::fmt_secs(self.min_ns * 1e-9),
            super::units::fmt_secs(self.max_ns * 1e-9),
        )
    }
}

/// Time `f` with `warmup` untimed runs then `iters` timed runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    assert!(iters >= 1);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    let s = stats::summarize(&samples);
    BenchResult {
        name: name.to_string(),
        iters,
        median_ns: s.p50,
        mean_ns: s.mean,
        min_ns: s.min,
        max_ns: s.max,
    }
}

/// A named group of benchmarks with a shared `main()`-style runner.
pub struct Runner {
    title: String,
    filter: Option<String>,
    results: Vec<BenchResult>,
}

impl Runner {
    /// Build from `std::env::args()`; accepts `--bench` (ignored, cargo
    /// passes it) and an optional substring filter argument.
    pub fn from_args(title: &str) -> Runner {
        let args: Vec<String> = std::env::args().skip(1).collect();
        let filter = args.into_iter().find(|a| !a.starts_with("--"));
        println!("=== {title} ===");
        Runner { title: title.to_string(), filter, results: Vec::new() }
    }

    /// Whether a bench with this name should run under the filter.
    pub fn enabled(&self, name: &str) -> bool {
        match &self.filter {
            None => true,
            Some(f) => name.contains(f.as_str()),
        }
    }

    /// Run one micro-benchmark if enabled.
    pub fn bench<F: FnMut()>(&mut self, name: &str, warmup: usize, iters: usize, f: F) {
        if !self.enabled(name) {
            return;
        }
        let r = bench(name, warmup, iters, f);
        println!("{}", r.report());
        self.results.push(r);
    }

    /// Run an arbitrary "scenario" block (used by figure benches that print
    /// tables rather than timing a closure).
    pub fn scenario<F: FnOnce()>(&self, name: &str, f: F) {
        if !self.enabled(name) {
            return;
        }
        println!("--- {} :: {} ---", self.title, name);
        let t0 = Instant::now();
        f();
        println!(
            "--- {} :: {} done in {} ---\n",
            self.title,
            name,
            super::units::fmt_secs(t0.elapsed().as_secs_f64())
        );
    }

    pub fn finish(self) {
        println!("=== {} complete ({} timed benches) ===", self.title, self.results.len());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_times_something() {
        let mut acc = 0u64;
        let r = bench("spin", 1, 5, || {
            for i in 0..1000 {
                acc = acc.wrapping_add(i);
            }
        });
        assert_eq!(r.iters, 5);
        assert!(r.median_ns > 0.0);
        assert!(r.min_ns <= r.median_ns && r.median_ns <= r.max_ns);
        // keep acc alive
        assert!(acc < u64::MAX);
    }
}
