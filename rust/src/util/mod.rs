//! In-crate substrates for facilities that would normally come from the
//! crates.io ecosystem (unavailable in this offline environment): PRNG,
//! JSON, CLI parsing, logging, a threadpool, ASCII tables, statistics and
//! a micro-benchmark harness used by `cargo bench`.

pub mod rng;
pub mod ford;
pub mod error;
pub mod json;
pub mod cli;
pub mod logging;
pub mod threadpool;
pub mod table;
pub mod stats;
pub mod units;
pub mod benchkit;

/// A deterministic, order-stable "hash" map replacement for small keys —
/// a sorted Vec. Used where iteration order must be reproducible across
/// runs (the scheduler relies on determinism for SHA tie-breaking).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct VecMap<K: Ord, V> {
    entries: Vec<(K, V)>,
}

impl<K: Ord, V> VecMap<K, V> {
    pub fn new() -> Self {
        VecMap { entries: Vec::new() }
    }

    pub fn insert(&mut self, k: K, v: V) -> Option<V> {
        match self.entries.binary_search_by(|(ek, _)| ek.cmp(&k)) {
            Ok(i) => Some(std::mem::replace(&mut self.entries[i].1, v)),
            Err(i) => {
                self.entries.insert(i, (k, v));
                None
            }
        }
    }

    pub fn get(&self, k: &K) -> Option<&V> {
        self.entries
            .binary_search_by(|(ek, _)| ek.cmp(k))
            .ok()
            .map(|i| &self.entries[i].1)
    }

    pub fn get_mut(&mut self, k: &K) -> Option<&mut V> {
        match self.entries.binary_search_by(|(ek, _)| ek.cmp(k)) {
            Ok(i) => Some(&mut self.entries[i].1),
            Err(_) => None,
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&K, &V)> {
        self.entries.iter().map(|(k, v)| (k, v))
    }

    pub fn contains_key(&self, k: &K) -> bool {
        self.get(k).is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vecmap_insert_get() {
        let mut m = VecMap::new();
        assert_eq!(m.insert(3, "c"), None);
        assert_eq!(m.insert(1, "a"), None);
        assert_eq!(m.insert(2, "b"), None);
        assert_eq!(m.insert(2, "B"), Some("b"));
        assert_eq!(m.get(&2), Some(&"B"));
        assert_eq!(m.len(), 3);
        let keys: Vec<_> = m.iter().map(|(k, _)| *k).collect();
        assert_eq!(keys, vec![1, 2, 3]); // sorted iteration order
    }
}
