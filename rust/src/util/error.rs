//! Minimal `anyhow`-compatible error substrate (the offline registry has
//! no `anyhow`). Provides [`Error`], [`Result`], the [`anyhow!`] /
//! [`bail!`] macros and a [`Context`] extension trait for `Result` and
//! `Option`, covering exactly the surface the runtime/engine code uses.
//!
//! [`anyhow!`]: crate::anyhow
//! [`bail!`]: crate::bail

use std::fmt;

/// A boxed, chain-printing error: a message plus an optional source
/// message chain, rendered as `outer: inner: ...` by `Display` (both
/// `{}` and `{:#}` print the full chain, like `anyhow`'s `{:#}`).
#[derive(Debug, Clone)]
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build from a single message.
    pub fn msg(message: impl fmt::Display) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context(mut self, message: impl fmt::Display) -> Error {
        self.chain.insert(0, message.to_string());
        self
    }

    /// The outermost message.
    pub fn root(&self) -> &str {
        self.chain.first().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::msg(e)
    }
}

impl From<std::fmt::Error> for Error {
    fn from(e: std::fmt::Error) -> Error {
        Error::msg(e)
    }
}

impl From<String> for Error {
    fn from(s: String) -> Error {
        Error { chain: vec![s] }
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Error {
        Error { chain: vec![s.to_string()] }
    }
}

impl From<crate::util::json::JsonError> for Error {
    fn from(e: crate::util::json::JsonError) -> Error {
        Error::msg(e)
    }
}

/// Crate-wide result type (the `anyhow::Result` stand-in).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow!`-style construction from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// `bail!`-style early return with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::util::error::Error::msg(format!($($arg)*)))
    };
}

// Allow `use crate::util::error::{anyhow, bail}` at call sites, keeping
// the original `anyhow`-idiomatic imports intact.
pub use crate::{anyhow, bail};

/// `anyhow::Context` stand-in for `Result` and `Option`.
pub trait Context<T> {
    fn context(self, message: impl fmt::Display) -> Result<T>;
    fn with_context<D: fmt::Display>(self, f: impl FnOnce() -> D) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, message: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(message))
    }

    fn with_context<D: fmt::Display>(self, f: impl FnOnce() -> D) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, message: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error::msg(message))
    }

    fn with_context<D: fmt::Display>(self, f: impl FnOnce() -> D) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("inner {}", 42)
    }

    #[test]
    fn bail_and_display() {
        let e = fails().unwrap_err();
        assert_eq!(format!("{e}"), "inner 42");
        assert_eq!(format!("{e:#}"), "inner 42");
    }

    #[test]
    fn context_chains() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer: inner 42");
        let e = fails().with_context(|| format!("step {}", 3)).unwrap_err();
        assert_eq!(format!("{e}"), "step 3: inner 42");
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        let e = none.context("missing value").unwrap_err();
        assert_eq!(e.root(), "missing value");
        assert_eq!(Some(7).context("x").unwrap(), 7);
    }

    #[test]
    fn io_error_converts() {
        fn read() -> Result<String> {
            Ok(std::fs::read_to_string("/definitely/not/here")?)
        }
        assert!(read().is_err());
    }

    #[test]
    fn anyhow_macro_formats() {
        let e = anyhow!("bad {} at {}", "thing", 9);
        assert_eq!(e.root(), "bad thing at 9");
    }
}
