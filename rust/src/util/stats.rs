//! Small statistics toolkit: summary stats, percentiles, error metrics
//! used by the benchmark harness and the cost-model validation (Fig 7).

/// Summary of a sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
}

/// Compute a [`Summary`] of a non-empty sample. NaN-tolerant: NaNs sort
/// last under [`super::ford::cmp_f64`] instead of panicking.
pub fn summarize(xs: &[f64]) -> Summary {
    assert!(!xs.is_empty(), "summarize() on empty sample");
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = if n > 1 {
        xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
    } else {
        0.0
    };
    let mut sorted = xs.to_vec();
    super::ford::sort_f64(&mut sorted);
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        max: sorted[n - 1],
        p50: percentile_sorted(&sorted, 50.0),
        p95: percentile_sorted(&sorted, 95.0),
    }
}

/// Percentile (linear interpolation) of an already-sorted sample.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Mean absolute percentage error between predictions and ground truth.
pub fn mape(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    assert!(!pred.is_empty());
    let s: f64 = pred
        .iter()
        .zip(truth)
        .map(|(p, t)| ((p - t) / t).abs())
        .sum();
    100.0 * s / pred.len() as f64
}

/// Relative error of a single prediction.
pub fn rel_err(pred: f64, truth: f64) -> f64 {
    ((pred - truth) / truth).abs()
}

/// Geometric mean of positive values.
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let s: f64 = xs.iter().map(|x| x.ln()).sum();
    (s / xs.len() as f64).exp()
}

/// Harmonic mean of positive values.
pub fn harmonic_mean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    xs.len() as f64 / xs.iter().map(|x| 1.0 / x).sum::<f64>()
}

/// Pearson correlation coefficient.
pub fn pearson(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len() as f64;
    let ma = a.iter().sum::<f64>() / n;
    let mb = b.iter().sum::<f64>() / n;
    let mut cov = 0.0;
    let mut va = 0.0;
    let mut vb = 0.0;
    for (x, y) in a.iter().zip(b) {
        cov += (x - ma) * (y - mb);
        va += (x - ma) * (x - ma);
        vb += (y - mb) * (y - mb);
    }
    cov / (va.sqrt() * vb.sqrt()).max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.p50 - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert!((percentile_sorted(&v, 50.0) - 5.0).abs() < 1e-12);
        assert!((percentile_sorted(&v, 0.0) - 0.0).abs() < 1e-12);
        assert!((percentile_sorted(&v, 100.0) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn mape_zero_for_exact() {
        assert_eq!(mape(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert!((mape(&[1.1], &[1.0]) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn geomean_of_powers() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_perfect() {
        let a = [1.0, 2.0, 3.0];
        let b = [2.0, 4.0, 6.0];
        assert!((pearson(&a, &b) - 1.0).abs() < 1e-9);
        let c = [3.0, 2.0, 1.0];
        assert!((pearson(&a, &c) + 1.0).abs() < 1e-9);
    }

    #[test]
    fn harmonic_mean_basic() {
        assert!((harmonic_mean(&[1.0, 1.0]) - 1.0).abs() < 1e-12);
        assert!((harmonic_mean(&[2.0, 6.0]) - 3.0).abs() < 1e-12);
    }
}
