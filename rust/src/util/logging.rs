//! Minimal logging backend writing to stderr with timestamps relative to
//! process start, installed into the in-crate [`crate::log`] facade.
//! Controlled by `HETRL_LOG` (error|warn|info|debug|trace).

use crate::log::{self, Level, LevelFilter, Metadata, Record};
use std::sync::Once;
use std::time::Instant;

struct StderrLogger {
    start: Instant,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record<'_>) {
        let t = self.start.elapsed().as_secs_f64();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{t:9.3}s {lvl} {}] {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

static INIT: Once = Once::new();

/// Install the logger (idempotent). Level from `HETRL_LOG`, default `info`.
pub fn init() {
    INIT.call_once(|| {
        // detlint:allow(D4): log verbosity only — never feeds search or plan selection
        let level = match std::env::var("HETRL_LOG").as_deref() {
            Ok("error") => LevelFilter::Error,
            Ok("warn") => LevelFilter::Warn,
            Ok("debug") => LevelFilter::Debug,
            Ok("trace") => LevelFilter::Trace,
            Ok("off") => LevelFilter::Off,
            _ => LevelFilter::Info,
        };
        let logger = Box::new(StderrLogger { start: Instant::now() });
        if log::set_boxed_logger(logger).is_ok() {
            log::set_max_level(level);
        }
    });
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        crate::log::info!("logging smoke test");
    }
}
