//! Unit helpers. The cost model mixes TFLOPS, GB, GB/s, Gbps and
//! milliseconds; converting consistently to SI base units (FLOP/s, bytes,
//! bytes/s, seconds) at the boundary avoids an entire class of bugs.

/// 1 TFLOP/s in FLOP/s.
pub const TFLOPS: f64 = 1e12;
/// 1 GiB in bytes (GPU memory sizes are marketed in GB but allocated in GiB;
/// we follow the paper's Table 1 and use binary GiB for capacities).
pub const GIB: f64 = 1024.0 * 1024.0 * 1024.0;
/// 1 GB/s in bytes/s (HBM and NVLink bandwidths are decimal).
pub const GBPS_BYTES: f64 = 1e9;
/// 1 Gbit/s in bytes/s (network bandwidths are decimal bits).
pub const GBITPS_BYTES: f64 = 1e9 / 8.0;
/// 1 millisecond in seconds.
pub const MS: f64 = 1e-3;

/// Bytes of a BF16 scalar.
pub const B_BF16: f64 = 2.0;
/// Bytes of an FP32 scalar.
pub const B_FP32: f64 = 4.0;

/// Pretty-print a duration in seconds with adaptive units.
pub fn fmt_secs(s: f64) -> String {
    if s >= 60.0 {
        format!("{:.1}m", s / 60.0)
    } else if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.2}us", s * 1e6)
    } else {
        format!("{:.0}ns", s * 1e9)
    }
}

/// Pretty-print a byte count.
pub fn fmt_bytes(b: f64) -> String {
    if b >= GIB {
        format!("{:.2}GiB", b / GIB)
    } else if b >= 1024.0 * 1024.0 {
        format!("{:.2}MiB", b / (1024.0 * 1024.0))
    } else if b >= 1024.0 {
        format!("{:.2}KiB", b / 1024.0)
    } else {
        format!("{b:.0}B")
    }
}

/// Pretty-print a throughput in samples/s.
pub fn fmt_throughput(x: f64) -> String {
    if x >= 1000.0 {
        format!("{:.2}k/s", x / 1000.0)
    } else {
        format!("{x:.2}/s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversions() {
        assert_eq!(10.0 * MS, 0.01);
        assert_eq!(GBITPS_BYTES * 8.0, GBPS_BYTES);
        assert!((312.0 * TFLOPS - 3.12e14).abs() < 1.0);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_secs(90.0), "1.5m");
        assert_eq!(fmt_secs(2.0), "2.00s");
        assert_eq!(fmt_secs(0.002), "2.00ms");
        assert_eq!(fmt_bytes(GIB * 2.0), "2.00GiB");
        assert_eq!(fmt_throughput(1500.0), "1.50k/s");
    }
}
