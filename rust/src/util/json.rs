//! Minimal JSON parser + writer (no serde offline). Used for the config
//! system, the artifact manifest produced by `python/compile/aot.py`, and
//! metric dumps consumed by EXPERIMENTS.md tooling.
//!
//! Supports the full JSON grammar minus `\u` surrogate pairs (accepted,
//! decoded as the raw code unit) — sufficient for our ASCII artifacts.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` so serialization is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { src: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.src.len() {
            return Err(p.err("trailing content"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().and_then(|x| if x.fract() == 0.0 { Some(x as i64) } else { None })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj["key"]` access; returns `Json::Null` when missing.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(m) => m.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Index into an array; `Json::Null` out of bounds.
    pub fn at(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(v) => v.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    // -- construction helpers ---------------------------------------------

    pub fn obj(entries: Vec<(&str, Json)>) -> Json {
        Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    /// Serialize compactly.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(*x, out),
            Json::Str(s) => write_str(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    e.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    e.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    write_str(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

fn write_num(x: f64, out: &mut String) {
    if x.fract() == 0.0 && x.abs() < 1e15 {
        out.push_str(&format!("{}", x as i64));
    } else {
        out.push_str(&format!("{x}"));
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    src: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.pos, message: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.src[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or ']'"));
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or '}'"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (d as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Re-decode multibyte UTF-8: back up and take the char.
                    let start = self.pos - 1;
                    let rest = std::str::from_utf8(&self.src[start..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = rest.chars().next().unwrap();
                    self.pos = start + ch.len_utf8();
                    let _ = c;
                    s.push(ch);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.src[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -3.5e2 ").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(v.get("a").at(2).get("b").as_str(), Some("x"));
        assert_eq!(v.get("c").as_bool(), Some(false));
        assert_eq!(v.get("missing"), &Json::Null);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,"s"],"b":{"c":null,"d":true},"e":-7}"#;
        let v = Json::parse(src).unwrap();
        let dumped = v.dump();
        assert_eq!(Json::parse(&dumped).unwrap(), v);
    }

    #[test]
    fn roundtrip_unicode_and_escapes() {
        let v = Json::Str("héllo \"q\" \\ \n π".to_string());
        assert_eq!(Json::parse(&v.dump()).unwrap(), v);
    }

    #[test]
    fn errors() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("[1,").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("[1] trailing").is_err());
    }

    #[test]
    fn pretty_is_reparsable() {
        let v = Json::obj(vec![
            ("xs", Json::arr([Json::num(1.0), Json::num(2.0)])),
            ("name", Json::str("hetrl")),
        ]);
        assert_eq!(Json::parse(&v.pretty()).unwrap(), v);
    }
}
