//! ASCII table rendering for bench output: every figure/table bench prints
//! the same rows/series the paper reports, via this module.

/// A simple left/right-aligned ASCII table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row arity mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for i in 0..ncols {
                // First column left-aligned, the rest right-aligned
                if i == 0 {
                    s.push_str(&format!(" {:<width$} |", cells[i], width = widths[i]));
                } else {
                    s.push_str(&format!(" {:>width$} |", cells[i], width = widths[i]));
                }
            }
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("## {}\n", self.title));
        }
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }

    /// CSV form (for dumping to `results/`).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format helper: `3.17x`.
pub fn speedup(x: f64) -> String {
    format!("{x:.2}x")
}

/// Format helper: fixed decimals.
pub fn f(x: f64, decimals: usize) -> String {
    format!("{x:.decimals$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(vec!["alpha".into(), "1".into()]);
        t.row(vec!["b".into(), "12345".into()]);
        let r = t.render();
        assert!(r.contains("| name  | value |"));
        assert!(r.contains("| alpha |     1 |"));
        assert!(r.contains("| b     | 12345 |"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["has,comma".into(), "has\"quote".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"has,comma\""));
        assert!(csv.contains("\"has\"\"quote\""));
    }
}
