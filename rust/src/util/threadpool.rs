//! Fixed-size work-stealing-free threadpool built on std channels.
//! Used by the scheduler to evaluate candidate plans in parallel and by
//! the engine to run worker groups. (tokio is unavailable offline; the
//! coordinator's concurrency needs are CPU-bound fan-out/fan-in, which a
//! plain pool serves well.)

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed pool of worker threads executing boxed closures.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `n` workers (`n >= 1`).
    pub fn new(n: usize) -> Self {
        assert!(n >= 1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("hetrl-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                // A panicking candidate evaluation must not
                                // take down the pool.
                                let _ = catch_unwind(AssertUnwindSafe(job));
                            }
                            Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers }
    }

    /// Submit a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker channel closed");
    }

    /// Map `f` over `items` in parallel, preserving order.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let (rtx, rrx): (Sender<(usize, R)>, Receiver<(usize, R)>) = channel();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let rtx = rtx.clone();
            self.execute(move || {
                let r = f(item);
                let _ = rtx.send((i, r));
            });
        }
        drop(rtx);
        let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, r) = rrx.recv().expect("worker died before producing result");
            slots[i] = Some(r);
        }
        slots.into_iter().map(|s| s.expect("missing result")).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Run `f` over `items` with a transient pool of `threads` workers.
pub fn parallel_map<T, R, F>(threads: usize, items: Vec<T>, f: F) -> Vec<R>
where
    T: Send + 'static,
    R: Send + 'static,
    F: Fn(T) -> R + Send + Sync + 'static,
{
    if threads <= 1 || items.len() <= 1 {
        return items.into_iter().map(f).collect();
    }
    let pool = ThreadPool::new(threads.min(items.len()));
    pool.map(items, f)
}

/// [`parallel_map`] over *borrowed* state: maps `f` across `items` on up
/// to `threads` scoped workers (`std::thread::scope`), preserving input
/// order in the result. Unlike [`ThreadPool`], closures may borrow from
/// the caller's stack (no `'static` bound) — this is what the scheduler's
/// parallel evaluation engine runs its rungs on. Items are pulled from a
/// shared queue, so uneven per-item work self-balances. Falls back to an
/// inline sequential map (bit-identical results) for `threads <= 1` or a
/// single item.
pub fn scoped_map<T, R, F>(threads: usize, items: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    if threads <= 1 || items.len() <= 1 {
        return items.into_iter().map(f).collect();
    }
    let n = items.len();
    let queue: Mutex<Vec<(usize, T)>> = Mutex::new(items.into_iter().enumerate().rev().collect());
    let slots: Mutex<Vec<Option<R>>> = Mutex::new((0..n).map(|_| None).collect());
    let f = &f;
    std::thread::scope(|s| {
        for _ in 0..threads.min(n) {
            s.spawn(|| loop {
                let item = queue.lock().unwrap().pop();
                match item {
                    Some((i, t)) => {
                        let r = f(t);
                        slots.lock().unwrap()[i] = Some(r);
                    }
                    None => break,
                }
            });
        }
    });
    slots
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|s| s.expect("scoped_map: missing result"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(4);
        let out = pool.map((0..100).collect::<Vec<usize>>(), |x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(3);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..50 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join workers
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn survives_panicking_job() {
        let pool = ThreadPool::new(2);
        pool.execute(|| panic!("boom"));
        let out = pool.map(vec![1, 2, 3], |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn parallel_map_sequential_fallback() {
        let out = parallel_map(1, vec![1, 2, 3], |x| x * x);
        assert_eq!(out, vec![1, 4, 9]);
    }

    #[test]
    fn scoped_map_borrows_and_preserves_order() {
        let offset = 10usize; // borrowed, not 'static
        let items: Vec<usize> = (0..64).collect();
        let seq = scoped_map(1, items.clone(), |x| x + offset);
        let par = scoped_map(4, items, |x| x + offset);
        assert_eq!(seq, par);
        assert_eq!(par[0], 10);
        assert_eq!(par[63], 73);
    }

    #[test]
    fn scoped_map_single_item_inline() {
        let out = scoped_map(8, vec![5], |x: usize| x * 2);
        assert_eq!(out, vec![10]);
    }
}
