//! Total-order float comparisons ("ford" = float ordering).
//!
//! The schedulers sort and select by cost-model outputs everywhere, and
//! the idiomatic `a.partial_cmp(&b).unwrap()` comparator panics the
//! moment a degraded cost model produces a NaN — inside a rayon-free
//! but still multi-threaded rung, taking the whole search down.
//! [`cmp_f64`] is the crate-wide replacement: a total order over *all*
//! `f64` values (detlint rule **D3** bans NaN-unsafe comparators and
//! points here).
//!
//! The order is IEEE 754 `totalOrder` (via [`f64::total_cmp`]):
//!
//! ```text
//! -NaN < -inf < ... < -0.0 < +0.0 < ... < +inf < +NaN
//! ```
//!
//! Two properties matter for the determinism contract:
//!
//! * it never panics and never returns "unordered", so sorts and
//!   `min_by`/`max_by` selections are well-defined on degraded inputs;
//! * positive NaN ranks *after* `+inf`, so when ascending cost picks a
//!   minimum, a NaN-costed candidate loses to every real candidate.

use std::cmp::Ordering;

/// Total-order comparison of two `f64`s; see the module docs for the
/// exact order. Drop-in for `a.partial_cmp(&b).unwrap()` in comparators.
#[inline]
pub fn cmp_f64(a: f64, b: f64) -> Ordering {
    a.total_cmp(&b)
}

/// Sort a slice ascending under [`cmp_f64`] (NaNs sort last, never
/// panic). Drop-in for `xs.sort_by(|a, b| a.partial_cmp(b).unwrap())`.
pub fn sort_f64(xs: &mut [f64]) {
    xs.sort_by(|a, b| cmp_f64(*a, *b));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn agrees_with_partial_cmp_on_ordinary_values() {
        let vals = [-3.5, -1.0, 0.5, 1.0, 2.0, 1e300, -1e300];
        for &a in &vals {
            for &b in &vals {
                // detlint:allow(D3): the NaN-unsafe idiom is the reference under test
                assert_eq!(cmp_f64(a, b), a.partial_cmp(&b).unwrap(), "{a} vs {b}");
            }
        }
    }

    #[test]
    fn nan_orders_after_infinity() {
        assert_eq!(cmp_f64(f64::NAN, f64::INFINITY), Ordering::Greater);
        assert_eq!(cmp_f64(f64::INFINITY, f64::NAN), Ordering::Less);
        assert_eq!(cmp_f64(f64::NAN, f64::NAN), Ordering::Equal);
        // Negative NaN sits at the very bottom of the order.
        assert_eq!(cmp_f64(-f64::NAN, f64::NEG_INFINITY), Ordering::Less);
    }

    #[test]
    fn signed_zero_is_ordered() {
        assert_eq!(cmp_f64(-0.0, 0.0), Ordering::Less);
        assert_eq!(cmp_f64(0.0, -0.0), Ordering::Greater);
        assert_eq!(cmp_f64(0.0, 0.0), Ordering::Equal);
    }

    #[test]
    fn sort_with_nans_never_panics_and_ranks_them_last() {
        let mut xs = vec![2.0, f64::NAN, -1.0, f64::INFINITY, 0.0, f64::NEG_INFINITY];
        sort_f64(&mut xs);
        assert_eq!(xs[0], f64::NEG_INFINITY);
        assert_eq!(xs[1], -1.0);
        assert_eq!(xs[2], 0.0);
        assert_eq!(xs[3], 2.0);
        assert_eq!(xs[4], f64::INFINITY);
        assert!(xs[5].is_nan());
    }

    #[test]
    fn min_selection_prefers_real_costs_over_nan() {
        // Ascending-cost selection must never pick a NaN-costed
        // candidate over a finite one.
        let costs = [f64::NAN, 3.0, 7.0];
        let best = costs.iter().copied().min_by(|a, b| cmp_f64(*a, *b)).unwrap();
        assert_eq!(best, 3.0);
    }

    #[test]
    fn total_order_is_antisymmetric_on_mixed_inputs() {
        let vals = [f64::NAN, -f64::NAN, f64::INFINITY, -0.0, 0.0, 1.5, -2.5];
        for &a in &vals {
            for &b in &vals {
                assert_eq!(cmp_f64(a, b), cmp_f64(b, a).reverse());
            }
        }
    }
}
