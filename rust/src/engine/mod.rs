//! Execution engine: the part of HetRL that actually *runs* RL training
//! against the AOT-compiled model, entirely from rust (verl-equivalent
//! role; Megatron/vLLM are replaced by PJRT executables + the in-crate
//! samplers).
//!
//! * [`tokenizer`] — char-level tokenizer for the arithmetic tasks;
//! * [`dataset`] — synthetic GSM8K-like / MATH-like problem generators
//!   with rule-based exact-answer rewards;
//! * [`policy`] — model state (params/optimizer) + sampling on top of
//!   the [`crate::runtime::Runtime`];
//! * [`grpo`] — the GRPO training loop (rollout → reward → advantage →
//!   AOT train step → weight sync);
//! * [`workers`] — heterogeneity-scaled worker-group accounting used by
//!   the Figures 8/9 hetero-vs-homo wall-clock comparison, including
//!   sequence-length-aware sample routing (the engine-level load
//!   balancing strategy of §4.2).

pub mod tokenizer;
pub mod dataset;
pub mod policy;
pub mod grpo;
pub mod workers;

pub use dataset::{Problem, TaskDifficulty};
pub use grpo::{GrpoConfig, GrpoStats, GrpoTrainer};
pub use policy::Policy;
pub use tokenizer::Tokenizer;
pub use workers::WorkerFleet;
