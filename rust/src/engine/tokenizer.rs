//! Char-level tokenizer over a fixed 64-symbol alphabet — matches the
//! `vocab=64` the artifacts are compiled with.

/// Special token ids.
pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;

/// Printable alphabet starting at id 3.
const ALPHABET: &str = "0123456789+-*/=() .,:?abcdefghijklmnopqrstuvwxyzABCDEFGHIJK";

/// Char-level tokenizer.
#[derive(Debug, Clone)]
pub struct Tokenizer {
    chars: Vec<char>,
}

impl Default for Tokenizer {
    fn default() -> Self {
        Self::new()
    }
}

impl Tokenizer {
    pub fn new() -> Tokenizer {
        let chars: Vec<char> = ALPHABET.chars().collect();
        assert!(chars.len() + 3 <= 64, "alphabet must fit vocab 64");
        Tokenizer { chars }
    }

    pub fn vocab_size(&self) -> usize {
        64
    }

    pub fn encode_char(&self, c: char) -> Option<i32> {
        self.chars.iter().position(|&x| x == c).map(|i| i as i32 + 3)
    }

    /// Encode text (unknown chars are skipped), without BOS/EOS.
    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.chars().filter_map(|c| self.encode_char(c)).collect()
    }

    /// Decode ids, stopping at EOS, skipping PAD/BOS.
    pub fn decode(&self, ids: &[i32]) -> String {
        let mut s = String::new();
        for &id in ids {
            if id == EOS {
                break;
            }
            if id == PAD || id == BOS {
                continue;
            }
            let idx = (id - 3) as usize;
            if idx < self.chars.len() {
                s.push(self.chars[idx]);
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let t = Tokenizer::new();
        let text = "12+34=46";
        let ids = t.encode(text);
        assert_eq!(t.decode(&ids), text);
    }

    #[test]
    fn specials_not_in_alphabet() {
        let t = Tokenizer::new();
        for c in "0123456789+-*= ".chars() {
            let id = t.encode_char(c).unwrap();
            assert!(id >= 3);
        }
    }

    #[test]
    fn decode_stops_at_eos() {
        let t = Tokenizer::new();
        let mut ids = t.encode("42");
        ids.push(EOS);
        ids.extend(t.encode("junk"));
        assert_eq!(t.decode(&ids), "42");
    }

    #[test]
    fn vocab_fits() {
        let t = Tokenizer::new();
        let max = t.encode(ALPHABET).into_iter().max().unwrap();
        assert!(max < 64);
    }
}
