//! Synthetic reasoning datasets with exact-answer rewards.
//!
//! Substitution for GSM8K / MATH-500 (DESIGN.md §2): arithmetic word
//! problems with a rule-based verifier — the same binary
//! exact-match-on-extracted-number reward structure the paper's GSM8K
//! workload uses.

use super::tokenizer::Tokenizer;
use crate::util::rng::Rng;

/// Difficulty tiers: `Easy` ≈ GSM8K-like 2-term arithmetic, `Hard` ≈
/// MATH-like multi-step expressions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskDifficulty {
    Easy,
    Hard,
}

/// One problem: prompt text and the gold answer string.
#[derive(Debug, Clone, PartialEq)]
pub struct Problem {
    pub prompt: String,
    pub answer: String,
}

/// Deterministic problem generator.
#[derive(Debug)]
pub struct ProblemGen {
    rng: Rng,
    pub difficulty: TaskDifficulty,
}

impl ProblemGen {
    pub fn new(seed: u64, difficulty: TaskDifficulty) -> ProblemGen {
        ProblemGen { rng: Rng::new(seed), difficulty }
    }

    pub fn next(&mut self) -> Problem {
        match self.difficulty {
            TaskDifficulty::Easy => {
                let a = self.rng.range(2, 50) as i64;
                let b = self.rng.range(2, 50) as i64;
                if self.rng.chance(0.5) {
                    Problem {
                        prompt: format!("{a}+{b}="),
                        answer: format!("{}", a + b),
                    }
                } else {
                    let (hi, lo) = (a.max(b), a.min(b));
                    Problem {
                        prompt: format!("{hi}-{lo}="),
                        answer: format!("{}", hi - lo),
                    }
                }
            }
            TaskDifficulty::Hard => {
                let a = self.rng.range(2, 12) as i64;
                let b = self.rng.range(2, 12) as i64;
                let c = self.rng.range(2, 30) as i64;
                if self.rng.chance(0.5) {
                    Problem {
                        prompt: format!("{a}*{b}+{c}="),
                        answer: format!("{}", a * b + c),
                    }
                } else {
                    Problem {
                        prompt: format!("{a}*{b}-{c}="),
                        answer: format!("{}", a * b - c),
                    }
                }
            }
        }
    }

    pub fn batch(&mut self, n: usize) -> Vec<Problem> {
        (0..n).map(|_| self.next()).collect()
    }
}

/// Rule-based verifier standing in for GSM8K's extract-and-match
/// scoring, with partial credit so the tiny-model substrate has a dense
/// learning signal (documented in DESIGN.md §2):
/// * 1.0 — extracted number equals the gold answer;
/// * up to 0.3 — correct leading digits (prefix match fraction);
/// * 0.02 — output at least starts with a digit;
/// * 0.0 — otherwise.
pub fn reward(problem: &Problem, generated: &str) -> f64 {
    let cleaned: String = generated
        .chars()
        .take_while(|c| c.is_ascii_digit() || *c == '-')
        .collect();
    if cleaned == problem.answer {
        return 1.0;
    }
    let prefix = cleaned
        .chars()
        .zip(problem.answer.chars())
        .take_while(|(a, b)| a == b)
        .count();
    if prefix > 0 {
        return 0.3 * prefix as f64 / problem.answer.len().max(1) as f64;
    }
    if generated
        .chars()
        .next()
        .map(|c| c.is_ascii_digit() || c == '-')
        .unwrap_or(false)
    {
        0.02
    } else {
        0.0
    }
}

/// Strict exact-match accuracy (used by evaluation, not training).
pub fn exact_match(problem: &Problem, generated: &str) -> bool {
    reward(problem, generated) >= 1.0
}

/// Encode a prompt for the fixed-width model input: BOS + prompt tokens.
pub fn encode_prompt(tok: &Tokenizer, p: &Problem) -> Vec<i32> {
    let mut ids = vec![super::tokenizer::BOS];
    ids.extend(tok.encode(&p.prompt));
    ids
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn answers_are_correct() {
        let mut g = ProblemGen::new(1, TaskDifficulty::Easy);
        for _ in 0..50 {
            let p = g.next();
            // Parse "a+b=" or "a-b=" and verify.
            let body = p.prompt.trim_end_matches('=');
            let (op_idx, op) = body
                .char_indices()
                .skip(1)
                .find(|(_, c)| *c == '+' || *c == '-')
                .unwrap();
            let a: i64 = body[..op_idx].parse().unwrap();
            let b: i64 = body[op_idx + 1..].parse().unwrap();
            let want = if op == '+' { a + b } else { a - b };
            assert_eq!(p.answer, want.to_string());
        }
    }

    #[test]
    fn hard_problems_multiply() {
        let mut g = ProblemGen::new(2, TaskDifficulty::Hard);
        let p = g.next();
        assert!(p.prompt.contains('*'));
    }

    #[test]
    fn reward_grading() {
        let p = Problem { prompt: "2+2=".into(), answer: "4".into() };
        assert_eq!(reward(&p, "4"), 1.0);
        assert_eq!(reward(&p, "4 junk"), 1.0); // digits prefix matches
        assert!(reward(&p, "5") <= 0.02); // wrong but numeric
        assert_eq!(reward(&p, "x"), 0.0);
        assert_eq!(reward(&p, ""), 0.0);
        // Partial credit: correct leading digit but wrong answer.
        let p2 = Problem { prompt: "10+13=".into(), answer: "23".into() };
        let partial = reward(&p2, "21");
        assert!(partial > 0.02 && partial < 1.0, "{partial}");
        assert!(exact_match(&p2, "23"));
        assert!(!exact_match(&p2, "21"));
    }

    #[test]
    fn deterministic() {
        let a: Vec<Problem> = ProblemGen::new(7, TaskDifficulty::Easy).batch(5);
        let b: Vec<Problem> = ProblemGen::new(7, TaskDifficulty::Easy).batch(5);
        assert_eq!(a, b);
    }

    #[test]
    fn prompts_tokenizable() {
        let tok = Tokenizer::new();
        let mut g = ProblemGen::new(3, TaskDifficulty::Hard);
        for _ in 0..20 {
            let p = g.next();
            let ids = encode_prompt(&tok, &p);
            assert!(ids.len() >= 4);
            // decode(encode(prompt)) == prompt
            assert_eq!(tok.decode(&ids[1..]), p.prompt);
        }
    }
}
