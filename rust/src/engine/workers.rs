//! Heterogeneity-scaled worker-group accounting.
//!
//! The real engine executes on one CPU PJRT client; heterogeneous
//! *wall-clock* behaviour (Figures 8/9: same convergence per step,
//! faster per hour with more aggregate hardware) is modeled by scaling
//! measured execution time by the fleet's aggregate speed. This module
//! also hosts the engine-level load-balancing strategy from §4.2:
//! sequence-length-aware sample routing (longest sequences to the
//! fastest workers).

/// One homogeneous worker group (e.g. "8×A100").
#[derive(Debug, Clone)]
pub struct WorkerGroup {
    pub name: String,
    /// Relative per-worker speed (1.0 = reference GPU).
    pub speed: f64,
    pub count: usize,
}

/// A fleet of worker groups with a virtual clock.
#[derive(Debug, Clone)]
pub struct WorkerFleet {
    pub groups: Vec<WorkerGroup>,
    /// Accumulated virtual wall-clock (seconds).
    pub virtual_time: f64,
}

impl WorkerFleet {
    pub fn new(groups: Vec<WorkerGroup>) -> WorkerFleet {
        assert!(!groups.is_empty());
        WorkerFleet { groups, virtual_time: 0.0 }
    }

    /// `n` identical reference workers.
    pub fn homogeneous(n: usize) -> WorkerFleet {
        WorkerFleet::new(vec![WorkerGroup {
            name: format!("{n}x reference"),
            speed: 1.0,
            count: n,
        }])
    }

    /// The paper's mixed fleet shape: reference GPUs plus slower and
    /// faster tiers (relative speeds follow Table 1 effective FLOPs).
    pub fn heterogeneous_default() -> WorkerFleet {
        WorkerFleet::new(vec![
            WorkerGroup { name: "3x A100".into(), speed: 1.0, count: 3 },
            WorkerGroup { name: "3x L40S".into(), speed: 0.93, count: 3 },
            WorkerGroup { name: "2x L4".into(), speed: 0.28, count: 2 },
        ])
    }

    /// Aggregate throughput in reference-worker units.
    pub fn throughput(&self) -> f64 {
        self.groups.iter().map(|g| g.speed * g.count as f64).sum()
    }

    pub fn n_workers(&self) -> usize {
        self.groups.iter().map(|g| g.count).sum()
    }

    /// Account a data-parallel phase measured at `real_secs` on the
    /// reference worker: virtual duration = real / aggregate-throughput.
    pub fn account_parallel(&mut self, real_secs: f64) -> f64 {
        let t = real_secs / self.throughput().max(1e-9);
        self.virtual_time += t;
        t
    }

    /// Account a serial phase (e.g. weight sync) that does not scale.
    pub fn account_serial(&mut self, real_secs: f64) -> f64 {
        self.virtual_time += real_secs;
        real_secs
    }

    /// Sequence-length-aware routing (§4.2 data-level balancing at the
    /// engine level): assign each sample to a worker group, longest
    /// samples to the fastest groups, filling proportionally to group
    /// capacity. Returns group index per sample.
    pub fn route_by_length(&self, lengths: &[usize]) -> Vec<usize> {
        let n = lengths.len();
        // Capacity per group ∝ speed·count.
        let total: f64 = self.throughput();
        let mut capacity: Vec<usize> = self
            .groups
            .iter()
            .map(|g| ((g.speed * g.count as f64) / total * n as f64).round() as usize)
            .collect();
        // Fix rounding to sum exactly n.
        let n_groups = capacity.len();
        let mut diff = n as i64 - capacity.iter().sum::<usize>() as i64;
        let mut gi = 0;
        while diff != 0 {
            let idx = gi % n_groups;
            if diff > 0 {
                capacity[idx] += 1;
                diff -= 1;
            } else if capacity[idx] > 0 {
                capacity[idx] -= 1;
                diff += 1;
            }
            gi += 1;
        }
        // Sort samples by length desc; groups by speed desc.
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by_key(|&i| std::cmp::Reverse(lengths[i]));
        let mut group_order: Vec<usize> = (0..self.groups.len()).collect();
        group_order.sort_by(|&a, &b| {
            crate::util::ford::cmp_f64(self.groups[b].speed, self.groups[a].speed)
        });
        let mut out = vec![0usize; n];
        let mut g_iter = group_order.into_iter();
        let mut cur = g_iter.next().unwrap();
        let mut left = capacity[cur];
        for &i in &order {
            while left == 0 {
                match g_iter.next() {
                    Some(g) => {
                        cur = g;
                        left = capacity[cur];
                    }
                    None => break,
                }
            }
            out[i] = cur;
            left = left.saturating_sub(1);
        }
        out
    }

    /// Imbalance of a routing: max over groups of (assigned work /
    /// group speed) normalized by the ideal. 1.0 = perfectly balanced.
    pub fn routing_imbalance(&self, lengths: &[usize], assignment: &[usize]) -> f64 {
        let mut work = vec![0.0f64; self.groups.len()];
        for (i, &g) in assignment.iter().enumerate() {
            work[g] += lengths[i] as f64;
        }
        let total_work: f64 = lengths.iter().map(|&l| l as f64).sum();
        let ideal = total_work / self.throughput();
        let worst = work
            .iter()
            .zip(&self.groups)
            .map(|(w, g)| w / (g.speed * g.count as f64))
            .fold(0.0f64, f64::max);
        worst / ideal.max(1e-12)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_sums() {
        let f = WorkerFleet::heterogeneous_default();
        assert!((f.throughput() - (3.0 + 2.79 + 0.56)).abs() < 1e-9);
        assert_eq!(f.n_workers(), 8);
    }

    #[test]
    fn bigger_fleet_faster_virtual_clock() {
        let mut small = WorkerFleet::homogeneous(3);
        let mut big = WorkerFleet::heterogeneous_default();
        small.account_parallel(10.0);
        big.account_parallel(10.0);
        assert!(big.virtual_time < small.virtual_time);
    }

    #[test]
    fn routing_covers_all_samples() {
        let f = WorkerFleet::heterogeneous_default();
        let lengths: Vec<usize> = (0..32).map(|i| 16 + (i * 7) % 64).collect();
        let assignment = f.route_by_length(&lengths);
        assert_eq!(assignment.len(), lengths.len());
        assert!(assignment.iter().all(|&g| g < f.groups.len()));
    }

    #[test]
    fn routing_sends_long_to_fast() {
        let f = WorkerFleet::new(vec![
            WorkerGroup { name: "fast".into(), speed: 1.0, count: 2 },
            WorkerGroup { name: "slow".into(), speed: 0.25, count: 2 },
        ]);
        let lengths = vec![100, 10, 90, 20, 80, 30, 70, 40];
        let assignment = f.route_by_length(&lengths);
        // The longest sample goes to the fast group (index 0).
        assert_eq!(assignment[0], 0);
        // The shortest goes to the slow group.
        assert_eq!(assignment[1], 1);
    }

    #[test]
    fn length_aware_beats_round_robin() {
        let f = WorkerFleet::new(vec![
            WorkerGroup { name: "fast".into(), speed: 1.0, count: 2 },
            WorkerGroup { name: "slow".into(), speed: 0.3, count: 2 },
        ]);
        let lengths: Vec<usize> = (0..64).map(|i| 8 + (i * 13) % 120).collect();
        let smart = f.route_by_length(&lengths);
        let rr: Vec<usize> = (0..64).map(|i| i % 2).collect();
        assert!(f.routing_imbalance(&lengths, &smart) <= f.routing_imbalance(&lengths, &rr));
    }
}
