//! Policy state on top of the PJRT runtime: parameters + Adam moments
//! live as host tensors, sampled autoregressively through the `forward`
//! executable, scored through `logprobs`, updated through `grpo_train`.

use crate::runtime::{HostTensor, Runtime};
use crate::util::rng::Rng;
use crate::util::error::Result;

/// Model parameters (+ optional optimizer state).
pub struct Policy {
    pub params: Vec<HostTensor>,
    pub adam_m: Vec<HostTensor>,
    pub adam_v: Vec<HostTensor>,
    pub step: usize,
}

impl Policy {
    /// Initialize from the AOT `init` entry point.
    pub fn init(rt: &Runtime, seed: u64) -> Result<Policy> {
        let seed_t = HostTensor::u32(vec![2], vec![(seed >> 32) as u32, seed as u32]);
        let params = rt.execute("init", &[seed_t])?;
        let zeros: Vec<HostTensor> = params
            .iter()
            .map(|p| HostTensor::f32(p.shape().to_vec(), vec![0.0; p.shape().iter().product()]))
            .collect();
        Ok(Policy {
            adam_m: zeros.clone(),
            adam_v: zeros,
            params,
            step: 0,
        })
    }

    /// Deep copy (reference policy snapshot / generation-side weights).
    pub fn snapshot_params(&self) -> Vec<HostTensor> {
        self.params.clone()
    }

    /// Bytes moved when synchronizing weights to a generation worker.
    pub fn weight_bytes(&self) -> usize {
        self.params
            .iter()
            .map(|p| p.shape().iter().product::<usize>() * 4)
            .sum()
    }
}

/// Batched autoregressive sampler over the fixed-shape `forward`
/// executable. Token buffers are `[B, max_len]`, padded with PAD.
pub struct Sampler<'a> {
    pub rt: &'a Runtime,
    pub temperature: f64,
}

impl<'a> Sampler<'a> {
    pub fn new(rt: &'a Runtime, temperature: f64) -> Sampler<'a> {
        Sampler { rt, temperature }
    }

    /// Generate up to `max_new` tokens for each prompt (right-padded
    /// buffers). Returns (tokens `[B, L]` flat, per-sample lengths).
    ///
    /// `params` are the *generation-side* weights (weight sync hands a
    /// snapshot over). Sampling is greedy at temperature 0.
    pub fn generate(
        &self,
        params: &[HostTensor],
        prompts: &[Vec<i32>],
        max_new: usize,
        rng: &mut Rng,
    ) -> Result<(Vec<i32>, Vec<usize>)> {
        let b = self.rt.manifest.batch;
        let l = self.rt.model().max_len;
        let v = self.rt.model().vocab;
        assert_eq!(prompts.len(), b, "sampler is compiled for batch {b}");
        let mut buf = vec![super::tokenizer::PAD; b * l];
        let mut lens: Vec<usize> = Vec::with_capacity(b);
        for (i, p) in prompts.iter().enumerate() {
            assert!(p.len() + max_new <= l, "prompt too long");
            buf[i * l..i * l + p.len()].copy_from_slice(p);
            lens.push(p.len());
        }
        let mut done = vec![false; b];
        // §Perf L3-3: parameters are converted to XLA literals once and
        // reused across the whole decode loop (PJRT-CPU buffer donation
        // rules out keeping them as device buffers — see runtime docs).
        let device_params = self.rt.upload(params)?;
        for _ in 0..max_new {
            if done.iter().all(|&d| d) {
                break;
            }
            let tokens = HostTensor::i32(vec![b, l], buf.clone());
            let out = self.rt.execute_prepared("forward", &device_params, &[tokens])?;
            let logits = out[0].as_f32()?;
            for i in 0..b {
                if done[i] {
                    continue;
                }
                let pos = lens[i] - 1;
                let row = &logits[(i * l + pos) * v..(i * l + pos + 1) * v];
                let next = self.sample_token(row, rng);
                buf[i * l + lens[i]] = next;
                lens[i] += 1;
                if next == super::tokenizer::EOS || lens[i] >= l {
                    done[i] = true;
                }
            }
        }
        Ok((buf, lens))
    }

    fn sample_token(&self, logits: &[f32], rng: &mut Rng) -> i32 {
        if self.temperature <= 1e-6 {
            let mut best = 0;
            for (i, &x) in logits.iter().enumerate() {
                if x > logits[best] {
                    best = i;
                }
            }
            return best as i32;
        }
        // softmax with temperature
        let t = self.temperature as f32;
        let max = logits.iter().cloned().fold(f32::MIN, f32::max);
        let weights: Vec<f64> = logits
            .iter()
            .map(|&x| (((x - max) / t) as f64).exp())
            .collect();
        rng.weighted(&weights) as i32
    }
}

/// Score token log-probs via the `logprobs` executable:
/// output `[B, L-1]`, entry t = log p(tokens[t+1] | ..).
pub fn score_logprobs(
    rt: &Runtime,
    params: &[HostTensor],
    tokens_flat: &[i32],
) -> Result<Vec<f32>> {
    let b = rt.manifest.batch;
    let l = rt.model().max_len;
    let mut inputs: Vec<HostTensor> = params.to_vec();
    inputs.push(HostTensor::i32(vec![b, l], tokens_flat.to_vec()));
    let out = rt.execute("logprobs", &inputs)?;
    Ok(out[0].as_f32()?.to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Option<Runtime> {
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        Some(Runtime::load("artifacts").unwrap())
    }

    #[test]
    fn init_deterministic_per_seed() {
        let Some(rt) = runtime() else { return };
        let a = Policy::init(&rt, 7).unwrap();
        let b = Policy::init(&rt, 7).unwrap();
        let c = Policy::init(&rt, 8).unwrap();
        // index 2 = l0.wq, a randomly-initialized matrix (index 1 is a
        // norm gain initialized to ones for every seed).
        assert_eq!(a.params[2], b.params[2]);
        assert_ne!(a.params[2], c.params[2]);
        assert!(a.weight_bytes() > 1_000_000);
    }

    #[test]
    fn generation_appends_tokens() {
        let Some(rt) = runtime() else { return };
        let policy = Policy::init(&rt, 1).unwrap();
        let tok = super::super::tokenizer::Tokenizer::new();
        let b = rt.manifest.batch;
        let prompt = super::super::dataset::encode_prompt(
            &tok,
            &super::super::dataset::Problem {
                prompt: "1+2=".into(),
                answer: "3".into(),
            },
        );
        let prompts = vec![prompt.clone(); b];
        let sampler = Sampler::new(&rt, 1.0);
        let mut rng = Rng::new(3);
        let (buf, lens) = sampler.generate(&policy.params, &prompts, 8, &mut rng).unwrap();
        for (i, &len) in lens.iter().enumerate() {
            assert!(len > prompt.len(), "sample {i} generated nothing");
            assert!(len <= rt.model().max_len);
            // prompt preserved
            let l = rt.model().max_len;
            assert_eq!(&buf[i * l..i * l + prompt.len()], prompt.as_slice());
        }
    }

    #[test]
    fn logprob_scores_are_negative() {
        let Some(rt) = runtime() else { return };
        let policy = Policy::init(&rt, 1).unwrap();
        let b = rt.manifest.batch;
        let l = rt.model().max_len;
        let tokens = vec![3i32; b * l];
        let lp = score_logprobs(&rt, &policy.params, &tokens).unwrap();
        assert_eq!(lp.len(), b * (l - 1));
        assert!(lp.iter().all(|&x| x <= 1e-5 && x.is_finite()));
    }
}
