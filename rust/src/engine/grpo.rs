//! The GRPO training loop: rollout (generation workers) → rule-based
//! reward → group-normalized advantages → AOT `grpo_train` step →
//! weight sync back to the generation side. This is the real end-to-end
//! path: every model execution goes through PJRT, python never runs.

use super::dataset::{encode_prompt, reward, Problem, ProblemGen, TaskDifficulty};
use super::policy::{score_logprobs, Policy, Sampler};
use super::tokenizer::Tokenizer;
use super::workers::WorkerFleet;
use crate::runtime::{HostTensor, Runtime};
use crate::util::rng::Rng;
use crate::util::error::Result;
use std::time::Instant;

/// Trainer configuration.
#[derive(Debug, Clone)]
pub struct GrpoConfig {
    /// Responses per prompt (the GRPO group). Must divide the AOT batch.
    pub group_size: usize,
    /// New tokens per rollout.
    pub max_new: usize,
    pub temperature: f64,
    pub difficulty: TaskDifficulty,
    pub seed: u64,
    /// Expert injection: replace the last response of each GRPO group
    /// with the gold answer (reward 1 ⇒ positive within-group advantage
    /// ⇒ imitation gradient). Standard trick for cold-starting tiny
    /// policies whose random rollouts never hit the sparse reward; the
    /// group-normalized advantage anneals it away automatically once
    /// sampled responses start scoring.
    pub expert_inject: bool,
}

impl Default for GrpoConfig {
    fn default() -> Self {
        GrpoConfig {
            group_size: 4,
            max_new: 12,
            temperature: 1.0,
            difficulty: TaskDifficulty::Easy,
            seed: 0x6EED,
            expert_inject: true,
        }
    }
}

/// Per-step statistics.
#[derive(Debug, Clone)]
pub struct GrpoStats {
    pub step: usize,
    pub mean_reward: f64,
    pub loss: f64,
    pub kl: f64,
    /// Real wall-clock of the step (seconds).
    pub wall: f64,
    /// Virtual wall-clock on the configured fleet.
    pub virtual_wall: f64,
    pub rollout_secs: f64,
    pub train_secs: f64,
    pub sync_bytes: usize,
}

/// GRPO trainer over one runtime.
pub struct GrpoTrainer<'a> {
    pub rt: &'a Runtime,
    pub cfg: GrpoConfig,
    pub policy: Policy,
    /// Frozen reference policy (KL anchor).
    pub ref_params: Vec<HostTensor>,
    /// Generation-side weights (updated by weight sync each step).
    pub gen_params: Vec<HostTensor>,
    pub fleet: WorkerFleet,
    tok: Tokenizer,
    gen: ProblemGen,
    rng: Rng,
}

impl<'a> GrpoTrainer<'a> {
    pub fn new(rt: &'a Runtime, cfg: GrpoConfig, fleet: WorkerFleet) -> Result<GrpoTrainer<'a>> {
        assert_eq!(
            rt.manifest.batch % cfg.group_size,
            0,
            "group size must divide batch"
        );
        let policy = Policy::init(rt, cfg.seed)?;
        let ref_params = policy.snapshot_params();
        let gen_params = policy.snapshot_params();
        Ok(GrpoTrainer {
            rng: Rng::new(cfg.seed ^ 0xD1CE),
            gen: ProblemGen::new(cfg.seed ^ 0xDA7A, cfg.difficulty),
            tok: Tokenizer::new(),
            rt,
            cfg,
            policy,
            ref_params,
            gen_params,
            fleet,
        })
    }

    /// One GRPO iteration. Returns the step statistics.
    pub fn step(&mut self) -> Result<GrpoStats> {
        self.step_with_rewards(None)
    }

    /// One iteration with an optional reward override (used by tests and
    /// by experiments plugging in a learned reward model instead of the
    /// rule-based verifier).
    pub fn step_with_rewards(&mut self, reward_override: Option<&[f64]>) -> Result<GrpoStats> {
        let t0 = Instant::now();
        let b = self.rt.manifest.batch;
        let l = self.rt.model().max_len;
        let n_groups = b / self.cfg.group_size;

        // -- rollout --------------------------------------------------
        let problems: Vec<Problem> = self.gen.batch(n_groups);
        let prompts: Vec<Vec<i32>> = (0..b)
            .map(|i| encode_prompt(&self.tok, &problems[i / self.cfg.group_size]))
            .collect();
        let prompt_lens: Vec<usize> = prompts.iter().map(|p| p.len()).collect();
        let sampler = Sampler::new(self.rt, self.cfg.temperature);
        let roll_t = Instant::now();
        let (mut tokens, mut lens) =
            sampler.generate(&self.gen_params, &prompts, self.cfg.max_new, &mut self.rng)?;
        let rollout_secs = roll_t.elapsed().as_secs_f64();
        if self.cfg.expert_inject {
            // Overwrite the last member of each group with the gold
            // completion (prompt + answer + EOS).
            for g in 0..n_groups {
                let i = g * self.cfg.group_size + self.cfg.group_size - 1;
                let gold = self.tok.encode(&problems[g].answer);
                let start = i * l + prompt_lens[i];
                let avail = l - prompt_lens[i];
                let take = gold.len().min(avail.saturating_sub(1));
                for (k, &tk) in gold[..take].iter().enumerate() {
                    tokens[start + k] = tk;
                }
                tokens[start + take] = super::tokenizer::EOS;
                for slot in tokens[start + take + 1..(i + 1) * l].iter_mut() {
                    *slot = super::tokenizer::PAD;
                }
                lens[i] = prompt_lens[i] + take + 1;
            }
        }
        // Sequence-length-aware routing feeds the virtual fleet clock.
        let _assignment = self.fleet.route_by_length(&lens);
        self.fleet.account_parallel(rollout_secs);

        // -- rewards + advantages --------------------------------------
        let mut rewards = vec![0.0f64; b];
        for i in 0..b {
            let resp = &tokens[i * l + prompt_lens[i]..i * l + lens[i]];
            let text = self.tok.decode(resp);
            rewards[i] = reward(&problems[i / self.cfg.group_size], &text);
        }
        if let Some(over) = reward_override {
            assert_eq!(over.len(), b);
            rewards.copy_from_slice(over);
        }
        let mut adv = vec![0.0f32; b];
        for g in 0..n_groups {
            let slice = &rewards[g * self.cfg.group_size..(g + 1) * self.cfg.group_size];
            let mean: f64 = slice.iter().sum::<f64>() / slice.len() as f64;
            let var: f64 = slice.iter().map(|r| (r - mean) * (r - mean)).sum::<f64>()
                / slice.len() as f64;
            let std = var.sqrt().max(1e-4);
            for k in 0..self.cfg.group_size {
                adv[g * self.cfg.group_size + k] = ((slice[k] - mean) / std) as f32;
            }
        }

        // -- scoring (reward/ref inference wave) -----------------------
        let score_t = Instant::now();
        let logp_old = score_logprobs(self.rt, &self.gen_params, &tokens)?;
        let logp_ref = score_logprobs(self.rt, &self.ref_params, &tokens)?;
        self.fleet.account_parallel(score_t.elapsed().as_secs_f64());

        // -- mask: response tokens only ---------------------------------
        // logp index t corresponds to predicting tokens[t+1].
        let mut mask = vec![0.0f32; b * (l - 1)];
        for i in 0..b {
            for t in prompt_lens[i].saturating_sub(1)..lens[i] - 1 {
                mask[i * (l - 1) + t] = 1.0;
            }
        }

        // -- train step --------------------------------------------------
        let train_t = Instant::now();
        self.policy.step += 1;
        let mut inputs: Vec<HostTensor> = Vec::with_capacity(3 * self.rt.manifest.n_params + 6);
        inputs.extend(self.policy.params.iter().cloned());
        inputs.extend(self.policy.adam_m.iter().cloned());
        inputs.extend(self.policy.adam_v.iter().cloned());
        inputs.push(HostTensor::scalar_f32(self.policy.step as f32));
        inputs.push(HostTensor::i32(vec![b, l], tokens.clone()));
        inputs.push(HostTensor::f32(vec![b, l - 1], logp_old));
        inputs.push(HostTensor::f32(vec![b, l - 1], logp_ref));
        inputs.push(HostTensor::f32(vec![b], adv));
        inputs.push(HostTensor::f32(vec![b, l - 1], mask));
        let mut out = self.rt.execute("grpo_train", &inputs)?;
        let n_p = self.rt.manifest.n_params;
        let kl = out.pop().unwrap().as_f32()?[0] as f64;
        let loss = out.pop().unwrap().as_f32()?[0] as f64;
        let new_v = out.split_off(2 * n_p);
        let new_m = out.split_off(n_p);
        let new_p = out;
        self.policy.params = new_p;
        self.policy.adam_m = new_m;
        self.policy.adam_v = new_v;
        let train_secs = train_t.elapsed().as_secs_f64();
        self.fleet.account_parallel(train_secs);

        // -- weight sync (train → generation) ----------------------------
        let sync_bytes = self.policy.weight_bytes();
        self.gen_params = self.policy.snapshot_params();
        // Serial cost modeled from bytes over a reference 25 GB/s link.
        self.fleet.account_serial(sync_bytes as f64 / 25e9);

        let mean_reward = rewards.iter().sum::<f64>() / b as f64;
        Ok(GrpoStats {
            step: self.policy.step,
            mean_reward,
            loss,
            kl,
            wall: t0.elapsed().as_secs_f64(),
            virtual_wall: self.fleet.virtual_time,
            rollout_secs,
            train_secs,
            sync_bytes,
        })
    }

    /// Greedy-decoding accuracy over `n_batches` fresh problems.
    pub fn evaluate(&mut self, n_batches: usize) -> Result<f64> {
        let b = self.rt.manifest.batch;
        let l = self.rt.model().max_len;
        let sampler = Sampler::new(self.rt, 0.0);
        let mut correct = 0usize;
        let mut total = 0usize;
        for _ in 0..n_batches {
            let problems: Vec<Problem> = self.gen.batch(b);
            let prompts: Vec<Vec<i32>> =
                problems.iter().map(|p| encode_prompt(&self.tok, p)).collect();
            let plens: Vec<usize> = prompts.iter().map(|p| p.len()).collect();
            let (tokens, lens) =
                sampler.generate(&self.policy.params, &prompts, self.cfg.max_new, &mut self.rng)?;
            for i in 0..b {
                let resp = &tokens[i * l + plens[i]..i * l + lens[i]];
                let text = self.tok.decode(resp);
                if reward(&problems[i], &text) > 0.5 {
                    correct += 1;
                }
                total += 1;
            }
        }
        Ok(correct as f64 / total as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Option<Runtime> {
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        Some(Runtime::load("artifacts").unwrap())
    }

    #[test]
    fn grpo_step_runs_and_updates_weights() {
        let Some(rt) = runtime() else { return };
        let mut trainer =
            GrpoTrainer::new(&rt, GrpoConfig::default(), WorkerFleet::homogeneous(4)).unwrap();
        // param index 2 = l0.wq (a random weight matrix; index 1 is an
        // RMSNorm gain that starts at ones and moves slowly).
        let before = trainer.policy.params[2].clone();
        // Alternating rewards force nonzero within-group advantages so
        // the gradient cannot vanish (at init old == ref == current and
        // tied rewards would yield exactly zero gradient).
        let b = rt.manifest.batch;
        let rewards: Vec<f64> = (0..b).map(|i| (i % 2) as f64).collect();
        let stats = trainer.step_with_rewards(Some(&rewards)).unwrap();
        assert!(stats.loss.is_finite());
        assert!(stats.kl.is_finite());
        assert!(stats.mean_reward >= 0.0 && stats.mean_reward <= 1.0);
        assert_ne!(trainer.policy.params[2], before, "weights unchanged");
        // weight sync happened
        assert_eq!(trainer.gen_params[2], trainer.policy.params[2]);
        assert!(stats.sync_bytes > 1_000_000);
        assert!(stats.virtual_wall > 0.0);
    }

    #[test]
    fn evaluate_returns_fraction() {
        let Some(rt) = runtime() else { return };
        let mut trainer =
            GrpoTrainer::new(&rt, GrpoConfig::default(), WorkerFleet::homogeneous(4)).unwrap();
        let acc = trainer.evaluate(1).unwrap();
        assert!((0.0..=1.0).contains(&acc));
    }
}
