//! Profiler (paper §4.1): "collects hardware information about the
//! computing environment, including the computation power (TFLOPs),
//! memory capacity (GBs), and HBM bandwidth (GB/s) of available GPUs,
//! intra-machine bandwidth (GB/s), and network delay (ms) and bandwidth
//! (Gbps) between them."
//!
//! On the real testbed this runs micro-benchmarks; on the simulator
//! substrate it probes the topology with measurement noise and fits the
//! per-model MFU calibration the cost model consumes.

use crate::topology::{DeviceTopology, GpuModel};
use crate::util::rng::Rng;

/// Measured properties of one device.
#[derive(Debug, Clone)]
pub struct DeviceProfile {
    pub id: usize,
    pub model: GpuModel,
    /// Measured achievable dense FLOP/s.
    pub flops: f64,
    /// Measured HBM bandwidth (bytes/s).
    pub hbm: f64,
    /// Usable memory (bytes).
    pub mem: f64,
}

/// Measured properties of one (directed) link.
#[derive(Debug, Clone, Copy)]
pub struct LinkProbe {
    pub from: usize,
    pub to: usize,
    /// RTT/2 (s).
    pub latency: f64,
    /// Achieved bandwidth (bytes/s).
    pub bandwidth: f64,
}

/// Full profile of a computing environment.
#[derive(Debug, Clone)]
pub struct ProfileReport {
    pub devices: Vec<DeviceProfile>,
    pub links: Vec<LinkProbe>,
}

/// Profiler configuration.
#[derive(Debug, Clone)]
pub struct ProfilerConfig {
    /// Relative measurement noise (σ of multiplicative error).
    pub noise: f64,
    /// Links probed per device (full N² probing is wasteful; HetRL
    /// probes a deterministic sample and infers the rest from region
    /// structure).
    pub links_per_device: usize,
    pub seed: u64,
}

impl Default for ProfilerConfig {
    fn default() -> Self {
        ProfilerConfig { noise: 0.02, links_per_device: 4, seed: 0xFACE }
    }
}

/// Probe the environment.
pub fn profile(topo: &DeviceTopology, cfg: &ProfilerConfig) -> ProfileReport {
    let mut rng = Rng::new(cfg.seed);
    let mut jitter = |x: f64| x * (1.0 + cfg.noise * rng.normal());
    let devices = topo
        .devices
        .iter()
        .map(|d| DeviceProfile {
            id: d.id,
            model: d.gpu,
            flops: jitter(d.effective_flops()),
            hbm: jitter(d.spec().hbm_bps),
            mem: d.spec().mem_bytes * 0.95, // framework reserve
        })
        .collect();
    let mut links = Vec::new();
    let mut rng2 = Rng::new(cfg.seed ^ 0xABCD);
    for a in 0..topo.n() {
        for _ in 0..cfg.links_per_device {
            let b = rng2.below(topo.n());
            if a == b {
                continue;
            }
            links.push(LinkProbe {
                from: a,
                to: b,
                latency: topo.lat(a, b) * (1.0 + cfg.noise * rng2.normal()).max(0.5),
                bandwidth: topo.bw(a, b).min(1e18) * (1.0 + cfg.noise * rng2.normal()).max(0.5),
            });
        }
    }
    ProfileReport { devices, links }
}

impl ProfileReport {
    /// Fit per-GPU-model MFU: measured achievable FLOPs / peak.
    pub fn calibrate_mfu(&self) -> Vec<(GpuModel, f64)> {
        let mut acc: Vec<(GpuModel, f64, usize)> = Vec::new();
        for d in &self.devices {
            let mfu = d.flops / d.model.spec().fp16_flops;
            match acc.iter_mut().find(|(m, _, _)| *m == d.model) {
                Some((_, s, c)) => {
                    *s += mfu;
                    *c += 1;
                }
                None => acc.push((d.model, mfu, 1)),
            }
        }
        acc.into_iter().map(|(m, s, c)| (m, s / c as f64)).collect()
    }

    /// Human-readable hardware summary (the CLI `profile` subcommand).
    pub fn summary(&self, topo: &DeviceTopology) -> String {
        use crate::util::table::Table;
        let mut t = Table::new(
            "Profiled hardware",
            &["model", "count", "eff TFLOPS", "HBM GB/s", "mem GiB"],
        );
        for (model, mfu) in self.calibrate_mfu() {
            let count = self.devices.iter().filter(|d| d.model == model).count();
            let spec = model.spec();
            t.row(vec![
                spec.name.to_string(),
                count.to_string(),
                format!("{:.0}", spec.fp16_flops * mfu / 1e12),
                format!("{:.0}", spec.hbm_bps / 1e9),
                format!("{:.0}", spec.mem_bytes / crate::util::units::GIB),
            ]);
        }
        let mut s = t.render();
        let wan: Vec<&LinkProbe> = self
            .links
            .iter()
            .filter(|l| topo.devices[l.from].region != topo.devices[l.to].region)
            .collect();
        if !wan.is_empty() {
            let lat: Vec<f64> = wan.iter().map(|l| l.latency * 1e3).collect();
            let bw: Vec<f64> = wan.iter().map(|l| l.bandwidth * 8.0 / 1e9).collect();
            let sl = crate::util::stats::summarize(&lat);
            let sb = crate::util::stats::summarize(&bw);
            s.push_str(&format!(
                "WAN links probed: {} | delay {:.1}-{:.1} ms | bw {:.1}-{:.1} Gbps\n",
                wan.len(),
                sl.min,
                sl.max,
                sb.min,
                sb.max
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{build_testbed, Scenario, TestbedSpec};

    #[test]
    fn profile_covers_all_devices() {
        let topo = build_testbed(Scenario::MultiCountry, &TestbedSpec::default());
        let rep = profile(&topo, &ProfilerConfig::default());
        assert_eq!(rep.devices.len(), 64);
        assert!(!rep.links.is_empty());
    }

    #[test]
    fn calibration_recovers_mfu_within_noise() {
        let topo = build_testbed(Scenario::SingleRegion, &TestbedSpec::default());
        let rep = profile(&topo, &ProfilerConfig { noise: 0.02, ..Default::default() });
        for (model, mfu) in rep.calibrate_mfu() {
            let truth = model.spec().mfu;
            assert!(
                (mfu / truth - 1.0).abs() < 0.05,
                "{model:?}: {mfu} vs {truth}"
            );
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let topo = build_testbed(Scenario::SingleRegion, &TestbedSpec::default());
        let a = profile(&topo, &ProfilerConfig::default());
        let b = profile(&topo, &ProfilerConfig::default());
        assert_eq!(a.devices[0].flops, b.devices[0].flops);
    }

    #[test]
    fn summary_renders() {
        let topo = build_testbed(Scenario::MultiContinent, &TestbedSpec::default());
        let rep = profile(&topo, &ProfilerConfig::default());
        let s = rep.summary(&topo);
        assert!(s.contains("A100"));
        assert!(s.contains("WAN links"));
    }
}
