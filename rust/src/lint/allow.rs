//! Inline suppression directives for `detlint`.
//!
//! A finding is suppressed by a **plain** line comment of the form
//! (shown here with the marker split so this doc example is not itself
//! a directive): `det`‑`lint:allow(D2): keyed lookups only`.
//!
//! * A *trailing* directive (code before it on the same line)
//!   suppresses findings of that rule on its own line.
//! * A *standalone* directive (alone on its line) suppresses findings
//!   on the **next line that contains code** — blank lines and further
//!   comments in between are fine, so directives stack.
//! * Directives are machine-checked: a directive whose rule id is
//!   unknown, whose reason is empty, or whose targeted line has no
//!   finding of that rule is itself an `A0` error. Stale suppressions
//!   can be stripped mechanically with `hetrl lint --fix-allow`.
//!
//! Only plain `//` comments carry directives — doc comments (`///`,
//! `//!`) and block comments never do, so rustdoc can show the syntax
//! verbatim without registering a directive.

use super::lexer::Lexed;
use super::report::Finding;
use super::rules::Rule;

/// The directive marker inside a plain line comment.
const MARKER: &str = "detlint:allow(";

/// One parsed directive.
#[derive(Debug)]
pub struct Directive {
    /// Line the comment sits on.
    pub line: u32,
    /// Line whose findings it suppresses (same line for trailing
    /// directives, next code line for standalone ones; `None` when no
    /// code follows — always unused).
    pub target: Option<u32>,
    pub rule: Rule,
}

/// Parse all directives in a lexed file. Malformed directives become
/// `A0` findings immediately.
pub fn parse(path: &str, lx: &Lexed) -> (Vec<Directive>, Vec<Finding>) {
    let mut dirs = Vec::new();
    let mut bad = Vec::new();
    for c in &lx.comments {
        if !c.plain_line {
            continue;
        }
        let body = c.text.trim_start();
        if !body.starts_with(MARKER) {
            continue;
        }
        let rest = &body[MARKER.len()..];
        let malformed = |msg: &str| Finding {
            file: path.to_string(),
            line: c.line,
            rule: Rule::A0,
            msg: format!("malformed detlint:allow — {msg}; expected `detlint:allow(D<n>): reason`"),
            fixable: false,
        };
        let Some(close) = rest.find(')') else {
            bad.push(malformed("missing `)`"));
            continue;
        };
        let Some(rule) = Rule::parse_allowable(rest[..close].trim()) else {
            bad.push(malformed(&format!("unknown rule `{}`", rest[..close].trim())));
            continue;
        };
        let after = rest[close + 1..].trim_start();
        let reason = after.strip_prefix(':').map(str::trim).unwrap_or("");
        if reason.is_empty() {
            bad.push(malformed("empty reason"));
            continue;
        }
        let target = if c.has_code_before {
            Some(c.line)
        } else {
            // First code token strictly after the directive's line.
            lx.tokens.iter().find(|t| t.line > c.line).map(|t| t.line)
        };
        dirs.push(Directive { line: c.line, target, rule });
    }
    (dirs, bad)
}

/// Apply directives to raw rule findings: matching findings are
/// dropped; directives that suppressed nothing become `A0` findings
/// (marked fixable, so `--fix-allow` can strip the stale comment).
pub fn apply(path: &str, dirs: &[Directive], findings: Vec<Finding>) -> Vec<Finding> {
    let mut used = vec![false; dirs.len()];
    let mut out = Vec::new();
    'findings: for f in findings {
        for (di, d) in dirs.iter().enumerate() {
            if d.rule == f.rule && d.target == Some(f.line) {
                used[di] = true;
                continue 'findings;
            }
        }
        out.push(f);
    }
    for (di, d) in dirs.iter().enumerate() {
        if !used[di] {
            out.push(Finding {
                file: path.to_string(),
                line: d.line,
                rule: Rule::A0,
                msg: format!(
                    "unused detlint:allow({}) — the targeted line has no {} finding",
                    d.rule.id(),
                    d.rule.id()
                ),
                fixable: true,
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::lexer::lex;

    fn parse_src(src: &str) -> (Vec<Directive>, Vec<Finding>) {
        parse("src/x.rs", &lex(src))
    }

    #[test]
    fn trailing_and_standalone_targets() {
        let src = "let a = 1; // detlint:allow(D2): keyed only\n\n// detlint:allow(D1): telemetry\n\nlet b = 2;\n";
        let (dirs, bad) = parse_src(src);
        assert!(bad.is_empty(), "{bad:?}");
        assert_eq!(dirs.len(), 2);
        assert_eq!(dirs[0].target, Some(1));
        assert_eq!(dirs[1].target, Some(5), "standalone skips blank lines");
    }

    #[test]
    fn doc_comments_never_carry_directives() {
        let src = "/// detlint:allow(D1): example\n//! detlint:allow(D2): example\nlet a = 1;\n";
        let (dirs, bad) = parse_src(src);
        assert!(dirs.is_empty());
        assert!(bad.is_empty());
    }

    #[test]
    fn malformed_directives_are_a0() {
        let src = "// detlint:allow(D9): nope\n// detlint:allow(D1)\n// detlint:allow(D1):   \n";
        let (dirs, bad) = parse_src(src);
        assert!(dirs.is_empty());
        assert_eq!(bad.len(), 3, "{bad:?}");
        assert!(bad.iter().all(|f| f.rule == Rule::A0));
    }
}
