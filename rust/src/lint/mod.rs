//! `detlint` — the crate's zero-dependency determinism & concurrency
//! static-analysis pass, run in CI as `hetrl lint`.
//!
//! The engine's contract is that a schedule search is **bit-identical**
//! for a given seed at any thread count. That property is easy to lose
//! to a stray wall-clock read, a `HashMap` iteration, or a NaN-unsafe
//! comparator — bugs that survive code review because each one looks
//! innocuous. `detlint` makes the contract mechanical:
//!
//! | rule | enforces |
//! |------|----------|
//! | `D1` | no wall-clock (`Instant`/`SystemTime`) outside telemetry modules |
//! | `D2` | no `HashMap`/`HashSet` whose iteration order could feed ordered logic |
//! | `D3` | no NaN-unsafe float ordering — use `util::ford::cmp_f64` |
//! | `D4` | no ambient nondeterminism (parallelism probes, env reads, thread ids) outside sanctioned modules |
//! | `D5` | `Ordering::Relaxed` / `Mutex` sites must match the audited inventory; lock nesting must be declared |
//! | `A0` | every allow directive must be well-formed and suppress a real finding |
//!
//! The pass is **lexical**, built on a hand-rolled comment- and
//! string-aware scanner ([`lexer`]) — no `syn`, no new dependencies.
//! Intentional exceptions are suppressed inline (see [`allow`]) with a
//! mandatory reason, and stale suppressions are themselves errors that
//! `hetrl lint --fix-allow` can strip mechanically. Diagnostics render
//! in a stable sorted order ([`report`]) with a nonzero exit code, so
//! the `ci.sh` gate and snapshot tests are deterministic too.

pub mod allow;
pub mod lexer;
pub mod report;
pub mod rules;

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

pub use report::{Finding, Report};
pub use rules::{Rule, LOCK_ORDER, RULES};

/// Lint one source text under its display path: lex, run the D-rules,
/// then resolve allow directives (suppressions consume findings; unused
/// or malformed directives surface as `A0`).
pub fn check_source(path: &str, src: &str) -> Vec<Finding> {
    let lx = lexer::lex(src);
    let raw = rules::check(path, &lx);
    let (dirs, mut malformed) = allow::parse(path, &lx);
    let mut out = allow::apply(path, &dirs, raw);
    out.append(&mut malformed);
    out
}

/// Normalize a path for display and allowlist matching: forward
/// slashes, no leading `./`.
fn display_path(p: &Path) -> String {
    let s = p.to_string_lossy().replace('\\', "/");
    s.strip_prefix("./").unwrap_or(&s).to_string()
}

/// Collect every `.rs` file under `root` (or `root` itself if it is a
/// file), sorted by path so the scan order — and therefore finding
/// order before the final sort — is stable.
fn collect_rs(root: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    if root.is_file() {
        if root.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(root.to_path_buf());
        }
        return Ok(());
    }
    let mut entries: Vec<PathBuf> =
        fs::read_dir(root)?.map(|e| e.map(|e| e.path())).collect::<io::Result<_>>()?;
    entries.sort();
    for e in entries {
        if e.is_dir() {
            collect_rs(&e, out)?;
        } else if e.extension().map(|x| x == "rs").unwrap_or(false) {
            out.push(e);
        }
    }
    Ok(())
}

/// Lint every `.rs` file under the given paths (files or directories)
/// and return the finalized report.
pub fn run_paths(paths: &[PathBuf]) -> io::Result<Report> {
    let mut files = Vec::new();
    for p in paths {
        collect_rs(p, &mut files)?;
    }
    files.sort();
    files.dedup();
    let mut rep = Report::default();
    for f in &files {
        let src = fs::read_to_string(f)?;
        rep.findings.extend(check_source(&display_path(f), &src));
        rep.files_scanned += 1;
    }
    rep.finalize();
    Ok(rep)
}

/// If `line` ends in an allow directive comment, return it with the
/// comment stripped (trailing whitespace trimmed).
fn strip_trailing_directive(line: &str) -> Option<String> {
    let mut at = None;
    for (i, _) in line.match_indices("//") {
        if line[i + 2..].trim_start().starts_with("detlint:allow(") {
            at = Some(i);
        }
    }
    at.map(|i| line[..i].trim_end().to_string())
}

/// Mechanically remove unused allow directives (the `A0 … unused`
/// findings, which are the only fixable rule) from the files under
/// `paths`. Returns the number of directives removed. Malformed
/// directives and real rule findings are *not* touched — those need a
/// human.
pub fn fix_unused_allows(paths: &[PathBuf]) -> io::Result<usize> {
    let rep = run_paths(paths)?;
    let mut fixed = 0usize;
    // Group fixable findings by file; edit each file once, bottom-up so
    // line numbers stay valid while lines are removed.
    let mut by_file: Vec<(&str, Vec<u32>)> = Vec::new();
    for f in rep.findings.iter().filter(|f| f.fixable) {
        match by_file.iter_mut().find(|(p, _)| *p == f.file) {
            Some((_, lines)) => lines.push(f.line),
            None => by_file.push((&f.file, vec![f.line])),
        }
    }
    for (path, mut lines) in by_file {
        let src = fs::read_to_string(path)?;
        let mut rows: Vec<String> = src.lines().map(str::to_string).collect();
        lines.sort_unstable();
        lines.dedup();
        for &ln in lines.iter().rev() {
            let idx = ln as usize - 1;
            if idx >= rows.len() {
                continue;
            }
            match strip_trailing_directive(&rows[idx]) {
                Some(stripped) if !stripped.is_empty() => rows[idx] = stripped,
                Some(_) => {
                    rows.remove(idx);
                }
                None => continue,
            }
            fixed += 1;
        }
        let mut text = rows.join("\n");
        if src.ends_with('\n') {
            text.push('\n');
        }
        fs::write(path, text)?;
    }
    Ok(fixed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_source_suppresses_with_allow_and_flags_unused() {
        let dirty = "use std::collections::HashMap;\n";
        assert_eq!(check_source("src/x.rs", dirty).len(), 1);
        let allowed = "use std::collections::HashMap; // detlint:allow(D2): keyed lookups only\n";
        assert!(check_source("src/x.rs", allowed).is_empty());
        let stale = "let a = 1; // detlint:allow(D2): nothing here\n";
        let f = check_source("src/x.rs", stale);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, Rule::A0);
        assert!(f[0].fixable);
    }

    #[test]
    fn strip_trailing_directive_handles_both_shapes() {
        assert_eq!(
            strip_trailing_directive("let x = 1; // detlint:allow(D2): reason"),
            Some("let x = 1;".to_string())
        );
        assert_eq!(
            strip_trailing_directive("    // detlint:allow(D1): reason"),
            Some("".to_string())
        );
        assert_eq!(strip_trailing_directive("let x = 1; // plain comment"), None);
    }
}
