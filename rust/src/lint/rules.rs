//! The `detlint` rule registry and the D1–D5 rule implementations.
//!
//! Every rule is lexical: it scans the token stream of one file (the
//! [`super::lexer`] output, so strings and comments are already out of
//! the way) against a pinned, in-source inventory of audited sites.
//! The rules deliberately over-approximate — a flagged site is either
//! fixed, moved into an allowlisted module, or suppressed with an
//! inline allow comment (see [`super::allow`]) whose reason is part of
//! the diff under review.

use super::lexer::{Lexed, TokKind, Token};
use super::report::Finding;

/// Rule identifiers. `A0` is the allow-hygiene meta rule (unused or
/// malformed allow directives); it cannot itself be suppressed.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    D1,
    D2,
    D3,
    D4,
    D5,
    A0,
}

impl Rule {
    pub fn id(self) -> &'static str {
        match self {
            Rule::D1 => "D1",
            Rule::D2 => "D2",
            Rule::D3 => "D3",
            Rule::D4 => "D4",
            Rule::D5 => "D5",
            Rule::A0 => "A0",
        }
    }

    /// Parse a rule id as written in an allow directive. `A0` is not
    /// suppressible, so it does not parse here.
    pub fn parse_allowable(s: &str) -> Option<Rule> {
        match s {
            "D1" => Some(Rule::D1),
            "D2" => Some(Rule::D2),
            "D3" => Some(Rule::D3),
            "D4" => Some(Rule::D4),
            "D5" => Some(Rule::D5),
            _ => None,
        }
    }
}

/// The rule registry: id + one-line summary, as printed by
/// `hetrl lint --rules` and mirrored in `docs/ARCHITECTURE.md`.
pub const RULES: &[(Rule, &str)] = &[
    (Rule::D1, "no wall-clock (Instant/SystemTime) outside telemetry modules (util/logging, util/benchkit, engine/grpo)"),
    (Rule::D2, "no HashMap/HashSet — hash iteration order can feed ordered logic; use BTreeMap/BTreeSet or sort-after-collect"),
    (Rule::D3, "no NaN-unsafe float ordering (.partial_cmp(..).unwrap()); use util::ford::cmp_f64"),
    (Rule::D4, "no ambient nondeterminism (available_parallelism, thread::current, RandomState, env reads) outside engine::resolve_threads / testing::fixtures"),
    (Rule::D5, "audited concurrency only: Ordering::Relaxed, Mutex lock sites and RwLock types must match the declared inventory; no undeclared lock nesting"),
    (Rule::A0, "allow-directive hygiene: every detlint:allow must be well-formed and suppress a real finding"),
];

// ---- Pinned inventories -------------------------------------------------
//
// Paths are matched as suffixes of the scanned file's normalized path,
// so the lint behaves identically whether invoked from the repo root
// (`rust/src/...`), from `rust/` (`src/...`), or with absolute paths.

/// D1: modules allowed to touch `Instant`/`SystemTime` — telemetry
/// facades whose readings must never feed back into search decisions.
const D1_ALLOW: &[&str] = &[
    "src/util/logging.rs",
    "src/util/benchkit.rs",
    "src/engine/grpo.rs",
];

/// D4: the only sanctioned homes of ambient machine state — the
/// scheduler's single thread-count resolver and the test-matrix
/// fixtures (`HETRL_TEST_THREADS`).
const D4_ALLOW: &[&str] = &[
    "src/scheduler/engine.rs",
    "src/testing/fixtures.rs",
];

/// D5 inventory: files allowed to contain `Ordering::Relaxed` atomics.
/// Each entry is audited in docs/ARCHITECTURE.md: the cost-cache
/// hit/miss counters, the eval ledger's spent counter, and the log
/// facade's max-level cell — all monotone telemetry or
/// quota-reconciled counters, never ordered-logic inputs.
const D5_RELAXED: &[&str] = &[
    "src/costmodel/cache.rs",
    "src/scheduler/mod.rs",
    "src/log.rs",
];

/// D5 inventory: files allowed to take `Mutex` locks — the
/// threadpool's queue/slots/receiver. (The cost cache moved to sharded
/// `RwLock`s; see [`D5_RWLOCK`].)
const D5_LOCK: &[&str] = &["src/util/threadpool.rs"];

/// D5 inventory: files allowed to mention the `RwLock` type — the
/// sharded cost cache, whose read-mostly shards take a shared lock on
/// the warm path and an exclusive lock only to insert. Flagging the
/// type (rather than `.read()`/`.write()` calls, which collide with the
/// io traits) makes any new reader-writer lock a declared, reviewed
/// site.
const D5_RWLOCK: &[&str] = &["src/costmodel/cache.rs"];

/// D5 lock-order table: files whose statements may acquire **two**
/// locks, pinned in acquisition order. The audited inventory currently
/// acquires at most one lock per statement, so the table is empty; any
/// new nesting must be declared here (and documented in
/// docs/ARCHITECTURE.md) before it will pass the lint — which is
/// exactly the review moment where lock-order deadlocks are cheap to
/// catch.
pub const LOCK_ORDER: &[&str] = &[];

fn path_in(path: &str, list: &[&str]) -> bool {
    list.iter().any(|p| path.ends_with(p))
}

fn is_ident(t: &Token, s: &str) -> bool {
    t.kind == TokKind::Ident && t.text == s
}

/// Does the token sequence starting at `i` spell `pat` exactly?
fn seq(toks: &[Token], i: usize, pat: &[&str]) -> bool {
    pat.len() <= toks.len() - i && pat.iter().enumerate().all(|(k, p)| toks[i + k].text == *p)
}

/// Index just past the balanced group opened by the `(` at `open`
/// (returns `toks.len()` if unbalanced).
fn skip_parens(toks: &[Token], open: usize) -> usize {
    let mut depth = 0usize;
    let mut i = open;
    while i < toks.len() {
        match toks[i].text.as_str() {
            "(" => depth += 1,
            ")" => {
                depth -= 1;
                if depth == 0 {
                    return i + 1;
                }
            }
            _ => {}
        }
        i += 1;
    }
    toks.len()
}

/// Run every rule over one lexed file. `path` is the normalized display
/// path (used for the inventory allowlists and the findings).
pub fn check(path: &str, lx: &Lexed) -> Vec<Finding> {
    let mut out = Vec::new();
    let toks = &lx.tokens;
    let mut finding = |rule: Rule, line: u32, msg: String| {
        out.push(Finding { file: path.to_string(), line, rule, msg, fixable: false });
    };

    // Lock calls per statement, for the D5 nesting check. Statement
    // boundaries are `;`, `{`, `}` — conservative, but lock guards held
    // across them are exactly what the rule wants a human to look at.
    let mut locks_this_stmt = 0usize;

    for i in 0..toks.len() {
        let t = &toks[i];
        match t.text.as_str() {
            ";" | "{" | "}" => locks_this_stmt = 0,
            _ => {}
        }

        // D1 — wall-clock sources.
        if (is_ident(t, "Instant") || is_ident(t, "SystemTime")) && !path_in(path, D1_ALLOW) {
            finding(
                Rule::D1,
                t.line,
                format!(
                    "wall-clock `{}` outside the telemetry allowlist (util/logging, util/benchkit, engine/grpo); time must not influence search results",
                    t.text
                ),
            );
        }

        // D2 — hash-ordered collections.
        if is_ident(t, "HashMap") || is_ident(t, "HashSet") {
            finding(
                Rule::D2,
                t.line,
                format!(
                    "hash-ordered `{}`: iteration order can feed ordered logic; use BTreeMap/BTreeSet, sort-after-collect, or justify with an allow",
                    t.text
                ),
            );
        }

        // D3 — NaN-unsafe comparators: `.partial_cmp( … ).unwrap()`.
        // `fn partial_cmp` trait implementations are definitions, not
        // comparisons, and are skipped.
        if is_ident(t, "partial_cmp")
            && i > 0
            && toks[i - 1].text == "."
            && !(i > 1 && is_ident(&toks[i - 2], "fn"))
            && toks.get(i + 1).map(|n| n.text == "(").unwrap_or(false)
        {
            let after = skip_parens(toks, i + 1);
            if seq(toks, after, &[".", "unwrap"]) {
                finding(
                    Rule::D3,
                    t.line,
                    "NaN-unsafe comparator `.partial_cmp(..).unwrap()`; use util::ford::cmp_f64 (total order)".to_string(),
                );
            }
        }

        // D4 — ambient nondeterminism.
        if !path_in(path, D4_ALLOW) {
            if is_ident(t, "available_parallelism") || is_ident(t, "RandomState") {
                finding(
                    Rule::D4,
                    t.line,
                    format!(
                        "ambient nondeterminism `{}` outside engine::resolve_threads / testing::fixtures",
                        t.text
                    ),
                );
            }
            if is_ident(t, "thread") && seq(toks, i, &["thread", ":", ":", "current"]) {
                finding(
                    Rule::D4,
                    t.line,
                    "ambient nondeterminism `thread::current()` outside engine::resolve_threads / testing::fixtures".to_string(),
                );
            }
            if is_ident(t, "env")
                && (seq(toks, i, &["env", ":", ":", "var"])
                    || seq(toks, i, &["env", ":", ":", "var_os"])
                    || seq(toks, i, &["env", ":", ":", "vars"]))
            {
                finding(
                    Rule::D4,
                    t.line,
                    "environment read outside engine::resolve_threads / testing::fixtures".to_string(),
                );
            }
        }

        // D5 — audited concurrency inventory.
        if is_ident(t, "Ordering")
            && seq(toks, i, &["Ordering", ":", ":", "Relaxed"])
            && !path_in(path, D5_RELAXED)
        {
            finding(
                Rule::D5,
                t.line,
                "`Ordering::Relaxed` outside the audited atomics inventory (docs/ARCHITECTURE.md)".to_string(),
            );
        }
        if is_ident(t, "RwLock") && !path_in(path, D5_RWLOCK) {
            finding(
                Rule::D5,
                t.line,
                "`RwLock` outside the audited reader-writer inventory (docs/ARCHITECTURE.md)".to_string(),
            );
        }
        if is_ident(t, "lock")
            && i > 0
            && toks[i - 1].text == "."
            && toks.get(i + 1).map(|n| n.text == "(").unwrap_or(false)
        {
            if !path_in(path, D5_LOCK) {
                finding(
                    Rule::D5,
                    t.line,
                    "`.lock()` outside the audited mutex inventory (docs/ARCHITECTURE.md)".to_string(),
                );
            }
            locks_this_stmt += 1;
            if locks_this_stmt == 2 && !path_in(path, LOCK_ORDER) {
                finding(
                    Rule::D5,
                    t.line,
                    "nested lock acquisition in one statement; declare the pair in lint::rules::LOCK_ORDER (pinned acquisition order) first".to_string(),
                );
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::lexer::lex;

    fn run(path: &str, src: &str) -> Vec<Finding> {
        check(path, &lex(src))
    }

    #[test]
    fn d1_fires_outside_allowlist_only() {
        let src = "use std::time::Instant;\nfn f() -> f64 { 0.0 }\n";
        assert_eq!(run("src/scheduler/foo.rs", src).len(), 1);
        assert!(run("src/util/benchkit.rs", src).is_empty());
        // In a string or comment: never fires.
        assert!(run("src/x.rs", "// Instant\nlet s = \"Instant\";").is_empty());
    }

    #[test]
    fn d3_flags_usage_not_definitions() {
        let usage = "xs.sort_by(|a, b| a.partial_cmp(b).unwrap());";
        let f = run("src/x.rs", usage);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule.id(), "D3");
        // Trait impl definition and un-unwrapped use are fine.
        let def = "fn partial_cmp(&self, o: &Self) -> Option<Ordering> { self.0.partial_cmp(&o.0) }";
        assert!(run("src/x.rs", def).is_empty());
    }

    #[test]
    fn d5_nested_lock_in_one_statement() {
        let ok = "let a = m1.lock().unwrap(); let b = m2.lock().unwrap();";
        assert!(run("src/util/threadpool.rs", ok).is_empty());
        let nested = "let v = m1.lock().unwrap().merge(m2.lock().unwrap());";
        let f = run("src/util/threadpool.rs", nested);
        assert_eq!(f.len(), 1, "{f:?}");
        assert!(f[0].msg.contains("nested lock"));
    }

    #[test]
    fn d5_rwlock_type_outside_inventory() {
        let src = "use std::sync::RwLock;\nlet s: RwLock<u32> = RwLock::new(0);";
        assert_eq!(run("src/scheduler/x.rs", src).len(), 3);
        assert!(run("src/costmodel/cache.rs", src).is_empty());
    }

    #[test]
    fn d4_env_and_parallelism() {
        let src = "let n = std::thread::available_parallelism(); let v = std::env::var(\"X\");";
        let f = run("src/x.rs", src);
        assert_eq!(f.len(), 2);
        assert!(run("src/testing/fixtures.rs", src).is_empty());
    }
}
