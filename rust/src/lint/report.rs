//! Diagnostic collection and rendering for `detlint`.
//!
//! Output is pinned byte-for-byte by `tests/lint_selfcheck.rs`: one
//! `file:line rule message` line per finding, sorted by
//! `(file, line, rule, message)` and deduplicated, so CI diffs and
//! snapshot tests are stable across thread counts and walk order.

use super::rules::Rule;

/// One diagnostic.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Normalized display path (forward slashes).
    pub file: String,
    /// 1-based source line.
    pub line: u32,
    pub rule: Rule,
    pub msg: String,
    /// Whether `hetrl lint --fix-allow` can mechanically repair this
    /// finding (currently: unused allow directives only).
    pub fixable: bool,
}

impl Finding {
    /// The rendered diagnostic line.
    pub fn render(&self) -> String {
        format!("{}:{} {} {}", self.file, self.line, self.rule.id(), self.msg)
    }
}

/// All findings for one lint run.
#[derive(Debug, Default)]
pub struct Report {
    pub findings: Vec<Finding>,
    /// Number of `.rs` files scanned.
    pub files_scanned: usize,
}

impl Report {
    /// Stable order: `(file, line, rule, message)`, duplicates removed.
    pub fn finalize(&mut self) {
        self.findings
            .sort_by(|a, b| {
                a.file
                    .cmp(&b.file)
                    .then(a.line.cmp(&b.line))
                    .then(a.rule.cmp(&b.rule))
                    .then(a.msg.cmp(&b.msg))
            });
        self.findings.dedup();
    }

    pub fn is_clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// Render the full report (call [`Report::finalize`] first). Clean
    /// runs render a one-line all-clear; dirty runs render one line per
    /// finding plus a trailing count.
    pub fn render(&self) -> String {
        if self.is_clean() {
            return format!("detlint: {} files, no findings\n", self.files_scanned);
        }
        let mut s = String::new();
        for f in &self.findings {
            s.push_str(&f.render());
            s.push('\n');
        }
        s.push_str(&format!(
            "detlint: {} finding{} in {} files\n",
            self.findings.len(),
            if self.findings.len() == 1 { "" } else { "s" },
            self.files_scanned
        ));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn f(file: &str, line: u32, rule: Rule, msg: &str) -> Finding {
        Finding { file: file.to_string(), line, rule, msg: msg.to_string(), fixable: false }
    }

    #[test]
    fn finalize_sorts_and_dedups() {
        let mut r = Report::default();
        r.findings.push(f("b.rs", 2, Rule::D2, "x"));
        r.findings.push(f("a.rs", 9, Rule::D1, "y"));
        r.findings.push(f("a.rs", 9, Rule::D1, "y"));
        r.findings.push(f("a.rs", 3, Rule::D5, "z"));
        r.files_scanned = 2;
        r.finalize();
        let lines: Vec<String> = r.findings.iter().map(Finding::render).collect();
        assert_eq!(lines, vec!["a.rs:3 D5 z", "a.rs:9 D1 y", "b.rs:2 D2 x"]);
        assert!(r.render().ends_with("detlint: 3 findings in 2 files\n"));
    }

    #[test]
    fn clean_report_renders_all_clear() {
        let mut r = Report::default();
        r.files_scanned = 7;
        r.finalize();
        assert!(r.is_clean());
        assert_eq!(r.render(), "detlint: 7 files, no findings\n");
    }
}
