//! A small hand-rolled Rust token scanner for `detlint`.
//!
//! This is deliberately **not** a parser: the crate is zero-dep (no
//! `syn`), and the determinism rules only need a token stream that is
//! reliably *comment- and string-aware* — a banned identifier inside a
//! string literal or a doc comment must never fire a rule, and an
//! allow directive inside a string must never suppress one.
//!
//! The scanner understands: line comments (plain `//` vs doc `///` /
//! `//!`), nested block comments, string literals with escapes, raw and
//! byte strings (`r"…"`, `r#"…"#`, `b"…"`, `br#"…"#`), char and byte
//! literals, lifetimes vs char literals, raw identifiers (`r#type`),
//! identifiers, numbers and single-character punctuation. Multi-char
//! operators are left as single punct tokens; rules match sequences
//! (e.g. `Ordering` `:` `:` `Relaxed`).

/// What a code token is. Literal payloads are irrelevant to the rules,
/// so strings/chars collapse into [`TokKind::Literal`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Punct,
    /// String, raw string, byte string, char or byte literal.
    Literal,
    Num,
    Lifetime,
}

/// One code token with its 1-based source line.
#[derive(Debug, Clone)]
pub struct Token {
    pub line: u32,
    pub text: String,
    pub kind: TokKind,
}

/// One comment (line or block) with its 1-based starting line.
#[derive(Debug, Clone)]
pub struct Comment {
    pub line: u32,
    /// Comment body without the `//` / `/* */` delimiters.
    pub text: String,
    /// A plain `//` line comment (not `///`, `//!` or a block comment).
    /// Allow directives are only honored in plain line comments, so doc
    /// examples can show the syntax without registering directives.
    pub plain_line: bool,
    /// Whether a code token precedes the comment on its own line — a
    /// trailing comment targets its own line, a standalone one the next
    /// code line.
    pub has_code_before: bool,
}

/// Lexed view of one source file.
#[derive(Debug, Default)]
pub struct Lexed {
    pub tokens: Vec<Token>,
    pub comments: Vec<Comment>,
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_continue(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Scan `src` into tokens and comments. Never fails: unterminated
/// constructs simply run to end of input (the lint is best-effort on
/// files rustc would reject anyway).
pub fn lex(src: &str) -> Lexed {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut out = Lexed::default();
    let mut i = 0usize;
    let mut line: u32 = 1;
    let mut line_has_code = false;

    macro_rules! push_tok {
        ($line:expr, $text:expr, $kind:expr) => {{
            out.tokens.push(Token { line: $line, text: $text, kind: $kind });
            line_has_code = true;
        }};
    }

    while i < n {
        let c = chars[i];
        if c == '\n' {
            line += 1;
            line_has_code = false;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // Line comment.
        if c == '/' && i + 1 < n && chars[i + 1] == '/' {
            let doc = matches!(chars.get(i + 2), Some('/') | Some('!'));
            i += 2;
            let start = i;
            while i < n && chars[i] != '\n' {
                i += 1;
            }
            out.comments.push(Comment {
                line,
                text: chars[start..i].iter().collect(),
                plain_line: !doc,
                has_code_before: line_has_code,
            });
            continue;
        }
        // Block comment (nested).
        if c == '/' && i + 1 < n && chars[i + 1] == '*' {
            let start_line = line;
            let had_code = line_has_code;
            i += 2;
            let start = i;
            let mut depth = 1usize;
            while i < n && depth > 0 {
                if chars[i] == '\n' {
                    line += 1;
                    line_has_code = false;
                    i += 1;
                } else if chars[i] == '/' && i + 1 < n && chars[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if chars[i] == '*' && i + 1 < n && chars[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            let end = if depth == 0 { i - 2 } else { i };
            out.comments.push(Comment {
                line: start_line,
                text: chars[start..end].iter().collect(),
                plain_line: false,
                has_code_before: had_code,
            });
            continue;
        }
        // String literal.
        if c == '"' {
            let start_line = line;
            i += 1;
            while i < n {
                match chars[i] {
                    '\\' => i += 2,
                    '"' => {
                        i += 1;
                        break;
                    }
                    '\n' => {
                        line += 1;
                        i += 1;
                    }
                    _ => i += 1,
                }
            }
            push_tok!(start_line, String::from("\"…\""), TokKind::Literal);
            continue;
        }
        // Lifetime or char literal.
        if c == '\'' {
            let next_is_ident = i + 1 < n && is_ident_continue(chars[i + 1]);
            let closes = chars.get(i + 2) == Some(&'\'');
            if next_is_ident && !closes {
                // Lifetime: 'a, 'static — no closing quote.
                let start = i + 1;
                i += 1;
                while i < n && is_ident_continue(chars[i]) {
                    i += 1;
                }
                let text: String = chars[start..i].iter().collect();
                push_tok!(line, text, TokKind::Lifetime);
            } else {
                // Char literal: 'x', '\n', '\u{1F600}'.
                i += 1;
                while i < n {
                    match chars[i] {
                        '\\' => i += 2,
                        '\'' => {
                            i += 1;
                            break;
                        }
                        '\n' => {
                            line += 1;
                            i += 1;
                        }
                        _ => i += 1,
                    }
                }
                push_tok!(line, String::from("'…'"), TokKind::Literal);
            }
            continue;
        }
        // Identifier — with raw-string / byte-string / raw-ident prefixes.
        if is_ident_start(c) {
            let start = i;
            while i < n && is_ident_continue(chars[i]) {
                i += 1;
            }
            let ident: String = chars[start..i].iter().collect();
            let nextc = chars.get(i).copied();
            let string_prefix = matches!(ident.as_str(), "r" | "b" | "br");
            if string_prefix && (nextc == Some('"') || nextc == Some('#')) {
                // Count '#'s; a raw identifier (r#type) has ident chars
                // after the '#' instead of a quote.
                let mut hashes = 0usize;
                while chars.get(i + hashes) == Some(&'#') {
                    hashes += 1;
                }
                if ident == "r"
                    && hashes == 1
                    && chars.get(i + 1).map(|&c| is_ident_start(c)).unwrap_or(false)
                {
                    // Raw identifier: r#match.
                    i += 1;
                    let rstart = i;
                    while i < n && is_ident_continue(chars[i]) {
                        i += 1;
                    }
                    let text: String = chars[rstart..i].iter().collect();
                    push_tok!(line, text, TokKind::Ident);
                    continue;
                }
                if chars.get(i + hashes) == Some(&'"') {
                    // Raw (byte) string: scan to `"` + `hashes` '#'s.
                    let start_line = line;
                    i += hashes + 1;
                    while i < n {
                        if chars[i] == '\n' {
                            line += 1;
                            i += 1;
                        } else if chars[i] == '"'
                            && (0..hashes).all(|k| chars.get(i + 1 + k) == Some(&'#'))
                        {
                            i += 1 + hashes;
                            break;
                        } else {
                            i += 1;
                        }
                    }
                    push_tok!(start_line, String::from("r\"…\""), TokKind::Literal);
                    continue;
                }
                // `b` / `br` followed by lone '#'s: fall through as ident.
            }
            if ident == "b" && nextc == Some('\'') {
                // Byte literal: b'x'.
                i += 1;
                while i < n {
                    match chars[i] {
                        '\\' => i += 2,
                        '\'' => {
                            i += 1;
                            break;
                        }
                        _ => i += 1,
                    }
                }
                push_tok!(line, String::from("b'…'"), TokKind::Literal);
                continue;
            }
            push_tok!(line, ident, TokKind::Ident);
            continue;
        }
        // Number: digits, then idents/underscores, plus a dot followed
        // by a digit (1.5, 0xff, 1_000, 1e9).
        if c.is_ascii_digit() {
            let start = i;
            while i < n
                && (is_ident_continue(chars[i])
                    || (chars[i] == '.'
                        && chars.get(i + 1).map(|c| c.is_ascii_digit()).unwrap_or(false)))
            {
                i += 1;
            }
            let text: String = chars[start..i].iter().collect();
            push_tok!(line, text, TokKind::Num);
            continue;
        }
        // Single-character punctuation.
        push_tok!(line, c.to_string(), TokKind::Punct);
        i += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .tokens
            .into_iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn strings_and_comments_hide_tokens() {
        let src = r##"
let a = "Instant::now()"; // Instant in a comment
/* block Instant */ let b = r#"SystemTime"#;
let c = 'I'; let d = b"bytes";
"##;
        let ids = idents(src);
        assert_eq!(ids, vec!["let", "a", "let", "b", "let", "c", "let", "d"]);
    }

    #[test]
    fn comments_are_captured_with_kind_and_position() {
        let src = "let x = 1; // trailing\n// standalone\n/// doc\nlet y = 2;\n";
        let lx = lex(src);
        assert_eq!(lx.comments.len(), 3);
        assert!(lx.comments[0].has_code_before && lx.comments[0].plain_line);
        assert_eq!(lx.comments[0].line, 1);
        assert!(!lx.comments[1].has_code_before && lx.comments[1].plain_line);
        assert_eq!(lx.comments[1].line, 2);
        assert!(!lx.comments[2].plain_line, "doc comments are not plain");
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let src = "fn f<'a>(x: &'a str) { let c = 'a'; let s = 'static; }";
        let lx = lex(src);
        let lifetimes: Vec<_> = lx
            .tokens
            .iter()
            .filter(|t| t.kind == TokKind::Lifetime)
            .map(|t| t.text.clone())
            .collect();
        assert_eq!(lifetimes, vec!["a", "a", "static"]);
        assert_eq!(
            lx.tokens.iter().filter(|t| t.kind == TokKind::Literal).count(),
            1,
            "exactly the 'a' char literal"
        );
    }

    #[test]
    fn nested_block_comments_and_lines() {
        let src = "/* outer /* inner */ still */ let x = 1;\nlet y = 2;";
        let lx = lex(src);
        assert_eq!(lx.comments.len(), 1);
        let ids = idents(src);
        assert_eq!(ids, vec!["let", "x", "let", "y"]);
        assert_eq!(lx.tokens.last().unwrap().line, 2);
    }

    #[test]
    fn raw_identifier_is_an_ident() {
        assert_eq!(idents("let r#type = 1;"), vec!["let", "type"]);
    }

    #[test]
    fn numbers_do_not_eat_ranges() {
        let lx = lex("for i in 0..10 { let f = 1.5e3; }");
        let nums: Vec<_> = lx
            .tokens
            .iter()
            .filter(|t| t.kind == TokKind::Num)
            .map(|t| t.text.clone())
            .collect();
        assert_eq!(nums, vec!["0", "10", "1.5e3"]);
    }

    #[test]
    fn multiline_raw_string_tracks_lines() {
        let src = "let s = r#\"a\nb\nc\"#;\nlet t = 1;";
        let lx = lex(src);
        assert_eq!(lx.tokens.last().unwrap().line, 4);
    }
}
