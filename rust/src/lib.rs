//! # HetRL — Efficient Reinforcement Learning for LLMs in Heterogeneous Environments
//!
//! Reproduction of the MLSys'26 paper. The crate implements, from scratch:
//!
//! * the **device/topology substrate** ([`topology`]): GPU catalog (paper
//!   Table 1), region-to-region latency/bandwidth matrices, the four
//!   evaluation network scenarios;
//! * the **RL workflow model** ([`workflow`]): PPO/GRPO task graphs
//!   (sync + async), Qwen-style model specs with a full memory model;
//! * the **plan layer** ([`plan`]): DP×PP×TP parallel strategies, tasklet
//!   graphs `G_L`, execution plans `(ρ, σ)` with constraints C1–C3;
//! * the **analytical cost model** ([`costmodel`]) — paper Appendix B,
//!   verbatim: TP/PP/DP communication, compute, HBM-bound decoding,
//!   pipeline bubbles, resharding, weight synchronization, task-level
//!   `Ψ^{gen,inf,train}` and end-to-end `C` for Sync/Async PPO/GRPO;
//! * the **schedulers** ([`scheduler`]): the multi-level search framework
//!   (Levels 1–5), the hybrid nested-SHA + evolutionary algorithm
//!   (paper Algorithm 1) running on a **parallel plan-evaluation
//!   engine** ([`scheduler::engine`]: scoped worker threads per SHA
//!   rung, an atomic eval ledger with deterministic per-arm quotas, and
//!   an always-on sharded per-task cost cache — same seed, bit-identical
//!   best plan at any thread count), the exact ILP formulation, and the
//!   baselines (verl-like, StreamRL-like, pure EA / DEAP-like, random);
//! * **elastic cluster dynamics** ([`elastic`]): a seeded
//!   [`elastic::ClusterEvent`] trace model (machine join/leave/preempt,
//!   WAN degradation, stragglers) over a mutable fleet
//!   ([`elastic::FleetState`]), event-driven replanning that
//!   warm-starts the EA from the repaired incumbent under a reduced
//!   budget with a migration-aware objective
//!   ([`costmodel::MigrationModel`], now with source-NIC egress
//!   contention) across parallel warm-start arms, reusing per-task
//!   costs through the always-on [`costmodel::CostCache`], an
//!   **anytime background search** ([`elastic::anytime`]) that keeps
//!   improving the plan *between* events under a sim-time-accounted
//!   eval allowance and merges migration-aware at each barrier,
//!   **predictive preemption** (noticed machine losses pre-warm a
//!   second incumbent against the post-event fleet hypothesis, the
//!   allowance split deterministically between the two), and full
//!   dynamic-trace replay through the DES (`hetrl replay
//!   --scenario <s1..s4> --seed N`, compared as static vs warm-replan
//!   vs anytime vs preempt vs oracle in `benches/fig11_elastic.rs`);
//! * **asynchronous RL workflows** ([`asyncrl`]): generation and
//!   training streams joined by a bounded rollout queue under a hard
//!   off-policy staleness bound `k` (`k = 0` degenerates exactly to the
//!   synchronous iteration), simulated as per-stream continuous
//!   batching on the DES core, priced k-aware by
//!   [`costmodel::bounded_staleness_period`], searched through the
//!   **pool split** plan dimension (generation vs training pools as SHA
//!   arms), and replayed elastically with per-pool event attribution
//!   (`hetrl replay --workflow async`, `benches/fig_async.rs`);
//! * a standalone **0-1 ILP solver** ([`solver`]): dense simplex LP
//!   relaxation + branch & bound;
//! * a **discrete-event cluster simulator** ([`simulator`]) standing in
//!   for the paper's 64-GPU heterogeneous testbed;
//! * the **load balancer** ([`balance`]) and **profiler** ([`profiler`]);
//! * the **PJRT runtime** ([`runtime`]) that loads the AOT-compiled
//!   JAX/Pallas artifacts (HLO text) and the **execution engine**
//!   ([`engine`]) that runs real GRPO/PPO training with Python never on
//!   the request path.
//!
//! Offline-registry constraints mean the usual ecosystem crates are not
//! available; [`util`] and [`testing`] provide the in-crate substrates
//! (PRNG, JSON, CLI, logging, threadpool, bench harness, property-based
//! testing, and the shared [`testing::fixtures`] builders every test
//! suite uses), [`log`] is an in-crate facade replacing the `log` crate,
//! [`util::error`] replaces `anyhow`, and [`runtime::xla_stub`] stands
//! in for the PJRT bindings (host-side literal ops are real; device
//! compile/execute report unavailability until real bindings are wired
//! back in).
//!
//! The determinism contract is *enforced*, not just documented: the
//! in-crate [`lint`] module (`hetrl lint`, a hard CI gate) statically
//! rejects wall-clock reads, hash-ordered collections, NaN-unsafe float
//! comparators, ambient nondeterminism, and unaudited atomics/locks —
//! see `docs/ARCHITECTURE.md` for the rule table and inventories.

#![forbid(unsafe_code)]

pub mod log;
pub mod lint;
pub mod util;
pub mod testing;
pub mod topology;
pub mod workflow;
pub mod plan;
pub mod costmodel;
pub mod simulator;
pub mod solver;
pub mod scheduler;
pub mod elastic;
pub mod asyncrl;
pub mod balance;
pub mod profiler;
pub mod metrics;
pub mod runtime;
pub mod engine;

/// Crate version string, used by the CLI.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
