//! RL tasks and workflows.
//!
//! PPO (paper Figure 1(b)): four models (actor, critic, reward, reference)
//! and six tasks — actor generation (t=1), reward inference (t=2),
//! reference inference (t=3), critic inference (t=4), actor training
//! (t=5), critic training (t=6). GRPO drops the critic model, leaving
//! actor generation, reward inference, reference inference and actor
//! training.

use super::model::ModelSpec;

/// What kind of computation a task performs; drives the cost model's
/// choice of Ψ (gen / inf / train) and the memory model.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TaskKind {
    /// Autoregressive decoding (HBM-bandwidth bound, keeps KV cache).
    Generation,
    /// Forward-only scoring (compute bound, no KV cache across calls).
    Inference,
    /// Forward + backward + optimizer step (compute bound, keeps
    /// activations, gradients and optimizer state).
    Training,
}

/// Identity of a task in the canonical PPO ordering (paper t = 1..6).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum RlTaskId {
    ActorGen,
    RewardInf,
    RefInf,
    CriticInf,
    ActorTrain,
    CriticTrain,
}

impl RlTaskId {
    pub fn name(self) -> &'static str {
        match self {
            RlTaskId::ActorGen => "actor-gen",
            RlTaskId::RewardInf => "reward-inf",
            RlTaskId::RefInf => "ref-inf",
            RlTaskId::CriticInf => "critic-inf",
            RlTaskId::ActorTrain => "actor-train",
            RlTaskId::CriticTrain => "critic-train",
        }
    }

    pub fn kind(self) -> TaskKind {
        match self {
            RlTaskId::ActorGen => TaskKind::Generation,
            RlTaskId::RewardInf | RlTaskId::RefInf | RlTaskId::CriticInf => TaskKind::Inference,
            RlTaskId::ActorTrain | RlTaskId::CriticTrain => TaskKind::Training,
        }
    }

    /// Which of the four RL models this task uses.
    pub fn model_role(self) -> ModelRole {
        match self {
            RlTaskId::ActorGen | RlTaskId::ActorTrain => ModelRole::Actor,
            RlTaskId::RewardInf => ModelRole::Reward,
            RlTaskId::RefInf => ModelRole::Reference,
            RlTaskId::CriticInf | RlTaskId::CriticTrain => ModelRole::Critic,
        }
    }
}

/// The four RL models.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ModelRole {
    Actor,
    Critic,
    Reward,
    Reference,
}

/// RL algorithm family.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Algo {
    Ppo,
    Grpo,
}

impl Algo {
    pub fn name(self) -> &'static str {
        match self {
            Algo::Ppo => "PPO",
            Algo::Grpo => "GRPO",
        }
    }
}

/// Synchronous (iteration barrier) or asynchronous (generation of the
/// next iterations overlaps training) execution.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Mode {
    Sync,
    Async,
}

impl Mode {
    pub fn name(self) -> &'static str {
        match self {
            Mode::Sync => "Sync",
            Mode::Async => "Async",
        }
    }
}

/// One task instance in a workflow: identity + the model it runs.
#[derive(Debug, Clone, PartialEq)]
pub struct RlTask {
    pub id: RlTaskId,
    pub model: ModelSpec,
}

impl RlTask {
    pub fn kind(&self) -> TaskKind {
        self.id.kind()
    }
}

/// A concrete RL workflow: tasks plus inter-task data dependencies
/// (`E_inter` in the paper's computational graph `G`).
#[derive(Debug, Clone)]
pub struct RlWorkflow {
    pub algo: Algo,
    pub mode: Mode,
    pub tasks: Vec<RlTask>,
    /// Edges `(from, to)` over indices into `tasks`.
    pub deps: Vec<(usize, usize)>,
}

impl RlWorkflow {
    /// Build a workflow where every task runs the same-size model (the
    /// paper's evaluation setting; heterogeneous model sizes are allowed
    /// via [`RlWorkflow::with_models`]).
    pub fn new(algo: Algo, mode: Mode, model: ModelSpec) -> RlWorkflow {
        let ids = Self::task_ids(algo);
        let models = ids.iter().map(|_| model.clone()).collect();
        Self::with_models(algo, mode, models)
    }

    /// Build with a distinct model per task (lengths must match the
    /// algorithm's task list).
    pub fn with_models(algo: Algo, mode: Mode, models: Vec<ModelSpec>) -> RlWorkflow {
        let ids = Self::task_ids(algo);
        assert_eq!(models.len(), ids.len(), "one model per task");
        let tasks: Vec<RlTask> = ids
            .iter()
            .zip(models)
            .map(|(&id, model)| RlTask { id, model })
            .collect();
        let deps = Self::dependency_edges(algo, &tasks);
        RlWorkflow { algo, mode, tasks, deps }
    }

    /// Canonical task lists.
    pub fn task_ids(algo: Algo) -> Vec<RlTaskId> {
        match algo {
            Algo::Ppo => vec![
                RlTaskId::ActorGen,
                RlTaskId::RewardInf,
                RlTaskId::RefInf,
                RlTaskId::CriticInf,
                RlTaskId::ActorTrain,
                RlTaskId::CriticTrain,
            ],
            Algo::Grpo => vec![
                RlTaskId::ActorGen,
                RlTaskId::RewardInf,
                RlTaskId::RefInf,
                RlTaskId::ActorTrain,
            ],
        }
    }

    fn dependency_edges(algo: Algo, tasks: &[RlTask]) -> Vec<(usize, usize)> {
        let idx = |id: RlTaskId| tasks.iter().position(|t| t.id == id).unwrap();
        match algo {
            Algo::Ppo => {
                let (g, rw, rf, ci, at, ct) = (
                    idx(RlTaskId::ActorGen),
                    idx(RlTaskId::RewardInf),
                    idx(RlTaskId::RefInf),
                    idx(RlTaskId::CriticInf),
                    idx(RlTaskId::ActorTrain),
                    idx(RlTaskId::CriticTrain),
                );
                vec![
                    (g, rw),
                    (g, rf),
                    (g, ci),
                    (rw, at),
                    (rf, at),
                    (ci, at),
                    (rw, ct),
                    (rf, ct),
                    (ci, ct),
                ]
            }
            Algo::Grpo => {
                let (g, rw, rf, at) = (
                    idx(RlTaskId::ActorGen),
                    idx(RlTaskId::RewardInf),
                    idx(RlTaskId::RefInf),
                    idx(RlTaskId::ActorTrain),
                );
                vec![(g, rw), (g, rf), (rw, at), (rf, at)]
            }
        }
    }

    pub fn n_tasks(&self) -> usize {
        self.tasks.len()
    }

    /// Task indices with no outstanding dependencies among `done`.
    pub fn ready(&self, done: &[bool]) -> Vec<usize> {
        (0..self.tasks.len())
            .filter(|&t| {
                !done[t]
                    && self
                        .deps
                        .iter()
                        .all(|&(from, to)| to != t || done[from])
            })
            .collect()
    }

    /// Topological "waves" of tasks: tasks in the same wave have no
    /// dependencies among each other (gen → inferences → trainings).
    pub fn waves(&self) -> Vec<Vec<usize>> {
        let mut done = vec![false; self.tasks.len()];
        let mut out = Vec::new();
        while done.iter().any(|d| !d) {
            let wave = self.ready(&done);
            assert!(!wave.is_empty(), "dependency cycle in workflow");
            for &t in &wave {
                done[t] = true;
            }
            out.push(wave);
        }
        out
    }

    /// Display name, e.g. "PPO-Sync".
    pub fn name(&self) -> String {
        format!("{}-{}", self.algo.name(), self.mode.name())
    }

    /// Index of a task by id, if present.
    pub fn task_index(&self, id: RlTaskId) -> Option<usize> {
        self.tasks.iter().position(|t| t.id == id)
    }

    /// A clone of this workflow under a different execution mode. Task
    /// lists and dependency edges depend only on the algorithm, so the
    /// clone shares them verbatim; only cost-model pricing and the
    /// async-pipeline construction consult `mode`. Used by
    /// [`crate::asyncrl`] to force a workflow onto the sync (`k = 0`)
    /// or async pricing path without rebuilding it.
    pub fn with_mode(&self, mode: Mode) -> RlWorkflow {
        RlWorkflow { mode, ..self.clone() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> ModelSpec {
        ModelSpec::qwen_4b()
    }

    #[test]
    fn ppo_has_six_tasks_grpo_four() {
        let ppo = RlWorkflow::new(Algo::Ppo, Mode::Sync, model());
        let grpo = RlWorkflow::new(Algo::Grpo, Mode::Sync, model());
        assert_eq!(ppo.n_tasks(), 6);
        assert_eq!(grpo.n_tasks(), 4);
        assert!(grpo.task_index(RlTaskId::CriticInf).is_none());
        assert!(grpo.task_index(RlTaskId::CriticTrain).is_none());
    }

    #[test]
    fn ppo_waves_match_paper() {
        // gen → {reward, ref, critic} inference → {actor, critic} training
        let ppo = RlWorkflow::new(Algo::Ppo, Mode::Sync, model());
        let waves = ppo.waves();
        assert_eq!(waves.len(), 3);
        assert_eq!(waves[0], vec![0]);
        assert_eq!(waves[1].len(), 3);
        assert_eq!(waves[2].len(), 2);
    }

    #[test]
    fn grpo_waves() {
        let grpo = RlWorkflow::new(Algo::Grpo, Mode::Sync, model());
        let waves = grpo.waves();
        assert_eq!(waves.len(), 3);
        assert_eq!(waves[1].len(), 2); // reward + ref inference
        assert_eq!(waves[2].len(), 1); // actor training
    }

    #[test]
    fn kinds() {
        assert_eq!(RlTaskId::ActorGen.kind(), TaskKind::Generation);
        assert_eq!(RlTaskId::RefInf.kind(), TaskKind::Inference);
        assert_eq!(RlTaskId::CriticTrain.kind(), TaskKind::Training);
    }

    #[test]
    fn with_mode_changes_only_the_mode() {
        let sync = RlWorkflow::new(Algo::Grpo, Mode::Sync, model());
        let asy = sync.with_mode(Mode::Async);
        assert_eq!(asy.mode, Mode::Async);
        assert_eq!(asy.algo, sync.algo);
        assert_eq!(asy.tasks, sync.tasks);
        assert_eq!(asy.deps, sync.deps);
        assert_eq!(asy.with_mode(Mode::Sync).mode, Mode::Sync);
    }

    #[test]
    fn ready_respects_deps() {
        let ppo = RlWorkflow::new(Algo::Ppo, Mode::Sync, model());
        let mut done = vec![false; 6];
        assert_eq!(ppo.ready(&done), vec![0]);
        done[0] = true;
        assert_eq!(ppo.ready(&done).len(), 3);
    }
}
