//! Transformer model specifications and the memory model.
//!
//! The cost model (Appendix B) only needs `(h1, h2, nl)` — hidden size,
//! intermediate size, layer count — plus vocabulary for the embedding
//! terms the paper folds away ("we have omitted the vocabulary and token
//! embeddings in the cost model, but they are included in our actual
//! implementation"); we include them.

use crate::util::units::{B_BF16, B_FP32};

/// Architecture of one LLM in the RL workflow.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    pub name: String,
    /// Hidden size `h1`.
    pub h1: usize,
    /// MLP intermediate size `h2`.
    pub h2: usize,
    /// Number of transformer layers `nl`.
    pub nl: usize,
    pub vocab: usize,
    pub n_heads: usize,
}

impl ModelSpec {
    pub fn new(name: &str, h1: usize, h2: usize, nl: usize, vocab: usize, n_heads: usize) -> Self {
        ModelSpec { name: name.to_string(), h1, h2, nl, vocab, n_heads }
    }

    /// Qwen3-style presets used in the paper's evaluation.
    pub fn qwen_4b() -> Self {
        ModelSpec::new("Qwen-4B", 2560, 9728, 36, 151_936, 32)
    }

    pub fn qwen_8b() -> Self {
        ModelSpec::new("Qwen-8B", 4096, 12288, 36, 151_936, 32)
    }

    pub fn qwen_14b() -> Self {
        ModelSpec::new("Qwen-14B", 5120, 17408, 40, 151_936, 40)
    }

    /// Qwen3-1.7B-Base (training-quality case studies, Figures 8/9).
    pub fn qwen_1b7() -> Self {
        ModelSpec::new("Qwen-1.7B", 2048, 6144, 28, 151_936, 16)
    }

    pub fn by_name(name: &str) -> Option<ModelSpec> {
        match name.to_ascii_lowercase().replace('-', "").as_str() {
            "qwen4b" | "4b" => Some(ModelSpec::qwen_4b()),
            "qwen8b" | "8b" => Some(ModelSpec::qwen_8b()),
            "qwen14b" | "14b" => Some(ModelSpec::qwen_14b()),
            "qwen1.7b" | "qwen1b7" | "1.7b" => Some(ModelSpec::qwen_1b7()),
            _ => None,
        }
    }

    /// Parameter count per layer:
    /// attention 4·h1² (QKVO) + MLP 3·h1·h2 (gate/up/down).
    pub fn params_per_layer(&self) -> f64 {
        4.0 * (self.h1 as f64) * (self.h1 as f64)
            + 3.0 * (self.h1 as f64) * (self.h2 as f64)
    }

    /// Total parameter count (incl. embedding + unembedding).
    pub fn params(&self) -> f64 {
        self.nl as f64 * self.params_per_layer()
            + 2.0 * (self.vocab as f64) * (self.h1 as f64)
    }

    /// Bytes to hold the BF16 weights of `layers` layers under TP degree
    /// `tp` (the per-tasklet "model memory" of inference/generation).
    pub fn weight_bytes(&self, layers: usize, tp: usize) -> f64 {
        B_BF16 * layers as f64 * self.params_per_layer() / tp as f64
            + B_BF16 * 2.0 * (self.vocab as f64) * (self.h1 as f64) / tp as f64
    }

    /// Bytes of training state per tasklet: BF16 weights + FP32 master
    /// weights + FP32 grads + Adam m/v (mixed-precision Megatron recipe:
    /// 2 + 4 + 4 + 8 = 18 bytes/param).
    pub fn train_state_bytes(&self, layers: usize, tp: usize) -> f64 {
        let per_param = B_BF16 + B_FP32 + B_FP32 + 2.0 * B_FP32;
        per_param * layers as f64 * self.params_per_layer() / tp as f64
            + per_param * 2.0 * (self.vocab as f64) * (self.h1 as f64) / tp as f64
    }

    /// KV-cache bytes for `batch` sequences of `seq` tokens over `layers`
    /// layers under TP degree `tp` (2 tensors × seq × h1, BF16).
    pub fn kv_cache_bytes(&self, batch: usize, seq: usize, layers: usize, tp: usize) -> f64 {
        B_BF16 * 2.0 * batch as f64 * seq as f64 * (self.h1 as f64) * layers as f64 / tp as f64
    }

    /// Activation memory for training one micro-batch of `mbs` sequences
    /// of length `seq` across `layers` layers with TP `tp`, assuming
    /// selective recomputation (the ~`34·seq·h1 + 5·a·seq²` term reduced
    /// to checkpointed inputs, BF16).
    pub fn activation_bytes(&self, mbs: usize, seq: usize, layers: usize, tp: usize) -> f64 {
        // Checkpoint one activation tensor per layer plus working set of
        // roughly 8 live tensors inside the recomputed layer.
        let per_layer = B_BF16 * mbs as f64 * seq as f64 * (self.h1 as f64) / tp as f64;
        per_layer * layers as f64 + 8.0 * per_layer
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qwen_param_counts_plausible() {
        // Within ±35% of the nominal sizes (we use a uniform-layer
        // approximation of the real configs, which use GQA etc.).
        let cases = [
            (ModelSpec::qwen_1b7(), 1.7e9),
            (ModelSpec::qwen_4b(), 4.0e9),
            (ModelSpec::qwen_8b(), 8.0e9),
            (ModelSpec::qwen_14b(), 14.0e9),
        ];
        for (spec, nominal) in cases {
            let p = spec.params();
            assert!(
                (p / nominal) > 0.65 && (p / nominal) < 1.35,
                "{}: {p:.3e} vs nominal {nominal:.1e}",
                spec.name
            );
        }
    }

    #[test]
    fn memory_scales_inverse_with_tp() {
        let m = ModelSpec::qwen_8b();
        let w1 = m.weight_bytes(m.nl, 1);
        let w4 = m.weight_bytes(m.nl, 4);
        assert!((w1 / w4 - 4.0).abs() < 1e-9);
    }

    #[test]
    fn train_state_is_9x_weights() {
        let m = ModelSpec::qwen_4b();
        let w = m.weight_bytes(m.nl, 1);
        let t = m.train_state_bytes(m.nl, 1);
        assert!((t / w - 9.0).abs() < 1e-9); // 18 bytes vs 2 bytes per param
    }

    #[test]
    fn kv_cache_linear_in_batch_and_seq() {
        let m = ModelSpec::qwen_4b();
        let a = m.kv_cache_bytes(8, 1024, m.nl, 1);
        let b = m.kv_cache_bytes(16, 1024, m.nl, 1);
        let c = m.kv_cache_bytes(8, 2048, m.nl, 1);
        assert!((b / a - 2.0).abs() < 1e-9);
        assert!((c / a - 2.0).abs() < 1e-9);
    }

    #[test]
    fn by_name() {
        assert_eq!(ModelSpec::by_name("qwen-8b").unwrap().name, "Qwen-8B");
        assert_eq!(ModelSpec::by_name("14b").unwrap().name, "Qwen-14B");
        assert!(ModelSpec::by_name("gpt-5").is_none());
    }
}
