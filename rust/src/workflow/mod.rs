//! RL workflow model: LLM specs (Qwen-style), the six PPO / four GRPO
//! tasks with their computational and data dependencies, and the job
//! configuration (batch size, sequence lengths, precision...).

pub mod model;
pub mod task;
pub mod job;

pub use job::JobConfig;
pub use model::ModelSpec;
pub use task::{Algo, Mode, RlTask, RlTaskId, RlWorkflow, TaskKind};
