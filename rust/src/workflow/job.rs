//! RL training job configuration (paper §4.1: "an RL algorithm, a
//! dataset, models for different tasks, an optimizer, numerical precision,
//! global batch size, sequence lengths of prompts and responses, and
//! other optional configurations").

/// Hyperparameters of an RL training job that the scheduler and cost
/// model need. Defaults match the paper's evaluation setup (§5.1).
#[derive(Debug, Clone, PartialEq)]
pub struct JobConfig {
    /// Global batch size (prompts per iteration). Paper: 384.
    pub global_batch: usize,
    /// Max input-prompt length. Paper: 1024.
    pub seq_in: usize,
    /// Max generated-response length. Paper: 1024.
    pub seq_out: usize,
    /// Responses generated per prompt (GRPO group size). Paper: 8.
    pub n_responses: usize,
    /// Micro-batch size for training.
    pub mbs: usize,
    /// Task-parallelism coefficient η of Φ (0 sequential … 1 parallel).
    pub eta: f64,
    /// Whether activation recomputation is enabled for training
    /// (switches the 2× vs 6× TP-communication multiplier, Appendix B).
    pub recompute: bool,
    /// Decoding batch size per serving-engine replica, `dbs_d`, as a
    /// fraction of the local generation batch (vLLM continuous batching
    /// keeps this near the whole local batch).
    pub decode_batch_frac: f64,
    /// Hard off-policy staleness bound `k` for **asynchronous**
    /// workflows: a rollout batch may be consumed by training at most
    /// `k` policy versions after the one that generated it (AReaL-Hex /
    /// LlamaRL bounded staleness). `k = 0` degenerates exactly to the
    /// synchronous iteration — generation, training and weight sync
    /// serialize. Consulted only when the workflow's
    /// [`Mode`](super::Mode) is `Async`; inert for sync workflows.
    pub staleness_bound: usize,
    /// Capacity of the bounded rollout queue joining the generation
    /// stream to the training stream (asynchronous workflows only):
    /// generation of batch `i` blocks until batch `i - cap` has been
    /// dequeued. Clamped to ≥ 1 wherever it is consumed.
    pub rollout_queue_cap: usize,
}

impl Default for JobConfig {
    fn default() -> Self {
        JobConfig {
            global_batch: 384,
            seq_in: 1024,
            seq_out: 1024,
            n_responses: 8,
            mbs: 2,
            eta: 0.8,
            recompute: true,
            decode_batch_frac: 1.0,
            staleness_bound: 1,
            rollout_queue_cap: 2,
        }
    }
}

impl JobConfig {
    /// Total sequences entering inference/training per iteration
    /// (prompts × responses-per-prompt).
    pub fn total_samples(&self) -> usize {
        self.global_batch * self.n_responses
    }

    /// Full sequence length (prompt + response).
    pub fn seq_total(&self) -> usize {
        self.seq_in + self.seq_out
    }

    /// Number of micro-batches for a task replicated over `dp` data
    /// parallel groups ("we have preprocessed nm based on the number of
    /// responses generated per prompt [and] the data parallelism degree").
    pub fn num_microbatches(&self, dp: usize) -> usize {
        let local = self.total_samples().div_ceil(dp);
        local.div_ceil(self.mbs).max(1)
    }

    /// A scaled-down config for unit tests.
    pub fn tiny() -> Self {
        JobConfig {
            global_batch: 8,
            seq_in: 128,
            seq_out: 128,
            n_responses: 2,
            mbs: 1,
            eta: 0.8,
            recompute: true,
            decode_batch_frac: 1.0,
            staleness_bound: 1,
            rollout_queue_cap: 2,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let j = JobConfig::default();
        assert_eq!(j.global_batch, 384);
        assert_eq!(j.seq_in, 1024);
        assert_eq!(j.seq_out, 1024);
        assert_eq!(j.n_responses, 8);
        assert_eq!(j.total_samples(), 3072);
        // Async-pipeline defaults: one version of slack, two queued
        // batches (k = 0 would force the synchronous degenerate case).
        assert_eq!(j.staleness_bound, 1);
        assert_eq!(j.rollout_queue_cap, 2);
        assert_eq!(JobConfig::tiny().staleness_bound, 1);
    }

    #[test]
    fn microbatches_divide_by_dp() {
        let j = JobConfig::default();
        assert_eq!(j.num_microbatches(1), 1536);
        assert_eq!(j.num_microbatches(4), 384);
        // dp larger than samples still yields >= 1
        assert_eq!(JobConfig::tiny().num_microbatches(64), 1);
    }
}
