//! PJRT client wrapper: HLO text → compile → execute, with host-side
//! tensors ([`HostTensor`]) shuttled in and out as literals.
//!
//! Pattern follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`,
//! entry points lowered with `return_tuple=True` so outputs arrive as a
//! single tuple literal.

use super::artifacts::{Dtype, Manifest, TensorSpec};
use super::xla_stub as xla;
use crate::util::error::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;

/// A host-side tensor (row-major f32/i32/u32).
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
    U32 { shape: Vec<usize>, data: Vec<u32> },
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::F32 { shape, data }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::I32 { shape, data }
    }

    pub fn u32(shape: Vec<usize>, data: Vec<u32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::U32 { shape, data }
    }

    pub fn scalar_f32(x: f32) -> HostTensor {
        HostTensor::F32 { shape: vec![], data: vec![x] }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. }
            | HostTensor::I32 { shape, .. }
            | HostTensor::U32 { shape, .. } => shape,
        }
    }

    pub fn dtype(&self) -> Dtype {
        match self {
            HostTensor::F32 { .. } => Dtype::F32,
            HostTensor::I32 { .. } => Dtype::I32,
            HostTensor::U32 { .. } => Dtype::U32,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is not i32"),
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32 { data, .. } => xla::Literal::vec1(data),
            HostTensor::I32 { data, .. } => xla::Literal::vec1(data),
            HostTensor::U32 { data, .. } => xla::Literal::vec1(data),
        };
        Ok(lit.reshape(&dims)?)
    }

    fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(HostTensor::F32 {
                shape: dims,
                data: lit.to_vec::<f32>()?,
            }),
            xla::ElementType::S32 => Ok(HostTensor::I32 {
                shape: dims,
                data: lit.to_vec::<i32>()?,
            }),
            xla::ElementType::U32 => Ok(HostTensor::U32 {
                shape: dims,
                data: lit.to_vec::<u32>()?,
            }),
            other => bail!("unsupported output element type {other:?}"),
        }
    }

    /// Validate against a manifest spec.
    pub fn check(&self, spec: &TensorSpec) -> Result<()> {
        if self.shape() != spec.shape.as_slice() || self.dtype() != spec.dtype {
            bail!(
                "tensor mismatch: got {:?} {:?}, want {:?} {:?}",
                self.dtype(),
                self.shape(),
                spec.dtype,
                spec.shape
            );
        }
        Ok(())
    }
}

/// Pre-converted literals (opaque parameter pack for
/// [`Runtime::execute_prepared`]). PJRT CPU treats caller-owned buffers
/// as donatable (input/output aliasing) which corrupts reused
/// parameters, so the resident form is the XLA literal: conversion from
/// host vectors happens once, and `execute` borrows it per call.
pub struct DeviceTensors {
    literals: Vec<xla::Literal>,
}

impl DeviceTensors {
    pub fn len(&self) -> usize {
        self.literals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.literals.is_empty()
    }
}

/// Loaded runtime: one compiled executable per manifest entry point.
pub struct Runtime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    executables: BTreeMap<String, xla::PjRtLoadedExecutable>,
    /// Executions per entry point (telemetry).
    pub exec_counts: std::cell::RefCell<BTreeMap<String, usize>>,
}

impl Runtime {
    /// Load and compile every entry point in `dir`.
    pub fn load(dir: &str) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        let mut executables = BTreeMap::new();
        for (name, ep) in &manifest.entrypoints {
            let path = ep
                .file
                .to_str()
                .ok_or_else(|| anyhow!("non-utf8 path"))?;
            let proto = xla::HloModuleProto::from_text_file(path)
                .with_context(|| format!("parsing HLO text for '{name}'"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling '{name}'"))?;
            executables.insert(name.clone(), exe);
        }
        crate::log::info!(
            "runtime loaded {} entry points from {} ({:.2}M params)",
            executables.len(),
            dir,
            manifest.total_params() as f64 / 1e6
        );
        Ok(Runtime {
            manifest,
            client,
            executables,
            exec_counts: std::cell::RefCell::new(BTreeMap::new()),
        })
    }

    /// Execute an entry point with shape/dtype checking.
    pub fn execute(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let ep = self.manifest.entry(name)?;
        if inputs.len() != ep.inputs.len() {
            bail!(
                "'{name}' expects {} inputs, got {}",
                ep.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, spec)) in inputs.iter().zip(&ep.inputs).enumerate() {
            t.check(spec)
                .with_context(|| format!("'{name}' input {i}"))?;
        }
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let exe = self.executables.get(name).unwrap();
        let result = exe.execute::<xla::Literal>(&literals)?;
        let tuple = result[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        *self
            .exec_counts
            .borrow_mut()
            .entry(name.to_string())
            .or_insert(0) += 1;
        let out: Vec<HostTensor> = parts
            .iter()
            .map(HostTensor::from_literal)
            .collect::<Result<_>>()?;
        if out.len() != ep.outputs.len() {
            bail!(
                "'{name}' returned {} outputs, manifest says {}",
                out.len(),
                ep.outputs.len()
            );
        }
        Ok(out)
    }

    /// Upload host tensors to device buffers once (§Perf L3-3: the
    /// sampler re-executes `forward` per generated token — keeping the
    /// parameters resident avoids re-staging megabytes of weights every
    /// call).
    pub fn upload(&self, tensors: &[HostTensor]) -> Result<DeviceTensors> {
        let literals = tensors
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<Vec<_>>>()?;
        Ok(DeviceTensors { literals })
    }

    /// Execute with prepared leading arguments (the parameters)
    /// followed by per-call host tensors.
    pub fn execute_prepared(
        &self,
        name: &str,
        prepared: &DeviceTensors,
        host_rest: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let ep = self.manifest.entry(name)?;
        let total = prepared.literals.len() + host_rest.len();
        if total != ep.inputs.len() {
            bail!(
                "'{name}' expects {} inputs, got {} prepared + {} host",
                ep.inputs.len(),
                prepared.literals.len(),
                host_rest.len()
            );
        }
        for (i, (t, spec)) in host_rest
            .iter()
            .zip(&ep.inputs[prepared.literals.len()..])
            .enumerate()
        {
            t.check(spec).with_context(|| format!("'{name}' host input {i}"))?;
        }
        let mut rest_lits: Vec<xla::Literal> = Vec::with_capacity(host_rest.len());
        for t in host_rest {
            rest_lits.push(t.to_literal()?);
        }
        let all: Vec<&xla::Literal> = prepared.literals.iter().chain(rest_lits.iter()).collect();
        let exe = self.executables.get(name).unwrap();
        let result = exe.execute::<&xla::Literal>(&all)?;
        let tuple = result[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        *self
            .exec_counts
            .borrow_mut()
            .entry(name.to_string())
            .or_insert(0) += 1;
        parts.iter().map(HostTensor::from_literal).collect()
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Manifest model info shortcut.
    pub fn model(&self) -> &super::artifacts::ModelInfo {
        &self.manifest.model
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Option<Runtime> {
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        Some(Runtime::load("artifacts").expect("runtime load"))
    }

    #[test]
    fn host_tensor_roundtrip() {
        let t = HostTensor::f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
        let ti = HostTensor::i32(vec![4], vec![1, -2, 3, -4]);
        let back = HostTensor::from_literal(&ti.to_literal().unwrap()).unwrap();
        assert_eq!(ti, back);
    }

    #[test]
    fn init_and_forward() {
        let Some(rt) = runtime() else { return };
        let m = rt.model().clone_info();
        // init: seed -> params
        let params = rt
            .execute("init", &[HostTensor::u32(vec![2], vec![0, 42])])
            .unwrap();
        assert_eq!(params.len(), rt.manifest.n_params);
        // forward: params + tokens -> logits
        let b = rt.manifest.batch;
        let tokens = HostTensor::i32(
            vec![b, m.max_len],
            vec![1; b * m.max_len],
        );
        let mut inputs = params.clone();
        inputs.push(tokens);
        let out = rt.execute("forward", &inputs).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].shape(), &[b, m.max_len, m.vocab]);
        let logits = out[0].as_f32().unwrap();
        assert!(logits.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn shape_checking_rejects_bad_input() {
        let Some(rt) = runtime() else { return };
        let err = rt
            .execute("init", &[HostTensor::u32(vec![3], vec![0, 1, 2])])
            .unwrap_err();
        assert!(format!("{err:#}").contains("mismatch"));
    }
}

impl super::artifacts::ModelInfo {
    /// Cheap copy helper for tests.
    pub fn clone_info(&self) -> super::artifacts::ModelInfo {
        self.clone()
    }
}
