//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the rust runtime (entry-point files, tensor shapes/dtypes, parameter
//! layout, model hyperparameters).

use crate::util::json::Json;
use crate::util::error::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Tensor element type used in the manifest.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
    U32,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            "u32" => Ok(Dtype::U32),
            other => bail!("unknown dtype '{other}'"),
        }
    }
}

/// Shape + dtype of one tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        let shape = j
            .get("shape")
            .as_arr()
            .ok_or_else(|| anyhow!("spec missing shape"))?
            .iter()
            .map(|x| x.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = Dtype::parse(
            j.get("dtype").as_str().ok_or_else(|| anyhow!("missing dtype"))?,
        )?;
        Ok(TensorSpec { shape, dtype })
    }
}

/// One AOT-lowered entry point.
#[derive(Debug, Clone)]
pub struct EntryPoint {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Model hyperparameters baked into the artifacts.
#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub n_layers: usize,
    pub max_len: usize,
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelInfo,
    pub batch: usize,
    pub n_params: usize,
    pub param_names: Vec<String>,
    pub param_shapes: Vec<Vec<usize>>,
    pub entrypoints: BTreeMap<String, EntryPoint>,
}

impl Manifest {
    pub fn load(dir: &str) -> Result<Manifest> {
        let dir = Path::new(dir).to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json (run `make artifacts`)", dir.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let usize_field = |obj: &Json, k: &str| -> Result<usize> {
            obj.get(k).as_usize().ok_or_else(|| anyhow!("manifest missing {k}"))
        };
        let mj = j.get("model");
        let model = ModelInfo {
            vocab: usize_field(mj, "vocab")?,
            d_model: usize_field(mj, "d_model")?,
            n_heads: usize_field(mj, "n_heads")?,
            d_ff: usize_field(mj, "d_ff")?,
            n_layers: usize_field(mj, "n_layers")?,
            max_len: usize_field(mj, "max_len")?,
        };
        let param_names = j
            .get("param_names")
            .as_arr()
            .ok_or_else(|| anyhow!("missing param_names"))?
            .iter()
            .map(|x| x.as_str().unwrap_or("?").to_string())
            .collect::<Vec<_>>();
        let param_shapes = j
            .get("param_shapes")
            .as_arr()
            .ok_or_else(|| anyhow!("missing param_shapes"))?
            .iter()
            .map(|s| {
                s.as_arr()
                    .ok_or_else(|| anyhow!("bad shape"))?
                    .iter()
                    .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                    .collect::<Result<Vec<_>>>()
            })
            .collect::<Result<Vec<_>>>()?;
        let mut entrypoints = BTreeMap::new();
        let eps = j
            .get("entrypoints")
            .as_obj()
            .ok_or_else(|| anyhow!("missing entrypoints"))?;
        for (name, ej) in eps {
            let file = dir.join(
                ej.get("file").as_str().ok_or_else(|| anyhow!("missing file"))?,
            );
            let parse_specs = |key: &str| -> Result<Vec<TensorSpec>> {
                ej.get(key)
                    .as_arr()
                    .ok_or_else(|| anyhow!("missing {key}"))?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect()
            };
            entrypoints.insert(
                name.clone(),
                EntryPoint {
                    name: name.clone(),
                    file,
                    inputs: parse_specs("inputs")?,
                    outputs: parse_specs("outputs")?,
                },
            );
        }
        Ok(Manifest {
            dir,
            model,
            batch: usize_field(&j, "batch")?,
            n_params: usize_field(&j, "n_params")?,
            param_names,
            param_shapes,
            entrypoints,
        })
    }

    pub fn entry(&self, name: &str) -> Result<&EntryPoint> {
        self.entrypoints
            .get(name)
            .ok_or_else(|| anyhow!("entrypoint '{name}' not in manifest"))
    }

    /// Total parameter count of the model.
    pub fn total_params(&self) -> usize {
        self.param_shapes.iter().map(|s| s.iter().product::<usize>()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_available() -> bool {
        std::path::Path::new("artifacts/manifest.json").exists()
    }

    #[test]
    fn loads_real_manifest() {
        if !artifacts_available() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = Manifest::load("artifacts").unwrap();
        assert!(m.n_params > 10);
        assert_eq!(m.param_names.len(), m.n_params);
        assert_eq!(m.param_shapes.len(), m.n_params);
        assert!(m.total_params() > 100_000);
        for name in ["init", "forward", "logprobs", "grpo_train"] {
            let e = m.entry(name).unwrap();
            assert!(e.file.exists(), "{:?}", e.file);
        }
        // grpo_train threads 3 copies of the state + 6 aux inputs.
        let gt = m.entry("grpo_train").unwrap();
        assert_eq!(gt.inputs.len(), 3 * m.n_params + 6);
        assert_eq!(gt.outputs.len(), 3 * m.n_params + 2);
    }

    #[test]
    fn dtype_parsing() {
        assert_eq!(Dtype::parse("f32").unwrap(), Dtype::F32);
        assert_eq!(Dtype::parse("i32").unwrap(), Dtype::I32);
        assert!(Dtype::parse("f64").is_err());
    }

    #[test]
    fn missing_dir_is_helpful() {
        let err = Manifest::load("/nonexistent/artifacts").unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
