//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt` +
//! `manifest.json`) produced by `python/compile/aot.py` and executes
//! them from the rust request path. Python never runs at execution time.

pub mod artifacts;
pub mod client;
pub mod xla_stub;

pub use artifacts::{Dtype, EntryPoint, Manifest, TensorSpec};
pub use client::{DeviceTensors, HostTensor, Runtime};
