//! Stand-in for the `xla` (PJRT) bindings, which are not present in the
//! offline build image. The *host-side* literal operations are real —
//! shape/dtype bookkeeping, reshape, tuple access and round-tripping all
//! work, so [`super::client::HostTensor`] conversion is fully functional
//! without any XLA install. The *device-side* operations
//! ([`PjRtClient::cpu`], compile, execute) return a descriptive error:
//! wiring a real binding back in only requires deleting the
//! `use super::xla_stub as xla;` alias in `client.rs`.

use crate::util::error::{bail, Error, Result};

/// Element types the artifact manifest uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    U32,
}

/// Raw storage behind a [`Literal`] (public only because the sealed
/// [`NativeType`] trait mentions it; not part of the stable surface).
#[doc(hidden)]
#[derive(Debug, Clone, PartialEq)]
pub enum Storage {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U32(Vec<u32>),
    Tuple(Vec<Literal>),
}

/// Host-side literal: typed buffer + dims, or a tuple of literals.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    storage: Storage,
}

/// Shape of an array literal.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Sealed conversion trait for the native element types.
pub trait NativeType: Sized + Clone {
    fn wrap(data: Vec<Self>) -> Storage;
    fn unwrap(storage: &Storage) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(data: Vec<f32>) -> Storage {
        Storage::F32(data)
    }

    fn unwrap(storage: &Storage) -> Option<Vec<f32>> {
        match storage {
            Storage::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<i32>) -> Storage {
        Storage::I32(data)
    }

    fn unwrap(storage: &Storage) -> Option<Vec<i32>> {
        match storage {
            Storage::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for u32 {
    fn wrap(data: Vec<u32>) -> Storage {
        Storage::U32(data)
    }

    fn unwrap(storage: &Storage) -> Option<Vec<u32>> {
        match storage {
            Storage::U32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            dims: vec![data.len() as i64],
            storage: T::wrap(data.to_vec()),
        }
    }

    fn numel(&self) -> i64 {
        match &self.storage {
            Storage::F32(v) => v.len() as i64,
            Storage::I32(v) => v.len() as i64,
            Storage::U32(v) => v.len() as i64,
            Storage::Tuple(_) => -1,
        }
    }

    /// Reinterpret with new dims (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if matches!(self.storage, Storage::Tuple(_)) {
            bail!("cannot reshape a tuple literal");
        }
        if want != self.numel() {
            bail!("reshape {:?} -> {:?}: element count mismatch", self.dims, dims);
        }
        Ok(Literal { dims: dims.to_vec(), storage: self.storage.clone() })
    }

    /// Array shape (error for tuples).
    pub fn array_shape(&self) -> Result<ArrayShape> {
        let ty = match &self.storage {
            Storage::F32(_) => ElementType::F32,
            Storage::I32(_) => ElementType::S32,
            Storage::U32(_) => ElementType::U32,
            Storage::Tuple(_) => bail!("tuple literal has no array shape"),
        };
        Ok(ArrayShape { dims: self.dims.clone(), ty })
    }

    /// Copy out the typed data.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.storage)
            .ok_or_else(|| Error::msg("literal element type mismatch"))
    }

    /// Destructure a tuple literal.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.storage {
            Storage::Tuple(parts) => Ok(parts.clone()),
            _ => bail!("literal is not a tuple"),
        }
    }
}

const UNAVAILABLE: &str = "XLA/PJRT backend is not available in this build \
(runtime::xla_stub); install the xla bindings and drop the stub alias to \
enable real execution";

/// Parsed HLO module (never constructible through the stub).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        bail!("{UNAVAILABLE}")
    }
}

/// XLA computation wrapper.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device buffer handle returned by `execute`.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        bail!("{UNAVAILABLE}")
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        bail!("{UNAVAILABLE}")
    }
}

/// PJRT client handle.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        bail!("{UNAVAILABLE}")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        bail!("{UNAVAILABLE}")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        let shape = r.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 2]);
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(r.to_vec::<i32>().is_err());
        assert!(l.reshape(&[3, 2]).is_err());
        assert!(l.to_tuple().is_err());
    }

    #[test]
    fn device_side_fails_gracefully() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(format!("{err}").contains("not available"));
        assert!(HloModuleProto::from_text_file("x").is_err());
    }
}
