//! Memory model: `M_model(l)` and `M_working(l)` per tasklet, and the
//! decoding batch size `dbs_d` derived from what fits after weights
//! (feeds the HBM-bound decoding cost, Appendix B).

use crate::workflow::{JobConfig, RlTask, TaskKind};

/// Memory requirement of one tasklet (stage `j` of a task).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TaskletMemory {
    /// Persistent bytes: weights (+ optimizer state for training).
    pub model: f64,
    /// Peak transient bytes: activations / KV cache.
    pub working: f64,
}

/// Memory for one tasklet of `task`, holding `layers_j` layers under TP
/// degree `tp`, processing a local batch of `local_batch` sequences in
/// micro-batches of `mbs`.
pub fn tasklet_memory(
    task: &RlTask,
    job: &JobConfig,
    layers_j: usize,
    tp: usize,
    local_batch: usize,
) -> TaskletMemory {
    let m = &task.model;
    let seq = job.seq_total();
    match task.kind() {
        TaskKind::Training => TaskletMemory {
            model: m.train_state_bytes(layers_j, tp),
            working: m.activation_bytes(job.mbs, seq, layers_j, tp),
        },
        TaskKind::Inference => TaskletMemory {
            model: m.weight_bytes(layers_j, tp),
            // Forward-only scoring keeps ~4 live activation tensors.
            working: 4.0 * crate::util::units::B_BF16
                * job.mbs as f64
                * seq as f64
                * m.h1 as f64
                / tp as f64,
        },
        TaskKind::Generation => {
            let weights = m.weight_bytes(layers_j, tp);
            // KV cache for the decode batch; `dbs` is derived elsewhere,
            // here we budget for at least one sequence so feasibility is
            // conservative but not impossible.
            let one_seq_kv = m.kv_cache_bytes(1, seq, layers_j, tp);
            TaskletMemory { model: weights, working: one_seq_kv.min(local_batch as f64 * one_seq_kv) }
        }
    }
}

/// Decoding batch size `dbs_d` on a device with `mem_bytes` capacity:
/// how many sequences' KV cache fit beside the weights, clamped to
/// `[1, local_batch]` and scaled by the job's `decode_batch_frac`.
pub fn decode_batch_size(
    task: &RlTask,
    job: &JobConfig,
    layers_j: usize,
    tp: usize,
    local_batch: usize,
    mem_bytes: f64,
) -> usize {
    debug_assert_eq!(task.kind(), TaskKind::Generation);
    let m = &task.model;
    let weights = m.weight_bytes(layers_j, tp);
    let one_seq_kv = m.kv_cache_bytes(1, job.seq_total(), layers_j, tp);
    let free = (mem_bytes * 0.9 - weights).max(0.0);
    let fit = (free / one_seq_kv).floor() as usize;
    ((fit as f64 * job.decode_batch_frac) as usize).clamp(1, local_batch.max(1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::GIB;
    use crate::workflow::{ModelSpec, RlTaskId};

    fn task(id: RlTaskId) -> RlTask {
        RlTask { id, model: ModelSpec::qwen_4b() }
    }

    #[test]
    fn training_needs_most_model_memory() {
        let job = JobConfig::default();
        let t_train = tasklet_memory(&task(RlTaskId::ActorTrain), &job, 36, 1, 96);
        let t_inf = tasklet_memory(&task(RlTaskId::RefInf), &job, 36, 1, 96);
        let t_gen = tasklet_memory(&task(RlTaskId::ActorGen), &job, 36, 1, 96);
        assert!(t_train.model > 8.0 * t_inf.model); // 18 vs 2 bytes/param
        assert!((t_inf.model - t_gen.model).abs() < 1e-6);
    }

    #[test]
    fn tp_divides_memory() {
        let job = JobConfig::default();
        let t1 = tasklet_memory(&task(RlTaskId::ActorTrain), &job, 36, 1, 96);
        let t4 = tasklet_memory(&task(RlTaskId::ActorTrain), &job, 36, 4, 96);
        assert!((t1.model / t4.model - 4.0).abs() < 1e-9);
    }

    #[test]
    fn decode_batch_respects_memory() {
        let job = JobConfig::default();
        let gen = task(RlTaskId::ActorGen);
        // A100-40G, full 36-layer model TP1: a handful of 2k-token KV
        // caches fit.
        let dbs_small = decode_batch_size(&gen, &job, 36, 1, 384, 40.0 * GIB);
        let dbs_big = decode_batch_size(&gen, &job, 36, 1, 384, 80.0 * GIB);
        assert!(dbs_small >= 1);
        assert!(dbs_big > dbs_small);
        // Splitting layers across 4 pipeline stages frees memory.
        let dbs_pp = decode_batch_size(&gen, &job, 9, 1, 384, 40.0 * GIB);
        assert!(dbs_pp > dbs_small);
    }

    #[test]
    fn decode_batch_clamped_to_local_batch() {
        let job = JobConfig::tiny();
        let gen = task(RlTaskId::ActorGen);
        let dbs = decode_batch_size(&gen, &job, 4, 1, 4, 1000.0 * GIB);
        assert_eq!(dbs, 4);
    }
}
