//! The plan layer: partitioning strategy ρ (task grouping + intra-model
//! parallelization → tasklet graph `G_L`) and assignment strategy σ
//! (tasklet → device), with the paper's feasibility constraints C1–C3.

pub mod parallel;
pub mod memory;
pub mod plan;

pub use parallel::ParallelStrategy;
pub use plan::{ExecutionPlan, PlanError, TaskPlan};
