//! Intra-model parallelization strategies (Level 4): DP × PP × TP degree
//! triples `(i, j, k)` with `i·j·k ≤ n_t` (paper §3.2 search-space
//! analysis), plus enumeration helpers.

/// A parallelization strategy for one task.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ParallelStrategy {
    pub dp: usize,
    pub pp: usize,
    pub tp: usize,
}

impl ParallelStrategy {
    pub fn new(dp: usize, pp: usize, tp: usize) -> Self {
        assert!(dp >= 1 && pp >= 1 && tp >= 1);
        ParallelStrategy { dp, pp, tp }
    }

    /// Number of tasklets (= devices used) under this strategy.
    pub fn degree(&self) -> usize {
        self.dp * self.pp * self.tp
    }

    /// Flattened tasklet index for `(i, j, k)` = (dp, pp, tp) coordinates.
    #[inline]
    pub fn tasklet_index(&self, i: usize, j: usize, k: usize) -> usize {
        debug_assert!(i < self.dp && j < self.pp && k < self.tp);
        (i * self.pp + j) * self.tp + k
    }

    /// Inverse of [`Self::tasklet_index`].
    #[inline]
    pub fn tasklet_coords(&self, idx: usize) -> (usize, usize, usize) {
        let k = idx % self.tp;
        let j = (idx / self.tp) % self.pp;
        let i = idx / (self.tp * self.pp);
        (i, j, k)
    }

    pub fn label(&self) -> String {
        format!("dp{}·pp{}·tp{}", self.dp, self.pp, self.tp)
    }

    /// Enumerate feasible strategies for a group of `n` GPUs and a model
    /// of `nl` layers:
    /// * `tp` a power of two ≤ 8 (all-reduce rings degrade fast beyond a
    ///   machine; matches Megatron practice),
    /// * `pp ≤ nl` and `pp ≤ 16`,
    /// * `dp·pp·tp ≤ n`, and at least `utilization · n` GPUs used (the
    ///   scheduler passes 0.5 by default so mostly-idle plans are pruned
    ///   but deliberately-undersized ones remain reachable).
    pub fn enumerate(n: usize, nl: usize, utilization: f64) -> Vec<ParallelStrategy> {
        let mut out = Vec::new();
        let min_used = ((n as f64) * utilization).ceil() as usize;
        for tp in [1usize, 2, 4, 8] {
            if tp > n {
                break;
            }
            let mut pp = 1;
            while pp <= nl.min(16) && tp * pp <= n {
                for dp in 1..=(n / (tp * pp)) {
                    let used = dp * pp * tp;
                    if used >= min_used.max(1) {
                        out.push(ParallelStrategy::new(dp, pp, tp));
                    }
                }
                pp *= 2;
            }
        }
        out.sort_by_key(|s| (std::cmp::Reverse(s.degree()), s.tp, s.pp));
        out
    }
}

/// Split `nl` layers into `pp` pipeline stages as evenly as possible
/// (earlier stages take the remainder). The layer-level load balancer
/// replaces this with a cost-model-driven split.
pub fn uniform_layer_split(nl: usize, pp: usize) -> Vec<usize> {
    assert!(pp >= 1 && nl >= pp, "need at least one layer per stage");
    let base = nl / pp;
    let extra = nl % pp;
    (0..pp).map(|j| base + usize::from(j < extra)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, Gen};

    #[test]
    fn degree_and_indexing_roundtrip() {
        let s = ParallelStrategy::new(3, 4, 2);
        assert_eq!(s.degree(), 24);
        for idx in 0..s.degree() {
            let (i, j, k) = s.tasklet_coords(idx);
            assert_eq!(s.tasklet_index(i, j, k), idx);
        }
    }

    #[test]
    fn enumerate_respects_bounds() {
        let strategies = ParallelStrategy::enumerate(16, 36, 0.5);
        assert!(!strategies.is_empty());
        for s in &strategies {
            assert!(s.degree() <= 16);
            assert!(s.degree() >= 8); // 0.5 utilization floor
            assert!([1, 2, 4, 8].contains(&s.tp));
            assert!(s.pp <= 16);
        }
        // Full-utilization strategies come first.
        assert_eq!(strategies[0].degree(), 16);
    }

    #[test]
    fn enumerate_small_groups() {
        let s1 = ParallelStrategy::enumerate(1, 36, 0.5);
        assert_eq!(s1, vec![ParallelStrategy::new(1, 1, 1)]);
        let s3 = ParallelStrategy::enumerate(3, 36, 0.9);
        // 3 GPUs at 90%: dp3, or dp1·pp?·tp? combos of degree 3
        assert!(s3.iter().all(|s| s.degree() == 3));
    }

    #[test]
    fn uniform_split_sums() {
        assert_eq!(uniform_layer_split(36, 4), vec![9, 9, 9, 9]);
        assert_eq!(uniform_layer_split(10, 4), vec![3, 3, 2, 2]);
        assert_eq!(uniform_layer_split(5, 1), vec![5]);
    }

    #[test]
    fn prop_uniform_split_invariants() {
        check(
            "uniform layer split sums to nl, stages within 1 of each other",
            300,
            Gen::pair(Gen::usize_range(1, 96), Gen::usize_range(1, 16)),
            |&(nl, pp)| {
                if pp > nl {
                    return true; // precondition
                }
                let split = uniform_layer_split(nl, pp);
                let sum: usize = split.iter().sum();
                let min = *split.iter().min().unwrap();
                let max = *split.iter().max().unwrap();
                split.len() == pp && sum == nl && max - min <= 1 && min >= 1
            },
        );
    }
}
