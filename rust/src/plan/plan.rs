//! Execution plans: the joint `(ρ, σ)` object the schedulers search over
//! and the simulator/engine execute, with validation of the paper's
//! constraints:
//!
//! * **C1** — each task's tasklet count ≤ number of devices;
//! * **C2** — every tasklet is assigned a device (σ total);
//! * **C3** — per device: `max_l M_working(l) + Σ_l M_model(l) ≤ M_gpu(d)`.

use super::memory::tasklet_memory;
use super::parallel::{uniform_layer_split, ParallelStrategy};
use crate::topology::DeviceTopology;
use crate::workflow::{JobConfig, RlWorkflow};

/// Plan for one task: strategy + layer split + σ restricted to the task.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskPlan {
    pub strategy: ParallelStrategy,
    /// Layers per pipeline stage (len == pp, sums to the model's nl).
    pub layer_split: Vec<usize>,
    /// Device id per tasklet, indexed by
    /// [`ParallelStrategy::tasklet_index`]. Injective within a task.
    pub assignment: Vec<usize>,
    /// Fraction of the task's micro-batches per DP replica (len == dp,
    /// sums to 1). Uniform unless the data-level load balancer ran.
    pub dp_shares: Vec<f64>,
}

impl TaskPlan {
    /// Build with uniform layer split and uniform DP shares.
    pub fn uniform(strategy: ParallelStrategy, nl: usize, assignment: Vec<usize>) -> TaskPlan {
        assert_eq!(assignment.len(), strategy.degree());
        TaskPlan {
            layer_split: uniform_layer_split(nl, strategy.pp),
            dp_shares: vec![1.0 / strategy.dp as f64; strategy.dp],
            strategy,
            assignment,
        }
    }

    /// Devices of the TP subgraph `G_D^{t}_{i,j}` (replica i, stage j).
    pub fn tp_group(&self, i: usize, j: usize) -> Vec<usize> {
        (0..self.strategy.tp)
            .map(|k| self.assignment[self.strategy.tasklet_index(i, j, k)])
            .collect()
    }

    /// Devices of the DP subgraph `G_D^{t}_{j,k}` (stage j, shard k).
    pub fn dp_group(&self, j: usize, k: usize) -> Vec<usize> {
        (0..self.strategy.dp)
            .map(|i| self.assignment[self.strategy.tasklet_index(i, j, k)])
            .collect()
    }

    /// Devices of replica i (all stages and shards): `V_D^{t}_i`.
    pub fn replica_devices(&self, i: usize) -> Vec<usize> {
        (0..self.strategy.pp)
            .flat_map(|j| self.tp_group(i, j))
            .collect()
    }

    /// All devices the task touches.
    pub fn devices(&self) -> Vec<usize> {
        let mut v = self.assignment.clone();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Micro-batch count for replica `i` given the task's total `nm`.
    pub fn replica_microbatches(&self, nm_total: usize, i: usize) -> usize {
        ((nm_total as f64) * self.dp_shares[i]).round().max(1.0) as usize
    }
}

/// Complete execution plan for a workflow.
#[derive(Debug, Clone, PartialEq)]
pub struct ExecutionPlan {
    /// Level 1: partition of task indices into colocated groups.
    pub task_groups: Vec<Vec<usize>>,
    /// Levels 2–3: device ids per task group (disjoint across groups).
    pub gpu_groups: Vec<Vec<usize>>,
    /// Levels 4–5: per-task plan, indexed by workflow task index.
    pub task_plans: Vec<TaskPlan>,
}

/// Plan validation failure.
#[derive(Debug, Clone, PartialEq)]
pub enum PlanError {
    BadTaskGrouping,
    BadGpuGrouping,
    TooManyTasklets { task: usize, tasklets: usize, devices: usize },
    AssignmentOutsideGroup { task: usize, device: usize },
    DuplicateDevice { task: usize, device: usize },
    BadLayerSplit { task: usize },
    BadDpShares { task: usize },
    OutOfMemory { device: usize, need_gib: f64, cap_gib: f64 },
}

impl std::fmt::Display for PlanError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PlanError::BadTaskGrouping => {
                write!(f, "task groups are not a partition of the workflow's tasks")
            }
            PlanError::BadGpuGrouping => {
                write!(f, "gpu groups overlap or reference unknown devices")
            }
            PlanError::TooManyTasklets { task, tasklets, devices } => write!(
                f,
                "task {task}: tasklet count {tasklets} exceeds devices {devices} (C1)"
            ),
            PlanError::AssignmentOutsideGroup { task, device } => write!(
                f,
                "task {task}: assignment uses device {device} outside its gpu group"
            ),
            PlanError::DuplicateDevice { task, device } => write!(
                f,
                "task {task}: device {device} assigned more than one tasklet of the task"
            ),
            PlanError::BadLayerSplit { task } => write!(f, "task {task}: layer split invalid"),
            PlanError::BadDpShares { task } => write!(f, "task {task}: dp shares invalid"),
            PlanError::OutOfMemory { device, need_gib, cap_gib } => write!(
                f,
                "device {device}: memory over capacity ({need_gib:.1} GiB > {cap_gib:.1} GiB) (C3)"
            ),
        }
    }
}

impl std::error::Error for PlanError {}

impl ExecutionPlan {
    /// Which task group a task belongs to.
    pub fn group_of_task(&self, task: usize) -> usize {
        self.task_groups
            .iter()
            .position(|g| g.contains(&task))
            .expect("task not in any group")
    }

    /// Validate C1–C3 plus structural well-formedness.
    pub fn validate(
        &self,
        wf: &RlWorkflow,
        topo: &DeviceTopology,
        job: &JobConfig,
    ) -> Result<(), PlanError> {
        let t_count = wf.n_tasks();
        // ρ: task groups partition tasks.
        let mut seen = vec![false; t_count];
        for g in &self.task_groups {
            for &t in g {
                if t >= t_count || seen[t] {
                    return Err(PlanError::BadTaskGrouping);
                }
                seen[t] = true;
            }
        }
        if !seen.iter().all(|&s| s) || self.task_groups.len() != self.gpu_groups.len() {
            return Err(PlanError::BadTaskGrouping);
        }
        // GPU groups: disjoint, valid ids.
        let mut dev_seen = vec![false; topo.n()];
        for g in &self.gpu_groups {
            for &d in g {
                if d >= topo.n() || dev_seen[d] {
                    return Err(PlanError::BadGpuGrouping);
                }
                dev_seen[d] = true;
            }
        }
        if self.task_plans.len() != t_count {
            return Err(PlanError::BadTaskGrouping);
        }
        // Per-task checks.
        for (t, tp) in self.task_plans.iter().enumerate() {
            let group = &self.gpu_groups[self.group_of_task(t)];
            let s = &tp.strategy;
            if s.degree() > topo.n() {
                return Err(PlanError::TooManyTasklets {
                    task: t,
                    tasklets: s.degree(),
                    devices: topo.n(),
                });
            }
            if tp.assignment.len() != s.degree() {
                return Err(PlanError::TooManyTasklets {
                    task: t,
                    tasklets: tp.assignment.len(),
                    devices: s.degree(),
                });
            }
            let mut used = std::collections::BTreeSet::new();
            for &d in &tp.assignment {
                if !group.contains(&d) {
                    return Err(PlanError::AssignmentOutsideGroup { task: t, device: d });
                }
                if !used.insert(d) {
                    return Err(PlanError::DuplicateDevice { task: t, device: d });
                }
            }
            let nl = wf.tasks[t].model.nl;
            if tp.layer_split.len() != s.pp
                || tp.layer_split.iter().sum::<usize>() != nl
                || tp.layer_split.iter().any(|&l| l == 0)
            {
                return Err(PlanError::BadLayerSplit { task: t });
            }
            if tp.dp_shares.len() != s.dp
                || (tp.dp_shares.iter().sum::<f64>() - 1.0).abs() > 1e-6
                || tp.dp_shares.iter().any(|&x| x <= 0.0)
            {
                return Err(PlanError::BadDpShares { task: t });
            }
        }
        // C3: memory per device.
        self.check_memory(wf, topo, job)
    }

    /// C3 check: `max_l M_working + Σ_l M_model ≤ M_gpu` per device.
    pub fn check_memory(
        &self,
        wf: &RlWorkflow,
        topo: &DeviceTopology,
        job: &JobConfig,
    ) -> Result<(), PlanError> {
        let mut model_sum = vec![0.0f64; topo.n()];
        let mut working_max = vec![0.0f64; topo.n()];
        for (t, tp) in self.task_plans.iter().enumerate() {
            let task = &wf.tasks[t];
            let s = &tp.strategy;
            let local_batch = (job.total_samples() as f64 / s.dp as f64).ceil() as usize;
            for idx in 0..s.degree() {
                let (_, j, _) = s.tasklet_coords(idx);
                let mem = tasklet_memory(task, job, tp.layer_split[j], s.tp, local_batch);
                let d = tp.assignment[idx];
                model_sum[d] += mem.model;
                working_max[d] = working_max[d].max(mem.working);
            }
        }
        for d in 0..topo.n() {
            let need = model_sum[d] + working_max[d];
            let cap = topo.devices[d].spec().mem_bytes;
            if need > cap {
                return Err(PlanError::OutOfMemory {
                    device: d,
                    need_gib: need / crate::util::units::GIB,
                    cap_gib: cap / crate::util::units::GIB,
                });
            }
        }
        Ok(())
    }

    /// Order-sensitive FNV-1a digest over every field of the plan:
    /// groupings, then each task's strategy, layer split, device
    /// assignment, and DP shares (as IEEE-754 bits). Each list is
    /// length-prefixed and each field domain-tagged, so two plans share
    /// a fingerprint iff they are structurally identical. Used by
    /// `hetrl schedule` (and the CI delta-vs-full smoke that diffs its
    /// output) to compare plans across process runs.
    pub fn fingerprint(&self) -> u64 {
        struct Fnv(u64);
        impl Fnv {
            fn mix(&mut self, v: u64) {
                self.0 ^= v;
                self.0 = self.0.wrapping_mul(0x0000_0100_0000_01B3);
            }
            fn list(&mut self, tag: u8, items: impl ExactSizeIterator<Item = u64>) {
                self.mix(tag as u64);
                self.mix(items.len() as u64);
                for v in items {
                    self.mix(v);
                }
            }
        }
        let mut f = Fnv(0xcbf2_9ce4_8422_2325);
        f.mix(0xB0);
        f.mix(self.task_groups.len() as u64);
        for tg in &self.task_groups {
            f.list(0xB1, tg.iter().map(|&t| t as u64));
        }
        for gg in &self.gpu_groups {
            f.list(0xB2, gg.iter().map(|&d| d as u64));
        }
        for tp in &self.task_plans {
            f.mix(0xB3);
            f.mix(tp.strategy.dp as u64);
            f.mix(tp.strategy.pp as u64);
            f.mix(tp.strategy.tp as u64);
            f.list(0xB4, tp.layer_split.iter().map(|&x| x as u64));
            f.list(0xB5, tp.assignment.iter().map(|&d| d as u64));
            f.list(0xB6, tp.dp_shares.iter().map(|s| s.to_bits()));
        }
        f.0
    }

    /// Human-readable plan dump.
    pub fn describe(&self, wf: &RlWorkflow, topo: &DeviceTopology) -> String {
        let mut s = String::new();
        for (gi, (tg, gg)) in self.task_groups.iter().zip(&self.gpu_groups).enumerate() {
            let names: Vec<&str> = tg.iter().map(|&t| wf.tasks[t].id.name()).collect();
            s.push_str(&format!(
                "group {gi}: tasks [{}] on {} GPUs\n",
                names.join(", "),
                gg.len()
            ));
            for &t in tg {
                let tp = &self.task_plans[t];
                let devs = tp.devices();
                let census: Vec<String> = {
                    let sub = devs.iter().map(|&d| topo.devices[d].gpu).collect::<Vec<_>>();
                    let mut counts: Vec<(String, usize)> = Vec::new();
                    for g in sub {
                        let name = g.spec().name.to_string();
                        match counts.iter_mut().find(|(n, _)| *n == name) {
                            Some((_, c)) => *c += 1,
                            None => counts.push((name, 1)),
                        }
                    }
                    counts.into_iter().map(|(n, c)| format!("{c}×{n}")).collect()
                };
                s.push_str(&format!(
                    "  {}: {} layers {:?} on [{}]\n",
                    wf.tasks[t].id.name(),
                    tp.strategy.label(),
                    tp.layer_split,
                    census.join(", ")
                ));
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{build_testbed, Scenario, TestbedSpec};
    use crate::workflow::{Algo, Mode, ModelSpec};

    fn setup() -> (RlWorkflow, DeviceTopology, JobConfig) {
        let wf = RlWorkflow::new(Algo::Grpo, Mode::Sync, ModelSpec::qwen_4b());
        let topo = build_testbed(Scenario::SingleRegion, &TestbedSpec::default());
        (wf, topo, JobConfig::default())
    }

    /// A simple valid plan: all 4 GRPO tasks in one group over all GPUs,
    /// each task on a disjoint 16-GPU slice.
    fn simple_plan(wf: &RlWorkflow, topo: &DeviceTopology) -> ExecutionPlan {
        let all: Vec<usize> = (0..topo.n()).collect();
        let mut task_plans = Vec::new();
        for (t, task) in wf.tasks.iter().enumerate() {
            let s = ParallelStrategy::new(2, 2, 4); // 16 GPUs
            let devs: Vec<usize> = (t * 16..(t + 1) * 16).collect();
            task_plans.push(TaskPlan::uniform(s, task.model.nl, devs));
        }
        ExecutionPlan {
            task_groups: vec![(0..wf.n_tasks()).collect()],
            gpu_groups: vec![all],
            task_plans,
        }
    }

    #[test]
    fn valid_plan_passes() {
        let (wf, topo, job) = setup();
        let plan = simple_plan(&wf, &topo);
        plan.validate(&wf, &topo, &job).unwrap();
    }

    #[test]
    fn fingerprint_tracks_structural_identity() {
        let (wf, topo, _) = setup();
        let plan = simple_plan(&wf, &topo);
        assert_eq!(plan.fingerprint(), plan.clone().fingerprint());
        let mut swapped = plan.clone();
        swapped.task_plans[0].assignment.swap(0, 1);
        assert_ne!(plan.fingerprint(), swapped.fingerprint());
        let mut reshared = plan.clone();
        reshared.task_plans[1].dp_shares = vec![0.75, 0.25];
        assert_ne!(plan.fingerprint(), reshared.fingerprint());
    }

    #[test]
    fn duplicate_device_rejected() {
        let (wf, topo, job) = setup();
        let mut plan = simple_plan(&wf, &topo);
        plan.task_plans[0].assignment[1] = plan.task_plans[0].assignment[0];
        assert!(matches!(
            plan.validate(&wf, &topo, &job),
            Err(PlanError::DuplicateDevice { .. })
        ));
    }

    #[test]
    fn assignment_outside_group_rejected() {
        let (wf, topo, job) = setup();
        let mut plan = simple_plan(&wf, &topo);
        plan.gpu_groups[0].retain(|&d| d != 0); // drop device 0 from group
        assert!(matches!(
            plan.validate(&wf, &topo, &job),
            Err(PlanError::AssignmentOutsideGroup { .. }) | Err(PlanError::BadGpuGrouping)
        ));
    }

    #[test]
    fn bad_layer_split_rejected() {
        let (wf, topo, job) = setup();
        let mut plan = simple_plan(&wf, &topo);
        plan.task_plans[0].layer_split[0] += 1; // no longer sums to nl
        assert!(matches!(
            plan.validate(&wf, &topo, &job),
            Err(PlanError::BadLayerSplit { .. })
        ));
    }

    #[test]
    fn oom_detected_for_oversized_model() {
        let (_, topo, job) = setup();
        // 14B on a single L4 (24 GiB) cannot hold training state.
        let wf = RlWorkflow::new(Algo::Grpo, Mode::Sync, ModelSpec::qwen_14b());
        let l4 = topo
            .devices
            .iter()
            .find(|d| d.spec().name == "L4")
            .unwrap()
            .id;
        let mut plan = simple_plan(&wf, &topo);
        // Put actor training entirely on one L4.
        let t = wf.task_index(crate::workflow::RlTaskId::ActorTrain).unwrap();
        plan.task_plans[t] = TaskPlan::uniform(
            ParallelStrategy::new(1, 1, 1),
            wf.tasks[t].model.nl,
            vec![l4],
        );
        assert!(matches!(
            plan.validate(&wf, &topo, &job),
            Err(PlanError::OutOfMemory { .. })
        ));
    }

    #[test]
    fn subgroup_accessors() {
        let s = ParallelStrategy::new(2, 3, 2);
        let tp = TaskPlan::uniform(s, 6, (0..12).collect());
        assert_eq!(tp.tp_group(0, 0), vec![0, 1]);
        assert_eq!(tp.tp_group(1, 2), vec![10, 11]);
        assert_eq!(tp.dp_group(0, 0), vec![0, 6]);
        assert_eq!(tp.replica_devices(0), vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn replica_microbatches_follow_shares() {
        let s = ParallelStrategy::new(2, 1, 1);
        let mut tp = TaskPlan::uniform(s, 4, vec![0, 1]);
        assert_eq!(tp.replica_microbatches(100, 0), 50);
        tp.dp_shares = vec![0.75, 0.25];
        assert_eq!(tp.replica_microbatches(100, 0), 75);
        assert_eq!(tp.replica_microbatches(100, 1), 25);
    }
}
