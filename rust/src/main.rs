//! HetRL CLI — the leader entry point.
//!
//! Subcommands:
//!   profile              probe the (simulated) fleet and print hardware info
//!   schedule             search for an execution plan and print it
//!   simulate             schedule + run the discrete-event simulator
//!   validate-cost-model  predicted vs simulated iteration time
//!   train                real GRPO training over the AOT artifacts
//!   info                 artifact manifest summary
//!   lint                 detlint determinism/concurrency static analysis

use hetrl::balance::{self, BalanceConfig};
use hetrl::costmodel::{CostModel, MigrationModel, RecoveryModel};
use hetrl::elastic::{
    self, first_event_iter, generate_trace, CkptSearchConfig, Policy, ReplanConfig, ReplayConfig,
    TraceConfig,
};
use hetrl::engine::{GrpoConfig, GrpoTrainer, TaskDifficulty, WorkerFleet};
use hetrl::profiler::{profile, ProfilerConfig};
use hetrl::runtime::Runtime;
use hetrl::scheduler::{
    Budget, IlpScheduler, PureEaScheduler, RandomScheduler, Scheduler, ShaEaScheduler,
    StreamRlScheduler, VerlScheduler,
};
use hetrl::simulator::{simulate_plan, SimConfig};
use hetrl::topology::{build_testbed, Scenario, TestbedSpec};
use hetrl::util::cli::{usage, Args, OptSpec};
use hetrl::util::units::{fmt_secs, GBITPS_BYTES};
use hetrl::workflow::{Algo, JobConfig, Mode, ModelSpec, RlWorkflow};

fn main() {
    hetrl::util::logging::init();
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    let code = match args.subcommand.as_deref() {
        Some("profile") => cmd_profile(&args),
        Some("schedule") => cmd_schedule(&args, false),
        Some("simulate") => cmd_schedule(&args, true),
        Some("validate-cost-model") => cmd_validate(&args),
        Some("replay") => cmd_replay(&args),
        Some("train") => cmd_train(&args),
        Some("info") => cmd_info(&args),
        Some("lint") => cmd_lint(&args),
        _ => {
            print!("{}", help());
            if args.subcommand.is_none() { 0 } else { 2 }
        }
    };
    std::process::exit(code);
}

fn help() -> String {
    usage(
        "hetrl",
        &[
            ("profile", "probe the fleet, print hardware summary"),
            ("schedule", "search for an execution plan"),
            ("simulate", "schedule + discrete-event simulation"),
            ("validate-cost-model", "predicted vs simulated iteration time"),
            ("replay", "dynamic trace: plan -> event -> replan -> resume"),
            ("train", "real GRPO training over artifacts/"),
            ("info", "artifact manifest summary"),
            ("lint", "detlint: determinism & concurrency static analysis"),
        ],
        &[
            OptSpec { name: "scenario", help: "single|hybrid|country|continent", default: Some("country") },
            OptSpec { name: "model", help: "qwen model: 1.7b|4b|8b|14b", default: Some("8b") },
            OptSpec { name: "algo", help: "ppo|grpo", default: Some("grpo") },
            OptSpec { name: "mode", help: "sync|async", default: Some("sync") },
            OptSpec { name: "scheduler", help: "sha-ea|ilp|verl|streamrl|deap|random", default: Some("sha-ea") },
            OptSpec { name: "budget", help: "search budget (cost-model evals)", default: Some("600") },
            OptSpec { name: "threads", help: "search worker threads (0 = all cores)", default: Some("0") },
            OptSpec { name: "seed", help: "random seed", default: Some("0") },
            OptSpec { name: "iters", help: "replay: iterations to replay", default: Some("24") },
            OptSpec { name: "events", help: "replay: cluster events in the trace", default: Some("5") },
            OptSpec { name: "policy", help: "replay: static|warm|anytime|preempt|oracle|all", default: Some("all") },
            OptSpec { name: "workflow", help: "replay: sync|async workflow model", default: Some("sync") },
            OptSpec { name: "staleness", help: "replay --workflow async: staleness bound k (0 = sync)", default: Some("2") },
            OptSpec { name: "queue-cap", help: "replay --workflow async: rollout-queue capacity", default: Some("2") },
            OptSpec { name: "window", help: "replay --workflow async: pipeline steps per iteration", default: Some("8") },
            OptSpec { name: "warm-budget", help: "replay: evals per warm replan", default: Some("150") },
            OptSpec { name: "anytime-rate", help: "replay: background evals per simulated second", default: Some("0.5") },
            OptSpec { name: "notice-secs", help: "replay: pin machine-loss advance notice (0 = none; default: realistic drawn notice)", default: None },
            OptSpec { name: "shuffle-seed", help: "replay: permute same-timestamp DES ready ties with this seed (metrics are invariant; unset = FIFO)", default: None },
            OptSpec { name: "faults", help: "replay: seed N transient faults and enable recovery pricing (bare flag = 4)", default: None },
            OptSpec { name: "ckpt-interval", help: "replay: checkpoint cadence in secs, or 'auto' to search it (enables recovery)", default: None },
            OptSpec { name: "max-retries", help: "replay: retry budget per transient fault", default: Some("3") },
            OptSpec { name: "ckpt-bw", help: "checkpoint-store bandwidth in Gbit/s (prices migrations restores + ckpt writes)", default: Some("2.5") },
            OptSpec { name: "tiny", help: "replay: scaled-down job (flag)", default: None },
            OptSpec { name: "steps", help: "train: number of GRPO steps", default: Some("100") },
            OptSpec { name: "artifacts", help: "artifacts directory", default: Some("artifacts") },
            OptSpec { name: "no-balance", help: "disable load balancing (flag)", default: None },
            OptSpec { name: "full-eval", help: "schedule: disable delta-eval, re-price every task per candidate (flag)", default: None },
            OptSpec { name: "hard", help: "train: MATH-like tasks (flag)", default: None },
            OptSpec { name: "fix-allow", help: "lint: strip unused detlint:allow directives (flag)", default: None },
            OptSpec { name: "rules", help: "lint: print the rule registry and exit (flag)", default: None },
        ],
    )
}

fn parse_env(args: &Args) -> Result<(RlWorkflow, hetrl::topology::DeviceTopology, JobConfig), String> {
    let scenario = Scenario::parse(&args.get_or("scenario", "country"))
        .ok_or("bad --scenario")?;
    let model = ModelSpec::by_name(&args.get_or("model", "8b")).ok_or("bad --model")?;
    let algo = match args.get_or("algo", "grpo").as_str() {
        "ppo" => Algo::Ppo,
        "grpo" => Algo::Grpo,
        _ => return Err("bad --algo".into()),
    };
    let mode = match args.get_or("mode", "sync").as_str() {
        "sync" => Mode::Sync,
        "async" => Mode::Async,
        _ => return Err("bad --mode".into()),
    };
    let topo = build_testbed(scenario, &TestbedSpec::default());
    Ok((RlWorkflow::new(algo, mode, model), topo, JobConfig::default()))
}

fn make_scheduler(
    name: &str,
    seed: u64,
    threads: usize,
    full_eval: bool,
) -> Result<Box<dyn Scheduler>, String> {
    Ok(match name {
        "sha-ea" => {
            let mut s = ShaEaScheduler::with_threads(seed, threads);
            s.cfg.ea.delta_eval = !full_eval;
            Box::new(s)
        }
        "ilp" => Box::new(IlpScheduler::new()),
        "verl" => Box::new(VerlScheduler::new(seed)),
        "streamrl" => Box::new(StreamRlScheduler::new(seed)),
        "deap" => {
            let mut s = PureEaScheduler::new(seed);
            s.threads = threads;
            s.cfg.delta_eval = !full_eval;
            Box::new(s)
        }
        "random" => Box::new(RandomScheduler::new(seed)),
        other => return Err(format!("unknown scheduler '{other}'")),
    })
}

fn cmd_profile(args: &Args) -> i32 {
    let Ok((_, topo, _)) = parse_env(args) else { return 2 };
    let report = profile(&topo, &ProfilerConfig::default());
    print!("{}", report.summary(&topo));
    0
}

fn cmd_schedule(args: &Args, also_simulate: bool) -> i32 {
    let (wf, topo, job) = match parse_env(args) {
        Ok(x) => x,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let seed = args.get_u64("seed", 0).unwrap_or(0);
    let budget = args.get_usize("budget", 600).unwrap_or(600);
    let threads = args.get_usize("threads", 0).unwrap_or(0);
    let full_eval = args.flag("full-eval");
    let mut sched =
        match make_scheduler(&args.get_or("scheduler", "sha-ea"), seed, threads, full_eval) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    println!(
        "scheduling {} of {} on {} GPUs ({}) with {} (budget {budget})",
        wf.name(),
        wf.tasks[0].model.name,
        topo.n(),
        args.get_or("scenario", "country"),
        sched.name()
    );
    let out = sched.schedule(&topo, &wf, &job, Budget::timed(budget, 600.0));
    let Some(mut plan) = out.plan else {
        eprintln!("no feasible plan found");
        return 1;
    };
    if !args.flag("no-balance") {
        plan = balance::apply(&plan, &wf, &topo, BalanceConfig::default());
    }
    let lookups = out.cache_hits + out.cache_misses;
    println!(
        "search: {} evals in {} ({} cache hits / {} lookups, {} task pricings) -> predicted iteration {}",
        out.evals,
        fmt_secs(out.wall),
        out.cache_hits,
        lookups,
        out.task_pricings,
        fmt_secs(out.cost)
    );
    println!("plan fingerprint: {:016x}", plan.fingerprint());
    print!("{}", plan.describe(&wf, &topo));
    let cm = CostModel::new(&topo, &wf, &job);
    let cost = cm.plan_cost(&plan);
    println!(
        "predicted: iter {} | throughput {:.1} samples/s",
        fmt_secs(cost.iter_time),
        cost.throughput(&job)
    );
    if also_simulate {
        let sim = simulate_plan(&topo, &wf, &job, &plan, &SimConfig::default());
        println!(
            "simulated: iter {} +- {} | throughput {:.1} samples/s | util {:.0}%",
            fmt_secs(sim.iter_time),
            fmt_secs(sim.iter_std),
            sim.throughput,
            sim.utilization * 100.0
        );
    }
    0
}

fn cmd_validate(args: &Args) -> i32 {
    let (wf, topo, job) = match parse_env(args) {
        Ok(x) => x,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let seed = args.get_u64("seed", 0).unwrap_or(0);
    let budget = args.get_usize("budget", 400).unwrap_or(400);
    let threads = args.get_usize("threads", 0).unwrap_or(0);
    let mut sched = ShaEaScheduler::with_threads(seed, threads);
    let out = sched.schedule(&topo, &wf, &job, Budget::timed(budget, 300.0));
    let Some(plan) = out.plan else {
        eprintln!("no plan");
        return 1;
    };
    let pred = CostModel::new(&topo, &wf, &job).plan_cost(&plan).iter_time;
    let sim = simulate_plan(&topo, &wf, &job, &plan, &SimConfig::default());
    let err = hetrl::util::stats::rel_err(pred, sim.iter_time) * 100.0;
    println!(
        "predicted {} vs simulated {} -> error {err:.1}%",
        fmt_secs(pred),
        fmt_secs(sim.iter_time)
    );
    0
}

fn cmd_replay(args: &Args) -> i32 {
    let (wf, _topo, mut job) = match parse_env(args) {
        Ok(x) => x,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    if args.flag("tiny") {
        job = hetrl::workflow::JobConfig::tiny();
    }
    let Some(scenario) = Scenario::parse(&args.get_or("scenario", "country")) else {
        eprintln!("bad --scenario");
        return 2;
    };
    let seed = args.get_u64("seed", 0).unwrap_or(0);
    let iters = args.get_usize("iters", 24).unwrap_or(24);
    let n_events = args.get_usize("events", 5).unwrap_or(5);
    let cold_budget = args.get_usize("budget", 600).unwrap_or(600);
    let warm_budget = args.get_usize("warm-budget", 150).unwrap_or(150);
    let anytime_rate = args.get_f64("anytime-rate", 0.5).unwrap_or(0.5);
    let threads = args.get_usize("threads", 0).unwrap_or(0);
    // `--policy all` runs every policy in the fixed documented order
    // (Policy::ALL): static, warm-replan, anytime, preempt, oracle.
    let policies: Vec<Policy> = match args.get_or("policy", "all").as_str() {
        "all" => Policy::ALL.to_vec(),
        other => match Policy::parse(other) {
            Some(p) => vec![p],
            None => {
                eprintln!("bad --policy '{other}' (static|warm|anytime|preempt|oracle|all)");
                return 2;
            }
        },
    };
    let notice_override = match args.get("notice-secs") {
        None => None,
        Some(_) => match args.get_f64("notice-secs", 0.0) {
            Ok(v) => Some(v),
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        },
    };
    // `--shuffle-seed N` permutes same-timestamp DES ready ties with a
    // seeded rank (simulator::ShuffleConfig); replay metrics are
    // invariant under any seed (tests/prop_interleave.rs), so this is
    // an order-sensitivity fuzz knob, not a behavior knob. Unset =
    // FIFO, byte-identical to the pre-shuffle output.
    let shuffle = match args.get("shuffle-seed") {
        None => None,
        Some(_) => match args.get_u64("shuffle-seed", 0) {
            Ok(s) => Some(hetrl::simulator::ShuffleConfig { seed: s }),
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        },
    };
    // Failure & recovery knobs. `--faults [N]` seeds transient-fault
    // events into the trace and turns recovery pricing on;
    // `--ckpt-interval <secs|auto>` turns it on too, with either a
    // pinned cadence or the searched one; `--ckpt-bw` reprices the
    // checkpoint store (migration restores *and* checkpoint writes).
    let faults_on = args.flag("faults") || args.get("faults").is_some();
    let fault_events = if faults_on {
        match args.get_usize("faults", 4) {
            Ok(n) => n,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        }
    } else {
        0
    };
    let mut recovery = RecoveryModel::default();
    let mut ckpt_search = None;
    match args.get("ckpt-interval") {
        None => {}
        Some("auto") => {
            recovery.enabled = true;
            ckpt_search = Some(CkptSearchConfig::default());
        }
        Some(v) => match v.parse::<f64>() {
            Ok(s) if s >= 0.0 => recovery = RecoveryModel::with_interval(s),
            _ => {
                eprintln!("--ckpt-interval expects seconds >= 0 or 'auto', got '{v}'");
                return 2;
            }
        },
    }
    // Seeded faults without an explicit cadence still price recovery,
    // at the default checkpoint interval.
    recovery.enabled = recovery.enabled || faults_on;
    recovery.max_retries = match args.get_usize("max-retries", recovery.max_retries) {
        Ok(n) => n,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let mut spec = TestbedSpec::default();
    if args.get("ckpt-bw").is_some() {
        match args.get_f64("ckpt-bw", 0.0) {
            Ok(g) if g > 0.0 => spec.ckpt_bw = g * GBITPS_BYTES,
            Ok(_) => {
                eprintln!("--ckpt-bw expects a positive Gbit/s figure");
                return 2;
            }
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        }
    }
    let mut replan = ReplanConfig { warm_budget, cold_budget, threads, ..ReplanConfig::default() };
    replan.anytime.evals_per_sim_sec = anytime_rate;
    replan.migration = MigrationModel::for_spec(&spec);
    let cfg = ReplayConfig {
        iters,
        trace: TraceConfig {
            horizon: iters,
            n_events,
            fault_events,
            notice_override,
            ..TraceConfig::default()
        },
        replan,
        recovery,
        ckpt_search,
        shuffle,
        ..ReplayConfig::default()
    };

    // Print the (policy-independent) trace first.
    let base = hetrl::topology::build_testbed(scenario, &spec);
    let trace = generate_trace(&base, &cfg.trace, seed);
    println!(
        "replaying {} iterations of {} on {} ({} GPUs), seed {seed}, {} events:",
        iters,
        wf.name(),
        scenario.name(),
        base.n(),
        trace.len()
    );
    for e in &trace {
        println!("  iter {:>3}: {}", e.at_iter, e.label());
    }
    let post = first_event_iter(&trace).unwrap_or(0);

    // The async workflow model: `--workflow async` replays the
    // bounded-staleness pipeline (crate::asyncrl) instead of the
    // synchronous barrier; `--staleness 0` delegates back to the sync
    // path bit-identically.
    let workflow = args.get_or("workflow", "sync");
    let async_cfg = match workflow.as_str() {
        "sync" => None,
        "async" => Some(hetrl::asyncrl::AsyncReplayConfig {
            base: cfg.clone(),
            staleness_bound: args.get_usize("staleness", 2).unwrap_or(2),
            queue_capacity: args.get_usize("queue-cap", 2).unwrap_or(2),
            window: args.get_usize("window", 8).unwrap_or(8).max(1),
            ..hetrl::asyncrl::AsyncReplayConfig::default()
        }),
        other => {
            eprintln!("bad --workflow '{other}' (sync|async)");
            return 2;
        }
    };

    let mut table = hetrl::util::table::Table::new(
        &format!("replay: {} / {} / seed {seed}", scenario.name(), wf.name()),
        &[
            "policy",
            "workflow",
            "k",
            "total (s)",
            "mean iter (s)",
            "thpt (samp/s)",
            "post-event thpt",
            "replans",
            "evals",
            "bg evals",
            "hyp evals",
            "cache hit%",
            "migration (s)",
            "retry stall (s)",
            "rework (s)",
            "ckpt (s)",
            "degraded",
            "queue mean/max",
            "gen stall (s)",
        ],
    );
    for policy in policies {
        // (base telemetry, workflow / staleness / queue columns)
        let (r, wf_col, k_col, queue_col, stall_col) = match &async_cfg {
            None => {
                let r = elastic::replay(scenario, &spec, &wf, &job, policy, &cfg, seed);
                (r, "sync".to_string(), "-".into(), "-".into(), "-".into())
            }
            Some(ac) => {
                let ar =
                    hetrl::asyncrl::replay_async(scenario, &spec, &wf, &job, policy, ac, seed);
                let cols = (
                    ar.workflow_name().to_string(),
                    ar.staleness_bound.to_string(),
                    format!("{:.2}/{}", ar.mean_queue_depth(), ar.max_queue_depth()),
                    format!("{:.1}", ar.producer_stall_secs()),
                );
                (ar.base, cols.0, cols.1, cols.2, cols.3)
            }
        };
        let mig: f64 = r.records.iter().map(|x| x.migration_secs).sum();
        for rec in r.records.iter().filter(|rec| !rec.events.is_empty()) {
            println!(
                "  [{}] iter {:>3}: {} -> {} GPUs, {} evals, migration {}, iter {}",
                policy.name(),
                rec.iter,
                rec.events.join(" + "),
                rec.active_gpus,
                rec.evals,
                fmt_secs(rec.migration_secs),
                fmt_secs(rec.iter_secs),
            );
        }
        if cfg.recovery.enabled {
            println!(
                "  [{}] checkpoint cadence {} -> {} writes, {} degraded iters",
                policy.name(),
                fmt_secs(r.ckpt_interval_secs),
                r.ckpts,
                r.degraded_iters,
            );
        }
        table.row(vec![
            policy.name().to_string(),
            wf_col,
            k_col,
            format!("{:.1}", r.total_secs),
            format!("{:.2}", r.mean_iter_secs()),
            format!("{:.2}", r.throughput()),
            format!("{:.2}", r.throughput_after(post)),
            r.replans.to_string(),
            r.total_evals.to_string(),
            r.anytime_evals.to_string(),
            r.hypothesis_evals.to_string(),
            format!("{:.0}%", r.cache_hit_rate() * 100.0),
            format!("{mig:.1}"),
            format!("{:.1}", r.retry_stall_secs),
            format!("{:.1}", r.rework_secs),
            format!("{:.1}/{}", r.ckpt_secs, r.ckpts),
            r.degraded_iters.to_string(),
            queue_col,
            stall_col,
        ]);
    }
    table.print();
    0
}

fn cmd_train(args: &Args) -> i32 {
    let dir = args.get_or("artifacts", "artifacts");
    let steps = args.get_usize("steps", 100).unwrap_or(100);
    let rt = match Runtime::load(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("{e:#}");
            return 1;
        }
    };
    println!(
        "runtime up on {} | {} entry points | {:.2}M params",
        rt.platform(),
        rt.manifest.entrypoints.len(),
        rt.manifest.total_params() as f64 / 1e6
    );
    let cfg = GrpoConfig {
        difficulty: if args.flag("hard") {
            TaskDifficulty::Hard
        } else {
            TaskDifficulty::Easy
        },
        seed: args.get_u64("seed", 0).unwrap_or(0),
        ..GrpoConfig::default()
    };
    let fleet = WorkerFleet::heterogeneous_default();
    let mut trainer = match GrpoTrainer::new(&rt, cfg, fleet) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("{e:#}");
            return 1;
        }
    };
    for s in 0..steps {
        match trainer.step() {
            Ok(st) => {
                if s % 10 == 0 || s + 1 == steps {
                    println!(
                        "step {:>4} | reward {:.3} | loss {:+.4} | kl {:.4} | wall {}",
                        st.step,
                        st.mean_reward,
                        st.loss,
                        st.kl,
                        fmt_secs(st.wall)
                    );
                }
            }
            Err(e) => {
                eprintln!("step failed: {e:#}");
                return 1;
            }
        }
    }
    match trainer.evaluate(4) {
        Ok(acc) => println!("final greedy accuracy: {:.1}%", acc * 100.0),
        Err(e) => eprintln!("eval failed: {e:#}"),
    }
    0
}

fn cmd_lint(args: &Args) -> i32 {
    use std::path::{Path, PathBuf};
    if args.flag("rules") {
        for (r, summary) in hetrl::lint::RULES {
            println!("{:<3} {}", r.id(), summary);
        }
        return 0;
    }
    // The parser binds `--fix-allow <path>` as an option with the path
    // as its value; accept both shapes and recover the path operand.
    let fix = args.flag("fix-allow") || args.get("fix-allow").is_some();
    let mut paths: Vec<PathBuf> = args.positional.iter().map(PathBuf::from).collect();
    if let Some(v) = args.get("fix-allow") {
        paths.push(PathBuf::from(v));
    }
    if paths.is_empty() {
        let roots: &[&str] = if Path::new("src").is_dir() {
            &["src", "tests", "benches"]
        } else if Path::new("rust/src").is_dir() {
            &["rust/src", "rust/tests", "rust/benches"]
        } else {
            eprintln!("hetrl lint: no src/ tree here (run from the repo root or rust/), or pass paths");
            return 2;
        };
        paths = roots.iter().map(PathBuf::from).filter(|p| p.is_dir()).collect();
    }
    if fix {
        match hetrl::lint::fix_unused_allows(&paths) {
            Ok(n) => println!(
                "detlint: removed {n} unused allow directive{}",
                if n == 1 { "" } else { "s" }
            ),
            Err(e) => {
                eprintln!("hetrl lint: {e}");
                return 2;
            }
        }
    }
    match hetrl::lint::run_paths(&paths) {
        Ok(rep) => {
            print!("{}", rep.render());
            if rep.is_clean() {
                0
            } else {
                1
            }
        }
        Err(e) => {
            eprintln!("hetrl lint: {e}");
            2
        }
    }
}

fn cmd_info(args: &Args) -> i32 {
    let dir = args.get_or("artifacts", "artifacts");
    match hetrl::runtime::Manifest::load(&dir) {
        Ok(m) => {
            println!(
                "model: d={} layers={} heads={} vocab={} maxlen={} ({:.2}M params), batch {}",
                m.model.d_model,
                m.model.n_layers,
                m.model.n_heads,
                m.model.vocab,
                m.model.max_len,
                m.total_params() as f64 / 1e6,
                m.batch
            );
            for (name, ep) in &m.entrypoints {
                println!(
                    "  {name:<14} {} in / {} out ({})",
                    ep.inputs.len(),
                    ep.outputs.len(),
                    ep.file.file_name().unwrap().to_string_lossy()
                );
            }
            0
        }
        Err(e) => {
            eprintln!("{e:#}");
            1
        }
    }
}
