//! HetRL scheduling algorithms (paper §3).
//!
//! * [`levels`] — the multi-level search framework (Figure 1): task
//!   grouping (L1), coarse GPU grouping (L2), medium-grained GPU
//!   assignment (L3), intra-model parallelization (L4), fine-grained
//!   tasklet assignment (L5).
//! * [`ea`] — evolutionary low-level plan generation with the TFLOPS
//!   upgrade mutation and the Baldwinian swap local search (§3.4).
//! * [`sha`] — the nested successive-halving hybrid scheduler
//!   (Algorithm 1).
//! * [`ilp`] — the exact ILP formulation solved with the in-crate
//!   simplex + branch & bound (§3.5).
//! * [`baselines`] — verl-like, StreamRL-like, pure-EA (DEAP-like) and
//!   random-search baselines used across the evaluation.

pub mod levels;
pub mod ea;
pub mod sha;
pub mod ilp;
pub mod baselines;

use crate::costmodel::CostModel;
use crate::plan::ExecutionPlan;
use crate::topology::DeviceTopology;
use crate::workflow::{JobConfig, RlWorkflow};
use std::time::Instant;

/// Search budget: cost-model evaluations (deterministic unit used by the
/// algorithms) plus a wall-clock cap.
#[derive(Debug, Clone, Copy)]
pub struct Budget {
    pub evals: usize,
    pub wall_secs: f64,
}

impl Budget {
    pub fn evals(evals: usize) -> Budget {
        Budget { evals, wall_secs: f64::INFINITY }
    }

    pub fn timed(evals: usize, wall_secs: f64) -> Budget {
        Budget { evals, wall_secs }
    }
}

/// A point on the search-efficiency curve (Figures 5/6): after `evals`
/// evaluations / `wall` seconds, the best plan cost was `best_cost`.
#[derive(Debug, Clone, Copy)]
pub struct TracePoint {
    pub wall: f64,
    pub evals: usize,
    pub best_cost: f64,
}

/// Result of a scheduling run.
#[derive(Debug, Clone)]
pub struct ScheduleOutcome {
    pub plan: Option<ExecutionPlan>,
    /// Cost-model iteration time of the best plan (∞ if none found).
    pub cost: f64,
    pub evals: usize,
    pub wall: f64,
    pub trace: Vec<TracePoint>,
}

impl ScheduleOutcome {
    pub fn empty() -> Self {
        ScheduleOutcome {
            plan: None,
            cost: f64::INFINITY,
            evals: 0,
            wall: 0.0,
            trace: Vec::new(),
        }
    }
}

/// Common interface for all scheduling algorithms.
pub trait Scheduler {
    fn name(&self) -> &'static str;
    fn schedule(
        &mut self,
        topo: &DeviceTopology,
        wf: &RlWorkflow,
        job: &JobConfig,
        budget: Budget,
    ) -> ScheduleOutcome;
}

/// Shared evaluation context: counts cost-model evaluations, tracks the
/// incumbent and the search trace, and enforces the budget.
pub struct EvalCtx<'a> {
    pub cm: CostModel<'a>,
    pub wf: &'a RlWorkflow,
    pub topo: &'a DeviceTopology,
    pub job: &'a JobConfig,
    pub budget: Budget,
    pub evals: usize,
    pub best_cost: f64,
    pub best_plan: Option<ExecutionPlan>,
    pub trace: Vec<TracePoint>,
    /// Per-task cost memo (the elastic replanner turns this on; valid
    /// only while the topology stays fixed).
    pub cache: Option<crate::costmodel::CostCache>,
    /// Additive objective term beyond iteration time — e.g. the
    /// amortized migration cost of switching to a candidate plan.
    /// Applied only to valid plans; `best_cost` includes it.
    pub penalty: Option<Box<dyn Fn(&ExecutionPlan) -> f64 + 'a>>,
    started: Instant,
}

impl<'a> EvalCtx<'a> {
    pub fn new(
        topo: &'a DeviceTopology,
        wf: &'a RlWorkflow,
        job: &'a JobConfig,
        budget: Budget,
    ) -> Self {
        EvalCtx {
            cm: CostModel::new(topo, wf, job),
            wf,
            topo,
            job,
            budget,
            evals: 0,
            best_cost: f64::INFINITY,
            best_plan: None,
            trace: Vec::new(),
            cache: None,
            penalty: None,
            started: Instant::now(),
        }
    }

    pub fn exhausted(&self) -> bool {
        self.evals >= self.budget.evals
            || self.started.elapsed().as_secs_f64() >= self.budget.wall_secs
    }

    pub fn wall(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Evaluate a candidate plan: validity check + cost model (+ the
    /// optional penalty term). Returns the objective (∞ for invalid
    /// plans). Updates incumbent and trace.
    pub fn eval(&mut self, plan: &ExecutionPlan) -> f64 {
        self.evals += 1;
        let mut cost = if plan.validate(self.wf, self.topo, self.job).is_ok() {
            match &mut self.cache {
                Some(cache) => self.cm.plan_cost_cached(plan, cache).iter_time,
                None => self.cm.plan_cost(plan).iter_time,
            }
        } else {
            f64::INFINITY
        };
        if cost.is_finite() {
            if let Some(penalty) = &self.penalty {
                cost += penalty(plan);
            }
        }
        if cost < self.best_cost {
            self.best_cost = cost;
            self.best_plan = Some(plan.clone());
            self.trace.push(TracePoint {
                wall: self.wall(),
                evals: self.evals,
                best_cost: cost,
            });
        }
        cost
    }

    pub fn outcome(self) -> ScheduleOutcome {
        ScheduleOutcome {
            plan: self.best_plan,
            cost: self.best_cost,
            evals: self.evals,
            wall: self.started.elapsed().as_secs_f64(),
            trace: self.trace,
        }
    }
}

pub use baselines::{RandomScheduler, StreamRlScheduler, VerlScheduler};
pub use ea::PureEaScheduler;
pub use ilp::IlpScheduler;
pub use sha::ShaEaScheduler;
