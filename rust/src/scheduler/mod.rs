//! HetRL scheduling algorithms (paper §3).
//!
//! * [`levels`] — the multi-level search framework (Figure 1): task
//!   grouping (L1), coarse GPU grouping (L2), medium-grained GPU
//!   assignment (L3), intra-model parallelization (L4), fine-grained
//!   tasklet assignment (L5).
//! * [`ea`] — evolutionary low-level plan generation with the TFLOPS
//!   upgrade mutation and the Baldwinian swap local search (§3.4).
//! * [`sha`] — the nested successive-halving hybrid scheduler
//!   (Algorithm 1), run on the parallel evaluation engine.
//! * [`engine`] — the parallel plan-evaluation engine (below).
//! * [`ilp`] — the exact ILP formulation solved with the in-crate
//!   simplex + branch & bound (§3.5).
//! * [`baselines`] — verl-like, StreamRL-like, pure-EA (DEAP-like) and
//!   random-search baselines used across the evaluation.
//!
//! # Parallel evaluation engine
//!
//! Candidate-plan evaluation is the schedulers' hot path, and SHA rungs
//! are embarrassingly parallel: every arm in a rung evolves
//! independently until the next halving barrier. The engine therefore
//! splits the old monolithic evaluation context in two:
//!
//! * a **shared view** — `topo`/`wf`/`job`, the [`costmodel::CostModel`]
//!   (all immutable borrows), one atomic [`EvalLedger`] charging
//!   [`Budget::evals`], and one always-on sharded
//!   [`costmodel::CostCache`] reused by every worker;
//! * **per-worker scratch** — an [`EvalCtx`] clone
//!   ([`EvalCtx::worker`]) holding its own incumbent, trace and local
//!   eval count. Each arm keeps its own seeded RNG stream.
//!
//! Rungs run on scoped threads
//! ([`crate::util::threadpool::scoped_map`]); results merge at the rung
//! barrier **in arm-index order**, never completion order. Beyond SHA,
//! the engine is the substrate for the elastic replanner's warm arms
//! and the anytime background search that runs between cluster events
//! ([`crate::elastic::anytime`]).
//!
//! ## Determinism contract
//!
//! The same seed yields the **bit-identical best plan, best cost and
//! eval count at any thread count**. This holds because (a) per-arm
//! eval quotas are derived deterministically from the ledger's
//! remaining budget at each barrier (never from completion order),
//! (b) quotas per rung sum to at most the remaining budget, so the
//! global cap cannot cut an arm off mid-rung, (c) the barrier reduction
//! is ordered by arm index with strict-improvement tie-breaks, and
//! (d) **wall-clock time never terminates the search**: the
//! [`EvalLedger`] is exhausted by eval counts alone, and `hetrl lint`
//! rule D1 statically keeps `Instant`/`SystemTime` out of scheduler
//! code (the ledger's stopwatch is a [`crate::util::benchkit`]
//! telemetry type). Trace `wall` stamps are telemetry and may vary
//! across runs; `plan`, `cost` and `evals` in [`ScheduleOutcome`] do
//! not — and since the cost cache moved to exact double-checked miss
//! accounting, `cache_hits`/`cache_misses`/`task_pricings` are also
//! bit-deterministic at any thread count (misses count distinct priced
//! keys; the candidate stream is seed-determined).
//!
//! ## Incremental (delta) evaluation
//!
//! [`EvalCtx::eval`] prices every task of a candidate. EA perturbations
//! touch a known footprint, so [`EvalCtx::eval_delta`] takes the
//! baseline's per-task costs plus a [`DirtySet`] and re-prices only the
//! dirty tasks ([`crate::costmodel::CostModel::price_delta_into`]); the
//! cost model is pure per task, so the result is bit-identical to the
//! full path whenever the footprint covers every task whose plan
//! differs from the baseline. Delta evaluation is **on by default**
//! ([`ea::EaConfig::delta_eval`]); the full re-price remains the oracle
//! (`tests/prop_delta_eval.rs`, the ci.sh consistency smoke, and the
//! `fig5_search_throughput` bit-identity gate).
//!
//! [`costmodel::CostModel`]: crate::costmodel::CostModel
//! [`costmodel::CostCache`]: crate::costmodel::CostCache

pub mod levels;
pub mod ea;
pub mod engine;
pub mod sha;
pub mod ilp;
pub mod baselines;

use crate::costmodel::{CostCache, CostModel, DirtySet, TaskCost};
use crate::plan::ExecutionPlan;
use crate::topology::DeviceTopology;
use crate::util::benchkit::Stopwatch;
use crate::workflow::{JobConfig, RlWorkflow};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Search budget. `evals` — cost-model evaluations — is the
/// deterministic unit every algorithm spends and the **only** quantity
/// that terminates the deterministic searchers (SHA-EA, pure EA, warm
/// replans, anytime search).
///
/// `wall_secs` is an *advisory* wall-clock cap: its single consumer is
/// the [`IlpScheduler`]'s branch & bound cutoff, an explicitly anytime
/// exact baseline that is exempt from the bit-determinism contract.
/// Since the D1 fix it never influences the [`EvalLedger`], so a tight
/// wall cap cannot change which plan the deterministic searchers select
/// (pinned by `wall_cap_is_telemetry_only` in
/// `tests/prop_scheduler_parallel.rs`).
#[derive(Debug, Clone, Copy)]
pub struct Budget {
    pub evals: usize,
    pub wall_secs: f64,
}

impl Budget {
    /// A pure eval budget (no advisory wall cap).
    pub fn evals(evals: usize) -> Budget {
        Budget { evals, wall_secs: f64::INFINITY }
    }

    /// An eval budget with an advisory wall cap — honored only by the
    /// ILP baseline's branch & bound cutoff (see the type docs).
    pub fn timed(evals: usize, wall_secs: f64) -> Budget {
        Budget { evals, wall_secs }
    }
}

/// A point on the search-efficiency curve (Figures 5/6): after `evals`
/// evaluations / `wall` seconds, the best plan cost was `best_cost`.
#[derive(Debug, Clone, Copy)]
pub struct TracePoint {
    pub wall: f64,
    pub evals: usize,
    pub best_cost: f64,
}

/// Result of a scheduling run.
#[derive(Debug, Clone)]
pub struct ScheduleOutcome {
    pub plan: Option<ExecutionPlan>,
    /// Cost-model iteration time of the best plan (∞ if none found).
    pub cost: f64,
    pub evals: usize,
    pub wall: f64,
    pub trace: Vec<TracePoint>,
    /// Per-task cost-cache lookups that reused a memoized result.
    /// Exact and bit-deterministic at any thread count (the cache's
    /// double-checked insert counts one miss per distinct priced key
    /// and every other lookup as a hit).
    pub cache_hits: usize,
    /// Distinct per-task plans whose cost was computed (exact; see
    /// [`Self::cache_hits`]).
    pub cache_misses: usize,
    /// Per-task cost resolutions routed through the shared cache: the
    /// task count for every full evaluation plus the dirty-footprint
    /// size for every delta evaluation. This is the delta-eval
    /// scoreboard — strictly lower than `evals × n_tasks` when the
    /// incremental path is doing its job — and, like the cache
    /// counters, bit-deterministic for a given seed at any thread
    /// count.
    pub task_pricings: usize,
}

impl ScheduleOutcome {
    pub fn empty() -> Self {
        ScheduleOutcome {
            plan: None,
            cost: f64::INFINITY,
            evals: 0,
            wall: 0.0,
            trace: Vec::new(),
            cache_hits: 0,
            cache_misses: 0,
            task_pricings: 0,
        }
    }
}

/// Common interface for all scheduling algorithms.
pub trait Scheduler {
    fn name(&self) -> &'static str;
    fn schedule(
        &mut self,
        topo: &DeviceTopology,
        wf: &RlWorkflow,
        job: &JobConfig,
        budget: Budget,
    ) -> ScheduleOutcome;
}

/// Atomic evaluation ledger shared by all workers of one search run:
/// the single source of truth for budget exhaustion. Quota assignment
/// at rung barriers guarantees the cap is never exceeded (see the
/// module docs); the ledger's counter is how the outcome reports total
/// evals.
///
/// Exhaustion is a pure function of the eval count — wall-clock time is
/// recorded only as telemetry (a [`Stopwatch`], detlint D1's audited
/// home for timing) and **never** terminates a search. The ledger used
/// to honor `Budget::wall_secs` as a second exhaustion condition, which
/// let machine load change which plan a seeded search returned; that
/// hazard is now banned statically by `hetrl lint`.
#[derive(Debug)]
pub struct EvalLedger {
    cap: usize,
    spent: AtomicUsize,
    sw: Stopwatch,
}

impl EvalLedger {
    pub fn new(budget: Budget) -> EvalLedger {
        EvalLedger {
            cap: budget.evals,
            spent: AtomicUsize::new(0),
            sw: Stopwatch::start(),
        }
    }

    /// Charge `n` evaluations; returns the new total.
    pub fn charge(&self, n: usize) -> usize {
        self.spent.fetch_add(n, Ordering::Relaxed) + n
    }

    pub fn spent(&self) -> usize {
        self.spent.load(Ordering::Relaxed)
    }

    /// Evaluations left under the cap (0 when exhausted).
    pub fn remaining(&self) -> usize {
        self.cap.saturating_sub(self.spent())
    }

    /// Seconds since the ledger was created. Telemetry only: reported
    /// in [`ScheduleOutcome::wall`] and trace stamps, never consulted
    /// for exhaustion.
    pub fn wall(&self) -> f64 {
        self.sw.elapsed_secs()
    }

    /// True once the eval cap is spent. Deliberately independent of
    /// wall-clock time (see the type docs).
    pub fn exhausted(&self) -> bool {
        self.spent() >= self.cap
    }
}

/// Evaluation context: the immutable shared view (`cm`/`wf`/`topo`/
/// `job`), the shared atomic [`EvalLedger`] + [`CostCache`], and this
/// worker's private scratch (incumbent, trace, local eval count).
/// [`EvalCtx::worker`] clones share the view and the ledger/cache but
/// get fresh scratch, so rung workers never contend on search state.
pub struct EvalCtx<'a> {
    pub cm: CostModel<'a>,
    pub wf: &'a RlWorkflow,
    pub topo: &'a DeviceTopology,
    pub job: &'a JobConfig,
    pub budget: Budget,
    /// Shared across all workers of this search run.
    pub ledger: Arc<EvalLedger>,
    /// Always-on sharded per-task cost memo, shared across workers.
    pub cache: Arc<CostCache>,
    /// Additive objective term beyond iteration time — e.g. the
    /// amortized migration cost of switching to a candidate plan.
    /// Applied only to valid plans; `best_cost` includes it.
    pub penalty: Option<Arc<dyn Fn(&ExecutionPlan) -> f64 + Send + Sync + 'a>>,
    /// Evaluations charged through *this* context (per-worker).
    pub evals: usize,
    /// Per-task cost resolutions performed through *this* context
    /// (per-worker; merged into [`ScheduleOutcome::task_pricings`] at
    /// rung barriers). A full evaluation adds the task count, a delta
    /// evaluation adds its dirty-footprint size.
    pub pricings: usize,
    pub best_cost: f64,
    pub best_plan: Option<ExecutionPlan>,
    pub trace: Vec<TracePoint>,
    /// Reusable per-task cost buffer: one allocation serves a whole
    /// batch of candidates (see `ea`'s batched scoring loop). Valid —
    /// holding the last evaluated candidate's per-task costs — only
    /// when `scratch_valid`.
    scratch: Vec<TaskCost>,
    scratch_valid: bool,
}

impl<'a> EvalCtx<'a> {
    pub fn new(
        topo: &'a DeviceTopology,
        wf: &'a RlWorkflow,
        job: &'a JobConfig,
        budget: Budget,
    ) -> Self {
        EvalCtx {
            cm: CostModel::new(topo, wf, job),
            wf,
            topo,
            job,
            budget,
            ledger: Arc::new(EvalLedger::new(budget)),
            cache: Arc::new(CostCache::new()),
            penalty: None,
            evals: 0,
            pricings: 0,
            best_cost: f64::INFINITY,
            best_plan: None,
            trace: Vec::new(),
            scratch: Vec::new(),
            scratch_valid: false,
        }
    }

    /// A worker context for one rung: shares the view, ledger, cache and
    /// penalty; starts from this context's incumbent *cost* (so its
    /// trace records only global improvements) with fresh scratch.
    pub fn worker(&self) -> EvalCtx<'a> {
        EvalCtx {
            cm: CostModel::new(self.topo, self.wf, self.job),
            wf: self.wf,
            topo: self.topo,
            job: self.job,
            budget: self.budget,
            ledger: Arc::clone(&self.ledger),
            cache: Arc::clone(&self.cache),
            penalty: self.penalty.clone(),
            evals: 0,
            pricings: 0,
            best_cost: self.best_cost,
            best_plan: None,
            trace: Vec::new(),
            scratch: Vec::new(),
            scratch_valid: false,
        }
    }

    pub fn exhausted(&self) -> bool {
        self.ledger.exhausted()
    }

    pub fn wall(&self) -> f64 {
        self.ledger.wall()
    }

    /// Charge `n` evaluations to the shared ledger (and this worker's
    /// local count) without scoring a plan — used for infeasible
    /// candidate draws so they still consume budget.
    pub fn charge(&mut self, n: usize) {
        self.ledger.charge(n);
        self.evals += n;
    }

    /// Evaluate a candidate plan: validity check + cost model (+ the
    /// optional penalty term). Returns the objective (∞ for invalid
    /// plans). Updates this worker's incumbent and trace. Prices every
    /// task (adding the task count to [`Self::pricings`]); see
    /// [`Self::eval_delta`] for the incremental form.
    pub fn eval(&mut self, plan: &ExecutionPlan) -> f64 {
        self.charge(1);
        let cost = if plan.validate(self.wf, self.topo, self.job).is_ok() {
            let it = self.cm.price_cached_into(plan, &self.cache, &mut self.scratch);
            self.pricings += plan.task_plans.len();
            self.scratch_valid = true;
            it
        } else {
            self.scratch_valid = false;
            f64::INFINITY
        };
        self.finish(plan, cost)
    }

    /// Incremental evaluation: identical contract to [`Self::eval`]
    /// (validity check, penalty, incumbent/trace update, one ledger
    /// charge) but re-prices only the tasks in `dirty`, reusing `base`
    /// — the per-task costs of a previously evaluated plan that agrees
    /// with `plan` outside the footprint — for the rest. Bit-identical
    /// to [`Self::eval`] under that soundness condition (the cost model
    /// is pure per task); adds only `dirty.len()` to [`Self::pricings`].
    pub fn eval_delta(
        &mut self,
        plan: &ExecutionPlan,
        base: &[TaskCost],
        dirty: &DirtySet,
    ) -> f64 {
        self.charge(1);
        let cost = if plan.validate(self.wf, self.topo, self.job).is_ok() {
            let it = self
                .cm
                .price_delta_into(plan, base, dirty, &self.cache, &mut self.scratch);
            self.pricings += dirty.len();
            self.scratch_valid = true;
            it
        } else {
            self.scratch_valid = false;
            f64::INFINITY
        };
        self.finish(plan, cost)
    }

    /// Per-task costs of the most recently evaluated *valid* candidate
    /// (`None` if the last candidate failed validation). The EA stores
    /// this as the baseline for its next delta evaluation; the borrow
    /// ends before the next `eval*` call, which overwrites the buffer.
    pub fn last_per_task(&self) -> Option<&[TaskCost]> {
        self.scratch_valid.then(|| self.scratch.as_slice())
    }

    /// Shared tail of the `eval*` family: penalty, incumbent, trace.
    fn finish(&mut self, plan: &ExecutionPlan, mut cost: f64) -> f64 {
        if cost.is_finite() {
            if let Some(penalty) = &self.penalty {
                cost += (**penalty)(plan);
            }
        }
        if cost < self.best_cost {
            self.best_cost = cost;
            self.best_plan = Some(plan.clone());
            self.trace.push(TracePoint {
                wall: self.wall(),
                evals: self.ledger.spent(),
                best_cost: cost,
            });
        }
        cost
    }

    pub fn outcome(self) -> ScheduleOutcome {
        ScheduleOutcome {
            plan: self.best_plan,
            cost: self.best_cost,
            evals: self.ledger.spent(),
            wall: self.ledger.wall(),
            trace: self.trace,
            cache_hits: self.cache.hits(),
            cache_misses: self.cache.misses(),
            task_pricings: self.pricings,
        }
    }
}

pub use baselines::{RandomScheduler, StreamRlScheduler, VerlScheduler};
pub use ea::PureEaScheduler;
pub use engine::resolve_threads;
pub use ilp::IlpScheduler;
pub use sha::ShaEaScheduler;
