//! Baseline schedulers used across the paper's evaluation:
//!
//! * [`VerlScheduler`] — verl's (HybridFlow) scheduling: colocate the
//!   whole workflow on all GPUs, pick parallelization by a cost model
//!   that *assumes homogeneous devices and a uniform fast network* —
//!   heterogeneity-blind by construction (the paper's §2.3.2 point).
//! * [`StreamRlScheduler`] — StreamRL's disaggregated-stream design:
//!   two groups, actor generation vs everything else, with the paper's
//!   stated restriction that "all GPUs within the same group are
//!   homogeneous and located in the same data center".
//! * [`RandomScheduler`] — uniform random feasible plans (sanity floor).

use super::levels::{
    assemble, assign_devices, default_task_plans, gpu_groupings, set_partitions, TaskGrouping,
};
use super::{Budget, EvalCtx, ScheduleOutcome, Scheduler};
use crate::plan::ExecutionPlan;
use crate::topology::{Device, DeviceTopology, GpuModel};
use crate::util::rng::Rng;
use crate::workflow::{JobConfig, RlWorkflow};

// ---------------------------------------------------------------------
// verl
// ---------------------------------------------------------------------

/// verl-like scheduler (homogeneity-assuming).
pub struct VerlScheduler {
    pub seed: u64,
}

impl VerlScheduler {
    pub fn new(seed: u64) -> Self {
        VerlScheduler { seed }
    }

    /// Homogenized view of a topology: every device becomes the modal GPU
    /// model; every link becomes a uniform fast datacenter link. This is
    /// the world verl's search believes it lives in.
    pub fn homogenized(topo: &DeviceTopology) -> DeviceTopology {
        let census = topo.census();
        let modal: GpuModel = census
            .iter()
            .max_by_key(|(_, c)| *c)
            .map(|(m, _)| *m)
            .unwrap_or(GpuModel::A100);
        let n = topo.n();
        let devices: Vec<Device> = (0..n)
            .map(|id| Device { id, gpu: modal, machine: id / 8, zone: 0, region: 0, speed: 1.0 })
            .collect();
        let mut alpha = vec![vec![0.0; n]; n];
        let mut beta = vec![vec![f64::INFINITY; n]; n];
        for i in 0..n {
            for j in 0..n {
                if i == j {
                    continue;
                }
                if devices[i].machine == devices[j].machine {
                    alpha[i][j] = 25e-6;
                    beta[i][j] = modal.spec().link_bps;
                } else {
                    alpha[i][j] = 0.2e-3;
                    beta[i][j] = 100.0e9 / 8.0;
                }
            }
        }
        DeviceTopology { devices, alpha, beta, region_names: vec!["homogeneous".into()] }
    }
}

impl Scheduler for VerlScheduler {
    fn name(&self) -> &'static str {
        "verl"
    }

    fn schedule(
        &mut self,
        topo: &DeviceTopology,
        wf: &RlWorkflow,
        job: &JobConfig,
        budget: Budget,
    ) -> ScheduleOutcome {
        let mut ctx = EvalCtx::new(topo, wf, job, budget);
        let fake = Self::homogenized(topo);
        let fake_cm = crate::costmodel::CostModel::new(&fake, wf, job);
        let mut rng = Rng::new(self.seed);

        // verl's candidate groupings: fully colocated, or generation
        // split from the rest (its two standard resource-pool layouts).
        let colocated: TaskGrouping = vec![(0..wf.n_tasks()).collect()];
        let gen_idx = wf
            .task_index(crate::workflow::RlTaskId::ActorGen)
            .unwrap_or(0);
        let rest: Vec<usize> = (0..wf.n_tasks()).filter(|&t| t != gen_idx).collect();
        let split: TaskGrouping = vec![vec![gen_idx], rest];

        let mut best_fake = f64::INFINITY;
        let mut best_plan: Option<ExecutionPlan> = None;
        for grouping in [colocated, split] {
            for sizes in gpu_groupings(wf, job, topo, &grouping, 8) {
                for roll in 0..6 {
                    if ctx.exhausted() {
                        break;
                    }
                    // Device-id-order assignment: verl does not reason
                    // about which physical GPU goes where.
                    let mut groups: Vec<Vec<usize>> = Vec::new();
                    let mut next = 0;
                    for &sz in &sizes {
                        groups.push((next..next + sz).collect());
                        next += sz;
                    }
                    // Placement memory-checks against the *real* fleet
                    // (verl users bump TP/PP until the job stops OOM-ing
                    // on the smallest GPU) — but ranking stays blind.
                    let Some(plans) = default_task_plans(
                        wf,
                        job,
                        topo,
                        &grouping,
                        &groups,
                        &mut rng,
                        roll > 0,
                    ) else {
                        continue;
                    };
                    let plan = assemble(&grouping, groups, plans);
                    // verl users iterate TP/PP settings until the job
                    // stops OOM-ing on the real fleet — real-infeasible
                    // candidates are discarded, but *ranking* still uses
                    // the homogeneity-assuming model.
                    if plan.validate(wf, topo, job).is_err() {
                        ctx.charge(1);
                        continue;
                    }
                    let fake_cost = fake_cm.plan_cost(&plan).iter_time;
                    let _real = ctx.eval(&plan);
                    if fake_cost < best_fake {
                        best_fake = fake_cost;
                        best_plan = Some(plan);
                    }
                }
            }
        }
        // verl deploys the plan *it* believes is best.
        let mut out = ctx.outcome();
        if let Some(p) = best_plan {
            if p.validate(wf, topo, job).is_ok() {
                let real = crate::costmodel::CostModel::new(topo, wf, job)
                    .plan_cost(&p)
                    .iter_time;
                out.cost = real;
                out.plan = Some(p);
            }
        }
        out
    }
}

// ---------------------------------------------------------------------
// StreamRL
// ---------------------------------------------------------------------

/// StreamRL-like scheduler: generation | rest disaggregation over
/// homogeneous same-region buckets.
pub struct StreamRlScheduler {
    pub seed: u64,
}

impl StreamRlScheduler {
    pub fn new(seed: u64) -> Self {
        StreamRlScheduler { seed }
    }

    /// Buckets of device ids by (GPU model, region).
    fn buckets(topo: &DeviceTopology) -> Vec<Vec<usize>> {
        let mut keys: Vec<(GpuModel, usize)> = Vec::new();
        let mut out: Vec<Vec<usize>> = Vec::new();
        for d in &topo.devices {
            let key = (d.gpu, d.region);
            match keys.iter().position(|&k| k == key) {
                Some(i) => out[i].push(d.id),
                None => {
                    keys.push(key);
                    out.push(vec![d.id]);
                }
            }
        }
        out
    }

    /// Model-homogeneous buckets spanning regions: the training-side
    /// group needs enough aggregate memory for the whole non-generation
    /// pipeline, which a single 8-GPU (model, region) bucket cannot hold
    /// for the larger models. StreamRL's constraint is homogeneity
    /// within a group; the cross-DC link sits between the two groups.
    fn model_buckets(topo: &DeviceTopology) -> Vec<Vec<usize>> {
        let mut keys: Vec<GpuModel> = Vec::new();
        let mut out: Vec<Vec<usize>> = Vec::new();
        for d in &topo.devices {
            match keys.iter().position(|&k| k == d.gpu) {
                Some(i) => out[i].push(d.id),
                None => {
                    keys.push(d.gpu);
                    out.push(vec![d.id]);
                }
            }
        }
        out
    }
}

impl Scheduler for StreamRlScheduler {
    fn name(&self) -> &'static str {
        "StreamRL"
    }

    fn schedule(
        &mut self,
        topo: &DeviceTopology,
        wf: &RlWorkflow,
        job: &JobConfig,
        budget: Budget,
    ) -> ScheduleOutcome {
        let mut ctx = EvalCtx::new(topo, wf, job, budget);
        let mut rng = Rng::new(self.seed);
        let gen_buckets = Self::buckets(topo);
        let mut rest_buckets = Self::buckets(topo);
        rest_buckets.extend(Self::model_buckets(topo));
        let gen_idx = wf
            .task_index(crate::workflow::RlTaskId::ActorGen)
            .unwrap_or(0);
        let rest: Vec<usize> = (0..wf.n_tasks()).filter(|&t| t != gen_idx).collect();
        let grouping: TaskGrouping = vec![vec![gen_idx], rest];

        for gen_bucket in gen_buckets.iter() {
            for rest_bucket in rest_buckets.iter() {
                let disjoint = gen_bucket.iter().all(|d| !rest_bucket.contains(d));
                if !disjoint || ctx.exhausted() {
                    continue;
                }
                let groups = vec![gen_bucket.clone(), rest_bucket.clone()];
                let Some(plans) =
                    default_task_plans(wf, job, topo, &grouping, &groups, &mut rng, false)
                else {
                    continue;
                };
                let plan = assemble(&grouping, groups, plans);
                ctx.eval(&plan);
                // A couple of strategy re-rolls per bucket pair.
                for _ in 0..3 {
                    if ctx.exhausted() {
                        break;
                    }
                    let groups = vec![gen_bucket.clone(), rest_bucket.clone()];
                    if let Some(plans) =
                        default_task_plans(wf, job, topo, &grouping, &groups, &mut rng, true)
                    {
                        let plan = assemble(&grouping, groups, plans);
                        ctx.eval(&plan);
                    }
                }
            }
        }
        ctx.outcome()
    }
}

// ---------------------------------------------------------------------
// Random search
// ---------------------------------------------------------------------

/// Uniform random feasible plans.
pub struct RandomScheduler {
    pub seed: u64,
}

impl RandomScheduler {
    pub fn new(seed: u64) -> Self {
        RandomScheduler { seed }
    }
}

impl Scheduler for RandomScheduler {
    fn name(&self) -> &'static str {
        "random"
    }

    fn schedule(
        &mut self,
        topo: &DeviceTopology,
        wf: &RlWorkflow,
        job: &JobConfig,
        budget: Budget,
    ) -> ScheduleOutcome {
        let mut ctx = EvalCtx::new(topo, wf, job, budget);
        let mut rng = Rng::new(self.seed);
        let groupings = set_partitions(wf.n_tasks());
        while !ctx.exhausted() {
            let grouping = groupings[rng.below(groupings.len())].clone();
            let ggs = gpu_groupings(wf, job, topo, &grouping, 16);
            if ggs.is_empty() {
                ctx.charge(1);
                continue;
            }
            let sizes = ggs[rng.below(ggs.len())].clone();
            let groups = assign_devices(wf, &grouping, &sizes, topo, &mut rng);
            if let Some(plans) =
                default_task_plans(wf, job, topo, &grouping, &groups, &mut rng, true)
            {
                let plan = assemble(&grouping, groups, plans);
                ctx.eval(&plan);
            } else {
                ctx.charge(1);
            }
        }
        ctx.outcome()
    }
}

/// Build a "use every GPU for every task, id-ordered" reference plan —
/// handy for tests and the quickstart.
pub fn naive_colocated_plan(
    topo: &DeviceTopology,
    wf: &RlWorkflow,
    job: &JobConfig,
) -> Option<ExecutionPlan> {
    let grouping: TaskGrouping = vec![(0..wf.n_tasks()).collect()];
    let groups = vec![(0..topo.n()).collect::<Vec<usize>>()];
    let mut rng = Rng::new(0);
    let plans = default_task_plans(wf, job, topo, &grouping, &groups, &mut rng, false)?;
    Some(assemble(&grouping, groups, plans))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{build_testbed, Scenario, TestbedSpec};
    use crate::workflow::{Algo, Mode, ModelSpec};

    fn setup(s: Scenario) -> (RlWorkflow, DeviceTopology, JobConfig) {
        (
            RlWorkflow::new(Algo::Grpo, Mode::Sync, ModelSpec::qwen_4b()),
            build_testbed(s, &TestbedSpec::default()),
            JobConfig::default(),
        )
    }

    #[test]
    fn verl_produces_valid_plan() {
        let (wf, topo, job) = setup(Scenario::SingleRegion);
        let out = VerlScheduler::new(1).schedule(&topo, &wf, &job, Budget::evals(50));
        let plan = out.plan.expect("verl plan");
        plan.validate(&wf, &topo, &job).unwrap();
        assert!(out.cost.is_finite());
    }

    #[test]
    fn homogenized_topo_is_flat() {
        let (_, topo, _) = setup(Scenario::MultiContinent);
        let h = VerlScheduler::homogenized(&topo);
        assert_eq!(h.n(), topo.n());
        let models: std::collections::BTreeSet<_> =
            h.devices.iter().map(|d| d.gpu).collect();
        assert_eq!(models.len(), 1);
        // no WAN latencies
        for i in 0..h.n() {
            for j in 0..h.n() {
                assert!(h.lat(i, j) < 1e-3);
            }
        }
    }

    #[test]
    fn streamrl_produces_valid_plan() {
        let (wf, topo, job) = setup(Scenario::MultiCountry);
        let out = StreamRlScheduler::new(2).schedule(&topo, &wf, &job, Budget::evals(200));
        let plan = out.plan.expect("streamrl plan");
        plan.validate(&wf, &topo, &job).unwrap();
        // Group 0 (generation) must be homogeneous and single-region.
        let gen_devices = &plan.gpu_groups[0];
        let models: std::collections::BTreeSet<_> =
            gen_devices.iter().map(|&d| topo.devices[d].gpu).collect();
        let regions: std::collections::BTreeSet<_> =
            gen_devices.iter().map(|&d| topo.devices[d].region).collect();
        assert_eq!(models.len(), 1);
        assert_eq!(regions.len(), 1);
    }

    #[test]
    fn random_finds_something() {
        let (wf, topo, job) = setup(Scenario::SingleRegion);
        let out = RandomScheduler::new(3).schedule(&topo, &wf, &job, Budget::evals(40));
        assert!(out.cost.is_finite());
    }

    #[test]
    fn naive_plan_valid() {
        let (wf, topo, job) = setup(Scenario::SingleRegion);
        let plan = naive_colocated_plan(&topo, &wf, &job).unwrap();
        plan.validate(&wf, &topo, &job).unwrap();
    }

    #[test]
    fn verl_blind_to_heterogeneity() {
        // verl picks (nearly) the same plan on Single-Region and
        // Multi-Continent — its model cannot tell them apart. The real
        // costs must then differ wildly.
        let job = JobConfig::default();
        let wf = RlWorkflow::new(Algo::Grpo, Mode::Sync, ModelSpec::qwen_8b());
        let t1 = build_testbed(Scenario::SingleRegion, &TestbedSpec::default());
        let t4 = build_testbed(Scenario::MultiContinent, &TestbedSpec::default());
        let p1 = VerlScheduler::new(5).schedule(&t1, &wf, &job, Budget::evals(50));
        let p4 = VerlScheduler::new(5).schedule(&t4, &wf, &job, Budget::evals(50));
        assert_eq!(
            p1.plan.as_ref().map(|p| p.task_groups.clone()),
            p4.plan.as_ref().map(|p| p.task_groups.clone())
        );
        // WAN can never make verl's (identically-chosen) plan faster.
        assert!(p4.cost >= p1.cost * 0.999, "p4 {} p1 {}", p4.cost, p1.cost);
    }
}
