//! The multi-level search framework (paper §3.2, Figure 1).
//!
//! * **Level 1** — task groupings: set partitions of the workflow's task
//!   list (`B_T` of them, the Bell number).
//! * **Level 2** — coarse GPU groupings: compositions of N GPUs into
//!   |groups| positive parts, pruned by per-group memory lower bounds
//!   and (for large N) quantized to keep the arm count tractable.
//! * **Level 3** — medium-grained assignment: which concrete GPUs each
//!   group gets (randomized, affinity-aware; mutated by the EA).
//! * **Level 4** — intra-model parallelization
//!   ([`crate::plan::ParallelStrategy::enumerate`]).
//! * **Level 5** — fine-grained tasklet→GPU maps (orderings within the
//!   group; mutated by the EA).

use crate::plan::memory::tasklet_memory;
use crate::plan::parallel::uniform_layer_split;
use crate::plan::{ExecutionPlan, ParallelStrategy, TaskPlan};
use crate::topology::DeviceTopology;
use crate::util::rng::Rng;
use crate::workflow::{JobConfig, RlWorkflow, TaskKind};

/// A Level-1 decision: partition of task indices.
pub type TaskGrouping = Vec<Vec<usize>>;

/// A Level-2 decision: GPUs per group (aligned with the task grouping).
pub type GpuGrouping = Vec<usize>;

/// Enumerate all set partitions of `0..n` (Bell(n) of them) in a
/// deterministic order. n ≤ 6 for RL workflows, so Bell(6) = 203.
pub fn set_partitions(n: usize) -> Vec<TaskGrouping> {
    assert!(n >= 1 && n <= 10, "set_partitions is for small n");
    let mut out = Vec::new();
    // Restricted growth strings: a[i] ≤ 1 + max(a[0..i])
    let mut a = vec![0usize; n];
    loop {
        let groups = a.iter().max().unwrap() + 1;
        let mut part: TaskGrouping = vec![Vec::new(); groups];
        for (i, &g) in a.iter().enumerate() {
            part[g].push(i);
        }
        out.push(part);
        // next restricted growth string
        let mut i = n - 1;
        loop {
            if i == 0 {
                return out;
            }
            let max_prefix = a[..i].iter().max().unwrap() + 1;
            if a[i] < max_prefix {
                a[i] += 1;
                for x in a.iter_mut().skip(i + 1) {
                    *x = 0;
                }
                break;
            }
            i -= 1;
        }
    }
}

/// Minimum GPUs a task group needs: ceil(total model memory of the
/// group's tasks / largest GPU memory), and at least 1.
pub fn min_gpus_for_group(
    wf: &RlWorkflow,
    job: &JobConfig,
    topo: &DeviceTopology,
    group: &[usize],
) -> usize {
    let max_mem = topo
        .devices
        .iter()
        .map(|d| d.spec().mem_bytes)
        .fold(0.0f64, f64::max);
    let mut total = 0.0;
    for &t in group {
        let task = &wf.tasks[t];
        // Cheapest memory configuration: maximal TP+PP sharding (cap 8·16)
        // still must hold the model somewhere.
        let mem = tasklet_memory(task, job, task.model.nl, 1, 1);
        total += mem.model + mem.working;
    }
    ((total / max_mem).ceil() as usize).max(1)
}

/// Enumerate Level-2 GPU groupings for a task grouping: compositions of
/// `n` into `groups.len()` parts, each ≥ its group's memory lower bound.
/// For large `n` the parts are quantized to multiples of `quantum` to
/// bound the arm count (the paper prunes with SHA instead; quantization
/// keeps the same coverage at coarser stride).
pub fn gpu_groupings(
    wf: &RlWorkflow,
    job: &JobConfig,
    topo: &DeviceTopology,
    grouping: &TaskGrouping,
    max_arms: usize,
) -> Vec<GpuGrouping> {
    let n = topo.n();
    let g = grouping.len();
    let mins: Vec<usize> = grouping
        .iter()
        .map(|grp| min_gpus_for_group(wf, job, topo, grp))
        .collect();
    let quantum = if n >= 32 { 4 } else if n >= 16 { 2 } else { 1 };
    let mut out = Vec::new();
    let mut parts = vec![0usize; g];
    compose(n, 0, &mut parts, &mins, quantum, &mut out);
    // Deterministically thin to `max_arms`, keeping spread.
    if out.len() > max_arms {
        let step = out.len() as f64 / max_arms as f64;
        let mut thin = Vec::with_capacity(max_arms);
        let mut idx = 0.0;
        while (idx as usize) < out.len() && thin.len() < max_arms {
            thin.push(out[idx as usize].clone());
            idx += step;
        }
        out = thin;
    }
    out
}

fn compose(
    remaining: usize,
    i: usize,
    parts: &mut Vec<usize>,
    mins: &[usize],
    quantum: usize,
    out: &mut Vec<GpuGrouping>,
) {
    let g = mins.len();
    if i == g - 1 {
        if remaining >= mins[i] {
            parts[i] = remaining;
            out.push(parts.clone());
        }
        return;
    }
    // Reserve minima for the remaining groups.
    let reserve: usize = mins[i + 1..].iter().sum();
    let mut size = mins[i].max(1);
    // Round up to quantum.
    if size % quantum != 0 {
        size += quantum - size % quantum;
    }
    while size + reserve <= remaining {
        parts[i] = size;
        compose(remaining - size, i + 1, parts, mins, quantum, out);
        size += quantum;
    }
}

/// Level 3: assign concrete devices to groups given sizes. The heuristic
/// scores each group's appetite (generation → HBM bandwidth, training →
/// FLOPs, inference → FLOPs) and deals whole machines first to preserve
/// locality; `rng` perturbs the order for EA initialization diversity.
pub fn assign_devices(
    wf: &RlWorkflow,
    grouping: &TaskGrouping,
    sizes: &[usize],
    topo: &DeviceTopology,
    rng: &mut Rng,
) -> Vec<Vec<usize>> {
    let g = grouping.len();
    assert_eq!(sizes.len(), g);
    // Appetite: 0 = prefer HBM (generation-heavy), 1 = prefer FLOPs.
    let mut appetite = vec![0.0f64; g];
    for (gi, grp) in grouping.iter().enumerate() {
        let mut hbm = 0;
        let mut comp = 0;
        for &t in grp {
            match wf.tasks[t].kind() {
                TaskKind::Generation => hbm += 1,
                _ => comp += 1,
            }
        }
        appetite[gi] = if hbm + comp == 0 {
            0.5
        } else {
            comp as f64 / (hbm + comp) as f64
        };
    }
    // Machines sorted two ways.
    let mut machines: Vec<(usize, Vec<usize>)> = Vec::new();
    for d in &topo.devices {
        match machines.iter_mut().find(|(m, _)| *m == d.machine) {
            Some((_, v)) => v.push(d.id),
            None => machines.push((d.machine, vec![d.id])),
        }
    }
    let score_hbm = |devs: &[usize]| -> f64 {
        devs.iter().map(|&d| topo.devices[d].spec().hbm_bps).sum()
    };
    let score_comp = |devs: &[usize]| -> f64 {
        devs.iter().map(|&d| topo.devices[d].effective_flops()).sum()
    };

    // Groups pick machines greedily in order of size (largest first),
    // with a random tiebreak for diversity.
    let mut order: Vec<usize> = (0..g).collect();
    order.sort_by_key(|&gi| std::cmp::Reverse(sizes[gi]));
    let mut taken: Vec<bool> = vec![false; machines.len()];
    let mut result: Vec<Vec<usize>> = vec![Vec::new(); g];
    for &gi in &order {
        let want_comp = appetite[gi];
        while result[gi].len() < sizes[gi] {
            // Pick the best remaining machine for this group's appetite.
            let mut best: Option<(usize, f64)> = None;
            for (mi, (_, devs)) in machines.iter().enumerate() {
                if taken[mi] {
                    continue;
                }
                let s = want_comp * score_comp(devs) + (1.0 - want_comp) * score_hbm(devs) * 0.15;
                let jittered = s * (1.0 + 0.1 * rng.f64());
                if best.map(|(_, bs)| jittered > bs).unwrap_or(true) {
                    best = Some((mi, jittered));
                }
            }
            let Some((mi, _)) = best else { break };
            taken[mi] = true;
            for &d in &machines[mi].1 {
                if result[gi].len() < sizes[gi] {
                    result[gi].push(d);
                }
            }
        }
    }
    // Any shortfall (machines exhausted while partially filled): take
    // leftover devices.
    let mut used: Vec<bool> = vec![false; topo.n()];
    for grp in &result {
        for &d in grp {
            used[d] = true;
        }
    }
    let mut leftovers: Vec<usize> = (0..topo.n()).filter(|&d| !used[d]).collect();
    for gi in 0..g {
        while result[gi].len() < sizes[gi] {
            let d = leftovers.pop().expect("not enough devices for sizes");
            result[gi].push(d);
        }
    }
    for grp in result.iter_mut() {
        grp.sort_unstable();
    }
    result
}

/// Pick a memory-feasible strategy for each task of a group (Level 4)
/// and build locality-ordered assignments (Level 5 default), yielding
/// TaskPlans. Colocated tasks stack on the same devices, so placement is
/// load-aware: each task takes the cyclic window of the group's locality
/// order that fits beside what is already placed. Returns `None` if any
/// task cannot be placed.
pub fn default_task_plans(
    wf: &RlWorkflow,
    job: &JobConfig,
    topo: &DeviceTopology,
    grouping: &TaskGrouping,
    group_devices: &[Vec<usize>],
    rng: &mut Rng,
    randomize: bool,
) -> Option<Vec<TaskPlan>> {
    let mut plans: Vec<Option<TaskPlan>> = vec![None; wf.n_tasks()];
    // Per-device committed model memory / max working memory (C3 shape).
    let mut model_sum = vec![0.0f64; topo.n()];
    let mut working_max = vec![0.0f64; topo.n()];
    for (gi, grp) in grouping.iter().enumerate() {
        let devs = &group_devices[gi];
        let ordered = topo.locality_order(devs);
        // Place training tasks first (largest footprints).
        let mut order: Vec<usize> = grp.clone();
        order.sort_by_key(|&t| match wf.tasks[t].kind() {
            TaskKind::Training => 0,
            TaskKind::Generation => 1,
            TaskKind::Inference => 2,
        });
        // Headroom reservation: placing a task may not squeeze out the
        // tasks still waiting — reserve each later task's minimal
        // per-device footprint (C3 is checked against cap − reserve).
        let min_mem: Vec<f64> = order
            .iter()
            .map(|&t| {
                let task = &wf.tasks[t];
                ParallelStrategy::enumerate(devs.len(), task.model.nl, 0.0)
                    .into_iter()
                    .map(|s| {
                        let split = uniform_layer_split(task.model.nl, s.pp);
                        let lb =
                            (job.total_samples() as f64 / s.dp as f64).ceil() as usize;
                        split
                            .iter()
                            .map(|&nl_j| {
                                let m = tasklet_memory(task, job, nl_j, s.tp, lb);
                                m.model + m.working
                            })
                            .fold(0.0f64, f64::max)
                    })
                    .fold(f64::INFINITY, f64::min)
            })
            .collect();
        let mut rotation = 0usize;
        for (oi, &t) in order.iter().enumerate() {
            let reserve: f64 = min_mem[oi + 1..].iter().sum();
            let task = &wf.tasks[t];
            let mut strategies = ParallelStrategy::enumerate(devs.len(), task.model.nl, 0.5);
            if randomize && strategies.len() > 1 {
                let cut = strategies.len().min(6);
                let pick = rng.below(cut);
                strategies.swap(0, pick);
            }
            let placed = strategies
                .into_iter()
                .find_map(|s| {
                    place_task(
                        task, job, topo, &ordered, s, rotation, &model_sum, &working_max,
                        reserve,
                    )
                })
                .or_else(|| {
                    // Second chance: drop the utilization floor and try
                    // the most memory-sharded strategies first — heavily
                    // colocated groups (e.g. StreamRL's 5-task "rest"
                    // group) only fit when later tasks slice thin.
                    let mut fallback =
                        ParallelStrategy::enumerate(devs.len(), task.model.nl, 0.0);
                    fallback.sort_by_key(|s| std::cmp::Reverse(s.tp * s.pp));
                    fallback.into_iter().find_map(|s| {
                        place_task(
                            task, job, topo, &ordered, s, rotation, &model_sum,
                            &working_max, 0.0,
                        )
                    })
                });
            let Some(placed) = placed else {
                let max_load = devs
                    .iter()
                    .map(|&d| model_sum[d])
                    .fold(0.0f64, f64::max);
                crate::log::debug!(
                    "default_task_plans: cannot place task {t} ({}) on {} devices (max committed {:.1} GiB, cap min {:.1} GiB)",
                    wf.tasks[t].id.name(),
                    devs.len(),
                    max_load / crate::util::units::GIB,
                    devs.iter().map(|&d| topo.devices[d].spec().mem_bytes).fold(f64::INFINITY, f64::min) / crate::util::units::GIB
                );
                return None;
            };
            // Commit memory.
            let s = placed.strategy;
            let local_batch = (job.total_samples() as f64 / s.dp as f64).ceil() as usize;
            for idx in 0..s.degree() {
                let (_, j, _) = s.tasklet_coords(idx);
                let mem = tasklet_memory(task, job, placed.layer_split[j], s.tp, local_batch);
                let d = placed.assignment[idx];
                model_sum[d] += mem.model;
                working_max[d] = working_max[d].max(mem.working);
            }
            rotation += s.degree();
            plans[t] = Some(placed);
        }
    }
    plans.into_iter().collect()
}

/// Try to place one task with strategy `s` on a cyclic window of
/// `ordered` devices, respecting residual memory. Tries the preferred
/// rotation first, then all others.
#[allow(clippy::too_many_arguments)]
#[allow(clippy::too_many_arguments)]
fn place_task(
    task: &crate::workflow::RlTask,
    job: &JobConfig,
    topo: &DeviceTopology,
    ordered: &[usize],
    s: ParallelStrategy,
    prefer_rot: usize,
    model_sum: &[f64],
    working_max: &[f64],
    reserve: f64,
) -> Option<TaskPlan> {
    let n = ordered.len();
    if s.degree() > n || s.pp > task.model.nl {
        return None;
    }
    let split = uniform_layer_split(task.model.nl, s.pp);
    let local_batch = (job.total_samples() as f64 / s.dp as f64).ceil() as usize;
    // Per-stage memory needs (same for every replica/shard).
    let stage_mem: Vec<crate::plan::memory::TaskletMemory> = split
        .iter()
        .map(|&nl_j| tasklet_memory(task, job, nl_j, s.tp, local_batch))
        .collect();
    'rot: for r in 0..n {
        let rot = (prefer_rot + r) % n;
        let window: Vec<usize> = (0..s.degree()).map(|i| ordered[(rot + i) % n]).collect();
        for (idx, &d) in window.iter().enumerate() {
            let (_, j, _) = s.tasklet_coords(idx);
            let need = model_sum[d]
                + stage_mem[j].model
                + working_max[d].max(stage_mem[j].working);
            if need + reserve > topo.devices[d].spec().mem_bytes {
                continue 'rot;
            }
        }
        return Some(TaskPlan {
            layer_split: split,
            dp_shares: vec![1.0 / s.dp as f64; s.dp],
            strategy: s,
            assignment: window,
        });
    }
    None
}

/// Quick memory feasibility for a strategy on a device set: the stage
/// with the most layers must fit on the smallest GPU of the set.
pub fn strategy_feasible(
    task: &crate::workflow::RlTask,
    job: &JobConfig,
    topo: &DeviceTopology,
    devs: &[usize],
    s: ParallelStrategy,
) -> bool {
    if s.degree() > devs.len() {
        return false;
    }
    let split = uniform_layer_split(task.model.nl.max(s.pp), s.pp);
    let worst_layers = *split.iter().max().unwrap();
    let local_batch = (job.total_samples() as f64 / s.dp as f64).ceil() as usize;
    let mem = tasklet_memory(task, job, worst_layers, s.tp, local_batch);
    let min_cap = devs
        .iter()
        .map(|&d| topo.devices[d].spec().mem_bytes)
        .fold(f64::INFINITY, f64::min);
    s.pp <= task.model.nl && mem.model + mem.working <= min_cap
}

/// Assemble a full [`ExecutionPlan`].
pub fn assemble(
    grouping: &TaskGrouping,
    group_devices: Vec<Vec<usize>>,
    task_plans: Vec<TaskPlan>,
) -> ExecutionPlan {
    ExecutionPlan {
        task_groups: grouping.clone(),
        gpu_groups: group_devices,
        task_plans,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{build_testbed, Scenario, TestbedSpec};
    use crate::workflow::{Algo, Mode, ModelSpec};

    fn setup() -> (RlWorkflow, DeviceTopology, JobConfig) {
        (
            RlWorkflow::new(Algo::Grpo, Mode::Sync, ModelSpec::qwen_4b()),
            build_testbed(Scenario::SingleRegion, &TestbedSpec::default()),
            JobConfig::default(),
        )
    }

    #[test]
    fn bell_numbers() {
        assert_eq!(set_partitions(1).len(), 1);
        assert_eq!(set_partitions(2).len(), 2);
        assert_eq!(set_partitions(3).len(), 5);
        assert_eq!(set_partitions(4).len(), 15);
        assert_eq!(set_partitions(6).len(), 203);
    }

    #[test]
    fn partitions_are_partitions() {
        for p in set_partitions(4) {
            let mut all: Vec<usize> = p.iter().flatten().cloned().collect();
            all.sort_unstable();
            assert_eq!(all, vec![0, 1, 2, 3]);
        }
    }

    #[test]
    fn gpu_groupings_cover_and_respect_minimums() {
        let (wf, topo, job) = setup();
        let grouping: TaskGrouping = vec![vec![0], vec![1, 2], vec![3]];
        let ggs = gpu_groupings(&wf, &job, &topo, &grouping, 64);
        assert!(!ggs.is_empty());
        for gg in &ggs {
            assert_eq!(gg.iter().sum::<usize>(), topo.n());
            assert_eq!(gg.len(), 3);
            for (i, &sz) in gg.iter().enumerate() {
                assert!(sz >= min_gpus_for_group(&wf, &job, &topo, &grouping[i]));
            }
        }
    }

    #[test]
    fn arm_cap_respected() {
        let (wf, topo, job) = setup();
        let grouping: TaskGrouping = vec![vec![0], vec![1], vec![2], vec![3]];
        let ggs = gpu_groupings(&wf, &job, &topo, &grouping, 10);
        assert!(ggs.len() <= 10);
    }

    #[test]
    fn assign_devices_partitions() {
        let (wf, topo, _) = setup();
        let grouping: TaskGrouping = vec![vec![0], vec![1, 2, 3]];
        let sizes = vec![24, 40];
        let mut rng = Rng::new(5);
        let groups = assign_devices(&wf, &grouping, &sizes, &topo, &mut rng);
        assert_eq!(groups[0].len(), 24);
        assert_eq!(groups[1].len(), 40);
        let mut all: Vec<usize> = groups.iter().flatten().cloned().collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 64);
    }

    #[test]
    fn default_plans_validate() {
        let (wf, topo, job) = setup();
        let grouping: TaskGrouping = vec![vec![0, 1, 2, 3]];
        let sizes = vec![64];
        let mut rng = Rng::new(1);
        let groups = assign_devices(&wf, &grouping, &sizes, &topo, &mut rng);
        let plans = default_task_plans(&wf, &job, &topo, &grouping, &groups, &mut rng, false)
            .expect("feasible");
        let plan = assemble(&grouping, groups, plans);
        plan.validate(&wf, &topo, &job).unwrap();
    }

    #[test]
    fn default_plans_validate_across_groupings_and_scenarios() {
        let job = JobConfig::default();
        for algo in [Algo::Ppo, Algo::Grpo] {
            let wf = RlWorkflow::new(algo, Mode::Sync, ModelSpec::qwen_8b());
            let topo = build_testbed(Scenario::MultiCountry, &TestbedSpec::default());
            let mut rng = Rng::new(7);
            for grouping in set_partitions(wf.n_tasks()).into_iter().take(8) {
                let ggs = gpu_groupings(&wf, &job, &topo, &grouping, 4);
                for sizes in ggs.into_iter().take(2) {
                    let groups = assign_devices(&wf, &grouping, &sizes, &topo, &mut rng);
                    if let Some(plans) =
                        default_task_plans(&wf, &job, &topo, &grouping, &groups, &mut rng, false)
                    {
                        let plan = assemble(&grouping, groups, plans);
                        plan.validate(&wf, &topo, &job).unwrap();
                    }
                }
            }
        }
    }

    #[test]
    fn min_gpus_scales_with_model() {
        let (_, topo, job) = setup();
        let wf4 = RlWorkflow::new(Algo::Grpo, Mode::Sync, ModelSpec::qwen_4b());
        let wf14 = RlWorkflow::new(Algo::Grpo, Mode::Sync, ModelSpec::qwen_14b());
        let g: Vec<usize> = (0..4).collect();
        let m4 = min_gpus_for_group(&wf4, &job, &topo, &g);
        let m14 = min_gpus_for_group(&wf14, &job, &topo, &g);
        assert!(m14 > m4);
    }
}
