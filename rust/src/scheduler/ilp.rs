//! ILP-based scheduling (paper §3.5): "for each RL task, we enumerate all
//! feasible parallelization strategies …, associate each strategy with a
//! binary decision variable, … use the analytical cost model to
//! parameterize the execution cost of each task, … introduce time
//! variables for each task … and minimize the overall workflow makespan."
//!
//! Concretely we build a *candidate-option* MILP: an option is one task's
//! (strategy × device-class allocation) with its exact analytical cost on
//! a locality-ordered representative assignment; binaries pick one option
//! per task; linear capacity rows keep class usage within the fleet;
//! wave/time variables express the workflow makespan. Tasklet
//! permutations within a device class are cost-equivalent under locality
//! ordering, so class-granular options preserve the effective search
//! space (documented in DESIGN.md §7). Solved exactly with the in-crate
//! simplex + branch & bound.

use super::levels::{strategy_feasible, TaskGrouping};
use super::{Budget, EvalCtx, ScheduleOutcome, Scheduler};
use crate::costmodel::task_cost::task_cost;
use crate::plan::parallel::uniform_layer_split;
use crate::plan::{ExecutionPlan, ParallelStrategy, TaskPlan};
use crate::solver::{solve_milp, BnbConfig, Cmp, Lp};
use crate::topology::{DeviceTopology, GpuModel};
use crate::workflow::{JobConfig, RlWorkflow};

/// One candidate deployment of one task.
#[derive(Debug, Clone)]
struct Option_ {
    task: usize,
    strategy: ParallelStrategy,
    /// Devices drawn from each class (aligned with the class list).
    class_counts: Vec<usize>,
    /// Representative device assignment (locality-ordered).
    assignment: Vec<usize>,
    /// Analytical cost of the task under this option (seconds).
    cost: f64,
    /// Worst per-device memory demand (bytes) — for the stacking rows.
    mem_per_device: f64,
}

/// Worst-stage per-device memory of a task under a strategy.
fn option_mem(task: &crate::workflow::RlTask, job: &JobConfig, s: ParallelStrategy) -> f64 {
    let split = uniform_layer_split(task.model.nl, s.pp);
    let local_batch = (job.total_samples() as f64 / s.dp as f64).ceil() as usize;
    split
        .iter()
        .map(|&nl_j| {
            let m = crate::plan::memory::tasklet_memory(task, job, nl_j, s.tp, local_batch);
            m.model + m.working
        })
        .fold(0.0, f64::max)
}

/// HetRL (ILP).
pub struct IlpScheduler {
    pub bnb: BnbConfig,
    /// Cap on strategies per (task, class-combo) to bound option count.
    pub max_strategies: usize,
}

impl IlpScheduler {
    pub fn new() -> Self {
        IlpScheduler {
            bnb: BnbConfig { time_limit: 120.0, max_nodes: 20_000, gap: 1e-6 },
            max_strategies: 6,
        }
    }

    pub fn with_time_limit(secs: f64) -> Self {
        let mut s = Self::new();
        s.bnb.time_limit = secs;
        s
    }
}

impl Default for IlpScheduler {
    fn default() -> Self {
        Self::new()
    }
}

/// Device classes: (model, region) buckets with their member ids
/// (locality-ordered).
fn device_classes(topo: &DeviceTopology) -> Vec<((GpuModel, usize), Vec<usize>)> {
    let mut out: Vec<((GpuModel, usize), Vec<usize>)> = Vec::new();
    for d in &topo.devices {
        let key = (d.gpu, d.region);
        match out.iter_mut().find(|(k, _)| *k == key) {
            Some((_, v)) => v.push(d.id),
            None => out.push((key, vec![d.id])),
        }
    }
    for (_, v) in out.iter_mut() {
        let ordered = topo.locality_order(v);
        *v = ordered;
    }
    out.sort_by_key(|(k, _)| *k);
    out
}

impl Scheduler for IlpScheduler {
    fn name(&self) -> &'static str {
        "HetRL(ILP)"
    }

    fn schedule(
        &mut self,
        topo: &DeviceTopology,
        wf: &RlWorkflow,
        job: &JobConfig,
        budget: Budget,
    ) -> ScheduleOutcome {
        let mut ctx = EvalCtx::new(topo, wf, job, budget);
        let classes = device_classes(topo);
        let n_classes = classes.len();

        // ---- 1. Enumerate candidate options with analytical costs. ----
        // NOTE: enumeration cost is charged to ctx.evals for reporting,
        // but is not aborted by the eval budget — an ILP run with a tiny
        // budget should still produce its (poor) incumbent, matching the
        // paper's Figure 5 behaviour.
        let mut options: Vec<Option_> = Vec::new();
        for (t, task) in wf.tasks.iter().enumerate() {
            // Single-class options, spread across degrees so the MILP can
            // trade devices between tasks (all-maximal options would make
            // the capacity rows infeasible).
            for (ci, (_, devs)) in classes.iter().enumerate() {
                let strategies = ParallelStrategy::enumerate(devs.len(), task.model.nl, 0.0);
                let mut taken = 0;
                let mut per_degree: Vec<(usize, usize)> = Vec::new(); // (degree, count)
                for s in strategies {
                    if taken >= self.max_strategies * 3 {
                        break;
                    }
                    // At most 2 options per distinct degree.
                    let deg = s.degree();
                    let cnt = per_degree
                        .iter_mut()
                        .find(|(d, _)| *d == deg)
                        .map(|(_, c)| {
                            *c += 1;
                            *c
                        })
                        .unwrap_or_else(|| {
                            per_degree.push((deg, 1));
                            1
                        });
                    if cnt > 2 {
                        continue;
                    }
                    if !strategy_feasible(task, job, topo, devs, s) {
                        continue;
                    }
                    let assignment: Vec<usize> = devs[..s.degree()].to_vec();
                    let tp = TaskPlan {
                        layer_split: uniform_layer_split(task.model.nl, s.pp),
                        dp_shares: vec![1.0 / s.dp as f64; s.dp],
                        strategy: s,
                        assignment: assignment.clone(),
                    };
                    let cost = task_cost(topo, task, job, &tp).total;
                    ctx.charge(1);
                    let mut counts = vec![0usize; n_classes];
                    counts[ci] = s.degree();
                    options.push(Option_ {
                        task: t,
                        strategy: s,
                        class_counts: counts,
                        assignment,
                        cost,
                        mem_per_device: option_mem(task, job, s),
                    });
                    taken += 1;
                }
            }
            // Two-class options: all of class a plus a prefix of class b.
            // Pairs are restricted to same-region or same-model classes
            // (the only mixes locality-ordered assignment keeps cheap),
            // bounding the option count on many-region fleets.
            for a in 0..n_classes {
                for b in 0..n_classes {
                    if a == b {
                        continue;
                    }
                    let (ka, kb) = (&classes[a].0, &classes[b].0);
                    if ka.0 != kb.0 && ka.1 != kb.1 {
                        continue;
                    }
                    let (ka, da) = (&classes[a].0, &classes[a].1);
                    let db = &classes[b].1;
                    let _ = ka;
                    let pool: Vec<usize> =
                        da.iter().chain(db.iter()).cloned().collect();
                    let strategies =
                        ParallelStrategy::enumerate(pool.len(), task.model.nl, 0.6);
                    let mut taken = 0;
                    for s in strategies {
                        if taken >= 2 {
                            break;
                        }
                        if s.degree() <= da.len() {
                            continue; // single-class already covers it
                        }
                        if !strategy_feasible(task, job, topo, &pool, s) {
                            continue;
                        }
                        let assignment: Vec<usize> = pool[..s.degree()].to_vec();
                        let tp = TaskPlan {
                            layer_split: uniform_layer_split(task.model.nl, s.pp),
                            dp_shares: vec![1.0 / s.dp as f64; s.dp],
                            strategy: s,
                            assignment: assignment.clone(),
                        };
                        let cost = task_cost(topo, task, job, &tp).total;
                        ctx.charge(1);
                        let mut counts = vec![0usize; n_classes];
                        counts[a] = da.len();
                        counts[b] = s.degree() - da.len();
                        options.push(Option_ {
                            task: t,
                            strategy: s,
                            class_counts: counts,
                            assignment,
                            cost,
                            mem_per_device: option_mem(task, job, s),
                        });
                        taken += 1;
                    }
                }
            }
        }
        // Thin to the cheapest options per task (degree-diverse: best 2
        // per distinct degree, then best overall) to keep the MILP dense
        // tableau tractable.
        let cap_per_task = self.max_strategies * 8;
        {
            let mut keep: Vec<bool> = vec![false; options.len()];
            for t in 0..wf.n_tasks() {
                let mut idx: Vec<usize> =
                    (0..options.len()).filter(|&i| options[i].task == t).collect();
                idx.sort_by(|&a, &b| crate::util::ford::cmp_f64(options[a].cost, options[b].cost));
                let mut per_degree: Vec<(usize, usize)> = Vec::new();
                let mut kept = 0;
                for &i in &idx {
                    if kept >= cap_per_task {
                        break;
                    }
                    let deg = options[i].strategy.degree();
                    let cnt = match per_degree.iter_mut().find(|(d, _)| *d == deg) {
                        Some((_, c)) => {
                            *c += 1;
                            *c
                        }
                        None => {
                            per_degree.push((deg, 1));
                            1
                        }
                    };
                    if cnt <= 2 {
                        keep[i] = true;
                        kept += 1;
                    }
                }
                // Backfill with cheapest regardless of degree.
                for &i in &idx {
                    if kept >= cap_per_task {
                        break;
                    }
                    if !keep[i] {
                        keep[i] = true;
                        kept += 1;
                    }
                }
            }
            let mut thinned = Vec::new();
            for (i, o) in options.into_iter().enumerate() {
                if keep[i] {
                    thinned.push(o);
                }
            }
            options = thinned;
        }
        // Index options per task.
        let mut per_task: Vec<Vec<usize>> = vec![Vec::new(); wf.n_tasks()];
        for (oi, o) in options.iter().enumerate() {
            per_task[o.task].push(oi);
        }
        if per_task.iter().any(|v| v.is_empty()) {
            return ctx.outcome(); // some task has no feasible option
        }

        // ---- 2. Build the MILP. ----
        // Variables: x[o] binaries, then one duration var per wave.
        let waves = wf.waves();
        let n_x = options.len();
        let n_vars = n_x + waves.len();
        let mut c = vec![0.0f64; n_vars];
        // Objective: minimize sum of wave durations (= sync makespan).
        for (w, cw) in c.iter_mut().skip(n_x).enumerate() {
            let _ = w;
            *cw = 1.0;
        }
        let mut lp = Lp::new(n_vars, c, false);
        // One option per task.
        for opts in per_task.iter() {
            lp.constrain(opts.iter().map(|&o| (o, 1.0)).collect(), Cmp::Eq, 1.0);
        }
        // Class capacities *per wave*: tasks in different waves run at
        // different times and may reuse devices (colocation); tasks in
        // the same wave run concurrently and may not.
        for wave in &waves {
            for (ci, (_, devs)) in classes.iter().enumerate() {
                let terms: Vec<(usize, f64)> = options
                    .iter()
                    .enumerate()
                    .filter(|(_, o)| o.class_counts[ci] > 0 && wave.contains(&o.task))
                    .map(|(oi, o)| (oi, o.class_counts[ci] as f64))
                    .collect();
                if !terms.is_empty() {
                    lp.constrain(terms, Cmp::Le, devs.len() as f64);
                }
            }
        }
        // Approximate memory stacking across waves: the sum over all
        // tasks of per-device memory demand drawn from class `k` must fit
        // the class's per-device capacity (uniform-spread approximation;
        // the exact C3 check re-validates the extracted plan).
        for (ci, (key, _)) in classes.iter().enumerate() {
            let cap = key.0.spec().mem_bytes;
            let terms: Vec<(usize, f64)> = options
                .iter()
                .enumerate()
                .filter(|(_, o)| o.class_counts[ci] > 0)
                .map(|(oi, o)| (oi, o.mem_per_device))
                .collect();
            if !terms.is_empty() {
                lp.constrain(terms, Cmp::Le, cap);
            }
        }
        // Wave durations: W_w ≥ dur[t] = Σ_o cost·x for t in wave w.
        for (w, wave) in waves.iter().enumerate() {
            for &t in wave {
                let mut terms: Vec<(usize, f64)> =
                    per_task[t].iter().map(|&o| (o, -options[o].cost)).collect();
                terms.push((n_x + w, 1.0));
                lp.constrain(terms, Cmp::Ge, 0.0);
            }
        }

        // ---- 3. Greedy wave-capacity incumbent (always evaluated) ----
        // The "ILP with insufficient budget" regime of Figure 5 still
        // deploys *something*; it also seeds the comparison when the
        // solver times out without an integral solution.
        let greedy_chosen: Option<Vec<usize>> = (|| {
            let mut chosen = vec![usize::MAX; wf.n_tasks()];
            for wave in &waves {
                let mut used = vec![0usize; n_classes];
                for &t in wave {
                    let mut best: Option<(usize, f64)> = None;
                    for &oi in &per_task[t] {
                        let o = &options[oi];
                        let fits = o
                            .class_counts
                            .iter()
                            .enumerate()
                            .all(|(ci, &c)| used[ci] + c <= classes[ci].1.len());
                        if fits && best.map(|(_, c)| o.cost < c).unwrap_or(true) {
                            best = Some((oi, o.cost));
                        }
                    }
                    let (oi, _) = best?;
                    chosen[t] = oi;
                    for (ci, &c) in options[oi].class_counts.iter().enumerate() {
                        used[ci] += c;
                    }
                }
            }
            Some(chosen)
        })();
        if let Some(chosen) = &greedy_chosen {
            let plans = extract_plans(wf, topo, &waves, &classes, &options, &per_task, chosen);
            crate::log::debug!("ILP greedy: {} extracted plan variants", plans.len());
            for plan in plans {
                let c = ctx.eval(&plan);
                if !c.is_finite() {
                    crate::log::debug!(
                        "ILP greedy variant invalid: {:?}",
                        plan.validate(wf, topo, job).err()
                    );
                }
            }
        } else {
            crate::log::debug!("ILP greedy: no capacity-feasible choice");
        }

        // ---- 4. Solve exactly and evaluate the MILP's choice. ----
        let binaries: Vec<usize> = (0..n_x).collect();
        let mut bnb = self.bnb.clone();
        bnb.time_limit = bnb
            .time_limit
            .min(ctx.budget.wall_secs - ctx.wall())
            .max(0.1);
        let result = solve_milp(&lp, &binaries, &bnb);
        if let Some(x) = &result.x {
            let chosen: Vec<usize> = per_task
                .iter()
                .map(|opts| {
                    *opts
                        .iter()
                        .max_by(|&&a, &&b| crate::util::ford::cmp_f64(x[a], x[b]))
                        .unwrap()
                })
                .collect();
            for plan in extract_plans(wf, topo, &waves, &classes, &options, &per_task, &chosen) {
                ctx.eval(&plan);
            }
        }
        let mut out = ctx.outcome();
        if !result.optimal {
            crate::log::warn!(
                "ILP hit budget: bound {:.3}, incumbent {:.3}, {} nodes",
                result.bound,
                result.obj,
                result.nodes
            );
        }
        out.evals += result.nodes;
        out
    }
}

/// Try to place one option on the fleet given the committed memory
/// ledger and this wave's used set: least-loaded fitting devices of each
/// requested class first, then any fitting spare. On success commits
/// the memory and returns the locality-ordered devices.
fn try_place(
    topo: &DeviceTopology,
    classes: &[((GpuModel, usize), Vec<usize>)],
    o: &Option_,
    load: &mut [f64],
    used_in_wave: &mut [bool],
) -> Option<Vec<usize>> {
    let mut devices: Vec<usize> = Vec::with_capacity(o.strategy.degree());
    for (ci, &cnt) in o.class_counts.iter().enumerate() {
        if cnt == 0 {
            continue;
        }
        let mut pool: Vec<usize> = classes[ci]
            .1
            .iter()
            .cloned()
            .filter(|&d| !used_in_wave[d] && !devices.contains(&d))
            .collect();
        pool.sort_by(|&a, &b| crate::util::ford::cmp_f64(load[a], load[b]));
        let mut taken = 0;
        for &d in &pool {
            if taken >= cnt {
                break;
            }
            if load[d] + o.mem_per_device <= topo.devices[d].spec().mem_bytes {
                devices.push(d);
                taken += 1;
            }
        }
    }
    if devices.len() < o.strategy.degree() {
        // Backfill with any unused, fitting device.
        let mut spares: Vec<usize> = (0..topo.n())
            .filter(|&d| !used_in_wave[d] && !devices.contains(&d))
            .collect();
        spares.sort_by(|&a, &b| crate::util::ford::cmp_f64(load[a], load[b]));
        for d in spares {
            if devices.len() >= o.strategy.degree() {
                break;
            }
            if load[d] + o.mem_per_device <= topo.devices[d].spec().mem_bytes {
                devices.push(d);
            }
        }
    }
    if devices.len() < o.strategy.degree() {
        return None;
    }
    for &d in &devices {
        used_in_wave[d] = true;
        load[d] += o.mem_per_device;
    }
    Some(topo.locality_order(&devices))
}

/// Materialize execution plans from a per-task option choice: one
/// variant reusing devices across waves (colocation), one fully
/// disaggregated (returned only if capacity allows) — the caller
/// evaluates both and keeps the better (memory stacking can invalidate
/// the colocated variant).
fn extract_plans(
    wf: &RlWorkflow,
    topo: &DeviceTopology,
    waves: &[Vec<usize>],
    classes: &[((GpuModel, usize), Vec<usize>)],
    options: &[Option_],
    per_task: &[Vec<usize>],
    chosen: &[usize],
) -> Vec<ExecutionPlan> {
    let mut out = Vec::new();
    let n_classes = classes.len();
    for reuse in [true, false] {
        let pseudo_waves: Vec<Vec<usize>> = if reuse {
            waves.to_vec()
        } else {
            vec![(0..wf.n_tasks()).collect()]
        };
        // disjoint devices; across (pseudo-)waves, devices may be reused
        // (colocation), with a per-device memory ledger steering reuse
        // toward the least-loaded members of each class.
        let mut task_devices: Vec<Vec<usize>> = vec![Vec::new(); wf.n_tasks()];
        let mut placed_opt: Vec<usize> = chosen.to_vec();
        let mut load = vec![0.0f64; topo.n()]; // committed bytes per device
        let mut feasible = true;
        for wave in &pseudo_waves {
            let mut used_in_wave = vec![false; topo.n()];
            for &t in wave {
                // Preference order: the chosen option, then the task's
                // other options by ascending cost (self-repair when the
                // memory ledger cannot materialize the first choice).
                let mut prefs: Vec<usize> = vec![chosen[t]];
                let mut rest: Vec<usize> = per_task[t]
                    .iter()
                    .cloned()
                    .filter(|&oi| oi != chosen[t])
                    .collect();
                rest.sort_by(|&a, &b| crate::util::ford::cmp_f64(options[a].cost, options[b].cost));
                prefs.extend(rest);
                let mut placed = false;
                for oi in prefs {
                    let o = &options[oi];
                    if let Some(devices) =
                        try_place(topo, classes, o, &mut load, &mut used_in_wave)
                    {
                        task_devices[t] = devices;
                        placed_opt[t] = oi;
                        placed = true;
                        break;
                    }
                }
                if !placed {
                    crate::log::debug!("extract(reuse={reuse}): task {t} unplaceable");
                    feasible = false;
                    break;
                }
            }
            if !feasible {
                break;
            }
        }
        if !feasible {
            continue;
        }

        // Task groups = connected components of device sharing.
        let mut comp: Vec<usize> = (0..wf.n_tasks()).collect();
        fn find(comp: &mut Vec<usize>, x: usize) -> usize {
            if comp[x] != x {
                let r = find(comp, comp[x]);
                comp[x] = r;
            }
            comp[x]
        }
        for a in 0..wf.n_tasks() {
            for b in a + 1..wf.n_tasks() {
                if task_devices[a].iter().any(|d| task_devices[b].contains(d)) {
                    let (ra, rb) = (find(&mut comp, a), find(&mut comp, b));
                    if ra != rb {
                        comp[ra] = rb;
                    }
                }
            }
        }
        let mut grouping: TaskGrouping = Vec::new();
        let mut groups: Vec<Vec<usize>> = Vec::new();
        let mut roots: Vec<usize> = Vec::new();
        for t in 0..wf.n_tasks() {
            let r = find(&mut comp, t);
            let gi = match roots.iter().position(|&x| x == r) {
                Some(i) => i,
                None => {
                    roots.push(r);
                    grouping.push(Vec::new());
                    groups.push(Vec::new());
                    roots.len() - 1
                }
            };
            grouping[gi].push(t);
            for &d in &task_devices[t] {
                if !groups[gi].contains(&d) {
                    groups[gi].push(d);
                }
            }
        }
        for g in groups.iter_mut() {
            g.sort_unstable();
        }
        let task_plans: Vec<TaskPlan> = (0..wf.n_tasks())
            .map(|t| {
                let o = &options[placed_opt[t]];
                TaskPlan {
                    layer_split: uniform_layer_split(wf.tasks[t].model.nl, o.strategy.pp),
                    dp_shares: vec![1.0 / o.strategy.dp as f64; o.strategy.dp],
                    strategy: o.strategy,
                    assignment: task_devices[t].clone(),
                }
            })
            .collect();
        out.push(ExecutionPlan { task_groups: grouping, gpu_groups: groups, task_plans });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{build_testbed, subset_by_model, Scenario, TestbedSpec};
    use crate::workflow::{Algo, Mode, ModelSpec};

    fn small_topo(n_per_model: usize) -> DeviceTopology {
        let full = build_testbed(Scenario::SingleRegion, &TestbedSpec::default());
        subset_by_model(
            &full,
            &[
                (GpuModel::A100, n_per_model),
                (GpuModel::L40S, n_per_model),
                (GpuModel::L4, n_per_model),
            ],
        )
    }

    #[test]
    fn classes_partition_devices() {
        let topo = small_topo(8);
        let classes = device_classes(&topo);
        let total: usize = classes.iter().map(|(_, v)| v.len()).sum();
        assert_eq!(total, topo.n());
    }

    #[test]
    fn ilp_schedules_small_cluster() {
        let topo = small_topo(8); // 24 GPUs, the paper's small-scale size
        let wf = RlWorkflow::new(Algo::Grpo, Mode::Sync, ModelSpec::qwen_4b());
        let job = JobConfig::default();
        let mut s = IlpScheduler::with_time_limit(30.0);
        let out = s.schedule(&topo, &wf, &job, Budget::timed(100_000, 60.0));
        let plan = out.plan.expect("ILP plan");
        plan.validate(&wf, &topo, &job).unwrap();
        assert!(out.cost.is_finite());
    }

    #[test]
    fn ilp_close_to_or_better_than_sha_small() {
        // Paper: "the performance gaps between the solutions obtained by
        // HetRL (SHA-EA) and the optimal solutions obtained by HetRL
        // (ILP) are within 1%" — here we just require the ILP not to be
        // much worse than SHA-EA on a small instance (both near-optimal).
        let topo = small_topo(4); // 12 GPUs
        let wf = RlWorkflow::new(Algo::Grpo, Mode::Sync, ModelSpec::qwen_1b7());
        let job = JobConfig::default();
        let ilp = IlpScheduler::with_time_limit(30.0)
            .schedule(&topo, &wf, &job, Budget::timed(100_000, 60.0));
        let sha = crate::scheduler::ShaEaScheduler::new(1)
            .schedule(&topo, &wf, &job, Budget::evals(800));
        assert!(ilp.cost.is_finite() && sha.cost.is_finite());
        assert!(
            ilp.cost <= sha.cost * 1.25,
            "ilp {} vs sha {}",
            ilp.cost,
            sha.cost
        );
    }
}
