//! The hybrid SHA-EA scheduler — paper Algorithm 1.
//!
//! Nested successive halving: Level-1 task groupings are the outer arms,
//! Level-2 GPU groupings the inner arms; each (outer, inner) pair owns an
//! evolutionary population ([`EaArm`]) that generates and evaluates
//! low-level plans. Budgets are measured in cost-model evaluations (the
//! deterministic unit); wall-clock caps still apply through [`EvalCtx`].

use super::ea::{EaArm, EaConfig};
use super::levels::{gpu_groupings, set_partitions};
use super::{Budget, EvalCtx, ScheduleOutcome, Scheduler};
use crate::topology::DeviceTopology;
use crate::workflow::{JobConfig, RlWorkflow};

/// Configuration of the hybrid scheduler.
#[derive(Debug, Clone)]
pub struct ShaConfig {
    pub ea: EaConfig,
    /// Cap on Level-2 arms per task grouping (quantized enumeration).
    pub max_gpu_groupings: usize,
    pub seed: u64,
}

impl Default for ShaConfig {
    fn default() -> Self {
        ShaConfig { ea: EaConfig::default(), max_gpu_groupings: 12, seed: 0x5EED }
    }
}

/// HetRL (SHA-EA).
pub struct ShaEaScheduler {
    pub cfg: ShaConfig,
}

impl ShaEaScheduler {
    pub fn new(seed: u64) -> Self {
        ShaEaScheduler { cfg: ShaConfig { seed, ..ShaConfig::default() } }
    }
}

/// One outer arm: a task grouping with its surviving inner arms.
struct OuterArm {
    inner: Vec<EaArm>,
    best: f64,
}

impl Scheduler for ShaEaScheduler {
    fn name(&self) -> &'static str {
        "HetRL(SHA-EA)"
    }

    fn schedule(
        &mut self,
        topo: &DeviceTopology,
        wf: &RlWorkflow,
        job: &JobConfig,
        budget: Budget,
    ) -> ScheduleOutcome {
        let mut ctx = EvalCtx::new(topo, wf, job, budget);
        let mut seed = self.cfg.seed;
        let mut next_seed = || {
            seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            seed
        };

        // Line 5–12: enumerate TG and per-tg GG, init populations.
        let mut outers: Vec<OuterArm> = Vec::new();
        for tg in set_partitions(wf.n_tasks()) {
            let ggs = gpu_groupings(wf, job, topo, &tg, self.cfg.max_gpu_groupings);
            if ggs.is_empty() {
                continue;
            }
            let inner: Vec<EaArm> = ggs
                .into_iter()
                .map(|sizes| EaArm::new(tg.clone(), sizes, self.cfg.ea.clone(), next_seed()))
                .collect();
            outers.push(OuterArm { inner, best: f64::INFINITY });
        }
        if outers.is_empty() {
            return ctx.outcome();
        }

        let n_tg = outers.len();
        let outer_rounds = (n_tg as f64).log2().ceil().max(1.0) as usize;

        // Line 14–33: outer SHA over task groupings.
        let mut alive: Vec<OuterArm> = outers;
        for _m in 0..outer_rounds {
            if ctx.exhausted() || alive.is_empty() {
                break;
            }
            // b_m = B / (|TG_m| * ceil(log2 |TG|))
            let b_m = (ctx.budget.evals / (alive.len() * outer_rounds)).max(4);
            for outer in alive.iter_mut() {
                if ctx.exhausted() {
                    break;
                }
                run_inner_sha(&mut ctx, outer, b_m);
            }
            // Line 31: keep the best half of task groupings.
            alive = best_half(alive, |o| o.best);
        }
        ctx.outcome()
    }
}

/// Inner SHA over the GPU groupings of one task grouping
/// (Algorithm 1 lines 17–29).
fn run_inner_sha(ctx: &mut EvalCtx<'_>, outer: &mut OuterArm, b_m: usize) {
    let n_gg = outer.inner.len();
    if n_gg == 0 {
        return;
    }
    let inner_rounds = (n_gg as f64).log2().ceil().max(1.0) as usize;
    // Move populations out so survivors (and their EA state) persist.
    let mut alive: Vec<EaArm> = std::mem::take(&mut outer.inner);
    for _n in 0..inner_rounds {
        if ctx.exhausted() || alive.is_empty() {
            break;
        }
        // b_{m,n} = b_m / (|GG_n| * ceil(log2 |GG|))
        let b_mn = (b_m / (alive.len() * inner_rounds)).max(2);
        for arm in alive.iter_mut() {
            if ctx.exhausted() {
                break;
            }
            // Lines 21–25: EA generates and scores b_{m,n} plans.
            arm.run(ctx, b_mn);
        }
        alive = best_half(alive, |a| a.best);
    }
    outer.best = alive
        .iter()
        .map(|a| a.best)
        .fold(f64::INFINITY, f64::min)
        .min(outer.best);
    // Line 29: retain the surviving (best-half) GPU groupings.
    outer.inner = alive;
}

/// Keep the better half (ties broken stably by original index).
fn best_half<T>(items: Vec<T>, score: impl Fn(&T) -> f64) -> Vec<T> {
    if items.len() <= 1 {
        return items;
    }
    let keep = (items.len() + 1) / 2;
    let mut scored: Vec<(f64, usize, T)> = items
        .into_iter()
        .enumerate()
        .map(|(i, x)| (score(&x), i, x))
        .collect();
    scored.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
    scored.truncate(keep);
    scored.into_iter().map(|(_, _, x)| x).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{build_testbed, Scenario, TestbedSpec};
    use crate::workflow::{Algo, Mode, ModelSpec};

    fn setup(scenario: Scenario) -> (RlWorkflow, DeviceTopology, JobConfig) {
        (
            RlWorkflow::new(Algo::Grpo, Mode::Sync, ModelSpec::qwen_4b()),
            build_testbed(scenario, &TestbedSpec::default()),
            JobConfig::default(),
        )
    }

    #[test]
    fn best_half_keeps_best() {
        let v = vec![3.0, 1.0, 2.0, 5.0];
        let kept = best_half(v, |x| *x);
        assert_eq!(kept, vec![1.0, 2.0]);
        let single = best_half(vec![9.0], |x| *x);
        assert_eq!(single, vec![9.0]);
        // Odd count keeps ceil(n/2).
        let odd = best_half(vec![3.0, 1.0, 2.0], |x| *x);
        assert_eq!(odd, vec![1.0, 2.0]);
    }

    #[test]
    fn sha_finds_valid_plan_within_budget() {
        let (wf, topo, job) = setup(Scenario::SingleRegion);
        let mut s = ShaEaScheduler::new(1);
        let out = s.schedule(&topo, &wf, &job, Budget::evals(400));
        assert!(out.cost.is_finite(), "no plan found");
        assert!(out.evals <= 450, "budget overrun: {}", out.evals);
        out.plan.unwrap().validate(&wf, &topo, &job).unwrap();
        assert!(!out.trace.is_empty());
    }

    #[test]
    fn sha_beats_random_plans_on_wan() {
        let (wf, topo, job) = setup(Scenario::MultiContinent);
        let mut sha = ShaEaScheduler::new(3);
        let out = sha.schedule(&topo, &wf, &job, Budget::evals(600));
        // Compare to the *average* of a few random feasible plans.
        let mut ctx = EvalCtx::new(&topo, &wf, &job, Budget::evals(40));
        let mut rng = crate::util::rng::Rng::new(5);
        let groupings = set_partitions(wf.n_tasks());
        let mut costs = Vec::new();
        for i in 0..30 {
            let tg = groupings[i % groupings.len()].clone();
            let ggs = gpu_groupings(&wf, &job, &topo, &tg, 4);
            if ggs.is_empty() {
                continue;
            }
            let sizes = ggs[i % ggs.len()].clone();
            let groups =
                super::super::levels::assign_devices(&wf, &tg, &sizes, &topo, &mut rng);
            if let Some(plans) = super::super::levels::default_task_plans(
                &wf, &job, &topo, &tg, &groups, &mut rng, true,
            ) {
                let plan = super::super::levels::assemble(&tg, groups, plans);
                let c = ctx.cm.plan_cost(&plan).iter_time;
                if plan.validate(&wf, &topo, &job).is_ok() {
                    costs.push(c);
                }
            }
        }
        assert!(!costs.is_empty());
        let mean_random = costs.iter().sum::<f64>() / costs.len() as f64;
        assert!(
            out.cost < mean_random,
            "SHA {} should beat mean random {}",
            out.cost,
            mean_random
        );
    }

    #[test]
    fn more_budget_no_worse() {
        let (wf, topo, job) = setup(Scenario::MultiCountry);
        let small = ShaEaScheduler::new(9).schedule(&topo, &wf, &job, Budget::evals(120));
        let large = ShaEaScheduler::new(9).schedule(&topo, &wf, &job, Budget::evals(900));
        assert!(large.cost <= small.cost * 1.001);
    }
}
