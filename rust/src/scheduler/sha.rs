//! The hybrid SHA-EA scheduler — paper Algorithm 1, run on the parallel
//! evaluation engine.
//!
//! Nested successive halving: Level-1 task groupings are the outer arms,
//! Level-2 GPU groupings the inner arms; each (outer, inner) pair owns an
//! evolutionary population ([`EaArm`]) that generates and evaluates
//! low-level plans. Budgets are measured in cost-model evaluations (the
//! deterministic unit); wall-clock caps still apply through [`EvalCtx`].
//!
//! Parallel schedule: the outer arms' inner-SHA ladders advance in
//! lockstep — at every global step, each still-active outer arm
//! contributes its alive inner arms as one task each, the whole batch
//! runs on the engine's scoped workers, and halving happens at the
//! barrier. Per-arm quotas derive from the *remaining* budget at each
//! barrier (`b_m = remaining / (|alive| * rounds_left)`), assigned in
//! arm order, so `Budget::evals` is a hard cap rather than the old
//! soft target, and the same seed produces the bit-identical best plan
//! at any thread count (see the [`super`] module docs). The same rung
//! machinery (in its seeded form, [`super::engine::run_seeded_rung`])
//! is what the elastic replanner's warm arms and the anytime
//! background search ([`crate::elastic::anytime`]) run on.

use super::ea::{EaArm, EaConfig};
use super::engine::{self, ArmTask};
use super::levels::{gpu_groupings, set_partitions};
use super::{Budget, EvalCtx, ScheduleOutcome, Scheduler};
use crate::topology::DeviceTopology;
use crate::workflow::{JobConfig, RlWorkflow};

/// Configuration of the hybrid scheduler.
#[derive(Debug, Clone)]
pub struct ShaConfig {
    pub ea: EaConfig,
    /// Cap on Level-2 arms per task grouping (quantized enumeration).
    pub max_gpu_groupings: usize,
    pub seed: u64,
    /// Worker threads per rung (0 = all available cores). Any value
    /// yields the same plan for the same seed.
    pub threads: usize,
}

impl Default for ShaConfig {
    fn default() -> Self {
        ShaConfig {
            ea: EaConfig::default(),
            max_gpu_groupings: 12,
            seed: 0x5EED,
            threads: 0,
        }
    }
}

/// HetRL (SHA-EA).
pub struct ShaEaScheduler {
    pub cfg: ShaConfig,
}

impl ShaEaScheduler {
    pub fn new(seed: u64) -> Self {
        ShaEaScheduler { cfg: ShaConfig { seed, ..ShaConfig::default() } }
    }

    /// [`Self::new`] with an explicit worker-thread count (0 = auto).
    pub fn with_threads(seed: u64, threads: usize) -> Self {
        ShaEaScheduler { cfg: ShaConfig { seed, threads, ..ShaConfig::default() } }
    }
}

/// One outer arm: a task grouping with its surviving inner arms.
struct OuterArm {
    inner: Vec<EaArm>,
    best: f64,
}

/// Lockstep inner-SHA state for one outer arm during an outer rung.
struct InnerSha {
    alive: Vec<EaArm>,
    rounds_left: usize,
    budget_left: usize,
}

impl Scheduler for ShaEaScheduler {
    fn name(&self) -> &'static str {
        "HetRL(SHA-EA)"
    }

    fn schedule(
        &mut self,
        topo: &DeviceTopology,
        wf: &RlWorkflow,
        job: &JobConfig,
        budget: Budget,
    ) -> ScheduleOutcome {
        let threads = engine::resolve_threads(self.cfg.threads);
        let mut ctx = EvalCtx::new(topo, wf, job, budget);
        let mut seed = self.cfg.seed;
        let mut next_seed = || {
            seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            seed
        };

        // Line 5–12: enumerate TG and per-tg GG, init populations.
        let mut outers: Vec<OuterArm> = Vec::new();
        for tg in set_partitions(wf.n_tasks()) {
            let ggs = gpu_groupings(wf, job, topo, &tg, self.cfg.max_gpu_groupings);
            if ggs.is_empty() {
                continue;
            }
            let inner: Vec<EaArm> = ggs
                .into_iter()
                .map(|sizes| EaArm::new(tg.clone(), sizes, self.cfg.ea.clone(), next_seed()))
                .collect();
            outers.push(OuterArm { inner, best: f64::INFINITY });
        }
        if outers.is_empty() {
            return ctx.outcome();
        }

        let n_tg = outers.len();
        let outer_rounds = (n_tg as f64).log2().ceil().max(1.0) as usize;

        // Line 14–33: outer SHA over task groupings.
        let mut alive: Vec<OuterArm> = outers;
        for m in 0..outer_rounds {
            if ctx.exhausted() || alive.is_empty() {
                break;
            }
            // b_m from the budget still unspent at this barrier —
            // derived in arm order, so rungs can never overrun the cap.
            let quotas =
                engine::split_quota(ctx.ledger.remaining(), alive.len(), outer_rounds - m);
            run_outer_rung(&mut ctx, &mut alive, &quotas, threads);
            // Line 31: keep the best half of task groupings.
            alive = best_half(alive, |o| o.best);
        }
        ctx.outcome()
    }
}

/// One outer rung: the inner SHA of every alive outer arm (Algorithm 1
/// lines 17–29), advanced in lockstep so all inner arms of all outer
/// arms in the same inner round form one parallel batch. Inner quotas
/// re-derive from each outer arm's remaining rung budget at every step
/// (`b_{m,n}`), and an arm that under-spends (e.g. proved infeasible)
/// hands the difference to its siblings at the next step.
fn run_outer_rung(
    ctx: &mut EvalCtx<'_>,
    outers: &mut [OuterArm],
    quotas: &[usize],
    threads: usize,
) {
    let mut states: Vec<InnerSha> = outers
        .iter_mut()
        .zip(quotas)
        .map(|(o, &q)| {
            let alive = std::mem::take(&mut o.inner);
            let rounds = (alive.len() as f64).log2().ceil().max(1.0) as usize;
            InnerSha { alive, rounds_left: rounds, budget_left: q }
        })
        .collect();

    loop {
        if ctx.exhausted() {
            break;
        }
        // Collect this step's batch across all outer arms.
        let mut tasks: Vec<ArmTask> = Vec::new();
        let mut ran: Vec<usize> = Vec::new();
        for (oi, st) in states.iter_mut().enumerate() {
            if st.rounds_left == 0 || st.budget_left == 0 || st.alive.is_empty() {
                continue;
            }
            ran.push(oi);
            let qs = engine::split_quota(st.budget_left, st.alive.len(), st.rounds_left);
            for (ii, arm) in st.alive.drain(..).enumerate() {
                tasks.push(ArmTask { key: (oi, ii), arm, quota: qs[ii] });
            }
        }
        if tasks.is_empty() {
            break;
        }
        // Lines 21–25: every arm's EA generates and scores its quota,
        // one arm per worker; barrier + in-order merge at return.
        let runs = engine::run_rung(ctx, tasks, threads);
        for r in runs {
            let st = &mut states[r.key.0];
            st.budget_left = st.budget_left.saturating_sub(r.spent);
            st.alive.push(r.arm);
        }
        for &oi in &ran {
            let st = &mut states[oi];
            st.rounds_left -= 1;
            st.alive = best_half(std::mem::take(&mut st.alive), |a| a.best);
        }
    }

    // Line 29: retain the surviving (best-half) GPU groupings.
    for (o, st) in outers.iter_mut().zip(states) {
        o.best = st.alive.iter().map(|a| a.best).fold(o.best, f64::min);
        o.inner = st.alive;
    }
}

/// Keep the better half (ties broken stably by original index).
fn best_half<T>(items: Vec<T>, score: impl Fn(&T) -> f64) -> Vec<T> {
    if items.len() <= 1 {
        return items;
    }
    let keep = (items.len() + 1) / 2;
    let mut scored: Vec<(f64, usize, T)> = items
        .into_iter()
        .enumerate()
        .map(|(i, x)| (score(&x), i, x))
        .collect();
    scored.sort_by(|a, b| crate::util::ford::cmp_f64(a.0, b.0).then(a.1.cmp(&b.1)));
    scored.truncate(keep);
    scored.into_iter().map(|(_, _, x)| x).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{build_testbed, Scenario, TestbedSpec};
    use crate::workflow::{Algo, Mode, ModelSpec};

    fn setup(scenario: Scenario) -> (RlWorkflow, DeviceTopology, JobConfig) {
        (
            RlWorkflow::new(Algo::Grpo, Mode::Sync, ModelSpec::qwen_4b()),
            build_testbed(scenario, &TestbedSpec::default()),
            JobConfig::default(),
        )
    }

    #[test]
    fn best_half_keeps_best() {
        let v = vec![3.0, 1.0, 2.0, 5.0];
        let kept = best_half(v, |x| *x);
        assert_eq!(kept, vec![1.0, 2.0]);
        let single = best_half(vec![9.0], |x| *x);
        assert_eq!(single, vec![9.0]);
        // Odd count keeps ceil(n/2).
        let odd = best_half(vec![3.0, 1.0, 2.0], |x| *x);
        assert_eq!(odd, vec![1.0, 2.0]);
    }

    #[test]
    fn sha_finds_valid_plan_within_budget() {
        let (wf, topo, job) = setup(Scenario::SingleRegion);
        let mut s = ShaEaScheduler::new(1);
        let out = s.schedule(&topo, &wf, &job, Budget::evals(400));
        assert!(out.cost.is_finite(), "no plan found");
        // Remaining-budget quotas make the eval budget a hard cap (the
        // old total-budget `b_m` overran it by ~12%).
        assert!(out.evals <= 400, "budget overrun: {}", out.evals);
        out.plan.unwrap().validate(&wf, &topo, &job).unwrap();
        assert!(!out.trace.is_empty());
    }

    #[test]
    fn sha_uses_cost_cache() {
        let (wf, topo, job) = setup(Scenario::SingleRegion);
        // Enough budget that surviving arms fill their populations and
        // reach the mutation phase, where offspring share most task
        // plans with their parents (the cache's hit case).
        let out = ShaEaScheduler::new(1).schedule(&topo, &wf, &job, Budget::evals(600));
        assert!(out.cache_misses > 0, "cache never consulted");
        assert!(out.cache_hits > 0, "mutated candidates should reuse task costs");
        // Exact accounting: every pricing is either a hit or a miss.
        assert_eq!(out.cache_hits + out.cache_misses, out.task_pricings);
        // Delta-eval (on by default) prices strictly fewer tasks than
        // full re-pricing every candidate would.
        assert!(
            out.task_pricings < out.evals * wf.n_tasks(),
            "delta-eval inactive: {} pricings for {} evals × {} tasks",
            out.task_pricings,
            out.evals,
            wf.n_tasks()
        );
    }

    #[test]
    fn sha_beats_random_plans_on_wan() {
        let (wf, topo, job) = setup(Scenario::MultiContinent);
        let mut sha = ShaEaScheduler::new(3);
        let out = sha.schedule(&topo, &wf, &job, Budget::evals(600));
        // Compare to the *average* of a few random feasible plans.
        let mut ctx = EvalCtx::new(&topo, &wf, &job, Budget::evals(40));
        let mut rng = crate::util::rng::Rng::new(5);
        let groupings = set_partitions(wf.n_tasks());
        let mut costs = Vec::new();
        for i in 0..30 {
            let tg = groupings[i % groupings.len()].clone();
            let ggs = gpu_groupings(&wf, &job, &topo, &tg, 4);
            if ggs.is_empty() {
                continue;
            }
            let sizes = ggs[i % ggs.len()].clone();
            let groups =
                super::super::levels::assign_devices(&wf, &tg, &sizes, &topo, &mut rng);
            if let Some(plans) = super::super::levels::default_task_plans(
                &wf, &job, &topo, &tg, &groups, &mut rng, true,
            ) {
                let plan = super::super::levels::assemble(&tg, groups, plans);
                let c = ctx.cm.plan_cost(&plan).iter_time;
                if plan.validate(&wf, &topo, &job).is_ok() {
                    costs.push(c);
                }
            }
        }
        assert!(!costs.is_empty());
        let mean_random = costs.iter().sum::<f64>() / costs.len() as f64;
        assert!(
            out.cost < mean_random,
            "SHA {} should beat mean random {}",
            out.cost,
            mean_random
        );
    }

    #[test]
    fn more_budget_no_worse() {
        let (wf, topo, job) = setup(Scenario::MultiCountry);
        let small = ShaEaScheduler::new(9).schedule(&topo, &wf, &job, Budget::evals(120));
        let large = ShaEaScheduler::new(9).schedule(&topo, &wf, &job, Budget::evals(900));
        assert!(large.cost <= small.cost * 1.001);
    }
}
