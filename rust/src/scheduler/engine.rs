//! The parallel plan-evaluation engine: deterministic fan-out/fan-in of
//! search work over scoped worker threads.
//!
//! Building blocks (see the [`super`] module docs for the determinism
//! contract):
//!
//! * [`resolve_threads`] — maps a config value (0 = auto) to a worker
//!   count;
//! * [`split_quota`] — deterministic per-arm eval quotas from a
//!   remaining budget (sum never exceeds it);
//! * [`split_allowance`] — deterministic split of an anytime step's
//!   allowance between the primary incumbent and a pending post-event
//!   hypothesis incumbent (predictive preemption);
//! * [`fan_out`] — run jobs on worker [`EvalCtx`]s in parallel and
//!   merge their incumbents/traces back **in job order**;
//! * [`run_rung`] — one SHA/EA rung: each [`EaArm`] runs its quota on
//!   its own worker, arms and spends return in arm order;
//! * [`run_seeded_rung`] — the warm-start variant: each arm first
//!   injects its seed plans (budget-charged), then evolves — the unit
//!   shared by the elastic replanner and the anytime background search.
//!
//! Worker results merge with strict-improvement (`<`) comparisons, so a
//! tie between two arms always resolves to the lower arm index — the
//! same winner a sequential pass over the arms would pick.

use super::ea::EaArm;
use super::{EvalCtx, TracePoint};
use crate::plan::ExecutionPlan;
use crate::util::threadpool::scoped_map;

/// Resolve a configured thread count: `0` means "all available cores".
pub fn resolve_threads(cfg: usize) -> usize {
    if cfg > 0 {
        cfg
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }
}

/// Split `remaining` evaluations across `n_arms` arms that have
/// `rounds_left` halving rounds ahead of them: every arm targets
/// `remaining / (n_arms * rounds_left)` (Algorithm 1's `b_m`, computed
/// from the *remaining* rather than the total budget), floored at one
/// eval, assigned greedily in arm order so the quotas never sum past
/// `remaining`. Arms past the point of exhaustion get zero.
pub fn split_quota(remaining: usize, n_arms: usize, rounds_left: usize) -> Vec<usize> {
    if n_arms == 0 {
        return Vec::new();
    }
    let per = (remaining / (n_arms * rounds_left.max(1))).max(1);
    let mut left = remaining;
    (0..n_arms)
        .map(|_| {
            let q = per.min(left);
            left -= q;
            q
        })
        .collect()
}

/// Split one anytime step's eval allowance between the **primary**
/// incumbent (searched against the current fleet) and a pending
/// **post-event hypothesis** incumbent (searched against the fleet a
/// noticed machine loss is about to produce — see
/// [`crate::elastic::anytime::AnytimeSearch`]). Returns
/// `(primary, hypothesis)`; the halves always sum to exactly `quota`,
/// the primary gets the odd eval, and without a pending hypothesis the
/// primary keeps everything — a pure function of its arguments, so the
/// split is identical at any thread count.
pub fn split_allowance(quota: usize, hypothesis_pending: bool) -> (usize, usize) {
    if !hypothesis_pending {
        return (quota, 0);
    }
    let hyp = quota / 2;
    (quota - hyp, hyp)
}

/// What one worker context produced during a rung.
pub struct WorkerOutcome {
    /// Evaluations this worker charged to the shared ledger.
    pub spent: usize,
    /// Per-task cost resolutions this worker performed (full evals add
    /// the task count, delta evals their footprint size); summed into
    /// the parent in merge order, so the total is thread-count
    /// invariant.
    pub pricings: usize,
    /// Best objective the worker saw (including the parent incumbent's
    /// cost it started from).
    pub best_cost: f64,
    /// The plan behind `best_cost`, when the worker improved on it.
    pub best_plan: Option<ExecutionPlan>,
    /// Strict-improvement trace points, in discovery order.
    pub trace: Vec<TracePoint>,
}

impl WorkerOutcome {
    /// Extract the outcome of a finished worker context.
    pub fn capture(w: EvalCtx<'_>) -> WorkerOutcome {
        WorkerOutcome {
            spent: w.evals,
            pricings: w.pricings,
            best_cost: w.best_cost,
            best_plan: w.best_plan,
            trace: w.trace,
        }
    }
}

/// Merge one worker's outcome into the parent context. Worker traces
/// are strict improvements over the parent's incumbent *at rung start*;
/// filtering against the running merged best keeps the combined trace
/// monotone, and because a worker's trace is itself decreasing, any
/// accepted point implies its final point is accepted — so the plan
/// hand-off below is exactly the plan of the last accepted point.
fn merge(ctx: &mut EvalCtx<'_>, wo: WorkerOutcome) {
    ctx.evals += wo.spent;
    ctx.pricings += wo.pricings;
    let mut improved = false;
    for tp in wo.trace {
        if tp.best_cost < ctx.best_cost {
            ctx.best_cost = tp.best_cost;
            ctx.trace.push(tp);
            improved = true;
        }
    }
    if improved {
        ctx.best_plan = wo.best_plan;
    }
}

/// Run `jobs` on up to `threads` scoped workers, each with its own
/// worker [`EvalCtx`], and merge every worker's incumbent/trace into
/// `ctx` **in job order** (not completion order). Returns the jobs'
/// results, also in job order.
pub fn fan_out<'a, T, R, F>(
    ctx: &mut EvalCtx<'a>,
    threads: usize,
    jobs: Vec<T>,
    f: F,
) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T, &mut EvalCtx<'a>) -> R + Sync,
{
    let parent: &EvalCtx<'a> = ctx;
    let outs: Vec<(R, WorkerOutcome)> = scoped_map(threads, jobs, |job| {
        let mut w = parent.worker();
        let r = f(job, &mut w);
        (r, WorkerOutcome::capture(w))
    });
    let mut results = Vec::with_capacity(outs.len());
    for (r, wo) in outs {
        merge(ctx, wo);
        results.push(r);
    }
    results
}

/// One arm's work unit in a rung: run `quota` evaluations.
pub struct ArmTask {
    /// (outer, inner) identity — carried through so callers can route
    /// results back; also the deterministic merge order.
    pub key: (usize, usize),
    /// The arm (with its population) to evolve.
    pub arm: EaArm,
    /// Evaluations this arm may spend in the rung.
    pub quota: usize,
}

/// One arm's rung result: the arm (with its evolved population) and the
/// evaluations it actually consumed (≤ quota; an infeasible arm hands
/// the rest of its quota back to the caller's accounting).
pub struct ArmRun {
    /// The task's identity, unchanged.
    pub key: (usize, usize),
    /// The arm with its evolved population.
    pub arm: EaArm,
    /// Evaluations actually consumed (≤ the task's quota).
    pub spent: usize,
}

/// Run one rung: every task's arm on its own worker, merged in arm
/// order. Tasks must be pre-sorted by `key` (callers build them that
/// way); results come back in the same order.
pub fn run_rung(ctx: &mut EvalCtx<'_>, tasks: Vec<ArmTask>, threads: usize) -> Vec<ArmRun> {
    fan_out(ctx, threads, tasks, |task, w| {
        let ArmTask { key, mut arm, quota } = task;
        let spent = arm.run(w, quota);
        ArmRun { key, arm, spent }
    })
}

/// An [`ArmTask`] with warm-start seeds: plans injected into the arm's
/// population (in order, each charged one evaluation against the quota)
/// before the evolutionary loop runs. The unit of work shared by the
/// elastic replanner's warm arms and the anytime background search
/// (both the primary and the hypothesis incumbent).
pub struct SeededArmTask {
    /// (outer, inner) identity; the deterministic merge order.
    pub key: (usize, usize),
    /// The arm to seed and evolve.
    pub arm: EaArm,
    /// Evaluations this arm may spend (injections included).
    pub quota: usize,
    /// Warm-start plans to inject before evolving, in order.
    pub seeds: Vec<ExecutionPlan>,
}

/// [`run_rung`] for seeded arms: inject every seed the quota affords,
/// then evolve with the remainder. Merge order and budget accounting
/// are identical to [`run_rung`] — an arm that dies early hands its
/// unspent quota back through `spent`.
pub fn run_seeded_rung(
    ctx: &mut EvalCtx<'_>,
    tasks: Vec<SeededArmTask>,
    threads: usize,
) -> Vec<ArmRun> {
    fan_out(ctx, threads, tasks, |task, w| {
        let SeededArmTask { key, mut arm, quota, seeds } = task;
        let mut left = quota;
        for plan in seeds {
            if left == 0 || w.exhausted() {
                break;
            }
            left = left.saturating_sub(arm.inject(w, plan));
        }
        while left > 0 && !w.exhausted() {
            let spent = arm.run(w, left);
            if spent == 0 {
                break; // dead arm: hand the rest of the quota back
            }
            left -= spent;
        }
        ArmRun { key, arm, spent: quota - left }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_quota_respects_remaining() {
        for (rem, n, rounds) in [(400usize, 15usize, 4usize), (6, 12, 4), (0, 3, 2), (5, 2, 1)] {
            let qs = split_quota(rem, n, rounds);
            assert_eq!(qs.len(), n);
            assert!(qs.iter().sum::<usize>() <= rem, "{rem} {n} {rounds}: {qs:?}");
        }
        // Even split when the budget divides cleanly.
        assert_eq!(split_quota(400, 4, 1), vec![100; 4]);
        // Starved arms get zero, in arm order.
        assert_eq!(split_quota(2, 4, 1), vec![1, 1, 0, 0]);
        assert!(split_quota(0, 4, 2).iter().all(|&q| q == 0));
    }

    #[test]
    fn split_quota_matches_algorithm1_first_round() {
        // b_m = B / (|TG| * ceil(log2 |TG|)) on an untouched budget.
        let qs = split_quota(600, 15, 4);
        assert!(qs.iter().all(|&q| q == 600 / (15 * 4)));
    }

    #[test]
    fn split_allowance_exact_and_primary_biased() {
        for quota in 0..40usize {
            // No hypothesis: the primary keeps the whole allowance.
            assert_eq!(split_allowance(quota, false), (quota, 0));
            // Hypothesis pending: halves sum exactly, primary gets the
            // odd eval, hypothesis never exceeds the primary.
            let (p, h) = split_allowance(quota, true);
            assert_eq!(p + h, quota);
            assert!(p >= h);
            assert!(p - h <= 1);
        }
        assert_eq!(split_allowance(1, true), (1, 0));
        assert_eq!(split_allowance(32, true), (16, 16));
    }
}
