//! Evolutionary low-level plan generation (paper §3.4).
//!
//! Given a Level-1 task grouping and Level-2 GPU group sizes, the EA
//! searches Levels 3–5: concrete device assignment per group, per-task
//! parallelization, and tasklet ordering. Two paper-specific operators:
//!
//! * **TFLOPS-upgrade mutation** — "replaces a GPU in a training-task
//!   group with a higher-TFLOPS one selected from GPUs not assigned to
//!   any training-task group";
//! * **Baldwinian swap local search** — greedy cross-group swaps
//!   maximizing machine/zone/region locality; the improved *phenotype*
//!   is evaluated but "not mapped back to the genotype", preserving
//!   population diversity (Hinton & Nowlan 1987; Baldwin 1896).

use super::levels::{
    assemble, assign_devices, default_task_plans, strategy_feasible, TaskGrouping,
};
use super::{Budget, EvalCtx, ScheduleOutcome, Scheduler};
use crate::plan::parallel::uniform_layer_split;
use crate::plan::{ExecutionPlan, ParallelStrategy};
use crate::topology::DeviceTopology;
use crate::util::rng::Rng;
use crate::workflow::{JobConfig, RlWorkflow, TaskKind};

/// EA hyperparameters.
#[derive(Debug, Clone)]
pub struct EaConfig {
    pub population: usize,
    /// Probability of the TFLOPS-upgrade mutation (vs generic ones).
    pub upgrade_prob: f64,
    /// Swap pairs sampled per local-search pass.
    pub swap_samples: usize,
    pub swap_passes: usize,
    /// Disable the paper-specific operators (the DEAP-like baseline).
    pub vanilla: bool,
}

impl Default for EaConfig {
    fn default() -> Self {
        EaConfig {
            population: 12,
            upgrade_prob: 0.35,
            swap_samples: 160,
            swap_passes: 2,
            vanilla: false,
        }
    }
}

/// EA population for one (task grouping, GPU grouping) arm.
pub struct EaArm {
    pub grouping: TaskGrouping,
    pub sizes: Vec<usize>,
    cfg: EaConfig,
    population: Vec<(ExecutionPlan, f64)>,
    rng: Rng,
    /// Best cost this arm has produced (for SHA's BestHalf).
    pub best: f64,
    /// Consecutive failed random-init draws (resets on success).
    init_failures: usize,
    /// Random init gave up with a partial population; evolve what's there.
    init_exhausted: bool,
    /// The arm proved it cannot produce any feasible plan; [`Self::run`]
    /// returns immediately, handing its quota back to the caller.
    infeasible: bool,
}

impl EaArm {
    /// Failed random-init draws in a row before the arm stops retrying
    /// (and, with an empty population, is declared infeasible).
    const MAX_INIT_FAILURES: usize = 8;

    pub fn new(grouping: TaskGrouping, sizes: Vec<usize>, cfg: EaConfig, seed: u64) -> Self {
        EaArm {
            grouping,
            sizes,
            cfg,
            population: Vec::new(),
            rng: Rng::new(seed),
            best: f64::INFINITY,
            init_failures: 0,
            init_exhausted: false,
            infeasible: false,
        }
    }

    /// The arm was declared dead: no feasible plan after
    /// `MAX_INIT_FAILURES` consecutive init draws.
    pub fn is_infeasible(&self) -> bool {
        self.infeasible
    }

    /// Run up to `budget_evals` evaluations of this arm (or until the
    /// shared ledger's budget/wall cap). Returns the evaluations
    /// actually consumed; a dead arm stops early and returns its
    /// remaining quota to the caller's accounting.
    pub fn run(&mut self, ctx: &mut EvalCtx<'_>, budget_evals: usize) -> usize {
        if self.infeasible {
            return 0;
        }
        let mut spent = 0;
        while spent < budget_evals && !ctx.exhausted() {
            if self.population.len() < self.cfg.population && !self.init_exhausted {
                match self.random_plan(ctx) {
                    Some(plan) => {
                        self.init_failures = 0;
                        spent += self.offer(ctx, plan);
                    }
                    None => {
                        // An infeasible draw still burns one eval.
                        self.init_failures += 1;
                        spent += 1;
                        ctx.charge(1);
                        if self.init_failures >= Self::MAX_INIT_FAILURES {
                            if self.population.is_empty() {
                                // Dead arm: nothing to evolve — stop
                                // burning the budget on hopeless retries.
                                self.infeasible = true;
                                return spent;
                            }
                            self.init_exhausted = true;
                        }
                    }
                }
                continue;
            }
            // offspring by mutation
            let parent = self.rng.below(self.population.len());
            let mut child = self.population[parent].0.clone();
            self.mutate(ctx, &mut child);
            spent += self.offer(ctx, child);
        }
        spent
    }

    /// Warm-start hook: evaluate an externally-built plan (e.g. the
    /// repaired incumbent after a cluster event) and insert it into the
    /// population so subsequent mutation rounds evolve from it. Returns
    /// evaluations consumed.
    pub fn inject(&mut self, ctx: &mut EvalCtx<'_>, plan: ExecutionPlan) -> usize {
        self.offer(ctx, plan)
    }

    /// Number of genomes currently in the population.
    pub fn population_len(&self) -> usize {
        self.population.len()
    }

    /// Evaluate (with Baldwinian local search) and insert into the
    /// population. Returns evaluations consumed.
    fn offer(&mut self, ctx: &mut EvalCtx<'_>, genotype: ExecutionPlan) -> usize {
        let phenotype = if self.cfg.vanilla {
            genotype.clone()
        } else {
            self.local_search(ctx.topo, &genotype)
        };
        let cost = ctx.eval(&phenotype);
        self.best = self.best.min(cost);
        // Population stores the *genotype* with the phenotype's fitness.
        if self.population.len() < self.cfg.population {
            self.population.push((genotype, cost));
        } else {
            let worst = self
                .population
                .iter()
                .enumerate()
                .max_by(|a, b| crate::util::ford::cmp_f64(a.1 .1, b.1 .1))
                .map(|(i, _)| i)
                .unwrap();
            if cost < self.population[worst].1 {
                self.population[worst] = (genotype, cost);
            }
        }
        1
    }

    /// Random Level-3/4/5 initialization for this arm.
    fn random_plan(&mut self, ctx: &EvalCtx<'_>) -> Option<ExecutionPlan> {
        let groups = assign_devices(ctx.wf, &self.grouping, &self.sizes, ctx.topo, &mut self.rng);
        let plans = default_task_plans(
            ctx.wf,
            ctx.job,
            ctx.topo,
            &self.grouping,
            &groups,
            &mut self.rng,
            true,
        )?;
        Some(assemble(&self.grouping, groups, plans))
    }

    /// Mutation operators (paper-specific + generic).
    fn mutate(&mut self, ctx: &EvalCtx<'_>, plan: &mut ExecutionPlan) {
        let use_upgrade =
            !self.cfg.vanilla && self.rng.chance(self.cfg.upgrade_prob);
        if use_upgrade && self.tflops_upgrade(ctx, plan) {
            return;
        }
        match self.rng.below(3) {
            0 => self.mutate_strategy(ctx, plan),
            1 => self.mutate_cross_group_swap(ctx, plan),
            _ => self.mutate_assignment(ctx, plan),
        }
    }

    /// Paper mutation: move a higher-TFLOPS GPU from a non-training group
    /// into a training-task group (swapping with one of its members).
    fn tflops_upgrade(&mut self, ctx: &EvalCtx<'_>, plan: &mut ExecutionPlan) -> bool {
        let wf = ctx.wf;
        // Find training groups and non-training groups.
        let is_training_group = |gi: usize| {
            plan.task_groups[gi]
                .iter()
                .any(|&t| wf.tasks[t].kind() == TaskKind::Training)
        };
        let train_groups: Vec<usize> =
            (0..plan.task_groups.len()).filter(|&g| is_training_group(g)).collect();
        let other_groups: Vec<usize> =
            (0..plan.task_groups.len()).filter(|&g| !is_training_group(g)).collect();
        if train_groups.is_empty() || other_groups.is_empty() {
            return false;
        }
        let tg = *self.rng.choice(&train_groups);
        let og = *self.rng.choice(&other_groups);
        if plan.gpu_groups[tg].is_empty() || plan.gpu_groups[og].is_empty() {
            return false;
        }
        // Slowest device in the training group / fastest outside.
        let slow = *plan.gpu_groups[tg]
            .iter()
            .min_by(|&&a, &&b| {
                crate::util::ford::cmp_f64(
                    ctx.topo.devices[a].effective_flops(),
                    ctx.topo.devices[b].effective_flops(),
                )
            })
            .unwrap();
        let fast = *plan.gpu_groups[og]
            .iter()
            .max_by(|&&a, &&b| {
                crate::util::ford::cmp_f64(
                    ctx.topo.devices[a].effective_flops(),
                    ctx.topo.devices[b].effective_flops(),
                )
            })
            .unwrap();
        if ctx.topo.devices[fast].effective_flops() <= ctx.topo.devices[slow].effective_flops() {
            return false;
        }
        swap_devices(plan, slow, fast);
        true
    }

    /// Re-pick the parallelization of one random task.
    fn mutate_strategy(&mut self, ctx: &EvalCtx<'_>, plan: &mut ExecutionPlan) {
        let t = self.rng.below(ctx.wf.n_tasks());
        let gi = plan.group_of_task(t);
        let devs = plan.gpu_groups[gi].clone();
        let task = &ctx.wf.tasks[t];
        let strategies: Vec<ParallelStrategy> =
            ParallelStrategy::enumerate(devs.len(), task.model.nl, 0.5)
                .into_iter()
                .filter(|&s| strategy_feasible(task, ctx.job, ctx.topo, &devs, s))
                .collect();
        if strategies.is_empty() {
            return;
        }
        let s = *self.rng.choice(&strategies);
        let ordered = ctx.topo.locality_order(&devs);
        plan.task_plans[t].strategy = s;
        plan.task_plans[t].layer_split = uniform_layer_split(task.model.nl, s.pp);
        plan.task_plans[t].dp_shares = vec![1.0 / s.dp as f64; s.dp];
        plan.task_plans[t].assignment = ordered[..s.degree()].to_vec();
    }

    /// Swap one device between two GPU groups (keeping sizes fixed).
    fn mutate_cross_group_swap(&mut self, _ctx: &EvalCtx<'_>, plan: &mut ExecutionPlan) {
        if plan.gpu_groups.len() < 2 {
            return;
        }
        let a = self.rng.below(plan.gpu_groups.len());
        let mut b = self.rng.below(plan.gpu_groups.len());
        if a == b {
            b = (b + 1) % plan.gpu_groups.len();
        }
        if plan.gpu_groups[a].is_empty() || plan.gpu_groups[b].is_empty() {
            return;
        }
        let da = *self.rng.choice(&plan.gpu_groups[a]);
        let db = *self.rng.choice(&plan.gpu_groups[b]);
        swap_devices(plan, da, db);
    }

    /// Permute a task's tasklet→device map: swap two used devices, or
    /// swap a used device for an idle one in the same group.
    fn mutate_assignment(&mut self, _ctx: &EvalCtx<'_>, plan: &mut ExecutionPlan) {
        let t = self.rng.below(plan.task_plans.len());
        let gi = plan.group_of_task(t);
        let group = plan.gpu_groups[gi].clone();
        let tp = &mut plan.task_plans[t];
        if tp.assignment.len() >= 2 && self.rng.chance(0.5) {
            let i = self.rng.below(tp.assignment.len());
            let j = self.rng.below(tp.assignment.len());
            tp.assignment.swap(i, j);
        } else {
            let unused: Vec<usize> = group
                .iter()
                .filter(|d| !tp.assignment.contains(d))
                .cloned()
                .collect();
            if unused.is_empty() {
                return;
            }
            let i = self.rng.below(tp.assignment.len());
            tp.assignment[i] = *self.rng.choice(&unused);
        }
    }

    /// Greedy cross-group swap local search on the locality score
    /// (machine > zone > region affinity). Returns the improved
    /// phenotype; the genotype is left untouched by the caller.
    ///
    /// Perf note (§Perf L3-1): swap gains are computed *incrementally*
    /// on the group membership vectors — swapping `a∈A` with `b∈B`
    /// changes the total locality by
    /// `Σ_{m∈A\{a}} (aff(b,m) − aff(a,m)) + Σ_{m∈B\{b}} (aff(a,m) − aff(b,m))`
    /// — and accepted swaps are recorded and applied to the plan once at
    /// the end, instead of cloning the full plan per sampled swap.
    fn local_search(&mut self, topo: &DeviceTopology, plan: &ExecutionPlan) -> ExecutionPlan {
        if plan.gpu_groups.len() < 2 {
            return plan.clone();
        }
        let mut groups: Vec<Vec<usize>> = plan.gpu_groups.clone();
        let mut accepted: Vec<(usize, usize)> = Vec::new();
        for _pass in 0..self.cfg.swap_passes {
            let mut improved = false;
            for _ in 0..self.cfg.swap_samples {
                let gi = self.rng.below(groups.len());
                let mut gj = self.rng.below(groups.len());
                if gi == gj {
                    gj = (gj + 1) % groups.len();
                }
                if groups[gi].is_empty() || groups[gj].is_empty() {
                    continue;
                }
                let ia = self.rng.below(groups[gi].len());
                let ib = self.rng.below(groups[gj].len());
                let (da, db) = (groups[gi][ia], groups[gj][ib]);
                // Incremental gain of swapping da <-> db.
                let mut gain = 0.0f64;
                for &m in &groups[gi] {
                    if m != da {
                        gain += topo.affinity(db, m) as f64 - topo.affinity(da, m) as f64;
                    }
                }
                for &m in &groups[gj] {
                    if m != db {
                        gain += topo.affinity(da, m) as f64 - topo.affinity(db, m) as f64;
                    }
                }
                if gain > 0.0 {
                    groups[gi][ia] = db;
                    groups[gj][ib] = da;
                    accepted.push((da, db));
                    improved = true;
                }
            }
            if !improved {
                break;
            }
        }
        if accepted.is_empty() {
            return plan.clone();
        }
        let mut best = plan.clone();
        for (a, b) in accepted {
            swap_devices(&mut best, a, b);
        }
        best
    }
}

/// Light perturbations of a seed plan for warm-started populations:
/// each copy swaps one random device pair (cross-group when the plan
/// has several groups, within the group otherwise). Deterministic in
/// `(plan, count, seed)` — the shared helper behind the replanner's
/// warm arms and the elastic anytime background search, so both seed
/// their populations identically for the same arm seed.
pub fn perturbations(plan: &ExecutionPlan, count: usize, seed: u64) -> Vec<ExecutionPlan> {
    let mut rng = Rng::new(seed ^ 0x3A57_11CE);
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let mut mutant = plan.clone();
        let all: Vec<usize> = mutant.gpu_groups.iter().flatten().copied().collect();
        if all.len() >= 2 {
            let a = all[rng.below(all.len())];
            let mut b = all[rng.below(all.len())];
            if a == b {
                b = all[(rng.below(all.len()) + 1) % all.len()];
            }
            swap_devices(&mut mutant, a, b);
        }
        out.push(mutant);
    }
    out
}

/// Swap group membership of devices `a` and `b` and rewrite all task
/// assignments accordingly. Works whether or not the devices are in
/// different groups.
pub fn swap_devices(plan: &mut ExecutionPlan, a: usize, b: usize) {
    if a == b {
        return;
    }
    for grp in plan.gpu_groups.iter_mut() {
        for d in grp.iter_mut() {
            if *d == a {
                *d = b;
            } else if *d == b {
                *d = a;
            }
        }
        grp.sort_unstable();
    }
    for tp in plan.task_plans.iter_mut() {
        for d in tp.assignment.iter_mut() {
            if *d == a {
                *d = b;
            } else if *d == b {
                *d = a;
            }
        }
    }
}

/// The pure-EA baseline (DEAP-like, §6 "Pure EA"): evolves full plans —
/// including the Level-1/2 decisions — with generic operators only, no
/// SHA pruning and no Baldwinian local search. Runs its arms on the
/// parallel evaluation engine (round-robin rungs, deterministic quota
/// split — see [`super::engine`]).
pub struct PureEaScheduler {
    pub seed: u64,
    pub cfg: EaConfig,
    /// Worker threads per rung (0 = all available cores).
    pub threads: usize,
}

impl PureEaScheduler {
    pub fn new(seed: u64) -> Self {
        PureEaScheduler {
            seed,
            cfg: EaConfig { vanilla: true, population: 24, ..EaConfig::default() },
            threads: 0,
        }
    }
}

impl Scheduler for PureEaScheduler {
    fn name(&self) -> &'static str {
        "DEAP(pure-EA)"
    }

    fn schedule(
        &mut self,
        topo: &DeviceTopology,
        wf: &RlWorkflow,
        job: &JobConfig,
        budget: Budget,
    ) -> ScheduleOutcome {
        let threads = super::engine::resolve_threads(self.threads);
        let mut ctx = EvalCtx::new(topo, wf, job, budget);
        let mut rng = Rng::new(self.seed);
        let groupings = super::levels::set_partitions(wf.n_tasks());
        // One arm per random grouping+sizes, all evolving in a single
        // shared population (no hierarchy — that is the point of the
        // baseline).
        let mut arms: Vec<EaArm> = Vec::new();
        for _ in 0..6 {
            let grouping = groupings[rng.below(groupings.len())].clone();
            let sizes_all =
                super::levels::gpu_groupings(wf, job, topo, &grouping, 8);
            if sizes_all.is_empty() {
                continue;
            }
            let sizes = sizes_all[rng.below(sizes_all.len())].clone();
            arms.push(EaArm::new(grouping, sizes, self.cfg.clone(), rng.next_u64()));
        }
        if arms.is_empty() {
            return ctx.outcome();
        }
        // Round-robin without pruning: every arm gets a fixed chunk per
        // rung, capped in arm order by the remaining budget.
        let chunk = 16;
        while !ctx.exhausted() {
            let mut left = ctx.ledger.remaining();
            if left == 0 {
                break;
            }
            let tasks: Vec<super::engine::ArmTask> = arms
                .drain(..)
                .enumerate()
                .map(|(i, arm)| {
                    let quota = chunk.min(left);
                    left -= quota;
                    super::engine::ArmTask { key: (0, i), arm, quota }
                })
                .collect();
            let runs = super::engine::run_rung(&mut ctx, tasks, threads);
            let mut round_spent = 0;
            arms = runs
                .into_iter()
                .filter_map(|r| {
                    round_spent += r.spent;
                    // With no halving to prune it, a dead arm would keep
                    // absorbing quota it cannot spend — drop it so its
                    // share flows to the live arms next round.
                    if r.arm.is_infeasible() {
                        None
                    } else {
                        Some(r.arm)
                    }
                })
                .collect();
            if arms.is_empty() || round_spent == 0 {
                break; // every arm dead or starved — nothing will change
            }
        }
        ctx.outcome()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{build_testbed, Scenario, TestbedSpec};
    use crate::workflow::{Algo, Mode, ModelSpec};

    fn setup() -> (RlWorkflow, DeviceTopology, JobConfig) {
        (
            RlWorkflow::new(Algo::Grpo, Mode::Sync, ModelSpec::qwen_4b()),
            build_testbed(Scenario::SingleRegion, &TestbedSpec::default()),
            JobConfig::default(),
        )
    }

    #[test]
    fn ea_arm_finds_feasible_plans() {
        let (wf, topo, job) = setup();
        let mut ctx = EvalCtx::new(&topo, &wf, &job, Budget::evals(60));
        let grouping: TaskGrouping = vec![vec![0, 1, 2, 3]];
        let mut arm = EaArm::new(grouping, vec![64], EaConfig::default(), 42);
        arm.run(&mut ctx, 60);
        assert!(arm.best.is_finite(), "no feasible plan found");
        let out = ctx.outcome();
        out.plan
            .expect("plan")
            .validate(&wf, &topo, &job)
            .unwrap();
    }

    #[test]
    fn ea_improves_over_time() {
        let (wf, topo, job) = setup();
        let mut ctx = EvalCtx::new(&topo, &wf, &job, Budget::evals(150));
        let grouping: TaskGrouping = vec![vec![0], vec![1, 2, 3]];
        let sizes = vec![24, 40];
        let mut arm = EaArm::new(grouping, sizes, EaConfig::default(), 7);
        arm.run(&mut ctx, 20);
        let early = arm.best;
        arm.run(&mut ctx, 130);
        assert!(arm.best <= early);
    }

    #[test]
    fn swap_devices_keeps_validity() {
        let (wf, topo, job) = setup();
        let mut ctx = EvalCtx::new(&topo, &wf, &job, Budget::evals(20));
        let grouping: TaskGrouping = vec![vec![0, 1], vec![2, 3]];
        let mut arm = EaArm::new(grouping, vec![32, 32], EaConfig::default(), 3);
        arm.run(&mut ctx, 10);
        let mut plan = ctx.best_plan.clone().expect("plan");
        plan.validate(&wf, &topo, &job).unwrap();
        let a = plan.gpu_groups[0][0];
        let b = plan.gpu_groups[1][0];
        swap_devices(&mut plan, a, b);
        plan.validate(&wf, &topo, &job).unwrap();
    }

    #[test]
    fn perturbations_deterministic_and_preserve_device_set() {
        let (wf, topo, job) = setup();
        let mut ctx = EvalCtx::new(&topo, &wf, &job, Budget::evals(20));
        let grouping: TaskGrouping = vec![vec![0, 1], vec![2, 3]];
        let mut arm = EaArm::new(grouping, vec![32, 32], EaConfig::default(), 17);
        arm.run(&mut ctx, 20);
        let plan = ctx.best_plan.clone().expect("plan");
        let a = perturbations(&plan, 3, 99);
        let b = perturbations(&plan, 3, 99);
        assert_eq!(a, b, "same seed must produce identical mutants");
        assert_eq!(a.len(), 3);
        let devset = |p: &ExecutionPlan| {
            let mut v: Vec<usize> = p.gpu_groups.iter().flatten().copied().collect();
            v.sort_unstable();
            v
        };
        for m in &a {
            // A device swap rearranges groups but never invents devices.
            assert_eq!(devset(m), devset(&plan));
        }
    }

    #[test]
    fn pure_ea_scheduler_runs() {
        let (wf, topo, job) = setup();
        let mut s = PureEaScheduler::new(11);
        let out = s.schedule(&topo, &wf, &job, Budget::evals(120));
        assert!(out.cost.is_finite());
        // Quota-based rungs can never overrun the eval budget.
        assert!(out.evals <= 120, "budget overrun: {}", out.evals);
        out.plan.unwrap().validate(&wf, &topo, &job).unwrap();
    }
}
