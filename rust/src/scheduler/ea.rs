//! Evolutionary low-level plan generation (paper §3.4).
//!
//! Given a Level-1 task grouping and Level-2 GPU group sizes, the EA
//! searches Levels 3–5: concrete device assignment per group, per-task
//! parallelization, and tasklet ordering. Two paper-specific operators:
//!
//! * **TFLOPS-upgrade mutation** — "replaces a GPU in a training-task
//!   group with a higher-TFLOPS one selected from GPUs not assigned to
//!   any training-task group";
//! * **Baldwinian swap local search** — greedy cross-group swaps
//!   maximizing machine/zone/region locality; the improved *phenotype*
//!   is evaluated but "not mapped back to the genotype", preserving
//!   population diversity (Hinton & Nowlan 1987; Baldwin 1896).
//!
//! # Delta evaluation
//!
//! Every mutation operator reports the **dirty footprint** of tasks
//! whose `TaskPlan` it rewrote (a single task for strategy/assignment
//! mutations, [`swap_footprint`] for device swaps), and the local
//! search reports the footprint of its accepted swap sequence. Each
//! genome stores its phenotype's per-task costs as a baseline, so an
//! offspring's phenotype can be priced incrementally against its
//! parent's phenotype via [`EvalCtx::eval_delta`]: the child differs
//! from the parent phenotype only on `parent-local-search ∪ mutation ∪
//! child-local-search`. The cost model is pure per task, so the delta
//! path is bit-identical to a full re-price (`tests/prop_delta_eval.rs`
//! pins this against the oracle); it is on by default
//! ([`EaConfig::delta_eval`]) and only changes *how many tasks are
//! priced*, never which candidates are generated or what they score.

use super::levels::{
    assemble, assign_devices, default_task_plans, strategy_feasible, TaskGrouping,
};
use super::{Budget, EvalCtx, ScheduleOutcome, Scheduler};
use crate::costmodel::{DirtySet, TaskCost};
use crate::plan::parallel::uniform_layer_split;
use crate::plan::{ExecutionPlan, ParallelStrategy};
use crate::topology::DeviceTopology;
use crate::util::rng::Rng;
use crate::workflow::{JobConfig, RlWorkflow, TaskKind};

/// EA hyperparameters.
#[derive(Debug, Clone)]
pub struct EaConfig {
    pub population: usize,
    /// Probability of the TFLOPS-upgrade mutation (vs generic ones).
    pub upgrade_prob: f64,
    /// Swap pairs sampled per local-search pass.
    pub swap_samples: usize,
    pub swap_passes: usize,
    /// Disable the paper-specific operators (the DEAP-like baseline).
    pub vanilla: bool,
    /// Price offspring incrementally against their parent's phenotype
    /// baseline (bit-identical to the full path; see the module docs).
    /// On by default; `hetrl schedule --full-eval` turns it off for
    /// consistency smokes.
    pub delta_eval: bool,
    /// Offspring generated and scored per batch in [`EaArm::run`]:
    /// parents are drawn from the population snapshot at batch start,
    /// then the whole batch is priced back-to-back (sharing the
    /// evaluation context's scratch buffer) and inserted in batch
    /// order — deterministic at any thread count.
    pub score_batch: usize,
}

impl Default for EaConfig {
    fn default() -> Self {
        EaConfig {
            population: 12,
            upgrade_prob: 0.35,
            swap_samples: 160,
            swap_passes: 2,
            vanilla: false,
            delta_eval: true,
            score_batch: 8,
        }
    }
}

/// Delta-eval baseline of a genome: its *phenotype*'s per-task costs
/// plus the local-search footprint separating that phenotype from the
/// stored genotype. `None` when the phenotype failed validation (there
/// is nothing sound to delta against).
struct Baseline {
    per_task: Vec<TaskCost>,
    ls_dirty: DirtySet,
}

/// One population entry: the genotype (Baldwinian — the local-search
/// improvement is *not* written back), its phenotype fitness, and the
/// delta-eval baseline.
struct Genome {
    genotype: ExecutionPlan,
    cost: f64,
    base: Option<Baseline>,
}

/// An offspring awaiting scoring: produced by [`EaArm::spawn_candidate`]
/// during the generation half of a batch, priced in the scoring half.
struct Candidate {
    genotype: ExecutionPlan,
    phenotype: ExecutionPlan,
    /// Population index of the parent whose baseline prices this
    /// candidate incrementally; `None` → full evaluation (delta
    /// disabled, or the parent has no baseline). Valid for the whole
    /// batch because insertions are deferred to the batch boundary.
    parent: Option<usize>,
    /// Dirty footprint of `phenotype` vs the parent's *phenotype*:
    /// parent local search ∪ mutation ∪ child local search.
    dirty: DirtySet,
    /// Footprint of `phenotype` vs `genotype` (this candidate's own
    /// local search) — stored as the baseline if it joins the
    /// population.
    ls_dirty: DirtySet,
}

/// EA population for one (task grouping, GPU grouping) arm.
pub struct EaArm {
    pub grouping: TaskGrouping,
    pub sizes: Vec<usize>,
    cfg: EaConfig,
    population: Vec<Genome>,
    rng: Rng,
    /// Best cost this arm has produced (for SHA's BestHalf).
    pub best: f64,
    /// Consecutive failed random-init draws (resets on success).
    init_failures: usize,
    /// Random init gave up with a partial population; evolve what's there.
    init_exhausted: bool,
    /// The arm proved it cannot produce any feasible plan; [`Self::run`]
    /// returns immediately, handing its quota back to the caller.
    infeasible: bool,
}

impl EaArm {
    /// Failed random-init draws in a row before the arm stops retrying
    /// (and, with an empty population, is declared infeasible).
    const MAX_INIT_FAILURES: usize = 8;

    pub fn new(grouping: TaskGrouping, sizes: Vec<usize>, cfg: EaConfig, seed: u64) -> Self {
        EaArm {
            grouping,
            sizes,
            cfg,
            population: Vec::new(),
            rng: Rng::new(seed),
            best: f64::INFINITY,
            init_failures: 0,
            init_exhausted: false,
            infeasible: false,
        }
    }

    /// The arm was declared dead: no feasible plan after
    /// `MAX_INIT_FAILURES` consecutive init draws.
    pub fn is_infeasible(&self) -> bool {
        self.infeasible
    }

    /// Run up to `budget_evals` evaluations of this arm (or until the
    /// shared ledger's budget/wall cap). Returns the evaluations
    /// actually consumed; a dead arm stops early and returns its
    /// remaining quota to the caller's accounting.
    pub fn run(&mut self, ctx: &mut EvalCtx<'_>, budget_evals: usize) -> usize {
        if self.infeasible {
            return 0;
        }
        let mut spent = 0;
        while spent < budget_evals && !ctx.exhausted() {
            if self.population.len() < self.cfg.population && !self.init_exhausted {
                match self.random_plan(ctx) {
                    Some(plan) => {
                        self.init_failures = 0;
                        spent += self.offer(ctx, plan);
                    }
                    None => {
                        // An infeasible draw still burns one eval.
                        self.init_failures += 1;
                        spent += 1;
                        ctx.charge(1);
                        if self.init_failures >= Self::MAX_INIT_FAILURES {
                            if self.population.is_empty() {
                                // Dead arm: nothing to evolve — stop
                                // burning the budget on hopeless retries.
                                self.infeasible = true;
                                return spent;
                            }
                            self.init_exhausted = true;
                        }
                    }
                }
                continue;
            }
            // Offspring by mutation, in scoring batches: parents are
            // drawn from the population snapshot at batch start, all
            // candidates are generated (mutation + local search, pure
            // RNG work), then priced back-to-back — the tight pricing
            // loop reuses the context's scratch buffer — and finally
            // inserted in batch order. Deferring insertion keeps every
            // candidate's parent index (and its delta baseline) valid
            // through the whole batch.
            let batch = self.cfg.score_batch.max(1).min(budget_evals - spent);
            let mut cands = Vec::with_capacity(batch);
            for _ in 0..batch {
                cands.push(self.spawn_candidate(ctx));
            }
            let mut scored = Vec::with_capacity(cands.len());
            for cand in cands {
                if ctx.exhausted() {
                    break;
                }
                scored.push(self.score(ctx, cand));
                spent += 1;
            }
            for g in scored {
                self.insert_genome(g);
            }
        }
        spent
    }

    /// Warm-start hook: evaluate an externally-built plan (e.g. the
    /// repaired incumbent after a cluster event) and insert it into the
    /// population so subsequent mutation rounds evolve from it. Returns
    /// evaluations consumed.
    pub fn inject(&mut self, ctx: &mut EvalCtx<'_>, plan: ExecutionPlan) -> usize {
        self.offer(ctx, plan)
    }

    /// Number of genomes currently in the population.
    pub fn population_len(&self) -> usize {
        self.population.len()
    }

    /// Fully evaluate (with Baldwinian local search) and insert into
    /// the population — the path for random inits and injected seeds,
    /// which have no parent baseline to delta against. Returns
    /// evaluations consumed.
    fn offer(&mut self, ctx: &mut EvalCtx<'_>, genotype: ExecutionPlan) -> usize {
        let (phenotype, ls_dirty) = if self.cfg.vanilla {
            (genotype.clone(), DirtySet::new())
        } else {
            self.local_search(ctx.topo, &genotype)
        };
        let cost = ctx.eval(&phenotype);
        self.best = self.best.min(cost);
        let base = ctx.last_per_task().map(|pt| Baseline {
            per_task: pt.to_vec(),
            ls_dirty,
        });
        self.insert_genome(Genome { genotype, cost, base });
        1
    }

    /// Generation half of a batch: draw a parent from the current
    /// population, mutate its genotype, run the local search, and
    /// assemble the dirty footprint of the child phenotype versus the
    /// parent phenotype (parent local search ∪ mutation ∪ child local
    /// search). Pure RNG + plan surgery — no evaluations are charged.
    fn spawn_candidate(&mut self, ctx: &EvalCtx<'_>) -> Candidate {
        let parent = self.rng.below(self.population.len());
        let mut genotype = self.population[parent].genotype.clone();
        let mut dirty = self.mutate(ctx, &mut genotype);
        let (phenotype, ls_dirty) = if self.cfg.vanilla {
            (genotype.clone(), DirtySet::new())
        } else {
            self.local_search(ctx.topo, &genotype)
        };
        dirty.union_with(&ls_dirty);
        let parent = if self.cfg.delta_eval {
            match &self.population[parent].base {
                Some(b) => {
                    dirty.union_with(&b.ls_dirty);
                    Some(parent)
                }
                None => None,
            }
        } else {
            None
        };
        Candidate { genotype, phenotype, parent, dirty, ls_dirty }
    }

    /// Scoring half of a batch: price one candidate (incrementally
    /// against its parent's baseline when it has one, fully otherwise)
    /// and package it as a genome with its own baseline. Exactly one
    /// evaluation.
    fn score(&mut self, ctx: &mut EvalCtx<'_>, cand: Candidate) -> Genome {
        let Candidate { genotype, phenotype, parent, dirty, ls_dirty } = cand;
        let cost = match parent {
            Some(p) => {
                let base = self.population[p].base.as_ref().expect("parent baseline");
                ctx.eval_delta(&phenotype, &base.per_task, &dirty)
            }
            None => ctx.eval(&phenotype),
        };
        self.best = self.best.min(cost);
        let base = ctx.last_per_task().map(|pt| Baseline {
            per_task: pt.to_vec(),
            ls_dirty,
        });
        Genome { genotype, cost, base }
    }

    /// Population-insertion policy: fill to capacity, then replace the
    /// worst genome on strict improvement. The population stores the
    /// *genotype* with the phenotype's fitness (Baldwinian).
    fn insert_genome(&mut self, g: Genome) {
        if self.population.len() < self.cfg.population {
            self.population.push(g);
        } else {
            let worst = self
                .population
                .iter()
                .enumerate()
                .max_by(|a, b| crate::util::ford::cmp_f64(a.1.cost, b.1.cost))
                .map(|(i, _)| i)
                .unwrap();
            if g.cost < self.population[worst].cost {
                self.population[worst] = g;
            }
        }
    }

    /// Random Level-3/4/5 initialization for this arm.
    fn random_plan(&mut self, ctx: &EvalCtx<'_>) -> Option<ExecutionPlan> {
        let groups = assign_devices(ctx.wf, &self.grouping, &self.sizes, ctx.topo, &mut self.rng);
        let plans = default_task_plans(
            ctx.wf,
            ctx.job,
            ctx.topo,
            &self.grouping,
            &groups,
            &mut self.rng,
            true,
        )?;
        Some(assemble(&self.grouping, groups, plans))
    }

    /// Mutation operators (paper-specific + generic). Returns the dirty
    /// footprint: a superset of the tasks whose `TaskPlan` the mutation
    /// rewrote (empty for a no-op draw).
    fn mutate(&mut self, ctx: &EvalCtx<'_>, plan: &mut ExecutionPlan) -> DirtySet {
        let use_upgrade =
            !self.cfg.vanilla && self.rng.chance(self.cfg.upgrade_prob);
        if use_upgrade {
            if let Some(fp) = self.tflops_upgrade(ctx, plan) {
                return fp;
            }
        }
        match self.rng.below(3) {
            0 => self.mutate_strategy(ctx, plan),
            1 => self.mutate_cross_group_swap(ctx, plan),
            _ => self.mutate_assignment(ctx, plan),
        }
    }

    /// Paper mutation: move a higher-TFLOPS GPU from a non-training group
    /// into a training-task group (swapping with one of its members).
    /// Returns the swap's dirty footprint, or `None` if no upgrading
    /// swap exists (the caller falls through to the generic operators).
    fn tflops_upgrade(
        &mut self,
        ctx: &EvalCtx<'_>,
        plan: &mut ExecutionPlan,
    ) -> Option<DirtySet> {
        let wf = ctx.wf;
        // Find training groups and non-training groups.
        let is_training_group = |gi: usize| {
            plan.task_groups[gi]
                .iter()
                .any(|&t| wf.tasks[t].kind() == TaskKind::Training)
        };
        let train_groups: Vec<usize> =
            (0..plan.task_groups.len()).filter(|&g| is_training_group(g)).collect();
        let other_groups: Vec<usize> =
            (0..plan.task_groups.len()).filter(|&g| !is_training_group(g)).collect();
        if train_groups.is_empty() || other_groups.is_empty() {
            return None;
        }
        let tg = *self.rng.choice(&train_groups);
        let og = *self.rng.choice(&other_groups);
        if plan.gpu_groups[tg].is_empty() || plan.gpu_groups[og].is_empty() {
            return None;
        }
        // Slowest device in the training group / fastest outside.
        let slow = *plan.gpu_groups[tg]
            .iter()
            .min_by(|&&a, &&b| {
                crate::util::ford::cmp_f64(
                    ctx.topo.devices[a].effective_flops(),
                    ctx.topo.devices[b].effective_flops(),
                )
            })
            .unwrap();
        let fast = *plan.gpu_groups[og]
            .iter()
            .max_by(|&&a, &&b| {
                crate::util::ford::cmp_f64(
                    ctx.topo.devices[a].effective_flops(),
                    ctx.topo.devices[b].effective_flops(),
                )
            })
            .unwrap();
        if ctx.topo.devices[fast].effective_flops() <= ctx.topo.devices[slow].effective_flops() {
            return None;
        }
        let fp = swap_footprint(plan, slow, fast);
        swap_devices(plan, slow, fast);
        Some(fp)
    }

    /// Re-pick the parallelization of one random task. Footprint: that
    /// task (empty when no feasible alternative strategy exists).
    fn mutate_strategy(&mut self, ctx: &EvalCtx<'_>, plan: &mut ExecutionPlan) -> DirtySet {
        let t = self.rng.below(ctx.wf.n_tasks());
        let gi = plan.group_of_task(t);
        let devs = plan.gpu_groups[gi].clone();
        let task = &ctx.wf.tasks[t];
        let strategies: Vec<ParallelStrategy> =
            ParallelStrategy::enumerate(devs.len(), task.model.nl, 0.5)
                .into_iter()
                .filter(|&s| strategy_feasible(task, ctx.job, ctx.topo, &devs, s))
                .collect();
        if strategies.is_empty() {
            return DirtySet::new();
        }
        let s = *self.rng.choice(&strategies);
        let ordered = ctx.topo.locality_order(&devs);
        plan.task_plans[t].strategy = s;
        plan.task_plans[t].layer_split = uniform_layer_split(task.model.nl, s.pp);
        plan.task_plans[t].dp_shares = vec![1.0 / s.dp as f64; s.dp];
        plan.task_plans[t].assignment = ordered[..s.degree()].to_vec();
        DirtySet::single(t)
    }

    /// Swap one device between two GPU groups (keeping sizes fixed).
    /// Footprint: every task whose assignment touches either device.
    fn mutate_cross_group_swap(
        &mut self,
        _ctx: &EvalCtx<'_>,
        plan: &mut ExecutionPlan,
    ) -> DirtySet {
        if plan.gpu_groups.len() < 2 {
            return DirtySet::new();
        }
        let a = self.rng.below(plan.gpu_groups.len());
        let mut b = self.rng.below(plan.gpu_groups.len());
        if a == b {
            b = (b + 1) % plan.gpu_groups.len();
        }
        if plan.gpu_groups[a].is_empty() || plan.gpu_groups[b].is_empty() {
            return DirtySet::new();
        }
        let da = *self.rng.choice(&plan.gpu_groups[a]);
        let db = *self.rng.choice(&plan.gpu_groups[b]);
        let fp = swap_footprint(plan, da, db);
        swap_devices(plan, da, db);
        fp
    }

    /// Permute a task's tasklet→device map: swap two used devices, or
    /// swap a used device for an idle one in the same group. Footprint:
    /// that task (empty when the group has no idle device to swap in).
    fn mutate_assignment(&mut self, _ctx: &EvalCtx<'_>, plan: &mut ExecutionPlan) -> DirtySet {
        let t = self.rng.below(plan.task_plans.len());
        let gi = plan.group_of_task(t);
        let group = plan.gpu_groups[gi].clone();
        let tp = &mut plan.task_plans[t];
        if tp.assignment.len() >= 2 && self.rng.chance(0.5) {
            let i = self.rng.below(tp.assignment.len());
            let j = self.rng.below(tp.assignment.len());
            tp.assignment.swap(i, j);
        } else {
            let unused: Vec<usize> = group
                .iter()
                .filter(|d| !tp.assignment.contains(d))
                .cloned()
                .collect();
            if unused.is_empty() {
                return DirtySet::new();
            }
            let i = self.rng.below(tp.assignment.len());
            tp.assignment[i] = *self.rng.choice(&unused);
        }
        DirtySet::single(t)
    }

    /// Greedy cross-group swap local search on the locality score
    /// (machine > zone > region affinity). Returns the improved
    /// phenotype plus the dirty footprint of the accepted swap sequence
    /// (phenotype vs input plan); the genotype is left untouched by the
    /// caller.
    ///
    /// Perf note (§Perf L3-1): swap gains are computed *incrementally*
    /// on the group membership vectors — swapping `a∈A` with `b∈B`
    /// changes the total locality by
    /// `Σ_{m∈A\{a}} (aff(b,m) − aff(a,m)) + Σ_{m∈B\{b}} (aff(a,m) − aff(b,m))`
    /// — and accepted swaps are recorded and applied to the plan once at
    /// the end, instead of cloning the full plan per sampled swap.
    fn local_search(
        &mut self,
        topo: &DeviceTopology,
        plan: &ExecutionPlan,
    ) -> (ExecutionPlan, DirtySet) {
        if plan.gpu_groups.len() < 2 {
            return (plan.clone(), DirtySet::new());
        }
        let mut groups: Vec<Vec<usize>> = plan.gpu_groups.clone();
        let mut accepted: Vec<(usize, usize)> = Vec::new();
        for _pass in 0..self.cfg.swap_passes {
            let mut improved = false;
            for _ in 0..self.cfg.swap_samples {
                let gi = self.rng.below(groups.len());
                let mut gj = self.rng.below(groups.len());
                if gi == gj {
                    gj = (gj + 1) % groups.len();
                }
                if groups[gi].is_empty() || groups[gj].is_empty() {
                    continue;
                }
                let ia = self.rng.below(groups[gi].len());
                let ib = self.rng.below(groups[gj].len());
                let (da, db) = (groups[gi][ia], groups[gj][ib]);
                // Incremental gain of swapping da <-> db.
                let mut gain = 0.0f64;
                for &m in &groups[gi] {
                    if m != da {
                        gain += topo.affinity(db, m) as f64 - topo.affinity(da, m) as f64;
                    }
                }
                for &m in &groups[gj] {
                    if m != db {
                        gain += topo.affinity(da, m) as f64 - topo.affinity(db, m) as f64;
                    }
                }
                if gain > 0.0 {
                    groups[gi][ia] = db;
                    groups[gj][ib] = da;
                    accepted.push((da, db));
                    improved = true;
                }
            }
            if !improved {
                break;
            }
        }
        if accepted.is_empty() {
            return (plan.clone(), DirtySet::new());
        }
        let mut best = plan.clone();
        let mut dirty = DirtySet::new();
        for (a, b) in accepted {
            // Footprint of each swap against the plan state it applies
            // to; the union is a sound superset of every task the swap
            // sequence touched (a task swapped back to its original
            // plan stays marked — redundant, never wrong).
            dirty.union_with(&swap_footprint(&best, a, b));
            swap_devices(&mut best, a, b);
        }
        (best, dirty)
    }
}

/// Light perturbations of a seed plan for warm-started populations:
/// each copy swaps one random device pair (cross-group when the plan
/// has several groups, within the group otherwise). Deterministic in
/// `(plan, count, seed)` — the shared helper behind the replanner's
/// warm arms and the elastic anytime background search, so both seed
/// their populations identically for the same arm seed.
pub fn perturbations(plan: &ExecutionPlan, count: usize, seed: u64) -> Vec<ExecutionPlan> {
    perturbations_with_footprints(plan, count, seed)
        .into_iter()
        .map(|(p, _)| p)
        .collect()
}

/// [`perturbations`] plus each mutant's dirty footprint versus the seed
/// plan — the form the delta-eval property tests drive their seeded
/// perturbation chains with. Identical RNG stream and mutants as
/// [`perturbations`] for the same `(plan, count, seed)`.
pub fn perturbations_with_footprints(
    plan: &ExecutionPlan,
    count: usize,
    seed: u64,
) -> Vec<(ExecutionPlan, DirtySet)> {
    let mut rng = Rng::new(seed ^ 0x3A57_11CE);
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let mut mutant = plan.clone();
        let mut dirty = DirtySet::new();
        let all: Vec<usize> = mutant.gpu_groups.iter().flatten().copied().collect();
        if all.len() >= 2 {
            let a = all[rng.below(all.len())];
            let mut b = all[rng.below(all.len())];
            if a == b {
                b = all[(rng.below(all.len()) + 1) % all.len()];
            }
            dirty = swap_footprint(&mutant, a, b);
            swap_devices(&mut mutant, a, b);
        }
        out.push((mutant, dirty));
    }
    out
}

/// Tasks whose `TaskPlan` a [`swap_devices`]`(plan, a, b)` call would
/// rewrite: exactly those whose assignment contains either device.
/// Containment of `{a, b}` is invariant under the swap itself, so the
/// footprint is the same computed before or after applying it.
pub fn swap_footprint(plan: &ExecutionPlan, a: usize, b: usize) -> DirtySet {
    let mut dirty = DirtySet::new();
    if a == b {
        return dirty;
    }
    for (t, tp) in plan.task_plans.iter().enumerate() {
        if tp.assignment.iter().any(|&d| d == a || d == b) {
            dirty.insert(t);
        }
    }
    dirty
}

/// Swap group membership of devices `a` and `b` and rewrite all task
/// assignments accordingly. Works whether or not the devices are in
/// different groups.
pub fn swap_devices(plan: &mut ExecutionPlan, a: usize, b: usize) {
    if a == b {
        return;
    }
    for grp in plan.gpu_groups.iter_mut() {
        for d in grp.iter_mut() {
            if *d == a {
                *d = b;
            } else if *d == b {
                *d = a;
            }
        }
        grp.sort_unstable();
    }
    for tp in plan.task_plans.iter_mut() {
        for d in tp.assignment.iter_mut() {
            if *d == a {
                *d = b;
            } else if *d == b {
                *d = a;
            }
        }
    }
}

/// The pure-EA baseline (DEAP-like, §6 "Pure EA"): evolves full plans —
/// including the Level-1/2 decisions — with generic operators only, no
/// SHA pruning and no Baldwinian local search. Runs its arms on the
/// parallel evaluation engine (round-robin rungs, deterministic quota
/// split — see [`super::engine`]).
pub struct PureEaScheduler {
    pub seed: u64,
    pub cfg: EaConfig,
    /// Worker threads per rung (0 = all available cores).
    pub threads: usize,
}

impl PureEaScheduler {
    pub fn new(seed: u64) -> Self {
        PureEaScheduler {
            seed,
            cfg: EaConfig { vanilla: true, population: 24, ..EaConfig::default() },
            threads: 0,
        }
    }
}

impl Scheduler for PureEaScheduler {
    fn name(&self) -> &'static str {
        "DEAP(pure-EA)"
    }

    fn schedule(
        &mut self,
        topo: &DeviceTopology,
        wf: &RlWorkflow,
        job: &JobConfig,
        budget: Budget,
    ) -> ScheduleOutcome {
        let threads = super::engine::resolve_threads(self.threads);
        let mut ctx = EvalCtx::new(topo, wf, job, budget);
        let mut rng = Rng::new(self.seed);
        let groupings = super::levels::set_partitions(wf.n_tasks());
        // One arm per random grouping+sizes, all evolving in a single
        // shared population (no hierarchy — that is the point of the
        // baseline).
        let mut arms: Vec<EaArm> = Vec::new();
        for _ in 0..6 {
            let grouping = groupings[rng.below(groupings.len())].clone();
            let sizes_all =
                super::levels::gpu_groupings(wf, job, topo, &grouping, 8);
            if sizes_all.is_empty() {
                continue;
            }
            let sizes = sizes_all[rng.below(sizes_all.len())].clone();
            arms.push(EaArm::new(grouping, sizes, self.cfg.clone(), rng.next_u64()));
        }
        if arms.is_empty() {
            return ctx.outcome();
        }
        // Round-robin without pruning: every arm gets a fixed chunk per
        // rung, capped in arm order by the remaining budget.
        let chunk = 16;
        while !ctx.exhausted() {
            let mut left = ctx.ledger.remaining();
            if left == 0 {
                break;
            }
            let tasks: Vec<super::engine::ArmTask> = arms
                .drain(..)
                .enumerate()
                .map(|(i, arm)| {
                    let quota = chunk.min(left);
                    left -= quota;
                    super::engine::ArmTask { key: (0, i), arm, quota }
                })
                .collect();
            let runs = super::engine::run_rung(&mut ctx, tasks, threads);
            let mut round_spent = 0;
            arms = runs
                .into_iter()
                .filter_map(|r| {
                    round_spent += r.spent;
                    // With no halving to prune it, a dead arm would keep
                    // absorbing quota it cannot spend — drop it so its
                    // share flows to the live arms next round.
                    if r.arm.is_infeasible() {
                        None
                    } else {
                        Some(r.arm)
                    }
                })
                .collect();
            if arms.is_empty() || round_spent == 0 {
                break; // every arm dead or starved — nothing will change
            }
        }
        ctx.outcome()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{build_testbed, Scenario, TestbedSpec};
    use crate::workflow::{Algo, Mode, ModelSpec};

    fn setup() -> (RlWorkflow, DeviceTopology, JobConfig) {
        (
            RlWorkflow::new(Algo::Grpo, Mode::Sync, ModelSpec::qwen_4b()),
            build_testbed(Scenario::SingleRegion, &TestbedSpec::default()),
            JobConfig::default(),
        )
    }

    #[test]
    fn ea_arm_finds_feasible_plans() {
        let (wf, topo, job) = setup();
        let mut ctx = EvalCtx::new(&topo, &wf, &job, Budget::evals(60));
        let grouping: TaskGrouping = vec![vec![0, 1, 2, 3]];
        let mut arm = EaArm::new(grouping, vec![64], EaConfig::default(), 42);
        arm.run(&mut ctx, 60);
        assert!(arm.best.is_finite(), "no feasible plan found");
        let out = ctx.outcome();
        out.plan
            .expect("plan")
            .validate(&wf, &topo, &job)
            .unwrap();
    }

    #[test]
    fn ea_improves_over_time() {
        let (wf, topo, job) = setup();
        let mut ctx = EvalCtx::new(&topo, &wf, &job, Budget::evals(150));
        let grouping: TaskGrouping = vec![vec![0], vec![1, 2, 3]];
        let sizes = vec![24, 40];
        let mut arm = EaArm::new(grouping, sizes, EaConfig::default(), 7);
        arm.run(&mut ctx, 20);
        let early = arm.best;
        arm.run(&mut ctx, 130);
        assert!(arm.best <= early);
    }

    #[test]
    fn swap_devices_keeps_validity() {
        let (wf, topo, job) = setup();
        let mut ctx = EvalCtx::new(&topo, &wf, &job, Budget::evals(20));
        let grouping: TaskGrouping = vec![vec![0, 1], vec![2, 3]];
        let mut arm = EaArm::new(grouping, vec![32, 32], EaConfig::default(), 3);
        arm.run(&mut ctx, 10);
        let mut plan = ctx.best_plan.clone().expect("plan");
        plan.validate(&wf, &topo, &job).unwrap();
        let a = plan.gpu_groups[0][0];
        let b = plan.gpu_groups[1][0];
        swap_devices(&mut plan, a, b);
        plan.validate(&wf, &topo, &job).unwrap();
    }

    #[test]
    fn perturbations_deterministic_and_preserve_device_set() {
        let (wf, topo, job) = setup();
        let mut ctx = EvalCtx::new(&topo, &wf, &job, Budget::evals(20));
        let grouping: TaskGrouping = vec![vec![0, 1], vec![2, 3]];
        let mut arm = EaArm::new(grouping, vec![32, 32], EaConfig::default(), 17);
        arm.run(&mut ctx, 20);
        let plan = ctx.best_plan.clone().expect("plan");
        let a = perturbations(&plan, 3, 99);
        let b = perturbations(&plan, 3, 99);
        assert_eq!(a, b, "same seed must produce identical mutants");
        assert_eq!(a.len(), 3);
        let devset = |p: &ExecutionPlan| {
            let mut v: Vec<usize> = p.gpu_groups.iter().flatten().copied().collect();
            v.sort_unstable();
            v
        };
        for m in &a {
            // A device swap rearranges groups but never invents devices.
            assert_eq!(devset(m), devset(&plan));
        }
    }

    #[test]
    fn perturbation_footprints_cover_changed_tasks() {
        let (wf, topo, job) = setup();
        let mut ctx = EvalCtx::new(&topo, &wf, &job, Budget::evals(20));
        let grouping: TaskGrouping = vec![vec![0, 1], vec![2, 3]];
        let mut arm = EaArm::new(grouping, vec![32, 32], EaConfig::default(), 23);
        arm.run(&mut ctx, 20);
        let plan = ctx.best_plan.clone().expect("plan");
        let mutants = perturbations_with_footprints(&plan, 8, 5);
        assert_eq!(
            mutants.iter().map(|(p, _)| p.clone()).collect::<Vec<_>>(),
            perturbations(&plan, 8, 5),
            "footprint variant must not perturb the RNG stream"
        );
        for (i, (m, dirty)) in mutants.iter().enumerate() {
            for t in 0..plan.task_plans.len() {
                if plan.task_plans[t] != m.task_plans[t] {
                    assert!(dirty.contains(t), "mutant {i}: task {t} changed but not dirty");
                }
            }
        }
    }

    #[test]
    fn delta_eval_matches_full_eval_bitwise() {
        // The same arm seed with delta on vs off must walk the identical
        // search trajectory and land on the identical best cost — delta
        // changes how many tasks are priced, never what anything scores.
        let (wf, topo, job) = setup();
        let run = |delta: bool| {
            let mut ctx = EvalCtx::new(&topo, &wf, &job, Budget::evals(140));
            let cfg = EaConfig { delta_eval: delta, ..EaConfig::default() };
            let grouping: TaskGrouping = vec![vec![0], vec![1, 2, 3]];
            let mut arm = EaArm::new(grouping, vec![24, 40], cfg, 9);
            arm.run(&mut ctx, 140);
            let best = arm.best;
            let out = ctx.outcome();
            (best, out)
        };
        let (best_d, out_d) = run(true);
        let (best_f, out_f) = run(false);
        assert_eq!(best_d.to_bits(), best_f.to_bits());
        assert_eq!(out_d.cost.to_bits(), out_f.cost.to_bits());
        assert_eq!(out_d.plan, out_f.plan);
        assert_eq!(out_d.evals, out_f.evals);
        assert!(
            out_d.task_pricings < out_f.task_pricings,
            "delta must price strictly fewer tasks: {} vs {}",
            out_d.task_pricings,
            out_f.task_pricings
        );
    }

    #[test]
    fn pure_ea_scheduler_runs() {
        let (wf, topo, job) = setup();
        let mut s = PureEaScheduler::new(11);
        let out = s.schedule(&topo, &wf, &job, Budget::evals(120));
        assert!(out.cost.is_finite());
        // Quota-based rungs can never overrun the eval budget.
        assert!(out.evals <= 120, "budget overrun: {}", out.evals);
        out.plan.unwrap().validate(&wf, &topo, &job).unwrap();
    }
}
