//! Shared, seeded test fixtures for the integration/property suites.
//!
//! Every `tests/*.rs` file used to hand-roll its own topologies,
//! workflows, replan configs and random-plan generators; the copies
//! drifted and each new suite re-invented them. This module is the
//! single source: deterministic builders over the public crate API,
//! usable from `tests/`, benches and in-crate unit tests alike.
//!
//! Conventions:
//! * the **full testbed** helpers ([`env`]/[`env_with`]) build the
//!   paper's 64-GPU fleet with `JobConfig::default()`;
//! * the **small testbed** helpers ([`small_spec`]/[`small_topo`] and
//!   the `small_*_cfg` configs) build a 12-GPU, 3-machine fleet with
//!   reduced search budgets — big enough for real group structure,
//!   small enough for debug-mode property runs;
//! * [`test_threads`] is the worker-thread matrix the determinism
//!   tests sweep; `HETRL_TEST_THREADS=n` replaces it with `{1, n}`,
//!   which is how `ci.sh` splits the suite into a fast sequential
//!   pass (`=1`) and a 1-vs-8 cross-thread determinism pass (`=8`).

use crate::elastic::{ReplanConfig, ReplayConfig, TraceConfig};
use crate::plan::ExecutionPlan;
use crate::scheduler::ea::EaConfig;
use crate::scheduler::levels::{
    assemble, assign_devices, default_task_plans, gpu_groupings, set_partitions,
};
use crate::simulator::{OpId, SimGraph};
use crate::topology::{build_testbed, DeviceTopology, GpuModel, Scenario, TestbedSpec};
use crate::util::rng::Rng;
use crate::workflow::{Algo, JobConfig, Mode, ModelSpec, RlWorkflow};

/// Default full-testbed environment: Qwen-4B sync GRPO on the paper's
/// 64-GPU fleet with the default job.
pub fn env(scenario: Scenario) -> (RlWorkflow, DeviceTopology, JobConfig) {
    env_with(scenario, Algo::Grpo, Mode::Sync, ModelSpec::qwen_4b())
}

/// [`env`] with explicit algorithm/mode/model.
pub fn env_with(
    scenario: Scenario,
    algo: Algo,
    mode: Mode,
    model: ModelSpec,
) -> (RlWorkflow, DeviceTopology, JobConfig) {
    (
        RlWorkflow::new(algo, mode, model),
        build_testbed(scenario, &TestbedSpec::default()),
        JobConfig::default(),
    )
}

/// The small workflow paired with [`small_spec`]: Qwen-1.7B sync GRPO
/// (use `JobConfig::tiny()` alongside it).
pub fn tiny_wf() -> RlWorkflow {
    RlWorkflow::new(Algo::Grpo, Mode::Sync, ModelSpec::qwen_1b7())
}

/// A 12-GPU, 3-machine testbed — big enough for real group structure,
/// small enough for debug-mode property runs.
pub fn small_spec() -> TestbedSpec {
    TestbedSpec {
        machines: vec![(GpuModel::A100, 1), (GpuModel::L40S, 1), (GpuModel::L4, 1)],
        gpus_per_machine: 4,
        ..TestbedSpec::default()
    }
}

/// [`small_spec`] materialized for a scenario.
pub fn small_topo(scenario: Scenario) -> DeviceTopology {
    build_testbed(scenario, &small_spec())
}

/// Reduced-budget replanning config matching [`small_spec`].
pub fn small_replan_cfg() -> ReplanConfig {
    ReplanConfig {
        warm_budget: 40,
        cold_budget: 160,
        seed_mutants: 2,
        ea: EaConfig { swap_samples: 40, ..EaConfig::default() },
        ..ReplanConfig::default()
    }
}

/// Short dynamic-replay config (6 iterations, 3 events) over
/// [`small_replan_cfg`]. Recovery pricing stays at its default
/// (disabled) — see [`fault_replay_cfg`] for the chaos variant.
pub fn small_replay_cfg() -> ReplayConfig {
    ReplayConfig {
        iters: 6,
        trace: TraceConfig { horizon: 6, n_events: 3, ..TraceConfig::default() },
        replan: small_replan_cfg(),
        ..ReplayConfig::default()
    }
}

/// Chaos-replay config for `tests/prop_recover.rs`: the small testbed
/// over an 8-iteration trace with 2 ordinary events, `faults` seeded
/// transient faults, and recovery pricing on at a 120 s checkpoint
/// cadence (short enough that tiny traces actually complete
/// checkpoints).
pub fn fault_replay_cfg(faults: usize, threads: usize) -> ReplayConfig {
    let mut cfg = small_replay_cfg();
    cfg.iters = 8;
    cfg.trace = TraceConfig {
        horizon: 8,
        n_events: 2,
        fault_events: faults,
        ..TraceConfig::default()
    };
    cfg.replan.threads = threads;
    cfg.recovery = crate::costmodel::RecoveryModel::with_interval(120.0);
    cfg
}

/// Replay config for the background-search property suites
/// (`tests/prop_anytime.rs`, `tests/prop_preempt.rs`): the small
/// testbed budgets over an 8-iteration, 2-event trace with a generous
/// sim-time allowance so the background (and, under `--policy preempt`,
/// hypothesis) search visibly runs. Callers pin
/// `trace.notice_override` to force or strip advance notice.
pub fn background_replay_cfg(threads: usize) -> ReplayConfig {
    let mut cfg = small_replay_cfg();
    cfg.iters = 8;
    cfg.trace = TraceConfig { horizon: 8, n_events: 2, ..TraceConfig::default() };
    cfg.replan.threads = threads;
    // Align the amortization horizon with the iterations actually
    // remaining in the short trace, so the migration-aware objective
    // tracks the realized replay cost.
    cfg.replan.horizon_iters = 4.0;
    cfg.replan.anytime = crate::elastic::AnytimeConfig {
        evals_per_sim_sec: 8.0,
        max_step_evals: 32,
        arms: 2,
        seed_mutants: 2,
    };
    cfg
}

/// The tiny job with the async-pipeline knobs pinned: staleness bound
/// 2, rollout-queue capacity 2. Pair with
/// [`tiny_wf`]`.with_mode(Mode::Async)` (or let
/// [`crate::asyncrl::replay_async`] pin the knobs itself from its
/// config).
pub fn async_job() -> JobConfig {
    JobConfig { staleness_bound: 2, rollout_queue_cap: 2, ..JobConfig::tiny() }
}

/// Async replay config over [`small_replay_cfg`]: the given staleness
/// bound, queue capacity 2, a 4-step DES window, and generation-pool
/// fractions suited to the 12-GPU small testbed.
pub fn async_replay_cfg(staleness_bound: usize, threads: usize) -> crate::asyncrl::AsyncReplayConfig {
    let mut base = small_replay_cfg();
    base.replan.threads = threads;
    crate::asyncrl::AsyncReplayConfig {
        base,
        staleness_bound,
        queue_capacity: 2,
        window: 4,
        gen_fracs: vec![1.0 / 3.0, 0.5, 2.0 / 3.0],
    }
}

/// Seeded random op-DAG over `n_resources` devices plus a couple of
/// WAN link tokens: durations quantized to 0.25 s (including zeros) so
/// distinct ops genuinely finish — and successors become ready — at
/// identical timestamps, random dependency fan-in from earlier ops,
/// occasional zero-duration barriers. Shared by the component-engine
/// equivalence suite (`tests/integration_simulator.rs`) and the
/// interleave fuzz suite (`tests/prop_interleave.rs`).
pub fn random_sim_graph(seed: u64, n_ops: usize, n_resources: usize) -> SimGraph {
    assert!(n_resources > 0, "random_sim_graph needs at least one device");
    let mut rng = Rng::new(seed ^ 0x51D5_EED5_0DA6_0000);
    let mut g = SimGraph::new(n_resources);
    let links: Vec<usize> = (0..n_resources.min(2)).map(|_| g.add_resource()).collect();
    fn pick_deps(rng: &mut Rng, upto: usize, max_n: usize) -> Vec<OpId> {
        let n = rng.below(max_n + 1);
        let mut deps: Vec<OpId> = (0..n).map(|_| rng.below(upto)).collect();
        deps.sort_unstable();
        deps.dedup();
        deps
    }
    for i in 0..n_ops {
        // ~1 in 8 ops is a barrier over random predecessors.
        if i > 0 && rng.chance(0.125) {
            g.barrier(pick_deps(&mut rng, i, 3));
            continue;
        }
        let mut resources = vec![rng.below(n_resources)];
        if rng.chance(0.25) {
            let r2 = rng.below(n_resources);
            if r2 != resources[0] {
                resources.push(r2);
            }
        }
        if rng.chance(0.2) {
            resources.push(links[rng.below(links.len())]);
        }
        let duration = rng.below(5) as f64 * 0.25;
        let deps = if i == 0 { Vec::new() } else { pick_deps(&mut rng, i, 2) };
        g.add(resources, duration, deps, i % 4);
    }
    g
}

/// Generate a random valid plan through the Level-1..5 machinery
/// (`None` when ten seeded attempts all fail).
pub fn random_plan(
    wf: &RlWorkflow,
    topo: &DeviceTopology,
    job: &JobConfig,
    seed: u64,
) -> Option<ExecutionPlan> {
    let mut rng = Rng::new(seed);
    let groupings = set_partitions(wf.n_tasks());
    for _ in 0..10 {
        let tg = groupings[rng.below(groupings.len())].clone();
        let ggs = gpu_groupings(wf, job, topo, &tg, 8);
        if ggs.is_empty() {
            continue;
        }
        let sizes = ggs[rng.below(ggs.len())].clone();
        let groups = assign_devices(wf, &tg, &sizes, topo, &mut rng);
        if let Some(plans) = default_task_plans(wf, job, topo, &tg, &groups, &mut rng, true) {
            let plan = assemble(&tg, groups, plans);
            if plan.validate(wf, topo, job).is_ok() {
                return Some(plan);
            }
        }
    }
    None
}

/// Load the AOT-artifact runtime, or `None` (with a skip notice) when
/// `artifacts/` is absent — the gate every runtime-backed integration
/// test shares.
pub fn artifacts_runtime() -> Option<crate::runtime::Runtime> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(crate::runtime::Runtime::load("artifacts").expect("runtime load"))
}

/// Worker-thread counts the determinism tests compare. By default the
/// canonical `{1, 2, 8}`. When `HETRL_TEST_THREADS=n` is set it
/// *replaces* the sweep with `{1, n}` (just `{1}` for `n = 1`): the
/// 1-thread run is always present as the comparison baseline, and the
/// two `ci.sh` passes become genuinely different — a fast
/// sequential-only suite at `=1`, and a 1-vs-8 cross-thread
/// determinism suite at `=8`.
pub fn test_threads() -> Vec<usize> {
    if let Some(n) = std::env::var("HETRL_TEST_THREADS")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&n| n > 0)
    {
        return if n == 1 { vec![1] } else { vec![1, n] };
    }
    vec![1, 2, 8]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_testbed_shape() {
        assert_eq!(small_spec().total_gpus(), 12);
        let topo = small_topo(Scenario::MultiCountry);
        assert_eq!(topo.n(), 12);
    }

    #[test]
    fn random_plan_validates() {
        let (wf, topo, job) = env(Scenario::MultiCountry);
        let mut found = 0;
        for seed in 0..20u64 {
            if let Some(p) = random_plan(&wf, &topo, &job, seed) {
                p.validate(&wf, &topo, &job).unwrap();
                found += 1;
            }
        }
        assert!(found > 0, "no valid random plan in 20 seeds");
    }

    #[test]
    fn async_fixtures_are_consistent() {
        let j = async_job();
        assert_eq!(j.staleness_bound, 2);
        assert_eq!(j.rollout_queue_cap, 2);
        let c = async_replay_cfg(1, 4);
        assert_eq!(c.staleness_bound, 1);
        assert_eq!(c.base.replan.threads, 4);
        assert!(c.window >= 1);
        assert!(c.gen_fracs.iter().all(|f| (0.0..1.0).contains(f)));
    }

    #[test]
    fn test_threads_always_has_baseline() {
        // The 1-thread baseline is always present, whatever the env
        // override says (tests compare N-thread runs against it).
        let t = test_threads();
        assert!(t.contains(&1));
        assert!(!t.is_empty());
    }
}
