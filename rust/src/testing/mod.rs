//! Property-based testing mini-framework (proptest is unavailable
//! offline). Provides composable generators over a seeded [`Rng`] and a
//! `check` runner with linear shrinking for failures, plus the shared
//! deterministic fixture builders ([`fixtures`]) every `tests/*.rs`
//! suite builds its environments from.
//!
//! Usage (`no_run`: doctest binaries lack the xla rpath in this image):
//! ```no_run
//! use hetrl::testing::{check, Gen};
//! check("add commutes", 100, Gen::pair(Gen::usize_range(0, 100), Gen::usize_range(0, 100)),
//!       |&(a, b)| a + b == b + a);
//! ```

pub mod fixtures;

use crate::util::rng::Rng;
use std::fmt::Debug;

/// A generator of values of type `T` plus a shrinker.
pub struct Gen<T> {
    gen: Box<dyn Fn(&mut Rng) -> T>,
    shrink: Box<dyn Fn(&T) -> Vec<T>>,
}

impl<T: Clone + 'static> Gen<T> {
    pub fn new(
        gen: impl Fn(&mut Rng) -> T + 'static,
        shrink: impl Fn(&T) -> Vec<T> + 'static,
    ) -> Gen<T> {
        Gen { gen: Box::new(gen), shrink: Box::new(shrink) }
    }

    /// Generator with no shrinking.
    pub fn no_shrink(gen: impl Fn(&mut Rng) -> T + 'static) -> Gen<T> {
        Gen::new(gen, |_| Vec::new())
    }

    pub fn sample(&self, rng: &mut Rng) -> T {
        (self.gen)(rng)
    }

    pub fn shrinks(&self, v: &T) -> Vec<T> {
        (self.shrink)(v)
    }

    /// Map the generated value (shrinking is lost across the map).
    pub fn map<U: Clone + 'static>(self, f: impl Fn(T) -> U + 'static) -> Gen<U> {
        Gen::no_shrink(move |rng| f(self.sample(rng)))
    }
}

impl Gen<usize> {
    /// Uniform usize in `[lo, hi)` shrinking toward `lo`.
    pub fn usize_range(lo: usize, hi: usize) -> Gen<usize> {
        assert!(hi > lo);
        Gen::new(
            move |rng| rng.range(lo, hi),
            move |&v| {
                let mut out = Vec::new();
                if v > lo {
                    out.push(lo);
                    out.push(lo + (v - lo) / 2);
                    out.push(v - 1);
                }
                out.dedup();
                out
            },
        )
    }
}

impl Gen<f64> {
    /// Uniform f64 in `[lo, hi)` shrinking toward `lo`.
    pub fn f64_range(lo: f64, hi: f64) -> Gen<f64> {
        assert!(hi > lo);
        Gen::new(
            move |rng| rng.range_f64(lo, hi),
            move |&v| {
                if v > lo {
                    vec![lo, lo + (v - lo) / 2.0]
                } else {
                    Vec::new()
                }
            },
        )
    }
}

impl<T: Clone + 'static> Gen<Vec<T>> {
    /// Vec with length in `[min_len, max_len]`, elements from `elem`.
    /// Shrinks by halving the vector and shrinking single elements.
    pub fn vec(elem: Gen<T>, min_len: usize, max_len: usize) -> Gen<Vec<T>> {
        assert!(max_len >= min_len);
        let elem = std::rc::Rc::new(elem);
        let elem2 = std::rc::Rc::clone(&elem);
        Gen::new(
            move |rng| {
                let n = rng.range(min_len, max_len + 1);
                (0..n).map(|_| elem.sample(rng)).collect()
            },
            move |v: &Vec<T>| {
                let mut out = Vec::new();
                if v.len() > min_len {
                    // drop second half
                    out.push(v[..min_len.max(v.len() / 2)].to_vec());
                    // drop last element
                    out.push(v[..v.len() - 1].to_vec());
                }
                // shrink first shrinkable element
                for (i, x) in v.iter().enumerate() {
                    let sh = elem2.shrinks(x);
                    if let Some(smaller) = sh.into_iter().next() {
                        let mut w = v.clone();
                        w[i] = smaller;
                        out.push(w);
                        break;
                    }
                }
                out
            },
        )
    }
}

impl<A: Clone + 'static, B: Clone + 'static> Gen<(A, B)> {
    /// Pair generator shrinking each component independently.
    pub fn pair(a: Gen<A>, b: Gen<B>) -> Gen<(A, B)> {
        let a = std::rc::Rc::new(a);
        let b = std::rc::Rc::new(b);
        let (a2, b2) = (std::rc::Rc::clone(&a), std::rc::Rc::clone(&b));
        Gen::new(
            move |rng| (a.sample(rng), b.sample(rng)),
            move |(x, y)| {
                let mut out: Vec<(A, B)> = Vec::new();
                for xs in a2.shrinks(x) {
                    out.push((xs, y.clone()));
                }
                for ys in b2.shrinks(y) {
                    out.push((x.clone(), ys));
                }
                out
            },
        )
    }
}

/// Pick uniformly from a fixed set of choices (no shrink).
pub fn one_of<T: Clone + 'static>(choices: Vec<T>) -> Gen<T> {
    assert!(!choices.is_empty());
    Gen::no_shrink(move |rng| choices[rng.below(choices.len())].clone())
}

/// Run a property over `cases` random cases. On failure, shrink up to 200
/// steps and panic with the smallest found counterexample.
pub fn check<T: Clone + Debug + 'static>(
    name: &str,
    cases: usize,
    gen: Gen<T>,
    prop: impl Fn(&T) -> bool,
) {
    check_seeded(name, cases, 0xC0FFEE, gen, prop)
}

/// [`check`] with an explicit seed.
pub fn check_seeded<T: Clone + Debug + 'static>(
    name: &str,
    cases: usize,
    seed: u64,
    gen: Gen<T>,
    prop: impl Fn(&T) -> bool,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let v = gen.sample(&mut rng);
        if !prop(&v) {
            // shrink
            let mut smallest = v.clone();
            let mut budget = 200;
            'outer: while budget > 0 {
                for cand in gen.shrinks(&smallest) {
                    budget -= 1;
                    if !prop(&cand) {
                        smallest = cand;
                        continue 'outer;
                    }
                    if budget == 0 {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property '{name}' failed on case {case}:\n  original: {v:?}\n  shrunk:   {smallest:?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("reverse twice is id", 200, Gen::vec(Gen::usize_range(0, 50), 0, 20), |v| {
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            w == *v
        });
    }

    #[test]
    fn failing_property_shrinks() {
        let result = std::panic::catch_unwind(|| {
            check("all < 10 (false)", 500, Gen::vec(Gen::usize_range(0, 100), 0, 10), |v| {
                v.iter().all(|&x| x < 10)
            });
        });
        let err = result.expect_err("property should fail");
        let msg = err.downcast_ref::<String>().expect("panic message");
        assert!(msg.contains("shrunk"), "got: {msg}");
    }

    #[test]
    fn pair_generation() {
        check(
            "pair in bounds",
            300,
            Gen::pair(Gen::usize_range(1, 5), Gen::f64_range(0.0, 1.0)),
            |&(a, b)| (1..5).contains(&a) && (0.0..1.0).contains(&b),
        );
    }

    #[test]
    fn one_of_picks_members() {
        let g = one_of(vec!["a", "b", "c"]);
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let v = g.sample(&mut rng);
            assert!(["a", "b", "c"].contains(&v));
        }
    }
}
