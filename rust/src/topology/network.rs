//! Region-level network model. The paper measured latencies/bandwidths
//! between 10 cloud regions (Virginia, Ohio, Paris, Stockholm, London,
//! Ireland, Spain, Zurich, Frankfurt, Milan) and replayed them on the
//! testbed. We reconstruct a measured-style matrix from great-circle
//! distances: delay ≈ RTT over fiber (~2/3 c) plus a routing overhead,
//! and WAN bandwidth in the paper's reported envelopes (0.9–5.0 Gbps).

use crate::util::units::{GBITPS_BYTES, MS};

/// The ten regions of the paper's Figure 3(a,b).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Region {
    Virginia,
    Ohio,
    Paris,
    Stockholm,
    London,
    Ireland,
    Spain,
    Zurich,
    Frankfurt,
    Milan,
}

impl Region {
    pub const ALL: [Region; 10] = [
        Region::Virginia,
        Region::Ohio,
        Region::Paris,
        Region::Stockholm,
        Region::London,
        Region::Ireland,
        Region::Spain,
        Region::Zurich,
        Region::Frankfurt,
        Region::Milan,
    ];

    /// Regions on the EU side (the paper's Multi-Country scenario).
    pub const EUROPE: [Region; 8] = [
        Region::Paris,
        Region::Stockholm,
        Region::London,
        Region::Ireland,
        Region::Spain,
        Region::Zurich,
        Region::Frankfurt,
        Region::Milan,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Region::Virginia => "Virginia",
            Region::Ohio => "Ohio",
            Region::Paris => "Paris",
            Region::Stockholm => "Stockholm",
            Region::London => "London",
            Region::Ireland => "Ireland",
            Region::Spain => "Spain",
            Region::Zurich => "Zurich",
            Region::Frankfurt => "Frankfurt",
            Region::Milan => "Milan",
        }
    }

    /// Approximate (lat, lon) of the region's data-center metro.
    fn coords(self) -> (f64, f64) {
        match self {
            Region::Virginia => (38.9, -77.4),
            Region::Ohio => (40.0, -83.0),
            Region::Paris => (48.9, 2.4),
            Region::Stockholm => (59.3, 18.1),
            Region::London => (51.5, -0.1),
            Region::Ireland => (53.3, -6.3),
            Region::Spain => (40.4, -3.7),
            Region::Zurich => (47.4, 8.5),
            Region::Frankfurt => (50.1, 8.7),
            Region::Milan => (45.5, 9.2),
        }
    }

    pub fn is_us(self) -> bool {
        matches!(self, Region::Virginia | Region::Ohio)
    }
}

fn haversine_km(a: (f64, f64), b: (f64, f64)) -> f64 {
    let (lat1, lon1) = (a.0.to_radians(), a.1.to_radians());
    let (lat2, lon2) = (b.0.to_radians(), b.1.to_radians());
    let dlat = lat2 - lat1;
    let dlon = lon2 - lon1;
    let h = (dlat / 2.0).sin().powi(2) + lat1.cos() * lat2.cos() * (dlon / 2.0).sin().powi(2);
    2.0 * 6371.0 * h.sqrt().asin()
}

/// Inter-region network graph: one-way delay (s) and bandwidth (bytes/s)
/// between every pair of regions.
#[derive(Debug, Clone)]
pub struct RegionGraph {
    pub regions: Vec<Region>,
    /// One-way delay in seconds, indexed by position in `regions`.
    pub delay: Vec<Vec<f64>>,
    /// Bandwidth in bytes/s.
    pub bandwidth: Vec<Vec<f64>>,
}

impl RegionGraph {
    /// Build the measured-style matrix for a set of regions.
    ///
    /// One-way delay model: `distance / (0.66 c) * 1.25` routing factor
    /// (fiber paths are not geodesics), floor of 0.25 ms. WAN bandwidth
    /// model: decays with distance from ~5 Gbps (nearby regions) to
    /// ~0.9 Gbps (trans-atlantic), matching the envelopes the paper
    /// reports (Multi-Country: 5–30 ms, 1.9–5.0 Gbps; Multi-Continent:
    /// 5–60 ms, 0.9–5.0 Gbps).
    pub fn build(regions: &[Region]) -> RegionGraph {
        let n = regions.len();
        let mut delay = vec![vec![0.0; n]; n];
        let mut bandwidth = vec![vec![f64::INFINITY; n]; n];
        const C_FIBER_KM_PER_S: f64 = 199_862.0; // 2/3 c
        for i in 0..n {
            for j in 0..n {
                if i == j {
                    delay[i][j] = 0.05 * MS;
                    bandwidth[i][j] = 25.0 * 8.0 * GBITPS_BYTES; // same-DC: 25 GB/s class
                    continue;
                }
                let km = haversine_km(regions[i].coords(), regions[j].coords());
                let d = (km / C_FIBER_KM_PER_S * 1.25).max(0.25 * MS);
                delay[i][j] = d;
                // Bandwidth: 5 Gbps within ~1200 km decaying to 0.9 Gbps
                // at ~7000 km, clamped.
                let bw_gbps = (5.0 - (km - 1200.0).max(0.0) / 5800.0 * 4.1).clamp(0.9, 5.0);
                bandwidth[i][j] = bw_gbps * GBITPS_BYTES;
            }
        }
        RegionGraph { regions: regions.to_vec(), delay, bandwidth }
    }

    pub fn index_of(&self, r: Region) -> usize {
        self.regions.iter().position(|&x| x == r).expect("region not in graph")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delays_in_paper_envelopes() {
        let g = RegionGraph::build(&Region::ALL);
        // EU↔EU pairs: 5–30 ms envelope (allow small slack at the bottom
        // for adjacent metros like Frankfurt–Zurich).
        for &a in &Region::EUROPE {
            for &b in &Region::EUROPE {
                if a == b {
                    continue;
                }
                let d = g.delay[g.index_of(a)][g.index_of(b)];
                assert!(d > 0.2 * MS && d < 30.0 * MS, "{}-{} delay {d}", a.name(), b.name());
            }
        }
        // Transatlantic: up to 60 ms, at least 15 ms.
        let d = g.delay[g.index_of(Region::Virginia)][g.index_of(Region::Stockholm)];
        assert!(d > 15.0 * MS && d < 60.0 * MS, "transatlantic delay {d}");
    }

    #[test]
    fn bandwidth_in_paper_envelopes() {
        let g = RegionGraph::build(&Region::ALL);
        for i in 0..g.regions.len() {
            for j in 0..g.regions.len() {
                if i == j {
                    continue;
                }
                let bw = g.bandwidth[i][j] / GBITPS_BYTES;
                assert!((0.9..=5.0).contains(&bw), "bw {bw} Gbps out of envelope");
            }
        }
        // Transatlantic links are the slowest.
        let va_sto = g.bandwidth[g.index_of(Region::Virginia)][g.index_of(Region::Stockholm)];
        let par_fra = g.bandwidth[g.index_of(Region::Paris)][g.index_of(Region::Frankfurt)];
        assert!(va_sto < par_fra);
    }

    #[test]
    fn symmetric() {
        let g = RegionGraph::build(&Region::ALL);
        for i in 0..10 {
            for j in 0..10 {
                assert!((g.delay[i][j] - g.delay[j][i]).abs() < 1e-12);
                assert!((g.bandwidth[i][j] - g.bandwidth[j][i]).abs() < 1e-3);
            }
        }
    }
}
