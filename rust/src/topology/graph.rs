//! The device topology graph `G_D = (V_D, E_D, comp, mem, hbm, A, B)`
//! (paper §3.1 / Appendix B.1): N devices, each labeled with computation
//! capability, memory capacity and HBM bandwidth; each edge labeled with
//! latency α and bandwidth β.

use super::gpu::{GpuModel, GpuSpec};
use crate::util::units::MS;

/// One GPU with its placement in the machine/zone/region hierarchy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Device {
    pub id: usize,
    pub gpu: GpuModel,
    /// Machine (server) index; GPUs on a machine share NVLink/PCIe.
    pub machine: usize,
    /// Availability zone index (unique per region in our builders).
    pub zone: usize,
    /// Region index into the testbed's region list.
    pub region: usize,
    /// Sustained-speed multiplier in (0, 1]; 1.0 for a healthy device.
    /// The elastic layer ([`crate::elastic`]) lowers it for stragglers,
    /// and both the cost model and the simulator see the effect through
    /// [`Device::effective_flops`].
    pub speed: f64,
}

impl Device {
    pub fn spec(&self) -> GpuSpec {
        self.gpu.spec()
    }

    /// Achievable sustained FLOP/s (peak × MFU ceiling). Both the cost
    /// model and the simulator use this — it is what the HetRL profiler
    /// measures on real hardware ("computation power (TFLOPs)", §4.1).
    #[inline]
    pub fn effective_flops(&self) -> f64 {
        let s = self.spec();
        s.fp16_flops * s.mfu * self.speed
    }
}

/// Full device topology with dense α/β matrices (seconds, bytes/s).
#[derive(Debug, Clone)]
pub struct DeviceTopology {
    pub devices: Vec<Device>,
    /// `alpha[i][j]`: one-way latency in seconds (0 on the diagonal).
    pub alpha: Vec<Vec<f64>>,
    /// `beta[i][j]`: bandwidth in bytes/s (infinite on the diagonal).
    pub beta: Vec<Vec<f64>>,
    /// Region names for display.
    pub region_names: Vec<String>,
}

impl DeviceTopology {
    pub fn n(&self) -> usize {
        self.devices.len()
    }

    /// Total FP16 compute across devices (FLOP/s).
    pub fn total_flops(&self) -> f64 {
        self.devices.iter().map(|d| d.spec().fp16_flops).sum()
    }

    /// Total memory capacity (bytes).
    pub fn total_mem(&self) -> f64 {
        self.devices.iter().map(|d| d.spec().mem_bytes).sum()
    }

    /// Latency between two devices (one-way, seconds).
    #[inline]
    pub fn lat(&self, a: usize, b: usize) -> f64 {
        self.alpha[a][b]
    }

    /// Bandwidth between two devices (bytes/s).
    #[inline]
    pub fn bw(&self, a: usize, b: usize) -> f64 {
        self.beta[a][b]
    }

    /// α + volume/β for a point-to-point transfer.
    #[inline]
    pub fn xfer_time(&self, a: usize, b: usize, bytes: f64) -> f64 {
        if a == b {
            return 0.0;
        }
        self.alpha[a][b] + bytes / self.beta[a][b]
    }

    /// Locality score between two devices: 3 = same machine, 2 = same
    /// zone, 1 = same region, 0 = cross-region. Used by the EA's swap
    /// local search (paper §3.4: "machine-, zone-, and region-level
    /// affinities").
    #[inline]
    pub fn affinity(&self, a: usize, b: usize) -> u32 {
        let (da, db) = (&self.devices[a], &self.devices[b]);
        if da.machine == db.machine {
            3
        } else if da.zone == db.zone {
            2
        } else if da.region == db.region {
            1
        } else {
            0
        }
    }

    /// Sum of pairwise affinities within a device set (the EA's
    /// group-locality objective).
    pub fn group_locality(&self, devs: &[usize]) -> f64 {
        let mut s = 0.0;
        for (idx, &a) in devs.iter().enumerate() {
            for &b in devs.iter().skip(idx + 1) {
                s += self.affinity(a, b) as f64;
            }
        }
        s
    }

    /// Devices sorted by locality (region, zone, machine, id): the
    /// nearest-neighbour ring order used by the comm cost heuristics.
    pub fn locality_order(&self, devs: &[usize]) -> Vec<usize> {
        let mut v = devs.to_vec();
        v.sort_by_key(|&d| {
            let dev = &self.devices[d];
            (dev.region, dev.zone, dev.machine, dev.id)
        });
        v
    }

    /// Count devices of each GPU model, for display.
    pub fn census(&self) -> Vec<(GpuModel, usize)> {
        let mut counts: Vec<(GpuModel, usize)> = Vec::new();
        for d in &self.devices {
            match counts.iter_mut().find(|(m, _)| *m == d.gpu) {
                Some((_, c)) => *c += 1,
                None => counts.push((d.gpu, 1)),
            }
        }
        counts.sort_by_key(|(m, _)| *m);
        counts
    }

    /// Restrict the topology to a subset of device ids, renumbering them
    /// 0..k. Returns the sub-topology and the old-id mapping.
    pub fn subset(&self, ids: &[usize]) -> (DeviceTopology, Vec<usize>) {
        let k = ids.len();
        let mut devices = Vec::with_capacity(k);
        for (new_id, &old) in ids.iter().enumerate() {
            let mut d = self.devices[old];
            d.id = new_id;
            devices.push(d);
        }
        let mut alpha = vec![vec![0.0; k]; k];
        let mut beta = vec![vec![f64::INFINITY; k]; k];
        for (i, &a) in ids.iter().enumerate() {
            for (j, &b) in ids.iter().enumerate() {
                alpha[i][j] = self.alpha[a][b];
                beta[i][j] = self.beta[a][b];
            }
        }
        (
            DeviceTopology {
                devices,
                alpha,
                beta,
                region_names: self.region_names.clone(),
            },
            ids.to_vec(),
        )
    }
}

/// Builder that places machines (8 GPUs each by default) into regions and
/// wires up the three-tier link model:
/// * same machine: GPU `link_bps` (NVLink/PCIe), ~25 µs launch latency;
/// * same region (different machine): `intra_bw` / `intra_lat`
///   (EFA-class 100 Gbps, 0.2 ms unless overridden);
/// * cross-region: the region graph's α/β (or explicit overrides).
#[derive(Debug, Clone)]
pub struct TopologyBuilder {
    machines: Vec<(GpuModel, usize, usize)>, // (model, gpus, region)
    region_names: Vec<String>,
    /// (region_i, region_j) -> (delay s, bw bytes/s); symmetric.
    region_links: Vec<Vec<(f64, f64)>>,
    intra_lat: f64,
    intra_bw: f64,
    /// Optional per-machine bandwidth cap (edge machines in scenario 2).
    machine_bw_cap: Vec<Option<f64>>,
}

impl TopologyBuilder {
    pub fn new(region_names: Vec<String>, region_links: Vec<Vec<(f64, f64)>>) -> Self {
        TopologyBuilder {
            machines: Vec::new(),
            region_names,
            region_links,
            intra_lat: 0.2 * MS,
            intra_bw: 100.0e9 / 8.0, // 100 Gbps EFA-class
            machine_bw_cap: Vec::new(),
        }
    }

    pub fn intra_link(mut self, lat_s: f64, bw_bps: f64) -> Self {
        self.intra_lat = lat_s;
        self.intra_bw = bw_bps;
        self
    }

    /// Add a machine of `count` GPUs of `model` in `region`.
    pub fn machine(mut self, model: GpuModel, count: usize, region: usize) -> Self {
        assert!(region < self.region_names.len());
        self.machines.push((model, count, region));
        self.machine_bw_cap.push(None);
        self
    }

    /// Add a machine whose *all* external links are capped at `bw_bps`
    /// (edge machines in Multi-Region-Hybrid).
    pub fn edge_machine(mut self, model: GpuModel, count: usize, region: usize, bw_bps: f64) -> Self {
        assert!(region < self.region_names.len());
        self.machines.push((model, count, region));
        self.machine_bw_cap.push(Some(bw_bps));
        self
    }

    pub fn build(self) -> DeviceTopology {
        let mut devices = Vec::new();
        for (m_idx, &(model, count, region)) in self.machines.iter().enumerate() {
            for _ in 0..count {
                devices.push(Device {
                    id: devices.len(),
                    gpu: model,
                    machine: m_idx,
                    zone: region, // one zone per region in the default builders
                    region,
                    speed: 1.0,
                });
            }
        }
        let n = devices.len();
        let mut alpha = vec![vec![0.0; n]; n];
        let mut beta = vec![vec![f64::INFINITY; n]; n];
        for i in 0..n {
            for j in 0..n {
                if i == j {
                    continue;
                }
                let (di, dj) = (&devices[i], &devices[j]);
                let (mut lat, mut bw);
                if di.machine == dj.machine {
                    lat = 25e-6;
                    bw = di.spec().link_bps.min(dj.spec().link_bps);
                } else if di.region == dj.region {
                    lat = self.intra_lat;
                    bw = self.intra_bw;
                } else {
                    let (d, b) = self.region_links[di.region][dj.region];
                    lat = d;
                    bw = b;
                }
                // Edge-machine caps apply to all off-machine traffic.
                if di.machine != dj.machine {
                    for m in [di.machine, dj.machine] {
                        if let Some(cap) = self.machine_bw_cap[m] {
                            bw = bw.min(cap);
                            lat = lat.max(self.intra_lat);
                        }
                    }
                }
                alpha[i][j] = lat;
                beta[i][j] = bw;
            }
        }
        DeviceTopology { devices, alpha, beta, region_names: self.region_names }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::GBITPS_BYTES;

    fn tiny() -> DeviceTopology {
        let links = vec![
            vec![(0.0, f64::INFINITY), (10.0 * MS, 5.0 * GBITPS_BYTES)],
            vec![(10.0 * MS, 5.0 * GBITPS_BYTES), (0.0, f64::INFINITY)],
        ];
        TopologyBuilder::new(vec!["r0".into(), "r1".into()], links)
            .machine(GpuModel::A100, 2, 0)
            .machine(GpuModel::L4, 2, 0)
            .machine(GpuModel::L40S, 2, 1)
            .build()
    }

    #[test]
    fn tiers_ordered() {
        let t = tiny();
        // same machine (0,1) < same region (0,2) < cross region (0,4)
        assert!(t.lat(0, 1) < t.lat(0, 2));
        assert!(t.lat(0, 2) < t.lat(0, 4));
        assert!(t.bw(0, 1) > t.bw(0, 2));
        assert!(t.bw(0, 2) > t.bw(0, 4));
    }

    #[test]
    fn affinity_hierarchy() {
        let t = tiny();
        assert_eq!(t.affinity(0, 1), 3); // same machine
        assert_eq!(t.affinity(0, 2), 2); // same zone (zone == region here)
        assert_eq!(t.affinity(0, 4), 0); // cross region
        assert!(t.group_locality(&[0, 1]) > t.group_locality(&[0, 4]));
    }

    #[test]
    fn subset_renumbers() {
        let t = tiny();
        let (s, map) = t.subset(&[4, 0]);
        assert_eq!(s.n(), 2);
        assert_eq!(map, vec![4, 0]);
        assert_eq!(s.devices[0].gpu, GpuModel::L40S);
        assert!((s.lat(0, 1) - t.lat(4, 0)).abs() < 1e-15);
    }

    #[test]
    fn census_counts() {
        let t = tiny();
        let c = t.census();
        assert_eq!(c, vec![(GpuModel::A100, 2), (GpuModel::L40S, 2), (GpuModel::L4, 2)]);
    }

    #[test]
    fn locality_order_groups_by_region() {
        let t = tiny();
        let order = t.locality_order(&[5, 0, 4, 1]);
        // region 0 devices first, then region 1
        assert_eq!(order, vec![0, 1, 4, 5]);
    }

    #[test]
    fn xfer_time_includes_latency_and_volume() {
        let t = tiny();
        let bytes = 1.0 * GBITPS_BYTES; // 1 Gbit worth of bytes
        let want = 10.0 * MS + bytes / (5.0 * GBITPS_BYTES);
        assert!((t.xfer_time(0, 4, bytes) - want).abs() < 1e-9);
        assert_eq!(t.xfer_time(3, 3, 1e9), 0.0);
    }
}
