//! The four evaluation network scenarios (paper §5.1) over the 64-GPU
//! testbed: 24×A100, 24×L40S, 16×L4 arranged as eight 8-GPU machines.
//!
//! * **Scenario 1 (Single-Region)** — all machines in one region, no
//!   latency/bandwidth shaping.
//! * **Scenario 2 (Multi-Region-Hybrid)** — Ohio + Virginia; a subset of
//!   Virginia machines are *edge* machines with 1 Gbps uplinks; the
//!   Ohio↔Virginia links have 10 ms delay and 5 Gbps bandwidth.
//! * **Scenario 3 (Multi-Country)** — eight EU regions (5–30 ms,
//!   1.9–5.0 Gbps between regions).
//! * **Scenario 4 (Multi-Continent)** — EU + US regions (5–60 ms,
//!   0.9–5.0 Gbps).

use super::gpu::GpuModel;
use super::graph::{DeviceTopology, TopologyBuilder};
use super::network::{Region, RegionGraph};
use crate::util::units::{GBITPS_BYTES, MS};

/// Evaluation scenario selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Scenario {
    SingleRegion,
    MultiRegionHybrid,
    MultiCountry,
    MultiContinent,
}

impl Scenario {
    pub const ALL: [Scenario; 4] = [
        Scenario::SingleRegion,
        Scenario::MultiRegionHybrid,
        Scenario::MultiCountry,
        Scenario::MultiContinent,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Scenario::SingleRegion => "Single-Region",
            Scenario::MultiRegionHybrid => "Multi-Region-Hybrid",
            Scenario::MultiCountry => "Multi-Country",
            Scenario::MultiContinent => "Multi-Continent",
        }
    }

    pub fn parse(s: &str) -> Option<Scenario> {
        match s.to_ascii_lowercase().replace('_', "-").as_str() {
            "single-region" | "single" | "s1" => Some(Scenario::SingleRegion),
            "multi-region-hybrid" | "hybrid" | "s2" => Some(Scenario::MultiRegionHybrid),
            "multi-country" | "country" | "s3" => Some(Scenario::MultiCountry),
            "multi-continent" | "continent" | "s4" => Some(Scenario::MultiContinent),
            _ => None,
        }
    }
}

/// Testbed composition. Default = the paper's 64-GPU fleet.
#[derive(Debug, Clone)]
pub struct TestbedSpec {
    /// (model, number of 8-GPU machines)
    pub machines: Vec<(GpuModel, usize)>,
    pub gpus_per_machine: usize,
    /// Checkpoint/object-store bandwidth (bytes/s) of this testbed —
    /// the single bottleneck both checkpoint *writes*
    /// ([`crate::costmodel::RecoveryModel`]) and no-live-holder
    /// *restores* ([`crate::costmodel::MigrationModel`]) serialize on.
    /// Heterogeneous deployments differ wildly here (S3 vs. a
    /// rack-local NVMe cache), so it is part of the testbed, not a
    /// model constant; `hetrl replay --ckpt-bw <gbps>` overrides it.
    pub ckpt_bw: f64,
}

impl Default for TestbedSpec {
    fn default() -> Self {
        // 24 A100 + 24 L40S + 16 L4 = 64 GPUs
        TestbedSpec {
            machines: vec![(GpuModel::A100, 3), (GpuModel::L40S, 3), (GpuModel::L4, 2)],
            gpus_per_machine: 8,
            ckpt_bw: 2.5 * GBITPS_BYTES,
        }
    }
}

impl TestbedSpec {
    pub fn total_gpus(&self) -> usize {
        self.machines.iter().map(|(_, n)| n * self.gpus_per_machine).sum()
    }

    /// Flattened machine list (model per machine), interleaved so each
    /// region gets a mix of GPU models when distributed round-robin.
    fn machine_models(&self) -> Vec<GpuModel> {
        let mut queues: Vec<(GpuModel, usize)> = self.machines.clone();
        let mut out = Vec::new();
        loop {
            let mut progressed = false;
            for (model, left) in queues.iter_mut() {
                if *left > 0 {
                    out.push(*model);
                    *left -= 1;
                    progressed = true;
                }
            }
            if !progressed {
                break;
            }
        }
        out
    }
}

fn region_links_from_graph(g: &RegionGraph) -> Vec<Vec<(f64, f64)>> {
    let n = g.regions.len();
    let mut links = vec![vec![(0.0, f64::INFINITY); n]; n];
    for i in 0..n {
        for j in 0..n {
            links[i][j] = (g.delay[i][j], g.bandwidth[i][j]);
        }
    }
    links
}

/// Build the testbed topology for a scenario.
pub fn build_testbed(scenario: Scenario, spec: &TestbedSpec) -> DeviceTopology {
    let models = spec.machine_models();
    let g = spec.gpus_per_machine;
    match scenario {
        Scenario::SingleRegion => {
            let links = vec![vec![(0.0, f64::INFINITY)]];
            let mut b = TopologyBuilder::new(vec!["Virginia".into()], links);
            for &m in &models {
                b = b.machine(m, g, 0);
            }
            b.build()
        }
        Scenario::MultiRegionHybrid => {
            // Ohio (region 0) + Virginia (region 1); 10 ms / 5 Gbps between
            // them; the last ~third of Virginia machines are edge machines
            // capped at 1 Gbps.
            let inter = (10.0 * MS, 5.0 * GBITPS_BYTES);
            let links = vec![
                vec![(0.0, f64::INFINITY), inter],
                vec![inter, (0.0, f64::INFINITY)],
            ];
            let mut b = TopologyBuilder::new(vec!["Ohio".into(), "Virginia".into()], links);
            let half = models.len() / 2;
            for (i, &m) in models.iter().enumerate() {
                if i < half {
                    b = b.machine(m, g, 0); // Ohio
                } else if i < models.len() - models.len() / 4 {
                    b = b.machine(m, g, 1); // Virginia core
                } else {
                    b = b.edge_machine(m, g, 1, 1.0 * GBITPS_BYTES); // Virginia edge
                }
            }
            b.build()
        }
        Scenario::MultiCountry => {
            let rg = RegionGraph::build(&Region::EUROPE);
            let names = rg.regions.iter().map(|r| r.name().to_string()).collect();
            let mut b = TopologyBuilder::new(names, region_links_from_graph(&rg));
            for (i, &m) in models.iter().enumerate() {
                b = b.machine(m, g, i % Region::EUROPE.len());
            }
            b.build()
        }
        Scenario::MultiContinent => {
            // Eight regions across Europe and the US (paper: "eight
            // different regions across Europe and US").
            let regions = [
                Region::Virginia,
                Region::Ohio,
                Region::Paris,
                Region::Stockholm,
                Region::London,
                Region::Ireland,
                Region::Frankfurt,
                Region::Milan,
            ];
            let rg = RegionGraph::build(&regions);
            let names = rg.regions.iter().map(|r| r.name().to_string()).collect();
            let mut b = TopologyBuilder::new(names, region_links_from_graph(&rg));
            for (i, &m) in models.iter().enumerate() {
                b = b.machine(m, g, i % regions.len());
            }
            b.build()
        }
    }
}

/// Homogeneous-subset topologies used by Figure 10 (GPU-combination study)
/// and the "24×A100 only" comparisons: keep only devices of the given
/// models, at most `limit` of each.
pub fn subset_by_model(
    topo: &DeviceTopology,
    keep: &[(GpuModel, usize)],
) -> DeviceTopology {
    let mut ids = Vec::new();
    for &(model, limit) in keep {
        let mut count = 0;
        for d in &topo.devices {
            if d.gpu == model && count < limit {
                ids.push(d.id);
                count += 1;
            }
        }
    }
    ids.sort_unstable();
    topo.subset(&ids).0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_testbed_is_64_gpus() {
        let spec = TestbedSpec::default();
        assert_eq!(spec.total_gpus(), 64);
        for s in Scenario::ALL {
            let t = build_testbed(s, &spec);
            assert_eq!(t.n(), 64, "{}", s.name());
            let census = t.census();
            assert!(census.contains(&(GpuModel::A100, 24)));
            assert!(census.contains(&(GpuModel::L40S, 24)));
            assert!(census.contains(&(GpuModel::L4, 16)));
        }
    }

    #[test]
    fn single_region_has_no_wan_links() {
        let t = build_testbed(Scenario::SingleRegion, &TestbedSpec::default());
        for i in 0..t.n() {
            for j in 0..t.n() {
                if i != j {
                    assert!(t.lat(i, j) <= 0.5 * MS, "lat({i},{j}) = {}", t.lat(i, j));
                }
            }
        }
    }

    #[test]
    fn hybrid_has_edge_caps() {
        let t = build_testbed(Scenario::MultiRegionHybrid, &TestbedSpec::default());
        // Some pair must be capped at 1 Gbps (edge), some at 5 Gbps (inter).
        let mut saw_edge = false;
        let mut saw_inter = false;
        for i in 0..t.n() {
            for j in 0..t.n() {
                if i == j {
                    continue;
                }
                let bw = t.bw(i, j);
                if (bw - 1.0 * GBITPS_BYTES).abs() < 1.0 {
                    saw_edge = true;
                }
                if (bw - 5.0 * GBITPS_BYTES).abs() < 1.0 {
                    saw_inter = true;
                }
            }
        }
        assert!(saw_edge && saw_inter);
    }

    #[test]
    fn continent_slower_than_country() {
        let spec = TestbedSpec::default();
        let country = build_testbed(Scenario::MultiCountry, &spec);
        let continent = build_testbed(Scenario::MultiContinent, &spec);
        let max_lat = |t: &DeviceTopology| {
            let mut m: f64 = 0.0;
            for i in 0..t.n() {
                for j in 0..t.n() {
                    m = m.max(t.lat(i, j));
                }
            }
            m
        };
        assert!(max_lat(&continent) > max_lat(&country));
    }

    #[test]
    fn scenario_parse() {
        assert_eq!(Scenario::parse("multi-country"), Some(Scenario::MultiCountry));
        assert_eq!(Scenario::parse("S2"), Some(Scenario::MultiRegionHybrid));
        assert_eq!(Scenario::parse("nope"), None);
    }

    #[test]
    fn subset_by_model_limits() {
        let t = build_testbed(Scenario::SingleRegion, &TestbedSpec::default());
        let s = subset_by_model(&t, &[(GpuModel::A100, 24)]);
        assert_eq!(s.n(), 24);
        assert!(s.devices.iter().all(|d| d.gpu == GpuModel::A100));
        let mixed = subset_by_model(&t, &[(GpuModel::A100, 8), (GpuModel::L4, 8)]);
        assert_eq!(mixed.n(), 16);
    }
}
