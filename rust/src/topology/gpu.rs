//! GPU catalog — paper Table 1, plus a few extra models so the catalog is
//! extensible (the paper's Limitations section notes only three NVIDIA
//! models were evaluated; we keep those three as the evaluation default).

use crate::util::units::{GBPS_BYTES, GIB, TFLOPS};

/// Static specification of a GPU model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GpuSpec {
    pub name: &'static str,
    pub arch: &'static str,
    /// Memory capacity in bytes.
    pub mem_bytes: f64,
    /// FP16/BF16 dense throughput in FLOP/s.
    pub fp16_flops: f64,
    /// HBM/GDDR bandwidth in bytes/s.
    pub hbm_bps: f64,
    /// Intra-machine interconnect (NVLink or PCIe) in bytes/s per direction.
    pub link_bps: f64,
    /// Achievable fraction of peak FLOPs for dense transformer work
    /// (model-FLOPs-utilization ceiling used by the simulator; the
    /// analytical cost model uses peak, as the paper's Appendix B does).
    pub mfu: f64,
}

/// GPU models known to the catalog. Table 1 rows first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum GpuModel {
    A100,
    L40S,
    L4,
    /// Extension models (not in the paper's evaluation; used by tests to
    /// check the catalog is not hard-coded to three entries).
    V100,
    H100,
}

impl GpuModel {
    /// Table 1. GPU specifications.
    ///
    /// | Model | Arch   | Size (GB) | FP16 (TFLOPS) | HBM (GB/s) | Link (GB/s) |
    /// |-------|--------|-----------|---------------|------------|-------------|
    /// | A100  | Ampere | 40        | 312           | 2039       | 600         |
    /// | L40S  | Ada    | 48        | 366           | 864        | 64          |
    /// | L4    | Ada    | 24        | 121           | 300        | 64          |
    pub fn spec(self) -> GpuSpec {
        match self {
            GpuModel::A100 => GpuSpec {
                name: "A100",
                arch: "Ampere",
                mem_bytes: 40.0 * GIB,
                fp16_flops: 312.0 * TFLOPS,
                hbm_bps: 2039.0 * GBPS_BYTES,
                link_bps: 600.0 * GBPS_BYTES,
                mfu: 0.48,
            },
            GpuModel::L40S => GpuSpec {
                name: "L40S",
                arch: "Ada",
                mem_bytes: 48.0 * GIB,
                fp16_flops: 366.0 * TFLOPS,
                hbm_bps: 864.0 * GBPS_BYTES,
                link_bps: 64.0 * GBPS_BYTES,
                mfu: 0.38,
            },
            GpuModel::L4 => GpuSpec {
                name: "L4",
                arch: "Ada",
                mem_bytes: 24.0 * GIB,
                fp16_flops: 121.0 * TFLOPS,
                hbm_bps: 300.0 * GBPS_BYTES,
                link_bps: 64.0 * GBPS_BYTES,
                mfu: 0.35,
            },
            GpuModel::V100 => GpuSpec {
                name: "V100",
                arch: "Volta",
                mem_bytes: 32.0 * GIB,
                fp16_flops: 125.0 * TFLOPS,
                hbm_bps: 900.0 * GBPS_BYTES,
                link_bps: 300.0 * GBPS_BYTES,
                mfu: 0.40,
            },
            GpuModel::H100 => GpuSpec {
                name: "H100",
                arch: "Hopper",
                mem_bytes: 80.0 * GIB,
                fp16_flops: 989.0 * TFLOPS,
                hbm_bps: 3350.0 * GBPS_BYTES,
                link_bps: 900.0 * GBPS_BYTES,
                mfu: 0.45,
            },
        }
    }

    /// The three models from the paper's testbed.
    pub fn table1() -> [GpuModel; 3] {
        [GpuModel::A100, GpuModel::L40S, GpuModel::L4]
    }

    pub fn parse(s: &str) -> Option<GpuModel> {
        match s.to_ascii_lowercase().as_str() {
            "a100" => Some(GpuModel::A100),
            "l40s" => Some(GpuModel::L40S),
            "l4" => Some(GpuModel::L4),
            "v100" => Some(GpuModel::V100),
            "h100" => Some(GpuModel::H100),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_matches_paper() {
        let a100 = GpuModel::A100.spec();
        assert_eq!(a100.mem_bytes, 40.0 * GIB);
        assert_eq!(a100.fp16_flops, 312.0 * TFLOPS);
        assert_eq!(a100.hbm_bps, 2039.0 * GBPS_BYTES);
        assert_eq!(a100.link_bps, 600.0 * GBPS_BYTES);

        let l40s = GpuModel::L40S.spec();
        assert_eq!(l40s.mem_bytes, 48.0 * GIB);
        assert_eq!(l40s.fp16_flops, 366.0 * TFLOPS);

        let l4 = GpuModel::L4.spec();
        assert_eq!(l4.fp16_flops, 121.0 * TFLOPS);
        assert_eq!(l4.hbm_bps, 300.0 * GBPS_BYTES);
    }

    #[test]
    fn l40s_flops_beat_a100_but_hbm_does_not() {
        // The crux of the heterogeneity: L40S has *more* peak FLOPs than
        // A100 but less than half the HBM bandwidth, so generation
        // (HBM-bound) and training (compute-bound) prefer different GPUs.
        let a = GpuModel::A100.spec();
        let l = GpuModel::L40S.spec();
        assert!(l.fp16_flops > a.fp16_flops);
        assert!(l.hbm_bps < a.hbm_bps / 2.0);
    }

    #[test]
    fn parse_roundtrip() {
        for m in [GpuModel::A100, GpuModel::L40S, GpuModel::L4, GpuModel::V100, GpuModel::H100] {
            assert_eq!(GpuModel::parse(m.spec().name), Some(m));
        }
        assert_eq!(GpuModel::parse("rtx5090"), None);
    }
}
