//! Device topology substrate: GPU catalog (paper Table 1), geographic
//! regions with measured-style latency/bandwidth matrices, the four
//! evaluation network scenarios, and the `DeviceTopology` graph
//! `G_D = (V_D, E_D, comp, mem, hbm, A, B)` the scheduler consumes.

pub mod gpu;
pub mod network;
pub mod scenarios;
pub mod graph;

pub use gpu::{GpuModel, GpuSpec};
pub use graph::{Device, DeviceTopology};
pub use network::{Region, RegionGraph};
pub use scenarios::{build_testbed, subset_by_model, Scenario, TestbedSpec};
