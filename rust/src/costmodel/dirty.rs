//! Dirty-task footprints for incremental plan evaluation.
//!
//! EA perturbations ([`crate::scheduler::ea`]) rewrite the `TaskPlan`s
//! of a *known* subset of tasks: a strategy or assignment mutation
//! touches one task, a device swap touches exactly the tasks whose
//! assignment contains either swapped device. A [`DirtySet`] carries
//! that footprint from the mutation site to
//! [`super::CostModel::plan_cost_delta`], which re-prices only the
//! dirty tasks and reuses the caller's memoized per-task costs for the
//! rest. Because [`super::task_cost::task_cost`] is a pure function of
//! `(task, TaskPlan)`, reusing a clean task's cost is bit-identical to
//! recomputing it — the full re-price is the delta path's oracle
//! (`tests/prop_delta_eval.rs` pins this).
//!
//! The only soundness requirement is that the set is a **superset** of
//! the tasks whose plans differ from the baseline; over-approximating
//! (e.g. a task swapped twice back to its original plan) costs a
//! redundant cache lookup, never correctness.

/// Sorted, deduplicated set of task indices whose `TaskPlan` may
/// differ from an evaluation baseline. Task counts are tiny (≤ 6 for
/// every workflow shape), so a sorted `Vec` beats any hash structure
/// and keeps iteration order deterministic.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct DirtySet {
    tasks: Vec<usize>,
}

impl DirtySet {
    /// Empty footprint (a no-op mutation).
    pub fn new() -> DirtySet {
        DirtySet::default()
    }

    /// Footprint of a single-task mutation.
    pub fn single(t: usize) -> DirtySet {
        DirtySet { tasks: vec![t] }
    }

    /// Mark task `t` dirty.
    pub fn insert(&mut self, t: usize) {
        if let Err(pos) = self.tasks.binary_search(&t) {
            self.tasks.insert(pos, t);
        }
    }

    /// Merge another footprint into this one (set union).
    pub fn union_with(&mut self, other: &DirtySet) {
        for &t in &other.tasks {
            self.insert(t);
        }
    }

    /// Whether task `t` must be re-priced.
    pub fn contains(&self, t: usize) -> bool {
        self.tasks.binary_search(&t).is_ok()
    }

    /// Number of dirty tasks.
    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    /// Dirty task indices in ascending order.
    pub fn iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.tasks.iter().copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_union_sorted_dedup() {
        let mut a = DirtySet::new();
        assert!(a.is_empty());
        a.insert(3);
        a.insert(1);
        a.insert(3);
        assert_eq!(a.iter().collect::<Vec<_>>(), vec![1, 3]);
        let mut b = DirtySet::single(2);
        b.union_with(&a);
        assert_eq!(b.iter().collect::<Vec<_>>(), vec![1, 2, 3]);
        assert_eq!(b.len(), 3);
        assert!(b.contains(2));
        assert!(!b.contains(0));
    }
}
