//! Migration cost: what it takes to *switch* execution plans on a live
//! fleet. Replanning after a cluster event is not free — devices that
//! newly serve a shard of a task must receive that shard's state
//! (weights, and for training tasks the optimizer state, folded into
//! the memory model's `M_model`) over the current — possibly degraded —
//! heterogeneous links, or re-load it from the checkpoint store when no
//! live holder survived the event.
//!
//! Shard identity is tracked per *(layer range, tp slot, tp degree)*:
//! DP replicas hold identical weights, so a device that held stage j /
//! tp-slot k before the event can serve any replica's (j, k) shard for
//! free, while a plan that keeps a task's device set but reshuffles its
//! parallelization (new pp/tp or layer split) pays for the internal
//! reshard it really causes.
//!
//! Transfers contend on *both* ends: a destination's fetches serialize
//! on its ingress NIC, and concurrent fetches from one source share
//! that source's egress bandwidth — source selection is greedy
//! least-loaded, so replicated shards fan out across their holders.
//! The migration finishes when the busiest NIC (send or receive side)
//! drains.
//!
//! The elastic replanner adds `migration_time / horizon` to the search
//! objective so a marginally-faster plan that moves terabytes across a
//! WAN loses to a slightly-slower plan that stays put.

use crate::plan::memory::tasklet_memory;
use crate::plan::{ExecutionPlan, TaskPlan};
use crate::topology::DeviceTopology;
use crate::util::units::GBITPS_BYTES;
use crate::workflow::{JobConfig, RlWorkflow};

/// Identity of a model shard: `(first_layer, n_layers, tp_slot, tp_degree)`.
/// The DP replica index is deliberately absent — replicas share weights.
pub type ShardKey = (usize, usize, usize, usize);

/// What survived of one task's previous placement (snapshot-id space).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PrevTask {
    /// Shard key → surviving devices that hold it.
    pub shards: Vec<(ShardKey, Vec<usize>)>,
    /// Union of all surviving holder devices (any shard of the task).
    pub holders: Vec<usize>,
}

impl PrevTask {
    /// Build from a task plan in *base* ids, keeping only devices that
    /// `translate` maps into the current snapshot.
    pub fn from_task_plan(
        tp: &TaskPlan,
        mut translate: impl FnMut(usize) -> Option<usize>,
    ) -> PrevTask {
        let mut out = PrevTask::default();
        let s = tp.strategy;
        let starts = stage_starts(&tp.layer_split);
        for idx in 0..s.degree() {
            let Some(d) = translate(tp.assignment[idx]) else { continue };
            let (_, j, k) = s.tasklet_coords(idx);
            let key: ShardKey = (starts[j], tp.layer_split[j], k, s.tp);
            match out.shards.iter_mut().find(|(sk, _)| *sk == key) {
                Some((_, devs)) => {
                    if !devs.contains(&d) {
                        devs.push(d);
                    }
                }
                None => out.shards.push((key, vec![d])),
            }
            if !out.holders.contains(&d) {
                out.holders.push(d);
            }
        }
        out
    }

    /// Build the per-task list for a whole plan (base ids) under a
    /// base→snapshot translation — the one constructor both the replay
    /// driver and the replanner use, so policies charge identically.
    pub fn from_plan(
        plan: &ExecutionPlan,
        mut translate: impl FnMut(usize) -> Option<usize>,
    ) -> Vec<PrevTask> {
        plan.task_plans
            .iter()
            .map(|tp| PrevTask::from_task_plan(tp, &mut translate))
            .collect()
    }

    /// Surviving devices that hold exactly this shard.
    fn holders_of(&self, key: &ShardKey) -> &[usize] {
        self.shards
            .iter()
            .find(|(sk, _)| sk == key)
            .map(|(_, devs)| devs.as_slice())
            .unwrap_or(&[])
    }
}

/// Cumulative layer offset of each pipeline stage.
fn stage_starts(layer_split: &[usize]) -> Vec<usize> {
    let mut starts = Vec::with_capacity(layer_split.len());
    let mut acc = 0;
    for &l in layer_split {
        starts.push(acc);
        acc += l;
    }
    starts
}

/// Parameters of the migration model.
#[derive(Debug, Clone, Copy)]
pub struct MigrationModel {
    /// Bandwidth to the checkpoint/object store (bytes/s), used when no
    /// surviving device holds the task's state (e.g. after a preemption
    /// of the whole group).
    pub ckpt_bw: f64,
    /// Fixed overhead of any non-empty migration: engine teardown,
    /// process restart, weight-reload bookkeeping (seconds).
    pub setup_secs: f64,
}

impl Default for MigrationModel {
    fn default() -> Self {
        MigrationModel {
            ckpt_bw: 2.5 * GBITPS_BYTES,
            setup_secs: 2.0,
        }
    }
}

impl MigrationModel {
    /// A migration model priced against a testbed's own checkpoint
    /// store ([`crate::topology::TestbedSpec::ckpt_bw`]) instead of the
    /// hardcoded default — the store is a property of the deployment,
    /// and the same bandwidth must govern restores here and checkpoint
    /// writes in [`crate::costmodel::RecoveryModel`].
    pub fn for_spec(spec: &crate::topology::TestbedSpec) -> MigrationModel {
        MigrationModel { ckpt_bw: spec.ckpt_bw, ..MigrationModel::default() }
    }

    /// Wall-clock cost of migrating from the previous placement to
    /// `plan` (both in `topo`'s id space). Per destination shard:
    ///
    /// * a device that already holds the identical shard — free;
    /// * else fetched from a device holding that shard, chosen
    ///   greedily by *loaded* completion time — concurrent fetches
    ///   from one source serialize on its egress NIC, so the best
    ///   source minimizes `egress_load + α + bytes/β` over the
    ///   *current* link state, not just the nearest link;
    /// * else (shard shape changed / no shard holder survived) fetched
    ///   the same way from a holder of *any* of the task's state,
    ///   which can re-shard on the fly — or resharded locally at HBM
    ///   speed when the destination itself holds some of the task's
    ///   state (no NIC involved);
    /// * else restored from the checkpoint store, whose egress
    ///   serializes like any other source.
    ///
    /// Fetches to one destination serialize on its ingress NIC and
    /// fetches from one source on its egress NIC; distinct devices
    /// proceed in parallel, so the cost is the worst per-device total
    /// (receive or send side, whichever is the bottleneck) plus a
    /// fixed setup term.
    pub fn migration_time(
        &self,
        topo: &DeviceTopology,
        wf: &RlWorkflow,
        job: &JobConfig,
        prev: &[PrevTask],
        plan: &ExecutionPlan,
    ) -> f64 {
        static EMPTY: PrevTask = PrevTask { shards: Vec::new(), holders: Vec::new() };
        let n = topo.n();
        let mut per_dev = vec![0.0f64; n];
        // Egress load per source NIC; slot `n` is the checkpoint store.
        let mut per_src = vec![0.0f64; n + 1];
        for (t, tp) in plan.task_plans.iter().enumerate() {
            let task = &wf.tasks[t];
            let s = tp.strategy;
            let prev_t = prev.get(t).unwrap_or(&EMPTY);
            let starts = stage_starts(&tp.layer_split);
            let local_batch = (job.total_samples() as f64 / s.dp as f64).ceil() as usize;
            for idx in 0..s.degree() {
                let d = tp.assignment[idx];
                let (_, j, k) = s.tasklet_coords(idx);
                let key: ShardKey = (starts[j], tp.layer_split[j], k, s.tp);
                let shard_holders = prev_t.holders_of(&key);
                if shard_holders.contains(&d) {
                    continue; // this device already holds this shard
                }
                let bytes =
                    tasklet_memory(task, job, tp.layer_split[j], s.tp, local_batch).model;
                let sources = if !shard_holders.is_empty() {
                    shard_holders
                } else {
                    prev_t.holders.as_slice()
                };
                // Remote fetch: pick the source minimizing loaded
                // completion time (its egress queue + this transfer),
                // so replicated shards spread across their holders
                // instead of hammering the first one.
                let mut remote_src: Option<usize> = None;
                let mut remote_loaded = f64::INFINITY;
                let mut remote_raw = f64::INFINITY;
                for &src in sources.iter().filter(|&&src| src != d) {
                    let raw = topo.xfer_time(src, d, bytes);
                    let loaded = per_src[src] + raw;
                    if loaded < remote_loaded {
                        remote_loaded = loaded;
                        remote_raw = raw;
                        remote_src = Some(src);
                    }
                }
                // A device that holds *some* state of the task can
                // re-shard locally at HBM speed (never free: the shard
                // shape changed or it would have matched above).
                let local = if prev_t.holders.contains(&d) {
                    bytes / topo.devices[d].spec().hbm_bps
                } else {
                    f64::INFINITY
                };
                match remote_src {
                    _ if local.is_finite() && local <= remote_loaded => {
                        per_dev[d] += local; // HBM reshard: no NIC used
                    }
                    Some(src) => {
                        per_src[src] += remote_raw;
                        per_dev[d] += remote_raw;
                    }
                    None => {
                        // No live holder anywhere: checkpoint restore,
                        // serialized on the store's egress bandwidth.
                        let fetch = bytes / self.ckpt_bw;
                        per_src[n] += fetch;
                        per_dev[d] += fetch;
                    }
                }
            }
        }
        let worst_recv = per_dev.iter().cloned().fold(0.0f64, f64::max);
        let worst_send = per_src.iter().cloned().fold(0.0f64, f64::max);
        let worst = worst_recv.max(worst_send);
        if worst > 0.0 {
            worst + self.setup_secs
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::ParallelStrategy;
    use crate::topology::{build_testbed, Scenario, TestbedSpec};
    use crate::workflow::{Algo, Mode, ModelSpec};

    fn setup(scenario: Scenario) -> (RlWorkflow, DeviceTopology, JobConfig) {
        (
            RlWorkflow::new(Algo::Grpo, Mode::Sync, ModelSpec::qwen_4b()),
            build_testbed(scenario, &TestbedSpec::default()),
            JobConfig::default(),
        )
    }

    fn plan(wf: &RlWorkflow, shift: usize) -> ExecutionPlan {
        let mut task_plans = Vec::new();
        for (t, task) in wf.tasks.iter().enumerate() {
            let s = ParallelStrategy::new(2, 2, 4);
            let devs: Vec<usize> = (0..16).map(|i| (t * 16 + i + shift) % 64).collect();
            task_plans.push(TaskPlan::uniform(s, task.model.nl, devs));
        }
        ExecutionPlan {
            task_groups: vec![(0..wf.n_tasks()).collect()],
            gpu_groups: vec![(0..64).collect()],
            task_plans,
        }
    }

    fn identity_prev(p: &ExecutionPlan) -> Vec<PrevTask> {
        PrevTask::from_plan(p, Some)
    }

    #[test]
    fn staying_put_is_free() {
        let (wf, topo, job) = setup(Scenario::MultiRegionHybrid);
        let p = plan(&wf, 0);
        let mm = MigrationModel::default();
        assert_eq!(mm.migration_time(&topo, &wf, &job, &identity_prev(&p), &p), 0.0);
    }

    #[test]
    fn moving_costs_more_than_staying() {
        let (wf, topo, job) = setup(Scenario::MultiRegionHybrid);
        let old = plan(&wf, 0);
        let moved = plan(&wf, 8);
        let mm = MigrationModel::default();
        let c = mm.migration_time(&topo, &wf, &job, &identity_prev(&old), &moved);
        assert!(c > mm.setup_secs, "moving half the devices must cost: {c}");
    }

    #[test]
    fn internal_reshuffle_is_not_free() {
        // Same devices, different parallelization: every shard changes
        // shape, so the migration model must charge a reshard.
        let (wf, topo, job) = setup(Scenario::SingleRegion);
        let old = plan(&wf, 0);
        let mut reshaped = plan(&wf, 0);
        for tp in reshaped.task_plans.iter_mut() {
            let nl = wf.tasks[0].model.nl;
            *tp = TaskPlan::uniform(
                ParallelStrategy::new(1, 4, 4),
                nl,
                tp.assignment.clone(),
            );
        }
        let mm = MigrationModel::default();
        let c = mm.migration_time(&topo, &wf, &job, &identity_prev(&old), &reshaped);
        assert!(c > 0.0, "reshuffled shards must not be free");
    }

    #[test]
    fn dp_replicas_share_shards() {
        // Swapping the two DP replicas' device sets keeps every device
        // on a shard it already holds (replica index is not part of
        // shard identity) — free.
        let (wf, topo, job) = setup(Scenario::SingleRegion);
        let old = plan(&wf, 0);
        let mut swapped = old.clone();
        for tp in swapped.task_plans.iter_mut() {
            let s = tp.strategy; // dp2·pp2·tp4: replica blocks of 8
            let half = s.degree() / 2;
            tp.assignment.rotate_left(half);
        }
        let mm = MigrationModel::default();
        assert_eq!(
            mm.migration_time(&topo, &wf, &job, &identity_prev(&old), &swapped),
            0.0
        );
    }

    /// Build a per-task plan where task 0 uses `s0`/`devs0` and every
    /// other task t sits alone on device `8 + t` (machine 1) — so only
    /// task 0 contributes migration cost between two such plans.
    fn isolating_plan(wf: &RlWorkflow, s0: ParallelStrategy, devs0: Vec<usize>) -> ExecutionPlan {
        let mut task_plans = Vec::new();
        for (t, task) in wf.tasks.iter().enumerate() {
            if t == 0 {
                task_plans.push(TaskPlan::uniform(s0, task.model.nl, devs0.clone()));
            } else {
                task_plans.push(TaskPlan::uniform(
                    ParallelStrategy::new(1, 1, 1),
                    task.model.nl,
                    vec![8 + t],
                ));
            }
        }
        ExecutionPlan {
            task_groups: vec![(0..wf.n_tasks()).collect()],
            gpu_groups: vec![(0..64).collect()],
            task_plans,
        }
    }

    #[test]
    fn contended_source_serializes_egress() {
        // Single region: all cross-machine links identical, so transfer
        // times are equal and only contention differentiates the cases.
        let (wf, topo, job) = setup(Scenario::SingleRegion);
        let mm = MigrationModel::default();

        // Baseline: one destination (device 40, machine 5) fetches the
        // full-model shard from its single holder (device 0).
        let single_prev =
            identity_prev(&isolating_plan(&wf, ParallelStrategy::new(1, 1, 1), vec![0]));
        let single_new = isolating_plan(&wf, ParallelStrategy::new(1, 1, 1), vec![40]);
        let single = mm.migration_time(&topo, &wf, &job, &single_prev, &single_new);
        assert!(single > mm.setup_secs, "baseline fetch must cost: {single}");
        let one_fetch = single - mm.setup_secs;

        // Contended: four DP replicas (devices 40..44) all need the
        // same shard, held only by device 0 — its egress serializes
        // the four transfers, so the cost is ~4x one fetch.
        let contended_new = isolating_plan(&wf, ParallelStrategy::new(4, 1, 1), vec![40, 41, 42, 43]);
        let contended = mm.migration_time(&topo, &wf, &job, &single_prev, &contended_new);
        let contended_fetch = contended - mm.setup_secs;
        assert!(
            contended_fetch > 3.5 * one_fetch && contended_fetch < 4.5 * one_fetch,
            "4 fetches from one source must serialize: {contended_fetch} vs 4x{one_fetch}"
        );

        // Uncontended: the shard is replicated on devices 0..4 (four
        // old DP replicas); the greedy least-loaded pick spreads the
        // four fetches across the four holders, so the cost stays at
        // ~one fetch.
        let spread_prev = identity_prev(&isolating_plan(
            &wf,
            ParallelStrategy::new(4, 1, 1),
            vec![0, 1, 2, 3],
        ));
        let spread = mm.migration_time(&topo, &wf, &job, &spread_prev, &contended_new);
        let spread_fetch = spread - mm.setup_secs;
        assert!(
            spread_fetch < 1.5 * one_fetch,
            "replicated holders must spread the load: {spread_fetch} vs {one_fetch}"
        );
        assert!(contended > spread, "contention must cost more than spreading");
    }

    #[test]
    fn slower_store_raises_restore_and_write_cost() {
        // The S2 plumbing test: a testbed with a 4x-slower checkpoint
        // store must raise *both* directions — migration restores (no
        // live holder) and checkpoint writes (RecoveryModel) — through
        // the one TestbedSpec knob.
        let (wf, topo, job) = setup(Scenario::SingleRegion);
        let spec = TestbedSpec::default();
        let slow_spec = TestbedSpec { ckpt_bw: spec.ckpt_bw / 4.0, ..spec.clone() };
        let mm = MigrationModel::for_spec(&spec);
        let mm_slow = MigrationModel::for_spec(&slow_spec);
        assert_eq!(mm.ckpt_bw, MigrationModel::default().ckpt_bw);
        assert_eq!(mm_slow.ckpt_bw * 4.0, mm.ckpt_bw);

        // Restore direction: everything re-fetched from the store.
        let moved = plan(&wf, 8);
        let none: Vec<PrevTask> = wf.tasks.iter().map(|_| PrevTask::default()).collect();
        let restore = mm.migration_time(&topo, &wf, &job, &none, &moved);
        let restore_slow = mm_slow.migration_time(&topo, &wf, &job, &none, &moved);
        assert!(
            restore_slow > restore,
            "slower store must slow restores: {restore_slow} vs {restore}"
        );

        // Write direction: one checkpoint of the same plan.
        let rm = crate::costmodel::RecoveryModel::with_interval(600.0);
        let write = rm.ckpt_write_secs(&mm, &wf, &job, &moved);
        let write_slow = rm.ckpt_write_secs(&mm_slow, &wf, &job, &moved);
        assert!(write > 0.0);
        assert!(
            (write_slow / write - 4.0).abs() < 1e-9,
            "slower store must slow writes 4x: {write_slow} vs {write}"
        );
    }

    #[test]
    fn checkpoint_restore_slower_than_peer_fetch() {
        // Single region: peer links (100 Gbps EFA-class) beat the
        // checkpoint store, and the ckpt path re-fetches *everything*.
        let (wf, topo, job) = setup(Scenario::SingleRegion);
        let old = plan(&wf, 0);
        let moved = plan(&wf, 8);
        let none: Vec<PrevTask> = wf.tasks.iter().map(|_| PrevTask::default()).collect();
        let mm = MigrationModel::default();
        let peer = mm.migration_time(&topo, &wf, &job, &identity_prev(&old), &moved);
        let ckpt = mm.migration_time(&topo, &wf, &job, &none, &moved);
        assert!(ckpt > peer, "ckpt {ckpt} vs peer {peer}");
    }
}
