//! Communication cost primitives (Appendix B.2).
//!
//! All collective costs reduce to a *bottleneck ring* term
//! `min_{r ∈ ring(G_D)} max_{e ∈ r} (α_e + cv/β_e)`: the best ring
//! ordering of the group's devices, scored by its worst edge. Finding
//! the optimal ring is bottleneck-TSP (NP-hard); we solve exactly for
//! groups ≤ `EXACT_RING_LIMIT` devices by enumerating cyclic orders and
//! use the locality order with a 2-opt improvement pass above that.

use crate::topology::DeviceTopology;

/// Group sizes up to which the optimal ring is found by enumeration.
/// (n-1)!/2 orders: 5 → 12 orders, 6 → 60.
const EXACT_RING_LIMIT: usize = 6;

/// Edge score for volume `cv`: `α + cv/β`.
#[inline]
fn edge(topo: &DeviceTopology, a: usize, b: usize, cv: f64) -> f64 {
    if a == b {
        0.0
    } else {
        topo.alpha[a][b] + cv / topo.beta[a][b]
    }
}

/// Max edge score of the ring visiting `order` cyclically.
fn ring_bottleneck(topo: &DeviceTopology, order: &[usize], cv: f64) -> f64 {
    let n = order.len();
    let mut worst: f64 = 0.0;
    for i in 0..n {
        let a = order[i];
        let b = order[(i + 1) % n];
        worst = worst.max(edge(topo, a, b, cv));
    }
    worst
}

/// `min over rings of max over edges (α + cv/β)` for the device group.
/// Returns 0 for groups of size ≤ 1.
pub fn ring_minmax(topo: &DeviceTopology, devs: &[usize], cv: f64) -> f64 {
    match devs.len() {
        0 | 1 => 0.0,
        2 => {
            // The "ring" is the single pair traversed twice.
            edge(topo, devs[0], devs[1], cv)
        }
        n if n <= EXACT_RING_LIMIT => exact_ring(topo, devs, cv),
        _ => heuristic_ring(topo, devs, cv),
    }
}

/// Exact: enumerate cyclic permutations fixing element 0 (and halving by
/// direction symmetry).
fn exact_ring(topo: &DeviceTopology, devs: &[usize], cv: f64) -> f64 {
    let n = devs.len();
    let mut rest: Vec<usize> = devs[1..].to_vec();
    let mut best = f64::INFINITY;
    // Heap's algorithm over `rest`.
    let mut c = vec![0usize; n - 1];
    let mut order = Vec::with_capacity(n);
    let mut eval = |rest: &[usize], best: &mut f64| {
        // Direction symmetry: require rest[0] < rest[last].
        if rest[0] > rest[rest.len() - 1] {
            return;
        }
        order.clear();
        order.push(devs[0]);
        order.extend_from_slice(rest);
        let score = ring_bottleneck(topo, &order, cv);
        if score < *best {
            *best = score;
        }
    };
    eval(&rest, &mut best);
    let mut i = 0;
    while i < n - 1 {
        if c[i] < i {
            if i % 2 == 0 {
                rest.swap(0, i);
            } else {
                rest.swap(c[i], i);
            }
            eval(&rest, &mut best);
            c[i] += 1;
            i = 0;
        } else {
            c[i] = 0;
            i += 1;
        }
    }
    best
}

/// Heuristic: locality order, then 2-opt passes. Full 2-opt is O(n³)
/// per pass; beyond `FULL_2OPT_LIMIT` devices only reversals touching
/// the current bottleneck edge are tried (O(n²) per pass) — the
/// bottleneck objective cannot improve otherwise (§Perf L3-2).
const FULL_2OPT_LIMIT: usize = 16;

fn heuristic_ring(topo: &DeviceTopology, devs: &[usize], cv: f64) -> f64 {
    let mut order = topo.locality_order(devs);
    let n = order.len();
    let mut best = ring_bottleneck(topo, &order, cv);
    let mut improved = true;
    let mut passes = 0;
    while improved && passes < 4 {
        improved = false;
        passes += 1;
        if n <= FULL_2OPT_LIMIT {
            for i in 0..n - 1 {
                for j in i + 1..n {
                    order[i..=j].reverse();
                    let score = ring_bottleneck(topo, &order, cv);
                    if score + 1e-15 < best {
                        best = score;
                        improved = true;
                    } else {
                        order[i..=j].reverse(); // undo
                    }
                }
            }
        } else {
            // Locate the bottleneck edge (b, b+1); only reversals that
            // replace one of its endpoints can lower the max.
            let mut b = 0;
            let mut worst: f64 = 0.0;
            for i in 0..n {
                let e = edge(topo, order[i], order[(i + 1) % n], cv);
                if e > worst {
                    worst = e;
                    b = i;
                }
            }
            for j in 0..n {
                if j == b {
                    continue;
                }
                let (i, j) = (b.min(j), b.max(j));
                if i + 1 > j {
                    continue;
                }
                order[i + 1..=j].reverse();
                let score = ring_bottleneck(topo, &order, cv);
                if score + 1e-15 < best {
                    best = score;
                    improved = true;
                    break; // bottleneck moved; restart pass
                } else {
                    order[i + 1..=j].reverse();
                }
            }
        }
    }
    best
}

/// Minimum point-to-point edge score between two device sets (used for
/// PP stage-to-stage transfer and cross-task weight sync).
pub fn min_cross_edge(topo: &DeviceTopology, from: &[usize], to: &[usize], cv: f64) -> f64 {
    let mut best = f64::INFINITY;
    for &a in from {
        for &b in to {
            if a == b {
                return 0.0;
            }
            let e = edge(topo, a, b, cv);
            if e < best {
                best = e;
            }
        }
    }
    best
}

// ---------------------------------------------------------------------
// Communication volumes (Appendix B.2).
// ---------------------------------------------------------------------

use crate::util::units::B_BF16;

/// TP all-reduce volume per neighbouring pair:
/// `B_BF16 · mbs · seq · h1 · 2(tp-1)/tp`.
pub fn cv_tp(mbs: usize, seq: usize, h1: usize, tp: usize) -> f64 {
    if tp <= 1 {
        return 0.0;
    }
    B_BF16 * mbs as f64 * seq as f64 * h1 as f64 * 2.0 * (tp as f64 - 1.0) / tp as f64
}

/// PP stage-to-stage activation volume per micro-batch:
/// `B_BF16 · mbs · seq · h1`.
pub fn cv_pp(mbs: usize, seq: usize, h1: usize) -> f64 {
    B_BF16 * mbs as f64 * seq as f64 * h1 as f64
}

/// Per-layer parameter volume `4·h1² + 3·h1·h2` (QKVO + MLP).
pub fn layer_params(h1: usize, h2: usize) -> f64 {
    4.0 * (h1 as f64) * (h1 as f64) + 3.0 * (h1 as f64) * (h2 as f64)
}

/// DP gradient all-reduce volume per neighbouring pair:
/// `B_BF16 · nl_j · (4h1²+3h1h2) · 2(dp-1)/(dp·tp)`.
pub fn cv_dp(nl_j: usize, h1: usize, h2: usize, dp: usize, tp: usize) -> f64 {
    if dp <= 1 {
        return 0.0;
    }
    B_BF16 * nl_j as f64 * layer_params(h1, h2) * 2.0 * (dp as f64 - 1.0)
        / (dp as f64 * tp as f64)
}

/// All-gather volume for resharding / weight sync within a replica group
/// of `group` members: `B_BF16 · nl · (4h1²+3h1h2) · (group-1)/group`.
pub fn cv_all_gather(nl: usize, h1: usize, h2: usize, group: usize) -> f64 {
    if group <= 1 {
        return 0.0;
    }
    B_BF16 * nl as f64 * layer_params(h1, h2) * (group as f64 - 1.0) / group as f64
}

/// Full-model point-to-point volume: `B_BF16 · nl · (4h1²+3h1h2)`.
pub fn cv_p2p(nl: usize, h1: usize, h2: usize) -> f64 {
    B_BF16 * nl as f64 * layer_params(h1, h2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{build_testbed, Scenario, TestbedSpec};
    use crate::util::units::{GBITPS_BYTES, MS};

    fn topo() -> DeviceTopology {
        build_testbed(Scenario::MultiContinent, &TestbedSpec::default())
    }

    #[test]
    fn ring_trivial_sizes() {
        let t = topo();
        assert_eq!(ring_minmax(&t, &[], 1e6), 0.0);
        assert_eq!(ring_minmax(&t, &[3], 1e6), 0.0);
        let two = ring_minmax(&t, &[0, 1], 1e6);
        assert!(two > 0.0);
    }

    #[test]
    fn ring_prefers_local_devices() {
        let t = topo();
        // Devices 0..4 share a machine; a cross-region set must be slower.
        let local = ring_minmax(&t, &[0, 1, 2, 3], 1e8);
        let far: Vec<usize> = vec![0, 8, 16, 24];
        let remote = ring_minmax(&t, &far, 1e8);
        assert!(remote > 10.0 * local, "local={local} remote={remote}");
    }

    #[test]
    fn exact_ring_beats_or_matches_heuristic() {
        let t = topo();
        // On a 5-device mixed set, exact must be ≤ any specific ring.
        let devs = vec![0, 1, 8, 9, 16];
        let exact = exact_ring(&t, &devs, 1e8);
        let heur = heuristic_ring(&t, &devs, 1e8);
        assert!(exact <= heur + 1e-12);
        assert!((ring_minmax(&t, &devs, 1e8) - exact).abs() < 1e-12);
    }

    #[test]
    fn ring_monotone_in_volume() {
        let t = topo();
        let devs: Vec<usize> = (0..8).collect();
        let a = ring_minmax(&t, &devs, 1e6);
        let b = ring_minmax(&t, &devs, 1e9);
        assert!(b > a);
    }

    #[test]
    fn min_cross_edge_picks_best_pair() {
        let t = topo();
        // from machine 0, to machine 1 (same region 0? machines are
        // spread round-robin). Just check bound correctness.
        let from = vec![0, 1];
        let to = vec![8, 9];
        let got = min_cross_edge(&t, &from, &to, 1e6);
        let mut expect = f64::INFINITY;
        for &a in &from {
            for &b in &to {
                expect = expect.min(t.lat(a, b) + 1e6 / t.bw(a, b));
            }
        }
        assert!((got - expect).abs() < 1e-12);
        assert_eq!(min_cross_edge(&t, &[1, 2], &[2, 5], 1e6), 0.0);
    }

    #[test]
    fn volumes_match_formulas() {
        // tp volume: 2 bytes * 2 * 1024 * 4096 * 2*(4-1)/4
        let v = cv_tp(2, 2048, 4096, 4);
        assert!((v - 2.0 * 2.0 * 2048.0 * 4096.0 * 1.5).abs() < 1.0);
        assert_eq!(cv_tp(2, 2048, 4096, 1), 0.0);
        assert_eq!(cv_dp(9, 4096, 12288, 1, 4), 0.0);
        let ag = cv_all_gather(36, 4096, 12288, 4);
        let p2p = cv_p2p(36, 4096, 12288);
        assert!((ag / p2p - 0.75).abs() < 1e-9);
    }

    #[test]
    fn two_device_ring_cost_formula() {
        let t = topo();
        // Find a cross-region pair with known α/β.
        let (a, b) = (0, 32);
        let cv = 1e9;
        let want = t.lat(a, b) + cv / t.bw(a, b);
        assert!((ring_minmax(&t, &[a, b], cv) - want).abs() < 1e-9);
        // Sanity: cross-region is dominated by bandwidth at this volume.
        assert!(want > 1.0 * MS);
        assert!(t.bw(a, b) <= 5.0 * GBITPS_BYTES);
    }
}
