//! Memoization of per-task cost-model sub-results. Within one search
//! episode the topology/workflow/job are fixed, so the expensive
//! [`super::task_cost::task_cost`] evaluation of a `TaskPlan` depends
//! only on the task index and the plan fields. Searches mutate one task
//! at a time, so most per-task results are reusable between candidate
//! plans — the cache is now **always on** for every scheduler (a fresh
//! one per [`crate::scheduler::EvalCtx`]), not just the elastic
//! replanner.
//!
//! The cache is concurrent: entries live in `SHARDS` mutex-guarded
//! shards selected by the top bits of the FNV key (the crate is
//! dependency-free, so no lock-free map), letting the parallel
//! evaluation engine's workers share warm results with little
//! contention. Values are computed *outside* the shard lock; a racing
//! duplicate computation is idempotent (the cost model is pure), so the
//! hit/miss counters are telemetry, not a determinism surface.

use super::task_cost::TaskCost;
use crate::plan::TaskPlan;
use std::collections::HashMap; // detlint:allow(D2): keyed get/insert only — shard maps are never iterated
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of mutex-guarded shards (power of two; indexed by key prefix).
const SHARDS: usize = 16;

/// FNV-1a over the fields of a task plan that determine its cost.
/// The topology, workflow and job are fixed for a cache's lifetime
/// (a fresh [`CostCache`] is created per search/replanning episode).
pub fn task_plan_key(task_idx: usize, tp: &TaskPlan) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01B3;
    let mut h = OFFSET;
    let mut mix = |x: u64| {
        for b in x.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(PRIME);
        }
    };
    mix(task_idx as u64);
    mix(tp.strategy.dp as u64);
    mix(tp.strategy.pp as u64);
    mix(tp.strategy.tp as u64);
    for &l in &tp.layer_split {
        mix(l as u64);
    }
    for &d in &tp.assignment {
        mix(d as u64);
    }
    for &s in &tp.dp_shares {
        mix(s.to_bits());
    }
    h
}

/// Sharded concurrent per-task cost memo with hit/miss telemetry.
/// All methods take `&self`; the cache is shared freely across the
/// parallel engine's workers (e.g. behind an `Arc`).
#[derive(Debug)]
pub struct CostCache {
    shards: Vec<Mutex<HashMap<u64, TaskCost>>>, // detlint:allow(D2): keyed lookups only, never iterated
    hits: AtomicUsize,
    misses: AtomicUsize,
}

impl Default for CostCache {
    fn default() -> Self {
        CostCache::new()
    }
}

impl CostCache {
    pub fn new() -> CostCache {
        CostCache {
            shards: (0..SHARDS).map(|_| Mutex::new(HashMap::new())).collect(), // detlint:allow(D2): keyed lookups only, never iterated
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
        }
    }

    /// Shard for a key: top `log2(SHARDS)` bits of the (well-mixed)
    /// FNV hash, so `SHARDS` is the single tuning knob.
    fn shard(&self, key: u64) -> &Mutex<HashMap<u64, TaskCost>> { // detlint:allow(D2): keyed lookups only, never iterated
        const _: () = assert!(SHARDS.is_power_of_two());
        &self.shards[(key >> (64 - SHARDS.trailing_zeros())) as usize]
    }

    /// Per-task lookups that found a memoized result.
    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    /// Per-task lookups that had to run the cost model.
    pub fn misses(&self) -> usize {
        self.misses.load(Ordering::Relaxed)
    }

    /// Entries currently memoized.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop all entries (topology changed — results are stale).
    pub fn clear(&self) {
        for s in &self.shards {
            s.lock().unwrap().clear();
        }
    }

    /// Look up the cost for `(task_idx, tp)`, computing via `f` on miss.
    /// `f` runs outside the shard lock; concurrent misses on the same
    /// key may both compute (idempotent), last insert wins.
    pub fn get_or(
        &self,
        task_idx: usize,
        tp: &TaskPlan,
        f: impl FnOnce() -> TaskCost,
    ) -> TaskCost {
        let key = task_plan_key(task_idx, tp);
        if let Some(&c) = self.shard(key).lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return c;
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let c = f();
        self.shard(key).lock().unwrap().insert(key, c);
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::{ParallelStrategy, TaskPlan};

    fn plan(devs: Vec<usize>) -> TaskPlan {
        TaskPlan::uniform(ParallelStrategy::new(1, 2, 2), 8, devs)
    }

    #[test]
    fn key_sensitive_to_fields() {
        let a = plan(vec![0, 1, 2, 3]);
        let mut b = plan(vec![0, 1, 2, 3]);
        assert_eq!(task_plan_key(0, &a), task_plan_key(0, &b));
        assert_ne!(task_plan_key(0, &a), task_plan_key(1, &a));
        b.assignment[3] = 7;
        assert_ne!(task_plan_key(0, &a), task_plan_key(0, &b));
        let mut c = plan(vec![0, 1, 2, 3]);
        c.layer_split = vec![5, 3];
        assert_ne!(task_plan_key(0, &a), task_plan_key(0, &c));
    }

    #[test]
    fn cache_hits_after_first_eval() {
        let cache = CostCache::new();
        let p = plan(vec![0, 1, 2, 3]);
        let mut calls = 0;
        for _ in 0..3 {
            let c = cache.get_or(0, &p, || {
                calls += 1;
                TaskCost { total: 42.0, ..TaskCost::default() }
            });
            assert_eq!(c.total, 42.0);
        }
        assert_eq!(calls, 1);
        assert_eq!(cache.hits(), 2);
        assert_eq!(cache.misses(), 1);
        cache.clear();
        assert!(cache.is_empty());
    }

    #[test]
    fn cache_shared_across_threads() {
        use std::sync::Arc;
        let cache = Arc::new(CostCache::new());
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let cache = Arc::clone(&cache);
            handles.push(std::thread::spawn(move || {
                for i in 0..32usize {
                    let p = plan(vec![i, i + 1, i + 2, i + 3]);
                    let c = cache.get_or(i % 4, &p, || TaskCost {
                        total: (i % 4) as f64 + 1.0,
                        ..TaskCost::default()
                    });
                    assert_eq!(c.total, (i % 4) as f64 + 1.0, "thread {t}");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // 32 distinct (task, plan) keys exist; every lookup is counted.
        // (Concurrent misses on the same key are legal, so no tight hit
        // floor — only the totals and the entry count are exact.)
        assert_eq!(cache.len(), 32);
        assert_eq!(cache.hits() + cache.misses(), 4 * 32);
        assert!(cache.misses() >= 32, "misses {}", cache.misses());
    }
}
