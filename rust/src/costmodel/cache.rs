//! Memoization of per-task cost-model sub-results, used by the elastic
//! replanner: across a replanning episode the topology is fixed, so the
//! expensive [`super::task_cost::task_cost`] evaluation of a `TaskPlan`
//! depends only on the task index and the plan fields. Warm-started
//! searches mutate one task at a time, so most per-task results are
//! reusable between candidate plans.

use super::task_cost::TaskCost;
use crate::plan::TaskPlan;
use std::collections::HashMap;

/// FNV-1a over the fields of a task plan that determine its cost.
/// The topology, workflow and job are fixed for a cache's lifetime
/// (a fresh [`CostCache`] is created per replanning episode).
pub fn task_plan_key(task_idx: usize, tp: &TaskPlan) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01B3;
    let mut h = OFFSET;
    let mut mix = |x: u64| {
        for b in x.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(PRIME);
        }
    };
    mix(task_idx as u64);
    mix(tp.strategy.dp as u64);
    mix(tp.strategy.pp as u64);
    mix(tp.strategy.tp as u64);
    for &l in &tp.layer_split {
        mix(l as u64);
    }
    for &d in &tp.assignment {
        mix(d as u64);
    }
    for &s in &tp.dp_shares {
        mix(s.to_bits());
    }
    h
}

/// Per-task cost memo with hit/miss telemetry.
#[derive(Debug, Default)]
pub struct CostCache {
    map: HashMap<u64, TaskCost>,
    pub hits: usize,
    pub misses: usize,
}

impl CostCache {
    pub fn new() -> CostCache {
        CostCache::default()
    }

    /// Entries currently memoized.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Drop all entries (topology changed — results are stale).
    pub fn clear(&mut self) {
        self.map.clear();
    }

    /// Look up the cost for `(task_idx, tp)`, computing via `f` on miss.
    pub fn get_or(
        &mut self,
        task_idx: usize,
        tp: &TaskPlan,
        f: impl FnOnce() -> TaskCost,
    ) -> TaskCost {
        let key = task_plan_key(task_idx, tp);
        if let Some(&c) = self.map.get(&key) {
            self.hits += 1;
            return c;
        }
        self.misses += 1;
        let c = f();
        self.map.insert(key, c);
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::{ParallelStrategy, TaskPlan};

    fn plan(devs: Vec<usize>) -> TaskPlan {
        TaskPlan::uniform(ParallelStrategy::new(1, 2, 2), 8, devs)
    }

    #[test]
    fn key_sensitive_to_fields() {
        let a = plan(vec![0, 1, 2, 3]);
        let mut b = plan(vec![0, 1, 2, 3]);
        assert_eq!(task_plan_key(0, &a), task_plan_key(0, &b));
        assert_ne!(task_plan_key(0, &a), task_plan_key(1, &a));
        b.assignment[3] = 7;
        assert_ne!(task_plan_key(0, &a), task_plan_key(0, &b));
        let mut c = plan(vec![0, 1, 2, 3]);
        c.layer_split = vec![5, 3];
        assert_ne!(task_plan_key(0, &a), task_plan_key(0, &c));
    }

    #[test]
    fn cache_hits_after_first_eval() {
        let mut cache = CostCache::new();
        let p = plan(vec![0, 1, 2, 3]);
        let mut calls = 0;
        for _ in 0..3 {
            let c = cache.get_or(0, &p, || {
                calls += 1;
                TaskCost { total: 42.0, ..TaskCost::default() }
            });
            assert_eq!(c.total, 42.0);
        }
        assert_eq!(calls, 1);
        assert_eq!(cache.hits, 2);
        assert_eq!(cache.misses, 1);
        cache.clear();
        assert!(cache.is_empty());
    }
}
