//! Memoization of per-task cost-model sub-results. Within one search
//! episode the topology/workflow/job are fixed, so the expensive
//! [`super::task_cost::task_cost`] evaluation of a `TaskPlan` depends
//! only on the task index and the plan fields. Searches mutate one task
//! at a time, so most per-task results are reusable between candidate
//! plans — the cache is **always on** for every scheduler (a fresh one
//! per [`crate::scheduler::EvalCtx`]), not just the elastic replanner.
//!
//! The cache is concurrent: entries live in `SHARDS` reader-writer
//! locked shards selected by the top bits of the FNV key (the crate is
//! dependency-free, so no lock-free map). Warm lookups — the vast
//! majority on the evaluation hot path — take only a read lock, so
//! workers sharing a warm cache never serialize against each other.
//! Values are computed *outside* any lock; inserts are double-checked
//! under the write lock and the **first** insert wins, which makes the
//! hit/miss counters exact: `misses()` equals the number of distinct
//! keys ever priced, and `hits()` equals all other lookups. Both are
//! therefore bit-deterministic for a given candidate stream at any
//! thread count (a racing duplicate computation is idempotent — the
//! cost model is pure — and the loser's lookup counts as a hit).

use super::task_cost::TaskCost;
use crate::plan::TaskPlan;
use std::collections::HashMap; // detlint:allow(D2): keyed get/insert only — shard maps are never iterated
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::RwLock;

/// Number of rw-locked shards (power of two; indexed by key prefix).
const SHARDS: usize = 16;

/// FNV-1a over the fields of a task plan that determine its cost.
/// The topology, workflow and job are fixed for a cache's lifetime
/// (a fresh [`CostCache`] is created per search/replanning episode).
///
/// Every field is mixed behind a **field-domain tag**, and each
/// variable-length field is additionally **length-prefixed**, so the
/// serialized byte stream is injective over `(task_idx, TaskPlan)`:
/// two distinct inputs always produce distinct streams, and the only
/// remaining collision source is the 64-bit hash itself. Without the
/// tags and prefixes, boundary-shifted plans (e.g. `layer_split=[5,3],
/// assignment=[7]` vs `layer_split=[5], assignment=[3,7]`) fed FNV the
/// identical stream and silently shared a memo slot — returning a
/// *wrong* cached `TaskCost` to every scheduler.
pub fn task_plan_key(task_idx: usize, tp: &TaskPlan) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01B3;
    // Field-domain tags — outside the value range of any mixed field's
    // low byte mattering; what matters is that each field starts with a
    // distinct constant so streams cannot be re-segmented.
    const TAG_TASK: u64 = 0xA1;
    const TAG_STRATEGY: u64 = 0xA2;
    const TAG_LAYER_SPLIT: u64 = 0xA3;
    const TAG_ASSIGNMENT: u64 = 0xA4;
    const TAG_DP_SHARES: u64 = 0xA5;
    let mut h = OFFSET;
    let mut mix = |x: u64| {
        for b in x.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(PRIME);
        }
    };
    mix(TAG_TASK);
    mix(task_idx as u64);
    mix(TAG_STRATEGY);
    mix(tp.strategy.dp as u64);
    mix(tp.strategy.pp as u64);
    mix(tp.strategy.tp as u64);
    mix(TAG_LAYER_SPLIT);
    mix(tp.layer_split.len() as u64);
    for &l in &tp.layer_split {
        mix(l as u64);
    }
    mix(TAG_ASSIGNMENT);
    mix(tp.assignment.len() as u64);
    for &d in &tp.assignment {
        mix(d as u64);
    }
    mix(TAG_DP_SHARES);
    mix(tp.dp_shares.len() as u64);
    for &s in &tp.dp_shares {
        mix(s.to_bits());
    }
    h
}

/// Sharded concurrent per-task cost memo with **exact** hit/miss
/// accounting. All methods take `&self`; the cache is shared freely
/// across the parallel engine's workers (e.g. behind an `Arc`).
///
/// Exactness guarantee: `misses()` is the number of distinct keys whose
/// cost was memoized (one miss per computed key, even under racing
/// duplicate computations), `hits()` is every other lookup, and
/// `hits() + misses()` is the total lookup count. All three are
/// bit-deterministic for a deterministic candidate stream regardless of
/// thread count or interleaving.
#[derive(Debug)]
pub struct CostCache {
    shards: Vec<RwLock<HashMap<u64, TaskCost>>>, // detlint:allow(D2): keyed lookups only, never iterated
    hits: AtomicUsize,
    misses: AtomicUsize,
}

impl Default for CostCache {
    fn default() -> Self {
        CostCache::new()
    }
}

impl CostCache {
    pub fn new() -> CostCache {
        CostCache {
            shards: (0..SHARDS).map(|_| RwLock::new(HashMap::new())).collect(), // detlint:allow(D2): keyed lookups only, never iterated
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
        }
    }

    /// Shard for a key: top `log2(SHARDS)` bits of the (well-mixed)
    /// FNV hash, so `SHARDS` is the single tuning knob.
    fn shard(&self, key: u64) -> &RwLock<HashMap<u64, TaskCost>> { // detlint:allow(D2): keyed lookups only, never iterated
        const _: () = assert!(SHARDS.is_power_of_two());
        &self.shards[(key >> (64 - SHARDS.trailing_zeros())) as usize]
    }

    /// Per-task lookups that reused a memoized result (including a
    /// lookup that lost an insert race and adopted the winner's value).
    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    /// Distinct keys whose cost was computed and memoized — exactly one
    /// miss per key, no matter how many workers raced to compute it.
    pub fn misses(&self) -> usize {
        self.misses.load(Ordering::Relaxed)
    }

    /// Entries currently memoized.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.read().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop all entries (topology changed — results are stale).
    pub fn clear(&self) {
        for s in &self.shards {
            s.write().unwrap().clear();
        }
    }

    /// Look up the cost for `(task_idx, tp)`, computing via `f` on miss.
    ///
    /// Warm path: a read lock and a hit. Cold path: `f` runs outside
    /// any lock (the cost model is pure, so racing duplicates are
    /// idempotent), then the insert is double-checked under the write
    /// lock — the first inserter records the miss, a loser discards its
    /// duplicate, adopts the memoized value, and records a hit.
    pub fn get_or(
        &self,
        task_idx: usize,
        tp: &TaskPlan,
        f: impl FnOnce() -> TaskCost,
    ) -> TaskCost {
        let key = task_plan_key(task_idx, tp);
        let shard = self.shard(key);
        if let Some(&c) = shard.read().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return c;
        }
        let c = f();
        let mut w = shard.write().unwrap();
        if let Some(&winner) = w.get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return winner;
        }
        w.insert(key, c);
        self.misses.fetch_add(1, Ordering::Relaxed);
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::{ParallelStrategy, TaskPlan};

    fn plan(devs: Vec<usize>) -> TaskPlan {
        TaskPlan::uniform(ParallelStrategy::new(1, 2, 2), 8, devs)
    }

    #[test]
    fn key_sensitive_to_fields() {
        let a = plan(vec![0, 1, 2, 3]);
        let mut b = plan(vec![0, 1, 2, 3]);
        assert_eq!(task_plan_key(0, &a), task_plan_key(0, &b));
        assert_ne!(task_plan_key(0, &a), task_plan_key(1, &a));
        b.assignment[3] = 7;
        assert_ne!(task_plan_key(0, &a), task_plan_key(0, &b));
        let mut c = plan(vec![0, 1, 2, 3]);
        c.layer_split = vec![5, 3];
        assert_ne!(task_plan_key(0, &a), task_plan_key(0, &c));
    }

    /// The untagged, unprefixed legacy scheme this PR replaces: fields
    /// mixed back-to-back, so a boundary shift between two
    /// variable-length fields produced the identical byte stream.
    fn legacy_key(task_idx: usize, tp: &TaskPlan) -> u64 {
        const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const PRIME: u64 = 0x0000_0100_0000_01B3;
        let mut h = OFFSET;
        let mut mix = |x: u64| {
            for b in x.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(PRIME);
            }
        };
        mix(task_idx as u64);
        mix(tp.strategy.dp as u64);
        mix(tp.strategy.pp as u64);
        mix(tp.strategy.tp as u64);
        for &l in &tp.layer_split {
            mix(l as u64);
        }
        for &d in &tp.assignment {
            mix(d as u64);
        }
        for &s in &tp.dp_shares {
            mix(s.to_bits());
        }
        h
    }

    /// Regression pin for the boundary-shift collision: the element
    /// `3` migrates between `layer_split` and `assignment` while the
    /// concatenated streams stay byte-identical. The legacy scheme
    /// collides (same memo slot, wrong cached cost); the tagged,
    /// length-prefixed scheme must not.
    #[test]
    fn boundary_shift_pair_no_longer_collides() {
        let strategy = ParallelStrategy::new(1, 2, 2);
        let a = TaskPlan {
            strategy,
            layer_split: vec![5, 3],
            assignment: vec![7],
            dp_shares: vec![1.0],
        };
        let b = TaskPlan {
            strategy,
            layer_split: vec![5],
            assignment: vec![3, 7],
            dp_shares: vec![1.0],
        };
        assert_ne!(a, b, "the two plans are genuinely distinct");
        assert_eq!(
            legacy_key(0, &a),
            legacy_key(0, &b),
            "the legacy scheme collides on the boundary-shift pair"
        );
        assert_ne!(
            task_plan_key(0, &a),
            task_plan_key(0, &b),
            "tags + length prefixes must separate the pair"
        );
        // The same shift across the assignment/dp_shares boundary.
        let c = TaskPlan {
            strategy,
            layer_split: vec![8],
            assignment: vec![2, 1.0f64.to_bits() as usize],
            dp_shares: vec![],
        };
        let d = TaskPlan {
            strategy,
            layer_split: vec![8],
            assignment: vec![2],
            dp_shares: vec![1.0],
        };
        assert_eq!(legacy_key(0, &c), legacy_key(0, &d));
        assert_ne!(task_plan_key(0, &c), task_plan_key(0, &d));
    }

    #[test]
    fn cache_hits_after_first_eval() {
        let cache = CostCache::new();
        let p = plan(vec![0, 1, 2, 3]);
        let mut calls = 0;
        for _ in 0..3 {
            let c = cache.get_or(0, &p, || {
                calls += 1;
                TaskCost { total: 42.0, ..TaskCost::default() }
            });
            assert_eq!(c.total, 42.0);
        }
        assert_eq!(calls, 1);
        assert_eq!(cache.hits(), 2);
        assert_eq!(cache.misses(), 1);
        cache.clear();
        assert!(cache.is_empty());
    }

    #[test]
    fn cache_shared_across_threads() {
        use std::sync::Arc;
        let cache = Arc::new(CostCache::new());
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let cache = Arc::clone(&cache);
            handles.push(std::thread::spawn(move || {
                for i in 0..32usize {
                    let p = plan(vec![i, i + 1, i + 2, i + 3]);
                    let c = cache.get_or(i % 4, &p, || TaskCost {
                        total: (i % 4) as f64 + 1.0,
                        ..TaskCost::default()
                    });
                    assert_eq!(c.total, (i % 4) as f64 + 1.0, "thread {t}");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // 32 distinct (task, plan) keys, each looked up by 4 threads.
        // Accounting is exact under any interleaving: one miss per
        // distinct key, every other lookup a hit.
        assert_eq!(cache.len(), 32);
        assert_eq!(cache.misses(), 32);
        assert_eq!(cache.hits(), 4 * 32 - 32);
    }

    /// All threads race on a *single* key through a barrier: no matter
    /// who wins the insert, exactly one miss is recorded and every
    /// other lookup (including racing losers that computed a duplicate)
    /// counts as a hit.
    #[test]
    fn racing_duplicate_computation_is_one_miss() {
        use std::sync::{Arc, Barrier};
        const N: usize = 8;
        let cache = Arc::new(CostCache::new());
        let gate = Arc::new(Barrier::new(N));
        let computed = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for _ in 0..N {
            let cache = Arc::clone(&cache);
            let gate = Arc::clone(&gate);
            let computed = Arc::clone(&computed);
            handles.push(std::thread::spawn(move || {
                let p = plan(vec![0, 1, 2, 3]);
                gate.wait();
                cache.get_or(0, &p, || {
                    computed.fetch_add(1, Ordering::Relaxed);
                    TaskCost { total: 7.0, ..TaskCost::default() }
                })
            }));
        }
        for h in handles {
            assert_eq!(h.join().unwrap().total, 7.0);
        }
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.misses(), 1, "one miss per computed key");
        assert_eq!(cache.hits(), N - 1);
        // Duplicate computations may have happened — that is legal —
        // but they never inflate the miss count.
        assert!(computed.load(Ordering::Relaxed) >= 1);
    }
}
