//! Failure & recovery pricing: checkpoint cadence, lost-work rollback,
//! and transient-fault retry/backoff.
//!
//! The elastic replay charges three new kinds of simulated time, all of
//! them deterministic functions of the plan, the trace, and the config:
//!
//! * **Checkpoint writes** — at a configurable cadence
//!   ([`RecoveryModel::ckpt_interval_secs`]) the job persists one DP
//!   replica's model/optimizer state to the checkpoint store, priced
//!   against the store bandwidth already modelled by
//!   [`MigrationModel::ckpt_bw`]. DP replicas hold identical weights,
//!   so only one replica per task writes.
//! * **Rollback / rework** — when an *unnoticed* machine loss fires (no
//!   advance-notice window, so nothing could be drained or pre-copied),
//!   or when a task-level failure exhausts its retry budget, the job
//!   rolls back to the last completed checkpoint and re-runs the
//!   productive sim-time since then. A noticed loss charges no rework:
//!   the notice window is exactly what lets the runtime flush state
//!   before the machine vanishes, so notice has a priced value.
//! * **Retry stalls** — transient faults ([`crate::elastic::ClusterEvent`]
//!   NIC bursts, checkpoint-store outages, task failures) are retried
//!   with a deterministic bounded linear backoff: a fault needing `a`
//!   attempts stalls the iteration by `min(a, max_retries) ·
//!   retry_backoff_secs`, so the stall is always bounded by
//!   `max_retries × retry_backoff_secs` in sim time.
//!
//! Degeneracy contract: with [`RecoveryModel::enabled`] false (the
//! default) nothing is charged and the replay is bit-identical to the
//! pre-recovery driver; with recovery enabled, a loss-free trace and
//! checkpointing disabled (`ckpt_interval_secs == 0`) charge exactly
//! `0.0` everywhere, which keeps every float bit-identical too.

use crate::costmodel::migration::MigrationModel;
use crate::plan::memory::tasklet_memory;
use crate::plan::ExecutionPlan;
use crate::workflow::{JobConfig, RlWorkflow};

/// Parameters of the failure-and-recovery model.
///
/// The model is deliberately plan-independent except through
/// [`RecoveryModel::ckpt_write_secs`]: the replay owns *when* rollbacks
/// and retries fire (from the event trace), this struct owns *how much*
/// each one costs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RecoveryModel {
    /// Master switch. `false` (the default) disables every charge and
    /// keeps the replay bit-identical to the pre-recovery driver.
    pub enabled: bool,
    /// Productive sim-seconds between checkpoint completions. `0.0`
    /// disables checkpointing while leaving rollback/retry pricing on:
    /// an unnoticed loss then reworks everything since the last
    /// completed checkpoint — i.e. since the start of the run.
    pub ckpt_interval_secs: f64,
    /// Retry budget per transient fault. A fault whose drawn `attempts`
    /// exceeds this is *unrecovered*: task failures then charge a full
    /// rollback. `0` disables retries entirely (zero stall), which
    /// degenerates NIC bursts to plain link-degrade events.
    pub max_retries: usize,
    /// Backoff per retry attempt, in sim seconds. The backoff is linear
    /// (constant per attempt), so the stall of any single fault is
    /// exactly `min(attempts, max_retries) * retry_backoff_secs` and
    /// never exceeds `max_retries * retry_backoff_secs`.
    pub retry_backoff_secs: f64,
}

impl Default for RecoveryModel {
    fn default() -> Self {
        RecoveryModel {
            enabled: false,
            ckpt_interval_secs: 600.0,
            max_retries: 3,
            retry_backoff_secs: 15.0,
        }
    }
}

impl RecoveryModel {
    /// A [`RecoveryModel`] with recovery pricing on and the given
    /// checkpoint cadence (the other knobs keep their defaults).
    pub fn with_interval(ckpt_interval_secs: f64) -> Self {
        RecoveryModel { enabled: true, ckpt_interval_secs, ..RecoveryModel::default() }
    }

    /// Wall-clock cost of one checkpoint write for `plan`: each task
    /// persists one DP replica's model/optimizer state (DP replicas are
    /// identical, so one writer per task suffices), and all writes
    /// serialize on the store's ingress bandwidth
    /// ([`MigrationModel::ckpt_bw`]) — the same bottleneck the
    /// migration model charges for restores, so a slower store raises
    /// both directions consistently.
    pub fn ckpt_write_secs(
        &self,
        mm: &MigrationModel,
        wf: &RlWorkflow,
        job: &JobConfig,
        plan: &ExecutionPlan,
    ) -> f64 {
        let mut bytes = 0.0f64;
        for (t, tp) in plan.task_plans.iter().enumerate() {
            let task = &wf.tasks[t];
            let s = tp.strategy;
            let local_batch = (job.total_samples() as f64 / s.dp as f64).ceil() as usize;
            for &layers_j in &tp.layer_split {
                // One replica = all pipeline stages × all tp slots; the
                // memory model prices a single (stage, tp-slot) shard.
                bytes += s.tp as f64 * tasklet_memory(task, job, layers_j, s.tp, local_batch).model;
            }
        }
        bytes / mm.ckpt_bw
    }

    /// Deterministic bounded retry/backoff for one transient fault that
    /// needs `attempts` attempts to clear. Returns `(stall_secs,
    /// recovered)`: the stall actually charged (retries performed ×
    /// linear backoff, capped at the retry budget) and whether the
    /// fault cleared within the budget.
    pub fn retry_stall(&self, attempts: usize) -> (f64, bool) {
        let performed = attempts.min(self.max_retries);
        (performed as f64 * self.retry_backoff_secs, attempts <= self.max_retries)
    }

    /// Upper bound on the stall any single fault can charge.
    pub fn max_stall_secs(&self) -> f64 {
        self.max_retries as f64 * self.retry_backoff_secs
    }
}

/// Running checkpoint/rollback bookkeeping for one replay.
///
/// Time is split into *productive* sim-time (iterations actually run)
/// and overheads; the cadence is measured in productive time so a slow
/// checkpoint store cannot starve the cadence clock it feeds. The
/// invariant maintained by [`RecoveryState::advance`] is that, whenever
/// the store is up, productive time since the last completed checkpoint
/// stays strictly below the interval — which is exactly the bound the
/// rollback rule inherits.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RecoveryState {
    /// Productive sim-seconds elapsed so far.
    prod: f64,
    /// Productive sim-time captured by the last completed checkpoint
    /// (0 until the first checkpoint completes).
    stable: f64,
    /// Checkpoints completed so far.
    pub ckpts: usize,
}

impl RecoveryState {
    /// Account one finished iteration of `iter_secs` productive time
    /// and complete any checkpoints whose cadence points were crossed.
    /// Returns the checkpoint-write overhead charged (0 when the store
    /// is down — an outage freezes `stable`, lengthening the exposure
    /// window, which is precisely the risk a store outage creates).
    pub fn advance(
        &mut self,
        iter_secs: f64,
        write_secs: f64,
        store_up: bool,
        interval: f64,
    ) -> f64 {
        self.prod += iter_secs;
        if !store_up || interval <= 0.0 {
            return 0.0;
        }
        let mut overhead = 0.0;
        while self.prod - self.stable >= interval {
            self.stable += interval;
            overhead += write_secs;
            self.ckpts += 1;
        }
        overhead
    }

    /// Charge a rollback: returns the rework (productive sim-time since
    /// the last completed checkpoint) and re-anchors the stable point —
    /// the re-run work itself is what re-establishes the state, so
    /// consecutive losses never double-charge the same window.
    pub fn rollback(&mut self) -> f64 {
        let rework = self.prod - self.stable;
        self.stable = self.prod;
        rework
    }

    /// Productive sim-time currently at risk (since the last completed
    /// checkpoint).
    pub fn exposure_secs(&self) -> f64 {
        self.prod - self.stable
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::{ParallelStrategy, TaskPlan};
    use crate::workflow::{Algo, Mode, ModelSpec};

    fn wf_plan() -> (RlWorkflow, JobConfig, ExecutionPlan) {
        let wf = RlWorkflow::new(Algo::Grpo, Mode::Sync, ModelSpec::qwen_1b7());
        let job = JobConfig::tiny();
        let mut task_plans = Vec::new();
        for (t, task) in wf.tasks.iter().enumerate() {
            let s = ParallelStrategy::new(1, 1, 2);
            task_plans.push(TaskPlan::uniform(s, task.model.nl, vec![2 * t, 2 * t + 1]));
        }
        let n = 2 * wf.n_tasks();
        let plan = ExecutionPlan {
            task_groups: vec![(0..wf.n_tasks()).collect()],
            gpu_groups: vec![(0..n).collect()],
            task_plans,
        };
        (wf, job, plan)
    }

    #[test]
    fn slower_store_raises_write_cost() {
        let (wf, job, plan) = wf_plan();
        let rm = RecoveryModel::with_interval(300.0);
        let fast = MigrationModel::default();
        let slow = MigrationModel { ckpt_bw: fast.ckpt_bw / 4.0, ..fast };
        let wf_fast = rm.ckpt_write_secs(&fast, &wf, &job, &plan);
        let wf_slow = rm.ckpt_write_secs(&slow, &wf, &job, &plan);
        assert!(wf_fast > 0.0);
        assert!(
            (wf_slow / wf_fast - 4.0).abs() < 1e-9,
            "4x slower store must write 4x slower: {wf_slow} vs {wf_fast}"
        );
    }

    #[test]
    fn retry_stall_is_bounded_and_linear() {
        let rm = RecoveryModel { max_retries: 3, retry_backoff_secs: 10.0, ..RecoveryModel::with_interval(0.0) };
        assert_eq!(rm.retry_stall(0), (0.0, true));
        assert_eq!(rm.retry_stall(2), (20.0, true));
        assert_eq!(rm.retry_stall(3), (30.0, true));
        // Budget exhausted: stall caps at the bound, fault unrecovered.
        assert_eq!(rm.retry_stall(7), (30.0, false));
        assert_eq!(rm.max_stall_secs(), 30.0);
        // Zero-retry policy: no stall ever, nothing recovers.
        let zero = RecoveryModel { max_retries: 0, ..rm };
        assert_eq!(zero.retry_stall(5), (0.0, false));
        assert_eq!(zero.max_stall_secs(), 0.0);
    }

    #[test]
    fn cadence_and_rollback_invariants() {
        let mut st = RecoveryState::default();
        let interval = 100.0;
        // 3 iterations of 40s: checkpoint completes inside the third.
        assert_eq!(st.advance(40.0, 5.0, true, interval), 0.0);
        assert_eq!(st.advance(40.0, 5.0, true, interval), 0.0);
        assert_eq!(st.advance(40.0, 5.0, true, interval), 5.0);
        assert_eq!(st.ckpts, 1);
        assert!(st.exposure_secs() < interval);
        // A long iteration crosses two cadence points at once.
        assert_eq!(st.advance(200.0, 5.0, true, interval), 10.0);
        assert_eq!(st.ckpts, 3);
        assert!(st.exposure_secs() < interval);
        // Rollback charges exactly the exposure and re-anchors.
        let exp = st.exposure_secs();
        assert_eq!(st.rollback(), exp);
        assert_eq!(st.exposure_secs(), 0.0);
        assert_eq!(st.rollback(), 0.0, "back-to-back losses never double-charge");
    }

    #[test]
    fn store_outage_freezes_the_stable_point() {
        let mut st = RecoveryState::default();
        let interval = 50.0;
        assert_eq!(st.advance(60.0, 2.0, false, interval), 0.0, "store down: no write");
        assert_eq!(st.ckpts, 0);
        assert!(st.exposure_secs() >= interval, "outage lengthens exposure");
        // Store back up: the backlog of cadence points drains.
        let overhead = st.advance(60.0, 2.0, true, interval);
        assert!(overhead >= 2.0);
        assert!(st.exposure_secs() < interval);
    }

    #[test]
    fn disabled_interval_charges_nothing() {
        let mut st = RecoveryState::default();
        assert_eq!(st.advance(1000.0, 5.0, true, 0.0), 0.0);
        assert_eq!(st.ckpts, 0);
        // ... but rollback still loses everything since the start.
        assert_eq!(st.rollback(), 1000.0);
    }
}
