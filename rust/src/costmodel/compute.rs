//! Computation and HBM-decoding cost primitives (Appendix B.2,
//! "Computation" and "Decoding (HBM-bandwidth bound)").

use super::comm::layer_params;

/// FLOPs of one transformer layer per forward pass per sample
/// (Appendix B): QKVO projections `2·4·seq·h1²`, attention
/// `2·2·seq²·h1`, MLP `2·3·seq·h1·h2`.
pub fn layer_flops(seq: usize, h1: usize, h2: usize) -> f64 {
    let s = seq as f64;
    let (h1f, h2f) = (h1 as f64, h2 as f64);
    2.0 * 4.0 * s * h1f * h1f + 2.0 * 2.0 * s * s * h1f + 2.0 * 3.0 * s * h1f * h2f
}

/// Computation cost of the forward pass of a tasklet holding `nl_j`
/// layers on a device with `comp_d` FLOP/s, TP degree `tp`, processing
/// `nm` micro-batches of `mbs` sequences of length `seq`:
/// `nm · mbs · nl_j · layer_flops / (comp_d · tp)`.
pub fn comp_forward(
    nm: usize,
    mbs: usize,
    nl_j: usize,
    seq: usize,
    h1: usize,
    h2: usize,
    comp_d: f64,
    tp: usize,
) -> f64 {
    nm as f64 * mbs as f64 * nl_j as f64 * layer_flops(seq, h1, h2) / (comp_d * tp as f64)
}

/// Forward + backward (+recompute) cost: 3× the forward term
/// (Appendix B uses the canonical 1:2 fwd:bwd ratio).
pub fn comp_train(
    nm: usize,
    mbs: usize,
    nl_j: usize,
    seq: usize,
    h1: usize,
    h2: usize,
    comp_d: f64,
    tp: usize,
) -> f64 {
    3.0 * comp_forward(nm, mbs, nl_j, seq, h1, h2, comp_d, tp)
}

/// HBM-bound decoding cost (Appendix B):
/// `seq_out · nm · mbs · B_BF16 · nl_j · (4h1²+3h1h2) / (dbs_d · hbm_d · tp)`
/// — every decode step re-reads the stage's weights from HBM; a decode
/// batch of `dbs_d` sequences amortizes each read.
pub fn hbm_decode(
    seq_out: usize,
    nm: usize,
    mbs: usize,
    nl_j: usize,
    h1: usize,
    h2: usize,
    dbs_d: usize,
    hbm_d: f64,
    tp: usize,
) -> f64 {
    let weight_bytes = crate::util::units::B_BF16 * nl_j as f64 * layer_params(h1, h2);
    seq_out as f64 * nm as f64 * mbs as f64 * weight_bytes
        / (dbs_d as f64 * hbm_d * tp as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::{GBPS_BYTES, TFLOPS};

    #[test]
    fn layer_flops_formula() {
        // seq=1, h1=2, h2=3: 8*1*4 + 4*1*2 + 6*1*2*3 = 32 + 8 + 36 = 76
        assert_eq!(layer_flops(1, 2, 3), 76.0);
    }

    #[test]
    fn train_is_3x_forward() {
        let f = comp_forward(4, 2, 9, 2048, 4096, 12288, 312.0 * TFLOPS, 4);
        let t = comp_train(4, 2, 9, 2048, 4096, 12288, 312.0 * TFLOPS, 4);
        assert!((t / f - 3.0).abs() < 1e-12);
    }

    #[test]
    fn compute_scales_inverse_with_tflops_and_tp() {
        let slow = comp_forward(4, 2, 9, 2048, 4096, 12288, 121.0 * TFLOPS, 1);
        let fast = comp_forward(4, 2, 9, 2048, 4096, 12288, 312.0 * TFLOPS, 1);
        assert!((slow / fast - 312.0 / 121.0).abs() < 1e-9);
        let tp4 = comp_forward(4, 2, 9, 2048, 4096, 12288, 312.0 * TFLOPS, 4);
        assert!((fast / tp4 - 4.0).abs() < 1e-9);
    }

    #[test]
    fn decode_amortized_by_batch() {
        let d1 = hbm_decode(1024, 8, 2, 9, 4096, 12288, 1, 2039.0 * GBPS_BYTES, 1);
        let d16 = hbm_decode(1024, 8, 2, 9, 4096, 12288, 16, 2039.0 * GBPS_BYTES, 1);
        assert!((d1 / d16 - 16.0).abs() < 1e-9);
    }

    #[test]
    fn a100_decode_beats_l40s() {
        // A100's 2039 GB/s vs L40S's 864 GB/s: decoding is ~2.4× faster.
        let a = hbm_decode(1024, 8, 2, 36, 2560, 9728, 32, 2039.0 * GBPS_BYTES, 1);
        let l = hbm_decode(1024, 8, 2, 36, 2560, 9728, 32, 864.0 * GBPS_BYTES, 1);
        assert!((l / a - 2039.0 / 864.0).abs() < 1e-9);
    }
}
