//! Analytical cost model — paper §3.3 and Appendix B, implemented
//! verbatim: component-level computation/communication costs (B.2),
//! task-level costs Ψ (B.3), end-to-end costs for Sync/Async PPO/GRPO
//! (B.4), with resharding and weight-synchronization terms.
//!
//! The model is the hot path of the schedulers (evaluated for every
//! candidate plan), so it avoids allocation where possible and uses a
//! bottleneck-ring heuristic that is exact for small TP/DP groups.

pub mod comm;
pub mod compute;
pub mod task_cost;
pub mod e2e;
pub mod cache;
pub mod dirty;
pub mod migration;
pub mod recovery;

pub use cache::{task_plan_key, CostCache};
pub use comm::ring_minmax;
pub use dirty::DirtySet;
pub use e2e::{bounded_staleness_period, CostModel, PlanCost, StreamCosts};
pub use migration::{MigrationModel, PrevTask};
pub use recovery::{RecoveryModel, RecoveryState};
pub use task_cost::TaskCost;
