//! Task-level cost assembly: Ψ^gen, Ψ^inf, Ψ^train (Appendix B.3) built
//! from the component costs of B.2 over a task's `TaskPlan`.

use super::comm::{cv_dp, cv_pp, cv_tp, min_cross_edge, ring_minmax};
use super::compute::{comp_forward, comp_train, hbm_decode};
use crate::plan::memory::decode_batch_size;
use crate::plan::TaskPlan;
use crate::topology::DeviceTopology;
use crate::workflow::{JobConfig, RlTask, TaskKind};

/// Decomposed cost of one task (seconds per iteration).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct TaskCost {
    pub comp: f64,
    pub tp: f64,
    pub pp: f64,
    pub dp: f64,
    pub hbm: f64,
    pub bubble: f64,
    /// Ψ-aggregated task cost.
    pub total: f64,
}

/// Total micro-batches of the job (before DP splitting).
pub fn total_microbatches(job: &JobConfig) -> usize {
    job.total_samples().div_ceil(job.mbs).max(1)
}

/// Compute the task-level cost Ψ for `task` under `plan` on `topo`.
pub fn task_cost(
    topo: &DeviceTopology,
    task: &RlTask,
    job: &JobConfig,
    plan: &TaskPlan,
) -> TaskCost {
    let s = &plan.strategy;
    let m = &task.model;
    let kind = task.kind();
    let seq = job.seq_total();
    // Generation: compute covers prefill only (seq_out = 0), the decode
    // phase is the HBM term.
    let comp_seq = match kind {
        TaskKind::Generation => job.seq_in,
        _ => seq,
    };
    let total_m = total_microbatches(job);
    let local_batch = (job.total_samples() as f64 / s.dp as f64).ceil() as usize;

    let vol_tp = cv_tp(job.mbs, seq, m.h1, s.tp);
    let vol_pp = cv_pp(job.mbs, seq, m.h1);

    // Multipliers: forward-only vs forward+backward(+recompute).
    let (tp_mult, pp_mult) = match kind {
        TaskKind::Training => (if job.recompute { 6.0 } else { 4.0 }, 2.0),
        _ => (2.0, 1.0),
    };

    let mut psi: f64 = 0.0; // max over replicas of the stage-path term
    let mut c_comp_max: f64 = 0.0;
    let mut c_tp_max: f64 = 0.0;
    let mut c_pp_max: f64 = 0.0;
    let mut c_hbm_max: f64 = 0.0;
    let mut c_bubble_max: f64 = 0.0;

    for i in 0..s.dp {
        let nm_i = plan.replica_microbatches(total_m, i);
        // Decode batch size is a *replica-wide* property: the pipeline
        // streams every decode batch through all stages, so the most
        // memory-constrained device throttles everyone (matches the
        // engine/simulator behaviour).
        let replica_dbs = if kind == TaskKind::Generation {
            let mut dbs = usize::MAX;
            for j in 0..s.pp {
                for &d in &plan.tp_group(i, j) {
                    dbs = dbs.min(decode_batch_size(
                        task,
                        job,
                        plan.layer_split[j],
                        s.tp,
                        local_batch,
                        topo.devices[d].spec().mem_bytes,
                    ));
                }
            }
            dbs.max(1)
        } else {
            1
        };
        let mut stage_max: f64 = 0.0; // max_j (comp + tp + pp [+ hbm])
        let mut bubble_num: f64 = 0.0; // Σ_{j≠0} per-microbatch stage cost
        for j in 0..s.pp {
            let nl_j = plan.layer_split[j];
            let tp_devs = plan.tp_group(i, j);
            // C_tp(t,i,j)
            let c_tp = tp_mult * nm_i as f64 * nl_j as f64 * ring_minmax(topo, &tp_devs, vol_tp);
            // C_pp(t,i,j): edge to stage j+1
            let c_pp = if j + 1 < s.pp {
                let next = plan.tp_group(i, j + 1);
                pp_mult * nm_i as f64 * min_cross_edge(topo, &tp_devs, &next, vol_pp)
            } else {
                0.0
            };
            // C_comp(t,i,j) = max_k
            let mut c_comp: f64 = 0.0;
            let mut c_hbm: f64 = 0.0;
            for &d in &tp_devs {
                let spec = topo.devices[d].spec();
                // Achievable (profiler-measured) FLOPs, not paper peak:
                // the HetRL profiler feeds measured TFLOPs to the model.
                let flops = topo.devices[d].effective_flops();
                let c = match kind {
                    TaskKind::Training => comp_train(
                        nm_i, job.mbs, nl_j, comp_seq, m.h1, m.h2, flops, s.tp,
                    ),
                    _ => comp_forward(
                        nm_i, job.mbs, nl_j, comp_seq, m.h1, m.h2, flops, s.tp,
                    ),
                };
                c_comp = c_comp.max(c);
                if kind == TaskKind::Generation {
                    let dbs = replica_dbs;
                    let mut h = hbm_decode(
                        job.seq_out, nm_i, job.mbs, nl_j, m.h1, m.h2, dbs, spec.hbm_bps, s.tp,
                    );
                    // Decode-phase TP all-reduce *latency*: every token
                    // pays 2(tp−1)·α per layer — negligible on NVLink,
                    // catastrophic over WAN (this is why serving systems
                    // never TP across data centers). The volume term is
                    // already in C_tp; the latency term matters here
                    // because decoding is per-token.
                    if s.tp > 1 {
                        let mut alpha_max: f64 = 0.0;
                        for (x, &a) in tp_devs.iter().enumerate() {
                            for &b in tp_devs.iter().skip(x + 1) {
                                alpha_max = alpha_max.max(topo.lat(a, b));
                            }
                        }
                        let n_batches = local_batch.div_ceil(dbs.max(1)).max(1) as f64;
                        h += job.seq_out as f64
                            * n_batches
                            * nl_j as f64
                            * 2.0
                            * (s.tp as f64 - 1.0)
                            * alpha_max;
                    }
                    c_hbm = c_hbm.max(h);
                }
            }
            let stage = c_comp + c_tp + c_pp + c_hbm;
            stage_max = stage_max.max(stage);
            if j != 0 {
                bubble_num += (c_comp + c_tp + c_pp) / nm_i as f64;
            }
            c_comp_max = c_comp_max.max(c_comp);
            c_tp_max = c_tp_max.max(c_tp);
            c_pp_max = c_pp_max.max(c_pp);
            c_hbm_max = c_hbm_max.max(c_hbm);
        }
        let replica_total = match kind {
            TaskKind::Training => stage_max + bubble_num,
            _ => stage_max,
        };
        psi = psi.max(replica_total);
        c_bubble_max = c_bubble_max.max(bubble_num);
    }

    // C_dp: gradient all-reduce per (j, k) subgraph, training only.
    let mut c_dp: f64 = 0.0;
    if kind == TaskKind::Training && s.dp > 1 {
        for j in 0..s.pp {
            let nl_j = plan.layer_split[j];
            let vol = cv_dp(nl_j, m.h1, m.h2, s.dp, s.tp);
            for k in 0..s.tp {
                let devs = plan.dp_group(j, k);
                c_dp = c_dp.max(ring_minmax(topo, &devs, vol));
            }
        }
        psi += c_dp;
    }

    TaskCost {
        comp: c_comp_max,
        tp: c_tp_max,
        pp: c_pp_max,
        dp: c_dp,
        hbm: c_hbm_max,
        bubble: c_bubble_max,
        total: psi,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::ParallelStrategy;
    use crate::topology::{build_testbed, Scenario, TestbedSpec};
    use crate::workflow::{ModelSpec, RlTaskId};

    fn setup() -> (DeviceTopology, JobConfig) {
        (
            build_testbed(Scenario::SingleRegion, &TestbedSpec::default()),
            JobConfig::default(),
        )
    }

    fn task(id: RlTaskId) -> RlTask {
        RlTask { id, model: ModelSpec::qwen_4b() }
    }

    #[test]
    fn training_costs_more_than_inference() {
        let (topo, job) = setup();
        let s = ParallelStrategy::new(2, 2, 4);
        let devs: Vec<usize> = (0..16).collect();
        let inf = task_cost(
            &topo,
            &task(RlTaskId::RefInf),
            &job,
            &TaskPlan::uniform(s, 36, devs.clone()),
        );
        let train = task_cost(
            &topo,
            &task(RlTaskId::ActorTrain),
            &job,
            &TaskPlan::uniform(s, 36, devs),
        );
        assert!(train.total > 2.0 * inf.total);
        assert!(train.dp > 0.0);
        assert!(inf.dp == 0.0);
    }

    #[test]
    fn generation_dominated_by_hbm() {
        let (topo, job) = setup();
        let s = ParallelStrategy::new(2, 2, 4);
        let devs: Vec<usize> = (0..16).collect();
        let gen = task_cost(
            &topo,
            &task(RlTaskId::ActorGen),
            &job,
            &TaskPlan::uniform(s, 36, devs),
        );
        assert!(gen.hbm > 0.0);
        assert!(gen.total >= gen.hbm);
    }

    #[test]
    fn more_devices_cut_compute() {
        let (topo, job) = setup();
        let small = task_cost(
            &topo,
            &task(RlTaskId::ActorTrain),
            &job,
            &TaskPlan::uniform(ParallelStrategy::new(2, 1, 4), 36, (0..8).collect()),
        );
        let large = task_cost(
            &topo,
            &task(RlTaskId::ActorTrain),
            &job,
            &TaskPlan::uniform(ParallelStrategy::new(4, 1, 4), 36, (0..16).collect()),
        );
        assert!(large.comp < small.comp, "large={:?} small={:?}", large, small);
    }

    #[test]
    fn a100_slice_faster_than_l4_slice() {
        let (topo, job) = setup();
        // machines are interleaved A100, L40S, L4, A100... → devices 0..8
        // are A100s, 16..24 are L4s.
        let s = ParallelStrategy::new(1, 1, 8);
        let a100 = task_cost(
            &topo,
            &task(RlTaskId::ActorTrain),
            &job,
            &TaskPlan::uniform(s, 36, (0..8).collect()),
        );
        let l4 = task_cost(
            &topo,
            &task(RlTaskId::ActorTrain),
            &job,
            &TaskPlan::uniform(s, 36, (16..24).collect()),
        );
        assert_eq!(topo.devices[16].spec().name, "L4");
        assert!(l4.comp > 2.0 * a100.comp);
    }

    #[test]
    fn pipeline_adds_bubble_for_training() {
        let (topo, job) = setup();
        let pp1 = task_cost(
            &topo,
            &task(RlTaskId::ActorTrain),
            &job,
            &TaskPlan::uniform(ParallelStrategy::new(1, 1, 8), 36, (0..8).collect()),
        );
        let pp4 = task_cost(
            &topo,
            &task(RlTaskId::ActorTrain),
            &job,
            &TaskPlan::uniform(ParallelStrategy::new(1, 4, 2), 36, (0..8).collect()),
        );
        assert_eq!(pp1.bubble, 0.0);
        assert!(pp4.bubble > 0.0);
        assert!(pp4.pp > 0.0);
    }

    #[test]
    fn wan_links_inflate_tp_cost() {
        let job = JobConfig::default();
        let local = build_testbed(Scenario::SingleRegion, &TestbedSpec::default());
        let wan = build_testbed(Scenario::MultiContinent, &TestbedSpec::default());
        let s = ParallelStrategy::new(1, 1, 8);
        // Spread TP over 8 different machines (device stride 8 = one per
        // machine) — catastrophic on WAN, fine locally.
        let devs: Vec<usize> = (0..8).map(|i| i * 8).collect();
        let t = task(RlTaskId::RefInf);
        let c_local = task_cost(&local, &t, &job, &TaskPlan::uniform(s, 36, devs.clone()));
        let c_wan = task_cost(&wan, &t, &job, &TaskPlan::uniform(s, 36, devs));
        assert!(c_wan.tp > 50.0 * c_local.tp, "wan={} local={}", c_wan.tp, c_local.tp);
    }
}
