//! End-to-end cost model (Appendix B.4): Φ aggregation, resharding and
//! weight-synchronization costs, and the per-algorithm iteration-time
//! estimates `C_SyncPPO`, `C_AsyncPPO`, `C_SyncGRPO`, `C_AsyncGRPO`.

use super::comm::{cv_all_gather, cv_p2p, min_cross_edge, ring_minmax};
use super::task_cost::{task_cost, TaskCost};
use crate::plan::ExecutionPlan;
use crate::topology::DeviceTopology;
use crate::workflow::{Algo, JobConfig, Mode, RlTaskId, RlWorkflow};

/// Full cost breakdown of an execution plan.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanCost {
    /// Per-task Ψ costs, indexed like the workflow's tasks.
    pub per_task: Vec<TaskCost>,
    /// Model resharding cost (sync modes).
    pub reshard: f64,
    /// Weight synchronization cost (async modes).
    pub sync: f64,
    /// Estimated end-to-end iteration time (seconds).
    pub iter_time: f64,
}

impl PlanCost {
    /// Throughput in samples (prompt-response pairs) per second.
    pub fn throughput(&self, job: &JobConfig) -> f64 {
        job.total_samples() as f64 / self.iter_time
    }
}

/// Steady-state seconds per training step of the bounded-staleness
/// asynchronous pipeline (the [`crate::asyncrl`] workload model):
/// generation (`gen`), the training side (`train_side` = reward/ref
/// inference aggregated with actor training), and weight sync (`sync`),
/// decoupled by a rollout queue of `queue_cap` slots under a hard
/// off-policy staleness bound of `staleness_bound` policy versions.
///
/// The period is the largest of four cycle bounds of the pipeline's
/// dependency graph:
///
/// * `gen` — the generation pool is busy every step;
/// * `train_side + sync` — training and weight sync serialize on the
///   training pool (the generation pool receives weights in-flight,
///   AReaL-style, and is not blocked by sync);
/// * `(gen + train_side + sync) / (k + 1)` — the staleness cycle:
///   generation of step `i` waits for the weight sync of step
///   `i - k - 1`, so one full gen→train→sync lap amortizes over at
///   most `k + 1` steps;
/// * `(gen + train_side) / (cap + 1)` — the capacity cycle: generation
///   of step `i` waits for batch `i - cap` to leave the queue, which
///   happens when training step `i - cap - 1`'s consumer frees the
///   slot.
///
/// `staleness_bound = 0` makes the staleness cycle `gen + train_side +
/// sync`, which dominates the other three bounds — exactly the
/// synchronous iteration. The period is monotone non-increasing in both
/// `staleness_bound` and `queue_cap` and floors at
/// `max(gen, train_side + sync)` (perfect overlap).
pub fn bounded_staleness_period(
    gen: f64,
    train_side: f64,
    sync: f64,
    staleness_bound: usize,
    queue_cap: usize,
) -> f64 {
    let k = staleness_bound as f64;
    let cap = queue_cap.max(1) as f64;
    gen.max(train_side + sync)
        .max((gen + train_side + sync) / (k + 1.0))
        .max((gen + train_side) / (cap + 1.0))
}

/// Per-stream decomposition of a plan's cost under the async workload
/// model: what [`bounded_staleness_period`] and the
/// [`crate::asyncrl::pipeline`] DES consume.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StreamCosts {
    /// Actor-generation cost per rollout batch (seconds).
    pub gen: f64,
    /// Training-side cost per step: reward/ref (and critic) inference
    /// aggregated by Φ, plus the training task(s).
    pub train_side: f64,
    /// Weight-synchronization cost per step (seconds).
    pub sync: f64,
    /// Fraction of generation devices shared with other tasks — the
    /// degree to which gen/train overlap is illusory for this plan.
    pub overlap_frac: f64,
}

/// The cost model `C(ρ, σ; G, G_D)`.
pub struct CostModel<'a> {
    pub topo: &'a DeviceTopology,
    pub wf: &'a RlWorkflow,
    pub job: &'a JobConfig,
}

impl<'a> CostModel<'a> {
    pub fn new(topo: &'a DeviceTopology, wf: &'a RlWorkflow, job: &'a JobConfig) -> Self {
        CostModel { topo, wf, job }
    }

    /// Φ({C^t}) = η·max + (1-η)·Σ — the task-parallelism aggregator.
    pub fn phi(&self, costs: &[f64]) -> f64 {
        if costs.is_empty() {
            return 0.0;
        }
        let max = costs.iter().cloned().fold(f64::MIN, f64::max);
        let sum: f64 = costs.iter().sum();
        let eta = self.job.eta;
        eta * max + (1.0 - eta) * sum
    }

    /// Evaluate the full plan. Returns `None` if required tasks are
    /// missing from the workflow (never happens for well-formed ones).
    pub fn plan_cost(&self, plan: &ExecutionPlan) -> PlanCost {
        let per_task: Vec<TaskCost> = self
            .wf
            .tasks
            .iter()
            .zip(&plan.task_plans)
            .map(|(task, tp)| task_cost(self.topo, task, self.job, tp))
            .collect();
        self.aggregate(plan, per_task)
    }

    /// [`Self::plan_cost`] with per-task memoization (see
    /// [`super::cache::CostCache`]); the schedulers' hot path — candidate
    /// plans share most task plans with earlier candidates, and the
    /// cache is sharded so the parallel engine's workers can share it.
    pub fn plan_cost_cached(
        &self,
        plan: &ExecutionPlan,
        cache: &super::cache::CostCache,
    ) -> PlanCost {
        let per_task: Vec<TaskCost> = self
            .wf
            .tasks
            .iter()
            .zip(&plan.task_plans)
            .enumerate()
            .map(|(t, (task, tp))| {
                cache.get_or(t, tp, || task_cost(self.topo, task, self.job, tp))
            })
            .collect();
        self.aggregate(plan, per_task)
    }

    /// [`Self::plan_cost_cached`] restricted to a dirty-task footprint:
    /// tasks in `dirty` are re-priced through the cache, every other
    /// task reuses its cost from `base` (the per-task costs of a
    /// previously priced plan that agrees with `plan` outside `dirty`).
    ///
    /// Because [`task_cost`] is pure in `(task, TaskPlan)`, the result
    /// is **bit-identical** to a full [`Self::plan_cost_cached`] of
    /// `plan` whenever `dirty` is a superset of the tasks whose
    /// `TaskPlan` differs from the baseline — the soundness contract
    /// every footprint producer in [`crate::scheduler::ea`] upholds and
    /// `tests/prop_delta_eval.rs` pins against the full-re-price oracle.
    pub fn plan_cost_delta(
        &self,
        plan: &ExecutionPlan,
        base: &[TaskCost],
        dirty: &super::dirty::DirtySet,
        cache: &super::cache::CostCache,
    ) -> PlanCost {
        let mut per_task = Vec::new();
        self.price_delta_into(plan, base, dirty, cache, &mut per_task);
        self.aggregate(plan, per_task)
    }

    /// Hot-path form of [`Self::plan_cost_cached`]: fills `out` with the
    /// per-task costs (reusing its allocation — the schedulers' batched
    /// scoring loop passes one scratch buffer for a whole batch) and
    /// returns the end-to-end iteration time.
    pub fn price_cached_into(
        &self,
        plan: &ExecutionPlan,
        cache: &super::cache::CostCache,
        out: &mut Vec<TaskCost>,
    ) -> f64 {
        out.clear();
        out.extend(
            self.wf
                .tasks
                .iter()
                .zip(&plan.task_plans)
                .enumerate()
                .map(|(t, (task, tp))| {
                    cache.get_or(t, tp, || task_cost(self.topo, task, self.job, tp))
                }),
        );
        self.iter_time_of(plan, out, self.reshard_cost(plan), self.sync_cost(plan))
    }

    /// Hot-path form of [`Self::plan_cost_delta`]: fills `out` (reusing
    /// its allocation) and returns the end-to-end iteration time. The
    /// number of per-task cost resolutions routed through the cache is
    /// exactly `dirty.len()`.
    pub fn price_delta_into(
        &self,
        plan: &ExecutionPlan,
        base: &[TaskCost],
        dirty: &super::dirty::DirtySet,
        cache: &super::cache::CostCache,
        out: &mut Vec<TaskCost>,
    ) -> f64 {
        debug_assert_eq!(base.len(), plan.task_plans.len());
        debug_assert!(dirty.iter().all(|t| t < plan.task_plans.len()));
        out.clear();
        out.extend(
            self.wf
                .tasks
                .iter()
                .zip(&plan.task_plans)
                .enumerate()
                .map(|(t, (task, tp))| {
                    if dirty.contains(t) {
                        cache.get_or(t, tp, || task_cost(self.topo, task, self.job, tp))
                    } else {
                        base[t]
                    }
                }),
        );
        self.iter_time_of(plan, out, self.reshard_cost(plan), self.sync_cost(plan))
    }

    /// Combine per-task Ψ costs into the end-to-end iteration time.
    fn aggregate(&self, plan: &ExecutionPlan, per_task: Vec<TaskCost>) -> PlanCost {
        let reshard = self.reshard_cost(plan);
        let sync = self.sync_cost(plan);
        let iter_time = self.iter_time_of(plan, &per_task, reshard, sync);
        PlanCost { per_task, reshard, sync, iter_time }
    }

    /// The per-algorithm/mode iteration-time formula — a pure function
    /// of the plan's task plans, the per-task Ψ costs and the
    /// reshard/sync terms, so the delta path reuses it verbatim (bit
    /// identity with the full path follows from purity).
    fn iter_time_of(
        &self,
        plan: &ExecutionPlan,
        per_task: &[TaskCost],
        reshard: f64,
        sync: f64,
    ) -> f64 {
        let c = |id: RlTaskId| -> f64 {
            self.wf
                .task_index(id)
                .map(|t| per_task[t].total)
                .unwrap_or(0.0)
        };

        match (self.wf.algo, self.wf.mode) {
            (Algo::Ppo, Mode::Sync) => {
                c(RlTaskId::ActorGen)
                    + self.phi(&[
                        c(RlTaskId::RewardInf),
                        c(RlTaskId::RefInf),
                        c(RlTaskId::CriticInf),
                    ])
                    + self.phi(&[c(RlTaskId::ActorTrain), c(RlTaskId::CriticTrain)])
                    + reshard
            }
            (Algo::Ppo, Mode::Async) => {
                let train_side = self.train_side_cost(&c);
                let gen = c(RlTaskId::ActorGen);
                let overlap = self.gen_overlap_frac(plan);
                // Steady-state period of the bounded-staleness pipeline
                // (job.staleness_bound / job.rollout_queue_cap), plus
                // the contention term: device sharing between generation
                // and the training side serializes that fraction of the
                // smaller stream (the paper's async designs disaggregate
                // for this reason).
                bounded_staleness_period(
                    gen,
                    train_side,
                    sync,
                    self.job.staleness_bound,
                    self.job.rollout_queue_cap,
                ) + overlap * gen.min(train_side)
            }
            (Algo::Grpo, Mode::Sync) => {
                c(RlTaskId::ActorGen)
                    + self.phi(&[c(RlTaskId::RewardInf), c(RlTaskId::RefInf)])
                    + c(RlTaskId::ActorTrain)
                    + reshard
            }
            (Algo::Grpo, Mode::Async) => {
                let train_side = self.train_side_cost(&c);
                let gen = c(RlTaskId::ActorGen);
                let overlap = self.gen_overlap_frac(plan);
                bounded_staleness_period(
                    gen,
                    train_side,
                    sync,
                    self.job.staleness_bound,
                    self.job.rollout_queue_cap,
                ) + overlap * gen.min(train_side)
            }
        }
    }

    /// Training-side cost per step: the non-generation inference tasks
    /// aggregated by Φ, then the training task(s) — the `train_side`
    /// stream of [`bounded_staleness_period`].
    fn train_side_cost(&self, c: &dyn Fn(RlTaskId) -> f64) -> f64 {
        match self.wf.algo {
            Algo::Ppo => {
                self.phi(&[
                    c(RlTaskId::RewardInf),
                    c(RlTaskId::RefInf),
                    c(RlTaskId::CriticInf),
                ]) + self.phi(&[c(RlTaskId::ActorTrain), c(RlTaskId::CriticTrain)])
            }
            Algo::Grpo => {
                self.phi(&[c(RlTaskId::RewardInf), c(RlTaskId::RefInf)])
                    + c(RlTaskId::ActorTrain)
            }
        }
    }

    /// Decompose a plan's cost into the async pipeline's streams:
    /// generation, training side, weight sync and the gen-device overlap
    /// fraction. The [`crate::asyncrl::pipeline`] DES builds its ops
    /// from exactly these four numbers.
    pub fn stream_costs(&self, plan: &ExecutionPlan) -> StreamCosts {
        let per_task: Vec<TaskCost> = self
            .wf
            .tasks
            .iter()
            .zip(&plan.task_plans)
            .map(|(task, tp)| task_cost(self.topo, task, self.job, tp))
            .collect();
        let c = |id: RlTaskId| -> f64 {
            self.wf
                .task_index(id)
                .map(|t| per_task[t].total)
                .unwrap_or(0.0)
        };
        StreamCosts {
            gen: c(RlTaskId::ActorGen),
            train_side: self.train_side_cost(&c),
            sync: self.sync_cost(plan),
            overlap_frac: self.gen_overlap_frac(plan),
        }
    }

    /// Fraction of the actor-generation devices also used by any other
    /// task — the degree to which async's gen/train overlap is illusory.
    fn gen_overlap_frac(&self, plan: &ExecutionPlan) -> f64 {
        let Some(tg) = self.wf.task_index(RlTaskId::ActorGen) else {
            return 0.0;
        };
        let gen_devices = plan.task_plans[tg].devices();
        if gen_devices.is_empty() {
            return 0.0;
        }
        let mut shared = 0usize;
        for &d in &gen_devices {
            let used_elsewhere = plan
                .task_plans
                .iter()
                .enumerate()
                .any(|(t, tp)| t != tg && tp.assignment.contains(&d));
            if used_elsewhere {
                shared += 1;
            }
        }
        shared as f64 / gen_devices.len() as f64
    }

    /// `C_reshard = max_i C_all-gather(actor-train, i)`: after training,
    /// each actor-training replica all-gathers the updated weights so the
    /// (colocated) generation engine can reload them.
    pub fn reshard_cost(&self, plan: &ExecutionPlan) -> f64 {
        let Some(t) = self.wf.task_index(RlTaskId::ActorTrain) else {
            return 0.0;
        };
        let tp = &plan.task_plans[t];
        let m = &self.wf.tasks[t].model;
        let group = tp.strategy.pp * tp.strategy.tp;
        let vol = cv_all_gather(m.nl, m.h1, m.h2, group);
        let mut worst: f64 = 0.0;
        for i in 0..tp.strategy.dp {
            let devs = tp.replica_devices(i);
            worst = worst.max(ring_minmax(self.topo, &devs, vol));
        }
        worst
    }

    /// `C_sync` (async): all-gather on the fastest training replica +
    /// broadcast on the slowest generation replica + point-to-point
    /// transfer between the two groups (Appendix B.2, Synchronization).
    pub fn sync_cost(&self, plan: &ExecutionPlan) -> f64 {
        let (Some(tt), Some(tg)) = (
            self.wf.task_index(RlTaskId::ActorTrain),
            self.wf.task_index(RlTaskId::ActorGen),
        ) else {
            return 0.0;
        };
        let (pt, pg) = (&plan.task_plans[tt], &plan.task_plans[tg]);
        let m = &self.wf.tasks[tt].model;

        // all-gather within a training replica — min over replicas
        let ag_group = pt.strategy.pp * pt.strategy.tp;
        let ag_vol = cv_all_gather(m.nl, m.h1, m.h2, ag_group);
        let mut ag_min = f64::INFINITY;
        for i in 0..pt.strategy.dp {
            ag_min = ag_min.min(ring_minmax(self.topo, &pt.replica_devices(i), ag_vol));
        }
        if !ag_min.is_finite() {
            ag_min = 0.0;
        }

        // broadcast within each generation replica — max over replicas
        let bc_group = pg.strategy.pp * pg.strategy.tp;
        let bc_vol = cv_all_gather(m.nl, m.h1, m.h2, bc_group);
        let mut bc_max: f64 = 0.0;
        for i in 0..pg.strategy.dp {
            bc_max = bc_max.max(ring_minmax(self.topo, &pg.replica_devices(i), bc_vol));
        }

        // point-to-point between the two groups
        let p2p_vol = cv_p2p(m.nl, m.h1, m.h2);
        let p2p = min_cross_edge(self.topo, &pt.devices(), &pg.devices(), p2p_vol);

        ag_min + bc_max + p2p
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::{ParallelStrategy, TaskPlan};
    use crate::topology::{build_testbed, Scenario, TestbedSpec};
    use crate::workflow::ModelSpec;

    fn plan_over(wf: &RlWorkflow, n: usize, per_task: usize) -> ExecutionPlan {
        let mut task_plans = Vec::new();
        for (t, task) in wf.tasks.iter().enumerate() {
            let s = ParallelStrategy::new(per_task / 8, 2, 4);
            let devs: Vec<usize> = (t * per_task..(t + 1) * per_task).collect();
            task_plans.push(TaskPlan::uniform(s, task.model.nl, devs));
        }
        ExecutionPlan {
            task_groups: vec![(0..wf.n_tasks()).collect()],
            gpu_groups: vec![(0..n).collect()],
            task_plans,
        }
    }

    #[test]
    fn sync_ppo_sums_waves() {
        let topo = build_testbed(Scenario::SingleRegion, &TestbedSpec::default());
        let job = JobConfig::default();
        let wf = RlWorkflow::new(Algo::Ppo, Mode::Sync, ModelSpec::qwen_4b());
        let cm = CostModel::new(&topo, &wf, &job);
        let plan = plan_over(&wf, 64, 8);
        let cost = cm.plan_cost(&plan);
        // Iteration time ≥ generation + max(inference) + max(training).
        let gen = cost.per_task[0].total;
        assert!(cost.iter_time > gen);
        assert!(cost.reshard > 0.0);
        assert!(cost.iter_time.is_finite() && cost.iter_time > 0.0);
    }

    #[test]
    fn async_overlaps_generation() {
        let topo = build_testbed(Scenario::SingleRegion, &TestbedSpec::default());
        let job = JobConfig::default();
        let model = ModelSpec::qwen_4b();
        let sync_wf = RlWorkflow::new(Algo::Grpo, Mode::Sync, model.clone());
        let async_wf = RlWorkflow::new(Algo::Grpo, Mode::Async, model);
        let plan_s = plan_over(&sync_wf, 64, 16);
        let plan_a = plan_over(&async_wf, 64, 16);
        let c_sync = CostModel::new(&topo, &sync_wf, &job).plan_cost(&plan_s);
        let c_async = CostModel::new(&topo, &async_wf, &job).plan_cost(&plan_a);
        // Async overlaps gen with train; with identical plans it should
        // be no slower (sync adds them sequentially).
        assert!(c_async.iter_time <= c_sync.iter_time + c_async.sync);
    }

    #[test]
    fn phi_interpolates_max_and_sum() {
        let topo = build_testbed(Scenario::SingleRegion, &TestbedSpec::default());
        let wf = RlWorkflow::new(Algo::Grpo, Mode::Sync, ModelSpec::qwen_4b());
        let mut job = JobConfig::default();
        job.eta = 1.0;
        let cm = CostModel::new(&topo, &wf, &job);
        assert_eq!(cm.phi(&[1.0, 2.0, 3.0]), 3.0);
        job.eta = 0.0;
        let cm = CostModel::new(&topo, &wf, &job);
        assert_eq!(cm.phi(&[1.0, 2.0, 3.0]), 6.0);
        job.eta = 0.5;
        let cm = CostModel::new(&topo, &wf, &job);
        assert_eq!(cm.phi(&[1.0, 2.0, 3.0]), 0.5 * 3.0 + 0.5 * 6.0);
        assert_eq!(cm.phi(&[]), 0.0);
    }

    #[test]
    fn grpo_cheaper_than_ppo_same_resources() {
        // GRPO has no critic tasks; with tasks sharing the same per-task
        // slice sizes, its iteration is cheaper.
        let topo = build_testbed(Scenario::SingleRegion, &TestbedSpec::default());
        let job = JobConfig::default();
        let model = ModelSpec::qwen_4b();
        let ppo = RlWorkflow::new(Algo::Ppo, Mode::Sync, model.clone());
        let grpo = RlWorkflow::new(Algo::Grpo, Mode::Sync, model);
        let c_ppo = CostModel::new(&topo, &ppo, &job).plan_cost(&plan_over(&ppo, 64, 8));
        let c_grpo = CostModel::new(&topo, &grpo, &job).plan_cost(&plan_over(&grpo, 64, 8));
        assert!(c_grpo.iter_time < c_ppo.iter_time);
    }

    #[test]
    fn wan_scenario_slower_than_single_region() {
        let job = JobConfig::default();
        let model = ModelSpec::qwen_8b();
        // GRPO: 4 tasks × 16 GPUs — each task spans two machines, which
        // are in different regions under Multi-Continent.
        let wf = RlWorkflow::new(Algo::Grpo, Mode::Sync, model);
        let local = build_testbed(Scenario::SingleRegion, &TestbedSpec::default());
        let wan = build_testbed(Scenario::MultiContinent, &TestbedSpec::default());
        let plan = plan_over(&wf, 64, 16);
        let c_local = CostModel::new(&local, &wf, &job).plan_cost(&plan);
        let c_wan = CostModel::new(&wan, &wf, &job).plan_cost(&plan);
        assert!(c_wan.iter_time > c_local.iter_time);
    }

    #[test]
    fn throughput_inverse_of_iter_time() {
        let topo = build_testbed(Scenario::SingleRegion, &TestbedSpec::default());
        let job = JobConfig::default();
        let wf = RlWorkflow::new(Algo::Grpo, Mode::Sync, ModelSpec::qwen_4b());
        let cost = CostModel::new(&topo, &wf, &job).plan_cost(&plan_over(&wf, 64, 16));
        let tp = cost.throughput(&job);
        assert!((tp * cost.iter_time - job.total_samples() as f64).abs() < 1e-6);
    }

    #[test]
    fn bounded_staleness_k0_is_the_synchronous_sum() {
        // k = 0: the staleness cycle forces one full serial lap per
        // step, whatever the queue capacity.
        for cap in [1usize, 2, 8] {
            let p = bounded_staleness_period(10.0, 6.0, 1.0, 0, cap);
            assert!((p - 17.0).abs() < 1e-12, "cap {cap}: {p}");
        }
    }

    #[test]
    fn bounded_staleness_monotone_and_floored() {
        let (g, t, s) = (10.0, 6.0, 1.0);
        let floor = g.max(t + s);
        let mut prev = f64::INFINITY;
        for k in 0..6usize {
            let p = bounded_staleness_period(g, t, s, k, 4);
            assert!(p <= prev + 1e-12, "k {k} regressed: {p} > {prev}");
            assert!(p >= floor - 1e-12, "k {k} below floor: {p}");
            prev = p;
        }
        // Large k and cap: the per-pool bounds dominate.
        assert!((bounded_staleness_period(g, t, s, 100, 100) - floor).abs() < 1e-12);
        // A starved queue (cap clamped to 1) still bounds the period.
        let tight = bounded_staleness_period(g, t, 0.0, 100, 0);
        assert!((tight - g.max(t).max((g + t) / 2.0)).abs() < 1e-12);
    }

    #[test]
    fn stream_costs_match_aggregate_arms() {
        // The async iteration time must be reconstructible from the
        // public stream decomposition (the DES pipeline relies on it).
        let topo = build_testbed(Scenario::SingleRegion, &TestbedSpec::default());
        let job = JobConfig::default();
        for algo in [Algo::Grpo, Algo::Ppo] {
            let wf = RlWorkflow::new(algo, Mode::Async, ModelSpec::qwen_4b());
            let per_task = if algo == Algo::Grpo { 16 } else { 8 };
            let plan = plan_over(&wf, 64, per_task);
            let cm = CostModel::new(&topo, &wf, &job);
            let sc = cm.stream_costs(&plan);
            let want = bounded_staleness_period(
                sc.gen,
                sc.train_side,
                sc.sync,
                job.staleness_bound,
                job.rollout_queue_cap,
            ) + sc.overlap_frac * sc.gen.min(sc.train_side);
            let got = cm.plan_cost(&plan).iter_time;
            assert!(
                (got - want).abs() < 1e-9 * want.max(1.0),
                "{algo:?}: {got} != {want}"
            );
            assert!(sc.gen > 0.0 && sc.train_side > 0.0 && sc.sync >= 0.0);
            // plan_over gives each task disjoint devices.
            assert_eq!(sc.overlap_frac, 0.0);
        }
    }

    #[test]
    fn tighter_staleness_never_speeds_up_a_plan() {
        let topo = build_testbed(Scenario::SingleRegion, &TestbedSpec::default());
        let wf = RlWorkflow::new(Algo::Grpo, Mode::Async, ModelSpec::qwen_4b());
        let plan = plan_over(&wf, 64, 16);
        let mut prev = f64::INFINITY;
        for k in 0..4usize {
            let mut job = JobConfig::default();
            job.staleness_bound = k;
            let t = CostModel::new(&topo, &wf, &job).plan_cost(&plan).iter_time;
            assert!(t <= prev + 1e-12, "k {k}: {t} > {prev}");
            prev = t;
        }
    }

    #[test]
    fn cached_matches_uncached() {
        let topo = build_testbed(Scenario::MultiCountry, &TestbedSpec::default());
        let job = JobConfig::default();
        let wf = RlWorkflow::new(Algo::Grpo, Mode::Sync, ModelSpec::qwen_4b());
        let cm = CostModel::new(&topo, &wf, &job);
        let plan = plan_over(&wf, 64, 16);
        let cache = super::super::cache::CostCache::new();
        let a = cm.plan_cost(&plan);
        let b = cm.plan_cost_cached(&plan, &cache);
        let c = cm.plan_cost_cached(&plan, &cache);
        assert_eq!(a, b);
        assert_eq!(b, c);
        assert_eq!(cache.misses(), wf.n_tasks());
        assert_eq!(cache.hits(), wf.n_tasks());
    }

    #[test]
    fn delta_matches_full_after_mutation() {
        use super::super::dirty::DirtySet;
        let topo = build_testbed(Scenario::MultiCountry, &TestbedSpec::default());
        let job = JobConfig::default();
        let wf = RlWorkflow::new(Algo::Grpo, Mode::Sync, ModelSpec::qwen_4b());
        let cm = CostModel::new(&topo, &wf, &job);
        let plan = plan_over(&wf, 64, 16);
        let cache = super::super::cache::CostCache::new();
        let base = cm.plan_cost_cached(&plan, &cache);
        assert_eq!(cache.misses(), wf.n_tasks());

        // Perturb one task's assignment; only that task is dirty.
        let mut mutant = plan.clone();
        mutant.task_plans[1].assignment.swap(0, 5);
        let delta = cm.plan_cost_delta(&mutant, &base.per_task, &DirtySet::single(1), &cache);
        // Bit-identical to pricing the mutant from scratch (PartialEq
        // on PlanCost compares every f64 exactly).
        assert_eq!(delta, cm.plan_cost(&mutant));
        // Exactly one new per-task cost was computed.
        assert_eq!(cache.misses(), wf.n_tasks() + 1);

        // The scratch forms agree with the owning forms bit-for-bit.
        let mut scratch = Vec::new();
        let it_full = cm.price_cached_into(&mutant, &cache, &mut scratch);
        assert_eq!(it_full.to_bits(), cm.plan_cost(&mutant).iter_time.to_bits());
        assert_eq!(scratch, cm.plan_cost(&mutant).per_task);
        let it_delta =
            cm.price_delta_into(&mutant, &base.per_task, &DirtySet::single(1), &cache, &mut scratch);
        assert_eq!(it_delta.to_bits(), it_full.to_bits());
    }
}
