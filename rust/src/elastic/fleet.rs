//! Live fleet state: the base topology plus the cumulative effect of
//! every applied [`ClusterEvent`], and snapshotting into a concrete
//! [`DeviceTopology`] the schedulers/simulator consume.
//!
//! Snapshots renumber surviving devices `0..k` (the scheduler stack
//! assumes dense ids); the returned map translates snapshot ids back to
//! base ids so plans can be carried across epochs.

use super::events::ClusterEvent;
use crate::topology::DeviceTopology;
use std::collections::BTreeMap;

/// Mutable fleet model over a fixed base topology.
#[derive(Debug, Clone)]
pub struct FleetState {
    base: DeviceTopology,
    /// Machine id → active? (indexed by machine id, which the builders
    /// keep dense; sized to the max machine id + 1).
    active: Vec<bool>,
    /// Base device id → speed multiplier (1.0 = healthy).
    slowdown: Vec<f64>,
    /// Region pair (min, max) → (lat_factor, bw_factor).
    link_scale: BTreeMap<(usize, usize), (f64, f64)>,
    /// Machine id → NIC bandwidth factor (≤ 1) from a transient
    /// [`ClusterEvent::NicDegrade`] burst; absent = healthy. Applies to
    /// every cross-*machine* link touching the machine.
    nic_scale: BTreeMap<usize, f64>,
    /// Checkpoint-store reachability ([`ClusterEvent::CkptOutage`] /
    /// [`ClusterEvent::CkptRestore`]). While `false`, no checkpoint
    /// completes.
    store_up: bool,
    /// Bumped on every applied event; snapshot caches key off it.
    epoch: u64,
}

impl FleetState {
    /// A fully healthy fleet over `base`: every machine active, every
    /// device at full speed, every link at its base rate.
    pub fn new(base: DeviceTopology) -> FleetState {
        let n_machines = base.devices.iter().map(|d| d.machine + 1).max().unwrap_or(0);
        let n = base.n();
        FleetState {
            base,
            active: vec![true; n_machines],
            slowdown: vec![1.0; n],
            link_scale: BTreeMap::new(),
            nic_scale: BTreeMap::new(),
            store_up: true,
            epoch: 0,
        }
    }

    /// The unmodified base topology.
    pub fn base(&self) -> &DeviceTopology {
        &self.base
    }

    /// Monotone epoch counter (one tick per applied event).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Number of currently active machines.
    pub fn active_machines(&self) -> usize {
        self.active.iter().filter(|&&a| a).count()
    }

    /// Whether the checkpoint store is currently reachable.
    pub fn store_up(&self) -> bool {
        self.store_up
    }

    /// Apply one event. Out-of-range indices are ignored (a trace built
    /// for a different testbed cannot corrupt the state).
    pub fn apply(&mut self, event: &ClusterEvent) {
        match *event {
            ClusterEvent::MachinePreempt { machine } | ClusterEvent::MachineLeave { machine } => {
                if let Some(a) = self.active.get_mut(machine) {
                    *a = false;
                }
            }
            ClusterEvent::MachineJoin { machine } => {
                if let Some(a) = self.active.get_mut(machine) {
                    *a = true;
                }
            }
            ClusterEvent::LinkDegrade { ra, rb, lat_factor, bw_factor } => {
                let key = (ra.min(rb), ra.max(rb));
                self.link_scale
                    .insert(key, (lat_factor.max(1.0), bw_factor.clamp(1e-3, 1.0)));
            }
            ClusterEvent::LinkRestore { ra, rb } => {
                self.link_scale.remove(&(ra.min(rb), ra.max(rb)));
            }
            ClusterEvent::StragglerOnset { device, slowdown } => {
                if let Some(s) = self.slowdown.get_mut(device) {
                    *s = slowdown.clamp(0.05, 1.0);
                }
            }
            ClusterEvent::StragglerClear { device } => {
                if let Some(s) = self.slowdown.get_mut(device) {
                    *s = 1.0;
                }
            }
            ClusterEvent::NicDegrade { machine, bw_factor, .. } => {
                if machine < self.active.len() {
                    self.nic_scale.insert(machine, bw_factor.clamp(1e-3, 1.0));
                }
            }
            ClusterEvent::NicRestore { machine } => {
                self.nic_scale.remove(&machine);
            }
            ClusterEvent::CkptOutage { .. } => {
                self.store_up = false;
            }
            ClusterEvent::CkptRestore => {
                self.store_up = true;
            }
            // A task failure changes no fleet state — the *replay*
            // charges its retry stall (and rollback if the retry budget
            // is exhausted); the fleet only ticks its epoch.
            ClusterEvent::TaskFailure { .. } => {}
        }
        self.epoch += 1;
    }

    /// The *post-event fleet hypothesis*: a copy of this fleet with
    /// `event` applied, leaving `self` untouched. Predictive preemption
    /// ([`super::replay::Policy::Preempt`]) snapshots the hypothesis to
    /// pre-warm a plan for the fleet about to exist while the current
    /// fleet keeps executing.
    pub fn apply_hypothetical(&self, event: &ClusterEvent) -> FleetState {
        let mut hypo = self.clone();
        hypo.apply(event);
        hypo
    }

    /// Base device ids currently active.
    pub fn active_device_ids(&self) -> Vec<usize> {
        self.base
            .devices
            .iter()
            .filter(|d| self.active[d.machine])
            .map(|d| d.id)
            .collect()
    }

    /// Materialize the current fleet: a dense sub-topology with link
    /// degradation and straggler slowdowns applied, plus the
    /// snapshot-id → base-id map.
    pub fn snapshot(&self) -> (DeviceTopology, Vec<usize>) {
        let ids = self.active_device_ids();
        let (mut topo, map) = self.base.subset(&ids);
        // Straggler slowdowns.
        for d in topo.devices.iter_mut() {
            d.speed = self.base.devices[map[d.id]].speed * self.slowdown[map[d.id]];
        }
        // Link degradation on cross-region edges.
        if !self.link_scale.is_empty() {
            let n = topo.n();
            for i in 0..n {
                for j in 0..n {
                    if i == j {
                        continue;
                    }
                    let (ri, rj) = (topo.devices[i].region, topo.devices[j].region);
                    if ri == rj {
                        continue;
                    }
                    if let Some(&(lat, bw)) = self.link_scale.get(&(ri.min(rj), ri.max(rj))) {
                        topo.alpha[i][j] *= lat;
                        topo.beta[i][j] *= bw;
                    }
                }
            }
        }
        // Transient NIC bursts: every cross-machine edge touching a
        // degraded machine loses bandwidth (both directions share the
        // NIC; two degraded endpoints compound).
        if !self.nic_scale.is_empty() {
            let n = topo.n();
            for i in 0..n {
                let mi = self.base.devices[map[i]].machine;
                for j in 0..n {
                    if i == j {
                        continue;
                    }
                    let mj = self.base.devices[map[j]].machine;
                    if mi == mj {
                        continue;
                    }
                    let mut f = 1.0f64;
                    if let Some(&s) = self.nic_scale.get(&mi) {
                        f *= s;
                    }
                    if let Some(&s) = self.nic_scale.get(&mj) {
                        f *= s;
                    }
                    if f < 1.0 {
                        topo.beta[i][j] *= f;
                    }
                }
            }
        }
        (topo, map)
    }

    /// Inverse of a snapshot map: base id → snapshot id.
    pub fn base_to_snapshot(map: &[usize]) -> BTreeMap<usize, usize> {
        map.iter().enumerate().map(|(new, &old)| (old, new)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{build_testbed, Scenario, TestbedSpec};

    fn fleet() -> FleetState {
        FleetState::new(build_testbed(Scenario::MultiCountry, &TestbedSpec::default()))
    }

    #[test]
    fn preemption_shrinks_snapshot() {
        let mut f = fleet();
        let (t0, m0) = f.snapshot();
        assert_eq!(t0.n(), 64);
        assert_eq!(m0, (0..64).collect::<Vec<_>>());
        f.apply(&ClusterEvent::MachinePreempt { machine: 0 });
        let (t1, m1) = f.snapshot();
        assert_eq!(t1.n(), 56);
        assert!(m1.iter().all(|&b| f.base().devices[b].machine != 0));
        f.apply(&ClusterEvent::MachineJoin { machine: 0 });
        assert_eq!(f.snapshot().0.n(), 64);
        assert_eq!(f.epoch(), 2);
    }

    #[test]
    fn straggler_slows_effective_flops() {
        let mut f = fleet();
        let before = f.snapshot().0.devices[5].effective_flops();
        f.apply(&ClusterEvent::StragglerOnset { device: 5, slowdown: 0.5 });
        let after = f.snapshot().0.devices[5].effective_flops();
        assert!((after / before - 0.5).abs() < 1e-9);
        f.apply(&ClusterEvent::StragglerClear { device: 5 });
        assert_eq!(f.snapshot().0.devices[5].effective_flops(), before);
    }

    #[test]
    fn link_degrade_scales_cross_region_only() {
        let mut f = fleet();
        let (t0, _) = f.snapshot();
        // Find a cross-region and an intra-region pair.
        let cross = {
            let mut found = None;
            'o: for i in 0..t0.n() {
                for j in 0..t0.n() {
                    if t0.devices[i].region == 0 && t0.devices[j].region == 1 {
                        found = Some((i, j));
                        break 'o;
                    }
                }
            }
            found.unwrap()
        };
        f.apply(&ClusterEvent::LinkDegrade { ra: 0, rb: 1, lat_factor: 2.0, bw_factor: 0.5 });
        let (t1, _) = f.snapshot();
        assert!((t1.lat(cross.0, cross.1) / t0.lat(cross.0, cross.1) - 2.0).abs() < 1e-9);
        assert!((t1.bw(cross.0, cross.1) / t0.bw(cross.0, cross.1) - 0.5).abs() < 1e-9);
        // Same-machine links untouched.
        assert_eq!(t1.lat(0, 1), t0.lat(0, 1));
        f.apply(&ClusterEvent::LinkRestore { ra: 1, rb: 0 });
        let (t2, _) = f.snapshot();
        assert_eq!(t2.lat(cross.0, cross.1), t0.lat(cross.0, cross.1));
    }

    #[test]
    fn nic_burst_scales_cross_machine_bandwidth_only() {
        let mut f = fleet();
        let (t0, _) = f.snapshot();
        f.apply(&ClusterEvent::NicDegrade { machine: 0, bw_factor: 0.25, attempts: 2 });
        let (t1, _) = f.snapshot();
        // Device 0 (machine 0) ↔ device 8 (machine 1): degraded.
        assert!((t1.bw(0, 8) / t0.bw(0, 8) - 0.25).abs() < 1e-9);
        assert!((t1.bw(8, 0) / t0.bw(8, 0) - 0.25).abs() < 1e-9);
        // Intra-machine links untouched; latency untouched.
        assert_eq!(t1.bw(0, 1), t0.bw(0, 1));
        assert_eq!(t1.lat(0, 8), t0.lat(0, 8));
        // Links not touching machine 0 untouched.
        assert_eq!(t1.bw(8, 16), t0.bw(8, 16));
        f.apply(&ClusterEvent::NicRestore { machine: 0 });
        assert_eq!(f.snapshot().0.bw(0, 8), t0.bw(0, 8));
    }

    #[test]
    fn store_outage_toggles_and_task_failure_is_stateless() {
        let mut f = fleet();
        assert!(f.store_up());
        let (t0, m0) = f.snapshot();
        f.apply(&ClusterEvent::CkptOutage { attempts: 1 });
        assert!(!f.store_up());
        f.apply(&ClusterEvent::TaskFailure { device: 3, attempts: 2 });
        // Neither event changes the topology snapshot.
        let (t1, m1) = f.snapshot();
        assert_eq!(m1, m0);
        assert_eq!(t1.n(), t0.n());
        assert_eq!(t1.devices[3].speed, t0.devices[3].speed);
        f.apply(&ClusterEvent::CkptRestore);
        assert!(f.store_up());
        assert_eq!(f.epoch(), 3);
    }

    #[test]
    fn hypothetical_apply_leaves_fleet_untouched() {
        let f = fleet();
        let epoch0 = f.epoch();
        let hypo = f.apply_hypothetical(&ClusterEvent::MachinePreempt { machine: 3 });
        // The hypothesis sees the shrunken fleet...
        assert_eq!(hypo.snapshot().0.n(), 56);
        assert_eq!(hypo.epoch(), epoch0 + 1);
        // ...while the real fleet is unchanged.
        assert_eq!(f.snapshot().0.n(), 64);
        assert_eq!(f.epoch(), epoch0);
        // Applying the event for real matches the hypothesis snapshot.
        let mut real = fleet();
        real.apply(&ClusterEvent::MachinePreempt { machine: 3 });
        assert_eq!(real.snapshot().1, hypo.snapshot().1);
    }

    #[test]
    fn base_to_snapshot_inverts() {
        let mut f = fleet();
        f.apply(&ClusterEvent::MachineLeave { machine: 2 });
        let (_, map) = f.snapshot();
        let inv = FleetState::base_to_snapshot(&map);
        for (new, &old) in map.iter().enumerate() {
            assert_eq!(inv[&old], new);
        }
        assert!(!inv.contains_key(&16)); // machine 2 = devices 16..24
    }
}
