//! Event-driven replanning: repair the incumbent plan against the new
//! fleet snapshot, warm-start the evolutionary search from it under a
//! reduced budget (several independent warm arms run on the parallel
//! evaluation engine — [`crate::scheduler::engine`]), and score
//! candidates with a migration-aware objective
//! (`iter_time + migration_time / horizon`), reusing unchanged per-task
//! cost-model sub-results through the always-on
//! [`crate::costmodel::CostCache`].

use super::anytime::AnytimeConfig;
use crate::costmodel::migration::PrevTask;
use crate::costmodel::{CostModel, MigrationModel};
use crate::plan::parallel::uniform_layer_split;
use crate::plan::{ExecutionPlan, ParallelStrategy, TaskPlan};
use crate::scheduler::ea::{perturbations, EaArm, EaConfig};
use crate::scheduler::engine::{self, SeededArmTask};
use crate::scheduler::levels::{default_task_plans, strategy_feasible};
use crate::scheduler::{Budget, EvalCtx, Scheduler, ShaEaScheduler};
use crate::topology::DeviceTopology;
use crate::util::rng::Rng;
use crate::workflow::{JobConfig, RlWorkflow};
use std::collections::BTreeMap;
use std::sync::Arc;

/// Replanning knobs.
///
/// # Example
///
/// ```
/// use hetrl::elastic::ReplanConfig;
///
/// // A reduced-budget config for a small testbed; everything else
/// // keeps its default.
/// let cfg = ReplanConfig { warm_budget: 60, cold_budget: 120, ..ReplanConfig::default() };
/// assert!(cfg.warm_budget < cfg.cold_budget);
/// assert_eq!(cfg.threads, 1); // replays are sequential by default
/// ```
#[derive(Debug, Clone)]
pub struct ReplanConfig {
    /// Cost-model evaluations for an event-driven (warm) replan.
    pub warm_budget: usize,
    /// Evaluations for a cold search (initial plan / fallback / oracle).
    pub cold_budget: usize,
    /// Iterations over which a migration is amortized in the objective.
    pub horizon_iters: f64,
    /// Perturbed copies of the repaired incumbent injected into each
    /// warm-start arm's population.
    pub seed_mutants: usize,
    /// Independent warm-start arms sharing `warm_budget` (each seeded
    /// with the repaired incumbent + its own mutants and RNG stream).
    /// Fixed per config — NOT tied to `threads` — so the chosen plan is
    /// identical at any thread count.
    pub warm_arms: usize,
    /// Worker threads for warm/cold search (0 = all available cores).
    /// Defaults to 1: replays are bit-reproducible by default, and
    /// cache hit/miss telemetry is exact; the CLI opts into parallelism
    /// via `--threads`.
    pub threads: usize,
    pub migration: MigrationModel,
    pub ea: EaConfig,
    /// Anytime background-search knobs (used by `Policy::Anytime`
    /// replays via [`super::anytime::AnytimeSearch`]; inert otherwise).
    pub anytime: AnytimeConfig,
}

impl Default for ReplanConfig {
    fn default() -> Self {
        ReplanConfig {
            warm_budget: 150,
            cold_budget: 600,
            horizon_iters: 8.0,
            seed_mutants: 6,
            warm_arms: 2,
            threads: 1,
            migration: MigrationModel::default(),
            ea: EaConfig::default(),
            anytime: AnytimeConfig::default(),
        }
    }
}

/// Outcome of one replanning episode.
#[derive(Debug, Clone)]
pub struct ReplanOutcome {
    /// Best plan, in the snapshot's device-id space.
    pub plan: Option<ExecutionPlan>,
    /// Pure predicted iteration time of that plan (seconds).
    pub iter_time: f64,
    /// One-off migration pause the switch costs (seconds).
    pub migration_secs: f64,
    /// Objective the search minimized (iter_time + amortized migration).
    pub objective: f64,
    /// Cost-model evaluations the episode charged (hard-capped by the
    /// configured budget; barrier-merge comparisons add one per hint).
    pub evals: usize,
    /// Whether the warm-started path produced the plan (vs cold search).
    pub warm: bool,
    /// Per-task cost-cache hits during the episode. Exact and
    /// bit-deterministic at any `ReplanConfig::threads`: a racing
    /// duplicate computation still counts one miss, so
    /// `hits + misses` equals the episode's cache lookups.
    pub cache_hits: usize,
    /// Per-task cost-cache misses during the episode — one per distinct
    /// key priced, at any thread count.
    pub cache_misses: usize,
}

/// Translate a plan across id spaces and drop vanished devices.
/// `base_to_new` maps base ids to snapshot ids; `plan` must be in base
/// ids. Tasks whose assignment lost devices get `None` task plans and
/// must be re-placed by the caller.
fn translate(
    plan: &ExecutionPlan,
    base_to_new: &BTreeMap<usize, usize>,
) -> (Vec<Vec<usize>>, Vec<Option<TaskPlan>>) {
    let gpu_groups: Vec<Vec<usize>> = plan
        .gpu_groups
        .iter()
        .map(|g| g.iter().filter_map(|d| base_to_new.get(d).copied()).collect())
        .collect();
    let task_plans: Vec<Option<TaskPlan>> = plan
        .task_plans
        .iter()
        .map(|tp| {
            let assignment: Vec<usize> = tp
                .assignment
                .iter()
                .filter_map(|d| base_to_new.get(d).copied())
                .collect();
            if assignment.len() == tp.assignment.len() {
                Some(TaskPlan { assignment, ..tp.clone() })
            } else {
                None
            }
        })
        .collect();
    (gpu_groups, task_plans)
}

/// Repair an incumbent plan (base ids) against a fleet snapshot:
/// translate ids, keep intact task plans, and re-place tasks that lost
/// devices on their (shrunken) groups. Returns a plan valid under the
/// snapshot, or `None` when the surviving fleet cannot hold the
/// workload in the incumbent's structure.
pub fn repair_plan(
    plan: &ExecutionPlan,
    wf: &RlWorkflow,
    job: &JobConfig,
    topo: &DeviceTopology,
    base_to_new: &BTreeMap<usize, usize>,
    seed: u64,
) -> Option<ExecutionPlan> {
    let (gpu_groups, mut task_plans) = translate(plan, base_to_new);
    if gpu_groups.iter().any(|g| g.is_empty()) {
        return None;
    }
    let broken: Vec<usize> = (0..task_plans.len())
        .filter(|&t| task_plans[t].is_none())
        .collect();
    if !broken.is_empty() {
        // Re-place every task of each broken task's group: colocation
        // memory budgeting is per group, so regenerating group-wise via
        // the Level-4/5 machinery keeps C3 honest.
        let mut rng = Rng::new(seed ^ 0x5EAF00D);
        let regenerated =
            default_task_plans(wf, job, topo, &plan.task_groups, &gpu_groups, &mut rng, false)?;
        let broken_groups: Vec<usize> = broken.iter().map(|&t| plan.group_of_task(t)).collect();
        for (t, tp) in task_plans.iter_mut().enumerate() {
            let gi = plan.group_of_task(t);
            if tp.is_none() || broken_groups.contains(&gi) {
                *tp = Some(regenerated[t].clone());
            }
        }
    }
    let repaired = ExecutionPlan {
        task_groups: plan.task_groups.clone(),
        gpu_groups,
        task_plans: task_plans.into_iter().collect::<Option<Vec<_>>>()?,
    };
    match repaired.validate(wf, topo, job) {
        Ok(()) => Some(repaired),
        Err(_) => repair_rebuild_all(&repaired, wf, job, topo, seed),
    }
}

/// Last-resort repair: keep the grouping structure, rebuild every task
/// plan from scratch on the surviving groups.
fn repair_rebuild_all(
    plan: &ExecutionPlan,
    wf: &RlWorkflow,
    job: &JobConfig,
    topo: &DeviceTopology,
    seed: u64,
) -> Option<ExecutionPlan> {
    let mut rng = Rng::new(seed ^ 0xBADCAFE);
    let task_plans =
        default_task_plans(wf, job, topo, &plan.task_groups, &plan.gpu_groups, &mut rng, false)?;
    let rebuilt = ExecutionPlan {
        task_groups: plan.task_groups.clone(),
        gpu_groups: plan.gpu_groups.clone(),
        task_plans,
    };
    rebuilt.validate(wf, topo, job).ok()?;
    Some(rebuilt)
}

/// Pick a memory-feasible fallback strategy for one task on `devs`
/// (most-sharded first). Used by tests and kept public for reuse.
pub fn fallback_task_plan(
    wf: &RlWorkflow,
    job: &JobConfig,
    topo: &DeviceTopology,
    t: usize,
    devs: &[usize],
) -> Option<TaskPlan> {
    let task = &wf.tasks[t];
    let mut strategies = ParallelStrategy::enumerate(devs.len(), task.model.nl, 0.0);
    strategies.sort_by_key(|s| std::cmp::Reverse(s.tp * s.pp));
    let ordered = topo.locality_order(devs);
    strategies
        .into_iter()
        .filter(|&s| strategy_feasible(task, job, topo, devs, s))
        .map(|s| TaskPlan {
            layer_split: uniform_layer_split(task.model.nl, s.pp),
            dp_shares: vec![1.0 / s.dp as f64; s.dp],
            strategy: s,
            assignment: ordered[..s.degree()].to_vec(),
        })
        .next()
}

/// Event-driven replanner: owns the warm-start policy and seeds.
#[derive(Debug, Clone)]
pub struct Replanner {
    /// Replanning knobs (budgets, arms, migration model, threads).
    pub cfg: ReplanConfig,
    seed: u64,
    episodes: u64,
}

impl Replanner {
    /// A replanner whose episode seeds all derive from `seed` (each
    /// [`Self::cold_plan`]/[`Self::replan`] episode advances a counter,
    /// so repeated episodes differ deterministically).
    pub fn new(seed: u64, cfg: ReplanConfig) -> Replanner {
        Replanner { cfg, seed, episodes: 0 }
    }

    fn next_seed(&mut self) -> u64 {
        self.episodes += 1;
        self.seed
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.episodes.wrapping_mul(1442695040888963407))
    }

    /// Advance the episode counter and hand out the next episode seed —
    /// lets sibling search drivers (the checkpoint-interval search in
    /// [`super::recovery`]) draw their arm seeds from the same
    /// deterministic stream the warm/cold episodes use.
    pub(crate) fn next_episode_seed(&mut self) -> u64 {
        self.next_seed()
    }

    /// Cold search (initial plan, oracle, or warm-path fallback): a full
    /// multi-level SHA-EA run, no migration penalty.
    pub fn cold_plan(
        &mut self,
        topo: &DeviceTopology,
        wf: &RlWorkflow,
        job: &JobConfig,
    ) -> ReplanOutcome {
        let seed = self.next_seed();
        // An empty snapshot (every machine lost) has no plan; searching
        // it is undefined in the level machinery, so the degraded
        // replay path gets a well-defined "no plan" outcome instead.
        if topo.n() == 0 {
            return ReplanOutcome {
                plan: None,
                iter_time: f64::INFINITY,
                objective: f64::INFINITY,
                migration_secs: 0.0,
                evals: 0,
                warm: false,
                cache_hits: 0,
                cache_misses: 0,
            };
        }
        let mut sched = ShaEaScheduler::with_threads(seed, self.cfg.threads);
        let out = sched.schedule(topo, wf, job, Budget::evals(self.cfg.cold_budget));
        ReplanOutcome {
            iter_time: out.cost,
            objective: out.cost,
            migration_secs: 0.0,
            evals: out.evals,
            warm: false,
            cache_hits: out.cache_hits,
            cache_misses: out.cache_misses,
            plan: out.plan,
        }
    }

    /// React to a fleet change: repair the incumbent (base-id space,
    /// translated through `base_to_new`), warm-start the EA from it
    /// under `warm_budget`, and minimize the migration-aware objective.
    /// Falls back to a cold search when repair is impossible.
    pub fn replan(
        &mut self,
        topo: &DeviceTopology,
        wf: &RlWorkflow,
        job: &JobConfig,
        incumbent_base: &ExecutionPlan,
        base_to_new: &BTreeMap<usize, usize>,
    ) -> ReplanOutcome {
        let seed = self.next_seed();
        // Surviving shard placement of the incumbent (snapshot ids).
        let prev = prev_placement(incumbent_base, base_to_new);

        let repaired = repair_plan(incumbent_base, wf, job, topo, base_to_new, seed);
        let Some(repaired) = repaired else {
            // Surviving fleet can't hold the incumbent's structure —
            // cold search, migration still charged against the result.
            let mut out = self.cold_plan(topo, wf, job);
            if let Some(plan) = &out.plan {
                out.migration_secs =
                    self.cfg.migration.migration_time(topo, wf, job, &prev, plan);
                out.objective =
                    out.iter_time + out.migration_secs / self.cfg.horizon_iters.max(1.0);
            }
            return out;
        };

        let mm = self.cfg.migration;
        let horizon = self.cfg.horizon_iters.max(1.0);
        let prev_for_penalty = prev.clone();
        let mut ctx = EvalCtx::new(topo, wf, job, Budget::evals(self.cfg.warm_budget));
        ctx.penalty = Some(Arc::new(move |plan: &ExecutionPlan| {
            mm.migration_time(topo, wf, job, &prev_for_penalty, plan) / horizon
        }));

        // Warm arms: the incumbent's Level-1/2 structure, each arm's
        // population seeded with the repaired plan plus its own light
        // perturbations of it, each on its own worker/RNG stream. The
        // arm count and per-arm quotas are fixed by the config, so the
        // chosen plan is identical at any thread count.
        let grouping = repaired.task_groups.clone();
        let sizes: Vec<usize> = repaired.gpu_groups.iter().map(|g| g.len()).collect();
        let n_arms = self.cfg.warm_arms.max(1);
        let quotas = engine::split_quota(self.cfg.warm_budget, n_arms, 1);
        let threads = engine::resolve_threads(self.cfg.threads);
        let tasks: Vec<SeededArmTask> = (0..n_arms)
            .map(|k| {
                let arm_seed =
                    seed.wrapping_add((k as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
                let mut seeds = vec![repaired.clone()];
                seeds.extend(perturbations(&repaired, self.cfg.seed_mutants, arm_seed));
                SeededArmTask {
                    key: (0, k),
                    arm: EaArm::new(
                        grouping.clone(),
                        sizes.clone(),
                        self.cfg.ea.clone(),
                        arm_seed,
                    ),
                    quota: quotas[k],
                    seeds,
                }
            })
            .collect();
        engine::run_seeded_rung(&mut ctx, tasks, threads);

        let migration_secs = ctx
            .best_plan
            .as_ref()
            .map(|p| mm.migration_time(topo, wf, job, &prev, p))
            .unwrap_or(0.0);
        let cache_hits = ctx.cache.hits();
        let cache_misses = ctx.cache.misses();
        let iter_time = ctx
            .best_plan
            .as_ref()
            .map(|p| CostModel::new(topo, wf, job).plan_cost(p).iter_time)
            .unwrap_or(f64::INFINITY);
        let out = ctx.outcome();
        ReplanOutcome {
            iter_time,
            objective: out.cost,
            migration_secs,
            evals: out.evals,
            warm: true,
            cache_hits,
            cache_misses,
            plan: out.plan,
        }
    }

    /// [`Self::replan`] plus the **barrier merge** at an event barrier:
    /// the warm replan runs *exactly* as it would without a background
    /// service (same arms, same RNG streams, same budget), then each
    /// hint — the anytime incumbent first, the predictive-preemption
    /// hypothesis plan second (pass `None` when the predicted event did
    /// not actually fire) — is repaired against the post-event snapshot,
    /// re-costed with the migration-aware objective from the *actual*
    /// surviving placement, and adopted iff strictly better than the
    /// best merged so far. Each surviving hint charges one comparison
    /// evaluation. With equal pre-event state the anytime and preempt
    /// policies are therefore never worse than the warm policy at a
    /// barrier.
    pub fn replan_with_anytime(
        &mut self,
        topo: &DeviceTopology,
        wf: &RlWorkflow,
        job: &JobConfig,
        incumbent_base: &ExecutionPlan,
        anytime_base: Option<&ExecutionPlan>,
        hypothesis_base: Option<&ExecutionPlan>,
        base_to_new: &BTreeMap<usize, usize>,
    ) -> ReplanOutcome {
        let mut out = self.replan(topo, wf, job, incumbent_base, base_to_new);
        if anytime_base.is_none() && hypothesis_base.is_none() {
            return out;
        }
        let prev = prev_placement(incumbent_base, base_to_new);
        let horizon = self.cfg.horizon_iters.max(1.0);
        // Fixed merge order: anytime incumbent, then hypothesis plan —
        // with strict-improvement adoption the order only breaks exact
        // ties, resolving them toward the longer-lived incumbent.
        for (slot, hint) in [anytime_base, hypothesis_base].into_iter().enumerate() {
            let Some(hint) = hint else { continue };
            let merge_seed = self.seed
                ^ self.episodes.wrapping_mul(0xA11F_1ED5)
                ^ (slot as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
            let Some(candidate) = repair_plan(hint, wf, job, topo, base_to_new, merge_seed)
            else {
                continue;
            };
            if candidate.validate(wf, topo, job).is_err() {
                continue;
            }
            let iter_time = CostModel::new(topo, wf, job).plan_cost(&candidate).iter_time;
            if !iter_time.is_finite() {
                continue;
            }
            let migration_secs =
                self.cfg.migration.migration_time(topo, wf, job, &prev, &candidate);
            let objective = iter_time + migration_secs / horizon;
            out.evals += 1; // the barrier comparison charges one evaluation
            if objective < out.objective {
                out.plan = Some(candidate);
                out.iter_time = iter_time;
                out.migration_secs = migration_secs;
                out.objective = objective;
            }
        }
        out
    }
}

/// Surviving shard placement of a base-id incumbent under a snapshot
/// translation — the single source both the replay driver and the
/// replanner charge migration from.
pub fn prev_placement(
    incumbent_base: &ExecutionPlan,
    base_to_new: &BTreeMap<usize, usize>,
) -> Vec<PrevTask> {
    PrevTask::from_plan(incumbent_base, |d| base_to_new.get(&d).copied())
}

/// Translate a snapshot-space plan back into base ids so it can serve
/// as the incumbent for the next epoch.
pub fn plan_to_base(plan: &ExecutionPlan, snapshot_to_base: &[usize]) -> ExecutionPlan {
    ExecutionPlan {
        task_groups: plan.task_groups.clone(),
        gpu_groups: plan
            .gpu_groups
            .iter()
            .map(|g| g.iter().map(|&d| snapshot_to_base[d]).collect())
            .collect(),
        task_plans: plan
            .task_plans
            .iter()
            .map(|tp| TaskPlan {
                assignment: tp.assignment.iter().map(|&d| snapshot_to_base[d]).collect(),
                ..tp.clone()
            })
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::elastic::events::ClusterEvent;
    use crate::elastic::fleet::FleetState;
    use crate::topology::{build_testbed, Scenario, TestbedSpec};
    use crate::workflow::{Algo, Mode, ModelSpec};

    fn small_cfg() -> ReplanConfig {
        ReplanConfig {
            warm_budget: 60,
            cold_budget: 120,
            seed_mutants: 3,
            ..ReplanConfig::default()
        }
    }

    fn setup() -> (RlWorkflow, FleetState, JobConfig) {
        (
            RlWorkflow::new(Algo::Grpo, Mode::Sync, ModelSpec::qwen_4b()),
            FleetState::new(build_testbed(Scenario::MultiCountry, &TestbedSpec::default())),
            JobConfig::tiny(),
        )
    }

    #[test]
    fn repair_survives_machine_loss() {
        let (wf, mut fleet, job) = setup();
        let (topo0, map0) = fleet.snapshot();
        let mut rp = Replanner::new(1, small_cfg());
        let cold = rp.cold_plan(&topo0, &wf, &job);
        let plan0 = cold.plan.expect("initial plan");
        let base = plan_to_base(&plan0, &map0);

        fleet.apply(&ClusterEvent::MachinePreempt { machine: 1 });
        let (topo1, map1) = fleet.snapshot();
        let b2n = FleetState::base_to_snapshot(&map1);
        let repaired = repair_plan(&base, &wf, &job, &topo1, &b2n, 9);
        if let Some(p) = repaired {
            p.validate(&wf, &topo1, &job).unwrap();
        }
    }

    #[test]
    fn warm_replan_yields_valid_plan_and_uses_cache() {
        let (wf, mut fleet, job) = setup();
        let (topo0, map0) = fleet.snapshot();
        let mut rp = Replanner::new(5, small_cfg());
        let cold = rp.cold_plan(&topo0, &wf, &job);
        let base = plan_to_base(&cold.plan.expect("plan"), &map0);

        fleet.apply(&ClusterEvent::MachinePreempt { machine: 2 });
        fleet.apply(&ClusterEvent::LinkDegrade {
            ra: 0,
            rb: 1,
            lat_factor: 2.0,
            bw_factor: 0.4,
        });
        let (topo1, map1) = fleet.snapshot();
        let b2n = FleetState::base_to_snapshot(&map1);
        let out = rp.replan(&topo1, &wf, &job, &base, &b2n);
        let plan = out.plan.expect("replanned plan");
        plan.validate(&wf, &topo1, &job).unwrap();
        assert!(out.iter_time.is_finite());
        assert!(out.objective >= out.iter_time - 1e-9);
        // Quota-based warm arms make the budget a hard cap (injections
        // used to overrun it by up to 2 evals).
        assert!(out.evals <= small_cfg().warm_budget, "overran: {}", out.evals);
        assert!(out.cache_hits > 0, "warm search should reuse task costs");
    }

    #[test]
    fn objective_is_iter_time_plus_amortized_migration() {
        let (wf, mut fleet, job) = setup();
        let (topo0, map0) = fleet.snapshot();
        let mut rp = Replanner::new(11, small_cfg());
        let base = plan_to_base(&rp.cold_plan(&topo0, &wf, &job).plan.unwrap(), &map0);
        fleet.apply(&ClusterEvent::MachinePreempt { machine: 3 });
        let (topo1, map1) = fleet.snapshot();
        let b2n = FleetState::base_to_snapshot(&map1);
        let out = rp.replan(&topo1, &wf, &job, &base, &b2n);
        assert!(out.plan.is_some());
        let horizon = rp.cfg.horizon_iters;
        let want = out.iter_time + out.migration_secs / horizon;
        assert!(
            (out.objective - want).abs() < 1e-9 * want.max(1.0),
            "objective {} != iter {} + mig {}/{horizon}",
            out.objective,
            out.iter_time,
            out.migration_secs
        );
    }

    #[test]
    fn anytime_merge_never_worse_than_plain_warm_replan() {
        let (wf, mut fleet, job) = setup();
        let (topo0, map0) = fleet.snapshot();
        let mk = || Replanner::new(23, small_cfg());
        let base = {
            let mut rp = mk();
            plan_to_base(&rp.cold_plan(&topo0, &wf, &job).plan.unwrap(), &map0)
        };
        fleet.apply(&ClusterEvent::MachinePreempt { machine: 2 });
        let (topo1, map1) = fleet.snapshot();
        let b2n = FleetState::base_to_snapshot(&map1);
        let warm = {
            let mut rp = mk();
            let _ = rp.cold_plan(&topo0, &wf, &job); // same episode counter
            rp.replan(&topo1, &wf, &job, &base, &b2n)
        };
        // Hint = the aged incumbent itself: the merge must charge one
        // comparison eval and never pick a worse objective.
        let merged = {
            let mut rp = mk();
            let _ = rp.cold_plan(&topo0, &wf, &job);
            rp.replan_with_anytime(&topo1, &wf, &job, &base, Some(&base), None, &b2n)
        };
        assert!(
            merged.objective <= warm.objective + 1e-12,
            "merge regressed: {} vs {}",
            merged.objective,
            warm.objective
        );
        // The comparison eval is charged only when the hint survives
        // repair; either way the count never drops below plain warm.
        assert!(
            merged.evals == warm.evals || merged.evals == warm.evals + 1,
            "evals {} vs warm {}",
            merged.evals,
            warm.evals
        );
        merged.plan.expect("plan").validate(&wf, &topo1, &job).unwrap();
    }

    #[test]
    fn three_way_merge_never_worse_and_charges_per_hint() {
        let (wf, mut fleet, job) = setup();
        let (topo0, map0) = fleet.snapshot();
        let mk = || Replanner::new(31, small_cfg());
        let base = {
            let mut rp = mk();
            plan_to_base(&rp.cold_plan(&topo0, &wf, &job).plan.unwrap(), &map0)
        };
        fleet.apply(&ClusterEvent::MachinePreempt { machine: 4 });
        let (topo1, map1) = fleet.snapshot();
        let b2n = FleetState::base_to_snapshot(&map1);
        let two_way = {
            let mut rp = mk();
            let _ = rp.cold_plan(&topo0, &wf, &job);
            rp.replan_with_anytime(&topo1, &wf, &job, &base, Some(&base), None, &b2n)
        };
        // Adding a hypothesis hint can only charge more comparison
        // evals and can never pick a worse objective.
        let three_way = {
            let mut rp = mk();
            let _ = rp.cold_plan(&topo0, &wf, &job);
            rp.replan_with_anytime(&topo1, &wf, &job, &base, Some(&base), Some(&base), &b2n)
        };
        assert!(
            three_way.objective <= two_way.objective + 1e-12,
            "hypothesis hint regressed the merge: {} vs {}",
            three_way.objective,
            two_way.objective
        );
        assert!(
            three_way.evals >= two_way.evals && three_way.evals <= two_way.evals + 1,
            "evals {} vs {}",
            three_way.evals,
            two_way.evals
        );
        three_way.plan.expect("plan").validate(&wf, &topo1, &job).unwrap();
    }

    #[test]
    fn replan_deterministic_for_seed() {
        let (wf, mut fleet, job) = setup();
        let (topo0, map0) = fleet.snapshot();
        let mk = || Replanner::new(13, small_cfg());
        let base = {
            let mut rp = mk();
            plan_to_base(&rp.cold_plan(&topo0, &wf, &job).plan.unwrap(), &map0)
        };
        fleet.apply(&ClusterEvent::MachinePreempt { machine: 1 });
        let (topo1, map1) = fleet.snapshot();
        let b2n = FleetState::base_to_snapshot(&map1);
        let run = || {
            let mut rp = mk();
            let _ = rp.cold_plan(&topo0, &wf, &job); // advance episode ctr identically
            rp.replan(&topo1, &wf, &job, &base, &b2n)
        };
        let a = run();
        let b = run();
        assert_eq!(a.plan, b.plan);
        assert_eq!(a.objective, b.objective);
        assert_eq!(a.migration_secs, b.migration_secs);
        assert_eq!(a.evals, b.evals);
    }
}
