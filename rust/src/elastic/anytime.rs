//! Anytime background search: keep improving the plan *between*
//! cluster events instead of only reacting at them — and, with advance
//! notice, search *through* them.
//!
//! The event-driven [`super::replan::Replanner`] closes part of the
//! static→oracle gap, but its search stops when the barrier clears —
//! during the (often long) quiet stretches between events the scheduler
//! sits idle while the fleet keeps executing a possibly mediocre plan.
//! This module models the asynchronous-RL insight (overlap optimization
//! with execution) applied to plan search itself:
//!
//! * **Allowance, not wall-clock** — "spare controller cycles" are an
//!   eval allowance accrued per *simulated* second of training
//!   ([`AnytimeConfig::evals_per_sim_sec`], capped per step by
//!   [`AnytimeConfig::max_step_evals`]). The budget is charged through
//!   the engine's shared [`crate::scheduler::EvalLedger`] in sim-time
//!   units, never wall-clock, so a replay remains a pure function of
//!   `(scenario, spec, wf, job, policy, cfg, seed)` and the determinism
//!   contract (same seed ⇒ bit-identical replay at any thread count)
//!   extends to the background search.
//! * **Persistent warm arms** — at every event barrier the service is
//!   [`AnytimeSearch::reseed`]ed from the post-event plan: a fixed
//!   number of [`EaArm`]s is rebuilt around the plan's Level-1/2
//!   structure, their populations seeded with the plan plus per-arm
//!   [`perturbations`]. Between events the arms' populations persist
//!   and keep evolving, one [`AnytimeSearch::step`] per replayed
//!   iteration on the scoped-worker engine
//!   ([`crate::scheduler::engine::run_seeded_rung`]).
//! * **Migration-aware objective** — candidates are scored as
//!   `iter_time + migration_time(running → candidate) / horizon`
//!   against the *currently executing* plan, so the background search
//!   cannot chase marginally-faster plans that would cost terabytes of
//!   resharding to adopt. The incumbent only ever improves within an
//!   inter-event window (monotone non-increasing objective).
//! * **Predictive preemption (the hypothesis incumbent)** — when an
//!   upcoming machine-loss event carries advance notice
//!   ([`super::events::TraceEvent::notice_secs`]), the replay driver
//!   [`AnytimeSearch::prime_hypothesis`]s a **second incumbent**
//!   searched against the *post-event fleet hypothesis*
//!   ([`super::fleet::FleetState::apply_hypothetical`]). Each step's
//!   allowance is then split deterministically between the two
//!   incumbents ([`crate::scheduler::engine::split_allowance`]:
//!   primary-biased halves that sum exactly to the step quota), so the
//!   barrier merge can start from a plan already shaped for the fleet
//!   about to exist, not the one that just died.
//! * **Barrier merge** — at the next event the replay hands the
//!   incumbent(s) (translated to base ids) to
//!   [`super::replan::Replanner::replan_with_anytime`], which runs the
//!   ordinary warm replan unchanged and adopts the anytime incumbent —
//!   and, when the predicted event actually fired, the pre-warmed
//!   hypothesis plan — only if its migration-aware objective against
//!   the post-event fleet is strictly better. Unspent allowance is
//!   forfeited at the barrier (the controller is busy replanning).
//!
//! Exposed as `hetrl replay --policy anytime` and `--policy preempt`
//! (both inside `--policy all`), compared in `benches/fig11_elastic.rs`,
//! and property-tested in `tests/prop_anytime.rs` /
//! `tests/prop_preempt.rs`.

use super::replan::ReplanConfig;
use crate::costmodel::{CostCache, PrevTask};
use crate::plan::ExecutionPlan;
use crate::scheduler::ea::{perturbations, EaArm};
use crate::scheduler::engine::{self, SeededArmTask};
use crate::scheduler::{Budget, EvalCtx};
use crate::topology::DeviceTopology;
use crate::workflow::{JobConfig, RlWorkflow};
use std::sync::Arc;

/// Anytime background-search knobs (nested in
/// [`super::replan::ReplanConfig`]).
///
/// # Example
///
/// ```
/// use hetrl::elastic::{AnytimeConfig, ReplanConfig};
///
/// // Double the spare-cycle allowance, keep every other default.
/// let cfg = ReplanConfig {
///     anytime: AnytimeConfig { evals_per_sim_sec: 1.0, ..AnytimeConfig::default() },
///     ..ReplanConfig::default()
/// };
/// assert_eq!(cfg.anytime.evals_per_sim_sec, 1.0);
/// assert!(cfg.anytime.max_step_evals > 0);
/// ```
#[derive(Debug, Clone, Copy)]
pub struct AnytimeConfig {
    /// Cost-model evaluations the controller can afford per *simulated*
    /// second of training — the spare-cycle allowance. Accounted in
    /// sim-time so replays stay deterministic.
    pub evals_per_sim_sec: f64,
    /// Hard cap on evaluations spent in one between-event step (the
    /// primary and hypothesis incumbents *combined*).
    pub max_step_evals: usize,
    /// Independent background arms (each on its own RNG stream and,
    /// when `ReplanConfig::threads` > 1, its own worker).
    pub arms: usize,
    /// Perturbed copies of the incumbent seeded per arm at reseed.
    pub seed_mutants: usize,
}

impl Default for AnytimeConfig {
    fn default() -> Self {
        AnytimeConfig {
            evals_per_sim_sec: 0.5,
            max_step_evals: 64,
            arms: 2,
            seed_mutants: 3,
        }
    }
}

/// What one background step did.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AnytimeStep {
    /// Evaluations spent on the primary incumbent this step
    /// (`evals + hypothesis_evals` ≤ the accrued allowance and
    /// ≤ [`AnytimeConfig::max_step_evals`]).
    pub evals: usize,
    /// Evaluations spent on the post-event hypothesis incumbent this
    /// step (0 unless a noticed machine loss is pending).
    pub hypothesis_evals: usize,
    /// Cost-cache hits for the step (exact at any worker-thread count:
    /// the sharded cache charges a racing duplicate computation as one
    /// miss plus hits for the losers).
    pub cache_hits: usize,
    /// Cost-cache misses for the step — one per distinct key priced,
    /// at any thread count.
    pub cache_misses: usize,
    /// Primary incumbent objective after the step: `iter_time` +
    /// amortized migration from the running plan (∞ when no incumbent
    /// exists).
    pub incumbent_cost: f64,
    /// Hypothesis incumbent objective after the step (∞ when no
    /// hypothesis is primed).
    pub hypothesis_cost: f64,
}

impl AnytimeStep {
    fn idle(incumbent_cost: f64, hypothesis_cost: f64) -> AnytimeStep {
        AnytimeStep {
            evals: 0,
            hypothesis_evals: 0,
            cache_hits: 0,
            cache_misses: 0,
            incumbent_cost,
            hypothesis_cost,
        }
    }
}

/// The background anytime-search service owned by a `Policy::Anytime`
/// or `Policy::Preempt` replay. The primary incumbent lives in the
/// *snapshot* id space of the current epoch; the hypothesis incumbent
/// lives in the id space of the *hypothetical post-event* snapshot. The
/// replay driver translates both across epochs at barriers.
pub struct AnytimeSearch {
    cfg: ReplanConfig,
    seed: u64,
    /// Bumped at every [`Self::reseed`] (event barrier).
    epochs: u64,
    /// Fractional eval allowance accrued but not yet spent this epoch.
    carry: f64,
    /// Lifetime allowance ever accrued (telemetry; `spent ≤ accrued`).
    accrued: f64,
    spent: usize,
    /// Background arms with persistent populations (current epoch).
    arms: Vec<EaArm>,
    /// Per-arm seed plans still to inject (drained across subsequent
    /// steps as each arm's quota affords, so a starved arm keeps its
    /// warm-start seeds until the allowance catches up).
    pending: Vec<Vec<ExecutionPlan>>,
    /// The plan the fleet is executing this epoch, and its shard view
    /// (identity translation — same snapshot space).
    running: Option<ExecutionPlan>,
    prev: Vec<PrevTask>,
    incumbent: Option<ExecutionPlan>,
    incumbent_cost: f64,
    /// Per-epoch cost memo shared across steps (cleared at reseed:
    /// a new snapshot invalidates every cached per-task cost).
    cache: Arc<CostCache>,
    /// Identity of the predicted event the hypothesis targets (the
    /// replay driver's trace index); `None` = no hypothesis primed.
    hyp_key: Option<u64>,
    /// Hypothesis arms, evolving against the post-event snapshot.
    hyp_arms: Vec<EaArm>,
    hyp_pending: Vec<Vec<ExecutionPlan>>,
    /// Surviving placement of the running plan under the hypothetical
    /// snapshot — what the hypothesis objective charges migration from.
    hyp_prev: Vec<PrevTask>,
    hyp_incumbent: Option<ExecutionPlan>,
    hyp_cost: f64,
    /// Hypothesis cost memo: keyed to the hypothetical snapshot, so it
    /// is dropped whenever the predicted event changes.
    hyp_cache: Arc<CostCache>,
}

/// Run one seeded rung of `arms` under `quota` evaluations against
/// `topo`, migration-penalized from `prev`, improving `incumbent` /
/// `incumbent_cost` in place (strict improvements only). The shared
/// unit under both the primary and the hypothesis incumbent; per-arm
/// quotas come from [`engine::split_quota`], so the outcome is
/// bit-identical at any thread count. Returns
/// `(spent, cache_hits, cache_misses)`.
#[allow(clippy::too_many_arguments)]
fn evolve_incumbent(
    cfg: &ReplanConfig,
    topo: &DeviceTopology,
    wf: &RlWorkflow,
    job: &JobConfig,
    quota: usize,
    arms: &mut Vec<EaArm>,
    pending: &mut Vec<Vec<ExecutionPlan>>,
    prev: &[PrevTask],
    cache: &Arc<CostCache>,
    incumbent: &mut Option<ExecutionPlan>,
    incumbent_cost: &mut f64,
) -> (usize, usize, usize) {
    if quota == 0 || arms.is_empty() {
        return (0, 0, 0);
    }
    let mut ctx = EvalCtx::new(topo, wf, job, Budget::evals(quota));
    ctx.cache = Arc::clone(cache);
    // Only strict improvements over the incumbent count.
    ctx.best_cost = *incumbent_cost;
    let mm = cfg.migration;
    let horizon = cfg.horizon_iters.max(1.0);
    let prev_cl = prev.to_vec();
    ctx.penalty = Some(Arc::new(move |p: &ExecutionPlan| {
        mm.migration_time(topo, wf, job, &prev_cl, p) / horizon
    }));
    let hits0 = ctx.cache.hits();
    let misses0 = ctx.cache.misses();

    let quotas = engine::split_quota(quota, arms.len(), 1);
    let threads = engine::resolve_threads(cfg.threads);
    let taken = std::mem::take(arms);
    let mut pend = std::mem::take(pending);
    pend.resize_with(taken.len(), Vec::new);
    // Hand each arm only the seeds its quota can inject this step; the
    // rest stay pending so a starved arm still warm-starts once the
    // allowance catches up (quotas are budget-derived, so this split is
    // deterministic at any thread count).
    let mut kept: Vec<Vec<ExecutionPlan>> = Vec::with_capacity(taken.len());
    let tasks: Vec<SeededArmTask> = taken
        .into_iter()
        .zip(pend)
        .enumerate()
        .map(|(k, (arm, mut seeds))| {
            let rest = seeds.split_off(quotas[k].min(seeds.len()));
            kept.push(rest);
            SeededArmTask { key: (0, k), arm, quota: quotas[k], seeds }
        })
        .collect();
    let runs = engine::run_seeded_rung(&mut ctx, tasks, threads);
    *arms = runs.into_iter().map(|r| r.arm).collect();
    *pending = kept;

    let spent = ctx.ledger.spent();
    if ctx.best_cost < *incumbent_cost {
        if let Some(p) = ctx.best_plan.take() {
            *incumbent_cost = ctx.best_cost;
            *incumbent = Some(p);
        }
    }
    (
        spent,
        ctx.cache.hits().saturating_sub(hits0),
        ctx.cache.misses().saturating_sub(misses0),
    )
}

/// Build a fresh set of background arms around `plan`'s Level-1/2
/// structure, each arm's pending list seeded with the plan plus its
/// own perturbations. `arm_seed` maps the arm index to its RNG stream
/// — the only thing that differs between the primary and hypothesis
/// arm sets.
fn build_arms(
    cfg: &ReplanConfig,
    plan: &ExecutionPlan,
    arm_seed: impl Fn(u64) -> u64,
) -> (Vec<EaArm>, Vec<Vec<ExecutionPlan>>) {
    let grouping = plan.task_groups.clone();
    let sizes: Vec<usize> = plan.gpu_groups.iter().map(|g| g.len()).collect();
    let mut arms = Vec::new();
    let mut pending = Vec::new();
    for k in 0..cfg.anytime.arms.max(1) {
        let seed = arm_seed(k as u64);
        arms.push(EaArm::new(grouping.clone(), sizes.clone(), cfg.ea.clone(), seed));
        let mut seeds = vec![plan.clone()];
        seeds.extend(perturbations(plan, cfg.anytime.seed_mutants, seed));
        pending.push(seeds);
    }
    (arms, pending)
}

impl AnytimeSearch {
    /// Create an idle service; [`Self::reseed`] arms it.
    pub fn new(seed: u64, cfg: ReplanConfig) -> AnytimeSearch {
        AnytimeSearch {
            cfg,
            seed,
            epochs: 0,
            carry: 0.0,
            accrued: 0.0,
            spent: 0,
            arms: Vec::new(),
            pending: Vec::new(),
            running: None,
            prev: Vec::new(),
            incumbent: None,
            incumbent_cost: f64::INFINITY,
            cache: Arc::new(CostCache::new()),
            hyp_key: None,
            hyp_arms: Vec::new(),
            hyp_pending: Vec::new(),
            hyp_prev: Vec::new(),
            hyp_incumbent: None,
            hyp_cost: f64::INFINITY,
            hyp_cache: Arc::new(CostCache::new()),
        }
    }

    /// Current primary incumbent (snapshot space) and its objective.
    pub fn incumbent(&self) -> Option<(&ExecutionPlan, f64)> {
        self.incumbent.as_ref().map(|p| (p, self.incumbent_cost))
    }

    /// Current hypothesis incumbent (in the *hypothetical post-event*
    /// snapshot space) and its objective, when one is primed.
    pub fn hypothesis(&self) -> Option<(&ExecutionPlan, f64)> {
        self.hyp_incumbent.as_ref().map(|p| (p, self.hyp_cost))
    }

    /// Identity of the predicted event the current hypothesis targets
    /// (`None` = no hypothesis primed).
    pub fn hypothesis_key(&self) -> Option<u64> {
        self.hyp_key
    }

    /// Background evaluations spent over the service's lifetime
    /// (primary and hypothesis combined).
    pub fn spent(&self) -> usize {
        self.spent
    }

    /// Allowance ever accrued (`spent() ≤ accrued()` always holds).
    pub fn accrued(&self) -> f64 {
        self.accrued
    }

    /// Epochs this service has seen (one per [`Self::reseed`]).
    pub fn epochs(&self) -> u64 {
        self.epochs
    }

    /// Start a new epoch at an event barrier: the chosen post-event
    /// plan (with `iter_time` its predicted pure iteration time)
    /// becomes both the running plan and the incumbent, the arms are
    /// rebuilt around its structure, the per-epoch cache is dropped,
    /// the unspent allowance is forfeited and any hypothesis is
    /// discarded (the fleet it anticipated no longer matches; the
    /// driver re-primes if the notice is still live).
    pub fn reseed(&mut self, plan: Option<&ExecutionPlan>, iter_time: f64) {
        self.epochs += 1;
        self.carry = 0.0;
        self.cache = Arc::new(CostCache::new());
        self.arms.clear();
        self.pending.clear();
        self.running = plan.cloned();
        self.incumbent = plan.cloned();
        self.incumbent_cost = if plan.is_some() { iter_time } else { f64::INFINITY };
        self.clear_hypothesis();
        let Some(plan) = plan else {
            self.prev = Vec::new();
            return;
        };
        self.prev = PrevTask::from_plan(plan, Some);
        let (seed, epochs) = (self.seed, self.epochs);
        let (arms, pending) = build_arms(&self.cfg, plan, |k| {
            seed.wrapping_mul(6364136223846793005)
                .wrapping_add(epochs.wrapping_mul(1442695040888963407))
                .wrapping_add(k.wrapping_mul(0x9E37_79B9_7F4A_7C15))
        });
        self.arms = arms;
        self.pending = pending;
    }

    /// Arm the hypothesis incumbent for a predicted machine-loss event.
    ///
    /// `key` identifies the predicted event (the replay driver uses the
    /// trace index); re-priming with the same key is a no-op, so the
    /// hypothesis population keeps evolving across quiet iterations.
    /// `seed_plan` is the running plan repaired into the *hypothetical
    /// post-event* snapshot space (`None` when repair is impossible —
    /// the hypothesis then stays inert for this key), `objective` its
    /// full migration-aware objective on the hypothetical fleet, and
    /// `prev` the running plan's surviving placement there (what the
    /// hypothesis search charges migration from).
    pub fn prime_hypothesis(
        &mut self,
        key: u64,
        seed_plan: Option<&ExecutionPlan>,
        objective: f64,
        prev: Vec<PrevTask>,
    ) {
        if self.hyp_key == Some(key) {
            return;
        }
        self.clear_hypothesis();
        self.hyp_key = Some(key);
        let Some(plan) = seed_plan else { return };
        self.hyp_prev = prev;
        self.hyp_incumbent = Some(plan.clone());
        self.hyp_cost = objective;
        // A distinct RNG stream per (service seed, predicted event,
        // arm) — disjoint from the primary arms' streams.
        let seed = self.seed;
        let (arms, pending) = build_arms(&self.cfg, plan, |k| {
            (seed ^ 0x48E5_0C7A_9B1D_F00D)
                .wrapping_mul(6364136223846793005)
                .wrapping_add(key.wrapping_mul(0x2545_F491_4F6C_DD1D))
                .wrapping_add(k.wrapping_mul(0x9E37_79B9_7F4A_7C15))
        });
        self.hyp_arms = arms;
        self.hyp_pending = pending;
    }

    /// Drop the hypothesis incumbent. [`Self::reseed`] calls this at
    /// every event barrier (the predicted fleet no longer matches);
    /// public for drivers with different notice semantics than the
    /// replay's latched window.
    pub fn clear_hypothesis(&mut self) {
        self.hyp_key = None;
        self.hyp_arms.clear();
        self.hyp_pending.clear();
        self.hyp_prev = Vec::new();
        self.hyp_incumbent = None;
        self.hyp_cost = f64::INFINITY;
        self.hyp_cache = Arc::new(CostCache::new());
    }

    /// Credit `sim_secs` of simulated training time to the allowance.
    pub fn accrue(&mut self, sim_secs: f64) {
        if sim_secs.is_finite() && sim_secs > 0.0 {
            let evals = sim_secs * self.cfg.anytime.evals_per_sim_sec;
            self.carry += evals;
            self.accrued += evals;
        }
    }

    /// Spend the accrued allowance improving the incumbent(s). One call
    /// per quiet replayed iteration; the fan-out/merge runs on the
    /// parallel engine with per-arm quotas from [`engine::split_quota`],
    /// so the outcome is bit-identical at any thread count.
    ///
    /// With `hypothesis` set to the hypothetical post-event topology
    /// (and a hypothesis primed via [`Self::prime_hypothesis`]), the
    /// step's quota is split between the two incumbents by
    /// [`engine::split_allowance`]; otherwise the primary incumbent
    /// keeps the whole quota and the call behaves exactly as it did
    /// before predictive preemption existed.
    pub fn step(
        &mut self,
        topo: &DeviceTopology,
        wf: &RlWorkflow,
        job: &JobConfig,
        hypothesis: Option<&DeviceTopology>,
    ) -> AnytimeStep {
        let quota = (self.carry as usize).min(self.cfg.anytime.max_step_evals);
        if quota == 0 || self.arms.is_empty() || self.running.is_none() {
            return AnytimeStep::idle(self.incumbent_cost, self.hyp_cost);
        }
        let hyp_active = hypothesis.is_some() && !self.hyp_arms.is_empty();
        let (primary_quota, hyp_quota) = engine::split_allowance(quota, hyp_active);

        let (spent, hits, misses) = evolve_incumbent(
            &self.cfg,
            topo,
            wf,
            job,
            primary_quota,
            &mut self.arms,
            &mut self.pending,
            &self.prev,
            &self.cache,
            &mut self.incumbent,
            &mut self.incumbent_cost,
        );
        let (hyp_spent, hyp_hits, hyp_misses) = match hypothesis {
            Some(hyp_topo) if hyp_active => evolve_incumbent(
                &self.cfg,
                hyp_topo,
                wf,
                job,
                hyp_quota,
                &mut self.hyp_arms,
                &mut self.hyp_pending,
                &self.hyp_prev,
                &self.hyp_cache,
                &mut self.hyp_incumbent,
                &mut self.hyp_cost,
            ),
            _ => (0, 0, 0),
        };

        let total = spent + hyp_spent;
        self.carry -= total as f64;
        self.spent += total;
        AnytimeStep {
            evals: spent,
            hypothesis_evals: hyp_spent,
            cache_hits: hits + hyp_hits,
            cache_misses: misses + hyp_misses,
            incumbent_cost: self.incumbent_cost,
            hypothesis_cost: self.hyp_cost,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::CostModel;
    use crate::elastic::events::ClusterEvent;
    use crate::elastic::fleet::FleetState;
    use crate::elastic::replan::{prev_placement, repair_plan, plan_to_base, Replanner};
    use crate::testing::fixtures;
    use crate::workflow::JobConfig;

    fn service(threads: usize) -> (AnytimeSearch, crate::workflow::RlWorkflow, DeviceTopology, JobConfig)
    {
        let wf = fixtures::tiny_wf();
        let job = JobConfig::tiny();
        let topo = fixtures::small_topo(crate::topology::Scenario::MultiCountry);
        let mut cfg = fixtures::small_replan_cfg();
        cfg.threads = threads;
        cfg.anytime =
            AnytimeConfig { evals_per_sim_sec: 1.0, max_step_evals: 24, arms: 2, seed_mutants: 2 };
        let mut rp = Replanner::new(3, cfg.clone());
        let plan = rp.cold_plan(&topo, &wf, &job).plan.expect("cold plan");
        let iter_time = CostModel::new(&topo, &wf, &job).plan_cost(&plan).iter_time;
        let mut svc = AnytimeSearch::new(7, cfg);
        svc.reseed(Some(&plan), iter_time);
        (svc, wf, topo, job)
    }

    #[test]
    fn allowance_caps_spending() {
        let (mut svc, wf, topo, job) = service(1);
        // Nothing accrued: the step must idle.
        let st = svc.step(&topo, &wf, &job, None);
        assert_eq!(st.evals, 0);
        svc.accrue(5.0); // 5 evals at 1 eval/sim-sec
        let st = svc.step(&topo, &wf, &job, None);
        assert!(st.evals <= 5, "overspent: {}", st.evals);
        assert!(svc.spent() as f64 <= svc.accrued() + 1e-9);
        // A huge accrual is clamped by the per-step cap.
        svc.accrue(1e6);
        let st = svc.step(&topo, &wf, &job, None);
        assert!(st.evals <= 24, "step cap violated: {}", st.evals);
    }

    #[test]
    fn incumbent_objective_monotone_within_epoch() {
        let (mut svc, wf, topo, job) = service(1);
        let mut prev = f64::INFINITY;
        for _ in 0..6 {
            svc.accrue(12.0);
            let st = svc.step(&topo, &wf, &job, None);
            assert!(
                st.incumbent_cost <= prev,
                "incumbent regressed: {} after {}",
                st.incumbent_cost,
                prev
            );
            assert!(st.incumbent_cost.is_finite());
            prev = st.incumbent_cost;
        }
        assert!(svc.spent() > 0, "background search never ran");
    }

    #[test]
    fn reseed_forfeits_allowance_and_restarts() {
        let (mut svc, wf, topo, job) = service(1);
        svc.accrue(50.0);
        let running = svc.incumbent().unwrap().0.clone();
        svc.reseed(Some(&running), 42.0);
        assert_eq!(svc.epochs(), 2);
        // Carry was forfeited: an immediate step has nothing to spend.
        let st = svc.step(&topo, &wf, &job, None);
        assert_eq!(st.evals, 0);
        assert_eq!(st.incumbent_cost, 42.0);
    }

    #[test]
    fn step_deterministic_across_thread_counts() {
        let run = |threads: usize| {
            let (mut svc, wf, topo, job) = service(threads);
            let mut trail = Vec::new();
            for _ in 0..4 {
                svc.accrue(10.0);
                let st = svc.step(&topo, &wf, &job, None);
                trail.push((st.evals, st.incumbent_cost.to_bits()));
            }
            (trail, svc.incumbent().map(|(p, c)| (p.clone(), c.to_bits())))
        };
        let a = run(1);
        let b = run(4);
        assert_eq!(a.0, b.0, "step telemetry diverged across thread counts");
        assert_eq!(a.1, b.1, "incumbent diverged across thread counts");
    }

    /// Prime a hypothesis against "a machine is about to vanish" for a
    /// service whose fleet is still whole, picking the first machine
    /// whose loss the running plan survives via repair. Returns the
    /// hypothetical snapshot topology alongside the service.
    fn service_with_hypothesis(
        threads: usize,
    ) -> (AnytimeSearch, crate::workflow::RlWorkflow, DeviceTopology, DeviceTopology, JobConfig)
    {
        let (mut svc, wf, topo, job) = service(threads);
        let fleet = FleetState::new(fixtures::small_topo(crate::topology::Scenario::MultiCountry));
        let (_, map) = fleet.snapshot();
        let running_base = plan_to_base(svc.incumbent().unwrap().0, &map);
        for machine in 0..3 {
            let hypo = fleet.apply_hypothetical(&ClusterEvent::MachinePreempt { machine });
            let (hyp_topo, hyp_map) = hypo.snapshot();
            let hb2n = FleetState::base_to_snapshot(&hyp_map);
            let Some(seed_plan) = repair_plan(&running_base, &wf, &job, &hyp_topo, &hb2n, 99)
            else {
                continue;
            };
            let prev = prev_placement(&running_base, &hb2n);
            let mm = svc.cfg.migration;
            let horizon = svc.cfg.horizon_iters.max(1.0);
            let objective = CostModel::new(&hyp_topo, &wf, &job).plan_cost(&seed_plan).iter_time
                + mm.migration_time(&hyp_topo, &wf, &job, &prev, &seed_plan) / horizon;
            svc.prime_hypothesis(machine as u64, Some(&seed_plan), objective, prev);
            return (svc, wf, topo, hyp_topo, job);
        }
        panic!("no machine loss the running plan survives via repair");
    }

    #[test]
    fn hypothesis_splits_allowance_and_stays_monotone() {
        let (mut svc, wf, topo, hyp_topo, job) = service_with_hypothesis(1);
        let key = svc.hypothesis_key().expect("hypothesis primed");
        let mut prev_hyp = svc.hypothesis().map(|(_, c)| c).unwrap_or(f64::INFINITY);
        let mut hyp_total = 0usize;
        for _ in 0..4 {
            svc.accrue(20.0);
            let st = svc.step(&topo, &wf, &job, Some(&hyp_topo));
            // The split never exceeds the step cap, and the hypothesis
            // quota is the smaller half of it.
            assert!(st.evals + st.hypothesis_evals <= 24, "cap: {st:?}");
            assert!(st.hypothesis_evals <= 12, "hypothesis over half-cap: {st:?}");
            assert!(
                st.hypothesis_cost <= prev_hyp,
                "hypothesis regressed: {} after {}",
                st.hypothesis_cost,
                prev_hyp
            );
            prev_hyp = st.hypothesis_cost;
            hyp_total += st.hypothesis_evals;
        }
        assert!(hyp_total > 0, "hypothesis search never ran");
        // Re-priming with the same key keeps the evolved hypothesis.
        let before = svc.hypothesis().map(|(_, c)| c.to_bits());
        svc.prime_hypothesis(key, None, f64::INFINITY, Vec::new());
        assert_eq!(svc.hypothesis().map(|(_, c)| c.to_bits()), before);
    }

    #[test]
    fn clear_and_reseed_drop_hypothesis() {
        let (mut svc, wf, topo, hyp_topo, job) = service_with_hypothesis(1);
        svc.accrue(20.0);
        svc.step(&topo, &wf, &job, Some(&hyp_topo));
        svc.clear_hypothesis();
        assert_eq!(svc.hypothesis_key(), None);
        assert!(svc.hypothesis().is_none());
        // Without a primed hypothesis the step ignores the hypothetical
        // topology entirely.
        svc.accrue(20.0);
        let st = svc.step(&topo, &wf, &job, Some(&hyp_topo));
        assert_eq!(st.hypothesis_evals, 0);
        // A barrier reseed also discards any primed hypothesis.
        let (mut svc2, wf2, topo2, hyp_topo2, job2) = service_with_hypothesis(1);
        let running = svc2.incumbent().unwrap().0.clone();
        svc2.reseed(Some(&running), 1.0);
        assert_eq!(svc2.hypothesis_key(), None);
        svc2.accrue(20.0);
        let st2 = svc2.step(&topo2, &wf2, &job2, Some(&hyp_topo2));
        assert_eq!(st2.hypothesis_evals, 0);
    }

    #[test]
    fn hypothesis_step_deterministic_across_thread_counts() {
        let run = |threads: usize| {
            let (mut svc, wf, topo, hyp_topo, job) = service_with_hypothesis(threads);
            let mut trail = Vec::new();
            for _ in 0..3 {
                svc.accrue(16.0);
                let st = svc.step(&topo, &wf, &job, Some(&hyp_topo));
                trail.push((
                    st.evals,
                    st.hypothesis_evals,
                    st.incumbent_cost.to_bits(),
                    st.hypothesis_cost.to_bits(),
                ));
            }
            (trail, svc.hypothesis().map(|(p, c)| (p.clone(), c.to_bits())))
        };
        let a = run(1);
        let b = run(4);
        assert_eq!(a.0, b.0, "hypothesis telemetry diverged across thread counts");
        assert_eq!(a.1, b.1, "hypothesis incumbent diverged across thread counts");
    }
}
