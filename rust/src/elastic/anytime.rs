//! Anytime background search: keep improving the plan *between*
//! cluster events instead of only reacting at them.
//!
//! The event-driven [`super::replan::Replanner`] closes part of the
//! static→oracle gap, but its search stops when the barrier clears —
//! during the (often long) quiet stretches between events the scheduler
//! sits idle while the fleet keeps executing a possibly mediocre plan.
//! This module models the asynchronous-RL insight (overlap optimization
//! with execution) applied to plan search itself:
//!
//! * **Allowance, not wall-clock** — "spare controller cycles" are an
//!   eval allowance accrued per *simulated* second of training
//!   ([`AnytimeConfig::evals_per_sim_sec`], capped per step by
//!   [`AnytimeConfig::max_step_evals`]). The budget is charged through
//!   the engine's shared [`crate::scheduler::EvalLedger`] in sim-time
//!   units, never wall-clock, so a replay remains a pure function of
//!   `(scenario, spec, wf, job, policy, cfg, seed)` and the determinism
//!   contract (same seed ⇒ bit-identical replay at any thread count)
//!   extends to the background search.
//! * **Persistent warm arms** — at every event barrier the service is
//!   [`AnytimeSearch::reseed`]ed from the post-event plan: a fixed
//!   number of [`EaArm`]s is rebuilt around the plan's Level-1/2
//!   structure, their populations seeded with the plan plus per-arm
//!   [`perturbations`]. Between events the arms' populations persist
//!   and keep evolving, one [`AnytimeSearch::step`] per replayed
//!   iteration on the scoped-worker engine
//!   ([`crate::scheduler::engine::run_seeded_rung`]).
//! * **Migration-aware objective** — candidates are scored as
//!   `iter_time + migration_time(running → candidate) / horizon`
//!   against the *currently executing* plan, so the background search
//!   cannot chase marginally-faster plans that would cost terabytes of
//!   resharding to adopt. The incumbent only ever improves within an
//!   inter-event window (monotone non-increasing objective).
//! * **Barrier merge** — at the next event the replay hands the
//!   incumbent (translated to base ids) to
//!   [`super::replan::Replanner::replan_with_anytime`], which runs the
//!   ordinary warm replan unchanged and adopts the anytime incumbent
//!   only if its migration-aware objective against the post-event
//!   fleet is strictly better. Unspent allowance is forfeited at the
//!   barrier (the controller is busy replanning).
//!
//! Exposed as `hetrl replay --policy anytime` (and inside
//! `--policy all`), compared in `benches/fig11_elastic.rs`, and
//! property-tested in `tests/prop_anytime.rs`.

use super::replan::ReplanConfig;
use crate::costmodel::{CostCache, PrevTask};
use crate::plan::ExecutionPlan;
use crate::scheduler::ea::{perturbations, EaArm};
use crate::scheduler::engine::{self, SeededArmTask};
use crate::scheduler::{Budget, EvalCtx};
use crate::topology::DeviceTopology;
use crate::workflow::{JobConfig, RlWorkflow};
use std::sync::Arc;

/// Anytime background-search knobs (nested in
/// [`super::replan::ReplanConfig`]).
#[derive(Debug, Clone, Copy)]
pub struct AnytimeConfig {
    /// Cost-model evaluations the controller can afford per *simulated*
    /// second of training — the spare-cycle allowance. Accounted in
    /// sim-time so replays stay deterministic.
    pub evals_per_sim_sec: f64,
    /// Hard cap on evaluations spent in one between-event step.
    pub max_step_evals: usize,
    /// Independent background arms (each on its own RNG stream and,
    /// when `ReplanConfig::threads` > 1, its own worker).
    pub arms: usize,
    /// Perturbed copies of the incumbent seeded per arm at reseed.
    pub seed_mutants: usize,
}

impl Default for AnytimeConfig {
    fn default() -> Self {
        AnytimeConfig {
            evals_per_sim_sec: 0.5,
            max_step_evals: 64,
            arms: 2,
            seed_mutants: 3,
        }
    }
}

/// What one background step did.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AnytimeStep {
    /// Evaluations actually spent (≤ the accrued allowance and
    /// ≤ [`AnytimeConfig::max_step_evals`]).
    pub evals: usize,
    /// Cost-cache telemetry for the step (exact at 1 worker thread).
    pub cache_hits: usize,
    pub cache_misses: usize,
    /// Incumbent objective after the step: `iter_time` + amortized
    /// migration from the running plan (∞ when no incumbent exists).
    pub incumbent_cost: f64,
}

impl AnytimeStep {
    fn idle(incumbent_cost: f64) -> AnytimeStep {
        AnytimeStep { evals: 0, cache_hits: 0, cache_misses: 0, incumbent_cost }
    }
}

/// The background anytime-search service owned by a `Policy::Anytime`
/// replay. All plans live in the *snapshot* id space of the current
/// epoch; the replay driver translates across epochs at barriers.
pub struct AnytimeSearch {
    cfg: ReplanConfig,
    seed: u64,
    /// Bumped at every [`Self::reseed`] (event barrier).
    epochs: u64,
    /// Fractional eval allowance accrued but not yet spent this epoch.
    carry: f64,
    /// Lifetime allowance ever accrued (telemetry; `spent ≤ accrued`).
    accrued: f64,
    spent: usize,
    /// Background arms with persistent populations (current epoch).
    arms: Vec<EaArm>,
    /// Per-arm seed plans still to inject (drained across subsequent
    /// steps as each arm's quota affords, so a starved arm keeps its
    /// warm-start seeds until the allowance catches up).
    pending: Vec<Vec<ExecutionPlan>>,
    /// The plan the fleet is executing this epoch, and its shard view
    /// (identity translation — same snapshot space).
    running: Option<ExecutionPlan>,
    prev: Vec<PrevTask>,
    incumbent: Option<ExecutionPlan>,
    incumbent_cost: f64,
    /// Per-epoch cost memo shared across steps (cleared at reseed:
    /// a new snapshot invalidates every cached per-task cost).
    cache: Arc<CostCache>,
}

impl AnytimeSearch {
    pub fn new(seed: u64, cfg: ReplanConfig) -> AnytimeSearch {
        AnytimeSearch {
            cfg,
            seed,
            epochs: 0,
            carry: 0.0,
            accrued: 0.0,
            spent: 0,
            arms: Vec::new(),
            pending: Vec::new(),
            running: None,
            prev: Vec::new(),
            incumbent: None,
            incumbent_cost: f64::INFINITY,
            cache: Arc::new(CostCache::new()),
        }
    }

    /// Current incumbent (snapshot space) and its objective.
    pub fn incumbent(&self) -> Option<(&ExecutionPlan, f64)> {
        self.incumbent.as_ref().map(|p| (p, self.incumbent_cost))
    }

    /// Background evaluations spent over the service's lifetime.
    pub fn spent(&self) -> usize {
        self.spent
    }

    /// Allowance ever accrued (`spent() ≤ accrued()` always holds).
    pub fn accrued(&self) -> f64 {
        self.accrued
    }

    pub fn epochs(&self) -> u64 {
        self.epochs
    }

    /// Start a new epoch at an event barrier: the chosen post-event
    /// plan (with `iter_time` its predicted pure iteration time)
    /// becomes both the running plan and the incumbent, the arms are
    /// rebuilt around its structure, the per-epoch cache is dropped and
    /// the unspent allowance is forfeited.
    pub fn reseed(&mut self, plan: Option<&ExecutionPlan>, iter_time: f64) {
        self.epochs += 1;
        self.carry = 0.0;
        self.cache = Arc::new(CostCache::new());
        self.arms.clear();
        self.pending.clear();
        self.running = plan.cloned();
        self.incumbent = plan.cloned();
        self.incumbent_cost = if plan.is_some() { iter_time } else { f64::INFINITY };
        let Some(plan) = plan else {
            self.prev = Vec::new();
            return;
        };
        self.prev = PrevTask::from_plan(plan, Some);
        let grouping = plan.task_groups.clone();
        let sizes: Vec<usize> = plan.gpu_groups.iter().map(|g| g.len()).collect();
        for k in 0..self.cfg.anytime.arms.max(1) {
            let arm_seed = self
                .seed
                .wrapping_mul(6364136223846793005)
                .wrapping_add(self.epochs.wrapping_mul(1442695040888963407))
                .wrapping_add((k as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
            self.arms.push(EaArm::new(
                grouping.clone(),
                sizes.clone(),
                self.cfg.ea.clone(),
                arm_seed,
            ));
            let mut seeds = vec![plan.clone()];
            seeds.extend(perturbations(plan, self.cfg.anytime.seed_mutants, arm_seed));
            self.pending.push(seeds);
        }
    }

    /// Credit `sim_secs` of simulated training time to the allowance.
    pub fn accrue(&mut self, sim_secs: f64) {
        if sim_secs.is_finite() && sim_secs > 0.0 {
            let evals = sim_secs * self.cfg.anytime.evals_per_sim_sec;
            self.carry += evals;
            self.accrued += evals;
        }
    }

    /// Spend the accrued allowance improving the incumbent on the
    /// current snapshot. One call per quiet replayed iteration; the
    /// fan-out/merge runs on the parallel engine with per-arm quotas
    /// from [`engine::split_quota`], so the outcome is bit-identical at
    /// any thread count.
    pub fn step(
        &mut self,
        topo: &DeviceTopology,
        wf: &RlWorkflow,
        job: &JobConfig,
    ) -> AnytimeStep {
        let quota = (self.carry as usize).min(self.cfg.anytime.max_step_evals);
        if quota == 0 || self.arms.is_empty() || self.running.is_none() {
            return AnytimeStep::idle(self.incumbent_cost);
        }
        let mut ctx = EvalCtx::new(topo, wf, job, Budget::evals(quota));
        ctx.cache = Arc::clone(&self.cache);
        // Only strict improvements over the incumbent count.
        ctx.best_cost = self.incumbent_cost;
        let mm = self.cfg.migration;
        let horizon = self.cfg.horizon_iters.max(1.0);
        let prev = self.prev.clone();
        ctx.penalty = Some(Arc::new(move |p: &ExecutionPlan| {
            mm.migration_time(topo, wf, job, &prev, p) / horizon
        }));
        let hits0 = ctx.cache.hits();
        let misses0 = ctx.cache.misses();

        let quotas = engine::split_quota(quota, self.arms.len(), 1);
        let threads = engine::resolve_threads(self.cfg.threads);
        let arms = std::mem::take(&mut self.arms);
        let mut pending = std::mem::take(&mut self.pending);
        pending.resize_with(arms.len(), Vec::new);
        // Hand each arm only the seeds its quota can inject this step;
        // the rest stay pending so a starved arm still warm-starts once
        // the allowance catches up (quotas are budget-derived, so this
        // split is deterministic at any thread count).
        let mut kept: Vec<Vec<ExecutionPlan>> = Vec::with_capacity(arms.len());
        let tasks: Vec<SeededArmTask> = arms
            .into_iter()
            .zip(pending)
            .enumerate()
            .map(|(k, (arm, mut seeds))| {
                let rest = seeds.split_off(quotas[k].min(seeds.len()));
                kept.push(rest);
                SeededArmTask { key: (0, k), arm, quota: quotas[k], seeds }
            })
            .collect();
        let runs = engine::run_seeded_rung(&mut ctx, tasks, threads);
        self.arms = runs.into_iter().map(|r| r.arm).collect();
        self.pending = kept;

        let step_spent = ctx.ledger.spent();
        self.carry -= step_spent as f64;
        self.spent += step_spent;
        if ctx.best_cost < self.incumbent_cost {
            if let Some(p) = ctx.best_plan.take() {
                self.incumbent_cost = ctx.best_cost;
                self.incumbent = Some(p);
            }
        }
        AnytimeStep {
            evals: step_spent,
            cache_hits: ctx.cache.hits().saturating_sub(hits0),
            cache_misses: ctx.cache.misses().saturating_sub(misses0),
            incumbent_cost: self.incumbent_cost,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::CostModel;
    use crate::elastic::replan::Replanner;
    use crate::testing::fixtures;
    use crate::workflow::JobConfig;

    fn service(threads: usize) -> (AnytimeSearch, crate::workflow::RlWorkflow, DeviceTopology, JobConfig)
    {
        let wf = fixtures::tiny_wf();
        let job = JobConfig::tiny();
        let topo = fixtures::small_topo(crate::topology::Scenario::MultiCountry);
        let mut cfg = fixtures::small_replan_cfg();
        cfg.threads = threads;
        cfg.anytime =
            AnytimeConfig { evals_per_sim_sec: 1.0, max_step_evals: 24, arms: 2, seed_mutants: 2 };
        let mut rp = Replanner::new(3, cfg.clone());
        let plan = rp.cold_plan(&topo, &wf, &job).plan.expect("cold plan");
        let iter_time = CostModel::new(&topo, &wf, &job).plan_cost(&plan).iter_time;
        let mut svc = AnytimeSearch::new(7, cfg);
        svc.reseed(Some(&plan), iter_time);
        (svc, wf, topo, job)
    }

    #[test]
    fn allowance_caps_spending() {
        let (mut svc, wf, topo, job) = service(1);
        // Nothing accrued: the step must idle.
        let st = svc.step(&topo, &wf, &job);
        assert_eq!(st.evals, 0);
        svc.accrue(5.0); // 5 evals at 1 eval/sim-sec
        let st = svc.step(&topo, &wf, &job);
        assert!(st.evals <= 5, "overspent: {}", st.evals);
        assert!(svc.spent() as f64 <= svc.accrued() + 1e-9);
        // A huge accrual is clamped by the per-step cap.
        svc.accrue(1e6);
        let st = svc.step(&topo, &wf, &job);
        assert!(st.evals <= 24, "step cap violated: {}", st.evals);
    }

    #[test]
    fn incumbent_objective_monotone_within_epoch() {
        let (mut svc, wf, topo, job) = service(1);
        let mut prev = f64::INFINITY;
        for _ in 0..6 {
            svc.accrue(12.0);
            let st = svc.step(&topo, &wf, &job);
            assert!(
                st.incumbent_cost <= prev,
                "incumbent regressed: {} after {}",
                st.incumbent_cost,
                prev
            );
            assert!(st.incumbent_cost.is_finite());
            prev = st.incumbent_cost;
        }
        assert!(svc.spent() > 0, "background search never ran");
    }

    #[test]
    fn reseed_forfeits_allowance_and_restarts() {
        let (mut svc, wf, topo, job) = service(1);
        svc.accrue(50.0);
        let running = svc.incumbent().unwrap().0.clone();
        svc.reseed(Some(&running), 42.0);
        assert_eq!(svc.epochs(), 2);
        // Carry was forfeited: an immediate step has nothing to spend.
        let st = svc.step(&topo, &wf, &job);
        assert_eq!(st.evals, 0);
        assert_eq!(st.incumbent_cost, 42.0);
    }

    #[test]
    fn step_deterministic_across_thread_counts() {
        let run = |threads: usize| {
            let (mut svc, wf, topo, job) = service(threads);
            let mut trail = Vec::new();
            for _ in 0..4 {
                svc.accrue(10.0);
                let st = svc.step(&topo, &wf, &job);
                trail.push((st.evals, st.incumbent_cost.to_bits()));
            }
            (trail, svc.incumbent().map(|(p, c)| (p.clone(), c.to_bits())))
        };
        let a = run(1);
        let b = run(4);
        assert_eq!(a.0, b.0, "step telemetry diverged across thread counts");
        assert_eq!(a.1, b.1, "incumbent diverged across thread counts");
    }
}
