//! Elastic cluster dynamics (the paper's implied deployment reality:
//! underutilized mid-range GPUs across regions come and go).
//!
//! The static HetRL pipeline — profile → multi-level search → plan →
//! execute — assumes a fixed fleet. This subsystem makes the stack
//! dynamic:
//!
//! * [`events`] — the [`events::ClusterEvent`] model (GPU machine
//!   join/leave/preempt, per-link bandwidth/latency shifts, straggler
//!   onset) and a deterministic, seeded trace generator; machine-loss
//!   events carry realistic advance-notice windows
//!   ([`events::TraceEvent::notice_secs`]) that predictive preemption
//!   exploits;
//! * [`fleet`] — [`fleet::FleetState`]: the base topology plus applied
//!   events, snapshotted into the dense [`crate::topology::DeviceTopology`]
//!   the schedulers consume (with id maps across epochs);
//! * [`replan`] — [`replan::Replanner`]: event-driven *incremental*
//!   re-search — repair the incumbent, warm-start several parallel EA
//!   arms from it under a reduced budget (on the
//!   [`crate::scheduler::engine`] evaluation engine, sharing the
//!   always-on [`crate::costmodel::CostCache`]), and optimize a
//!   migration-aware objective (`iter_time + migration/horizon`, see
//!   [`crate::costmodel::MigrationModel`]);
//! * [`anytime`] — [`anytime::AnytimeSearch`]: the *anytime* background
//!   search that keeps improving an incumbent **between** events under
//!   a rate-limited, sim-time-accounted eval allowance ("spare
//!   controller cycles"), merging migration-aware at each event
//!   barrier so the replanner's warm arms start from the best plan
//!   known, not just the aged incumbent; with a noticed machine loss
//!   pending it additionally maintains a **hypothesis incumbent**
//!   searched against the post-event fleet
//!   ([`fleet::FleetState::apply_hypothetical`]), the allowance split
//!   deterministically between the two
//!   ([`crate::scheduler::engine::split_allowance`]);
//! * [`replay`] — end-to-end dynamic-trace replay on the DES
//!   ([`crate::simulator`]): plan → event → replan → resume, comparing
//!   static / warm-replan / anytime / preempt / oracle policies
//!   (`hetrl replay`, `benches/fig11_elastic.rs`);
//! * [`recovery`] — the checkpoint interval as a *searched* plan
//!   dimension: SHA arms per candidate cadence on the evaluation
//!   engine, scored by a recovery-aware objective
//!   (`iter_time·(1 + w/I) + λ·I/2`) built from
//!   [`crate::costmodel::RecoveryModel`] and the trace's
//!   unnoticed-loss rate.

pub mod anytime;
pub mod events;
pub mod fleet;
pub mod recovery;
pub mod replan;
pub mod replay;

pub use anytime::{AnytimeConfig, AnytimeSearch, AnytimeStep};
pub use events::{generate_trace, ClusterEvent, TraceConfig, TraceEvent};
pub use fleet::FleetState;
pub use recovery::{
    interval_objective, pick_interval_analytic, plan_with_ckpt_interval, unnoticed_loss_rate,
    CkptSearchConfig,
};
pub use replan::{
    plan_to_base, prev_placement, repair_plan, ReplanConfig, ReplanOutcome, Replanner,
};
pub use replay::{
    first_event_iter, replay, replay_with_trace, IterRecord, Policy, ReplayConfig, ReplayResult,
};
