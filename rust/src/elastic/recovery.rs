//! The checkpoint interval as a **searched plan dimension**.
//!
//! A short cadence wastes bandwidth writing state nobody loses; a long
//! one exposes the job to huge rework when a spot machine vanishes
//! unannounced. The right trade depends on the plan itself (its state
//! size sets the write cost) and on the trace's loss rate — so the
//! interval is searched jointly with the plan, as successive-halving
//! arms on the existing evaluation engine ([`crate::scheduler::engine`]):
//!
//! 1. **Structure discovery** — a reduced-budget cold SHA-EA search
//!    finds a good plan structure (Level-1/2 grouping) exactly as the
//!    ordinary cold plan would.
//! 2. **Interval arms** — one EA arm per candidate interval, each
//!    seeded with the discovered plan (plus light perturbations) and
//!    evolved under a *recovery-aware* objective:
//!
//!    `iter_time · (1 + w(p)/I) + λ · I/2`
//!
//!    where `w(p)` is the plan's checkpoint-write time
//!    ([`RecoveryModel::ckpt_write_secs`]), `I` the arm's interval, and
//!    `λ` the trace's unnoticed-loss rate per iteration — `w/I` prices
//!    the cadence overhead per productive second and `I/2` the expected
//!    rework per loss. Arms run in a fixed order with quotas derived
//!    from the shared ledger ([`crate::scheduler::engine::split_quota`]),
//!    and halving keeps the better half by NaN-safe comparison — same
//!    seed ⇒ bit-identical winner (plan *and* interval) at any thread
//!    count.
//!
//! Degeneracy: with [`crate::elastic::ReplayConfig::ckpt_search`] unset
//! (the default) none of this runs and the replay's initial plan is
//! bit-identical to the plain cold search.

use super::events::TraceEvent;
use super::replan::{ReplanOutcome, Replanner};
use crate::costmodel::{CostModel, RecoveryModel};
use crate::plan::ExecutionPlan;
use crate::scheduler::ea::{perturbations, EaArm};
use crate::scheduler::engine::{self, SeededArmTask};
use crate::scheduler::{Budget, EvalCtx};
use crate::topology::DeviceTopology;
use crate::util::ford;
use crate::workflow::{JobConfig, RlWorkflow};
use std::sync::Arc;

/// Knobs for the checkpoint-interval search (CLI:
/// `hetrl replay --ckpt-interval auto`).
#[derive(Debug, Clone)]
pub struct CkptSearchConfig {
    /// Candidate checkpoint intervals, sim-seconds, ascending. One SHA
    /// arm per candidate.
    pub candidates: Vec<f64>,
    /// Successive-halving rounds over the candidate arms.
    pub rounds: usize,
    /// Fraction of the cold budget spent on structure discovery before
    /// the interval arms divide the rest.
    pub structure_frac: f64,
}

impl Default for CkptSearchConfig {
    fn default() -> Self {
        CkptSearchConfig {
            candidates: vec![120.0, 300.0, 600.0, 1200.0],
            rounds: 2,
            structure_frac: 0.4,
        }
    }
}

/// Unnoticed-loss rate of a trace, per iteration: machine losses with
/// no advance notice plus task failures whose drawn attempts exceed the
/// retry budget — exactly the events the replay charges a rollback for.
pub fn unnoticed_loss_rate(trace: &[TraceEvent], recovery: &RecoveryModel, iters: usize) -> f64 {
    let losses = trace
        .iter()
        .filter(|e| {
            (e.is_machine_loss() && e.notice_secs.is_none())
                || matches!(
                    e.event,
                    super::events::ClusterEvent::TaskFailure { attempts, .. }
                        if attempts > recovery.max_retries
                )
        })
        .count();
    losses as f64 / iters.max(1) as f64
}

/// The closed-form recovery-aware objective for a fixed plan: expected
/// per-iteration cost at interval `I` given the plan's iteration time,
/// its checkpoint-write time `w`, and the per-iteration loss rate `λ`.
/// Used by the arm penalty and, analytically, by the async replay
/// (which picks the interval for its fixed initial pool split instead
/// of re-searching the plan).
pub fn interval_objective(iter_time: f64, write_secs: f64, lambda_iter: f64, interval: f64) -> f64 {
    if interval <= 0.0 {
        return f64::INFINITY;
    }
    iter_time * (1.0 + write_secs / interval) + lambda_iter * interval / 2.0
}

/// Pick the candidate interval minimizing [`interval_objective`] for a
/// fixed plan — NaN-safe, ties to the earlier candidate. Returns
/// `fallback` when `candidates` is empty.
pub fn pick_interval_analytic(
    iter_time: f64,
    write_secs: f64,
    lambda_iter: f64,
    candidates: &[f64],
    fallback: f64,
) -> f64 {
    let mut best = fallback;
    let mut best_obj = f64::INFINITY;
    for &i in candidates {
        let obj = interval_objective(iter_time, write_secs, lambda_iter, i);
        if ford::cmp_f64(obj, best_obj) == std::cmp::Ordering::Less {
            best_obj = obj;
            best = i;
        }
    }
    best
}

/// One live interval arm.
struct IntervalArm {
    /// Index into `CkptSearchConfig::candidates` (the tie-break order).
    idx: usize,
    interval: f64,
    arm: EaArm,
    best_cost: f64,
    best_plan: Option<ExecutionPlan>,
}

/// Cold-plan with the checkpoint interval as a searched dimension.
/// Returns the winning plan episode (budget accounting includes both
/// phases) and the chosen interval (`recovery.ckpt_interval_secs` when
/// the search could not improve on the configured cadence — e.g. no
/// feasible structure, or an empty candidate list).
///
/// Deterministic: arm quotas derive from the shared ledger at each
/// round barrier, arms run and merge in candidate order, and halving
/// breaks ties toward the earlier candidate — the winner is
/// bit-identical at any thread count.
pub fn plan_with_ckpt_interval(
    replanner: &mut Replanner,
    topo: &DeviceTopology,
    wf: &RlWorkflow,
    job: &JobConfig,
    trace: &[TraceEvent],
    recovery: &RecoveryModel,
    cfg: &CkptSearchConfig,
    iters: usize,
) -> (ReplanOutcome, f64) {
    let fallback = recovery.ckpt_interval_secs;
    if topo.n() == 0 || cfg.candidates.is_empty() {
        return (replanner.cold_plan(topo, wf, job), fallback);
    }

    // Phase 1: structure discovery under a reduced budget.
    let full_budget = replanner.cfg.cold_budget;
    let b1 = ((full_budget as f64) * cfg.structure_frac.clamp(0.05, 0.95)).round() as usize;
    replanner.cfg.cold_budget = b1.max(1);
    let mut base = replanner.cold_plan(topo, wf, job);
    replanner.cfg.cold_budget = full_budget;
    let Some(base_plan) = base.plan.clone() else {
        // No feasible structure: nothing for the arms to refine.
        return (base, fallback);
    };

    // Phase 2: one arm per candidate interval over the remaining
    // budget, each under its own recovery-aware penalty.
    let lambda = unnoticed_loss_rate(trace, recovery, iters);
    let mm = replanner.cfg.migration;
    let seed = replanner.next_episode_seed();
    let grouping = base_plan.task_groups.clone();
    let sizes: Vec<usize> = base_plan.gpu_groups.iter().map(|g| g.len()).collect();
    let threads = engine::resolve_threads(replanner.cfg.threads);
    let arm_budget = full_budget.saturating_sub(base.evals);
    let parent = EvalCtx::new(topo, wf, job, Budget::evals(arm_budget));

    let mut live: Vec<IntervalArm> = cfg
        .candidates
        .iter()
        .enumerate()
        .map(|(idx, &interval)| IntervalArm {
            idx,
            interval,
            arm: EaArm::new(
                grouping.clone(),
                sizes.clone(),
                replanner.cfg.ea.clone(),
                seed.wrapping_add((idx as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)),
            ),
            best_cost: f64::INFINITY,
            best_plan: None,
        })
        .collect();

    let rounds = cfg.rounds.max(1);
    for round in 0..rounds {
        let quotas = engine::split_quota(parent.ledger.remaining(), live.len(), rounds - round);
        for (slot, ia) in live.iter_mut().enumerate() {
            if quotas[slot] == 0 {
                continue;
            }
            // Child context: shares the ledger and cache with every
            // other arm (global budget cap, shared per-task memo) but
            // carries this arm's own interval penalty and incumbent.
            let mut actx = parent.worker();
            actx.best_cost = ia.best_cost;
            let interval = ia.interval;
            let cache = Arc::clone(&parent.cache);
            let rec = *recovery;
            actx.penalty = Some(Arc::new(move |p: &ExecutionPlan| {
                let it = CostModel::new(topo, wf, job).plan_cost_cached(p, &cache).iter_time;
                let w = rec.ckpt_write_secs(&mm, wf, job, p);
                // `eval` already charged `it`; add the recovery terms.
                it * w / interval + lambda * interval / 2.0
            }));
            let seeds = if round == 0 {
                let mut s = vec![base_plan.clone()];
                s.extend(perturbations(
                    &base_plan,
                    replanner.cfg.seed_mutants,
                    seed ^ (ia.idx as u64).wrapping_mul(0xA5A5_A5A5_A5A5),
                ));
                s
            } else {
                Vec::new()
            };
            let arm = std::mem::replace(
                &mut ia.arm,
                EaArm::new(grouping.clone(), sizes.clone(), replanner.cfg.ea.clone(), 0),
            );
            let mut runs = engine::run_seeded_rung(
                &mut actx,
                vec![SeededArmTask { key: (0, ia.idx), arm, quota: quotas[slot], seeds }],
                threads,
            );
            ia.arm = runs.pop().expect("one task in, one run out").arm;
            if ford::cmp_f64(actx.best_cost, ia.best_cost) == std::cmp::Ordering::Less {
                ia.best_cost = actx.best_cost;
                ia.best_plan = actx.best_plan.take();
            }
        }
        // Halve: keep the better half by penalized objective, ties to
        // the earlier candidate; drop arms that proved infeasible.
        if live.len() > 1 {
            let mut order: Vec<usize> = (0..live.len()).collect();
            order.sort_by(|&a, &b| {
                ford::cmp_f64(live[a].best_cost, live[b].best_cost)
                    .then(live[a].idx.cmp(&live[b].idx))
            });
            let keep = live.len().div_ceil(2);
            let kept: Vec<usize> = order.into_iter().take(keep).collect();
            let mut slot = 0usize;
            live.retain(|ia| {
                let k = kept.contains(&slot);
                slot += 1;
                k
            });
            // `retain` kept slot order; that is candidate order, which
            // is what the next round's quota split iterates in.
        }
        if parent.ledger.exhausted() {
            break;
        }
    }

    // Winner: the surviving arm with the best penalized objective.
    let winner = live
        .into_iter()
        .filter(|ia| ia.best_plan.is_some())
        .min_by(|a, b| ford::cmp_f64(a.best_cost, b.best_cost).then(a.idx.cmp(&b.idx)));

    let spent = parent.ledger.spent();
    base.evals += spent;
    base.cache_hits += parent.cache.hits();
    base.cache_misses += parent.cache.misses();
    match winner {
        Some(ia) => {
            let plan = ia.best_plan.expect("filtered on is_some");
            let iter_time = CostModel::new(topo, wf, job).plan_cost(&plan).iter_time;
            base.iter_time = iter_time;
            base.objective = ia.best_cost;
            base.plan = Some(plan);
            (base, ia.interval)
        }
        // Arms found nothing: keep the structure-discovery plan and the
        // configured cadence.
        None => (base, fallback),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::elastic::events::{generate_trace, ClusterEvent, TraceConfig};
    use crate::elastic::replan::ReplanConfig;
    use crate::scheduler::ea::EaConfig;
    use crate::testing::fixtures;
    use crate::topology::{build_testbed, Scenario};
    use crate::workflow::{Algo, JobConfig, Mode, ModelSpec, RlWorkflow};

    fn setup() -> (RlWorkflow, DeviceTopology, JobConfig) {
        (
            RlWorkflow::new(Algo::Grpo, Mode::Sync, ModelSpec::qwen_1b7()),
            build_testbed(Scenario::MultiCountry, &fixtures::small_spec()),
            JobConfig::tiny(),
        )
    }

    fn small_cfg() -> ReplanConfig {
        ReplanConfig {
            warm_budget: 40,
            cold_budget: 160,
            seed_mutants: 2,
            ea: EaConfig { swap_samples: 40, ..EaConfig::default() },
            ..ReplanConfig::default()
        }
    }

    #[test]
    fn interval_objective_shape() {
        // Overhead term falls with I, rework term grows with I: the
        // objective is unimodal over a swept grid and ∞ at I ≤ 0.
        assert_eq!(interval_objective(10.0, 5.0, 0.1, 0.0), f64::INFINITY);
        let grid = [60.0, 120.0, 300.0, 600.0, 1200.0, 2400.0];
        let objs: Vec<f64> =
            grid.iter().map(|&i| interval_objective(30.0, 20.0, 0.2, i)).collect();
        let mut inflections = 0;
        for w in objs.windows(2) {
            if w[1] < w[0] {
                continue;
            }
            inflections += 1;
        }
        assert!(inflections >= 1, "rework term must eventually dominate: {objs:?}");
        // λ = 0 ⇒ the largest candidate wins (pure overhead amortization).
        assert_eq!(pick_interval_analytic(30.0, 20.0, 0.0, &grid, 600.0), 2400.0);
        // Huge λ ⇒ the smallest candidate wins.
        assert_eq!(pick_interval_analytic(30.0, 20.0, 100.0, &grid, 600.0), 60.0);
        // Empty candidates ⇒ fallback.
        assert_eq!(pick_interval_analytic(30.0, 20.0, 1.0, &[], 450.0), 450.0);
    }

    #[test]
    fn loss_rate_counts_unnoticed_and_exhausted_only() {
        let rec = RecoveryModel { max_retries: 2, ..RecoveryModel::with_interval(300.0) };
        let mk = |event, notice_secs| TraceEvent { at_iter: 1, event, notice_secs };
        let trace = vec![
            mk(ClusterEvent::MachinePreempt { machine: 0 }, None), // counts
            mk(ClusterEvent::MachineLeave { machine: 1 }, Some(120.0)), // noticed: no
            mk(ClusterEvent::TaskFailure { device: 0, attempts: 3 }, None), // exceeds budget
            mk(ClusterEvent::TaskFailure { device: 1, attempts: 2 }, None), // within: no
            mk(ClusterEvent::CkptOutage { attempts: 4 }, None),    // not a loss
        ];
        assert!((unnoticed_loss_rate(&trace, &rec, 10) - 0.2).abs() < 1e-12);
        assert_eq!(unnoticed_loss_rate(&[], &rec, 10), 0.0);
    }

    #[test]
    fn searched_interval_is_deterministic_across_threads() {
        let (wf, topo, job) = setup();
        let rec = RecoveryModel::with_interval(600.0);
        let scfg = CkptSearchConfig {
            candidates: vec![120.0, 600.0],
            rounds: 2,
            ..CkptSearchConfig::default()
        };
        let trace = generate_trace(
            &topo,
            &TraceConfig { horizon: 8, n_events: 3, ..TraceConfig::default() },
            7,
        );
        let run = |threads: usize| {
            let mut rp = Replanner::new(21, ReplanConfig { threads, ..small_cfg() });
            plan_with_ckpt_interval(&mut rp, &topo, &wf, &job, &trace, &rec, &scfg, 8)
        };
        let baseline = run(1);
        assert!(baseline.0.evals <= small_cfg().cold_budget, "budget overrun");
        for threads in fixtures::test_threads() {
            let (out, interval) = run(threads);
            assert_eq!(out.plan, baseline.0.plan, "plan diverged at {threads} threads");
            assert_eq!(interval, baseline.1, "interval diverged at {threads} threads");
            assert_eq!(out.evals, baseline.0.evals);
        }
    }

    #[test]
    fn loss_free_trace_prefers_longer_intervals() {
        // With λ = 0 the penalty is pure cadence overhead, so whenever
        // both arms evolve the same plan the longer interval must win.
        let (wf, topo, job) = setup();
        let rec = RecoveryModel::with_interval(600.0);
        let scfg = CkptSearchConfig {
            candidates: vec![60.0, 1200.0],
            rounds: 1,
            ..CkptSearchConfig::default()
        };
        let mut rp = Replanner::new(33, small_cfg());
        let (out, interval) = plan_with_ckpt_interval(
            &mut rp, &topo, &wf, &job, &[], &rec, &scfg, 8,
        );
        if out.plan.is_some() {
            assert_eq!(interval, 1200.0, "λ=0 must amortize toward the long cadence");
        }
    }
}
