//! Cluster event model and the deterministic, seeded trace generator.
//!
//! Events are expressed against the *base* topology (machine indices,
//! base device ids, region indices), never against a snapshot's
//! renumbered ids — [`super::fleet::FleetState`] owns the translation.
//! Traces are ordered by iteration index; the generator is a pure
//! function of `(base topology, config, seed)` so a replay is exactly
//! reproducible.
//!
//! Machine-loss events may carry an **advance notice window**
//! ([`TraceEvent::notice_secs`]): real spot fleets emit termination
//! warnings (e.g. the 2-minute AWS spot notice) and graceful drains are
//! announced minutes ahead. The generator draws realistic notice for
//! preempt/leave events; [`TraceConfig::notice_override`] pins it to a
//! fixed value (or disables it) without changing the event sequence, so
//! the same seed yields the same fleet dynamics with or without notice.
//! The `preempt` replay policy ([`super::replay::Policy::Preempt`])
//! uses the notice to pre-warm a plan for the post-event fleet.

use crate::topology::DeviceTopology;
use crate::util::rng::Rng;

/// One dynamic event in the life of the fleet.
#[derive(Debug, Clone, PartialEq)]
pub enum ClusterEvent {
    /// Spot preemption: the machine vanishes with (effectively) no
    /// notice — its task state is lost unless replicated elsewhere.
    MachinePreempt { machine: usize },
    /// Graceful departure (scale-down / maintenance drain).
    MachineLeave { machine: usize },
    /// A previously departed machine rejoins the fleet.
    MachineJoin { machine: usize },
    /// WAN degradation between two regions: latency multiplied by
    /// `lat_factor` (≥ 1), bandwidth by `bw_factor` (≤ 1).
    LinkDegrade { ra: usize, rb: usize, lat_factor: f64, bw_factor: f64 },
    /// The region pair's links return to their base state.
    LinkRestore { ra: usize, rb: usize },
    /// A device starts underperforming (thermal throttling, noisy
    /// neighbour): sustained speed multiplied by `slowdown` (≤ 1).
    StragglerOnset { device: usize, slowdown: f64 },
    /// The straggler recovers.
    StragglerClear { device: usize },
    /// Transient fault: one machine's NIC degrades (flapping optics,
    /// overloaded ToR port) — every cross-machine link touching it has
    /// its bandwidth multiplied by `bw_factor` (≤ 1) until the paired
    /// [`ClusterEvent::NicRestore`]. The runtime retries the flaky
    /// transfers; `attempts` is how many (deterministic, drawn by the
    /// generator) it takes to work around the burst, each priced by the
    /// [`crate::costmodel::RecoveryModel`] backoff. With a zero-retry
    /// policy the stall vanishes and the event degenerates to a plain
    /// bandwidth degradation.
    NicDegrade { machine: usize, bw_factor: f64, attempts: usize },
    /// The machine's NIC returns to its base bandwidth.
    NicRestore { machine: usize },
    /// Transient fault: the checkpoint/object store becomes
    /// unreachable. While down, no checkpoint completes (the recovery
    /// model's stable point freezes, lengthening the rollback exposure
    /// window) and reconnection is retried `attempts` times.
    CkptOutage { attempts: usize },
    /// The checkpoint store is reachable again.
    CkptRestore,
    /// Transient fault: one task attempt on `device` crashes (CUDA
    /// error, OOM spike, wedged collective) and is retried with
    /// deterministic backoff. If `attempts` exceeds the retry budget
    /// the iteration's progress is lost and a rollback to the last
    /// completed checkpoint is charged.
    TaskFailure { device: usize, attempts: usize },
}

impl ClusterEvent {
    /// Whether this is a machine-loss event (preempt or graceful
    /// leave) — the only kind that can carry advance notice and the
    /// only kind predictive preemption anticipates.
    pub fn is_machine_loss(&self) -> bool {
        matches!(
            self,
            ClusterEvent::MachinePreempt { .. } | ClusterEvent::MachineLeave { .. }
        )
    }

    /// Whether this is a transient fault — the retried kind
    /// ([`ClusterEvent::NicDegrade`], [`ClusterEvent::CkptOutage`],
    /// [`ClusterEvent::TaskFailure`]) whose recovery attempts are
    /// priced by the retry/backoff policy. Restore events are not
    /// faults.
    pub fn is_transient_fault(&self) -> bool {
        matches!(
            self,
            ClusterEvent::NicDegrade { .. }
                | ClusterEvent::CkptOutage { .. }
                | ClusterEvent::TaskFailure { .. }
        )
    }

    /// Retry attempts a transient fault needs to clear (`None` for
    /// every non-fault event).
    pub fn attempts(&self) -> Option<usize> {
        match *self {
            ClusterEvent::NicDegrade { attempts, .. }
            | ClusterEvent::CkptOutage { attempts }
            | ClusterEvent::TaskFailure { attempts, .. } => Some(attempts),
            _ => None,
        }
    }

    /// Compact display label for timelines and run records.
    pub fn label(&self) -> String {
        match self {
            ClusterEvent::MachinePreempt { machine } => format!("preempt(m{machine})"),
            ClusterEvent::MachineLeave { machine } => format!("leave(m{machine})"),
            ClusterEvent::MachineJoin { machine } => format!("join(m{machine})"),
            ClusterEvent::LinkDegrade { ra, rb, bw_factor, .. } => {
                format!("degrade(r{ra}-r{rb},bw×{bw_factor:.2})")
            }
            ClusterEvent::LinkRestore { ra, rb } => format!("restore(r{ra}-r{rb})"),
            ClusterEvent::StragglerOnset { device, slowdown } => {
                format!("straggler(d{device},×{slowdown:.2})")
            }
            ClusterEvent::StragglerClear { device } => format!("recover(d{device})"),
            ClusterEvent::NicDegrade { machine, bw_factor, attempts } => {
                format!("nic(m{machine},bw×{bw_factor:.2},a{attempts})")
            }
            ClusterEvent::NicRestore { machine } => format!("nic-ok(m{machine})"),
            ClusterEvent::CkptOutage { attempts } => format!("ckpt-out(a{attempts})"),
            ClusterEvent::CkptRestore => "ckpt-ok".to_string(),
            ClusterEvent::TaskFailure { device, attempts } => {
                format!("taskfail(d{device},a{attempts})")
            }
        }
    }
}

/// An event stamped with the training iteration *before* which it fires.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    /// The event fires before iteration `at_iter` starts.
    pub at_iter: usize,
    /// The cluster change itself.
    pub event: ClusterEvent,
    /// Advance notice, in simulated seconds, that the scheduler receives
    /// before the event lands (`None` = the event strikes unannounced).
    /// Only machine-loss events (preempt/leave) ever carry notice.
    pub notice_secs: Option<f64>,
}

impl TraceEvent {
    /// [`ClusterEvent::label`] with the notice window appended when one
    /// is present, e.g. `preempt(m3) [notice 90s]`.
    pub fn label(&self) -> String {
        match self.notice_secs {
            Some(n) => format!("{} [notice {n:.0}s]", self.event.label()),
            None => self.event.label(),
        }
    }

    /// [`ClusterEvent::is_machine_loss`] of the carried event.
    pub fn is_machine_loss(&self) -> bool {
        self.event.is_machine_loss()
    }

    /// [`ClusterEvent::is_transient_fault`] of the carried event.
    pub fn is_transient_fault(&self) -> bool {
        self.event.is_transient_fault()
    }
}

/// Trace-generation knobs.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    /// Iterations the trace spans; events land in `[1, horizon)`.
    pub horizon: usize,
    /// Number of events to generate (rejoin/restore events that pair
    /// with earlier ones count toward this too).
    pub n_events: usize,
    /// The fleet never shrinks below this fraction of its machines.
    pub min_active_frac: f64,
    /// Guarantee at least one machine preemption (the fig11 scenario).
    pub force_preempt: bool,
    /// Pin the notice window of every machine-loss event instead of
    /// drawing realistic values: `Some(n)` with `n > 0` gives every
    /// preempt/leave exactly `n` seconds of notice, `Some(0.0)` (or any
    /// non-positive value) strips all notice, `None` (default) lets the
    /// generator draw. The override is applied *after* generation, so
    /// the event sequence for a seed is identical whatever it is set to.
    pub notice_override: Option<f64>,
    /// Number of *transient-fault* events (NIC bursts, checkpoint-store
    /// outages, task failures) to inject on top of the base trace.
    /// Faults are drawn by a **separate** RNG stream, so `0` (the
    /// default) leaves the base trace bit-identical to a pre-fault
    /// generator run for the same seed.
    pub fault_events: usize,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            horizon: 24,
            n_events: 5,
            min_active_frac: 0.5,
            force_preempt: true,
            notice_override: None,
            fault_events: 0,
        }
    }
}

/// Distinct machine indices of a topology, ascending.
fn machine_ids(topo: &DeviceTopology) -> Vec<usize> {
    let mut ids: Vec<usize> = topo.devices.iter().map(|d| d.machine).collect();
    ids.sort_unstable();
    ids.dedup();
    ids
}

/// Distinct cross-region pairs `(ra < rb)` present in the topology.
fn region_pairs(topo: &DeviceTopology) -> Vec<(usize, usize)> {
    let mut regions: Vec<usize> = topo.devices.iter().map(|d| d.region).collect();
    regions.sort_unstable();
    regions.dedup();
    let mut pairs = Vec::new();
    for (i, &a) in regions.iter().enumerate() {
        for &b in regions.iter().skip(i + 1) {
            pairs.push((a, b));
        }
    }
    pairs
}

/// Realistic advance notice for a machine-loss event: spot preemptions
/// get the short spot-warning window (30–120 s) — except for a quarter
/// of them, which strike unannounced — while graceful drains are
/// announced well ahead (2–10 min).
fn draw_notice(rng: &mut Rng, preempt: bool) -> Option<f64> {
    if preempt {
        if rng.chance(0.25) {
            None
        } else {
            Some(30.0 + 90.0 * rng.f64())
        }
    } else {
        Some(120.0 + 480.0 * rng.f64())
    }
}

/// Generate a deterministic event trace for `topo`. Same `(topo, cfg,
/// seed)` → identical trace, bit for bit. Generated events are mutually
/// consistent: only active machines leave, only departed machines
/// rejoin, only healthy devices become stragglers, and the active
/// machine count never drops below `min_active_frac`. Machine-loss
/// events carry drawn (or [`TraceConfig::notice_override`]-pinned)
/// advance-notice windows.
pub fn generate_trace(topo: &DeviceTopology, cfg: &TraceConfig, seed: u64) -> Vec<TraceEvent> {
    let mut rng = Rng::new(seed ^ 0xE1A5_71C0_FFEE);
    let machines = machine_ids(topo);
    let pairs = region_pairs(topo);
    // `min_active_frac <= 0` deliberately permits losing *every*
    // machine — the all-loss chaos scenario the degraded replay path
    // must survive (see `super::replay`).
    let floor = if cfg.min_active_frac <= 0.0 {
        0
    } else {
        ((machines.len() as f64 * cfg.min_active_frac).ceil() as usize).max(1)
    };

    // Mutable world model mirrored while generating.
    let mut active: Vec<usize> = machines.clone();
    let mut departed: Vec<usize> = Vec::new();
    let mut degraded: Vec<(usize, usize)> = Vec::new();
    let mut stragglers: Vec<usize> = Vec::new();

    // Event iterations: sorted, in [1, horizon).
    let hi = cfg.horizon.max(2);
    let mut iters: Vec<usize> = (0..cfg.n_events).map(|_| rng.range(1, hi)).collect();
    iters.sort_unstable();

    let mut out: Vec<TraceEvent> = Vec::new();
    for (k, &at_iter) in iters.iter().enumerate() {
        // The first event is a preemption when forced (and legal).
        let force_now = cfg.force_preempt && k == 0 && active.len() > floor;
        let (event, drawn_notice) = loop {
            let roll = if force_now { 0 } else { rng.below(100) };
            match roll {
                // 0..35: machine loss (preempt or graceful).
                r if r < 35 => {
                    if active.len() <= floor {
                        continue;
                    }
                    let m = *rng.choice(&active);
                    active.retain(|&x| x != m);
                    departed.push(m);
                    let preempt = force_now || rng.chance(0.7);
                    let notice = draw_notice(&mut rng, preempt);
                    break (
                        if preempt {
                            ClusterEvent::MachinePreempt { machine: m }
                        } else {
                            ClusterEvent::MachineLeave { machine: m }
                        },
                        notice,
                    );
                }
                // 35..50: rejoin.
                r if r < 50 => {
                    if departed.is_empty() {
                        continue;
                    }
                    let m = *rng.choice(&departed);
                    departed.retain(|&x| x != m);
                    active.push(m);
                    break (ClusterEvent::MachineJoin { machine: m }, None);
                }
                // 50..75: WAN bandwidth/latency shift.
                r if r < 75 => {
                    if pairs.is_empty() {
                        continue;
                    }
                    let &(ra, rb) = rng.choice(&pairs);
                    if degraded.contains(&(ra, rb)) {
                        degraded.retain(|&p| p != (ra, rb));
                        break (ClusterEvent::LinkRestore { ra, rb }, None);
                    }
                    degraded.push((ra, rb));
                    break (
                        ClusterEvent::LinkDegrade {
                            ra,
                            rb,
                            lat_factor: 1.0 + 3.0 * rng.f64(),
                            bw_factor: 0.15 + 0.5 * rng.f64(),
                        },
                        None,
                    );
                }
                // 75..100: straggler onset/clear.
                _ => {
                    if !stragglers.is_empty() && rng.chance(0.4) {
                        let d = *rng.choice(&stragglers);
                        stragglers.retain(|&x| x != d);
                        break (ClusterEvent::StragglerClear { device: d }, None);
                    }
                    // Pick a device on an active machine.
                    let candidates: Vec<usize> = topo
                        .devices
                        .iter()
                        .filter(|d| active.contains(&d.machine) && !stragglers.contains(&d.id))
                        .map(|d| d.id)
                        .collect();
                    if candidates.is_empty() {
                        continue;
                    }
                    let d = *rng.choice(&candidates);
                    stragglers.push(d);
                    break (
                        ClusterEvent::StragglerOnset {
                            device: d,
                            slowdown: 0.25 + 0.5 * rng.f64(),
                        },
                        None,
                    );
                }
            }
        };
        // The override replaces drawn notice without touching the RNG
        // stream, so the event sequence is identical either way.
        let notice_secs = match (event.is_machine_loss(), cfg.notice_override) {
            (false, _) => None,
            (true, None) => drawn_notice,
            (true, Some(n)) if n > 0.0 => Some(n),
            (true, Some(_)) => None,
        };
        out.push(TraceEvent { at_iter, event, notice_secs });
    }
    if cfg.fault_events > 0 {
        out = merge_by_iter(out, generate_faults(topo, cfg, seed));
    }
    out
}

/// Generate `cfg.fault_events` transient faults from a dedicated RNG
/// stream (`seed ^ 0x_FA17_5EED_CAFE`). Keeping the stream separate
/// from the base generator's is what makes the base trace bit-identical
/// whether faults are requested or not — `fault_events = 0` consumes no
/// randomness at all.
fn generate_faults(topo: &DeviceTopology, cfg: &TraceConfig, seed: u64) -> Vec<TraceEvent> {
    let mut rng = Rng::new(seed ^ 0xFA17_5EED_CAFE);
    let machines = machine_ids(topo);
    let hi = cfg.horizon.max(2);
    let mut iters: Vec<usize> = (0..cfg.fault_events).map(|_| rng.range(1, hi)).collect();
    iters.sort_unstable();

    // Mirror of the fault-relevant world state.
    let mut nic_degraded: Vec<usize> = Vec::new();
    let mut store_down = false;

    let mut out = Vec::with_capacity(iters.len());
    for &at_iter in &iters {
        let event = match rng.below(100) {
            // 0..45: NIC burst onset, or the paired restore when the
            // drawn machine is already degraded.
            r if r < 45 => {
                let m = *rng.choice(&machines);
                if nic_degraded.contains(&m) {
                    nic_degraded.retain(|&x| x != m);
                    ClusterEvent::NicRestore { machine: m }
                } else {
                    nic_degraded.push(m);
                    ClusterEvent::NicDegrade {
                        machine: m,
                        bw_factor: 0.2 + 0.5 * rng.f64(),
                        attempts: 1 + rng.below(4),
                    }
                }
            }
            // 45..65: checkpoint-store outage toggle.
            r if r < 65 => {
                store_down = !store_down;
                if store_down {
                    ClusterEvent::CkptOutage { attempts: 1 + rng.below(4) }
                } else {
                    ClusterEvent::CkptRestore
                }
            }
            // 65..100: task-level failure on a random base device.
            _ => ClusterEvent::TaskFailure {
                device: rng.below(topo.n()),
                attempts: 1 + rng.below(4),
            },
        };
        out.push(TraceEvent { at_iter, event, notice_secs: None });
    }
    out
}

/// Stable merge of two `at_iter`-sorted traces: base events sort before
/// fault events at the same iteration, and relative order within each
/// stream is preserved — so the merged trace is a pure function of its
/// two inputs.
fn merge_by_iter(base: Vec<TraceEvent>, faults: Vec<TraceEvent>) -> Vec<TraceEvent> {
    let mut out = Vec::with_capacity(base.len() + faults.len());
    let (mut bi, mut fi) = (0, 0);
    while bi < base.len() && fi < faults.len() {
        if base[bi].at_iter <= faults[fi].at_iter {
            out.push(base[bi].clone());
            bi += 1;
        } else {
            out.push(faults[fi].clone());
            fi += 1;
        }
    }
    out.extend_from_slice(&base[bi..]);
    out.extend_from_slice(&faults[fi..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{build_testbed, Scenario, TestbedSpec};

    fn topo() -> DeviceTopology {
        build_testbed(Scenario::MultiCountry, &TestbedSpec::default())
    }

    #[test]
    fn deterministic_per_seed() {
        let t = topo();
        let cfg = TraceConfig::default();
        let a = generate_trace(&t, &cfg, 7);
        let b = generate_trace(&t, &cfg, 7);
        assert_eq!(a, b);
        let c = generate_trace(&t, &cfg, 8);
        assert_ne!(a, c);
    }

    #[test]
    fn sorted_and_sized() {
        let t = topo();
        let cfg = TraceConfig { n_events: 8, ..TraceConfig::default() };
        let trace = generate_trace(&t, &cfg, 3);
        assert_eq!(trace.len(), 8);
        for w in trace.windows(2) {
            assert!(w[0].at_iter <= w[1].at_iter);
        }
        for e in &trace {
            assert!(e.at_iter >= 1 && e.at_iter < cfg.horizon);
        }
    }

    #[test]
    fn forced_preempt_present() {
        let t = topo();
        for seed in 0..12 {
            let trace = generate_trace(&t, &TraceConfig::default(), seed);
            assert!(
                trace
                    .iter()
                    .any(|e| matches!(e.event, ClusterEvent::MachinePreempt { .. })),
                "seed {seed} lacks a preemption"
            );
        }
    }

    #[test]
    fn machine_floor_respected() {
        let t = topo();
        let cfg = TraceConfig { n_events: 24, min_active_frac: 0.5, ..TraceConfig::default() };
        for seed in 0..6 {
            let trace = generate_trace(&t, &cfg, seed);
            let mut active = 8i64; // default testbed: 8 machines
            let mut min_seen = active;
            for e in &trace {
                match e.event {
                    ClusterEvent::MachinePreempt { .. } | ClusterEvent::MachineLeave { .. } => {
                        active -= 1
                    }
                    ClusterEvent::MachineJoin { .. } => active += 1,
                    _ => {}
                }
                min_seen = min_seen.min(active);
            }
            assert!(min_seen >= 4, "seed {seed}: dropped to {min_seen} machines");
        }
    }

    #[test]
    fn notice_only_on_machine_loss_events() {
        let t = topo();
        let cfg = TraceConfig { n_events: 24, ..TraceConfig::default() };
        for seed in 0..8 {
            for e in generate_trace(&t, &cfg, seed) {
                if !e.is_machine_loss() {
                    assert_eq!(e.notice_secs, None, "non-loss event with notice: {}", e.label());
                } else if let Some(n) = e.notice_secs {
                    assert!(n > 0.0 && n <= 600.0, "implausible notice {n}");
                }
            }
        }
    }

    #[test]
    fn notice_override_pins_without_changing_events() {
        let t = topo();
        let base_cfg = TraceConfig { n_events: 12, ..TraceConfig::default() };
        for seed in 0..6 {
            let drawn = generate_trace(&t, &base_cfg, seed);
            let pinned = generate_trace(
                &t,
                &TraceConfig { notice_override: Some(45.0), ..base_cfg.clone() },
                seed,
            );
            let none = generate_trace(
                &t,
                &TraceConfig { notice_override: Some(0.0), ..base_cfg.clone() },
                seed,
            );
            assert_eq!(drawn.len(), pinned.len());
            for ((d, p), z) in drawn.iter().zip(&pinned).zip(&none) {
                // Same events, same order — only the notice differs.
                assert_eq!(d.event, p.event);
                assert_eq!(d.at_iter, p.at_iter);
                assert_eq!(d.event, z.event);
                assert_eq!(p.notice_secs, p.is_machine_loss().then_some(45.0));
                assert_eq!(z.notice_secs, None);
            }
        }
    }

    #[test]
    fn faults_do_not_perturb_the_base_trace() {
        let t = topo();
        let base_cfg = TraceConfig::default();
        for seed in 0..6 {
            let plain = generate_trace(&t, &base_cfg, seed);
            let faulty = generate_trace(
                &t,
                &TraceConfig { fault_events: 6, ..base_cfg.clone() },
                seed,
            );
            assert_eq!(faulty.len(), plain.len() + 6);
            // Dropping the fault events recovers the base trace exactly
            // (separate RNG streams) ...
            let stripped: Vec<TraceEvent> = faulty
                .iter()
                .filter(|e| {
                    !e.is_transient_fault()
                        && !matches!(
                            e.event,
                            ClusterEvent::NicRestore { .. } | ClusterEvent::CkptRestore
                        )
                })
                .cloned()
                .collect();
            assert_eq!(stripped, plain);
            // ... and the merged trace stays iteration-sorted.
            for w in faulty.windows(2) {
                assert!(w[0].at_iter <= w[1].at_iter);
            }
            // Faults never carry notice, always carry attempts ≥ 1.
            for e in &faulty {
                if e.is_transient_fault() {
                    assert_eq!(e.notice_secs, None);
                    assert!(e.event.attempts().unwrap() >= 1);
                }
            }
        }
    }

    #[test]
    fn fault_stream_is_deterministic() {
        let t = topo();
        let cfg = TraceConfig { fault_events: 8, ..TraceConfig::default() };
        assert_eq!(generate_trace(&t, &cfg, 11), generate_trace(&t, &cfg, 11));
        assert_ne!(generate_trace(&t, &cfg, 11), generate_trace(&t, &cfg, 12));
    }

    #[test]
    fn zero_floor_permits_total_loss() {
        let t = topo();
        let cfg = TraceConfig {
            n_events: 64,
            min_active_frac: 0.0,
            force_preempt: true,
            ..TraceConfig::default()
        };
        // With enough events and no floor, at least one seed must drive
        // the fleet to zero machines at some point.
        let mut saw_total_loss = false;
        for seed in 0..8 {
            let trace = generate_trace(&t, &cfg, seed);
            let mut active = 8i64;
            for e in &trace {
                match e.event {
                    ClusterEvent::MachinePreempt { .. } | ClusterEvent::MachineLeave { .. } => {
                        active -= 1
                    }
                    ClusterEvent::MachineJoin { .. } => active += 1,
                    _ => {}
                }
                assert!(active >= 0, "seed {seed}: negative machine count");
                if active == 0 {
                    saw_total_loss = true;
                }
            }
        }
        assert!(saw_total_loss, "no seed ever emptied the fleet");
    }

    #[test]
    fn single_region_has_no_link_events() {
        let t = build_testbed(Scenario::SingleRegion, &TestbedSpec::default());
        let trace = generate_trace(&t, &TraceConfig { n_events: 16, ..Default::default() }, 1);
        assert!(trace.iter().all(|e| !matches!(
            e.event,
            ClusterEvent::LinkDegrade { .. } | ClusterEvent::LinkRestore { .. }
        )));
    }
}
