//! Cluster event model and the deterministic, seeded trace generator.
//!
//! Events are expressed against the *base* topology (machine indices,
//! base device ids, region indices), never against a snapshot's
//! renumbered ids — [`super::fleet::FleetState`] owns the translation.
//! Traces are ordered by iteration index; the generator is a pure
//! function of `(base topology, config, seed)` so a replay is exactly
//! reproducible.

use crate::topology::DeviceTopology;
use crate::util::rng::Rng;

/// One dynamic event in the life of the fleet.
#[derive(Debug, Clone, PartialEq)]
pub enum ClusterEvent {
    /// Spot preemption: the machine vanishes with (effectively) no
    /// notice — its task state is lost unless replicated elsewhere.
    MachinePreempt { machine: usize },
    /// Graceful departure (scale-down / maintenance drain).
    MachineLeave { machine: usize },
    /// A previously departed machine rejoins the fleet.
    MachineJoin { machine: usize },
    /// WAN degradation between two regions: latency multiplied by
    /// `lat_factor` (≥ 1), bandwidth by `bw_factor` (≤ 1).
    LinkDegrade { ra: usize, rb: usize, lat_factor: f64, bw_factor: f64 },
    /// The region pair's links return to their base state.
    LinkRestore { ra: usize, rb: usize },
    /// A device starts underperforming (thermal throttling, noisy
    /// neighbour): sustained speed multiplied by `slowdown` (≤ 1).
    StragglerOnset { device: usize, slowdown: f64 },
    /// The straggler recovers.
    StragglerClear { device: usize },
}

impl ClusterEvent {
    /// Compact display label for timelines and run records.
    pub fn label(&self) -> String {
        match self {
            ClusterEvent::MachinePreempt { machine } => format!("preempt(m{machine})"),
            ClusterEvent::MachineLeave { machine } => format!("leave(m{machine})"),
            ClusterEvent::MachineJoin { machine } => format!("join(m{machine})"),
            ClusterEvent::LinkDegrade { ra, rb, bw_factor, .. } => {
                format!("degrade(r{ra}-r{rb},bw×{bw_factor:.2})")
            }
            ClusterEvent::LinkRestore { ra, rb } => format!("restore(r{ra}-r{rb})"),
            ClusterEvent::StragglerOnset { device, slowdown } => {
                format!("straggler(d{device},×{slowdown:.2})")
            }
            ClusterEvent::StragglerClear { device } => format!("recover(d{device})"),
        }
    }
}

/// An event stamped with the training iteration *before* which it fires.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub at_iter: usize,
    pub event: ClusterEvent,
}

/// Trace-generation knobs.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    /// Iterations the trace spans; events land in `[1, horizon)`.
    pub horizon: usize,
    /// Number of events to generate (rejoin/restore events that pair
    /// with earlier ones count toward this too).
    pub n_events: usize,
    /// The fleet never shrinks below this fraction of its machines.
    pub min_active_frac: f64,
    /// Guarantee at least one machine preemption (the fig11 scenario).
    pub force_preempt: bool,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            horizon: 24,
            n_events: 5,
            min_active_frac: 0.5,
            force_preempt: true,
        }
    }
}

/// Distinct machine indices of a topology, ascending.
fn machine_ids(topo: &DeviceTopology) -> Vec<usize> {
    let mut ids: Vec<usize> = topo.devices.iter().map(|d| d.machine).collect();
    ids.sort_unstable();
    ids.dedup();
    ids
}

/// Distinct cross-region pairs `(ra < rb)` present in the topology.
fn region_pairs(topo: &DeviceTopology) -> Vec<(usize, usize)> {
    let mut regions: Vec<usize> = topo.devices.iter().map(|d| d.region).collect();
    regions.sort_unstable();
    regions.dedup();
    let mut pairs = Vec::new();
    for (i, &a) in regions.iter().enumerate() {
        for &b in regions.iter().skip(i + 1) {
            pairs.push((a, b));
        }
    }
    pairs
}

/// Generate a deterministic event trace for `topo`. Same `(topo, cfg,
/// seed)` → identical trace, bit for bit. Generated events are mutually
/// consistent: only active machines leave, only departed machines
/// rejoin, only healthy devices become stragglers, and the active
/// machine count never drops below `min_active_frac`.
pub fn generate_trace(topo: &DeviceTopology, cfg: &TraceConfig, seed: u64) -> Vec<TraceEvent> {
    let mut rng = Rng::new(seed ^ 0xE1A5_71C0_FFEE);
    let machines = machine_ids(topo);
    let pairs = region_pairs(topo);
    let floor = ((machines.len() as f64 * cfg.min_active_frac).ceil() as usize).max(1);

    // Mutable world model mirrored while generating.
    let mut active: Vec<usize> = machines.clone();
    let mut departed: Vec<usize> = Vec::new();
    let mut degraded: Vec<(usize, usize)> = Vec::new();
    let mut stragglers: Vec<usize> = Vec::new();

    // Event iterations: sorted, in [1, horizon).
    let hi = cfg.horizon.max(2);
    let mut iters: Vec<usize> = (0..cfg.n_events).map(|_| rng.range(1, hi)).collect();
    iters.sort_unstable();

    let mut out: Vec<TraceEvent> = Vec::new();
    for (k, &at_iter) in iters.iter().enumerate() {
        // The first event is a preemption when forced (and legal).
        let force_now = cfg.force_preempt && k == 0 && active.len() > floor;
        let event = loop {
            let roll = if force_now { 0 } else { rng.below(100) };
            match roll {
                // 0..35: machine loss (preempt or graceful).
                r if r < 35 => {
                    if active.len() <= floor {
                        continue;
                    }
                    let m = *rng.choice(&active);
                    active.retain(|&x| x != m);
                    departed.push(m);
                    break if force_now || rng.chance(0.7) {
                        ClusterEvent::MachinePreempt { machine: m }
                    } else {
                        ClusterEvent::MachineLeave { machine: m }
                    };
                }
                // 35..50: rejoin.
                r if r < 50 => {
                    if departed.is_empty() {
                        continue;
                    }
                    let m = *rng.choice(&departed);
                    departed.retain(|&x| x != m);
                    active.push(m);
                    break ClusterEvent::MachineJoin { machine: m };
                }
                // 50..75: WAN bandwidth/latency shift.
                r if r < 75 => {
                    if pairs.is_empty() {
                        continue;
                    }
                    let &(ra, rb) = rng.choice(&pairs);
                    if degraded.contains(&(ra, rb)) {
                        degraded.retain(|&p| p != (ra, rb));
                        break ClusterEvent::LinkRestore { ra, rb };
                    }
                    degraded.push((ra, rb));
                    break ClusterEvent::LinkDegrade {
                        ra,
                        rb,
                        lat_factor: 1.0 + 3.0 * rng.f64(),
                        bw_factor: 0.15 + 0.5 * rng.f64(),
                    };
                }
                // 75..100: straggler onset/clear.
                _ => {
                    if !stragglers.is_empty() && rng.chance(0.4) {
                        let d = *rng.choice(&stragglers);
                        stragglers.retain(|&x| x != d);
                        break ClusterEvent::StragglerClear { device: d };
                    }
                    // Pick a device on an active machine.
                    let candidates: Vec<usize> = topo
                        .devices
                        .iter()
                        .filter(|d| active.contains(&d.machine) && !stragglers.contains(&d.id))
                        .map(|d| d.id)
                        .collect();
                    if candidates.is_empty() {
                        continue;
                    }
                    let d = *rng.choice(&candidates);
                    stragglers.push(d);
                    break ClusterEvent::StragglerOnset {
                        device: d,
                        slowdown: 0.25 + 0.5 * rng.f64(),
                    };
                }
            }
        };
        out.push(TraceEvent { at_iter, event });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{build_testbed, Scenario, TestbedSpec};

    fn topo() -> DeviceTopology {
        build_testbed(Scenario::MultiCountry, &TestbedSpec::default())
    }

    #[test]
    fn deterministic_per_seed() {
        let t = topo();
        let cfg = TraceConfig::default();
        let a = generate_trace(&t, &cfg, 7);
        let b = generate_trace(&t, &cfg, 7);
        assert_eq!(a, b);
        let c = generate_trace(&t, &cfg, 8);
        assert_ne!(a, c);
    }

    #[test]
    fn sorted_and_sized() {
        let t = topo();
        let cfg = TraceConfig { n_events: 8, ..TraceConfig::default() };
        let trace = generate_trace(&t, &cfg, 3);
        assert_eq!(trace.len(), 8);
        for w in trace.windows(2) {
            assert!(w[0].at_iter <= w[1].at_iter);
        }
        for e in &trace {
            assert!(e.at_iter >= 1 && e.at_iter < cfg.horizon);
        }
    }

    #[test]
    fn forced_preempt_present() {
        let t = topo();
        for seed in 0..12 {
            let trace = generate_trace(&t, &TraceConfig::default(), seed);
            assert!(
                trace
                    .iter()
                    .any(|e| matches!(e.event, ClusterEvent::MachinePreempt { .. })),
                "seed {seed} lacks a preemption"
            );
        }
    }

    #[test]
    fn machine_floor_respected() {
        let t = topo();
        let cfg = TraceConfig { n_events: 24, min_active_frac: 0.5, ..TraceConfig::default() };
        for seed in 0..6 {
            let trace = generate_trace(&t, &cfg, seed);
            let mut active = 8i64; // default testbed: 8 machines
            let mut min_seen = active;
            for e in &trace {
                match e.event {
                    ClusterEvent::MachinePreempt { .. } | ClusterEvent::MachineLeave { .. } => {
                        active -= 1
                    }
                    ClusterEvent::MachineJoin { .. } => active += 1,
                    _ => {}
                }
                min_seen = min_seen.min(active);
            }
            assert!(min_seen >= 4, "seed {seed}: dropped to {min_seen} machines");
        }
    }

    #[test]
    fn single_region_has_no_link_events() {
        let t = build_testbed(Scenario::SingleRegion, &TestbedSpec::default());
        let trace = generate_trace(&t, &TraceConfig { n_events: 16, ..Default::default() }, 1);
        assert!(trace.iter().all(|e| !matches!(
            e.event,
            ClusterEvent::LinkDegrade { .. } | ClusterEvent::LinkRestore { .. }
        )));
    }
}
